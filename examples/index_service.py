"""Streaming index service loop: ingest -> query -> compact -> snapshot.

    PYTHONPATH=src python examples/index_service.py [--iters N] [--chunk C]
    PYTHONPATH=src python examples/index_service.py --serve

Simulates the paper's §4.1 "real-time similarity search" service as a
lifecycle: a quantizer bootstrapped on a historical sample, a stream of
fresh series arriving in chunks (hot segment -> sealed IVF-PQ shards),
interleaved queries, deletions of stale ids, a periodic compaction, and a
crash-safe snapshot that a "restarted" service restores and keeps serving
from.  Runs on CPU in seconds; set REPRO_ELASTIC_BACKEND=pallas_interpret
to push every elastic hot path through the Pallas kernel bodies.

The service runs with the observability layer on (``repro.obs``): every
round's ingest and query land in ``service.*`` spans on top of the
library's own ``index.*`` stage spans, and the exit summary reports
per-stage p50/p99 latency, the LB-cascade pruning rate, and the dispatch
routing counters — the same report ``scripts/obs_report.py`` renders
from a ``REPRO_OBS_DUMP`` snapshot.

``--serve`` drives the same stream through the production serving core
(``repro.serve_index``, see docs/serving.md): concurrent client threads
submit queries that a coalescer merges into padded microbatches, while
ingest/delete/compact flow through the writer thread and publish
immutable snapshots — no search ever blocks on a seal.
"""

import argparse
import tempfile
import threading
import time

import jax
import numpy as np

from repro import obs
from repro.core.pq import PQConfig
from repro.data.timeseries import random_walks
from repro.index import (IndexConfig, StreamingIndex, restore_snapshot,
                         save_snapshot, search_sharded)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=12,
                    help="ingest/query rounds")
    ap.add_argument("--chunk", type=int, default=24,
                    help="series inserted per round")
    ap.add_argument("--length", type=int, default=96, help="series length")
    ap.add_argument("--prealign", action="store_true",
                    help="MODWT pre-aligned ingestion (§3.5): every seal "
                         "encodes through the fused prealign_encode kernel")
    ap.add_argument("--measure", default="dtw",
                    help="elastic measure for every stage (coarse routing, "
                         "PQ codebooks, hot-segment scan): a registry name, "
                         "optionally with params ('msm:c=0.5')")
    ap.add_argument("--no-obs", action="store_true",
                    help="leave the observability layer off (zero-overhead "
                         "mode; the exit report is skipped)")
    ap.add_argument("--serve", action="store_true",
                    help="drive the stream through the serving core "
                         "(repro.serve_index): coalesced concurrent "
                         "queries + writer-thread ingest")
    args = ap.parse_args()
    D = args.length
    from repro.core import measures
    spec = measures.resolve(args.measure)

    if not args.no_obs:
        obs.enable()

    # --- bootstrap the shared quantizers on a historical sample ------------
    # With --prealign, seal-time encoding snaps segment boundaries to MODWT
    # change points before quantizing (exact_encode=True keeps the encode on
    # the fused single-kernel dispatch path); queries are pre-aligned the
    # same way inside search, so codes and query LUTs stay comparable.
    sample = random_walks(128, D, seed=0)
    cfg = IndexConfig(
        pq=PQConfig(n_sub=4, codebook_size=32,
                    metric=spec.name, measure_params=spec.params,
                    use_prealign=args.prealign, exact_encode=args.prealign,
                    kmeans_iters=3, dba_iters=1),
        n_lists=8, hot_capacity=64, coarse_iters=4)
    t0 = time.perf_counter()
    index = StreamingIndex.bootstrap(jax.random.PRNGKey(0), sample, cfg)
    print(f"bootstrap: n_lists={cfg.n_lists} hot_capacity={cfg.hot_capacity}"
          f" measure={spec.label}"
          f" ({time.perf_counter() - t0:.2f}s)")

    if args.serve:
        serve_demo(index, args)
        return

    # --- serve the stream ---------------------------------------------------
    queries = random_walks(8, D, seed=99)
    rng = np.random.default_rng(1)
    ingest_h = obs.histogram("stage_seconds", persistent=True,
                             stage="service.ingest")
    query_h = obs.histogram("stage_seconds", persistent=True,
                            stage="service.query")
    for it in range(args.iters):
        fresh = random_walks(args.chunk, D, seed=100 + it)
        t0 = time.perf_counter()
        with obs.span("service.ingest"):
            ids = index.insert(fresh)
        t_ins = time.perf_counter() - t0

        if it % 3 == 2 and index.next_id > 8:   # retire a few stale series
            stale = rng.choice(index.next_id, size=4, replace=False)
            index.delete(stale)

        t0 = time.perf_counter()
        with obs.span("service.query") as sp:
            d, nn = index.search(queries, n_probe=4, topk=3)
            sp.fence(d)
        jax.block_until_ready(d)
        t_q = time.perf_counter() - t0
        s = index.stats()
        print(f"round {it:02d}: +{len(ids)} ids "
              f"({len(ids) / max(t_ins, 1e-9):,.0f}/s), "
              f"query {t_q * 1e3:.1f}ms, segments={s['n_segments']} "
              f"live={s['n_live']} hot={s['hot_fill']}")

    # --- compact ------------------------------------------------------------
    index.flush()                   # seal whatever is still staged in hot
    t0 = time.perf_counter()
    index.compact()
    max_list = index.segments[0].max_list if index.segments else 0
    print(f"compact: -> {index.n_segments} segment "
          f"(max_list={max_list}) in {time.perf_counter() - t0:.2f}s")
    d, nn = index.search(queries, n_probe=4, topk=3)
    print(f"post-compact top-1 ids: {np.asarray(nn)[:, 0].tolist()}")

    # --- snapshot, 'crash', restore, keep serving ---------------------------
    with tempfile.TemporaryDirectory() as snapdir:
        path = save_snapshot(snapdir, index)
        print(f"snapshot: {path}")
        restored = restore_snapshot(snapdir)
        d2, nn2 = restored.search(queries, n_probe=4, topk=3)
        same = bool(np.array_equal(np.asarray(nn), np.asarray(nn2)))
        print(f"restore: {restored.stats()['n_live']} live rows, "
              f"search identical: {same}")
        assert same, "restored index must reproduce pre-snapshot results"

        # sharded planner (1-device mesh on CPU; shards queries on TPU pods)
        d3, nn3 = search_sharded(restored, queries, n_probe=4, topk=3)
        assert np.array_equal(np.asarray(nn2), np.asarray(nn3))
        print("sharded planner agrees with single-device search")

    mem = index.memory_cost()
    print(f"memory: index {mem['index_bytes'] / 1e3:.1f}KB vs raw "
          f"{mem['raw_bytes'] / 1e3:.1f}KB "
          f"({mem['compression']:.1f}x codes-only compression)")

    # --- exit observability summary ------------------------------------------
    if obs.enabled() and ingest_h.count and query_h.count:
        print()
        print(f"service ingest p50/p99: {ingest_h.percentile(50) * 1e3:.1f}"
              f"ms / {ingest_h.percentile(99) * 1e3:.1f}ms "
              f"over {ingest_h.count} rounds")
        print(f"service query  p50/p99: {query_h.percentile(50) * 1e3:.1f}"
              f"ms / {query_h.percentile(99) * 1e3:.1f}ms "
              f"over {query_h.count} rounds")
        print()
        print(obs.render(obs.snapshot(), title="index service obs summary"))


def serve_demo(index, args):
    """--serve: concurrent clients + ingest through `repro.serve_index`."""
    from repro.serve_index import Backpressure, IndexServer, ServeConfig

    D = args.length
    queries = random_walks(8, D, seed=99)
    scfg = ServeConfig(n_probe=4, topk=3, q_buckets=(1, 2, 4, 8))
    answered = []
    client_errors = []
    stop = threading.Event()

    def client(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            rows = rng.choice(len(queries), size=int(rng.integers(1, 4)),
                              replace=False)
            try:
                _, ids = srv.search(queries[rows])
            except Exception as exc:      # surface, don't swallow
                client_errors.append(exc)
                return
            answered.append(ids.shape[0])

    t0 = time.perf_counter()
    with IndexServer(index, scfg) as srv:
        for b in scfg.q_buckets:    # compile each padded bucket once
            srv.search(queries[:b])
        print(f"serve: warmed {len(scfg.q_buckets)} query buckets "
              f"({time.perf_counter() - t0:.2f}s)")

        clients = [threading.Thread(target=client, args=(7 + i,))
                   for i in range(3)]
        for t in clients:
            t.start()
        shed = 0
        t0 = time.perf_counter()
        for it in range(args.iters):
            fresh = random_walks(args.chunk, D, seed=200 + it)
            try:
                srv.insert(fresh).result()      # resolved == visible
            except Backpressure:
                shed += 1
                continue
            if it % 3 == 2:
                srv.delete(np.arange(it, it + 3))
            if it == args.iters // 2:
                # seal the staged rows so later searches take the full
                # coarse -> LUT -> fine sealed path, then merge segments
                srv.flush().result()
                srv.compact().result()
        wall = time.perf_counter() - t0
        stop.set()
        for t in clients:
            t.join()
        version = srv.quiesce()
        st = srv.stats()
        n_live = int(srv.view.n_live())

    if client_errors:
        raise client_errors[0]
    n_q = sum(answered)
    print(f"serve: {len(answered)} requests / {n_q} queries from 3 clients "
          f"({n_q / max(wall, 1e-9):,.0f} q/s) alongside "
          f"{args.iters} ingest rounds, {shed} shed")
    print(f"serve: view version {version}, {n_live} live rows, "
          f"write queue {st['write_queue_depth']} "
          f"(pressure {st['pressure']:.2f})")
    assert n_q > 0 and st["version"] == version

    if obs.enabled():
        print()
        print(obs.render(obs.snapshot(), title="serving obs summary"))


if __name__ == "__main__":
    main()
