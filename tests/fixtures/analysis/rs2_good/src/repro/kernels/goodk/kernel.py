"""Pallas kernel body for the goodk op."""


def goodk_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2
