"""Pure-jnp oracle: de-quantize the key cache and run exact attention.

The kernel must match this bit-for-bit up to fp accumulation order — ADC
scores are algebraically identical to scores against reconstructed keys.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["pq_attn_decode_ref", "reconstruct_keys"]


def reconstruct_keys(k_codes: jnp.ndarray, k_books: jnp.ndarray) -> jnp.ndarray:
    """``codes (S, G, M)``, ``books (G, M, K, Ds)`` -> keys ``(S, G, M*Ds)``."""
    S, G, M = k_codes.shape
    Ds = k_books.shape[-1]
    g_idx = jnp.arange(G)[None, :, None]
    m_idx = jnp.arange(M)[None, None, :]
    gathered = k_books[g_idx, m_idx, k_codes]        # (S, G, M, Ds)
    return gathered.reshape(S, G, M * Ds)


def pq_attn_decode_ref(q: jnp.ndarray, k_codes: jnp.ndarray,
                       k_books: jnp.ndarray, v: jnp.ndarray,
                       valid_len: Optional[int] = None) -> jnp.ndarray:
    H, D = q.shape
    S, G, M = k_codes.shape
    R = H // G
    if valid_len is None:
        valid_len = S
    khat = reconstruct_keys(k_codes.astype(jnp.int32),
                            k_books.astype(jnp.float32))  # (S, G, D)
    qg = q.astype(jnp.float32).reshape(G, R, D)
    scores = jnp.einsum("grd,sgd->grs", qg, khat) / (D ** 0.5)
    mask = jnp.arange(S)[None, None, :] < valid_len
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)               # (G, R, S)
    out = jnp.einsum("grs,sgd->grd", p, v.astype(jnp.float32))
    return out.reshape(H, -1)
