"""Kernel launch autotuner: measured block sizes per launch geometry.

Every ``make_*_call`` builder in this package takes ``block`` /
``block_a`` / ``block_b`` sizes that until now were hardcoded defaults.
This module replaces the hardcoding with a *tuning table* keyed by

    (op, L bucket, window bucket, measure, backend)

consulted by the ops wrappers whenever the caller passes ``block=None``
(the new default throughout :mod:`repro.core.dispatch`).  An explicit
``block`` always wins — that is also how the tuner's own measurement
runs bypass the table.

``REPRO_TUNE`` selects the mode:

``off`` (default)
    No table: every lookup returns the builtin default.  CI's
    recompile gate and the test suite run here — launch geometry is
    byte-stable.
``auto``
    First use of an (op, geometry) key benchmarks the candidate grid,
    memoizes the winner in-process and persists it to a JSON table under
    ``experiments/tune/`` (override the directory with
    ``REPRO_TUNE_OUT``).  ``REPRO_TUNE_GRID=minimal`` shrinks every
    candidate grid to the single builtin default — the bench-smoke CI
    leg uses this so the auto path is exercised without making warm-path
    compile counts data-dependent.
``<path>``
    A pinned table: lookups are read-only from the JSON file at
    ``<path>`` (deterministic; missing keys fall back to the default).

Measurement runs never trigger inside an active JAX trace (the resolved
block is a *static* argument, so resolution happens at trace time): if
the trace state is not clean the lookup silently returns the memoized or
default value instead of benchmarking.

The table also carries the adaptive-corridor register width
(``op="adaptive_width"``): the width cap for ``band="adaptive"`` sweeps
derives from the corridor geometry bucket (projection factor + safety
radius), *not* from the worst-case static band — see
:func:`adaptive_width`.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional, Tuple

ENV = "REPRO_TUNE"
GRID_ENV = "REPRO_TUNE_GRID"
OUT_ENV = "REPRO_TUNE_OUT"

_DEFAULT_OUT = os.path.join("experiments", "tune")
_TABLE_NAME = "tuning.json"

# candidate grids per op; "minimal" mode collapses each to (default,)
_GRIDS: Dict[str, Dict[str, Tuple[int, ...]]] = {
    "dtw_band": {"block": (4, 8, 16)},
    "dtw_band_cdist": {"block_a": (4, 8, 16)},
    "lb_refine": {"block": (4, 8, 16)},
    "adc_sym": {"block_a": (64, 128), "block_b": (64, 128)},
    "adc_lookup": {"block": (128, 256, 512)},
}

_memo: Dict[str, Dict[str, int]] = {}
_pinned: Dict[str, Dict[str, Dict[str, int]]] = {}


def mode() -> str:
    return os.environ.get(ENV, "off") or "off"


def _bucket(n: int) -> int:
    """Next power of two >= n — geometry keys bucket L and window+1 so
    nearby shapes share one tuning entry."""
    b = 1
    while b < n:
        b *= 2
    return b


def table_key(op: str, *, length: int, window: Optional[int],
              measure: Optional[str], backend: str) -> str:
    w = length if window is None else int(window)
    return (f"{op}|L{_bucket(max(1, length))}"
            f"|w{_bucket(min(w, length - 1) + 1)}"
            f"|{measure or 'dtw'}|{backend}")


def _out_path() -> str:
    return os.path.join(os.environ.get(OUT_ENV, _DEFAULT_OUT), _TABLE_NAME)


def _load(path: str) -> Dict[str, Dict[str, int]]:
    if path not in _pinned:
        try:
            with open(path, encoding="utf-8") as f:
                _pinned[path] = json.load(f)
        except (OSError, ValueError):
            _pinned[path] = {}
    return _pinned[path]


def _persist(path: str, key: str, entry: Dict[str, int]) -> None:
    table = dict(_load(path))
    table[key] = entry
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(table, f, indent=2, sort_keys=True)
        f.write("\n")
    _pinned[path] = table


def _trace_clean() -> bool:
    try:
        import jax
        return jax.core.trace_state_clean()
    except Exception:
        return True


def _candidates(op: str, defaults: Dict[str, int]
                ) -> Tuple[Dict[str, int], ...]:
    grid = _GRIDS.get(op)
    if grid is None or os.environ.get(GRID_ENV) == "minimal":
        return (dict(defaults),)
    params = sorted(grid)
    combos = [{}]
    for p in params:
        combos = [dict(c, **{p: v}) for c in combos for v in grid[p]]
    return tuple(dict(defaults, **c) for c in combos)


def _time_once(fn) -> float:
    fn()                              # warmup: compile outside the clock
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _measure(op: str, params: Dict[str, int], *, length: int,
             window: Optional[int], measure: Optional[str],
             backend: str) -> Optional[float]:
    """One candidate micro-benchmark; None when the op has no runner."""
    import numpy as np
    rng = np.random.default_rng(0)
    interpret = None if backend == "pallas" else backend == "pallas_interpret"
    if backend == "jax":
        return None
    if op in ("dtw_band", "dtw_band_cdist"):
        from .dtw_band.ops import dtw_band, dtw_band_cdist
        n = 32
        A = rng.standard_normal((n, length)).astype(np.float32)
        B = rng.standard_normal((n, length)).astype(np.float32)
        if op == "dtw_band":
            def fn():
                dtw_band(A, B, window, measure=measure,
                         interpret=interpret, **params).block_until_ready()
        else:
            blk = params.get("block_a", 8)

            def fn():
                dtw_band_cdist(A, B[:8], window, measure=measure,
                               interpret=interpret,
                               block=blk).block_until_ready()
        return _time_once(fn)
    if op == "lb_refine":
        from .lb_cascade.ops import lb_refine
        n = 32
        A = rng.standard_normal((n, length)).astype(np.float32)
        B = rng.standard_normal((n, length)).astype(np.float32)
        upper, lower = B + 0.5, B - 0.5
        thresh = np.full((n,), np.inf, np.float32)

        def fn():
            lb_refine(A, B, upper, lower, thresh, window, measure=measure,
                      interpret=interpret, **params)[0].block_until_ready()
        return _time_once(fn)
    if op in ("adc_sym", "adc_lookup"):
        from .pq_adc.ops import adc_sym_cdist, adc_lookup
        n_sub, K = 8, max(4, min(length, 256))
        codes = rng.integers(0, K, (256, n_sub)).astype(np.int32)
        if op == "adc_sym":
            lut = rng.standard_normal((n_sub, K, K)).astype(np.float32)

            def fn():
                adc_sym_cdist(codes, codes, lut, interpret=interpret,
                              **params).block_until_ready()
        else:
            qlut = rng.standard_normal((n_sub, K)).astype(np.float32)

            def fn():
                # repro: ignore[RS101] tuner wall-clock timing; trace-clean
                adc_lookup(codes, qlut, interpret=interpret,
                           **params).block_until_ready()
        return _time_once(fn)
    return None


def _resolve_entry(op: str, defaults: Dict[str, int], *, length: int,
                   window: Optional[int], measure: Optional[str],
                   backend: str) -> Dict[str, int]:
    key = table_key(op, length=length, window=window, measure=measure,
                    backend=backend)
    m = mode()
    if m == "off":
        return defaults
    if m != "auto":                   # pinned table path
        return _load(m).get(key, defaults)
    if key in _memo:
        return _memo[key]
    table = _load(_out_path())
    if key in table:
        _memo[key] = table[key]
        return table[key]
    if not _trace_clean():            # never benchmark mid-trace
        return defaults
    best, best_t = dict(defaults), float("inf")
    for cand in _candidates(op, defaults):
        try:
            t = _measure(op, cand, length=length, window=window,
                         measure=measure, backend=backend)
        except Exception:
            continue
        if t is not None and t < best_t:
            best, best_t = cand, t
    _memo[key] = best
    _persist(_out_path(), key, best)
    return best


def tuned(op: str, param: str, *, length: int, window: Optional[int] = None,
          measure: Optional[str] = None, backend: str = "pallas",
          default: int = 8) -> int:
    """Resolve one launch parameter for ``op`` at the given geometry.

    Returns ``default`` in ``off`` mode (and for any key the table does
    not cover); otherwise the pinned or measured winner.
    """
    entry = _resolve_entry(op, {param: default}, length=length,
                           window=window, measure=measure, backend=backend)
    return int(entry.get(param, default))


def adaptive_width(length: int, window: Optional[int], lane: int = 8, *,
                   measure: Optional[str] = None, backend: str = "pallas",
                   factor: int = 8, radius: int = 2) -> int:
    """Register width cap for ``band="adaptive"`` sweeps.

    The default derives from the *corridor geometry* — projected coarse
    cells span at most ``~2*factor`` fine rows per diagonal, plus the
    block tail and the safety radius — rather than the worst-case static
    band, and is never wider than the static register.  The tuning table
    can override it per bucket (``op="adaptive_width"``)."""
    from .dtw_band.kernel import band_width
    need = 3 * factor + 2 * radius + 2
    default = min(band_width(length, window, lane),
                  max(lane, -(-need // lane) * lane))
    return tuned("adaptive_width", "width", length=length, window=window,
                 measure=measure, backend=backend, default=default)


def reset() -> None:
    """Drop every in-process memo and cached table (tests)."""
    _memo.clear()
    _pinned.clear()
