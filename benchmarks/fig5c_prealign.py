"""Fig 5c — cost of the MODWT pre-alignment step, plus the fused-path sweep.

The paper finds pre-alignment has a minor effect on runtime, driven mainly
by the wavelet decomposition level; tail length is immaterial.  We sweep
J (level) and t (tail fraction) and report the segmentation overhead vs the
fixed-split baseline.

On top of that, the encode-path sweep compares the three production routes
end-to-end (exact full-scan encode in all cases, so the work compared is
identical):

    no_prealign   fixed segments + exact encode (the paper's ablation)
    two_step      modwt.prealign -> HBM segment tensor -> exact encode
    fused         one dispatch launch: the prealign_encode kernel performs
                  MODWT, snap, re-interpolation and the DTW-1NN scan per
                  batch tile without materializing segments

each on both dispatch backends where it differs ("jax" reference vs
"pallas_interpret" kernel bodies).  Results land in
``experiments/bench/fig5c_prealign.json`` plus the committed repo-root
summary ``BENCH_prealign.json`` — both written by
``benchmarks.common.Bench`` (the single JSON writer).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.modwt import prealign, fixed_segments
from repro.core.pq import PQConfig, encode, fit, uses_fused_prealign
from repro.data.timeseries import trace_like

from . import common
from .common import Bench, timeit


def run(quick: bool = True) -> Bench:
    b = Bench("fig5c_prealign", root_name="prealign")
    n = 30 if quick else 100
    length = 128 if quick else 256
    if common.SMOKE:
        n, length = 16, 64
    X, _ = trace_like(n, length=length, seed=0)
    X = jnp.asarray(X)
    D = X.shape[1]
    M = 4

    # -- segmentation-only sweep (paper fig 5c) -----------------------------
    base = timeit(lambda: fixed_segments(X, M), repeats=3)
    b.add(mode="fixed", level=0, tail_frac=0.0,
          segment_s=base["median_s"], overhead=1.0)

    levels = (1, 2, 3) if quick else (1, 2, 3, 4, 5)
    for J in (levels[:2] if common.SMOKE else levels):
        for tail_frac in (0.1, 0.2):
            tail = max(1, int(round(tail_frac * (D // M))))
            t = timeit(lambda: prealign(X, M, J, tail), repeats=3)
            b.add(mode="modwt", level=J, tail_frac=tail_frac,
                  segment_s=t["median_s"],
                  overhead=t["median_s"] / max(base["median_s"], 1e-9))

    # -- encode-path sweep: no-prealign vs two-step vs fused ----------------
    key = jax.random.PRNGKey(0)
    K = min(16 if common.SMOKE else 32, X.shape[0])
    base_cfg = PQConfig(n_sub=M, codebook_size=K, kmeans_iters=3,
                        dba_iters=1, exact_encode=True)
    cfgs = {
        "no_prealign": dataclasses.replace(base_cfg, use_prealign=False),
        "two_step": dataclasses.replace(base_cfg, fused_encode=False),
        "fused": base_cfg,
    }
    assert uses_fused_prealign(cfgs["fused"])
    books = {}   # one codebook per segmentation geometry
    for name, cfg in cfgs.items():
        geom = cfg.subseq_len(D)
        if geom not in books:
            books[geom] = fit(key, X, cfg)
        cb = books[geom]
        for backend in ("jax", "pallas_interpret"):
            with dispatch.use_backend(backend):
                jax.clear_caches()
                t = timeit(lambda: encode(X, cb, cfg), repeats=2)
            b.add(mode=f"encode_{name}", backend=backend,
                  level=cfg.wavelet_level, tail=cfg.tail(D),
                  encode_s=t["median_s"],
                  per_series_us=t["median_s"] / X.shape[0] * 1e6)
    b.save(headline={"n": int(X.shape[0]), "length": int(D)})
    return b


if __name__ == "__main__":
    run(quick=False)
