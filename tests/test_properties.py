"""Property-based tests (hypothesis) on the system's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the dev-only hypothesis dependency")
from hypothesis import given, settings, strategies as st

from repro.core.dtw import dtw_pair, euclidean_sq
from repro.core.lb import keogh_envelope, lb_keogh, lb_kim
from repro.core.metrics import adjusted_rand_index, rand_index
from repro.core.cluster import cut_k, linkage
from repro.core.pq import PQConfig, cdist_sym, encode_with_stats, fit
from repro.train.optim import AdamWConfig, adamw_init, adamw_step, warmup_cosine

pytestmark = pytest.mark.slow    # hypothesis sweeps: tier-2

SETTINGS = dict(max_examples=15, deadline=None)


def _series(draw, n, length, lo=-4.0, hi=4.0):
    vals = draw(st.lists(
        st.floats(lo, hi, allow_nan=False, allow_infinity=False, width=32),
        min_size=n * length, max_size=n * length))
    return np.asarray(vals, np.float32).reshape(n, length)


@st.composite
def series_pair(draw, length=16):
    x = _series(draw, 1, length)[0]
    y = _series(draw, 1, length)[0]
    return x, y


class TestDtwInvariants:
    @given(series_pair())
    @settings(**SETTINGS)
    def test_identity_zero(self, pair):
        a, _ = pair
        d = float(dtw_pair(jnp.asarray(a), jnp.asarray(a), None))
        assert d == pytest.approx(0.0, abs=1e-5)

    @given(series_pair())
    @settings(**SETTINGS)
    def test_symmetry(self, pair):
        a, b = pair
        dab = float(dtw_pair(jnp.asarray(a), jnp.asarray(b), None))
        dba = float(dtw_pair(jnp.asarray(b), jnp.asarray(a), None))
        assert dab == pytest.approx(dba, rel=1e-5, abs=1e-5)

    @given(series_pair())
    @settings(**SETTINGS)
    def test_dtw_leq_euclidean(self, pair):
        """The diagonal path is one warping path, so DTW <= squared ED."""
        a, b = pair
        d = float(dtw_pair(jnp.asarray(a), jnp.asarray(b), None))
        ed = float(np.sum((a - b) ** 2))
        assert d <= ed + 1e-4 + 1e-5 * abs(ed)

    @given(series_pair(), st.integers(1, 16))
    @settings(**SETTINGS)
    def test_window_monotone(self, pair, w):
        """Widening the Sakoe-Chiba band can only lower the distance."""
        a, b = pair
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        d_w = float(dtw_pair(aj, bj, w))
        d_full = float(dtw_pair(aj, bj, None))
        assert d_full <= d_w + 1e-4 + 1e-5 * abs(d_w)

    @given(series_pair())
    @settings(**SETTINGS)
    def test_full_window_equals_unconstrained(self, pair):
        a, b = pair
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        assert float(dtw_pair(aj, bj, len(a))) == pytest.approx(
            float(dtw_pair(aj, bj, None)), rel=1e-5, abs=1e-5)


class TestLowerBounds:
    @given(series_pair(), st.integers(1, 8))
    @settings(**SETTINGS)
    def test_lb_keogh_sound(self, pair, w):
        """LB_Keogh(q, env(c)) <= DTW(q, c) — the pruning soundness."""
        q, c = pair
        up, lo = keogh_envelope(jnp.asarray(c)[None, :], w)
        lb = float(lb_keogh(jnp.asarray(q)[None, :], up, lo)[0])
        d = float(dtw_pair(jnp.asarray(q), jnp.asarray(c), w))
        assert lb <= d + 1e-3 + 1e-4 * abs(d)

    @given(series_pair())
    @settings(**SETTINGS)
    def test_lb_kim_sound(self, pair):
        q, c = pair
        lb = float(lb_kim(jnp.asarray(q)[None, :], jnp.asarray(c)[None, :])[0])
        d = float(dtw_pair(jnp.asarray(q), jnp.asarray(c), None))
        assert lb <= d + 1e-3 + 1e-4 * abs(d)

    @given(st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_envelope_contains_series(self, seed):
        x = np.random.default_rng(seed).standard_normal((3, 12)).astype(
            np.float32)
        up, lo = keogh_envelope(jnp.asarray(x), 2)
        assert bool(jnp.all(up >= jnp.asarray(x) - 1e-6))
        assert bool(jnp.all(lo <= jnp.asarray(x) + 1e-6))


class TestQuantizer:
    @pytest.fixture(scope="class")
    def fitted(self):
        rng = np.random.default_rng(0)
        X = jnp.asarray(rng.standard_normal((24, 32)), jnp.float32)
        cfg = PQConfig(n_sub=4, codebook_size=8, use_prealign=False,
                       kmeans_iters=2, dba_iters=1)
        cb = fit(jax.random.PRNGKey(0), X, cfg)
        return X, cfg, cb

    def test_codes_in_range_and_deterministic(self, fitted):
        X, cfg, cb = fitted
        codes1, _ = encode_with_stats(X, cb, cfg)
        codes2, _ = encode_with_stats(X, cb, cfg)
        assert codes1.shape == (24, 4)
        assert int(codes1.min()) >= 0 and int(codes1.max()) < 8
        np.testing.assert_array_equal(np.asarray(codes1), np.asarray(codes2))

    def test_sym_distance_axioms(self, fitted):
        X, cfg, cb = fitted
        codes, _ = encode_with_stats(X, cb, cfg)
        d = np.asarray(cdist_sym(codes, codes, cb.lut))
        assert (d >= -1e-6).all()
        np.testing.assert_allclose(d, d.T, atol=1e-5)   # symmetric
        assert np.allclose(np.diag(d), 0.0, atol=1e-5)  # self-distance 0

    def test_lut_diagonal_zero(self, fitted):
        _, _, cb = fitted
        lut = np.asarray(cb.lut)
        for m in range(lut.shape[0]):
            assert np.allclose(np.diag(lut[m]), 0.0, atol=1e-4)


class TestClusterMetrics:
    @given(st.integers(2, 6), st.integers(0, 1000))
    @settings(**SETTINGS)
    def test_cut_k_produces_k(self, k, seed):
        rng = np.random.default_rng(seed)
        n = 12
        pts = rng.standard_normal((n, 2))
        d = np.linalg.norm(pts[:, None] - pts[None, :], axis=-1)
        Z = linkage(d, "complete")
        labels = cut_k(Z, n, k)
        assert len(np.unique(labels)) == k

    @given(st.integers(0, 1000))
    @settings(**SETTINGS)
    def test_rand_index_bounds(self, seed):
        rng = np.random.default_rng(seed)
        a = rng.integers(0, 3, 20)
        b = rng.integers(0, 3, 20)
        assert rand_index(a, a) == pytest.approx(1.0)
        assert 0.0 <= rand_index(a, b) <= 1.0

    @given(st.integers(0, 1000))
    @settings(**SETTINGS)
    def test_ari_permutation_invariant(self, seed):
        rng = np.random.default_rng(seed)
        a = rng.integers(0, 3, 16)
        b = rng.integers(0, 3, 16)
        perm = {0: 2, 1: 0, 2: 1}
        b2 = np.vectorize(perm.get)(b)
        assert adjusted_rand_index(a, b) == pytest.approx(
            adjusted_rand_index(a, b2), abs=1e-9)


class TestOptimizer:
    def test_zero_grad_moves_only_by_decay(self):
        params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
        opt = adamw_init(params)
        cfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
        zero = jax.tree.map(jnp.zeros_like, params)
        new_p, _ = adamw_step(cfg, params, zero, opt)
        np.testing.assert_allclose(np.asarray(new_p["w"]),
                                   np.asarray(params["w"]), atol=1e-6)

    @given(st.integers(0, 20_000))
    @settings(**SETTINGS)
    def test_lr_schedule_bounds(self, step):
        cfg = AdamWConfig(lr=3e-4, warmup_steps=100, total_steps=10_000,
                          min_lr_frac=0.1)
        lr = float(warmup_cosine(cfg, jnp.asarray(step)))
        assert 0.0 <= lr <= cfg.lr * (1 + 1e-6)
        if step >= cfg.total_steps:
            assert lr == pytest.approx(cfg.lr * cfg.min_lr_frac, rel=1e-4)

    def test_grad_step_descends_quadratic(self):
        params = {"w": jnp.asarray([3.0, -2.0])}
        opt = adamw_init(params)
        cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=0)
        for _ in range(50):
            grads = {"w": 2 * params["w"]}
            params, opt = adamw_step(cfg, params, grads, opt)
        assert float(jnp.abs(params["w"]).max()) < 1.5


class TestHloCostModel:
    def test_shape_bytes(self):
        from repro.launch.hlo_cost import _shape_bytes
        assert _shape_bytes("f32[2,3]{1,0}") == 24
        assert _shape_bytes("bf16[10]") == 20
        assert _shape_bytes("(f32[2], s32[3])") == 8 + 12
        assert _shape_bytes("pred[7]") == 7

    def test_trip_count_multiplication(self):
        from repro.launch.hlo_cost import analyze_module
        hlo = """
HloModule m
%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %y = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[4,4]) tuple(%i, %y)
}
%cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (a: f32[4,4]) -> (s32[], f32[4,4]) {
  %a = f32[4,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4,4]) tuple(%z, %a)
  ROOT %w = (s32[], f32[4,4]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
"""
        c = analyze_module(hlo)
        # one 4x4x4 dot = 2*4*4*4 = 128 flops, x5 trips
        assert c.flops == pytest.approx(128 * 5)

    def test_collective_conventions(self):
        from repro.launch.hlo_cost import analyze_module
        hlo = """
HloModule m
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
ENTRY %main (x: f32[128]) -> f32[128] {
  %x = f32[128]{0} parameter(0)
  ROOT %ar = f32[128]{0} all-reduce(%x), to_apply=%add
}
"""
        c = analyze_module(hlo)
        assert c.coll["all-reduce"] == pytest.approx(2 * 128 * 4)
