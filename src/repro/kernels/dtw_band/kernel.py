"""Banded-DTW wavefront Pallas kernel.

Each grid step owns a VMEM tile of ``block`` (query, candidate) pairs and
sweeps the shared DP table anti-diagonal by anti-diagonal.  The two live
diagonals are ``(block, L)`` vector registers; every wavefront step is one
VPU-wide fused multiply/min, so the sequential depth is ``2L - 1``
irrespective of the batch size.

TPU notes:
  * the diagonal gather ``b[d - i]`` is a dynamic slice of a pre-reversed,
    pre-padded copy of ``b`` (built once per tile) — no scatter/gather ops;
  * the ``i-1`` predecessor shift is a lane rotate (`jnp.roll`) plus an edge
    mask — also gather-free;
  * the Sakoe-Chiba band is a static mask, so shapes never depend on data.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dtw_band_kernel", "make_dtw_band_call"]

_NEG_SAFE_INF = 3.0e38  # finite stand-in for +inf (avoids inf-inf NaNs)


def dtw_band_kernel(a_ref, b_ref, o_ref, *, length: int, window: int,
                    block: int):
    """Kernel body: ``a_ref (block, L)``, ``b_ref (block, L)`` ->
    ``o_ref (block, 1)`` squared banded DTW costs."""
    L = length
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)

    idx = jax.lax.broadcasted_iota(jnp.int32, (block, L), 1)
    # b_big[:, L + t] == b_rev[:, t]; diagonal d needs v[i] = b[d - i]
    #   = b_rev[i + L - 1 - d] = b_big[:, i + 2L - 1 - d].
    b_rev = jnp.flip(b, axis=1)
    zeros = jnp.zeros((block, L), jnp.float32)
    b_big = jnp.concatenate([zeros, b_rev, zeros], axis=1)

    inf = jnp.float32(_NEG_SAFE_INF)

    def step(d, carry):
        prev1, prev2 = carry
        j = d - idx
        valid = (j >= 0) & (j < L) & (jnp.abs(idx - j) <= window)
        v = jax.lax.dynamic_slice_in_dim(b_big, 2 * L - 1 - d, L, axis=1)
        cost = (a - v) ** 2

        shift1 = jnp.where(idx == 0, inf, jnp.roll(prev1, 1, axis=1))
        shift2 = jnp.where(idx == 0, inf, jnp.roll(prev2, 1, axis=1))
        best = jnp.minimum(jnp.minimum(shift2, prev1), shift1)
        best = jnp.where((idx == 0) & (d == 0), 0.0, best)
        diag = jnp.where(valid, cost + best, inf)
        # clamp so accumulating inf + cost never overflows to inf*2
        diag = jnp.minimum(diag, inf)
        return diag, prev1

    init = (jnp.full((block, L), inf), jnp.full((block, L), inf))
    last, _ = jax.lax.fori_loop(0, 2 * L - 1, step, init)
    o_ref[...] = last[:, L - 1:L]


def make_dtw_band_call(n_pairs: int, length: int, window: Optional[int],
                       block: int, interpret: bool):
    """Build the pallas_call for ``(n_pairs, L)`` zipped pair batches.

    ``n_pairs`` must already be padded to a multiple of ``block``.
    """
    w = length if window is None else int(window)
    grid = (n_pairs // block,)
    kernel = functools.partial(dtw_band_kernel, length=length, window=w,
                               block=block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, length), lambda i: (i, 0)),
            pl.BlockSpec((block, length), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pairs, 1), jnp.float32),
        interpret=interpret,
    )
