"""Seeded RS1xx violations: every finding here is asserted by
tests/test_analysis.py."""

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("k", "missing"))  # RS103
def topk(x, k=4):
    d = helper(x)
    if jnp.any(d > 0):  # RS102: data-dependent branch under trace
        d = -d
    return jnp.sort(d)[:k]


def helper(x):
    v = float(jnp.min(x))  # RS101: host sync, trace-reachable via topk
    return x - v


@functools.partial(jax.jit, static_argnames=("opts",))
def scale(x, opts={}):  # RS103: mutable default on a static arg
    return x * len(opts)


_CACHE = {}


def memo(x):
    _CACHE[x.shape] = x  # RS104: module state mutated under trace
    return x


def memo_root(x):
    return jax.jit(memo)(x)


def report(x):
    return x.item()  # RS101: unconditional sync, flagged anywhere


def offline(x):
    # np.asarray is only a finding on trace-reachable paths; this
    # function is never traced, so this line must NOT be flagged
    return np.asarray(x)
