#!/usr/bin/env python3
"""Render a metrics snapshot (REPRO_OBS_DUMP output) as a console report.

Usage:
    python scripts/obs_report.py SNAPSHOT.json
    python scripts/obs_report.py SNAPSHOT.json --require-stages a,b,c

The snapshot is the JSON written by ``repro.obs.write_snapshot`` (or the
``REPRO_OBS_DUMP`` atexit hook).  ``--require-stages`` turns the report
into a CI gate: exit 1 unless every named stage recorded at least one
span — catching instrumentation that silently stopped firing (an
always-disabled flag, a renamed stage, a refactor that dropped a span).
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(__file__)), "src"))

from repro.obs.report import check_stages, render  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("snapshot", help="metrics snapshot JSON path")
    ap.add_argument(
        "--require-stages",
        default="",
        help="comma-separated stage names that must have recorded "
        "at least one span (exit 1 otherwise)",
    )
    args = ap.parse_args()

    with open(args.snapshot) as f:
        snap = json.load(f)
    print(render(snap, title=f"observability report: {args.snapshot}"))

    required = [s for s in args.require_stages.split(",") if s.strip()]
    if required:
        ok, message = check_stages(snap, required)
        if not ok:
            print(f"\nFAIL: {message}")
            return 1
        print(f"\nOK: all {len(required)} required stages recorded samples")
    return 0


if __name__ == "__main__":
    sys.exit(main())
