"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings ``(B, n_frontend_tokens, d_model)`` which the
backbone consumes at the start of the sequence with M-RoPE (t, h, w)
position ids.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    mrope=True,
    mrope_sections=(16, 24, 24),
    frontend="vision",
    n_frontend_tokens=256,    # patch embeddings per sample (stub)
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen2-vl-72b-reduced", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, mrope_sections=(4, 6, 6),
    n_frontend_tokens=8)
