"""RS1xx — trace safety.

The PR 7 invariant these rules freeze: the obs-off hot path performs
*zero* host syncs, and anything that must block does so through
``repro.obs.fence`` (tracer-safe, obs-gated) instead of raw JAX device
syncs.

* **RS101** host sync primitive: ``jax.device_get`` /
  ``jax.block_until_ready`` / ``.block_until_ready()`` / ``.item()``
  anywhere in ``src/repro`` (these *always* synchronize), plus
  ``np.asarray``/``np.array``/``int()``/``float()``/``bool()`` over
  array-valued expressions inside trace-reachable functions (where they
  either fail at trace time or silently pull a tracer to host).
* **RS102** data-dependent Python branch (``if``/``while`` testing a
  ``jnp``/``lax`` array expression) in a trace-reachable function —
  under jit this raises ``TracerBoolConversionError``; route through
  ``lax.cond``/``jnp.where`` instead.
* **RS103** jit ``static_argnames`` naming a parameter that does not
  exist, or whose default is a mutable literal (unhashable at cache-key
  time).
* **RS104** mutation of module-level state from a trace-reachable
  function — the mutation replays per trace, not per call.

``repro.obs`` modules are exempt from RS101: they implement the fence.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set

from .callgraph import CallGraph, FunctionInfo, dotted_parts
from .findings import Finding

__all__ = ["run"]

_SYNC_ATTRS = frozenset({"block_until_ready", "item"})
_SYNC_FUNCS = frozenset({
    "jax.device_get", "jax.block_until_ready",
})
_HOST_CONVERTERS = frozenset({
    "numpy.asarray", "numpy.array", "np.asarray", "np.array",
})
_CASTS = frozenset({"int", "float", "bool"})

# jnp helpers that return python scalars / static metadata — safe in an
# ``if`` test even under trace
_STATIC_JNP = frozenset({
    "jax.numpy.issubdtype", "jax.numpy.result_type", "jax.numpy.dtype",
    "jax.numpy.iinfo", "jax.numpy.finfo", "jax.numpy.shape",
    "jax.numpy.ndim", "jax.numpy.size",
})

_ARRAY_METHODS = frozenset({
    "sum", "min", "max", "mean", "any", "all", "argmin", "argmax",
    "ravel", "astype", "reshape",
})


def _line(info: FunctionInfo, lineno: int) -> str:
    lines = info.module.source.splitlines()
    return lines[lineno - 1] if 0 < lineno <= len(lines) else ""


def _resolve(info: FunctionInfo, graph: CallGraph,
             node: ast.AST) -> Optional[str]:
    parts = dotted_parts(node)
    if parts is None:
        return None
    imports = info.module.imports
    if parts[0] in imports:
        return ".".join([imports[parts[0]]] + parts[1:])
    return ".".join(parts)


def _scope_nodes(info: FunctionInfo):
    """The scope's own statements, excluding nested function bodies."""
    todo = list(ast.iter_child_nodes(info.node))
    while todo:
        n = todo.pop()
        yield n
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)):
            continue
        todo.extend(ast.iter_child_nodes(n))


def _is_array_expr(expr: ast.AST, info: FunctionInfo,
                   graph: CallGraph) -> bool:
    """Heuristic: the expression's value is (or contains) a jnp array —
    a ``jnp.``/``lax.`` call or an array-method call like ``.min()``."""
    for n in ast.walk(expr):
        if not isinstance(n, ast.Call):
            continue
        qual = _resolve(info, graph, n.func)
        if qual is not None:
            if qual in _STATIC_JNP:
                continue
            if qual.startswith(("jax.numpy.", "jax.lax.")):
                return True
        if (isinstance(n.func, ast.Attribute)
                and n.func.attr in _ARRAY_METHODS
                and not _is_shape_access(n.func.value)):
            return True
    return False


def _is_shape_access(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in ("shape", "ndim",
                                                       "dtype"):
            return True
    return False


def run(graph: CallGraph) -> List[Finding]:
    out: List[Finding] = []
    reachable = graph.trace_reachable()
    mutable_globals = _module_mutable_globals(graph)
    for qual, info in graph.functions.items():
        if info.module.qualname.startswith("repro.obs"):
            continue
        in_trace = qual in reachable
        out.extend(_rs101(info, graph, in_trace))
        if in_trace:
            out.extend(_rs102(info, graph))
            out.extend(_rs104(info, graph, mutable_globals))
        out.extend(_rs103(info, graph))
    return out


# -- RS101 -------------------------------------------------------------------

def _rs101(info: FunctionInfo, graph: CallGraph,
           in_trace: bool) -> List[Finding]:
    out = []
    for n in _scope_nodes(info):
        if not isinstance(n, ast.Call):
            continue
        qual = _resolve(info, graph, n.func)
        hit = None
        if qual in _SYNC_FUNCS:
            hit = f"{qual} is an unconditional host sync"
        elif (isinstance(n.func, ast.Attribute)
              and n.func.attr in _SYNC_ATTRS
              and not n.args):
            hit = f".{n.func.attr}() is an unconditional host sync"
        elif in_trace and qual in _HOST_CONVERTERS:
            hit = f"{qual} pulls the array to host"
        elif (in_trace and isinstance(n.func, ast.Name)
              and n.func.id in _CASTS and len(n.args) == 1
              and _is_array_expr(n.args[0], info, graph)):
            hit = (f"{n.func.id}() over an array expression forces a "
                   f"host sync")
        if hit is not None:
            where = ("on a trace-reachable path" if in_trace
                     else "outside obs.fence")
            out.append(Finding(
                rule="RS101", path=info.module.path, lineno=n.lineno,
                scope=info.qualname,
                message=f"{hit} {where}; route through obs.fence or "
                        f"suppress with a reason",
                source_line=_line(info, n.lineno)))
    return out


# -- RS102 -------------------------------------------------------------------

def _rs102(info: FunctionInfo, graph: CallGraph) -> List[Finding]:
    out = []
    for n in _scope_nodes(info):
        if not isinstance(n, (ast.If, ast.While)):
            continue
        if _is_array_expr(n.test, info, graph):
            kind = "if" if isinstance(n, ast.If) else "while"
            out.append(Finding(
                rule="RS102", path=info.module.path, lineno=n.lineno,
                scope=info.qualname,
                message=f"data-dependent `{kind}` on an array expression "
                        f"in a trace-reachable function; use lax.cond/"
                        f"jnp.where or hoist the decision to trace time",
                source_line=_line(info, n.lineno)))
    return out


# -- RS103 -------------------------------------------------------------------

def _rs103(info: FunctionInfo, graph: CallGraph) -> List[Finding]:
    if info.jit_static is None:
        return []
    if not isinstance(info.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return []
    names, lineno = info.jit_static
    out = []
    params = info.params
    for name in names:
        if name not in params:
            out.append(Finding(
                rule="RS103", path=info.module.path, lineno=lineno,
                scope=info.qualname,
                message=f"static_argnames names {name!r} which is not a "
                        f"parameter of {info.qualname.rsplit('.', 1)[-1]}",
                source_line=_line(info, lineno)))
    # mutable defaults on static params are unhashable at jit cache-key
    # time and fail on first call with a non-None value
    a = info.node.args
    pos = a.posonlyargs + a.args
    defaults = dict(zip([p.arg for p in pos[len(pos) - len(a.defaults):]],
                        a.defaults))
    defaults.update({p.arg: d for p, d in zip(a.kwonlyargs, a.kw_defaults)
                     if d is not None})
    for name in names:
        d = defaults.get(name)
        if isinstance(d, (ast.List, ast.Dict, ast.Set)):
            out.append(Finding(
                rule="RS103", path=info.module.path, lineno=d.lineno,
                scope=info.qualname,
                message=f"static arg {name!r} has a mutable (unhashable) "
                        f"default; use a tuple/frozenset/None",
                source_line=_line(info, d.lineno)))
    return out


# -- RS104 -------------------------------------------------------------------

def _module_mutable_globals(graph: CallGraph) -> Set[str]:
    """``module.name`` for every module-level list/dict/set binding."""
    out: Set[str] = set()
    for mod in graph.modules.values():
        for stmt in mod.tree.body:
            if isinstance(stmt, ast.Assign):
                if isinstance(stmt.value, (ast.List, ast.Dict, ast.Set,
                                           ast.ListComp, ast.DictComp,
                                           ast.SetComp)):
                    for t in stmt.targets:
                        if isinstance(t, ast.Name):
                            out.add(f"{mod.qualname}.{t.id}")
    return out


def _rs104(info: FunctionInfo, graph: CallGraph,
           mutable_globals: Set[str]) -> List[Finding]:
    out = []
    mod = info.module.qualname

    def _is_mutable_global(node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name):
            q = f"{mod}.{node.id}"
            if q in mutable_globals and node.id not in info.params:
                return node.id
        return None

    for n in _scope_nodes(info):
        name = None
        if isinstance(n, ast.Global):
            name = ", ".join(n.names)
        elif isinstance(n, ast.AugAssign):
            name = _is_mutable_global(n.target)
        elif isinstance(n, ast.Assign):
            for t in n.targets:
                if isinstance(t, ast.Subscript):
                    name = _is_mutable_global(t.value)
        elif isinstance(n, ast.Call):
            if (isinstance(n.func, ast.Attribute)
                    and n.func.attr in ("append", "extend", "update",
                                        "add", "pop", "clear", "remove",
                                        "setdefault")):
                name = _is_mutable_global(n.func.value)
        if name is not None:
            out.append(Finding(
                rule="RS104", path=info.module.path, lineno=n.lineno,
                scope=info.qualname,
                message=f"mutation of module-level state ({name}) in a "
                        f"trace-reachable function replays per trace, "
                        f"not per call",
                source_line=_line(info, n.lineno)))
    return out
