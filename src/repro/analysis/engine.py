"""Rule engine: build the call graph, run every rule family, fold in
inline suppressions and the committed baseline.

:func:`analyze` is the one entry point; ``scripts/check_static.py`` is a
thin CLI over it and the fixture tests call it directly on miniature
trees under ``tests/fixtures/analysis/``.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List

from . import rules_concurrency, rules_dispatch, rules_trace
from .callgraph import CallGraph, build_graph
from .findings import (Finding, Suppression, apply_baseline,
                       apply_suppressions, load_baseline, scan_suppressions)

__all__ = ["RULES", "Report", "analyze"]

# id -> one-line summary; docs/static_analysis.md is checked against this
# table by scripts/check_docs.py, and --list-rules prints it
RULES: Dict[str, str] = {
    "RS001": "suppression comment has no justification text",
    "RS002": "suppression comment matched no finding",
    "RS101": "host sync primitive outside obs.fence",
    "RS102": "data-dependent Python branch in a trace-reachable function",
    "RS103": "invalid or mutable static_argnames in a jit wrapper",
    "RS104": "module-level state mutated from a trace-reachable function",
    "RS201": "kernel package missing part of the kernel/ops/ref triple",
    "RS202": "kernel package not registered in core/dispatch.py",
    "RS203": "dispatch op not gated by EXPECTED_OPS in check_routing.py",
    "RS204": "jax.vmap over a function that can reach a pallas_call",
    "RS301": "writer-only field assigned outside writer-thread methods",
    "RS302": "attribute assignment on a published IndexView",
    "RS303": "bare lock acquire/release instead of a with block",
    "RS205": "routing gate consumes more than one dump format",
}


@dataclasses.dataclass
class Report:
    graph: CallGraph
    findings: List[Finding]        # new, unsuppressed, unbaselined
    baselined: List[str]           # fingerprints matched by the baseline
    stale_baseline: List[str]      # baselined but no longer present
    unjustified_baseline: List[str]  # baselined with empty justification

    @property
    def clean(self) -> bool:
        return (not self.findings and not self.stale_baseline
                and not self.unjustified_baseline)


def _py_files(root: Path) -> List[Path]:
    pkg = root / "src" / "repro"
    return sorted(p for p in pkg.rglob("*.py")
                  if "__pycache__" not in p.parts
                  and "analysis" not in p.relative_to(pkg).parts)


def analyze(root: Path, baseline_path: Path | None = None) -> Report:
    """Run every rule over the tree rooted at ``root`` (which contains
    ``src/repro`` and optionally ``scripts/check_routing.py``)."""
    root = root.resolve()
    files = _py_files(root)
    graph = build_graph(files, root / "src")

    findings: List[Finding] = []
    findings += rules_trace.run(graph)
    findings += rules_dispatch.run(graph, root)
    findings += rules_concurrency.run(graph)

    suppressions: Dict[Path, List[Suppression]] = {}
    paths = {m.path for m in graph.modules.values()}
    paths.update(f.path for f in findings)
    for path in paths:
        if path.exists():
            subs = scan_suppressions(path, path.read_text(encoding="utf-8"))
            if subs:
                suppressions[path] = subs
    findings = apply_suppressions(findings, suppressions)
    findings.sort(key=lambda f: (f.rel(root), f.lineno, f.rule))

    baseline = (load_baseline(baseline_path)
                if baseline_path is not None else {})
    new, seen, stale = apply_baseline(findings, baseline, root)
    unjustified = [fp for fp in seen
                   if not baseline[fp].get("justification", "").strip()]
    return Report(graph=graph, findings=new, baselined=seen,
                  stale_baseline=stale, unjustified_baseline=unjustified)
