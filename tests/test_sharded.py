"""List-sharded scale-out: occupancy-aware placement, the shard-major
sealed layout, the list-partitioned planner with device-resident fan-in,
the two-level coarse quantizer, query-padding masks, per-device memory
accounting, and snapshot format 3."""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import MANIFEST
from repro.core import dispatch
from repro.core.dispatch import use_backend
from repro.core.ivf import build_two_level, coarse_dists
from repro.core.lb_search import filtered_topk
from repro.core.pq import PQConfig, memory_cost
from repro.data.timeseries import cbf
from repro.index import (IndexConfig, StreamingIndex, placement_loads,
                         plan_placement, restore_snapshot, save_snapshot,
                         search_sharded)
from repro.index.segments import seal
from repro.launch.mesh import make_search_mesh, validate_search_mesh


def _config(**kw):
    pq = PQConfig(n_sub=4, codebook_size=8, use_prealign=False,
                  kmeans_iters=2, dba_iters=1)
    base = dict(pq=pq, n_lists=4, hot_capacity=12, coarse_iters=3)
    base.update(kw)
    return IndexConfig(**base)


@pytest.fixture(scope="module")
def data():
    X, _ = cbf(n_per_class=12, length=48, seed=0)    # 36 series
    Q, _ = cbf(n_per_class=2, length=48, seed=7)     # 6 queries
    return X.astype(np.float32), Q.astype(np.float32)


@pytest.fixture(scope="module")
def booted(data):
    X, _ = data
    return StreamingIndex.bootstrap(jax.random.PRNGKey(0), X, _config())


def _fresh(booted, **cfg_kw):
    """Empty index on booted's trained quantizers, config overridable —
    the quantizers depend only on pq/n_lists, which stay fixed."""
    cfg = dataclasses.replace(booted.cfg, **cfg_kw)
    return StreamingIndex.from_parts(cfg, booted.coarse, booted.cb,
                                     booted.dim)


class TestPlacement:
    def test_lpt_makespan_bound(self):
        """Greedy LPT guarantee: heaviest shard <= average + one list."""
        rng = np.random.default_rng(0)
        for n_shards in (2, 3, 4, 7):
            for _ in range(20):
                counts = rng.integers(0, 50, size=rng.integers(1, 40))
                p = plan_placement(counts, n_shards)
                assert p.shape == counts.shape and p.dtype == np.int32
                assert (0 <= p).all() and (p < n_shards).all()
                loads = placement_loads(p, counts, n_shards)
                assert loads.sum() == counts.sum()
                bound = counts.sum() / n_shards + counts.max(initial=0)
                assert loads.max() <= bound

    def test_deterministic(self):
        counts = np.array([5, 9, 1, 9, 3, 0, 7])
        p1 = plan_placement(counts, 3)
        p2 = plan_placement(counts, 3)
        np.testing.assert_array_equal(p1, p2)

    def test_single_shard_and_validation(self):
        np.testing.assert_array_equal(
            plan_placement(np.array([3, 1, 4]), 1), np.zeros(3, np.int32))
        with pytest.raises(ValueError, match="n_shards"):
            plan_placement(np.array([1, 2]), 0)
        with pytest.raises(ValueError, match="1-D"):
            plan_placement(np.zeros((2, 2)), 2)


def _toy_rows(n=23, n_lists=5, m=4, seed=3):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 8, size=(n, m)).astype(np.int32)
    ids = np.arange(n, dtype=np.int32)
    assign = rng.integers(0, n_lists, size=n).astype(np.int32)
    return codes, ids, assign


class TestSealLayout:
    def test_shard_major_blocks(self):
        codes, ids, assign = _toy_rows()
        sg = seal(codes, ids, assign, 5, rows=23, n_shards=3)
        assert sg.rows == 3 * sg.shard_cap
        start = np.asarray(sg.list_start)
        length = np.asarray(sg.list_len)
        placed = np.asarray(sg.placement)
        seg_ids = np.asarray(sg.ids)
        seg_assign = np.asarray(sg.assign)
        for l in range(5):
            lo, n = start[l], length[l]
            s = placed[l]
            # every list is one contiguous run inside its shard's block
            assert s * sg.shard_cap <= lo
            assert lo + n <= (s + 1) * sg.shard_cap
            assert (seg_assign[lo:lo + n] == l).all()
            want = set(ids[assign == l].tolist())
            assert set(seg_ids[lo:lo + n].tolist()) == want
        # padding rows carry the usual sentinels
        pad = seg_ids == -1
        assert (~np.asarray(sg.live)[pad]).all()
        assert (seg_assign[pad] == 5).all()

    def test_single_shard_reproduces_legacy_layout(self):
        codes, ids, assign = _toy_rows()
        a = seal(codes, ids, assign, 5, rows=30)
        b = seal(codes, ids, assign, 5, rows=30, n_shards=1)
        np.testing.assert_array_equal(np.asarray(a.codes),
                                      np.asarray(b.codes))
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_array_equal(np.asarray(a.list_start),
                                      np.asarray(b.list_start))
        assert a.shard_cap == 30 and a.n_shards == 1

    def test_per_shard_occupancy_bound(self):
        """Acceptance bound: per-device rows (hence sealed-code bytes)
        <= total / n_shards + one list's worth (+ ceil rounding)."""
        codes, ids, assign = _toy_rows(n=97, n_lists=11, seed=9)
        for n_shards in (2, 3, 4):
            sg = seal(codes, ids, assign, 11, rows=97, n_shards=n_shards)
            max_len = int(np.asarray(sg.list_len).max())
            assert sg.shard_cap <= -(-97 // n_shards) + max_len

    def test_shard_views_consistent_with_global_tables(self):
        codes, ids, assign = _toy_rows()
        sg = seal(codes, ids, assign, 5, rows=23, n_shards=3)
        v_codes, v_ids, v_live, loc_start, loc_len = (
            np.asarray(a) for a in sg.shard_views())
        assert v_codes.shape == (3, sg.shard_cap, 4)
        placed = np.asarray(sg.placement)
        start = np.asarray(sg.list_start)
        length = np.asarray(sg.list_len)
        for s in range(3):
            for l in range(5):
                if placed[l] == s:
                    assert loc_len[s, l] == length[l]
                    lo = loc_start[s, l]
                    np.testing.assert_array_equal(
                        v_ids[s, lo:lo + length[l]],
                        np.asarray(sg.ids)[start[l]:start[l] + length[l]])
                else:
                    assert loc_len[s, l] == 0

    def test_seal_validation(self):
        codes, ids, assign = _toy_rows()
        with pytest.raises(ValueError, match="shard_round"):
            seal(codes, ids, assign, 5, rows=23, shard_round=0)


class TestConfigValidation:
    def test_bad_shards_and_two_level(self):
        with pytest.raises(ValueError, match="n_shards"):
            _config(n_shards=0)
        with pytest.raises(ValueError, match="n_top_lists"):
            _config(n_top_lists=5)               # > n_lists=4
        with pytest.raises(ValueError, match="n_probe_top"):
            _config(n_top_lists=2)               # missing n_probe_top
        with pytest.raises(ValueError, match="n_probe_top"):
            _config(n_top_lists=2, n_probe_top=3)
        with pytest.raises(ValueError, match="n_probe_top"):
            _config(n_probe_top=1)               # without n_top_lists


class TestTwoLevelCoarse:
    def test_exhaustive_fanout_matches_flat(self, data, booted):
        _, Q = data
        w = booted.cfg.coarse_window(booted.dim)
        tl = build_two_level(jax.random.PRNGKey(0), booted.coarse, 2, w)
        dc_flat = coarse_dists(Q, booted.coarse, w)
        dc_tl = coarse_dists(Q, booted.coarse, w, two_level=tl,
                             n_probe_top=tl.n_top)
        np.testing.assert_allclose(np.asarray(dc_tl), np.asarray(dc_flat),
                                   rtol=1e-5, atol=1e-5)

    def test_partial_fanout_is_masked_subset(self, data, booted):
        _, Q = data
        w = booted.cfg.coarse_window(booted.dim)
        tl = build_two_level(jax.random.PRNGKey(0), booted.coarse, 3, w)
        dc_flat = np.asarray(coarse_dists(Q, booted.coarse, w))
        dc_tl = np.asarray(coarse_dists(Q, booted.coarse, w, two_level=tl,
                                        n_probe_top=1))
        finite = np.isfinite(dc_tl)
        assert finite.any(axis=1).all()          # every query probes lists
        assert not finite.all()                  # and some were skipped
        np.testing.assert_allclose(dc_tl[finite], dc_flat[finite],
                                   rtol=1e-5, atol=1e-5)

    def test_index_search_exhaustive_fanout_equals_flat(self, data, booted):
        X, Q = data
        flat = _fresh(booted)
        hier = _fresh(booted, n_top_lists=2, n_probe_top=2)
        flat.insert(X[:30])
        hier.insert(X[:30])
        d0, i0 = flat.search(Q, n_probe=4, topk=5)
        d1, i1 = hier.search(Q, n_probe=4, topk=5)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                                   rtol=1e-6, atol=1e-6)

    def test_routing_counters_including_non_dtw(self, data, booted):
        """two_level_coarse is ledgered per backend and per measure — the
        CI routing gate requires both the bare op and a non-DTW variant."""
        _, Q = data
        w = booted.cfg.coarse_window(booted.dim)
        tl = build_two_level(jax.random.PRNGKey(0), booted.coarse, 2, w)
        with use_backend("pallas_interpret"):
            dispatch.reset_stats()
            dispatch.two_level_coarse(Q, tl.top, booted.coarse,
                                      tl.child_idx, tl.child_valid, w,
                                      n_probe_top=2)
            dispatch.two_level_coarse(Q, tl.top, booted.coarse,
                                      tl.child_idx, tl.child_valid, w,
                                      n_probe_top=2, measure="msm")
        assert dispatch.stats.get(
            ("two_level_coarse", "pallas_interpret"), 0) == 2
        assert dispatch.stats.get(
            ("two_level_coarse[msm]", "pallas_interpret"), 0) == 1

    def test_build_and_fanout_validation(self, booted):
        w = booted.cfg.coarse_window(booted.dim)
        with pytest.raises(ValueError, match="n_top"):
            build_two_level(jax.random.PRNGKey(0), booted.coarse, 9, w)
        tl = build_two_level(jax.random.PRNGKey(0), booted.coarse, 2, w)
        with pytest.raises(ValueError, match="n_probe_top"):
            coarse_dists(jnp.zeros((1, booted.dim)), booted.coarse, w,
                         two_level=tl)
        with pytest.raises(ValueError, match="n_probe_top"):
            coarse_dists(jnp.zeros((1, booted.dim)), booted.coarse, w,
                         two_level=tl, n_probe_top=3)


class TestQueryValidMask:
    def _padded(self, Q, pad):
        Qp = np.concatenate([Q, np.zeros((pad, Q.shape[1]), Q.dtype)])
        q_valid = jnp.arange(len(Qp)) < len(Q)
        return jnp.asarray(Qp), q_valid

    @pytest.mark.parametrize("measure", [None, "msm"])
    def test_masked_rows_inert(self, data, measure):
        """Padded query rows return inf/-1, leave real rows' results
        untouched, and claim zero LB-cascade refine work."""
        X, Q = data
        Qp, q_valid = self._padded(Q, 3)
        d0, i0, n0 = filtered_topk(jnp.asarray(Q), jnp.asarray(X), 5, 4,
                                   measure=measure)
        d1, i1, n1 = filtered_topk(Qp, jnp.asarray(X), 5, 4,
                                   measure=measure, q_valid=q_valid)
        np.testing.assert_allclose(np.asarray(d1)[:len(Q)], np.asarray(d0),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(i1)[:len(Q)],
                                      np.asarray(i0))
        assert np.isinf(np.asarray(d1)[len(Q):]).all()
        assert (np.asarray(i1)[len(Q):] == -1).all()
        # pad rows never inflate the refine count past the real-query
        # worst case (and the dense fallback counts only real pairs)
        assert int(n1) <= len(Q) * len(X)

    def test_sharded_padding_excluded_from_hot_scan(self, data, booted):
        """search_sharded on a non-divisible batch (hot rows only, so the
        whole result comes from the masked filtered_topk) matches the
        unpadded direct search."""
        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:8])                        # hot only
        d0, i0 = idx.search(Q[:3], n_probe=2, topk=4)
        d1, i1 = search_sharded(idx, Q[:3], n_probe=2, topk=4,
                                partition="queries")
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                                   rtol=1e-6, atol=1e-6)


class TestListShardedPlanner:
    @pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
    def test_matches_direct_and_replicated(self, data, booted, backend):
        """The three plans (direct, query-sharded, list-sharded) agree on
        whatever mesh the runtime provides, on both backends."""
        X, Q = data
        n_dev = len(jax.devices())
        idx = _fresh(booted, n_shards=n_dev)
        idx.insert(X[:30])                       # sealed segments + hot
        idx.delete([2, 13])
        with use_backend(backend):
            d0, i0 = idx.search(Q, n_probe=3, topk=4)
            d1, i1 = search_sharded(idx, Q, n_probe=3, topk=4,
                                    partition="queries")
            d2, i2 = search_sharded(idx, Q, n_probe=3, topk=4,
                                    partition="lists")
        for d, i in ((d1, i1), (d2, i2)):
            np.testing.assert_array_equal(np.asarray(i0), np.asarray(i))
            np.testing.assert_allclose(np.asarray(d0), np.asarray(d),
                                       rtol=1e-6, atol=1e-6)

    def test_auto_partition_selects_lists(self, data, booted):
        X, Q = data
        n_dev = len(jax.devices())
        idx = _fresh(booted, n_shards=n_dev)
        idx.insert(X[:30])
        d0, i0 = idx.search(Q, n_probe=3, topk=4)
        d1, i1 = search_sharded(idx, Q, n_probe=3, topk=4)   # auto
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                                   rtol=1e-6, atol=1e-6)

    def test_layout_mesh_mismatch_raises(self, data, booted):
        X, Q = data
        n_dev = len(jax.devices())
        idx = _fresh(booted, n_shards=n_dev + 1)
        idx.insert(X[:12])
        with pytest.raises(ValueError, match="n_shards"):
            search_sharded(idx, Q, n_probe=2, topk=2, partition="lists")

    def test_partition_arg_validation(self, data, booted):
        _, Q = data
        idx = _fresh(booted)
        with pytest.raises(ValueError, match="partition"):
            search_sharded(idx, Q, n_probe=2, partition="bogus")

    def test_empty_and_hot_only_list_sharded(self, data, booted):
        X, Q = data
        n_dev = len(jax.devices())
        idx = _fresh(booted, n_shards=n_dev)
        d, ids = search_sharded(idx, Q, n_probe=2, topk=3,
                                partition="lists")
        assert np.isinf(np.asarray(d)).all()
        assert (np.asarray(ids) == -1).all()
        idx.insert(X[:6])                        # hot only, no segments
        d, ids = search_sharded(idx, Q, n_probe=2, topk=3,
                                partition="lists")
        d0, i0 = idx.search(Q, n_probe=2, topk=3)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(ids))
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d),
                                   rtol=1e-6, atol=1e-6)

    def test_validate_search_mesh(self):
        mesh = make_search_mesh()
        validate_search_mesh(mesh, len(jax.devices()))
        with pytest.raises(ValueError, match="n_shards"):
            validate_search_mesh(mesh, len(jax.devices()) + 1)

    @pytest.mark.slow
    def test_list_sharded_multi_device_property(self):
        """The full equivalence chain on 4 simulated host devices: direct
        == query-sharded == list-sharded, on jax AND pallas_interpret,
        with a non-divisible query count, after deletes + compact(), and
        across a snapshot round-trip."""
        import subprocess
        import sys

        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=4",
                   JAX_PLATFORMS="cpu",
                   PYTHONPATH=os.path.join(root, "src"))
        code = """
import numpy as np, jax
assert len(jax.devices()) == 4
from repro.core.dispatch import use_backend
from repro.core.pq import PQConfig
from repro.index import (IndexConfig, StreamingIndex, restore_snapshot,
                         save_snapshot, search_sharded)
from repro.data.timeseries import cbf

X, _ = cbf(12, length=48, seed=0)
Q, _ = cbf(2, length=48, seed=7)          # 6 queries: not divisible by 4
pq = PQConfig(n_sub=4, codebook_size=8, use_prealign=False,
              kmeans_iters=2, dba_iters=1)
cfg = IndexConfig(pq=pq, n_lists=4, hot_capacity=12, coarse_iters=3,
                  n_shards=4, n_top_lists=2, n_probe_top=2)
idx = StreamingIndex.bootstrap(jax.random.PRNGKey(0), X, cfg)
idx.insert(X[:30]); idx.delete([3, 17])

def check(ix):
    d0, i0 = ix.search(Q, n_probe=3, topk=4)
    for backend in ("jax", "pallas_interpret"):
        with use_backend(backend):
            for part in ("queries", "lists"):
                d, i = search_sharded(ix, Q, n_probe=3, topk=4,
                                      partition=part)
                np.testing.assert_array_equal(np.asarray(i0), np.asarray(i))
                np.testing.assert_allclose(np.asarray(d0), np.asarray(d),
                                           rtol=1e-6, atol=1e-6)

check(idx)
idx.delete([5, 21]); idx.compact()
assert all(sg.n_shards == 4 for sg in idx.segments)
check(idx)
import tempfile
with tempfile.TemporaryDirectory() as tmp:
    save_snapshot(tmp, idx)
    back = restore_snapshot(tmp)
for a, b in zip(idx.segments, back.segments):
    assert a.n_shards == b.n_shards and a.shard_cap == b.shard_cap
    np.testing.assert_array_equal(np.asarray(a.placement),
                                  np.asarray(b.placement))
check(back)
print("OK")
"""
        res = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=900)
        assert res.returncode == 0, res.stderr[-2000:]


class TestPerDeviceAccounting:
    def test_memory_cost_per_device_keys(self):
        pq = PQConfig(n_sub=4, codebook_size=8, use_prealign=False)
        one = memory_cost(pq, 48, 1000, n_segments=2, n_lists=8,
                          hot_capacity=64)
        assert "max_device_bytes" not in one     # n_devices=1: old surface
        for n_dev in (2, 4, 8):
            m = memory_cost(pq, 48, 1000, n_segments=2, n_lists=8,
                            hot_capacity=64, n_devices=n_dev)
            assert m["n_devices"] == n_dev
            assert (m["replicated_bytes"] + m["partitioned_bytes"]
                    == m["index_bytes"] + m["aux_bytes"]
                    + m["coarse_bytes"])
            assert m["max_device_bytes"] == (
                m["replicated_bytes"]
                + -(-m["partitioned_bytes"] // n_dev))
            # index_bytes keeps its meaning regardless of the mesh
            assert m["index_bytes"] == one["index_bytes"]

    def test_partitioned_share_shrinks_linearly(self):
        pq = PQConfig(n_sub=8, codebook_size=16, use_prealign=False)
        m1 = memory_cost(pq, 96, 100_000, n_segments=1, n_lists=64,
                         hot_capacity=128, n_devices=2)
        m2 = memory_cost(pq, 96, 100_000, n_segments=1, n_lists=64,
                         hot_capacity=128, n_devices=4)
        shrink = ((m1["max_device_bytes"] - m1["replicated_bytes"])
                  / (m2["max_device_bytes"] - m2["replicated_bytes"]))
        assert shrink == pytest.approx(2.0, rel=0.01)


class TestSnapshotFormat3:
    def test_roundtrip_sharded_and_two_level(self, data, booted, tmp_path):
        X, Q = data
        idx = _fresh(booted, n_shards=2, n_top_lists=2, n_probe_top=2)
        idx.insert(X[:30])
        idx.delete([4, 14])
        idx.compact()
        save_snapshot(str(tmp_path), idx)
        with open(os.path.join(str(tmp_path), "snap_0000000000",
                               MANIFEST)) as f:
            assert json.load(f)["format"] == 3
        back = restore_snapshot(str(tmp_path))
        assert back.two_level is not None
        np.testing.assert_array_equal(np.asarray(idx.two_level.top),
                                      np.asarray(back.two_level.top))
        for a, b in zip(idx.segments, back.segments):
            assert (a.n_shards, a.shard_cap) == (b.n_shards, b.shard_cap)
            np.testing.assert_array_equal(np.asarray(a.placement),
                                          np.asarray(b.placement))
        d0, i0 = idx.search(Q, n_probe=3, topk=4)
        d1, i1 = back.search(Q, n_probe=3, topk=4)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))

    def test_restores_format2_single_shard_layout(self, data, booted,
                                                  tmp_path):
        """A doctored pre-scale-out snapshot (format 2: no placement
        arrays, no shard metadata, no scale-out config fields) restores to
        the single-shard layout with identical search results."""
        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:30])
        idx.flush()
        save_snapshot(str(tmp_path), idx)
        d = os.path.join(str(tmp_path), "snap_0000000000")
        with open(os.path.join(d, MANIFEST)) as f:
            manifest = json.load(f)
        manifest["format"] = 2
        manifest.pop("two_level")
        for k in ("n_shards", "n_top_lists", "n_probe_top"):
            manifest["config"].pop(k)
        for meta in manifest["segments"]:
            meta.pop("n_shards")
            meta.pop("shard_cap")
        for name in list(os.listdir(d)):
            if "placement" in name:
                os.remove(os.path.join(d, name))
        with open(os.path.join(d, MANIFEST), "w") as f:
            json.dump(manifest, f)
        back = restore_snapshot(str(tmp_path))
        assert all(sg.n_shards == 1 for sg in back.segments)
        assert all(sg.shard_cap == sg.rows for sg in back.segments)
        d0, i0 = idx.search(Q, n_probe=3, topk=4)
        d1, i1 = back.search(Q, n_probe=3, topk=4)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
