#!/usr/bin/env python3
"""Compilation-regression gate: replay warm-bucket mixed serving
traffic under ``jax_log_compiles`` and fail on any warm-path compile.

Usage: python scripts/check_recompile.py [--requests N]

The PR 8 contract this freezes: once the coalescer's padded query
buckets and the writer's fixed-shape ingest path are warm, arbitrary
further mixed traffic reuses the cached executables — zero new XLA
compilations.  A stray dynamic shape (an unpadded batch, a per-request
slice with novel bounds, a jit cache key that includes a fresh python
object) shows up here as a logged ``Compiling ...`` event.

Mechanics: build the small serving stack from
``benchmarks.serving_qps._build``, warm every (bucket, request-size)
pair and the ingest chunk shape with serial traffic, then turn on
``jax_log_compiles`` — its one-record-per-XLA-compile log line on the
``jax._src.interpreters.pxla`` logger is the counter — and replay the
same request-size mix.  The replay is deliberately *serial* and keeps
inserts below ``hot_capacity``: concurrent coalescing makes batch
composition (and therefore per-request result-slice bounds)
nondeterministic, and a hot-segment seal/flush legitimately compiles
the new segment's fine stage — both would make the gate flaky rather
than prove a regression.

Exit 0: zero compile events during replay; 1 otherwise.
"""

from __future__ import annotations

import argparse
import logging
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))
sys.path.insert(0, str(REPO_ROOT))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.serving_qps import _build  # noqa: E402
from repro.serve_index import IndexServer, ServeConfig  # noqa: E402

# one request per size: every bucket the replay can touch, plus an
# off-bucket size (3 -> padded into bucket 4) to exercise padding
REQUEST_SIZES = (1, 2, 3, 4)
INGEST_CHUNK = 8


class _CompileCounter(logging.Handler):
    """Counts jax_log_compiles records (one per XLA compilation)."""

    def __init__(self) -> None:
        super().__init__()
        self.events: list = []

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if msg.startswith("Compiling "):
            self.events.append(msg)


def _traffic(srv, Q, rng, rounds: int) -> None:
    """One serial mixed round: every request size + one ingest chunk."""
    dim = Q.shape[1]
    for _ in range(rounds):
        for n in REQUEST_SIZES:
            rows = rng.integers(0, len(Q), size=n)
            srv.submit_search(Q[rows]).result()
        chunk = rng.standard_normal((INGEST_CHUNK, dim)).astype(np.float32)
        srv.insert(chunk).result()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--requests",
        type=int,
        default=3,
        help="replay rounds over the warmed request-size mix",
    )
    args = ap.parse_args()

    # hot_capacity far above total replay ingest: no seal/flush (and the
    # legitimate novel-shape compiles one brings) during the gated phase
    index = _build(n_rows=96, dim=32, n_lists=4, hot_capacity=4096)
    cfg = ServeConfig(n_probe=2, topk=3, q_buckets=(1, 2, 4))
    rng = np.random.default_rng(0)
    Q = rng.standard_normal((32, 32)).astype(np.float32)

    counter = _CompileCounter()
    logger = logging.getLogger("jax._src.interpreters.pxla")

    with IndexServer(index, cfg) as srv:
        # warm every (bucket, request size) pair and the ingest shape
        _traffic(srv, Q, rng, rounds=2)
        print("  warmed buckets (1, 2, 4) and the ingest chunk shape")

        jax.config.update("jax_log_compiles", True)
        logger.addHandler(counter)
        try:
            _traffic(srv, Q, rng, rounds=args.requests)
        finally:
            logger.removeHandler(counter)
            jax.config.update("jax_log_compiles", False)

    n_req = args.requests * (len(REQUEST_SIZES) + 1)
    if counter.events:
        print(
            f"FAIL: {len(counter.events)} compilation(s) during the "
            f"warm-path replay ({n_req} requests):"
        )
        for msg in counter.events:
            print(f"  {msg}")
        return 1
    print(f"OK: zero compilations across {n_req} warm-path requests")
    return 0


if __name__ == "__main__":
    sys.exit(main())
