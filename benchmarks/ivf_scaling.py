"""IVF-PQDTW (paper §4.1's million-scale pointer): recall@1 vs probe count
and the candidate-evaluation reduction versus exhaustive PQDTW."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import build_index, search_batch
from repro.core.pq import PQConfig, cdist_asym
from repro.data.timeseries import random_walks

from . import common
from .common import Bench, timeit


def run(quick: bool = True) -> Bench:
    b = Bench("ivf_scaling")
    N, D, n_lists = (400, 96, 16) if quick else (4000, 256, 64)
    Q = jnp.asarray(random_walks(16, D, seed=7))
    X = jnp.asarray(random_walks(N, D, seed=1))
    cfg = PQConfig(n_sub=4, codebook_size=32, use_prealign=False,
                   **common.measure_config_fields(),
                   kmeans_iters=3, dba_iters=1)
    index = build_index(jax.random.PRNGKey(0), X, cfg, n_lists=n_lists,
                        coarse_iters=4)

    d_ex = np.asarray(cdist_asym(Q, index.codes, index.cb, cfg))
    truth = np.asarray(index.ids)[d_ex.argmin(1)]
    t_ex = timeit(lambda: cdist_asym(Q, index.codes, index.cb, cfg),
                  repeats=2)

    for n_probe in (1, 2, 4, n_lists // 2, n_lists):
        t = timeit(lambda: search_batch(index, Q, cfg, n_probe=n_probe,
                                        topk=1), repeats=2)
        _, ids = search_batch(index, Q, cfg, n_probe=n_probe, topk=1)
        recall = float((np.asarray(ids)[:, 0] == truth).mean())
        cand_frac = min(1.0, n_probe * index.max_list / N)
        b.add(n_probe=n_probe, recall_at_1=recall,
              candidates_frac=round(cand_frac, 3),
              search_s=t["median_s"], exhaustive_s=t_ex["median_s"])
    b.save(headline={"quick": quick, "measure": common.MEASURE,
                     "config": dict(N=N, D=D, n_lists=n_lists)})
    return b


if __name__ == "__main__":
    run(quick=False)
