"""KV / state cache construction for every family.

Caches are pytrees of arrays with a leading layer (or group) axis so the
decode step can ``lax.scan`` over layers; KV tensors are bf16.  A cache can
optionally be PQ-compressed (the paper's technique as a serving feature) —
see :mod:`repro.serve.pqkv`.
"""

from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from ..models.config import ModelConfig

__all__ = ["init_cache"]


def _kv(n_layers: int, B: int, S: int, G: int, hd: int) -> Dict[str, Any]:
    shape = (n_layers, B, S, G, hd)
    return {"k": jnp.zeros(shape, jnp.bfloat16),
            "v": jnp.zeros(shape, jnp.bfloat16)}


def _ssm_states(cfg: ModelConfig, n: int, B: int) -> Dict[str, Any]:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    din, ck = cfg.d_inner, cfg.ssm_conv
    return {
        "ssd": jnp.zeros((n, B, H, P, N), jnp.float32),
        "conv_x": jnp.zeros((n, B, ck - 1, din), jnp.float32),
        "conv_B": jnp.zeros((n, B, ck - 1, N), jnp.float32),
        "conv_C": jnp.zeros((n, B, ck - 1, N), jnp.float32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Zero-initialised cache pytree for ``serve_step``."""
    G, hd = cfg.n_kv_heads, (cfg.head_dim_ if cfg.n_heads else 0)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _kv(cfg.n_layers, batch, max_len, G, hd)
    if fam == "ssm":
        return _ssm_states(cfg, cfg.n_layers, batch)
    if fam == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        cache = _ssm_states(cfg, cfg.n_layers, batch)
        # reshape SSM states into (groups, per-group) for the grouped scan
        cache = {k: v.reshape(n_groups, cfg.attn_every, *v.shape[1:])
                 for k, v in cache.items()}
        cache.update({"attn_" + k: v for k, v in
                      _kv(n_groups, batch, max_len, G, hd).items()})
        return cache
    if fam == "encdec":
        cache = {"self_" + k: v for k, v in
                 _kv(cfg.n_layers, batch, max_len, G, hd).items()}
        Sf = cfg.n_frontend_tokens
        cache.update({"cross_" + k: v for k, v in
                      _kv(cfg.n_layers, batch, Sf, G, hd).items()})
        return cache
    raise ValueError(fam)
