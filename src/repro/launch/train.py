"""Production training launcher.

Fault-tolerance contract (designed for 1000+ node fleets, exercised here on
the host mesh):

  * checkpoint/restart — atomic step directories (write tmp + rename), keep-K
    GC, async writer thread off the step path; on start the latest valid
    checkpoint is restored and the data stream is fast-forwarded (data order
    is a pure function of the step index, so restarts are bit-deterministic).
  * preemption safety — SIGTERM/SIGINT trigger a synchronous checkpoint
    before exit (TPU preemption notice pattern).
  * straggler watchdog — a monitor thread flags steps exceeding
    ``--watchdog`` seconds (on a fleet this feeds the controller that
    re-schedules the slow host; here it logs and optionally aborts).
  * elastic restart — checkpoints store unsharded per-leaf arrays;
    ``restore`` re-lays them out for whatever mesh the relaunch uses, so the
    job can resume on a different device count (e.g. after losing a pod).

Usage (CPU example scale):
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 30 --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 10
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import threading
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_reduced
from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.sharding.partition import (activation_sharding, dp_axes,
                                      named_shardings, param_specs)
from repro.train.optim import AdamWConfig
from repro.train.step import init_train_state, make_train_step


class Watchdog:
    """Flags steps that exceed a wall-clock budget (straggler mitigation)."""

    def __init__(self, timeout_s: float, abort: bool = False):
        self.timeout = timeout_s
        self.abort = abort
        self._last_beat = time.monotonic()
        self._step = -1
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self.stragglers = 0

    def beat(self, step: int):
        self._last_beat = time.monotonic()
        self._step = step

    def _run(self):
        while not self._stop.wait(min(self.timeout / 4, 5.0)):
            lag = time.monotonic() - self._last_beat
            if lag > self.timeout:
                self.stragglers += 1
                print(f"[watchdog] step {self._step + 1} exceeded "
                      f"{self.timeout:.0f}s (lag {lag:.0f}s) — straggler",
                      file=sys.stderr, flush=True)
                if self.abort:
                    os.kill(os.getpid(), signal.SIGTERM)
                self._last_beat = time.monotonic()

    def start(self):
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU/example scale)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--keep-last", type=int, default=3)
    ap.add_argument("--watchdog", type=float, default=0.0,
                    help="straggler threshold in seconds (0 = off)")
    ap.add_argument("--watchdog-abort", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs 256 devices)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh())
    print(f"[train] arch={cfg.name} params={cfg.param_count():,} "
          f"mesh={dict(mesh.shape)} devices={mesh.size}")

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=max(args.steps, 2),
                          warmup_steps=max(2, args.steps // 10))
    train_step = make_train_step(cfg, opt_cfg, q_chunk=min(512, args.seq),
                                 microbatches=args.microbatches)

    key = jax.random.PRNGKey(args.seed)
    with mesh, activation_sharding(dp_axes(mesh)):
        state = init_train_state(key, cfg)
        rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        p_sh = named_shardings(param_specs(state.params, mesh), mesh)
        state_sh = type(state)(
            step=rep, params=p_sh,
            opt=type(state.opt)(mu=p_sh, nu=p_sh, count=rep))
        state = jax.device_put(state, state_sh)

        start_step = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = AsyncCheckpointer(args.ckpt_dir, keep_last=args.keep_last)
            last = latest_step(args.ckpt_dir)
            if last is not None:
                # elastic restore: stored unsharded, re-laid-out for this mesh
                state = restore(args.ckpt_dir, last, state, shardings=state_sh)
                start_step = last
                print(f"[train] restored step {last} from {args.ckpt_dir}")

        stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                             batch=args.batch, seed=args.seed)
        jstep = jax.jit(train_step, donate_argnums=(0,))

        dog = Watchdog(args.watchdog, args.watchdog_abort).start() \
            if args.watchdog else None

        stop_requested = {"flag": False}

        def _graceful(signum, frame):                     # noqa: ARG001
            stop_requested["flag"] = True
            print(f"[train] signal {signum}: checkpoint + exit after this "
                  "step", flush=True)

        old_handlers = [(s, signal.signal(s, _graceful))
                        for s in (signal.SIGTERM, signal.SIGINT)]

        metrics_f = open(args.metrics_out, "a") if args.metrics_out else None
        t_start = time.time()
        step = start_step
        try:
            for step in range(start_step, args.steps):
                if dog:
                    dog.beat(step)
                batch = {k: jax.numpy.asarray(v)
                         for k, v in stream.batch_at(step).items()}
                if cfg.family == "encdec":
                    batch["frames"] = jax.numpy.asarray(
                        np.random.default_rng(step).standard_normal(
                            (args.batch, cfg.n_frontend_tokens, cfg.d_model),
                            dtype=np.float32))
                if cfg.family == "vlm":
                    batch["patches"] = jax.numpy.asarray(
                        np.random.default_rng(step).standard_normal(
                            (args.batch, cfg.n_frontend_tokens, cfg.d_model),
                            dtype=np.float32))
                t0 = time.time()
                state, metrics = jstep(state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                rec = {"step": step + 1, "loss": round(loss, 4),
                       "ce": round(float(metrics["ce"]), 4),
                       "sec": round(dt, 3)}
                print(f"[train] {json.dumps(rec)}", flush=True)
                if metrics_f:
                    metrics_f.write(json.dumps(rec) + "\n")
                    metrics_f.flush()
                if not np.isfinite(loss):
                    raise FloatingPointError(f"loss diverged at step {step+1}")
                done = step + 1
                if ckpt and (done % args.ckpt_every == 0
                             or done == args.steps or stop_requested["flag"]):
                    ckpt.submit(done, state)
                if stop_requested["flag"]:
                    break
        finally:
            if dog:
                dog.stop()
            if ckpt:
                ckpt.wait()
                ckpt.close()
            if metrics_f:
                metrics_f.close()
            for s, h in old_handlers:
                signal.signal(s, h)
        wall = time.time() - t_start
        print(f"[train] finished at step {step + 1} in {wall:.1f}s"
              + (" (preempted)" if stop_requested["flag"] else ""))


if __name__ == "__main__":
    main()
