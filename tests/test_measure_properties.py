"""Property-based (hypothesis) tests of measure metric axioms.

Symmetry holds for every registered measure; ERP and MSM are true metrics
(triangle inequality) under their absolute-difference costs; squared DTW
famously is NOT a metric — a fixed violating triple documents that.  The
limiting-case equivalences (wdtw flat weight == dtw, erp lock-step limits)
are sweep-checked on both dispatch backends.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the dev-only hypothesis dependency")
from hypothesis import given, settings, strategies as st

from repro.core import dispatch
from repro.core.dtw import dtw_batch
from repro.core.measures import available, get_measure, resolve

pytestmark = pytest.mark.slow    # hypothesis sweeps: tier-2

SETTINGS = dict(max_examples=12, deadline=None)
MEASURES = ("dtw", "wdtw:g=0.1", "erp:g=0.3", "msm:c=0.5")
METRICS = ("erp:g=0.3", "msm:c=0.5")     # true metrics (triangle holds)


def _series(draw, length, lo=-4.0, hi=4.0):
    vals = draw(st.lists(
        st.floats(lo, hi, allow_nan=False, allow_infinity=False, width=32),
        min_size=length, max_size=length))
    return np.asarray(vals, np.float32)


@st.composite
def series_triple(draw, length=10):
    return (_series(draw, length), _series(draw, length),
            _series(draw, length))


def _d(spec, a, b, window=None):
    return float(dtw_batch(jnp.asarray(a)[None], jnp.asarray(b)[None],
                           window, spec)[0])


class TestMetricAxioms:
    @pytest.mark.parametrize("measure", MEASURES)
    @given(series_triple())
    @settings(**SETTINGS)
    def test_identity_zero(self, measure, triple):
        a, _, _ = triple
        spec = resolve(measure)
        assert _d(spec, a, a) == pytest.approx(0.0, abs=1e-4)

    @pytest.mark.parametrize("measure", MEASURES)
    @given(series_triple())
    @settings(**SETTINGS)
    def test_symmetry(self, measure, triple):
        a, b, _ = triple
        spec = resolve(measure)
        dab, dba = _d(spec, a, b), _d(spec, b, a)
        assert dab == pytest.approx(dba, rel=1e-4, abs=1e-4)

    @pytest.mark.parametrize("measure", METRICS)
    @given(series_triple())
    @settings(**SETTINGS)
    def test_triangle_inequality(self, measure, triple):
        a, b, c = triple
        spec = resolve(measure)
        dac = _d(spec, a, c)
        dab = _d(spec, a, b)
        dbc = _d(spec, b, c)
        assert dac <= dab + dbc + 1e-3 + 1e-4 * (dab + dbc)

    def test_dtw_triangle_violating_triple(self):
        """Squared DTW is not a metric: the classic constant-series triple
        violates the triangle inequality outright."""
        a = np.zeros(4, np.float32)
        b = np.full(4, 1.0, np.float32)
        c = np.full(4, 2.0, np.float32)
        spec = resolve("dtw")
        dac = _d(spec, a, c)        # 4 * 2^2 = 16
        dab = _d(spec, a, b)        # 4 * 1^2 = 4
        dbc = _d(spec, b, c)        # 4 * 1^2 = 4
        assert dac > dab + dbc + 1.0

    @pytest.mark.parametrize("measure", MEASURES)
    @given(series_triple(), st.integers(1, 9))
    @settings(**SETTINGS)
    def test_window_monotone(self, measure, triple, w):
        """Widening the band can only lower any measure's cost (a superset
        of feasible alignment paths)."""
        a, b, _ = triple
        spec = resolve(measure)
        d_w = _d(spec, a, b, w)
        d_full = _d(spec, a, b, None)
        assert d_full <= d_w + 1e-3 + 1e-4 * abs(d_w)


class TestLimitingCases:
    @pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
    @given(series_triple())
    @settings(**SETTINGS)
    def test_wdtw_flat_equals_dtw_both_backends(self, backend, triple):
        a, b, _ = triple
        A, B = jnp.asarray(a)[None], jnp.asarray(b)[None]
        with dispatch.use_backend(backend):
            flat = float(dispatch.elastic_pairwise(
                A, B, 3, measure=get_measure("wdtw", g=0.0))[0])
            plain = float(dispatch.elastic_pairwise(A, B, 3)[0])
        assert flat == pytest.approx(plain, rel=1e-4, abs=1e-4)

    @pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
    @given(series_triple())
    @settings(**SETTINGS)
    def test_erp_lockstep_limits_both_backends(self, backend, triple):
        """erp with an unaffordable gap penalty degenerates to the L1
        lock-step — the same limit dtw(window=0) hits in L2^2."""
        a, b, _ = triple
        A, B = jnp.asarray(a)[None], jnp.asarray(b)[None]
        with dispatch.use_backend(backend):
            big_g = float(dispatch.elastic_pairwise(
                A, B, None, measure=get_measure("erp", g=1e6))[0])
            dtw0 = float(dispatch.elastic_pairwise(A, B, 0)[0])
        assert big_g == pytest.approx(float(np.abs(a - b).sum()),
                                      rel=1e-4, abs=1e-3)
        assert dtw0 == pytest.approx(float(((a - b) ** 2).sum()),
                                     rel=1e-4, abs=1e-3)


def test_all_shipped_measures_covered():
    """Guard: every shipped measure appears in the axiom sweep above."""
    shipped = {"dtw", "wdtw", "erp", "msm"}
    assert shipped <= set(available())
    assert shipped == {resolve(m).name for m in MEASURES}
