"""Pure-jnp implementations of the LB-cascade filter-and-refine kernel.

Two flavors with the same contract as :func:`..ops.lb_refine`:

* :func:`lb_refine_ref` — the test oracle.  Delegates the refine to the
  core wavefront DTW (itself validated against an O(L^2) numpy DP oracle
  in tests/conftest.py), fully independent of the kernel's compressed DP.
* :func:`lb_refine_jax` — the dispatch layer's ``"jax"`` route.  Same
  bound math, but the refine runs the band-compressed anti-diagonal sweep
  (:func:`...kernels.dtw_band.kernel.wavefront_compressed` — plain jnp,
  no Pallas) vectorized over the whole batch, so per-step cost scales
  with the Sakoe-Chiba band rather than the series length.

Both compute the exact distance for every pair and select — the pruning
(tile-level wavefront skip) is a Pallas-route optimization, not a
semantic difference.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ...core.dispatch import effective_window
from ...core.dtw import dtw_batch
from ...core.lb import lb_keogh, lb_kim
from ...core.measures import MeasureArg
from ..dtw_band.kernel import band_width, wavefront_compressed

__all__ = ["lb_refine_ref", "lb_refine_jax", "cascade_bound_ref"]


def cascade_bound_ref(A: jnp.ndarray, B: jnp.ndarray, upper: jnp.ndarray,
                      lower: jnp.ndarray) -> jnp.ndarray:
    """``max(LB_Kim(a, b), LB_Keogh(b, env(a)))`` per zipped pair."""
    return jnp.maximum(lb_kim(A, B), lb_keogh(B, upper, lower))


@jax.jit
def _select(lb, d, thresh):
    surv = lb < thresh
    return jnp.where(surv, d, lb), surv


def lb_refine_ref(A: jnp.ndarray, B: jnp.ndarray, upper: jnp.ndarray,
                  lower: jnp.ndarray, thresh: jnp.ndarray,
                  window: Optional[int] = None,
                  measure: MeasureArg = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    lb = cascade_bound_ref(A, B, jnp.asarray(upper, jnp.float32),
                           jnp.asarray(lower, jnp.float32))
    d = dtw_batch(A, B, window, measure)
    return _select(lb, d, jnp.asarray(thresh, jnp.float32))


@functools.partial(jax.jit, static_argnames=("window", "measure", "width"))
def lb_refine_jax(A: jnp.ndarray, B: jnp.ndarray, upper: jnp.ndarray,
                  lower: jnp.ndarray, thresh: jnp.ndarray,
                  window: Optional[int] = None,
                  measure: MeasureArg = None,
                  corridor: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                  width: Optional[int] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    L = A.shape[-1]
    w = effective_window(L, window)
    lb = cascade_bound_ref(A, B, jnp.asarray(upper, jnp.float32),
                           jnp.asarray(lower, jnp.float32))
    if width is None:
        width = band_width(L, w)
    d = wavefront_compressed(A, B, length=L, window=w, width=width,
                             measure=measure, corridor=corridor)[:, 0]
    return _select(lb, d, jnp.asarray(thresh, jnp.float32))
