"""PQDTW — the paper's product quantizer for time series under DTW.

Training (Alg. 1): segment -> per-subspace DBA k-means -> pre-compute the
M x K x K symmetric DTW LUT and the Keogh envelope of every centroid.

Encoding (Alg. 2): per subspace, DTW-1NN against the K centroids.  The
paper's cascading-lower-bound early abandoning is replaced by its TPU-native
equivalent: a vectorized LB filter (max(LB_Kim, reversed LB_Keogh) for all K
at once) followed by exact banded DTW on the top-T most promising centroids
(static T -> static shapes).  ``exact=True`` disables the filter.

Distances (§3.3): symmetric = M LUT gathers + sum; asymmetric = one fresh
M x K DTW table per query, then gathers.  §4.2's clustering refinement
replaces the 0 distance of identical codes by the Keogh lower bound.

Every exact-DTW evaluation and the symmetric code-distance matrix route
through :mod:`repro.core.dispatch`, so the Pallas kernels are the default
execution engine on TPU (pure-JAX wavefront elsewhere).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import measures as measures_mod
from .dtw import euclidean_sq
from .dispatch import (adc_cdist, elastic_cdist, elastic_pairwise,
                       prealign_encode)
from .lb import keogh_envelope, lb_keogh, lb_kim
from .kmeans import dba_kmeans, euclidean_kmeans
from .measures import MeasureSpec
from .modwt import prealign, fixed_segments

__all__ = ["PQConfig", "PQCodebook", "segment", "fit", "encode",
           "encode_with_stats", "query_lut", "query_lut_batch", "cdist_sym",
           "cdist_asym", "cdist_sym_refined", "memory_cost",
           "uses_fused_prealign"]


@dataclasses.dataclass(frozen=True)
class PQConfig:
    """Hyper-parameters of the product quantizer (paper §3 + §5).

    ``metric`` selects the subspace distance: any registered elastic
    measure name ("dtw", "wdtw", "erp", "msm", ...) or "euclidean" (the
    PQ_ED baseline).  ``measure_params`` carries the measure's static
    hyper-parameters (e.g. ``{"g": 1.0}`` for erp) — normalized to a
    sorted tuple of pairs so the config stays hashable and JSON-safe.

    >>> cfg = PQConfig(n_sub=2, codebook_size=4, use_prealign=False)
    >>> cfg.is_elastic
    True
    >>> cfg.subseq_len(8), cfg.tail(8), cfg.window(8)
    (4, 1, 1)
    """
    n_sub: int = 8              # M: number of subspaces
    codebook_size: int = 256    # K
    window_frac: float = 0.1    # Sakoe-Chiba band, fraction of subseq length
    metric: str = "dtw"         # elastic measure name or "euclidean"
    measure_params: Tuple[Tuple[str, float], ...] = ()
    use_prealign: bool = True   # MODWT pre-alignment (§3.5)
    wavelet_level: int = 3      # J
    tail_frac: float = 0.15     # t, fraction of D/M
    snap_tail: Optional[int] = None  # explicit t in samples (overrides
                                     # tail_frac; 0 = fixed splits)
    kmeans_iters: int = 8
    dba_iters: int = 2
    refine_frac: float = 0.125  # T/K for filter-then-refine encoding
    exact_encode: bool = False  # disable the LB filter
    fused_encode: bool = True   # exact prealigned encodes take the fused
                                # MODWT+encode dispatch path (one kernel)

    def __post_init__(self):
        params = tuple(sorted((str(k), float(v)) for k, v in
                              dict(self.measure_params or ()).items()))
        object.__setattr__(self, "measure_params", params)
        if self.metric != "euclidean":
            measures_mod.get_measure(self.metric, **dict(params))  # validate

    @property
    def is_elastic(self) -> bool:
        return self.metric != "euclidean"

    def measure(self) -> Optional[MeasureSpec]:
        """The elastic measure spec, or None under the euclidean baseline."""
        if not self.is_elastic:
            return None
        return measures_mod.get_measure(self.metric,
                                        **dict(self.measure_params))

    def subseq_len(self, D: int) -> int:
        base = D // self.n_sub
        return base + self.tail(D) if (self.use_prealign and self.is_elastic) else base

    def tail(self, D: int) -> int:
        if self.snap_tail is not None:
            return int(self.snap_tail)
        return max(1, int(round(self.tail_frac * (D // self.n_sub))))

    def window(self, D: int) -> Optional[int]:
        if not self.is_elastic:
            return None
        return max(1, int(round(self.window_frac * self.subseq_len(D))))

    def refine_t(self) -> int:
        return max(1, int(round(self.refine_frac * self.codebook_size)))

    def full_scan_encode(self) -> bool:
        """True when encoding is an exact full scan of every centroid:
        explicitly requested, a refine budget covering the whole codebook,
        or a measure without a sound LB cascade (the filter-then-refine
        shortcut would prune incorrectly, so it is capability-gated off).
        """
        if self.exact_encode or self.refine_t() >= self.codebook_size:
            return True
        spec = self.measure()
        return spec is not None and not spec.has_keogh_lb


class PQCodebook(NamedTuple):
    """Trained quantizer state (a pytree — jit/shard friendly).

    >>> import jax.numpy as jnp
    >>> cb = PQCodebook(jnp.zeros((2, 4, 5)), jnp.zeros((2, 4, 4)),
    ...                 jnp.zeros((2, 4, 5)), jnp.zeros((2, 4, 5)))
    >>> cb.n_sub, cb.codebook_size, cb.subseq_len
    (2, 4, 5)
    """
    centroids: jnp.ndarray   # (M, K, S) float32
    lut: jnp.ndarray         # (M, K, K) squared elastic distance
    env_upper: jnp.ndarray   # (M, K, S)
    env_lower: jnp.ndarray   # (M, K, S)

    @property
    def n_sub(self) -> int:
        return self.centroids.shape[0]

    @property
    def codebook_size(self) -> int:
        return self.centroids.shape[1]

    @property
    def subseq_len(self) -> int:
        return self.centroids.shape[2]


# ---------------------------------------------------------------------------
# Segmentation
# ---------------------------------------------------------------------------

def segment(X: jnp.ndarray, cfg: PQConfig) -> jnp.ndarray:
    """``X (N, D)`` -> ``(N, M, S)`` subsequences (pre-aligned or fixed).

    >>> import jax.numpy as jnp
    >>> cfg = PQConfig(n_sub=2, use_prealign=False)
    >>> segment(jnp.zeros((3, 8)), cfg).shape
    (3, 2, 4)
    """
    D = X.shape[-1]
    if cfg.use_prealign and cfg.is_elastic:
        return prealign(X, cfg.n_sub, cfg.wavelet_level, cfg.tail(D))
    return fixed_segments(X, cfg.n_sub)


# ---------------------------------------------------------------------------
# Training (Algorithm 1)
# ---------------------------------------------------------------------------

def fit(key: jax.Array, X: jnp.ndarray, cfg: PQConfig) -> PQCodebook:
    """Learn the codebook, LUT and envelopes from training series ``X (N, D)``.

    >>> import jax, jax.numpy as jnp
    >>> cfg = PQConfig(n_sub=2, codebook_size=2, use_prealign=False,
    ...                kmeans_iters=1, dba_iters=1)
    >>> X = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 10.0
    >>> cb = fit(jax.random.PRNGKey(0), X, cfg)
    >>> cb.centroids.shape, cb.lut.shape
    ((2, 2, 4), (2, 2, 2))
    """
    X = jnp.asarray(X, jnp.float32)
    D = X.shape[-1]
    segs = segment(X, cfg)                       # (N, M, S)
    window = cfg.window(D)
    keys = jax.random.split(key, cfg.n_sub)

    spec = cfg.measure()
    cents, luts, uppers, lowers = [], [], [], []
    for m in range(cfg.n_sub):
        sub = segs[:, m, :]
        if cfg.is_elastic:
            res = dba_kmeans(keys[m], sub, cfg.codebook_size,
                             iters=cfg.kmeans_iters, dba_iters=cfg.dba_iters,
                             window=window, measure=spec)
            lut = elastic_cdist(res.centroids, res.centroids, window,
                                measure=spec)
        else:
            res = euclidean_kmeans(keys[m], sub, cfg.codebook_size,
                                   iters=cfg.kmeans_iters)
            lut = euclidean_sq(res.centroids, res.centroids)
        up, lo = keogh_envelope(res.centroids, window or 1)
        cents.append(res.centroids)
        luts.append(lut)
        uppers.append(up)
        lowers.append(lo)

    return PQCodebook(jnp.stack(cents), jnp.stack(luts),
                      jnp.stack(uppers), jnp.stack(lowers))


# ---------------------------------------------------------------------------
# Encoding (Algorithm 2) — vectorized filter-then-refine
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("window", "refine_t",
                                             "full_scan", "measure"))
def _encode_segs(segs: jnp.ndarray, cb: PQCodebook, window: Optional[int],
                 refine_t: int, full_scan: bool,
                 measure: Optional[MeasureSpec]
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``segs (N, M, S)`` -> codes ``(N, M)`` int32 + soundness flags.

    ``measure=None`` selects the euclidean baseline.  All exact elastic
    refinements across the whole (series x subspace x
    candidate) set are flattened into ONE zipped-pair batch through the
    dispatch layer, so the Pallas wavefront kernel sees a single large
    launch instead of N*M tiny ones.  The LB filter-then-refine shortcut
    only runs for measures with a sound Keogh cascade; ``full_scan`` (see
    ``PQConfig.full_scan_encode``) covers the rest.
    """
    N, M, S = segs.shape
    K = cb.codebook_size

    if measure is None:
        d = jnp.sum((segs[:, :, None, :] - cb.centroids[None]) ** 2, -1)
        return jnp.argmin(d, -1).astype(jnp.int32), jnp.ones((N, M), bool)

    if full_scan:
        # Full scan: per-subspace all-pairs launches — the cdist kernel
        # broadcasts centroids per tile, so nothing of size N*K*S is ever
        # materialized.
        d = jnp.stack([elastic_cdist(segs[:, m], cb.centroids[m], window,
                                     measure=measure)
                       for m in range(M)], axis=1)           # (N, M, K)
        return jnp.argmin(d, -1).astype(jnp.int32), jnp.ones((N, M), bool)

    lbs = jnp.maximum(
        lb_kim(segs[:, :, None, :], cb.centroids[None]),
        lb_keogh(segs[:, :, None, :], cb.env_upper[None],
                 cb.env_lower[None]))                        # (N, M, K)
    _, cand = jax.lax.top_k(-lbs, refine_t)                  # T most promising
    T = refine_t

    qs = jnp.broadcast_to(segs[:, :, None, :], (N, M, T, S))
    cs = cb.centroids[jnp.arange(M)[None, :, None], cand]    # (N, M, T, S)
    d = elastic_pairwise(qs.reshape(-1, S), cs.reshape(-1, S),
                         window, measure=measure).reshape(N, M, T)
    best = jnp.argmin(d, -1)                                 # (N, M)
    codes = jnp.take_along_axis(
        cand, best[..., None], -1)[..., 0].astype(jnp.int32)
    # Soundness certificate: the true NN is inside the candidate set iff
    # best refined distance <= every excluded centroid's lower bound; the
    # excluded minimum is simply the (T+1)-th smallest bound.
    best_d = jnp.take_along_axis(d, best[..., None], -1)[..., 0]
    neg, _ = jax.lax.top_k(-lbs, refine_t + 1)
    return codes, best_d <= -neg[..., -1]


def uses_fused_prealign(cfg: PQConfig) -> bool:
    """True when :func:`encode` takes the fused prealign+encode dispatch
    path: an elastic metric, pre-alignment on, and an exact (full-scan)
    encode — the LB filter-then-refine route still needs materialized
    segments and envelopes, so it stays on the two-step.

    >>> uses_fused_prealign(PQConfig())            # LB filter: two-step
    False
    >>> uses_fused_prealign(PQConfig(exact_encode=True))
    True
    """
    return (cfg.fused_encode and cfg.use_prealign and cfg.is_elastic
            and cfg.full_scan_encode())


def encode(X: jnp.ndarray, cb: PQCodebook, cfg: PQConfig) -> jnp.ndarray:
    """Encode raw series ``X (N, D)`` to PQ codes ``(N, M)``.

    >>> import jax, jax.numpy as jnp
    >>> cfg = PQConfig(n_sub=2, codebook_size=2, use_prealign=False,
    ...                kmeans_iters=1, dba_iters=1)
    >>> X = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 10.0
    >>> cb = fit(jax.random.PRNGKey(0), X, cfg)
    >>> codes = encode(X, cb, cfg)
    >>> codes.shape, str(codes.dtype)
    ((4, 2), 'int32')
    """
    codes, _ = encode_with_stats(X, cb, cfg)
    return codes


def encode_with_stats(X: jnp.ndarray, cb: PQCodebook, cfg: PQConfig
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Encode + per-code soundness flags (True = certified exact-NN code).

    >>> import jax, jax.numpy as jnp
    >>> cfg = PQConfig(n_sub=2, codebook_size=2, use_prealign=False,
    ...                kmeans_iters=1, dba_iters=1)
    >>> X = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 10.0
    >>> cb = fit(jax.random.PRNGKey(0), X, cfg)
    >>> codes, sound = encode_with_stats(X, cb, cfg)
    >>> sound.shape, str(sound.dtype)
    ((4, 2), 'bool')
    """
    X = jnp.asarray(X, jnp.float32)
    D = X.shape[-1]
    if uses_fused_prealign(cfg):
        codes = prealign_encode(X, cb.centroids, level=cfg.wavelet_level,
                                tail=cfg.tail(D), window=cfg.window(D),
                                measure=cfg.measure())
        return codes, jnp.ones(codes.shape, bool)   # full scan: always exact
    segs = segment(X, cfg)
    return _encode_segs(segs, cb, cfg.window(D), cfg.refine_t(),
                        cfg.full_scan_encode(), cfg.measure())


# ---------------------------------------------------------------------------
# Distances (§3.3)
# ---------------------------------------------------------------------------

def cdist_sym(codes_a: jnp.ndarray, codes_b: jnp.ndarray,
              lut: jnp.ndarray, *, lut_dtype: str = "float32") -> jnp.ndarray:
    """Symmetric PQ distance matrix: ``(Na, M) x (Nb, M) -> (Na, Nb)``.

    Routed through the dispatch layer: one-hot MXU contractions on the
    Pallas ADC kernel, plain LUT gathers on the pure-JAX route; sqrt of the
    summed squared subspace costs either way.  ``lut_dtype`` selects the
    resident-table precision (``"float32"`` exact, ``"int8"`` /
    ``"bfloat16"`` quantized — see :func:`repro.core.dispatch.adc_cdist`).

    >>> import jax.numpy as jnp
    >>> codes = jnp.array([[0, 1], [1, 0]], jnp.int32)
    >>> lut = jnp.stack([1.0 - jnp.eye(2)] * 2)    # (M=2, K=2, K=2)
    >>> [round(float(x), 3) for x in cdist_sym(codes, codes, lut).ravel()]
    [0.0, 1.414, 1.414, 0.0]
    """
    return adc_cdist(codes_a, codes_b, lut, lut_dtype=lut_dtype)


@functools.partial(jax.jit, static_argnames=("window", "euclidean",
                                             "measure"))
def query_lut(q_segs: jnp.ndarray, cb: PQCodebook, window: Optional[int],
              euclidean: bool = False,
              measure: Optional[MeasureSpec] = None) -> jnp.ndarray:
    """Asymmetric query table: ``q_segs (M, S)`` -> ``(M, K)`` subspace
    distances under the configured measure.

    >>> import jax, jax.numpy as jnp
    >>> cfg = PQConfig(n_sub=2, codebook_size=2, use_prealign=False,
    ...                kmeans_iters=1, dba_iters=1)
    >>> X = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 10.0
    >>> cb = fit(jax.random.PRNGKey(0), X, cfg)
    >>> q_segs = segment(X, cfg)[0]                # one query's segments
    >>> query_lut(q_segs, cb, cfg.window(8), measure=cfg.measure()).shape
    (2, 2)
    """
    return query_lut_batch(q_segs[None], cb, window, euclidean, measure)[0]


@functools.partial(jax.jit, static_argnames=("window", "euclidean",
                                             "measure"))
def query_lut_batch(q_segs: jnp.ndarray, cb: PQCodebook,
                    window: Optional[int],
                    euclidean: bool = False,
                    measure: Optional[MeasureSpec] = None) -> jnp.ndarray:
    """Batched asymmetric tables: ``q_segs (Nq, M, S)`` -> ``(Nq, M, K)``.

    One all-pairs dispatch launch per subspace; the cdist kernel broadcasts
    each centroid row per tile, so the Nq x K cross-product of series is
    never materialized.

    >>> import jax, jax.numpy as jnp
    >>> cfg = PQConfig(n_sub=2, codebook_size=2, use_prealign=False,
    ...                kmeans_iters=1, dba_iters=1)
    >>> X = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 10.0
    >>> cb = fit(jax.random.PRNGKey(0), X, cfg)
    >>> query_lut_batch(segment(X, cfg), cb, cfg.window(8),
    ...                 measure=cfg.measure()).shape
    (4, 2, 2)
    """
    Nq, M, S = q_segs.shape
    if euclidean:
        return jnp.sum(
            (q_segs[:, :, None, :] - cb.centroids[None]) ** 2, -1)
    return jnp.stack([elastic_cdist(q_segs[:, m], cb.centroids[m], window,
                                    measure=measure)
                      for m in range(M)], axis=1)


@jax.jit
def _adc_gather(qlut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """``qlut (M, K)``, ``codes (N, M)`` -> distances ``(N,)``."""
    m_idx = jnp.arange(qlut.shape[0])
    d2 = jnp.sum(qlut[m_idx[None, :], codes], axis=-1)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def cdist_asym(Q: jnp.ndarray, codes: jnp.ndarray, cb: PQCodebook,
               cfg: PQConfig) -> jnp.ndarray:
    """Asymmetric distances: raw queries ``Q (Nq, D)`` vs codes ``(N, M)``.

    >>> import jax, jax.numpy as jnp
    >>> cfg = PQConfig(n_sub=2, codebook_size=2, use_prealign=False,
    ...                kmeans_iters=1, dba_iters=1)
    >>> X = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 10.0
    >>> cb = fit(jax.random.PRNGKey(0), X, cfg)
    >>> cdist_asym(X[:3], encode(X, cb, cfg), cb, cfg).shape
    (3, 4)
    """
    Q = jnp.asarray(Q, jnp.float32)
    D = Q.shape[-1]
    q_segs = segment(Q, cfg)                     # (Nq, M, S)
    luts = query_lut_batch(q_segs, cb, cfg.window(D), not cfg.is_elastic,
                           cfg.measure())
    return jax.vmap(lambda ql: _adc_gather(ql, codes))(luts)


@jax.jit
def cdist_sym_refined(codes_a: jnp.ndarray, segs_a: jnp.ndarray,
                      codes_b: jnp.ndarray, segs_b: jnp.ndarray,
                      cb: PQCodebook) -> jnp.ndarray:
    """§4.2 clustering distance: symmetric PQ, but where two series share a
    code in subspace m (LUT says 0), substitute the Keogh lower bound
    ``max(lb(a^m, env(code)), lb(b^m, env(code)))`` — guaranteed between 0
    and the true subspace DTW.

    >>> import jax, jax.numpy as jnp
    >>> cfg = PQConfig(n_sub=2, codebook_size=2, use_prealign=False,
    ...                kmeans_iters=1, dba_iters=1)
    >>> X = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 10.0
    >>> cb = fit(jax.random.PRNGKey(0), X, cfg)
    >>> codes, segs = encode(X, cb, cfg), segment(X, cfg)
    >>> cdist_sym_refined(codes, segs, codes, segs, cb).shape
    (4, 4)
    """
    def per_sub(am, sa, bm, sb, lut_m, up_m, lo_m):
        base = lut_m[am[:, None], bm[None, :]]                  # (Na, Nb)
        lb_a = lb_keogh(sa[:, None, :], up_m[bm][None, :, :],   # a vs b's code
                        lo_m[bm][None, :, :])
        lb_b = lb_keogh(sb[None, :, :], up_m[am][:, None, :],   # b vs a's code
                        lo_m[am][:, None, :])
        fallback = jnp.maximum(lb_a, lb_b)
        same = am[:, None] == bm[None, :]
        return jnp.where(same, fallback, base)

    d2 = jnp.sum(jax.vmap(per_sub, in_axes=(1, 1, 1, 1, 0, 0, 0))(
        codes_a, segs_a, codes_b, segs_b,
        cb.lut, cb.env_upper, cb.env_lower), 0)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


# ---------------------------------------------------------------------------
# Memory accounting (§3.4)
# ---------------------------------------------------------------------------

def memory_cost(cfg: PQConfig, D: int, n_series: int, *,
                n_segments: int = 0, n_lists: int = 0,
                hot_capacity: int = 0, n_devices: int = 1) -> dict:
    """Bytes for raw data vs PQ representation + auxiliary structures.

    With the segmented-index keywords, the estimate also covers the
    streaming lifecycle layer (:mod:`repro.index`): per-entry id/tombstone/
    assignment sidecars, per-segment inverted-list offset tables, and the
    raw float32 hot-segment buffer — so ``compaction`` gains (fewer
    segments, no dead padding) are visible in the same accounting that
    §3.4 uses for the quantizer itself.

    ``n_devices > 1`` additionally splits the segmented estimate into
    per-device accounting for the list-sharded layout: the quantizers,
    inverted-list tables and hot buffer are *replicated* on every device
    (``replicated_bytes``) while the sealed codes and their sidecars are
    *partitioned* across the mesh (``partitioned_bytes``), so the
    per-device high-water mark is

        ``max_device_bytes = replicated + ceil(partitioned / n_devices)``

    — the partitioned share shrinks ~linearly with the mesh (up to the
    one-list placement slack of :mod:`repro.index.placement`).

    >>> cost = memory_cost(PQConfig(), 128, 1000)
    >>> cost["raw_bytes"], cost["code_bytes"]
    (512000, 8000)
    >>> cost["compression"]
    64.0
    """
    S = cfg.subseq_len(D)
    M, K = cfg.n_sub, cfg.codebook_size
    code_bits = max(1, int(np.ceil(np.log2(K))))
    raw = 4 * D * n_series
    codes = int(np.ceil(code_bits / 8)) * M * n_series
    codebook = 4 * M * K * S
    lut = 4 * M * K * K
    envelopes = 2 * 4 * M * K * S
    out = dict(raw_bytes=raw, code_bytes=codes, codebook_bytes=codebook,
               lut_bytes=lut, envelope_bytes=envelopes,
               aux_bytes=codebook + lut + envelopes,
               compression=raw / max(codes, 1))
    if n_segments or hot_capacity:
        # sealed sidecars: int32 id + int32 coarse assignment + bool live
        sidecar = (4 + 4 + 1) * n_series
        # per-segment inverted-list tables: int32 start + len per list
        # (+ int32 placement under the sharded layout — counted replicated)
        lists = 2 * 4 * n_lists * n_segments
        # hot segment: raw float32 buffer + id/live sidecars at capacity
        hot = (4 * D + 4 + 1) * hot_capacity
        out.update(sidecar_bytes=sidecar, list_bytes=lists, hot_bytes=hot,
                   index_bytes=codes + sidecar + lists + hot,
                   total_bytes=codes + sidecar + lists + hot
                   + out["aux_bytes"])
        if n_devices > 1:
            # coarse centroids ride along with every device's probe stage
            coarse = 4 * n_lists * D
            replicated = out["aux_bytes"] + coarse + lists + hot
            partitioned = codes + sidecar
            out.update(
                n_devices=n_devices,
                coarse_bytes=coarse,
                replicated_bytes=replicated,
                partitioned_bytes=partitioned,
                max_device_bytes=replicated + -(-partitioned // n_devices))
    return out
