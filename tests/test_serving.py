"""Serving core: coalesced microbatching, concurrent ingest, admission
control.  The acceptance test here is the headline guarantee of
``docs/serving.md``: searches issued during a background
insert/seal/compact storm are *bit-identical* to searching the quiesced
snapshot they ran against, on both the jax and Pallas-interpret backends.
"""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import dispatch
from repro.core.dispatch import use_backend
from repro.core.pq import PQConfig
from repro.data.timeseries import cbf
from repro.index import IndexConfig, StreamingIndex
from repro.serve_index import (SHED_POLICIES, Backpressure, IndexServer,
                               ServeConfig)


def _config(n_lists=4, hot_capacity=12):
    pq = PQConfig(n_sub=4, codebook_size=8, use_prealign=False,
                  kmeans_iters=2, dba_iters=1)
    return IndexConfig(pq=pq, n_lists=n_lists, hot_capacity=hot_capacity,
                       coarse_iters=3)


@pytest.fixture(scope="module")
def data():
    X, _ = cbf(n_per_class=12, length=48, seed=0)    # 36 series
    Q, _ = cbf(n_per_class=2, length=48, seed=7)     # 6 queries
    return X.astype(np.float32), Q.astype(np.float32)


@pytest.fixture(scope="module")
def booted(data):
    X, _ = data
    return StreamingIndex.bootstrap(jax.random.PRNGKey(0), X, _config())


def _fresh(booted):
    return StreamingIndex.from_parts(booted.cfg, booted.coarse, booted.cb,
                                     booted.dim)


@pytest.fixture
def obs_on():
    prev = obs.enabled()
    obs.enable()
    yield
    if not prev:
        obs.disable()


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

class TestServeConfig:
    def test_bucket_for(self):
        cfg = ServeConfig()
        assert [cfg.bucket_for(n) for n in (1, 2, 3, 5, 64)] == \
            [1, 2, 4, 8, 64]
        with pytest.raises(ValueError):
            cfg.bucket_for(65)
        with pytest.raises(ValueError):
            cfg.bucket_for(0)

    def test_validation(self):
        with pytest.raises(ValueError):
            ServeConfig(q_buckets=(4, 2))          # not increasing
        with pytest.raises(ValueError):
            ServeConfig(shed_policy="drop_tables")
        with pytest.raises(ValueError):
            ServeConfig(queue_bound=0)
        with pytest.raises(ValueError):
            ServeConfig(coalesce_window_s=-1.0)
        assert set(SHED_POLICIES) == {"shed_inserts", "shed_all", "block"}


# ---------------------------------------------------------------------------
# acceptance: bit-identical searches under a concurrent write storm
# ---------------------------------------------------------------------------

class TestConcurrentBitIdentity:
    @pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
    def test_search_during_storm_bit_identical(self, data, booted, backend):
        """Client threads search while the writer seals/compacts/deletes.
        Every result is re-derived afterwards by searching the retained
        (now quiesced) snapshot it reported running against — distances
        and ids must match bit-for-bit."""
        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:24])
        views = {}
        results = []
        res_lock = threading.Lock()
        cfg = ServeConfig(n_probe=4, topk=3, coalesce_window_s=0.001)

        def searcher(seed):
            rng = np.random.default_rng(seed)
            for _ in range(5):
                rows = rng.integers(0, len(Q), size=int(rng.integers(1, 4)))
                q = Q[rows]
                r = srv.submit_search(q).result(timeout=120)
                with res_lock:
                    results.append((q, r))

        with use_backend(backend):
            with IndexServer(idx, cfg, on_publish=lambda v:
                             views.setdefault(v.version, v)) as srv:
                views[srv.view.version] = srv.view
                threads = [threading.Thread(target=searcher, args=(s,))
                           for s in range(3)]
                for t in threads:
                    t.start()
                # the storm: grow, tombstone, seal, grow, merge, tombstone
                storm = [srv.insert(X[24:]), srv.delete([1, 5, 17]),
                         srv.flush(), srv.insert(X[:6] + 0.25),
                         srv.compact(), srv.delete([2])]
                for f in storm:
                    f.result(timeout=120)
                for t in threads:
                    t.join()
                srv.quiesce(timeout=120)

            assert len(results) == 15
            assert len(views) >= 2                # storm really swapped views
            for q, r in results:
                view = views[r.version]
                d_ref, i_ref = view.search(jnp.asarray(q), n_probe=4, topk=3)
                np.testing.assert_array_equal(np.asarray(r.ids),
                                              np.asarray(i_ref))
                np.testing.assert_array_equal(np.asarray(r.dist),
                                              np.asarray(d_ref))

    def test_completed_write_is_visible(self, data, booted):
        """insert(...).result() resolving implies the rows are searchable:
        futures resolve only after the snapshot swap."""
        X, _ = data
        idx = _fresh(booted)
        with IndexServer(idx, ServeConfig(n_probe=4, topk=1,
                                          coalesce_window_s=0.0)) as srv:
            ids = srv.insert(X[:10]).result(timeout=120)
            d, nn = srv.search(X[:3], timeout=120)
            assert set(np.asarray(nn)[:, 0].tolist()) <= set(ids.tolist())
            hits = srv.delete(ids[:2]).result(timeout=120)
            assert hits == 2
            _, nn2 = srv.search(X[:3], timeout=120)
            assert not set(np.asarray(nn2)[:, 0]) & set(ids[:2].tolist())

    def test_view_is_immune_to_later_writes(self, data, booted):
        """A captured view keeps answering identically after the hot
        buffer it copied has been mutated and sealed (the double-buffer
        property)."""
        X, Q = data
        idx = _fresh(booted)
        with IndexServer(idx, ServeConfig(n_probe=4, topk=2,
                                          coalesce_window_s=0.0)) as srv:
            srv.insert(X[:8]).result(timeout=120)     # hot-only state
            view = srv.view
            d0, i0 = view.search(jnp.asarray(Q), n_probe=4, topk=2)
            srv.insert(X[8:30]).result(timeout=120)   # mutates + seals hot
            srv.compact().result(timeout=120)
            d1, i1 = view.search(jnp.asarray(Q), n_probe=4, topk=2)
            np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
            np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


# ---------------------------------------------------------------------------
# admission control / backpressure
# ---------------------------------------------------------------------------

class TestBackpressure:
    def _wedged(self, booted, **kw):
        """Server whose writer never drains (not started): the bounded
        queue fills deterministically."""
        srv = IndexServer(_fresh(booted), ServeConfig(**kw))
        srv._started = True
        return srv

    def test_shed_inserts_full_queue(self, booted, obs_on):
        srv = self._wedged(booted, queue_bound=2, shed_policy="shed_inserts")
        X = np.zeros((1, booted.dim), np.float32)
        srv.flush(), srv.flush()                  # maintenance fills queue
        assert srv.pressure() == 1.0
        before = obs.counter("serving_shed_total", persistent=True,
                             op="insert").value
        with pytest.raises(Backpressure):
            srv.insert(X)
        assert obs.counter("serving_shed_total", persistent=True,
                           op="insert").value == before + 1

    def test_shed_inserts_admits_deletes(self, booted):
        srv = self._wedged(booted, queue_bound=2, shed_policy="shed_inserts")
        srv.flush()                               # 1 of 2 slots used
        fut = srv.delete([0])                     # admitted, no shed
        assert not fut.done()
        assert srv._wq.qsize() == 2

    def test_shed_all_sheds_deletes_too(self, booted, obs_on):
        srv = self._wedged(booted, queue_bound=1, shed_policy="shed_all")
        srv.flush()
        before = obs.counter("serving_shed_total", persistent=True,
                             op="delete").value
        with pytest.raises(Backpressure):
            srv.delete([0])
        assert obs.counter("serving_shed_total", persistent=True,
                           op="delete").value == before + 1

    def test_block_policy_blocks_until_drained(self, booted):
        srv = self._wedged(booted, queue_bound=1, shed_policy="block")
        srv.flush()                               # queue full
        X = np.zeros((1, booted.dim), np.float32)
        t = threading.Thread(target=lambda: srv.insert(X), daemon=True)
        t.start()
        t.join(timeout=0.2)
        assert t.is_alive()                       # blocked, not shed
        srv._wq.get()                             # writer-side drain
        t.join(timeout=10)
        assert not t.is_alive()

    def test_rejects_writes_when_not_running(self, booted):
        srv = IndexServer(_fresh(booted), ServeConfig())
        with pytest.raises(RuntimeError):
            srv.insert(np.zeros((1, booted.dim), np.float32))

    def test_search_validates_shape(self, booted):
        srv = IndexServer(_fresh(booted), ServeConfig())
        srv._started = True
        with pytest.raises(ValueError):
            srv.submit_search(np.zeros((2, booted.dim + 1), np.float32))
        with pytest.raises(ValueError):
            srv.submit_search(np.zeros((0, booted.dim), np.float32))


# ---------------------------------------------------------------------------
# coalescer: bucketing, windowing, compiled-shape reuse
# ---------------------------------------------------------------------------

class TestCoalescer:
    def test_concurrent_requests_coalesce_into_one_bucket(self, data,
                                                          booted, obs_on):
        """Three 1-query requests inside one window launch as a single
        padded bucket-4 batch against one snapshot."""
        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:16])
        cfg = ServeConfig(n_probe=2, topk=1, coalesce_window_s=0.25)
        with IndexServer(idx, cfg) as srv:
            before = obs.counter("serving_batches_total", persistent=True,
                                 bucket="4").value
            futs = [srv.submit_search(Q[i:i + 1]) for i in range(3)]
            rs = [f.result(timeout=120) for f in futs]
            after = obs.counter("serving_batches_total", persistent=True,
                                bucket="4").value
        assert after == before + 1
        assert len({r.version for r in rs}) == 1  # one snapshot, one launch
        for i, r in enumerate(rs):
            assert r.dist.shape == (1, 1) and r.ids.shape == (1, 1)

    def test_oversized_request_is_chunked(self, data, booted):
        """Requests wider than the largest bucket split into chunks whose
        re-concatenated rows match the direct index search bit-for-bit."""
        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:20])
        idx.flush()
        d_direct, i_direct = idx.search(Q, n_probe=2, topk=2)
        cfg = ServeConfig(n_probe=2, topk=2, coalesce_window_s=0.0,
                          q_buckets=(1, 2, 4))
        with IndexServer(idx, cfg) as srv:
            r = srv.submit_search(Q).result(timeout=120)   # 6 > max bucket 4
        assert r.dist.shape == (6, 2)
        np.testing.assert_array_equal(np.asarray(r.ids), np.asarray(i_direct))
        np.testing.assert_array_equal(np.asarray(r.dist),
                                      np.asarray(d_direct))

    def test_warm_buckets_trigger_no_new_compilations(self, data, booted):
        """After one warmup pass over the traffic's buckets, steady-state
        mixed-size traffic adds zero trace-time dispatch counts: the
        finite bucket family really does pin the compiled executables."""
        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:20])
        idx.flush()                               # freeze the segment set
        cfg = ServeConfig(n_probe=2, topk=1, coalesce_window_s=0.0)
        with IndexServer(idx, cfg) as srv:
            for n in (1, 2, 4):                   # warm each bucket
                srv.submit_search(Q[:n]).result(timeout=120)
            # the steady-state per-call signature: eager dispatch wrappers
            # (the coarse cdist) count once per *call*, jitted stages only
            # at *trace* time — so one more warm search isolates the
            # eager-only delta
            base = dict(dispatch.totals)
            srv.submit_search(Q[:2]).result(timeout=120)
            per_call = {k: v - base.get(k, 0)
                        for k, v in dispatch.totals.items()
                        if v != base.get(k, 0)}
            before = dict(dispatch.totals)
            rng = np.random.default_rng(0)
            rounds = 6
            for _ in range(rounds):
                n = int(rng.choice([1, 2, 3, 4]))   # 3 pads into bucket 4
                srv.submit_search(Q[:n]).result(timeout=120)
            want = dict(before)
            for key, v in per_call.items():
                want[key] = want.get(key, 0) + rounds * v
            # any re-trace of a jitted stage would bump its counter past
            # the eager-only expectation
            assert dict(dispatch.totals) == want

    def test_graceful_stop_answers_queued_requests(self, data, booted):
        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:12])
        cfg = ServeConfig(n_probe=2, topk=1, coalesce_window_s=0.2)
        srv = IndexServer(idx, cfg).start()
        futs = [srv.submit_search(Q[:2]) for _ in range(3)]
        srv.stop()                                # drains before exiting
        for f in futs:
            r = f.result(timeout=5)
            assert r.ids.shape == (2, 1)


# ---------------------------------------------------------------------------
# serving telemetry
# ---------------------------------------------------------------------------

class TestServingObs:
    def test_serving_metrics_populate(self, data, booted, obs_on):
        X, Q = data
        idx = _fresh(booted)
        with IndexServer(idx, ServeConfig(n_probe=2, topk=1,
                                          coalesce_window_s=0.0)) as srv:
            srv.insert(X[:16]).result(timeout=120)
            srv.search(Q[:2], timeout=120)
        assert obs.counter("serving_queries_total",
                           persistent=True).value >= 2
        assert obs.counter("serving_view_swaps_total",
                           persistent=True).value >= 1
        assert obs.gauge("serving_view_version",
                         persistent=True).value >= 1
        assert obs.histogram("serving_snapshot_swap_seconds",
                             persistent=True).count >= 1

    def test_serving_spans_recorded(self, data, booted, obs_on):
        from repro.obs import export
        X, Q = data
        idx = _fresh(booted)
        with IndexServer(idx, ServeConfig(n_probe=2, topk=1,
                                          coalesce_window_s=0.0)) as srv:
            srv.insert(X[:16]).result(timeout=120)
            srv.search(Q[:2], timeout=120)
        snap = export.snapshot()
        stages = {h["labels"].get("stage") for h in snap["histograms"]
                  if h["name"] == "stage_seconds"}
        assert {"serving.apply", "serving.snapshot_swap",
                "serving.batch_search"} <= stages
