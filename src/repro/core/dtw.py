"""Dynamic Time Warping in JAX — anti-diagonal wavefront formulation.

The classic DP recurrence

    dtw[i, j] = (a_i - b_j)^2 + min(dtw[i-1, j-1], dtw[i, j-1], dtw[i-1, j])

has a row-wise prefix dependency, which serializes on vector hardware.  We
instead sweep the DP table anti-diagonal by anti-diagonal: every cell on
diagonal ``d = i + j`` depends only on diagonals ``d-1`` and ``d-2``, so each
diagonal is one vector operation (VPU lanes = cells) and a length-``2L-1``
``lax.scan`` carries two diagonal registers.  A Sakoe-Chiba band ``|i-j| <= w``
is a static mask, keeping every shape fixed.

All distances here are *squared* DTW costs (the paper aggregates squared
subspace distances); take ``jnp.sqrt`` at the end if a metric value is needed.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "dtw",
    "dtw_pair",
    "dtw_batch",
    "dtw_cdist",
    "dtw_full_table",
    "euclidean_sq",
]

_INF = jnp.float32(jnp.inf)


def _diag_sweep(a: jnp.ndarray, b: jnp.ndarray, window: Optional[int],
                return_table: bool):
    """Shared wavefront sweep.  ``a``/``b`` are rank-1, equal length L.

    Returns the final squared DTW cost, and (optionally) the full stack of
    diagonals ``(2L-1, L)`` where ``table[d, i] == dtw[i, d-i]`` — used by the
    DBA backtracking pass.
    """
    L = a.shape[0]
    w = L if window is None else int(window)
    idx = jnp.arange(L)

    # b gathered along a diagonal: cell (i, d-i) needs b[d - i].
    # Pad b so that out-of-range gathers read +inf-cost positions.
    b_pad = jnp.concatenate([b, jnp.zeros((L,), b.dtype)])

    def step(carry, d):
        prev1, prev2 = carry  # diagonals d-1 and d-2, indexed by i
        j = d - idx
        valid = (j >= 0) & (j < L) & (jnp.abs(idx - j) <= w)
        cost = (a - b_pad[jnp.clip(j, 0, 2 * L - 1)]) ** 2

        # Predecessors (indexed by i on their own diagonals):
        #   dtw[i-1, j-1] -> prev2 shifted down by one in i
        #   dtw[i,   j-1] -> prev1 at i
        #   dtw[i-1, j  ] -> prev1 shifted down by one in i
        shift1 = jnp.concatenate([jnp.full((1,), _INF), prev1[:-1]])
        shift2 = jnp.concatenate([jnp.full((1,), _INF), prev2[:-1]])
        best_prev = jnp.minimum(jnp.minimum(shift2, prev1), shift1)
        # Base case: cell (0, 0) has no predecessor.
        best_prev = jnp.where((idx == 0) & (d == 0), 0.0, best_prev)
        diag = jnp.where(valid, cost + best_prev, _INF)
        out = diag if return_table else None
        return (diag, prev1), out

    init = (jnp.full((L,), _INF), jnp.full((L,), _INF))
    (last, _), table = jax.lax.scan(step, init, jnp.arange(2 * L - 1))
    final = last[L - 1]
    return final, table


def dtw_pair(a: jnp.ndarray, b: jnp.ndarray,
             window: Optional[int] = None) -> jnp.ndarray:
    """Squared DTW cost between two equal-length 1-D series."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    final, _ = _diag_sweep(a, b, window, return_table=False)
    return final


# Public alias used across the library.
dtw = dtw_pair


def dtw_full_table(a: jnp.ndarray, b: jnp.ndarray,
                   window: Optional[int] = None) -> jnp.ndarray:
    """Full DP table in diagonal layout: ``table[i + j, i] == dtw[i, j]``.

    Used by DBA to backtrack the optimal alignment path.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    _, table = _diag_sweep(a, b, window, return_table=True)
    return table


@functools.partial(jax.jit, static_argnames=("window",))
def dtw_batch(A: jnp.ndarray, B: jnp.ndarray,
              window: Optional[int] = None) -> jnp.ndarray:
    """Pairwise squared DTW over zipped batches: ``A (N, L)``, ``B (N, L)``."""
    return jax.vmap(lambda a, b: dtw_pair(a, b, window))(A, B)


@functools.partial(jax.jit, static_argnames=("window", "block"))
def dtw_cdist(A: jnp.ndarray, B: jnp.ndarray,
              window: Optional[int] = None, block: int = 4096) -> jnp.ndarray:
    """All-pairs squared DTW: ``A (N, L)``, ``B (M, L)`` -> ``(N, M)``.

    Flattens the cross-product and sweeps it in fixed-size blocks; the pair
    indices are derived arithmetically (``idx // M``, ``idx % M``) inside
    each block, so peak memory is bounded by ``block`` — nothing of size
    N*M is ever materialized.
    """
    N, L = A.shape
    M = B.shape[0]
    total = N * M
    nblk = -(-total // block)

    def blk(carry, k):
        idx = jnp.minimum(k * block + jnp.arange(block), total - 1)
        aa = A[idx // M]
        bb = B[idx % M]
        d = jax.vmap(lambda x, y: dtw_pair(x, y, window))(aa, bb)
        return carry, d

    _, out = jax.lax.scan(blk, 0, jnp.arange(nblk))
    return out.reshape(-1)[:total].reshape(N, M)


def euclidean_sq(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """All-pairs squared Euclidean distance (lock-step baseline)."""
    a2 = jnp.sum(A * A, -1)[:, None]
    b2 = jnp.sum(B * B, -1)[None, :]
    return jnp.maximum(a2 + b2 - 2.0 * A @ B.T, 0.0)
