"""PQ-compressed KV decode attention (flash-ADC) Pallas kernel.

The paper's asymmetric distance computation, specialised to dot-product
attention: cached *keys* are PQ-encoded per kv-head (subspaces along
head_dim); at decode time the query builds one small ADC table
``qlut[h, m, k] = q_h^m . codebook[g, m, k]`` and every cached position's
score is ``sum_m qlut[h, m, code]`` — M one-hot MXU contractions instead of
a (S, d) @ (d,) matvec against de-quantized keys.  Values stay exact.

Flash-decoding accumulation: the grid walks KV blocks sequentially; running
max / denominator / weighted-value accumulators persist in VMEM scratch and
the output is written on the last block.  HBM traffic per position drops
from ``2 * d * bytes(kv)`` to ``M + d * bytes(v)`` — the paper's memory
compression argument, applied to the KV cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pq_attn_kernel", "make_pq_attn_call"]


def _one_hot(col: jnp.ndarray, K: int) -> jnp.ndarray:
    iota = jax.lax.broadcasted_iota(jnp.int32, (col.shape[0], K), 1)
    return (iota == col[:, None]).astype(jnp.float32)


def pq_attn_kernel(qlut_ref, codes_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   groups: int, reps: int, n_sub: int, K: int,
                   scale: float, block_s: int, n_blocks: int,
                   valid_len: int):
    """One KV block: ``qlut (H, M*K)``, ``codes (bS, G*M)``, ``v (bS, G*Dv)``.

    Scratch: ``m (H, 1)``, ``l (H, 1)``, ``acc (H, Dv)`` persist across the
    sequential grid; output written at the final block.
    """
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], -1e30)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    codes = codes_ref[...]                       # (bS, G*M)
    vblk = v_ref[...]                            # (bS, G*Dv)
    pos = s * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    in_range = (pos < valid_len)                 # (1, bS)
    Dv = vblk.shape[1] // groups

    for g in range(groups):
        hs = slice(g * reps, (g + 1) * reps)
        # one-hot block for this group: (bS, M*K)
        oh = jnp.concatenate(
            [_one_hot(codes[:, g * n_sub + m], K) for m in range(n_sub)],
            axis=1)
        qq = qlut_ref[hs, :]                     # (R, M*K)
        scores = jax.lax.dot_general(
            qq, oh, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (R, bS)
        scores = jnp.where(in_range, scores, -1e30)

        m_old = m_ref[hs, :]                     # (R, 1)
        m_new = jnp.maximum(m_old, jnp.max(scores, axis=1, keepdims=True))
        corr = jnp.exp(m_old - m_new)            # (R, 1)
        p = jnp.exp(scores - m_new)              # (R, bS)
        p = jnp.where(in_range, p, 0.0)
        l_ref[hs, :] = l_ref[hs, :] * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, vblk[:, g * Dv:(g + 1) * Dv], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)  # (R, Dv)
        acc_ref[hs, :] = acc_ref[hs, :] * corr + pv
        m_ref[hs, :] = m_new

    @pl.when(s == n_blocks - 1)
    def _finalize():
        o_ref[...] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def make_pq_attn_call(H: int, S: int, groups: int, n_sub: int, K: int,
                      Dv: int, scale: float, block_s: int, valid_len: int,
                      interpret: bool):
    """S must be padded to a multiple of block_s."""
    from jax.experimental.pallas import tpu as pltpu

    reps = H // groups
    n_blocks = S // block_s
    kernel = functools.partial(
        pq_attn_kernel, groups=groups, reps=reps, n_sub=n_sub, K=K,
        scale=scale, block_s=block_s, n_blocks=n_blocks, valid_len=valid_len)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((H, n_sub * K), lambda s: (0, 0)),          # qlut
            pl.BlockSpec((block_s, groups * n_sub), lambda s: (s, 0)),  # codes
            pl.BlockSpec((block_s, groups * Dv), lambda s: (s, 0)),     # v
        ],
        out_specs=pl.BlockSpec((H, Dv), lambda s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Dv), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),   # running max
            pltpu.VMEM((H, 1), jnp.float32),   # running denominator
            pltpu.VMEM((H, Dv), jnp.float32),  # weighted-value accumulator
        ],
        interpret=interpret,
    )
