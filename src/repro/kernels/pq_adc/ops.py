"""Jitted public wrappers for the PQ-ADC Pallas kernels."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .. import tune
from ..common import default_interpret, pad_to
from .kernel import (
    make_adc_lookup_call,
    make_adc_lookup_quant_call,
    make_adc_sym_call,
    make_adc_sym_quant_call,
)

__all__ = [
    "adc_sym_cdist",
    "adc_lookup",
    "adc_sym_cdist_quant",
    "adc_lookup_quant",
    "quantize_lut",
]


def _tuned(op: str, param: str, value: Optional[int], K: int,
           interpret: bool, default: int) -> int:
    if value is not None:
        return value
    backend = "pallas_interpret" if interpret else "pallas"
    return tune.tuned(op, param, length=K, window=None, measure=None,
                      backend=backend, default=default)


@functools.partial(jax.jit, static_argnames=("dtype",))
def quantize_lut(lut: jnp.ndarray, dtype: str = "int8"
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-subspace affine quantization of an ADC table.

    ``lut (M, K, K)`` (or ``(M, K)`` query tables) -> ``(q, scale, zero)``
    with ``q`` int8 (symmetric-range, per-subspace affine
    ``v ~ q * scale_m + zero_m``) or bfloat16 (``scale=1``, ``zero=0``).
    ``scale``/``zero`` are ``(M, 1)`` f32, ready for the quantized
    kernels' affine-after-contraction accumulation.
    """
    lut = jnp.asarray(lut, jnp.float32)
    M = lut.shape[0]
    if dtype in ("bf16", "bfloat16"):
        return (lut.astype(jnp.bfloat16), jnp.ones((M, 1), jnp.float32),
                jnp.zeros((M, 1), jnp.float32))
    if dtype != "int8":
        raise ValueError(f"unsupported LUT quantization dtype: {dtype!r}")
    flat = lut.reshape(M, -1)
    lo = flat.min(axis=1, keepdims=True)
    hi = flat.max(axis=1, keepdims=True)
    zero = (hi + lo) * 0.5
    scale = jnp.maximum(hi - lo, 1e-12) / 254.0
    q = jnp.clip(jnp.round((flat - zero) / scale), -127, 127)
    return q.astype(jnp.int8).reshape(lut.shape), scale, zero


@functools.partial(jax.jit, static_argnames=("block_a", "block_b", "interpret"))
def adc_sym_cdist(codes_a: jnp.ndarray, codes_b: jnp.ndarray,
                  lut: jnp.ndarray, block_a: Optional[int] = None,
                  block_b: Optional[int] = None,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Symmetric PQ distance matrix via one-hot MXU contractions.

    ``codes_a (Na, M)``, ``codes_b (Nb, M)`` int32; ``lut (M, K, K)``.
    ``block_a``/``block_b`` default to the tuned launch geometry.
    """
    if interpret is None:
        interpret = default_interpret()
    nA, M = codes_a.shape
    nB = codes_b.shape[0]
    K = lut.shape[-1]
    block_a = _tuned("adc_sym", "block_a", block_a, K, interpret, 128)
    block_b = _tuned("adc_sym", "block_b", block_b, K, interpret, 128)
    block_a = min(block_a, max(8, nA))
    block_b = min(block_b, max(8, nB))
    a = pad_to(codes_a.astype(jnp.int32), block_a, axis=0, value=0)
    b = pad_to(codes_b.astype(jnp.int32), block_b, axis=0, value=0)
    call = make_adc_sym_call(a.shape[0], b.shape[0], M, K,
                             block_a, block_b, interpret)
    return call(a, b, lut.astype(jnp.float32))[:nA, :nB]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def adc_lookup(codes: jnp.ndarray, qlut: jnp.ndarray,
               block: Optional[int] = None,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """Asymmetric scan: ``codes (N, M)``, ``qlut (M, K)`` -> ``(N,)``."""
    if interpret is None:
        interpret = default_interpret()
    n, M = codes.shape
    K = qlut.shape[-1]
    block = _tuned("adc_lookup", "block", block, K, interpret, 256)
    block = min(block, max(8, n))
    c = pad_to(codes.astype(jnp.int32), block, axis=0, value=0)
    call = make_adc_lookup_call(c.shape[0], M, K, block, interpret)
    return call(c, qlut.astype(jnp.float32))[:n, 0]


@functools.partial(jax.jit, static_argnames=("block_a", "block_b", "interpret"))
def adc_sym_cdist_quant(codes_a: jnp.ndarray, codes_b: jnp.ndarray,
                        qlut: jnp.ndarray, scale: jnp.ndarray,
                        zero: jnp.ndarray, block_a: Optional[int] = None,
                        block_b: Optional[int] = None,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """Symmetric ADC over a quantized table from :func:`quantize_lut`:
    ``qlut (M, K, K)`` int8/bf16 plus ``scale``/``zero (M, 1)``."""
    if interpret is None:
        interpret = default_interpret()
    nA, M = codes_a.shape
    nB = codes_b.shape[0]
    K = qlut.shape[-1]
    block_a = _tuned("adc_sym", "block_a", block_a, K, interpret, 128)
    block_b = _tuned("adc_sym", "block_b", block_b, K, interpret, 128)
    block_a = min(block_a, max(8, nA))
    block_b = min(block_b, max(8, nB))
    a = pad_to(codes_a.astype(jnp.int32), block_a, axis=0, value=0)
    b = pad_to(codes_b.astype(jnp.int32), block_b, axis=0, value=0)
    call = make_adc_sym_quant_call(a.shape[0], b.shape[0], M, K,
                                   block_a, block_b, interpret)
    return call(a, b, qlut, scale.astype(jnp.float32),
                zero.astype(jnp.float32))[:nA, :nB]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def adc_lookup_quant(codes: jnp.ndarray, qlut: jnp.ndarray,
                     scale: jnp.ndarray, zero: jnp.ndarray,
                     block: Optional[int] = None,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Asymmetric scan over a quantized query table: ``qlut (M, K)``
    int8/bf16 plus ``scale``/``zero (M, 1)`` -> ``(N,)``."""
    if interpret is None:
        interpret = default_interpret()
    n, M = codes.shape
    K = qlut.shape[-1]
    block = _tuned("adc_lookup", "block", block, K, interpret, 256)
    block = min(block, max(8, n))
    c = pad_to(codes.astype(jnp.int32), block, axis=0, value=0)
    call = make_adc_lookup_quant_call(c.shape[0], M, K, block, interpret)
    return call(c, qlut, scale.astype(jnp.float32),
                zero.astype(jnp.float32))[:n, 0]
