"""Shared benchmark harness: timing, result records, JSON output.

This module is the SINGLE writer of benchmark JSON.  Every suite collects
rows into a :class:`Bench` and calls :meth:`Bench.save`:

* the full record always lands in the canonical directory ``OUT_DIR``
  (``experiments/bench/<name>.json`` — CI uploads this as an artifact);
* passing ``headline=...`` additionally writes the committed repo-root
  summary ``BENCH_<root_name or name>.json`` (headline metadata + the same
  rows) through the same code path — no suite opens files by hand, so the
  two locations can never drift apart.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import jax

__all__ = ["timeit", "Bench", "OUT_DIR", "ROOT_DIR", "SMOKE", "set_smoke",
           "MEASURE", "set_measure", "measure_config_fields",
           "backend_headline", "HW_DEVICE", "set_device"]

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")
# Where the committed BENCH_* headline summaries live (the repo root).
ROOT_DIR = os.environ.get("REPRO_BENCH_ROOT", ".")

# CI smoke mode (benchmarks/run.py --smoke): every suite runs its quick
# sizes with a single repetition — the goal is "the benchmark still runs
# and emits JSON", not stable numbers.
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"

# Elastic measure the measure-aware suites run under (benchmarks/run.py
# --measure; "name" or "name:param=value").
MEASURE = os.environ.get("REPRO_BENCH_MEASURE", "dtw")


def set_smoke(on: bool = True) -> None:
    global SMOKE
    SMOKE = on


def set_measure(name: str) -> None:
    global MEASURE
    MEASURE = name


# Real-hardware leg (benchmarks/run.py --device=tpu|gpu): results go to
# ``experiments/bench/hw_<device>_<suite>.json`` and the committed
# repo-root BENCH_* summaries are never touched — those stay the
# CPU/interpret baselines CI regenerates.
HW_DEVICE: Optional[str] = None


def set_device(device: str) -> None:
    """Record that this run targets real hardware ``device`` ("tpu" or
    "gpu").  Fails fast when JAX's actual default backend disagrees, so a
    mis-provisioned job cannot silently record CPU numbers as hardware."""
    actual = jax.default_backend()
    if actual != device:
        raise RuntimeError(
            f"--device={device} but jax.default_backend() is {actual!r}; "
            "refusing to record mislabelled hardware numbers")
    global HW_DEVICE
    HW_DEVICE = device


def measure_config_fields() -> Dict[str, object]:
    """PQConfig fields selecting :data:`MEASURE` (name + params parsed
    from the ``name:param=value`` form)."""
    from repro.core import measures
    spec = measures.resolve(MEASURE)
    return {"metric": spec.name, "measure_params": spec.params}


def backend_headline() -> Dict[str, object]:
    """Standard headline fields every root BENCH summary carries."""
    from repro.core import dispatch
    from repro.kernels.common import default_interpret
    return {"backend": jax.default_backend(),
            "elastic_backend": dispatch.get_backend(),
            "pallas_interpret": bool(default_interpret()),
            "smoke": SMOKE}


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1,
           **kwargs) -> Dict[str, object]:
    """Median wall time of ``fn(*args)`` with jit warmup; blocks on results.

    Returns the raw per-repetition samples (``samples_s``, wall order) and
    latency percentiles (``p50_s``/``p99_s``, linear-interpolated like
    ``np.percentile`` — same estimator the obs layer exports) alongside
    the legacy ``median_s``/``min_s``/``max_s`` keys, so suites can report
    tail latency without re-running."""
    from repro.obs import percentile
    if SMOKE:
        repeats, warmup = 1, min(warmup, 1)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    srt = sorted(times)
    return {"median_s": srt[len(srt) // 2], "min_s": srt[0],
            "max_s": srt[-1], "repeats": repeats,
            "mean_s": sum(times) / len(times),
            "p50_s": percentile(times, 50.0),
            "p99_s": percentile(times, 99.0),
            "samples_s": times}


class Bench:
    """Collects rows, prints a table, persists JSON (see module docstring).

    ``root_name`` overrides the committed summary's filename stem when it
    differs from the suite name (e.g. suite ``fig5c_prealign`` ->
    ``BENCH_prealign.json``).
    """

    def __init__(self, name: str, root_name: Optional[str] = None):
        self.name = name
        self.root_name = root_name or name
        self.rows: List[dict] = []

    def add(self, **row):
        self.rows.append(row)
        print("  " + " ".join(f"{k}={_fmt(v)}" for k, v in row.items()),
              flush=True)

    def save(self, headline: Optional[dict] = None) -> str:
        """Write the canonical full record; with ``headline``, also the
        committed repo-root ``BENCH_*`` summary.  Returns the canonical
        path.  Smoke runs never touch the root summaries — 1-repetition
        numbers must not clobber the committed baselines."""
        os.makedirs(OUT_DIR, exist_ok=True)
        stem = f"hw_{HW_DEVICE}_{self.name}" if HW_DEVICE else self.name
        path = os.path.join(OUT_DIR, f"{stem}.json")
        with open(path, "w") as f:
            json.dump({"name": self.name, **backend_headline(),
                       "rows": self.rows}, f, indent=1)
        if headline is not None and not SMOKE and not HW_DEVICE:
            os.makedirs(ROOT_DIR, exist_ok=True)
            root = os.path.join(ROOT_DIR, f"BENCH_{self.root_name}.json")
            with open(root, "w") as f:
                json.dump({"name": self.name, **backend_headline(),
                           **headline, "rows": self.rows}, f, indent=1)
            print(f"  saved {path} and {root}")
        return path


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)
