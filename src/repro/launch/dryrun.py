import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # XLA:CPU's while-loop LICM hoists converts of remat-saved activation
    # stacks out of backward loops, materialising a full-precision copy
    # (10.7 GB/device on qwen2-72b train_4k).  TPU's memory-aware scheduler
    # does not make multi-GB hoists; disabling the pass models the target.
    # Found + validated in EXPERIMENTS.md §Perf B4.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step, in_shardings, out_shardings).lower(*abstract)
.compile()`` must succeed on the single-pod 16x16 mesh and the 2x16x16
multi-pod mesh for every assigned architecture x input shape.  The compiled
artifact's ``memory_analysis()`` proves per-device fit; ``cost_analysis()``
plus an HLO collective parse feed EXPERIMENTS.md §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results are cached as JSON under --out (default experiments/dryrun); cells
with an existing result are skipped unless --force.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.registry import ARCH_IDS, SHAPES, all_cells, get_config
from repro.launch.cells import build_cell, lower_cell
from repro.launch.hlo_analysis import model_flops_per_step, roofline
from repro.launch.hlo_cost import analyze_module
from repro.launch.mesh import make_production_mesh

MESHES = {"single": False, "multi": True}


def cell_id(arch: str, shape: str, mesh_name: str) -> str:
    return f"{arch}__{shape}__{mesh_name}"


def _cost_dict(compiled) -> dict:
    c = compiled.cost_analysis()
    if isinstance(c, list):      # older jax: list with one dict
        c = c[0] if c else {}
    return dict(c)


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str,
             force: bool = False, extra: dict = None,
             tag: str = "") -> dict:
    """Lower + compile one cell; returns (and persists) the result record."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    cid = cell_id(arch, shape_name, mesh_name) + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, cid + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    os.makedirs(out_dir, exist_ok=True)
    mesh = make_production_mesh(multi_pod=MESHES[mesh_name])
    chips = mesh.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": chips, "tag": tag, "ok": False}
    t0 = time.time()
    try:
        plan = build_cell(arch, shape, mesh, extra=extra)
        lowered = lower_cell(plan, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled)
        # structural cost model: multiplies scan bodies by trip counts
        # (cost_analysis() counts each while body exactly once)
        hc = analyze_module(compiled.as_text())
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind == "train" else
                                       (shape.seq_len if shape.kind == "prefill"
                                        else 1))
        mf = model_flops_per_step(cfg.param_count(),
                                  cfg.active_param_count(), tokens,
                                  shape.kind)
        rep = roofline({"flops": hc.flops, "bytes accessed": hc.hbm_bytes},
                       hc.coll_bytes, chips, model_flops=mf)
        rec.update(
            ok=True, t_lower_s=round(t_lower, 1),
            t_compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=int(mem.argument_size_in_bytes),
                output_bytes=int(mem.output_size_in_bytes),
                temp_bytes=int(mem.temp_size_in_bytes),
                peak_bytes=int(mem.argument_size_in_bytes
                               + mem.temp_size_in_bytes),
                code_bytes=int(mem.generated_code_size_in_bytes)),
            cost_analysis_raw={k: cost[k] for k in ("flops", "bytes accessed")
                               if k in cost},
            cost=hc.to_dict(),
            collectives={k: int(v) for k, v in hc.coll.items() if v},
            roofline=rep.to_dict(),
            params=int(cfg.param_count()),
            active_params=int(cfg.active_param_count()),
        )
    except Exception as e:                                  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _fmt(rec: dict) -> str:
    if not rec["ok"]:
        return (f"FAIL  {rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:6s} "
                f"{rec.get('error', '?')[:90]}")
    r = rec["roofline"]
    m = rec["memory"]
    return (f"ok    {rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:6s} "
            f"peak={m['peak_bytes'] / 1e9:7.2f}GB "
            f"C={r['compute_s'] * 1e3:9.2f}ms "
            f"M={r['memory_s'] * 1e3:9.2f}ms "
            f"K={r['collective_s'] * 1e3:9.2f}ms "
            f"bound={r['bound']:10s} "
            f"frac={r['roofline_frac']:.3f} "
            f"[{rec['wall_s']:.0f}s]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="every applicable (arch x shape) cell")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="variant tag (hillclimb)")
    ap.add_argument("--extra", default="",
                    help="JSON overrides, e.g. "
                         "'{\"microbatch_rows\": 2, \"loss_chunk\": 512}'; "
                         "\"pqkv\": {...} builds a PQ-compressed decode cell")
    ap.add_argument("--verbose-memory", action="store_true",
                    help="print the raw memory/cost analysis per cell")
    args = ap.parse_args()

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    cells = []
    if args.all:
        for arch, shape, ok, why in all_cells():
            if not ok:
                print(f"skip  {arch:24s} {shape.name:12s} ({why})")
                continue
            cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    extra = json.loads(args.extra) if args.extra else None
    if extra and "pqkv" in extra:
        from repro.serve.pqkv import PQKVConfig
        extra["pqkv"] = PQKVConfig(**extra["pqkv"])

    n_fail = 0
    for mesh_name in meshes:
        for arch, shape_name in cells:
            rec = run_cell(arch, shape_name, mesh_name, args.out,
                           force=args.force, extra=extra, tag=args.tag)
            print(_fmt(rec), flush=True)
            if args.verbose_memory and rec["ok"]:
                print(json.dumps({k: rec[k] for k in
                                  ("memory", "cost", "collectives")},
                                 indent=1))
            n_fail += 0 if rec["ok"] else 1
    print(f"\ndone: {len(cells) * len(meshes) - n_fail} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
