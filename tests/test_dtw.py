"""Wavefront DTW vs the O(L^2) numpy oracle + metric properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.dtw import dtw_pair, dtw_batch, dtw_cdist, dtw_full_table


def _rand(n, l, seed):
    return np.random.default_rng(seed).standard_normal((n, l)).astype(np.float32)


@pytest.mark.parametrize("L", [2, 3, 8, 17, 32, 64])
@pytest.mark.parametrize("window", [None, 1, 3, 10])
def test_matches_oracle(dtw_ref, L, window):
    if window is not None and window >= L:
        pytest.skip("window >= L is equivalent to None")
    a, b = _rand(2, L, seed=L * 7 + (window or 0))
    got = float(dtw_pair(jnp.asarray(a), jnp.asarray(b), window))
    want = dtw_ref(a, b, window)
    assert got == pytest.approx(want, rel=1e-5)


def test_identity_is_zero():
    a = _rand(1, 50, 3)[0]
    assert float(dtw_pair(a, a)) == pytest.approx(0.0, abs=1e-6)


def test_symmetry():
    a, b = _rand(2, 40, 11)
    assert float(dtw_pair(a, b)) == pytest.approx(float(dtw_pair(b, a)), rel=1e-6)


def test_band_monotonicity():
    """Widening the band can only lower (or keep) the DTW cost."""
    a, b = _rand(2, 48, 5)
    costs = [float(dtw_pair(a, b, w)) for w in (1, 2, 4, 8, 16, None)]
    for narrow, wide in zip(costs, costs[1:]):
        assert wide <= narrow + 1e-5


def test_dtw_le_euclidean():
    """Unconstrained DTW is <= lock-step (diagonal path) squared cost."""
    a, b = _rand(2, 64, 9)
    assert float(dtw_pair(a, b)) <= float(np.sum((a - b) ** 2)) + 1e-4


def test_batch_and_cdist_agree():
    A = _rand(6, 32, 1)
    B = _rand(4, 32, 2)
    full = np.asarray(dtw_cdist(A, B, window=4, block=8))
    for i in range(6):
        for j in range(4):
            assert full[i, j] == pytest.approx(
                float(dtw_pair(A[i], B[j], 4)), rel=1e-5)
    zipped = np.asarray(dtw_batch(A[:4], B, window=4))
    assert np.allclose(zipped, full[np.arange(4), np.arange(4)], rtol=1e-5)


def test_full_table_layout(dtw_ref):
    """table[i+j, i] must equal the DP cell dtw[i, j]."""
    a, b = _rand(2, 12, 21)
    table = np.asarray(dtw_full_table(a, b))
    for i in range(12):
        for j in range(12):
            want = dtw_ref(a[: i + 1], b[: j + 1])
            assert table[i + j, i] == pytest.approx(want, rel=1e-4), (i, j)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 24), st.integers(0, 10_000))
def test_property_nonneg_and_oracle(L, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal(L).astype(np.float32)
    b = rng.standard_normal(L).astype(np.float32)
    got = float(dtw_pair(a, b))
    assert got >= 0.0
    # oracle check on small sizes
    n, m = len(a), len(b)
    D = np.full((n + 1, m + 1), np.inf)
    D[0, 0] = 0.0
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            c = (a[i - 1] - b[j - 1]) ** 2
            D[i, j] = c + min(D[i - 1, j - 1], D[i, j - 1], D[i - 1, j])
    assert got == pytest.approx(float(D[n, m]), rel=1e-4)
