"""End-to-end training driver: a reduced assigned architecture trained for a
few hundred steps with checkpoint/restart through the production launcher.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Exercises: deterministic data stream, grad-accum microbatching, AdamW +
cosine schedule, async checkpointing, and a simulated preemption + restart
half-way (the loss curve must continue seamlessly).
"""

import argparse
import json
import os
import shutil
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="internlm2-1.8b")
    args = ap.parse_args()

    ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_train_lm_ck")
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    metrics = os.path.join(ckpt_dir, "metrics.jsonl")
    os.makedirs(ckpt_dir, exist_ok=True)

    half = args.steps // 2
    common = ["--arch", args.arch, "--reduced", "--batch", "8",
              "--seq", "128", "--microbatches", "2",
              "--ckpt-dir", ckpt_dir, "--ckpt-every", "25",
              "--metrics-out", metrics]

    print(f"=== phase 1: steps 0..{half} (then 'preempted') ===")
    train_main(common + ["--steps", str(half)])

    print(f"\n=== phase 2: restart from checkpoint, steps {half}.."
          f"{args.steps} ===")
    train_main(common + ["--steps", str(args.steps)])

    with open(metrics) as f:
        rows = [json.loads(l) for l in f]
    first, last = rows[0]["loss"], rows[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {len(rows)} logged steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
