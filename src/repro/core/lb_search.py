"""Batched LB-cascade filter-and-refine top-k search.

The paper's cascading lower bounds (§3.2) — and the database-scale pruning
of "Exact Indexing for Massive Time Series Databases under Time Warping
Distance" — expressed as a fully device-resident two-phase computation
with static shapes:

  Phase 1 (bound): evaluate ``max(LB_Kim, reversed LB_Keogh)`` for every
  (query, candidate) pair at once — cheap vectorized VPU math, no DTW.
  A matching *upper* bound seeds the thresholds: squared Euclidean
  distance dominates squared (banded) DTW pointwise — the identity path
  is always inside the band — so the k-th smallest ED per query (one MXU
  matmul) upper-bounds the k-th smallest DTW, and the very first refine
  wave already discards most pairs instead of burning a full budget on
  establishing thresholds.

  Phase 2 (refine): a ``lax.while_loop`` threshold-tightening pass.  Each
  iteration gathers a static *global* batch of the lowest-bound
  unprocessed (query, candidate) pairs — ``lax.top_k`` over the flattened
  bound matrix, so straggler queries soak up exactly as many refine slots
  as they still need — and sends the zipped pairs through
  :func:`repro.core.dispatch.lb_refine`.  The fused kernel re-checks each
  pair's bound against the query's *current* k-th best verified distance
  (tightened since the candidates were ranked) and runs the banded-DTW
  wavefront only for tiles with survivors.  The loop exits when every
  query's smallest unprocessed bound is at or above its k-th best verified
  distance, which certifies the verified top-k as exact.

Exactness: a candidate is discarded unrefined only when its lower bound is
>= the threshold in force, and the threshold is always a *verified* exact
distance — so every true top-k member is refined before the loop can exit.

Measures: the whole two-phase machinery is only *sound* for measures whose
capability flags say so (``has_keogh_lb`` for phase 1/2 pruning,
``euclid_is_upper_bound`` for the threshold seed).  For any other
registered measure — wdtw, erp, msm — :func:`filtered_topk` transparently
falls back to the exact dense path: one ``dispatch.elastic_cdist`` launch
plus a top-k, identical results, no unsound prune.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import measures
from .dispatch import effective_window, elastic_cdist, lb_refine
from .dtw import euclidean_sq
from .lb import keogh_envelope, lb_keogh, lb_kim
from .measures import MeasureArg

__all__ = ["filtered_topk"]


def _dense_topk(Q: jnp.ndarray, X: jnp.ndarray, window: Optional[int],
                k: int, valid: Optional[jnp.ndarray],
                q_valid: Optional[jnp.ndarray],
                spec, with_stats: bool):
    """Exact dense fallback: one all-pairs launch + top-k (the sound path
    for measures without a Keogh cascade / Euclidean upper bound)."""
    d = elastic_cdist(Q, X, window, measure=spec)
    n_q = (jnp.int32(Q.shape[0]) if q_valid is None
           else jnp.sum(q_valid).astype(jnp.int32))
    if valid is not None:
        d = jnp.where(valid[None, :], d, jnp.inf)
        n_ref = n_q * jnp.sum(valid).astype(jnp.int32)
    else:
        n_ref = n_q * jnp.int32(X.shape[0])
    if q_valid is not None:
        d = jnp.where(q_valid[:, None], d, jnp.inf)
    neg, idx = jax.lax.top_k(-d, k)
    idx = jnp.where(jnp.isfinite(neg), idx, -1).astype(jnp.int32)
    if with_stats:
        # no cascade ran: every valid pair was evaluated exactly, in what
        # amounts to a single wave
        stats = {"n_bounded": n_ref, "n_refined": n_ref,
                 "n_waves": jnp.int32(1),
                 "refined_per_wave": n_ref[None]}
        return -neg, idx, stats
    return -neg, idx, n_ref


@functools.partial(jax.jit,
                   static_argnames=("window", "k", "budget", "max_iters",
                                    "measure", "with_stats", "band",
                                    "corridor_factor", "corridor_radius"))
def filtered_topk(Q: jnp.ndarray, X: jnp.ndarray, window: Optional[int],
                  k: int, budget: Optional[int] = None,
                  valid: Optional[jnp.ndarray] = None,
                  max_iters: Optional[int] = None,
                  measure: MeasureArg = None,
                  q_valid: Optional[jnp.ndarray] = None,
                  with_stats: bool = False,
                  band: str = "static",
                  corridor_factor: int = 8,
                  corridor_radius: int = 2
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Exact banded elastic top-k of ``Q (Nq, L)`` against ``X (N, L)``.

    ``valid`` is an optional ``(N,)`` mask (False rows are never returned).
    ``q_valid`` is an optional ``(Nq,)`` *query* mask for callers whose
    batch carries padding rows (e.g. the sharded planner's padded query
    blocks): masked queries get all-``inf`` / ``-1`` results, never claim
    refine-wave slots, and are excluded from ``n_refined`` — pad rows
    neither burn wavefront sweeps nor pollute pruning statistics.
    Returns ``(d (Nq, k), idx (Nq, k) int32, n_refined)``:
    distances ascending per query with ``inf`` / ``-1`` filling slots
    beyond the number of valid candidates, and ``n_refined`` the total
    count of exact elastic evaluations (for pruning statistics).  Requires
    ``1 <= k <= N``.  Measures without the pruning capabilities take the
    exact dense fallback (same results; ``n_refined`` counts every valid
    pair).

    ``with_stats=True`` (static) swaps the third return for the pruning
    telemetry the observability layer exports: a dict of device scalars
    ``n_bounded`` (valid pairs the cascade bounded), ``n_refined`` (pairs
    that reached the exact wavefront), ``n_waves`` (refine launches) and
    ``refined_per_wave`` (per-wave refine counts, zero-padded to the
    static wave cap).  The flag is static so the default path compiles
    exactly the pre-telemetry graph — obs-off callers pay nothing.

    ``band="adaptive"`` (static) runs every refine wave inside per-pair
    alignment corridors (``dispatch.lb_refine(band="adaptive")``).  The
    phase-1 bounds stay valid lower bounds of the static-band distance
    and the loop terminates identically, but refined distances are the
    corridor-restricted cost (>= static), so the returned top-k is the
    documented *approximate* contract — it is excluded from the
    certified-exact cascade guarantee above.  Static band only for
    measures without pruning capability (the dense fallback is exact).
    ``corridor_factor`` / ``corridor_radius`` (static) set the coarse
    projection grid and fine-cell safety margin of the per-wave corridor
    build; a coarser factor makes the build pass cheaper on long series
    at the cost of a wider projected corridor.
    """
    if band not in ("static", "adaptive"):
        raise ValueError(f"unknown band mode {band!r}; "
                         "expected 'static' or 'adaptive'")
    Q = jnp.asarray(Q, jnp.float32)
    X = jnp.asarray(X, jnp.float32)
    Nq, L = Q.shape
    N = X.shape[0]
    if not 1 <= k <= N:
        raise ValueError(f"k={k} out of range: must satisfy 1 <= k <= {N}")
    spec = measures.resolve(measure)
    if not spec.can_prune:
        return _dense_topk(Q, X, window, k, valid, q_valid, spec,
                           with_stats)
    # Per-wave budget: thresholds tighten after every wave, so small waves
    # (a few pairs per query) converge in a handful of launches and waste
    # the least refine work; the cap below bounds the worst (pruning-free)
    # case to the equivalent of one exhaustive sweep.
    per_q = max(k, 4) if budget is None else max(k, int(budget))
    R = min(Nq * N, Nq * per_q)             # global refine batch per launch
    iters_cap = (-(-(Nq * N) // R) + 1 if max_iters is None
                 else int(max_iters))

    # Envelopes around the queries ("reversed" role: one envelope, N bounds
    # each), on the library-wide window=None contract (see dispatch
    # docstring) so an unbanded search still gets a valid full-width
    # envelope.
    w_env = effective_window(L, window)
    up, lo = keogh_envelope(Q, w_env)

    lbs = jnp.maximum(lb_kim(Q[:, None, :], X[None, :, :]),
                      lb_keogh(X[None, :, :], up[:, None, :],
                               lo[:, None, :]))              # (Nq, N)
    d_ub = euclidean_sq(Q, X)                                # >= squared DTW
    if valid is not None:
        lbs = jnp.where(valid[None, :], lbs, jnp.inf)
        d_ub = jnp.where(valid[None, :], d_ub, jnp.inf)
    if q_valid is not None:
        # Masked (padding) queries: every bound and seed goes to +inf, so
        # the wave-selection key is +inf (never chosen except as already-
        # discarded filler), cond() sees inf < inf == False, and the
        # `fresh` re-check below keeps any filler pick out of n_refined.
        lbs = jnp.where(q_valid[:, None], lbs, jnp.inf)
        d_ub = jnp.where(q_valid[:, None], d_ub, jnp.inf)
    # strict upper margin: exact ties (e.g. a query that IS a database row)
    # must still refine, so the seed sits just above the k-th smallest ED
    seed = -jax.lax.top_k(-d_ub, k)[0][:, -1] * 1.0001 + 1e-6

    def threshold(d_exact):
        kth = -jax.lax.top_k(-d_exact, k)[0][:, -1]          # (Nq,)
        return jnp.minimum(kth, seed)

    # the per-query threshold rides in the loop state (recomputed once at
    # the end of each wave) so cond/body don't re-run the (Nq, N) top_k
    def cond(state):
        it, lb_rem, _, thresh, _ = state[:5]
        active = jnp.min(lb_rem, axis=1) < thresh
        return (it < iters_cap) & jnp.any(active)

    def body(state):
        it, lb_rem, d_exact, thresh, n_ref = state[:5]
        # Global work-conserving selection: the R smallest *still-useful*
        # bounds across the whole (query, candidate) matrix.  A bound at
        # or above its query's threshold keys to +inf — it can never beat
        # the final top-k (thresholds only tighten), so if it is picked as
        # filler it is simply discarded unrefined.
        key = jnp.where(lb_rem < thresh[:, None], lb_rem, jnp.inf)
        _, flat = jax.lax.top_k(-key.reshape(-1), R)
        q_idx = flat // N
        c_idx = flat % N
        # the kernel recomputes bounds from the raw series, so deleted
        # rows, masked queries and pairs a previous iteration already
        # handled (picked again only as filler once finite keys run out)
        # get a -inf threshold: the cascade can never beat it, the
        # cond-guarded tile skips their wavefront sweeps entirely
        fresh = jnp.isfinite(lb_rem[q_idx, c_idx])
        if valid is not None:
            fresh = fresh & valid[c_idx]
        th = jnp.where(fresh, thresh[q_idx], -jnp.inf)
        d, refined = lb_refine(Q[q_idx], X[c_idx], up[q_idx], lo[q_idx],
                               th, window, measure=spec, band=band,
                               corridor_factor=corridor_factor,
                               corridor_radius=corridor_radius)
        refined = refined & fresh
        d_exact = d_exact.at[q_idx, c_idx].min(
            jnp.where(refined, d, jnp.inf))
        lb_rem = lb_rem.at[q_idx, c_idx].set(jnp.inf)
        wave = jnp.sum(refined).astype(jnp.int32)
        out = (it + 1, lb_rem, d_exact, threshold(d_exact),
               n_ref + wave)
        if with_stats:
            # per-wave refine counts for the obs export (static length:
            # the wave cap; unused slots stay zero)
            out = out + (state[5].at[it].set(wave),)
        return out

    state = (jnp.int32(0), lbs, jnp.full((Nq, N), jnp.inf), seed,
             jnp.zeros((), jnp.int32))
    if with_stats:
        state = state + (jnp.zeros((iters_cap,), jnp.int32),)
    state = jax.lax.while_loop(cond, body, state)
    it, _, d_exact, _, n_ref = state[:5]

    neg, idx = jax.lax.top_k(-d_exact, k)
    idx = jnp.where(jnp.isfinite(neg), idx, -1).astype(jnp.int32)
    if with_stats:
        if q_valid is None:
            n_q = jnp.int32(Nq)
        else:
            n_q = jnp.sum(q_valid).astype(jnp.int32)
        n_cand = (jnp.int32(N) if valid is None
                  else jnp.sum(valid).astype(jnp.int32))
        stats = {"n_bounded": n_q * n_cand, "n_refined": n_ref,
                 "n_waves": it, "refined_per_wave": state[5]}
        return -neg, idx, stats
    return -neg, idx, n_ref
