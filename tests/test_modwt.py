"""MODWT pre-alignment: scale coefficients, segmentation, snapping, interp."""

import numpy as np
import pytest

from repro.core.modwt import (modwt_scale, segment_points, snap_splits,
                              extract_segments, prealign, fixed_segments)


def test_scale_level1_is_pairwise_mean():
    x = np.arange(8, dtype=np.float32)
    v = np.asarray(modwt_scale(x, 1))
    want = 0.5 * (x + np.roll(x, 1))
    assert np.allclose(v, want)


def test_scale_level_j_is_dyadic_mean():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(64).astype(np.float32)
    for j in (1, 2, 3):
        v = np.asarray(modwt_scale(x, j))
        width = 2 ** j
        want = np.array([np.mean([x[(i - s) % 64] for s in range(width)])
                         for i in range(64)])
        assert np.allclose(v, want, atol=1e-5), j


def test_constant_series_has_no_segment_points():
    x = np.ones(32, np.float32)
    pts = np.asarray(segment_points(x, 2))
    assert not pts.any()


def test_segment_points_on_square_wave():
    t = np.arange(64)
    x = np.where((t // 16) % 2 == 0, 1.0, -1.0).astype(np.float32)
    pts = np.asarray(segment_points(x, 3))
    assert pts.any()  # transitions must be detected


def test_snap_splits_uses_rightmost_point_in_tail():
    L, n_sub, tail = 32, 4, 4
    pts = np.zeros(L, bool)
    pts[6] = True   # inside [8-4, 8] -> split 8 moves to 6
    pts[5] = True   # 6 is right-most, wins
    pts[20] = True  # inside [24-4, 24] -> split 24 moves to 20; split 16 stays
    bounds = np.asarray(snap_splits(pts, n_sub, tail))
    assert bounds.tolist() == [0, 6, 16, 20, 32]


def test_snap_splits_batched_shape():
    pts = np.zeros((5, 64), bool)
    b = np.asarray(snap_splits(pts, 4, 3))
    assert b.shape == (5, 5)
    assert (b[:, 0] == 0).all() and (b[:, -1] == 64).all()


def test_extract_segments_identity_resample():
    x = np.arange(16, dtype=np.float32)
    bounds = np.array([0, 8, 16], np.int32)
    segs = np.asarray(extract_segments(x, bounds, 8))
    assert np.allclose(segs[0], x[:8], atol=1e-5)
    assert np.allclose(segs[1], x[8:], atol=1e-5)


def test_extract_segments_linear_interp():
    x = np.arange(16, dtype=np.float32)
    bounds = np.array([0, 4, 16], np.int32)
    segs = np.asarray(extract_segments(x, bounds, 7))
    # first segment covers x[0..3], resampled to 7 points: linspace(0,3,7)
    assert np.allclose(segs[0], np.linspace(0, 3, 7), atol=1e-5)


def test_prealign_shapes_and_finiteness():
    rng = np.random.default_rng(3)
    X = rng.standard_normal((6, 120)).astype(np.float32)
    out = np.asarray(prealign(X, n_sub=4, level=3, tail=5))
    assert out.shape == (6, 4, 120 // 4 + 5)
    assert np.isfinite(out).all()


def test_fixed_segments_roundtrip():
    X = np.arange(24, dtype=np.float32).reshape(2, 12)
    segs = np.asarray(fixed_segments(X, 3))
    assert segs.shape == (2, 3, 4)
    assert np.allclose(segs.reshape(2, 12), X)
