"""Finding records, inline suppressions, and the committed baseline.

A finding is identified by a *fingerprint* — ``sha1(rule|relpath|scope|
normalized source line)`` — deliberately independent of the line
*number*, so unrelated edits above a baselined finding don't churn the
baseline file.

Suppression syntax (checked by :func:`scan_suppressions`)::

    x = float(dist)  # repro: ignore[RS101] CLI timing, off hot path

The comment may sit on the finding's own line or the line directly
above.  A suppression without a reason still suppresses but raises the
meta-finding ``RS001``; a suppression that matches nothing raises
``RS002`` — both keep the ignore inventory honest.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Dict, List, Optional

__all__ = [
    "Finding", "Suppression", "scan_suppressions", "apply_suppressions",
    "load_baseline", "apply_baseline", "write_baseline",
]

_IGNORE_RE = re.compile(
    r"#\s*repro:\s*ignore\[(RS\d{3}(?:\s*,\s*RS\d{3})*)\]\s*(.*)$")


@dataclasses.dataclass
class Finding:
    rule: str                 # "RS101"
    path: Path                # absolute file path
    lineno: int
    scope: str                # qualname of the enclosing function/module
    message: str
    source_line: str = ""     # stripped source text of the finding line

    def rel(self, root: Path) -> str:
        try:
            return str(self.path.relative_to(root))
        except ValueError:
            return str(self.path)

    def fingerprint(self, root: Path) -> str:
        norm = re.sub(r"\s+", " ", self.source_line.strip())
        key = f"{self.rule}|{self.rel(root)}|{self.scope}|{norm}"
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def render(self, root: Path) -> str:
        return (f"{self.rel(root)}:{self.lineno}: {self.rule} "
                f"[{self.scope}] {self.message}")


@dataclasses.dataclass
class Suppression:
    path: Path
    lineno: int               # line the comment sits on
    rules: List[str]
    reason: str
    used: bool = False


def scan_suppressions(path: Path, source: str) -> List[Suppression]:
    out = []
    for i, line in enumerate(source.splitlines(), start=1):
        m = _IGNORE_RE.search(line)
        if m:
            rules = [r.strip() for r in m.group(1).split(",")]
            out.append(Suppression(path=path, lineno=i, rules=rules,
                                   reason=m.group(2).strip()))
    return out


def apply_suppressions(
    findings: List[Finding],
    suppressions: Dict[Path, List[Suppression]],
) -> List[Finding]:
    """Drop findings matched by an inline ignore; append RS001/RS002
    meta-findings for missing reasons and unused suppressions."""
    kept: List[Finding] = []
    for f in findings:
        hit: Optional[Suppression] = None
        for s in suppressions.get(f.path, ()):
            if f.rule in s.rules and s.lineno in (f.lineno, f.lineno - 1):
                hit = s
                break
        if hit is None:
            kept.append(f)
        else:
            hit.used = True
    for path, subs in suppressions.items():
        for s in subs:
            if s.used and not s.reason:
                kept.append(Finding(
                    rule="RS001", path=path, lineno=s.lineno,
                    scope="<suppression>",
                    message="suppression has no justification text — add "
                            "a reason after the bracket",
                    source_line=f"ignore[{','.join(s.rules)}]"))
            if not s.used:
                kept.append(Finding(
                    rule="RS002", path=path, lineno=s.lineno,
                    scope="<suppression>",
                    message=f"unused suppression for "
                            f"{','.join(s.rules)} — matched no finding; "
                            f"delete it",
                    source_line=f"ignore[{','.join(s.rules)}]"))
    return kept


# -- baseline ----------------------------------------------------------------

def load_baseline(path: Path) -> Dict[str, dict]:
    if not path.exists():
        return {}
    data = json.loads(path.read_text(encoding="utf-8"))
    return data.get("findings", {})


def apply_baseline(
    findings: List[Finding],
    baseline: Dict[str, dict],
    root: Path,
) -> tuple[List[Finding], List[str], List[str]]:
    """Split findings into (new, baselined fingerprints seen, stale
    fingerprints).  Stale = baselined but no longer present: the debt was
    paid, so the entry must be deleted (the file only ever shrinks)."""
    new: List[Finding] = []
    seen: List[str] = []
    for f in findings:
        fp = f.fingerprint(root)
        if fp in baseline:
            seen.append(fp)
        else:
            new.append(f)
    stale = [fp for fp in baseline if fp not in seen]
    return new, seen, stale


def write_baseline(path: Path, findings: List[Finding], root: Path) -> None:
    entries = {}
    for f in sorted(findings, key=lambda f: (f.rel(root), f.lineno)):
        entries[f.fingerprint(root)] = {
            "rule": f.rule,
            "path": f.rel(root),
            "scope": f.scope,
            "message": f.message,
            # every baselined entry must carry a human justification;
            # check_static errors on empty ones (the CI growth gate)
            "justification": "",
        }
    payload = {
        "_comment": "Frozen pre-existing findings. Entries may only be "
                    "removed (debt paid) — new findings must be fixed or "
                    "inline-suppressed, and every entry needs a "
                    "non-empty justification.",
        "findings": entries,
    }
    path.write_text(json.dumps(payload, indent=2) + "\n", encoding="utf-8")
