"""Console rendering of a metrics snapshot + stage-coverage checks.

Shared by ``scripts/obs_report.py`` (render a ``REPRO_OBS_DUMP`` file)
and ``examples/index_service.py`` (exit summary of a live registry) so
the two never drift: one table layout, one definition of "this stage
recorded samples".

Everything here consumes the *snapshot dict* from
:func:`repro.obs.export.snapshot` — not live metric objects — so a JSON
file read back from disk renders identically to an in-process registry.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["stage_rows", "counter_value", "missing_stages", "render",
           "check_stages"]


def _label_str(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def stage_rows(snap: dict) -> List[Tuple[str, int, float, float, float]]:
    """``(stage, count, p50, p95, p99)`` per ``stage_seconds`` histogram,
    sorted by stage name.  Stages with no samples report zero counts."""
    rows = []
    for h in snap.get("histograms", []):
        if h["name"] != "stage_seconds":
            continue
        stage = h["labels"].get("stage", "?")
        rows.append((stage, h["count"], h.get("p50") or 0.0,
                     h.get("p95") or 0.0, h.get("p99") or 0.0))
    return sorted(rows)


def counter_value(snap: dict, name: str, **labels: str) -> float:
    """Sum of every counter ``name`` whose labels are a superset of
    ``labels`` (so ``counter_value(s, "dispatch_total", op="adc_cdist")``
    aggregates over backends/measures)."""
    total = 0.0
    for c in snap.get("counters", []):
        if c["name"] != name:
            continue
        if all(c["labels"].get(k) == v for k, v in labels.items()):
            total += c["value"]
    return total


def missing_stages(snap: dict, required: Sequence[str]) -> List[str]:
    """Required stage names that recorded zero ``stage_seconds`` samples."""
    seen = {stage for stage, count, *_ in stage_rows(snap) if count > 0}
    return [s for s in required if s not in seen]


def _fmt_ms(seconds: float) -> str:
    ms = seconds * 1e3
    return f"{ms:10.2f}" if ms < 1e5 else f"{ms:10.3g}"


def render(snap: dict, title: str = "observability report") -> str:
    """Multi-section console report of a snapshot dict."""
    lines = [f"== {title} ==",
             f"obs_enabled: {snap.get('obs_enabled')}"]

    rows = stage_rows(snap)
    if rows:
        lines.append("")
        lines.append(f"{'stage':<28} {'count':>7} {'p50 ms':>10} "
                     f"{'p95 ms':>10} {'p99 ms':>10}")
        for stage, count, p50, p95, p99 in rows:
            lines.append(f"{stage:<28} {count:>7} {_fmt_ms(p50)} "
                         f"{_fmt_ms(p95)} {_fmt_ms(p99)}")

    prune = [h for h in snap.get("histograms", [])
             if h["name"] == "lb_pruning_rate" and h["count"]]
    bounded = counter_value(snap, "lb_candidates_bounded_total")
    refined = counter_value(snap, "lb_candidates_refined_total")
    if prune or bounded:
        lines.append("")
        lines.append("-- LB cascade --")
        if bounded:
            lines.append(
                f"candidates bounded/refined/pruned: {int(bounded)} / "
                f"{int(refined)} / {int(bounded - refined)} "
                f"(pruning rate {1.0 - refined / bounded:.1%})")
        for h in prune:
            lines.append(
                f"per-search pruning rate{_label_str(h['labels'])}: "
                f"p50 {h.get('p50') or 0.0:.1%}, over {h['count']} searches")

    routes = [c for c in snap.get("counters", [])
              if c["name"] == "dispatch_total"]
    if routes:
        lines.append("")
        lines.append("-- dispatch routing (trace-time counts) --")
        for c in sorted(routes, key=lambda c: sorted(c["labels"].items())):
            lab = dict(c["labels"])
            lab.pop("kind", None)
            op = lab.pop("op", "?")
            backend = lab.pop("backend", "?")
            extra = _label_str(lab)
            lines.append(f"{op + extra:<36} -> {backend:<18} "
                         f"{int(c['value']):>6}")

    other = [c for c in snap.get("counters", [])
             if c["name"] != "dispatch_total"]
    if other:
        lines.append("")
        lines.append("-- counters --")
        for c in sorted(other,
                        key=lambda c: (c["name"], sorted(c["labels"].items()))):
            lines.append(f"{c['name'] + _label_str(c['labels']):<44} "
                         f"{int(c['value']):>10}")

    gauges = snap.get("gauges", [])
    if gauges:
        lines.append("")
        lines.append("-- gauges --")
        for g in sorted(gauges,
                        key=lambda g: (g["name"], sorted(g["labels"].items()))):
            lines.append(f"{g['name'] + _label_str(g['labels']):<44} "
                         f"{g['value']:>10.4g}")
    return "\n".join(lines)


def check_stages(snap: dict, required: Sequence[str]
                 ) -> Tuple[bool, Optional[str]]:
    """``(ok, message)`` for a stage-coverage gate: every name in
    ``required`` must have recorded at least one span.  Fails (with a
    pointed message) when the snapshot was taken with obs disabled —
    a coverage assertion against a disabled registry is vacuous."""
    if not snap.get("obs_enabled"):
        return False, ("snapshot was captured with obs disabled "
                       "(obs_enabled: false) — set REPRO_OBS=1 in the "
                       "producing process to assert stage coverage")
    missing = missing_stages(snap, required)
    if missing:
        return False, ("stages recorded zero samples: "
                       + ", ".join(missing))
    return True, None
