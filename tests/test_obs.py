"""Observability layer: registry math vs numpy oracles, span
nesting/re-entrancy, the zero-overhead disabled contract (bit-identical
search results + no device syncs), export round-trips, and the dispatch
routing mirror."""

import json
import math

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import dispatch
from repro.core.dispatch import use_backend
from repro.core.lb_search import filtered_topk
from repro.core.pq import PQConfig
from repro.data.timeseries import random_walks
from repro.index import IndexConfig, StreamingIndex, search_sharded
from repro.obs.registry import Registry


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test here starts with obs disabled (the contract under test),
    then the session's state is restored — a CI run with REPRO_OBS=1 must
    keep recording spans in the test files that sort after this one."""
    prev = obs.enabled()
    obs.disable()
    yield
    if prev:
        obs.enable()
    else:
        obs.disable()


# ---------------------------------------------------------------------------
# registry: buckets + percentiles vs numpy
# ---------------------------------------------------------------------------

class TestHistogram:
    def test_percentile_matches_numpy(self):
        rng = np.random.default_rng(0)
        for n in (1, 2, 3, 10, 101):
            samples = rng.exponential(0.01, size=n).tolist()
            for p in (0.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0):
                assert obs.percentile(samples, p) == pytest.approx(
                    float(np.percentile(samples, p)), rel=1e-12)

    def test_histogram_percentiles_match_numpy(self):
        reg = Registry()
        h = reg.histogram("t")
        samples = np.random.default_rng(1).exponential(0.01, 257)
        for v in samples:
            h.record(v)
        for p in (50.0, 95.0, 99.0):
            assert h.percentile(p) == pytest.approx(
                float(np.percentile(samples, p)), rel=1e-12)

    def test_bucket_boundaries_le_semantics(self):
        reg = Registry()
        h = reg.histogram("t", buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.0, 1.5, 2.0, 4.0, 5.0):  # bounds land IN bucket
            h.record(v)
        assert h.bucket_counts == [2, 2, 1, 1]     # [-1] = +Inf overflow
        assert h.cumulative_counts() == [2, 4, 5, 6]
        assert h.cumulative_counts()[-1] == h.count

    def test_bucket_counts_match_numpy_histogram(self):
        bounds = obs.exp_buckets(1e-4, 2.0, 20)
        reg = Registry()
        h = reg.histogram("t", buckets=bounds)
        samples = np.random.default_rng(2).exponential(0.01, 500)
        for v in samples:
            h.record(v)
        # np.histogram uses right-open bins; with no sample exactly on a
        # bound (probability zero for continuous draws) both agree
        expect, _ = np.histogram(samples,
                                 bins=[0.0] + list(bounds) + [np.inf])
        assert h.bucket_counts == expect.tolist()

    def test_exp_buckets(self):
        assert obs.exp_buckets(1.0, 2.0, 4) == (1.0, 2.0, 4.0, 8.0)
        with pytest.raises(ValueError):
            obs.exp_buckets(0.0, 2.0, 4)
        with pytest.raises(ValueError):
            obs.exp_buckets(1.0, 1.0, 4)

    def test_sum_min_max(self):
        reg = Registry()
        h = reg.histogram("t", buckets=(1.0,))
        for v in (0.25, 0.5, 3.0):
            h.record(v)
        assert h.count == 3
        assert h.sum == pytest.approx(3.75)
        assert (h.min, h.max) == (0.25, 3.0)
        assert not h.samples_capped

    def test_conflicting_buckets_rejected(self):
        reg = Registry()
        reg.histogram("t", buckets=(1.0, 2.0))
        with pytest.raises(ValueError, match="already exists"):
            reg.histogram("t", buckets=(1.0, 3.0))


class TestRegistry:
    def test_get_or_create_by_name_and_labels(self):
        reg = Registry()
        a = reg.counter("c", op="x")
        assert reg.counter("c", op="x") is a
        assert reg.counter("c", op="y") is not a

    def test_reset_keeps_persistent(self):
        reg = Registry()
        reg.counter("scratch").inc()
        keep = reg.counter("keep", persistent=True)
        keep.inc(5)
        reg.reset()
        assert reg.counter("keep", persistent=True) is keep
        assert keep.value == 5
        assert reg.counter("scratch").value == 0    # recreated fresh
        reg.reset(include_persistent=True)
        assert reg.counter("keep", persistent=True) is not keep


# ---------------------------------------------------------------------------
# spans: nesting, re-entrancy, exception safety, disabled no-ops
# ---------------------------------------------------------------------------

class TestSpans:
    def test_nesting_and_stack(self):
        with obs.override(True):
            assert obs.current_spans() == ()
            with obs.span("outer"):
                with obs.span("inner"):
                    assert obs.current_spans() == ("outer", "inner")
                assert obs.current_spans() == ("outer",)
            assert obs.current_spans() == ()
        h = obs.histogram("stage_seconds", persistent=True, stage="inner")
        assert h.count >= 1

    def test_reentrancy_same_name(self):
        with obs.override(True):
            before = obs.histogram("stage_seconds", persistent=True,
                                   stage="re").count
            with obs.span("re"):
                with obs.span("re"):
                    assert obs.current_spans() == ("re", "re")
            after = obs.histogram("stage_seconds", persistent=True,
                                  stage="re").count
        assert after == before + 2

    def test_exception_still_records_and_pops(self):
        with obs.override(True):
            before = obs.histogram("stage_seconds", persistent=True,
                                   stage="boom").count
            with pytest.raises(RuntimeError):
                with obs.span("boom"):
                    raise RuntimeError("x")
            assert obs.current_spans() == ()
            after = obs.histogram("stage_seconds", persistent=True,
                                  stage="boom").count
        assert after == before + 1

    def test_disabled_span_is_shared_noop(self):
        s1, s2 = obs.span("a"), obs.span("b")
        assert s1 is s2                       # one immutable null object
        with s1 as sp:
            assert obs.current_spans() == ()
            assert sp.fence(123) == 123

    def test_fence_blocks_only_when_enabled(self, monkeypatch):
        calls = []
        monkeypatch.setattr("repro.obs.spans._block",
                            lambda x: calls.append(1) or x)
        x = jax.numpy.ones(3)
        assert obs.fence(x) is x
        assert calls == []                    # disabled: never blocks
        with obs.override(True):
            obs.fence(x)
        assert calls == [1]

    def test_fence_skips_tracers(self, monkeypatch):
        calls = []
        monkeypatch.setattr("repro.obs.spans._block",
                            lambda x: calls.append(1) or x)
        with obs.override(True):
            @jax.jit
            def f(x):
                return obs.fence(x * 2)       # tracer: must not block
            f(jax.numpy.ones(3))
        assert calls == []

    def test_env_var_parsing(self):
        assert obs.ENV_VAR == "REPRO_OBS"
        assert not obs.enabled()              # suite runs with obs off


# ---------------------------------------------------------------------------
# zero-overhead contract: search results bit-identical with obs on/off
# ---------------------------------------------------------------------------

def _small_index():
    cfg = IndexConfig(
        pq=PQConfig(n_sub=4, codebook_size=8, kmeans_iters=2, dba_iters=1),
        n_lists=4, hot_capacity=16, coarse_iters=2)
    idx = StreamingIndex.bootstrap(
        jax.random.PRNGKey(0), random_walks(48, 32, seed=0), cfg)
    idx.insert(random_walks(40, 32, seed=1))   # sealed segments + hot rows
    idx.delete([1, 2])
    return idx


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
class TestBitIdentical:
    def test_search_identical_on_off(self, backend):
        with use_backend(backend):
            idx = _small_index()
            Q = random_walks(5, 32, seed=9)
            d_off, i_off = idx.search(Q, n_probe=2, topk=3)
            with obs.override(True):
                d_on, i_on = idx.search(Q, n_probe=2, topk=3)
        assert np.asarray(d_off).tobytes() == np.asarray(d_on).tobytes()
        assert np.array_equal(np.asarray(i_off), np.asarray(i_on))

    def test_search_sharded_identical_on_off(self, backend):
        with use_backend(backend):
            idx = _small_index()
            Q = random_walks(5, 32, seed=9)
            d_off, i_off = search_sharded(idx, Q, n_probe=2, topk=3)
            with obs.override(True):
                d_on, i_on = search_sharded(idx, Q, n_probe=2, topk=3)
        assert np.asarray(d_off).tobytes() == np.asarray(d_on).tobytes()
        assert np.array_equal(np.asarray(i_off), np.asarray(i_on))

    def test_disabled_search_never_fences(self, backend, monkeypatch):
        def forbid(x):
            raise AssertionError("obs-off search must not block_until_ready"
                                 " through the obs layer")
        monkeypatch.setattr("repro.obs.spans._block", forbid)
        with use_backend(backend):
            idx = _small_index()
            idx.search(random_walks(3, 32, seed=9), n_probe=2, topk=3)


class TestFilteredTopkStats:
    def test_with_stats_same_results(self):
        Q = random_walks(4, 32, seed=0)
        X = random_walks(30, 32, seed=1)
        d0, i0, n_ref = filtered_topk(Q, X, 4, 3)
        d1, i1, st = filtered_topk(Q, X, 4, 3, with_stats=True)
        assert np.array_equal(np.asarray(d0), np.asarray(d1))
        assert np.array_equal(np.asarray(i0), np.asarray(i1))
        assert int(st["n_refined"]) == int(n_ref)
        assert int(st["n_bounded"]) == 4 * 30
        assert int(st["n_refined"]) <= int(st["n_bounded"])
        waves = np.asarray(st["refined_per_wave"])
        assert int(waves.sum()) == int(st["n_refined"])
        assert int(st["n_waves"]) <= waves.shape[0]

    def test_dense_fallback_stats(self):
        # msm has no Keogh cascade: dense path refines every valid pair
        Q = random_walks(3, 32, seed=0)
        X = random_walks(10, 32, seed=1)
        d, i, st = filtered_topk(Q, X, 4, 2, measure="msm",
                                 with_stats=True)
        assert int(st["n_refined"]) == 30
        assert int(st["n_waves"]) == 1


# ---------------------------------------------------------------------------
# instrumentation lands in the registry
# ---------------------------------------------------------------------------

class TestInstrumentation:
    def test_search_spans_and_pruning_counters(self):
        idx = _small_index()
        with obs.override(True):
            before = obs.counter("index_searches_total",
                                 persistent=True).value
            idx.search(random_walks(3, 32, seed=9), n_probe=2, topk=3)
        assert obs.counter("index_searches_total",
                           persistent=True).value == before + 1
        for stage in ("index.search", "index.search.coarse",
                      "index.search.lut", "index.search.fine",
                      "index.search.hot", "index.search.merge"):
            h = obs.histogram("stage_seconds", persistent=True, stage=stage)
            assert h.count >= 1, stage
        bounded = obs.counter("lb_candidates_bounded_total",
                              persistent=True).value
        refined = obs.counter("lb_candidates_refined_total",
                              persistent=True).value
        pruned = obs.counter("lb_candidates_pruned_total",
                             persistent=True).value
        assert bounded == refined + pruned
        assert bounded > 0

    def test_lifecycle_gauges(self):
        idx = _small_index()
        with obs.override(True):
            idx.insert(random_walks(3, 32, seed=5))
        stats = idx.stats()
        assert obs.gauge("hot_fill", persistent=True).value \
            == stats["hot_fill"]
        assert obs.gauge("n_segments", persistent=True).value \
            == stats["n_segments"]
        occ = obs.gauge("hot_occupancy", persistent=True).value
        assert 0.0 <= occ <= 1.0

    def test_dispatch_mirror_counts_routes(self):
        before = obs.counter("dispatch_total", persistent=True,
                             op="elastic_cdist", backend="jax",
                             kind="trace", measure="dtw").value
        with use_backend("jax"):
            dispatch.elastic_cdist(random_walks(2, 16, seed=0),
                                   random_walks(3, 16, seed=1), 2)
        after = obs.counter("dispatch_total", persistent=True,
                            op="elastic_cdist", backend="jax",
                            kind="trace", measure="dtw").value
        assert after == before + 1


# ---------------------------------------------------------------------------
# export: JSON snapshot round-trip + Prometheus text format
# ---------------------------------------------------------------------------

class TestExport:
    def _populated(self):
        reg = Registry()
        reg.counter("hits", op="scan").inc(3)
        reg.gauge("fill").set(0.5)
        h = reg.histogram("lat", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 2.0):
            h.record(v)
        return reg

    def test_snapshot_round_trip(self):
        reg = self._populated()
        snap = json.loads(obs.to_json(reg))
        assert snap["counters"] == [
            {"name": "hits", "labels": {"op": "scan"}, "value": 3}]
        assert snap["gauges"][0]["value"] == 0.5
        (h,) = snap["histograms"]
        assert h["count"] == 3
        assert h["buckets"] == {"le": [0.1, 1.0], "counts": [1, 1, 1]}
        assert h["p50"] == pytest.approx(0.5)
        assert h["min"] == 0.05 and h["max"] == 2.0

    def test_snapshot_include_samples(self):
        snap = obs.snapshot(self._populated(), include_samples=True)
        assert snap["histograms"][0]["samples"] == [0.05, 0.5, 2.0]

    def test_prometheus_format(self):
        text = obs.to_prometheus(self._populated())
        assert '# TYPE repro_hits counter' in text
        assert 'repro_hits{op="scan"} 3' in text
        assert '# TYPE repro_lat histogram' in text
        assert 'repro_lat_bucket{le="0.1"} 1' in text
        assert 'repro_lat_bucket{le="1.0"} 2' in text
        assert 'repro_lat_bucket{le="+Inf"} 3' in text
        assert 'repro_lat_count 3' in text
        assert text.endswith("\n")

    def test_write_snapshot_and_report(self, tmp_path):
        path = str(tmp_path / "sub" / "snap.json")
        obs.write_snapshot(path, self._populated())
        with open(path) as f:
            snap = json.load(f)
        text = obs.render(snap, title="t")
        assert "obs_enabled" in text
        assert "hits" in text

    def test_check_stages(self):
        reg = Registry()
        reg.histogram("stage_seconds", stage="a").record(0.1)
        snap = obs.snapshot(reg)
        snap["obs_enabled"] = True
        ok, msg = obs.check_stages(snap, ["a"])
        assert ok and msg is None
        ok, msg = obs.check_stages(snap, ["a", "ghost"])
        assert not ok and "ghost" in msg
        snap["obs_enabled"] = False
        ok, msg = obs.check_stages(snap, ["a"])
        assert not ok and "disabled" in msg

    def test_prometheus_inf_gauge(self):
        reg = Registry()
        reg.gauge("g").set(math.inf)
        assert "repro_g +Inf" in obs.to_prometheus(reg)
