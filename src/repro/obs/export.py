"""Exporters: JSON snapshot + Prometheus text format + atexit dump.

The JSON snapshot is the machine-readable interchange format of the obs
layer: ``scripts/obs_report.py`` renders it as a console report,
``scripts/check_routing.py`` asserts routing/stage coverage on it, CI
uploads it as an artifact, and ``tests/conftest.py`` writes one at
session end.  ``to_prometheus`` emits the standard text exposition format
(cumulative ``le`` buckets, ``_sum``/``_count`` series) so a scrape
endpoint can serve the same registry verbatim.

Set ``REPRO_OBS_DUMP=<path>`` to write a snapshot at interpreter exit —
how the benchmark smoke job and the index-service CI leg capture their
metrics without any in-process plumbing.
"""

from __future__ import annotations

import atexit
import json
import math
import os
from typing import Optional

from . import spans
from .registry import REGISTRY, Registry

__all__ = ["snapshot", "to_json", "to_prometheus", "write_snapshot",
           "DUMP_ENV_VAR", "PROM_PREFIX"]

DUMP_ENV_VAR = "REPRO_OBS_DUMP"

# Prometheus metric-name prefix for every exported series.
PROM_PREFIX = "repro_"

# histogram percentiles included in every snapshot / report
PERCENTILES = (50.0, 95.0, 99.0)


def snapshot(registry: Optional[Registry] = None,
             include_samples: bool = False) -> dict:
    """JSON-able dict of the whole registry.

    Histogram entries carry exact ``p50/p95/p99`` (from the recorded
    samples) next to the exponential buckets; ``include_samples`` embeds
    the raw samples too (round-trip tests, offline re-analysis).
    """
    reg = registry if registry is not None else REGISTRY
    out = {
        "obs_enabled": spans.enabled(),
        "counters": [
            {"name": c.name, "labels": c.labels, "value": c.value}
            for c in reg.counters()],
        "gauges": [
            {"name": g.name, "labels": g.labels, "value": g.value}
            for g in reg.gauges()],
        "histograms": [],
    }
    for h in reg.histograms():
        entry = {
            "name": h.name, "labels": h.labels, "count": h.count,
            "sum": h.sum,
            "min": h.min if h.count else None,
            "max": h.max if h.count else None,
            "samples_capped": h.samples_capped,
            "buckets": {"le": list(h.bounds),
                        "counts": list(h.bucket_counts)},
        }
        for p in PERCENTILES:
            entry[f"p{p:g}"] = h.percentile(p) if h.samples else None
        if include_samples:
            entry["samples"] = list(h.samples)
        out["histograms"].append(entry)
    return out


def to_json(registry: Optional[Registry] = None,
            include_samples: bool = False) -> str:
    return json.dumps(snapshot(registry, include_samples=include_samples),
                      indent=1, sort_keys=True)


def _prom_labels(labels: dict, extra: Optional[dict] = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    body = ",".join(
        f'{k}="{_escape(str(v))}"' for k, v in sorted(items.items()))
    return "{" + body + "}"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    return repr(float(v))


def to_prometheus(registry: Optional[Registry] = None) -> str:
    """Prometheus text exposition format for the whole registry."""
    reg = registry if registry is not None else REGISTRY
    lines = []
    typed = set()

    def header(name: str, kind: str):
        if name not in typed:
            typed.add(name)
            lines.append(f"# TYPE {PROM_PREFIX}{name} {kind}")

    for c in reg.counters():
        header(c.name, "counter")
        lines.append(
            f"{PROM_PREFIX}{c.name}{_prom_labels(c.labels)} {c.value}")
    for g in reg.gauges():
        header(g.name, "gauge")
        lines.append(
            f"{PROM_PREFIX}{g.name}{_prom_labels(g.labels)} {_fmt(g.value)}")
    for h in reg.histograms():
        header(h.name, "histogram")
        cum = h.cumulative_counts()
        for bound, count in zip(list(h.bounds) + [math.inf], cum):
            le = _prom_labels(h.labels, {"le": _fmt(bound)})
            lines.append(f"{PROM_PREFIX}{h.name}_bucket{le} {count}")
        lines.append(
            f"{PROM_PREFIX}{h.name}_sum{_prom_labels(h.labels)} "
            f"{_fmt(h.sum)}")
        lines.append(
            f"{PROM_PREFIX}{h.name}_count{_prom_labels(h.labels)} {h.count}")
    return "\n".join(lines) + "\n"


def write_snapshot(path: str, registry: Optional[Registry] = None,
                   include_samples: bool = False) -> str:
    """Write the JSON snapshot to ``path`` (parent dirs created)."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        f.write(to_json(registry, include_samples=include_samples))
    return path


def _dump_at_exit() -> None:
    path = os.environ.get(DUMP_ENV_VAR)
    if path:
        write_snapshot(path)


atexit.register(_dump_at_exit)
