"""Pluggable elastic-measure registry — the measure-generic engine core.

The paper positions PQ as "a highly efficient replacement for elastic
measures" in general, and the DMKD comparison of Wang et al. (PAPERS.md)
shows no single elastic measure dominates across datasets.  The only
measure-specific part of the whole engine is the DP *recurrence step*:
every cell ``(i, j)`` of the alignment table is

    T[i, j] = min(T[i-1, j-1] + diag_cost,
                  T[i-1, j  ] + vert_cost,     # consume a_i
                  T[i,   j-1] + horiz_cost)    # consume b_j

with measure-specific per-move costs (DTW charges the same matching cost
for all three moves; ERP charges gap penalties off-diagonal; MSM charges
split/merge costs).  This module owns those per-move costs plus the
capability flags the rest of the engine keys pruning decisions on; the
shared anti-diagonal sweeps (:func:`repro.core.dtw._diag_sweep` and
:func:`repro.kernels.dtw_band.kernel.wavefront_compressed`) consume a
:class:`MeasureSpec` as a *static* parameter, so one implementation serves
every measure on every backend.

Shipped measures
----------------

``dtw``
    Classic DTW over *squared* pointwise costs (the repo-wide convention).
    Has a sound reversed-LB_Keogh/LB_Kim cascade and squared Euclidean is
    a pointwise upper bound, so every pruning path applies.

``wdtw`` (``g``: logistic steepness, default 0.05)
    Jeong et al.'s weighted DTW: the matching cost is scaled by a logistic
    weight of the phase difference ``|i - j|``.  The weight here is
    normalized to ``2 / (1 + exp(-g * (|i-j| - L/2)))`` so the flat limit
    ``g = 0`` recovers plain DTW *exactly* (weight 1 everywhere).  Weights
    below 1 near the diagonal make LB_Keogh unsound, so no cascade; with
    ``g >= 0`` the identity-path weight is <= 1, so squared Euclidean
    still upper-bounds the distance.

``erp`` (``g``: gap reference value, default 0.0)
    Chen & Ng's Edit distance with Real Penalty over absolute differences
    (the norm that makes it a metric): off-diagonal moves pay the distance
    of the consumed point to the constant gap value ``g``, and the virtual
    first row/column are prefix sums of gap costs.

``msm`` (``c``: split/merge cost, default 0.5)
    Stefan et al.'s Move-Split-Merge over absolute differences (a metric):
    diagonal moves pay the move cost ``|a_i - b_j|``; vertical/horizontal
    moves pay the split/merge cost ``c`` when the consumed point lies
    between its two anchors and ``c`` plus the distance to the nearest
    anchor otherwise.

Registering a new measure is one :func:`register_measure` call: provide
the per-move cost step (and a gap-cost fn for ERP-style virtual borders)
and the spec flows through kernels, dispatch, PQ, search and the
streaming index without touching any of them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple, Union

import jax.numpy as jnp

__all__ = [
    "MeasureSpec", "MeasureArg", "register_measure", "get_measure",
    "resolve", "available", "registry_rows", "move_costs", "gap_costs",
    "DTW",
]

# What every measure-taking API accepts: None (-> dtw), a registry name
# with optional parameter suffix ("erp:g=1.5"), or a spec.
MeasureArg = Union[None, str, "MeasureSpec"]


@dataclasses.dataclass(frozen=True)
class MeasureSpec:
    """Pure-data description of one elastic measure.

    Hashable and comparable by value, so it can ride through ``jax.jit``
    as a static argument; the behavior (cost step / gap fn) lives in the
    registry keyed by ``name``, which keeps specs trivially serializable
    for snapshot manifests.

    ``params`` is a sorted tuple of ``(name, float)`` pairs — the static
    hyper-parameters of the measure (ERP's gap value, MSM's split cost,
    WDTW's steepness).

    Capability flags gate which engine paths are *sound*:

    ``has_keogh_lb``
        ``max(LB_Kim, LB_Keogh)`` lower-bounds the measure, so the LB
        cascade (filtered_topk, lb_refine, the encode filter, the IVF
        ``lb_budget`` pre-filter) may prune with it.
    ``euclid_is_upper_bound``
        pointwise squared Euclidean distance upper-bounds the measure, so
        it may seed filter-and-refine thresholds.

    Measures lacking either flag take the exact dense path instead of an
    unsound prune.
    """
    name: str
    params: Tuple[Tuple[str, float], ...] = ()
    has_keogh_lb: bool = False
    euclid_is_upper_bound: bool = False
    uses_gap_border: bool = False   # ERP-style virtual first row/column
    uses_neighbors: bool = False    # step needs a_{i-1} / b_{j-1} (MSM)
    uses_position: bool = False     # step needs |i - j| (WDTW)

    def param(self, key: str) -> float:
        return dict(self.params)[key]

    @property
    def label(self) -> str:
        """Human/bench label: ``dtw``, ``erp(g=1)``, ..."""
        if not self.params:
            return self.name
        inner = ",".join(f"{k}={v:g}" for k, v in self.params)
        return f"{self.name}({inner})"

    def to_manifest(self) -> dict:
        """JSON-safe record for snapshot manifests."""
        return {"name": self.name, "params": dict(self.params)}

    @property
    def can_prune(self) -> bool:
        """True when the LB-cascade filter-and-refine path is sound."""
        return self.has_keogh_lb and self.euclid_is_upper_bound


# name -> (spec factory defaults, step fn, gap fn)
_REGISTRY: Dict[str, dict] = {}


def register_measure(name: str, *, step: Callable,
                     gap: Optional[Callable] = None,
                     defaults: Tuple[Tuple[str, float], ...] = (),
                     has_keogh_lb: bool = False,
                     euclid_is_upper_bound: bool = False,
                     uses_neighbors: bool = False,
                     uses_position: bool = False,
                     doc: str = "") -> None:
    """Register an elastic measure.

    ``step(params, x, y, xp, yp, dd, length)`` returns the three per-move
    costs ``(diag, vert, horiz)`` for cells with values ``x = a_i``,
    ``y = b_j``, predecessors ``xp = a_{i-1}`` / ``yp = b_{j-1}``
    (sentinel-filled where a move never uses them), integer phase offset
    ``dd = |i - j|`` and static series length ``length``.  Returning the
    *same array object* three times marks the shared-cost fast path (DTW
    family).

    ``gap(params, values)`` — per-element virtual-border gap cost (ERP
    style); its presence implies the virtual first row/column are prefix
    sums of it rather than +inf.
    """
    _REGISTRY[name] = dict(step=step, gap=gap, defaults=tuple(defaults),
                           has_keogh_lb=has_keogh_lb,
                           euclid_is_upper_bound=euclid_is_upper_bound,
                           uses_neighbors=uses_neighbors,
                           uses_position=uses_position, doc=doc)


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_measure(name: str, **params: float) -> MeasureSpec:
    """Spec for a registered measure, with keyword parameter overrides."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown elastic measure {name!r}; registered: {available()}")
    entry = _REGISTRY[name]
    merged = dict(entry["defaults"])
    for k, v in params.items():
        if k not in merged:
            raise ValueError(
                f"measure {name!r} has no parameter {k!r}; expected "
                f"{tuple(merged)}")
        merged[k] = float(v)
    return MeasureSpec(
        name=name, params=tuple(sorted(merged.items())),
        has_keogh_lb=entry["has_keogh_lb"],
        euclid_is_upper_bound=entry["euclid_is_upper_bound"],
        uses_gap_border=entry["gap"] is not None,
        uses_neighbors=entry["uses_neighbors"],
        uses_position=entry["uses_position"])


def resolve(measure: Union[None, str, MeasureSpec]) -> MeasureSpec:
    """Normalize a measure argument to a spec.

    ``None`` -> the DTW default; a string -> registry lookup, with an
    optional parameter suffix ``"erp:g=1.5"`` / ``"msm:c=0.1"``; a spec
    passes through (re-validated against the registry).
    """
    if measure is None:
        return DTW
    if isinstance(measure, MeasureSpec):
        if measure.name not in _REGISTRY:
            raise ValueError(
                f"measure {measure.name!r} is not registered; call "
                f"register_measure first (registered: {available()})")
        return measure
    name, _, rest = str(measure).partition(":")
    params = {}
    if rest:
        for item in rest.split(","):
            k, _, v = item.partition("=")
            params[k.strip()] = float(v)
    return get_measure(name.strip(), **params)


def registry_rows() -> Tuple[dict, ...]:
    """One summary row per registered measure (docs / benchmarks)."""
    rows = []
    for name in available():
        spec = get_measure(name)
        rows.append(dict(
            name=name, params=dict(spec.params),
            has_keogh_lb=spec.has_keogh_lb,
            euclid_is_upper_bound=spec.euclid_is_upper_bound,
            prune_path=("LB cascade" if spec.can_prune
                        else "exact dense fallback"),
            doc=_REGISTRY[name]["doc"]))
    return tuple(rows)


# ---------------------------------------------------------------------------
# Recurrence-step evaluation (called from inside the shared sweeps)
# ---------------------------------------------------------------------------

def move_costs(spec: MeasureSpec, x, y, xp, yp, dd, length: int):
    """Per-cell costs of the three DP moves -> ``(diag, vert, horiz)``.

    All array arguments broadcast together; ``xp``/``yp``/``dd`` may be
    ``None`` when the spec's flags say the step never reads them.
    """
    return _REGISTRY[spec.name]["step"](dict(spec.params), x, y, xp, yp,
                                        dd, length)


def gap_costs(spec: MeasureSpec, values):
    """Per-element gap cost for the virtual first row/column (ERP style).

    Only meaningful when ``spec.uses_gap_border``; the border values are
    inclusive prefix sums of this array.
    """
    gap = _REGISTRY[spec.name]["gap"]
    if gap is None:
        raise ValueError(f"measure {spec.name!r} has no gap border")
    return gap(dict(spec.params), values)


# ---------------------------------------------------------------------------
# Shipped measures
# ---------------------------------------------------------------------------

def _dtw_step(params, x, y, xp, yp, dd, length):
    c = (x - y) ** 2
    return c, c, c   # same object: shared-cost fast path


def _wdtw_step(params, x, y, xp, yp, dd, length):
    # Logistic phase weight, normalized so g = 0 is flat weight 1 (== DTW).
    g = params["g"]
    w = 2.0 / (1.0 + jnp.exp(-g * (dd.astype(jnp.float32)
                                   - 0.5 * float(length))))
    c = w * (x - y) ** 2
    return c, c, c


def _erp_step(params, x, y, xp, yp, dd, length):
    g = params["g"]
    return jnp.abs(x - y), jnp.abs(x - g), jnp.abs(y - g)


def _erp_gap(params, values):
    return jnp.abs(values - params["g"])


def _msm_move(new, prev, other, c):
    """MSM split/merge cost C(new | prev, other)."""
    inside = (((prev <= new) & (new <= other))
              | ((prev >= new) & (new >= other)))
    return jnp.where(inside, c,
                     c + jnp.minimum(jnp.abs(new - prev),
                                     jnp.abs(new - other)))


def _msm_step(params, x, y, xp, yp, dd, length):
    c = params["c"]
    return (jnp.abs(x - y),
            _msm_move(x, xp, y, c),    # consume a_i after a_{i-1}
            _msm_move(y, yp, x, c))    # consume b_j after b_{j-1}


register_measure(
    "dtw", step=_dtw_step,
    has_keogh_lb=True, euclid_is_upper_bound=True,
    doc="classic DTW, squared pointwise costs")
register_measure(
    "wdtw", step=_wdtw_step, defaults=(("g", 0.05),), uses_position=True,
    euclid_is_upper_bound=True,
    doc="logistic phase-weighted DTW (g=0 recovers dtw exactly; "
        "Euclidean upper bound assumes g >= 0)")
register_measure(
    "erp", step=_erp_step, gap=_erp_gap, defaults=(("g", 0.0),),
    doc="edit distance with real penalty (metric, absolute costs)")
register_measure(
    "msm", step=_msm_step, defaults=(("c", 0.5),), uses_neighbors=True,
    doc="move-split-merge (metric, absolute costs)")

DTW = get_measure("dtw")
