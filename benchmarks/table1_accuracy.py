"""Table 1 — PQDTW vs baseline distance measures: 1NN classification error,
hierarchical-clustering Rand index, and runtime speedups.

Datasets are class-structured synthetic surrogates for the UCR archive
(offline container; DESIGN.md §7): CBF, Trace-like, GunPoint-like.  Measures
mirror the paper: ED, DTW (full), cDTW5/cDTW10, SBD, SAX, PQ_ED, PQDTW
(symmetric + the §4.2 LB-refined symmetric for clustering).  For each
baseline we report the error/RI difference vs PQDTW and the speedup of the
PQDTW distance phase — the same two columns as the paper's Table 1.
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import (cdtw_cdist, ed_cdist, sax_mindist_cdist,
                                  sax_transform, sbd_cdist)
from repro.core.cluster import hierarchical_labels
from repro.core.dtw import dtw_cdist
from repro.core.metrics import error_rate, rand_index
from repro.core.pq import (PQConfig, cdist_sym, cdist_sym_refined, encode,
                           fit, segment)
from repro.data.timeseries import make_dataset

from .common import Bench


def _measure(fn) -> Tuple[np.ndarray, float]:
    t0 = time.perf_counter()
    d = np.asarray(jax.block_until_ready(fn()))
    return d, time.perf_counter() - t0


def run(quick: bool = True) -> Bench:
    b = Bench("table1_accuracy")
    n_per_class = 12 if quick else 40
    length = 96 if quick else 192
    datasets = ("cbf", "trace", "gunpoint")
    seeds = (0, 1) if quick else (0, 1, 2, 3, 4)

    agg: Dict[str, list] = {}
    for ds in datasets:
        for seed in seeds:
            Xtr, ytr = make_dataset(ds, n_per_class, length, seed=seed)
            Xte, yte = make_dataset(ds, n_per_class, length, seed=seed + 100)
            Xtr_j, Xte_j = jnp.asarray(Xtr), jnp.asarray(Xte)
            D = Xtr.shape[1]
            k_classes = len(np.unique(ytr))

            pq_cfg = PQConfig(n_sub=5, codebook_size=min(48, Xtr.shape[0]),
                              window_frac=0.1, use_prealign=True,
                              kmeans_iters=4, dba_iters=1)
            key = jax.random.PRNGKey(seed)
            t0 = time.perf_counter()
            cb = fit(key, Xtr_j, pq_cfg)
            tr_codes = encode(Xtr_j, cb, pq_cfg)
            jax.block_until_ready(tr_codes)
            pq_train_s = time.perf_counter() - t0

            # PQDTW symmetric distances (1NN + clustering)
            def pq_test():
                q = encode(Xte_j, cb, pq_cfg)
                return cdist_sym(q, tr_codes, cb.lut)
            d_pq, t_pq = _measure(pq_test)

            te_codes = encode(Xte_j, cb, pq_cfg)
            te_segs = segment(Xte_j, pq_cfg)
            d_pq_ref, _ = _measure(
                lambda: cdist_sym_refined(te_codes, te_segs, te_codes,
                                          te_segs, cb))

            w5 = max(1, int(0.05 * D))
            w10 = max(1, int(0.10 * D))
            sax_l = max(2, int(0.2 * length))

            def sax_fn():
                Sa = sax_transform(Xte, sax_l)
                Sb = sax_transform(Xtr, sax_l)
                return sax_mindist_cdist(Sa, Sb, length)

            pq_ed_cfg = PQConfig(n_sub=5, codebook_size=min(48, Xtr.shape[0]),
                                 metric="euclidean", use_prealign=False,
                                 kmeans_iters=6)
            cb_ed = fit(key, Xtr_j, pq_ed_cfg)
            tr_codes_ed = encode(Xtr_j, cb_ed, pq_ed_cfg)

            def pq_ed_fn():
                q = encode(Xte_j, cb_ed, pq_ed_cfg)
                return cdist_sym(q, tr_codes_ed, cb_ed.lut)

            baselines = {
                "ED": lambda: ed_cdist(Xte_j, Xtr_j),
                "DTW": lambda: dtw_cdist(Xte_j, Xtr_j, None),
                "cDTW5": lambda: cdtw_cdist(Xte_j, Xtr_j, w5),
                "cDTW10": lambda: cdtw_cdist(Xte_j, Xtr_j, w10),
                "SBD": lambda: sbd_cdist(Xte_j, Xtr_j),
                "SAX": sax_fn,
                "PQ_ED": pq_ed_fn,
            }

            err_pq = error_rate(yte, ytr[np.argmin(d_pq, axis=1)])
            lab_pq = hierarchical_labels(np.asarray(d_pq_ref), k_classes)
            ri_pq = rand_index(yte, lab_pq)

            for name, fn in baselines.items():
                d, t = _measure(fn)
                err = error_rate(yte, ytr[np.argmin(d, axis=1)])
                # clustering needs the test-test matrix
                if name == "SAX":
                    Sa = sax_transform(Xte, sax_l)
                    d_tt = sax_mindist_cdist(Sa, Sa, length)
                elif name == "PQ_ED":
                    q = encode(Xte_j, cb_ed, pq_ed_cfg)
                    d_tt = np.asarray(cdist_sym(q, q, cb_ed.lut))
                elif name == "DTW":
                    d_tt = np.asarray(dtw_cdist(Xte_j, Xte_j, None))
                elif name == "cDTW5":
                    d_tt = np.asarray(cdtw_cdist(Xte_j, Xte_j, w5))
                elif name == "cDTW10":
                    d_tt = np.asarray(cdtw_cdist(Xte_j, Xte_j, w10))
                elif name == "SBD":
                    d_tt = np.asarray(sbd_cdist(Xte_j, Xte_j))
                else:
                    d_tt = np.asarray(ed_cdist(Xte_j, Xte_j))
                ri = rand_index(yte, hierarchical_labels(d_tt, k_classes))
                agg.setdefault(name, []).append(
                    (err - err_pq, ri - ri_pq, t / max(t_pq, 1e-9),
                     err, ri))

            agg.setdefault("PQDTW", []).append(
                (0.0, 0.0, 1.0, err_pq, ri_pq))
            agg.setdefault("_pq_train_s", []).append(
                (pq_train_s, 0, 0, 0, 0))

    for name in ("PQDTW", "ED", "DTW", "cDTW5", "cDTW10", "SBD", "SAX",
                 "PQ_ED"):
        vals = np.array(agg[name])
        b.add(measure=name,
              mean_err_diff=float(np.mean(vals[:, 0])),
              std_err_diff=float(np.std(vals[:, 0])),
              mean_ri_diff=float(np.mean(vals[:, 1])),
              speedup_vs_pqdtw=float(np.mean(vals[:, 2])),
              mean_err=float(np.mean(vals[:, 3])),
              mean_ri=float(np.mean(vals[:, 4])))
    b.save()
    return b


if __name__ == "__main__":
    run(quick=False)
