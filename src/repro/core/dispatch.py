"""Unified execution-engine dispatch for every elastic-distance hot path.

Every elastic / ADC consumer in the library (PQ encoding, query LUTs, DBA
k-means assignment, IVF coarse search, exact NN search, symmetric code
distances, LB-filtered search) funnels through the entry points here
instead of calling a
specific implementation, so the Pallas kernels are the *default engine* on
TPU rather than a dead benchmark artifact:

    elastic_pairwise(A, B, window)   zipped pairs          -> (N,)
    elastic_cdist(A, B, window)      all pairs             -> (N, M)
    adc_cdist(codes_a, codes_b, lut) symmetric ADC         -> (Na, Nb)
    adc_lookup(codes, qlut)          asymmetric scan       -> (N,)
    prealign_encode(X, centroids)    fused MODWT prealign
                                     + elastic-1NN encode  -> (N, M) codes
    lb_refine(A, B, up, lo, thresh)  fused LB cascade +
                                     conditional DTW refine -> (N,), (N,)
    two_level_coarse(Q, top, coarse, child_idx, child_valid)
                                     hierarchical coarse
                                     rank + child fan-out  -> (Nq, n_lists)

Measures: the elastic entry points take a ``measure`` argument (name,
``"name:param=value"`` string, or :class:`repro.core.measures.MeasureSpec`;
``None`` = DTW) that is threaded as a *static* parameter down to the shared
wavefront recurrence — one implementation per op regardless of measure.
``lb_refine`` additionally validates that the measure supports the Keogh
cascade (only capability-gated callers should reach it).

Window contract (shared by knn / lb / lb_search / ivf / kernels):
``window=None`` means *unbanded*, which is exactly a Sakoe-Chiba band of
``L - 1`` — shifts beyond the series length are infeasible, so
:func:`effective_window` clamps every materialized window to
``[0, L - 1]``.  Use it whenever a concrete integer window is needed
(envelope construction, band geometry); never materialize ``L`` itself.

Backends (resolved once per call site at trace time):

    "pallas"           Pallas kernels; compiled on TPU, interpret elsewhere
    "pallas_interpret" Pallas kernels, interpret mode forced (CI / debug)
    "jax"              pure-JAX lax.scan wavefront + gather ADC (reference)
    "auto"             "pallas" on TPU, "jax" otherwise

Selection order: :func:`set_backend` override > ``$REPRO_ELASTIC_BACKEND`` >
``"auto"``.  The :data:`stats` counters record which route every op took;
they are incremented at *trace* time (a jitted caller that hits its cache
does not re-count), which is exactly what tests need to assert that a code
path really executes through the dispatch layer.  Measure-parameterized
ops are double-counted: once under the bare op name and once under
``"op[measure]"``, so the routing ledger shows per-measure coverage.
:data:`totals` is the
same ledger but process-lifetime — :func:`reset_stats` leaves it alone, so
a CI run can dump it at session end and fail the build if an op silently
fell back to the ``"jax"`` route (see ``scripts/check_routing.py``).

The kernel modules are imported lazily (first dispatch) so that they may
themselves import :mod:`repro.core` submodules — e.g. the measure registry
— without creating an import cycle through this module.
"""

from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..obs.registry import REGISTRY as _obs_registry
from . import measures
from .dtw import dtw_batch, dtw_cdist
from .measures import MeasureArg, MeasureSpec

__all__ = [
    "BACKENDS", "ENV_VAR", "get_backend", "set_backend", "use_backend",
    "elastic_pairwise", "elastic_cdist", "adc_cdist", "adc_lookup",
    "prealign_encode", "lb_refine", "two_level_coarse", "stats", "totals",
    "reset_stats", "effective_window",
]

ENV_VAR = "REPRO_ELASTIC_BACKEND"
BACKENDS = ("auto", "pallas", "pallas_interpret", "jax")

_override: Optional[str] = None

# (op, resolved backend) -> number of dispatches (trace-time, see module
# doc); measure-parameterized ops are also ledgered as "op[measure]"
stats: Dict[Tuple[str, str], int] = {}

# same ledger, but never cleared by reset_stats: the process-lifetime record
# a CI routing gate can assert on after the whole test session
totals: Dict[Tuple[str, str], int] = {}


def effective_window(length: int, window: Optional[int]) -> int:
    """The library-wide ``window=None`` contract (see module docstring):
    ``None`` -> unbanded -> ``length - 1``; everything clamped to
    ``[0, length - 1]``.

    >>> effective_window(128, None)
    127
    >>> effective_window(128, 12)
    12
    >>> effective_window(128, 500)
    127
    """
    w = length - 1 if window is None else int(window)
    return max(0, min(w, length - 1))


def _check(name: str) -> str:
    if name not in BACKENDS:
        raise ValueError(
            f"unknown elastic backend {name!r}; expected one of {BACKENDS}")
    return name


def get_backend() -> str:
    """Resolved backend name: ``"pallas"``, ``"pallas_interpret"`` or
    ``"jax"`` (``"auto"`` is resolved against the runtime platform).

    >>> from repro.core import dispatch
    >>> with dispatch.use_backend("jax"):
    ...     dispatch.get_backend()
    'jax'
    >>> dispatch.get_backend() in ("pallas", "pallas_interpret", "jax")
    True
    """
    name = _override if _override is not None else _check(
        os.environ.get(ENV_VAR, "auto"))
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jax"
    return name


def set_backend(name: Optional[str]) -> None:
    """Process-wide override (``None`` restores env/auto selection).

    Callers that were already traced keep their route — pair with
    ``jax.clear_caches()`` to force re-dispatch.

    >>> from repro.core import dispatch
    >>> dispatch.set_backend("pallas_interpret")
    >>> dispatch.get_backend()
    'pallas_interpret'
    >>> dispatch.set_backend(None)          # back to env/auto selection
    """
    global _override
    _override = _check(name) if name is not None else None


@contextmanager
def use_backend(name: str):
    """Scoped :func:`set_backend` (tests, benchmarks).

    >>> from repro.core import dispatch
    >>> prev = dispatch.get_backend()
    >>> with dispatch.use_backend("jax"):
    ...     dispatch.get_backend()
    'jax'
    >>> dispatch.get_backend() == prev      # restored on exit
    True
    """
    global _override
    prev = _override
    _override = _check(name)
    try:
        yield
    finally:
        _override = prev


def reset_stats() -> None:
    """Clear the per-test :data:`stats` ledger; the process-lifetime
    :data:`totals` ledger (the CI routing gate's input) is untouched.

    >>> import jax.numpy as jnp
    >>> from repro.core import dispatch
    >>> with dispatch.use_backend("jax"):
    ...     _ = dispatch.elastic_pairwise(jnp.zeros((1, 4)),
    ...                                   jnp.ones((1, 4)), window=1)
    >>> dispatch.stats[("elastic_pairwise", "jax")] >= 1
    True
    >>> dispatch.reset_stats()
    >>> ("elastic_pairwise", "jax") in dispatch.stats
    False
    >>> ("elastic_pairwise", "jax") in dispatch.totals
    True
    """
    stats.clear()


def _count(op: str, route: str,
           measure: Optional[MeasureSpec] = None) -> None:
    keys = [(op, route)]
    if measure is not None:
        keys.append((f"{op}[{measure.name}]", route))
    for key in keys:
        stats[key] = stats.get(key, 0) + 1
        totals[key] = totals.get(key, 0) + 1
    # Mirror into the observability registry (repro.obs): same trace-time
    # semantics as `totals` — the kind="trace" label keeps the distinction
    # from run-time span metrics explicit in every export — and persistent,
    # so obs.reset() cannot erase the routing ledger mid-session.  A plain
    # host-side counter bump: cheap enough to run whether obs is enabled
    # or not, which keeps the exported routing coverage complete even for
    # sessions that never turn spans on.
    labels = {"op": op, "backend": route, "kind": "trace"}
    if measure is not None:
        labels["measure"] = measure.name
    _obs_registry.counter("dispatch_total", persistent=True, **labels).inc()


def _interpret_flag(backend: str) -> Optional[bool]:
    # "pallas" defers to default_interpret() (compiled on TPU); forced True
    # under "pallas_interpret" so CI exercises the kernel bodies on CPU.
    return True if backend == "pallas_interpret" else None


def _adaptive_geometry(L: int, window: Optional[int], backend: str,
                       spec: MeasureSpec, width: Optional[int],
                       factor: int, radius: int):
    """Shared adaptive-band resolution: the tuned register width cap for
    this geometry (static, trace-time) plus the corridor clipper."""
    from ..kernels import tune
    from . import corridor as corr
    if width is None:
        lane = 128 if jax.default_backend() == "tpu" else 8
        width = tune.adaptive_width(L, window, lane, measure=spec.name,
                                    backend=backend, factor=factor,
                                    radius=radius)
    return corr, width


def elastic_pairwise(A: jnp.ndarray, B: jnp.ndarray,
                     window: Optional[int] = None, *,
                     block: Optional[int] = None,
                     measure: MeasureArg = None,
                     band: str = "static",
                     corridor: Optional[Tuple[jnp.ndarray,
                                              jnp.ndarray]] = None,
                     corridor_factor: int = 8, corridor_radius: int = 2,
                     width: Optional[int] = None) -> jnp.ndarray:
    """Elastic cost over zipped pairs: ``(N, L) x (N, L) -> (N,)``.

    ``band="adaptive"`` sweeps each pair's own corridor envelope (built
    here from a coarse PAA pass unless ``corridor=(lo, hi)`` is given —
    see :mod:`repro.core.corridor`).  The adaptive result is bit-identical
    to the static band whenever the corridor contains the static optimal
    path (checkable via ``corridor.certify_adaptive``) and a documented
    *approximate* upper bound otherwise; it is ledgered separately as
    ``elastic_pairwise_adaptive``.  ``block=None`` consults the
    :mod:`repro.kernels.tune` table for the launch block.

    >>> import jax.numpy as jnp
    >>> from repro.core import dispatch
    >>> with dispatch.use_backend("jax"):
    ...     d = dispatch.elastic_pairwise(jnp.zeros((2, 8)),
    ...                                   jnp.ones((2, 8)), window=2)
    >>> d.shape
    (2,)
    >>> [float(x) for x in d]           # 8 unit squared diffs per pair
    [8.0, 8.0]
    """
    from ..kernels.dtw_band.ops import dtw_band
    spec = measures.resolve(measure)
    backend = get_backend()
    if band == "static":
        _count("elastic_pairwise", backend, spec)
        if backend == "jax":
            return dtw_batch(A, B, window, spec)
        return dtw_band(A, B, window, block=block,
                        interpret=_interpret_flag(backend), measure=spec)
    if band != "adaptive":
        raise ValueError(f"unknown band mode {band!r}; "
                         "expected 'static' or 'adaptive'")
    _count("elastic_pairwise_adaptive", backend, spec)
    L = A.shape[-1]
    corr, width = _adaptive_geometry(L, window, backend, spec, width,
                                     corridor_factor, corridor_radius)
    if corridor is None:
        corridor = corr.build_corridor(A, B, window, factor=corridor_factor,
                                       radius=corridor_radius)
    lo, hi = corr.clip_to_width(*corridor, width)
    if backend == "jax":
        return corr.corridor_sweep(A, B, lo, hi, window=window, width=width,
                                   measure=spec)[:, 0]
    return dtw_band(A, B, window, block=block,
                    interpret=_interpret_flag(backend), measure=spec,
                    corridor=(lo, hi), width=width)


def elastic_cdist(A: jnp.ndarray, B: jnp.ndarray,
                  window: Optional[int] = None, *,
                  block: Optional[int] = None,
                  measure: MeasureArg = None) -> jnp.ndarray:
    """All-pairs elastic cost: ``(N, L) x (M, L) -> (N, M)``.

    >>> import jax.numpy as jnp
    >>> from repro.core import dispatch
    >>> with dispatch.use_backend("jax"):
    ...     D = dispatch.elastic_cdist(jnp.zeros((2, 8)),
    ...                                jnp.ones((3, 8)), window=2)
    >>> D.shape
    (2, 3)
    >>> float(D[0, 0])
    8.0
    """
    from ..kernels.dtw_band.ops import dtw_band_cdist
    spec = measures.resolve(measure)
    backend = get_backend()
    _count("elastic_cdist", backend, spec)
    if backend == "jax":
        return dtw_cdist(A, B, window, measure=spec)
    return dtw_band_cdist(A, B, window, block=block,
                          interpret=_interpret_flag(backend), measure=spec)


def adc_cdist(codes_a: jnp.ndarray, codes_b: jnp.ndarray,
              lut: jnp.ndarray, *,
              lut_dtype: str = "float32") -> jnp.ndarray:
    """Symmetric PQ distance matrix ``sqrt(sum_m LUT[m, a^m, b^m])``:
    one-hot MXU contractions on the Pallas route, plain gathers on "jax".
    Measure-generic by construction — the LUT already encodes whichever
    measure built it (paper §3.3).

    ``lut_dtype`` selects the resident-table precision: ``"float32"``
    (exact), or the quantized LUT path — ``"int8"`` (per-subspace affine,
    4x smaller VMEM table) / ``"bfloat16"`` (2x).  The quantized route is
    ledgered as ``adc_cdist_quant`` and matches f32 within the
    per-subspace quantization step (see
    :func:`repro.kernels.pq_adc.ops.quantize_lut`).

    >>> import jax.numpy as jnp
    >>> from repro.core import dispatch
    >>> codes = jnp.array([[0, 1], [1, 0]], jnp.int32)
    >>> lut = jnp.stack([1.0 - jnp.eye(2)] * 2)   # (M=2, K=2, K=2)
    >>> with dispatch.use_backend("jax"):
    ...     D = dispatch.adc_cdist(codes, codes, lut)
    >>> [round(float(x), 3) for x in D.ravel()]   # sqrt(0), sqrt(2), ...
    [0.0, 1.414, 1.414, 0.0]
    >>> with dispatch.use_backend("jax"):
    ...     Dq = dispatch.adc_cdist(codes, codes, lut, lut_dtype="int8")
    >>> [round(float(x), 2) for x in Dq.ravel()]
    [0.0, 1.41, 1.41, 0.0]
    """
    from ..kernels.pq_adc.ops import adc_sym_cdist as _adc_sym_pallas
    from ..kernels.pq_adc.ops import adc_sym_cdist_quant, quantize_lut
    from ..kernels.pq_adc.ref import adc_sym_cdist_quant_ref, adc_sym_cdist_ref
    backend = get_backend()
    if lut_dtype != "float32":
        _count("adc_cdist_quant", backend)
        q, scale, zero = quantize_lut(lut, lut_dtype)
        if backend == "jax":
            return adc_sym_cdist_quant_ref(codes_a, codes_b, q, scale, zero)
        return adc_sym_cdist_quant(codes_a, codes_b, q, scale, zero,
                                   interpret=_interpret_flag(backend))
    _count("adc_cdist", backend)
    if backend == "jax":
        return adc_sym_cdist_ref(codes_a, codes_b, lut)
    return _adc_sym_pallas(codes_a, codes_b, lut,
                           interpret=_interpret_flag(backend))


def adc_lookup(codes: jnp.ndarray, qlut: jnp.ndarray, *,
               lut_dtype: str = "float32") -> jnp.ndarray:
    """Asymmetric ADC scan: ``codes (N, M)``, ``qlut (M, K)`` -> ``(N,)``.

    Returns ``sqrt(sum_m qlut[m, codes[n, m]])`` per row.  ``lut_dtype``
    mirrors :func:`adc_cdist`: ``"int8"`` / ``"bfloat16"`` run the
    quantized query-table kernel (ledgered ``adc_lookup_quant``).

    >>> import jax.numpy as jnp
    >>> from repro.core import dispatch
    >>> codes = jnp.array([[0, 0], [1, 1]], jnp.int32)
    >>> qlut = jnp.array([[0.0, 2.0], [0.0, 2.0]])
    >>> with dispatch.use_backend("jax"):
    ...     d = dispatch.adc_lookup(codes, qlut)
    >>> [float(x) for x in d]
    [0.0, 2.0]
    """
    from ..kernels.pq_adc.ops import adc_lookup as _adc_lookup_pallas
    from ..kernels.pq_adc.ops import adc_lookup_quant, quantize_lut
    from ..kernels.pq_adc.ref import adc_lookup_quant_ref, adc_lookup_ref
    backend = get_backend()
    if lut_dtype != "float32":
        _count("adc_lookup_quant", backend)
        q, scale, zero = quantize_lut(qlut, lut_dtype)
        if backend == "jax":
            return adc_lookup_quant_ref(codes, q, scale, zero)
        return adc_lookup_quant(codes, q, scale, zero,
                                interpret=_interpret_flag(backend))
    _count("adc_lookup", backend)
    if backend == "jax":
        return adc_lookup_ref(codes, qlut)
    return _adc_lookup_pallas(codes, qlut,
                              interpret=_interpret_flag(backend))


def prealign_encode(X: jnp.ndarray, centroids: jnp.ndarray, *, level: int,
                    tail: int, window: Optional[int] = None,
                    block: Optional[int] = None,
                    measure: MeasureArg = None) -> jnp.ndarray:
    """Fused MODWT prealign + exact elastic-1NN encode: ``X (N, D)`` against
    ``centroids (M, K, S)`` -> codes ``(N, M)`` int32.

    The Pallas route performs the whole §3.5 pipeline (scale recursion,
    change-point snap, segment re-interpolation, nearest-centroid scan) in
    one pass per batch tile — the ``(N, M, S)`` segment tensor never
    reaches HBM.  The ``"jax"`` route is the two-step reference.  The
    1-NN scan runs under ``measure`` (DTW by default).

    >>> import jax.numpy as jnp
    >>> from repro.core import dispatch
    >>> cents = jnp.stack([jnp.zeros((2, 5)), jnp.ones((2, 5))], axis=1)
    >>> cents.shape                                # (M=2, K=2, S=5)
    (2, 2, 5)
    >>> with dispatch.use_backend("jax"):
    ...     codes = dispatch.prealign_encode(jnp.zeros((2, 8)), cents,
    ...                                      level=1, tail=1, window=2)
    >>> codes.shape, str(codes.dtype)
    ((2, 2), 'int32')
    >>> bool((codes == 0).all())                   # zeros snap to centroid 0
    True
    """
    from ..kernels.prealign_encode.ops import (
        prealign_encode as _prealign_encode_pallas)
    from ..kernels.prealign_encode.ref import prealign_encode_ref
    spec = measures.resolve(measure)
    backend = get_backend()
    _count("prealign_encode", backend, spec)
    if backend == "jax":
        return prealign_encode_ref(X, centroids, level, tail, window,
                                   measure=spec)
    return _prealign_encode_pallas(X, centroids, level, tail, window,
                                   block=block,
                                   interpret=_interpret_flag(backend),
                                   measure=spec)


def lb_refine(A: jnp.ndarray, B: jnp.ndarray, upper: jnp.ndarray,
              lower: jnp.ndarray, thresh: jnp.ndarray,
              window: Optional[int] = None, *,
              block: Optional[int] = None,
              measure: MeasureArg = None,
              band: str = "static",
              corridor: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              corridor_factor: int = 8, corridor_radius: int = 2,
              width: Optional[int] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused cascade bound + conditional banded refine over zipped
    pairs: ``A (N, L)`` queries, ``B (N, L)`` candidates, ``upper``/
    ``lower (N, L)`` Keogh envelopes of ``A``, ``thresh (N,)``.

    Returns ``(d (N,), refined (N,) bool)``: ``d`` is the exact banded
    elastic cost where ``max(LB_Kim, LB_Keogh) < thresh`` and the (valid)
    lower bound elsewhere.  On the Pallas route a pair tile whose bounds
    all exceed their thresholds skips the wavefront sweep entirely.

    Only sound for measures with ``has_keogh_lb`` (a hard error otherwise
    — capability-gated callers such as ``lb_search.filtered_topk`` fall
    back to the exact dense path before reaching here).

    ``band="adaptive"`` refines inside each pair's own corridor envelope
    (built here unless ``corridor=(lo, hi)`` is given).  The bound math
    is unchanged — ``lb`` stays a valid lower bound of the static-band
    distance — but the refined value is the corridor-restricted cost, an
    *upper* bound of the static cost, so the adaptive cascade is the
    documented approximate contract (ledgered as ``lb_refine_adaptive``)
    and is excluded from the certified-exact LB cascade guarantees.

    >>> import jax.numpy as jnp
    >>> from repro.core import dispatch
    >>> A, B = jnp.zeros((2, 8)), jnp.ones((2, 8))
    >>> env = jnp.zeros((2, 8))                    # degenerate envelopes
    >>> with dispatch.use_backend("jax"):
    ...     d, refined = dispatch.lb_refine(A, B, env, env,
    ...                                     jnp.array([100.0, 0.0]),
    ...                                     window=2)
    >>> [bool(r) for r in refined]                 # row 1 pruned by bound
    [True, False]
    >>> float(d[0])                                # exact where refined
    8.0
    """
    from ..kernels.lb_cascade.ops import lb_refine as _lb_refine_pallas
    from ..kernels.lb_cascade.ref import lb_refine_jax
    spec = measures.resolve(measure)
    if not spec.has_keogh_lb:
        raise ValueError(
            f"measure {spec.name!r} has no sound Keogh/Kim lower bound; "
            "lb_refine would prune incorrectly — use the exact dense path")
    backend = get_backend()
    if band == "static":
        _count("lb_refine", backend, spec)
        if backend == "jax":
            return lb_refine_jax(A, B, upper, lower, thresh, window,
                                 measure=spec)
        return _lb_refine_pallas(A, B, upper, lower, thresh, window,
                                 block=block,
                                 interpret=_interpret_flag(backend),
                                 measure=spec)
    if band != "adaptive":
        raise ValueError(f"unknown band mode {band!r}; "
                         "expected 'static' or 'adaptive'")
    _count("lb_refine_adaptive", backend, spec)
    L = A.shape[-1]
    corr, width = _adaptive_geometry(L, window, backend, spec, width,
                                     corridor_factor, corridor_radius)
    if corridor is None:
        corridor = corr.build_corridor(A, B, window, factor=corridor_factor,
                                       radius=corridor_radius)
    lo, hi = corr.clip_to_width(*corridor, width)
    if backend == "jax":
        return lb_refine_jax(A, B, upper, lower, thresh, window,
                             measure=spec, corridor=(lo, hi), width=width)
    return _lb_refine_pallas(A, B, upper, lower, thresh, window,
                             block=block,
                             interpret=_interpret_flag(backend),
                             measure=spec, corridor=(lo, hi), width=width)


def two_level_coarse(Q: jnp.ndarray, top: jnp.ndarray, coarse: jnp.ndarray,
                     child_idx: jnp.ndarray, child_valid: jnp.ndarray,
                     window: Optional[int] = None, *, n_probe_top: int,
                     block: Optional[int] = None,
                     measure: MeasureArg = None) -> jnp.ndarray:
    """Hierarchical (two-level) coarse stage for large ``n_lists``.

    ``Q (Nq, D)`` queries are first ranked against the ``top (n_top, D)``
    cluster-the-centroids quantizer (one all-pairs kernel launch); only
    the children of each query's ``n_probe_top`` nearest top cells —
    ``child_idx`` / ``child_valid (n_top, max_children)`` indexing into
    ``coarse (n_lists, D)`` — are then evaluated exactly, as one *zipped
    pairs* launch over the ``Nq * n_probe_top * max_children`` gathered
    (query, centroid) pairs.  Returns the ``(Nq, n_lists)`` coarse
    distance row with ``+inf`` for lists outside the fan-out, which the
    downstream probe ``top_k`` consumes unchanged.

    Per-query cost is ``O(n_top + n_probe_top * max_children)`` elastic
    evaluations instead of ``O(n_lists)``; with ``n_probe_top == n_top``
    every list is visited and the result matches the flat coarse cdist.
    Both heavy stages route through the same kernel paths as
    :func:`elastic_cdist` / :func:`elastic_pairwise`; the op is ledgered
    separately so the routing gate can prove the hierarchical stage ran.

    >>> import jax.numpy as jnp
    >>> from repro.core import dispatch
    >>> coarse = jnp.arange(4, dtype=jnp.float32)[:, None] * jnp.ones(8)
    >>> top = jnp.array([[0.5] * 8, [2.5] * 8])    # parents of {0,1}, {2,3}
    >>> child_idx = jnp.array([[0, 1], [2, 3]], jnp.int32)
    >>> child_valid = jnp.ones((2, 2), bool)
    >>> with dispatch.use_backend("jax"):
    ...     dc = dispatch.two_level_coarse(jnp.zeros((1, 8)), top, coarse,
    ...                                    child_idx, child_valid,
    ...                                    n_probe_top=1)
    >>> dc.shape
    (1, 4)
    >>> [bool(jnp.isfinite(x)) for x in dc[0]]     # only top cell 0 fans out
    [True, True, False, False]
    >>> float(dc[0, 0])
    0.0
    """
    n_top, C = child_idx.shape
    if not 1 <= n_probe_top <= n_top:
        raise ValueError(
            f"n_probe_top={n_probe_top} out of range: must satisfy "
            f"1 <= n_probe_top <= n_top={n_top}")
    spec = measures.resolve(measure)
    _count("two_level_coarse", get_backend(), spec)
    Q = jnp.asarray(Q, jnp.float32)
    Nq = Q.shape[0]
    n_lists = coarse.shape[0]
    dc_top = elastic_cdist(Q, top, window, block=block, measure=spec)
    _, tops = jax.lax.top_k(-dc_top, n_probe_top)          # (Nq, P)
    cand = child_idx[tops].reshape(Nq, n_probe_top * C)    # (Nq, P*C)
    cvalid = child_valid[tops].reshape(Nq, n_probe_top * C)
    cents = coarse[cand.reshape(-1)]                       # (Nq*P*C, D)
    qq = jnp.repeat(Q, n_probe_top * C, axis=0)
    d = elastic_pairwise(qq, cents, window, block=block, measure=spec)
    d = jnp.where(cvalid.reshape(-1), d,
                  jnp.inf).reshape(Nq, n_probe_top * C)
    dc = jnp.full((Nq, n_lists), jnp.inf, jnp.float32)
    # scatter-min: a list reachable through two probed tops keeps one
    # (identical) distance; masked padding lanes are +inf no-ops
    return dc.at[jnp.arange(Nq)[:, None], cand].min(d)
