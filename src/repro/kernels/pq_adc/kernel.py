"""PQ asymmetric/symmetric distance-computation (ADC) Pallas kernels.

The distance scan over PQ codes is a gather+reduce; TPU gathers are slow, so
lookups are rewritten as one-hot contractions that land on the MXU:

  * symmetric cdist:  d2[i, j] = sum_m LUT[m, a_i^m, b_j^m]
        per subspace:  onehot(a^m) @ LUT[m] @ onehot(b^m)^T   (two matmuls)
  * asymmetric scan:  d2[n] = sum_m QLUT[m, c_n^m]
        per subspace:  onehot(c^m) @ QLUT[m]                  (one matvec)

K (=256 by default) is MXU-lane aligned, so the one-hot matrices tile
perfectly.  LUT/QLUT live fully in VMEM (M*K*K*4 bytes = 1 MiB for M=4,
K=256); code tiles stream through the grid.

Quantized LUT variants (``*_quant_kernel``) take the table as int8 or
bfloat16 with per-subspace affine parameters ``scale``/``zero`` — the
resident LUT shrinks 4x (int8) or 2x (bf16).  Because each one-hot
contraction *selects* exactly one table entry per subspace, the affine
map commutes with the contraction: the kernels accumulate
``scale_m * contraction + zero_m`` per subspace, which equals running
the f32 kernel on the dequantized table (up to the quantization error
itself — see :func:`repro.kernels.pq_adc.ops.quantize_lut`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "make_adc_sym_call",
    "make_adc_lookup_call",
    "make_adc_sym_quant_call",
    "make_adc_lookup_quant_call",
]


def _one_hot(codes_col: jnp.ndarray, K: int) -> jnp.ndarray:
    """``codes_col (B,)`` int32 -> ``(B, K)`` float32 one-hot (iota compare)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (codes_col.shape[0], K), 1)
    return (iota == codes_col[:, None]).astype(jnp.float32)


def adc_sym_kernel(a_ref, b_ref, lut_ref, o_ref, *, n_sub: int, K: int):
    """``a_ref (bA, M)``, ``b_ref (bB, M)``, ``lut_ref (M, K, K)`` ->
    ``o_ref (bA, bB)`` = sqrt(sum_m LUT[m, a^m, b^m])."""
    a = a_ref[...]
    b = b_ref[...]
    acc = jnp.zeros((a.shape[0], b.shape[0]), jnp.float32)
    for m in range(n_sub):  # static unroll: M is small
        a_oh = _one_hot(a[:, m], K)                    # (bA, K)
        b_oh = _one_hot(b[:, m], K)                    # (bB, K)
        mid = jax.lax.dot_general(
            a_oh, lut_ref[m], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bA, K)
        acc += jax.lax.dot_general(
            mid, b_oh, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bA, bB)
    o_ref[...] = jnp.sqrt(jnp.maximum(acc, 0.0))


def adc_lookup_kernel(c_ref, qlut_ref, o_ref, *, n_sub: int, K: int):
    """``c_ref (B, M)``, ``qlut_ref (M, K)`` -> ``o_ref (B, 1)`` distances."""
    c = c_ref[...]
    acc = jnp.zeros((c.shape[0], 1), jnp.float32)
    for m in range(n_sub):
        oh = _one_hot(c[:, m], K)                      # (B, K)
        acc += jax.lax.dot_general(
            oh, qlut_ref[m][:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (B, 1)
    o_ref[...] = jnp.sqrt(jnp.maximum(acc, 0.0))


def adc_sym_quant_kernel(a_ref, b_ref, qlut_ref, sc_ref, zp_ref, o_ref, *,
                         n_sub: int, K: int):
    """Quantized-LUT symmetric ADC: ``qlut_ref (M, K, K)`` int8/bf16 with
    per-subspace affine ``sc_ref``/``zp_ref (M, 1)`` f32 ->
    ``o_ref (bA, bB)``.  The affine is applied *after* each subspace
    contraction (the one-hot selection commutes with it)."""
    a = a_ref[...]
    b = b_ref[...]
    acc = jnp.zeros((a.shape[0], b.shape[0]), jnp.float32)
    for m in range(n_sub):  # static unroll: M is small
        a_oh = _one_hot(a[:, m], K)
        b_oh = _one_hot(b[:, m], K)
        mid = jax.lax.dot_general(
            a_oh, qlut_ref[m].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        sel = jax.lax.dot_general(
            mid, b_oh, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc += sc_ref[m, 0] * sel + zp_ref[m, 0]
    o_ref[...] = jnp.sqrt(jnp.maximum(acc, 0.0))


def adc_lookup_quant_kernel(c_ref, qlut_ref, sc_ref, zp_ref, o_ref, *,
                            n_sub: int, K: int):
    """Quantized-LUT asymmetric scan: ``qlut_ref (M, K)`` int8/bf16 plus
    ``sc_ref``/``zp_ref (M, 1)`` f32 -> ``o_ref (B, 1)``."""
    c = c_ref[...]
    acc = jnp.zeros((c.shape[0], 1), jnp.float32)
    for m in range(n_sub):
        oh = _one_hot(c[:, m], K)
        sel = jax.lax.dot_general(
            oh, qlut_ref[m].astype(jnp.float32)[:, None],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc += sc_ref[m, 0] * sel + zp_ref[m, 0]
    o_ref[...] = jnp.sqrt(jnp.maximum(acc, 0.0))


def make_adc_sym_call(nA: int, nB: int, n_sub: int, K: int,
                      block_a: int, block_b: int, interpret: bool):
    kernel = functools.partial(adc_sym_kernel, n_sub=n_sub, K=K)
    return pl.pallas_call(
        kernel,
        grid=(nA // block_a, nB // block_b),
        in_specs=[
            pl.BlockSpec((block_a, n_sub), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, n_sub), lambda i, j: (j, 0)),
            pl.BlockSpec((n_sub, K, K), lambda i, j: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_a, block_b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nA, nB), jnp.float32),
        interpret=interpret,
    )


def make_adc_lookup_call(n: int, n_sub: int, K: int, block: int,
                         interpret: bool):
    kernel = functools.partial(adc_lookup_kernel, n_sub=n_sub, K=K)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, n_sub), lambda i: (i, 0)),
            pl.BlockSpec((n_sub, K), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )


def make_adc_sym_quant_call(nA: int, nB: int, n_sub: int, K: int,
                            block_a: int, block_b: int, interpret: bool):
    kernel = functools.partial(adc_sym_quant_kernel, n_sub=n_sub, K=K)
    return pl.pallas_call(
        kernel,
        grid=(nA // block_a, nB // block_b),
        in_specs=[
            pl.BlockSpec((block_a, n_sub), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, n_sub), lambda i, j: (j, 0)),
            pl.BlockSpec((n_sub, K, K), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((n_sub, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((n_sub, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_a, block_b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nA, nB), jnp.float32),
        interpret=interpret,
    )


def make_adc_lookup_quant_call(n: int, n_sub: int, K: int, block: int,
                               interpret: bool):
    kernel = functools.partial(adc_lookup_quant_kernel, n_sub=n_sub, K=K)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, n_sub), lambda i: (i, 0)),
            pl.BlockSpec((n_sub, K), lambda i: (0, 0)),
            pl.BlockSpec((n_sub, 1), lambda i: (0, 0)),
            pl.BlockSpec((n_sub, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )
