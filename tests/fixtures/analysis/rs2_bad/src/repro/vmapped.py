"""RS204 seed: vmap over a function that reaches a pallas_call."""

import jax

from .kernels.badk.ops import run_badk


def batched(xs):
    return jax.vmap(run_badk)(xs)  # RS204
