"""DBA barycenters and DBA k-means behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dba import alignment_path, dba, dba_update
from repro.core.dtw import dtw_batch, dtw_cdist
from repro.core.kmeans import dba_kmeans, euclidean_kmeans


def _shifted_family(n, L, seed=0):
    """Same underlying bump, randomly shifted — DBA should recover the bump."""
    rng = np.random.default_rng(seed)
    t = np.arange(L, dtype=np.float32)
    out = np.zeros((n, L), np.float32)
    for i in range(n):
        c = L / 2 + rng.uniform(-L / 8, L / 8)
        out[i] = np.exp(-((t - c) ** 2) / (2 * (L / 12) ** 2))
    return out


def test_alignment_path_valid():
    rng = np.random.default_rng(1)
    c = rng.standard_normal(16).astype(np.float32)
    x = rng.standard_normal(16).astype(np.float32)
    i_cells, j_cells, active = map(np.asarray, alignment_path(c, x))
    ii, jj = i_cells[active], j_cells[active]
    # path starts at the corner and ends at the origin
    assert ii[0] == 15 and jj[0] == 15
    assert ii[-1] == 0 and jj[-1] == 0
    # monotone, unit steps
    di = -np.diff(ii)
    dj = -np.diff(jj)
    assert ((di == 0) | (di == 1)).all()
    assert ((dj == 0) | (dj == 1)).all()
    assert ((di + dj) >= 1).all()
    # every barycenter index visited
    assert set(ii.tolist()) == set(range(16))


def test_dba_reduces_within_cost():
    X = _shifted_family(12, 48)
    c0 = X[0]
    before = float(jnp.sum(dtw_batch(
        jnp.broadcast_to(c0, X.shape), jnp.asarray(X))))
    c = dba(c0, X, iters=5)
    after = float(jnp.sum(dtw_batch(
        jnp.broadcast_to(np.asarray(c), X.shape), jnp.asarray(X))))
    assert after <= before + 1e-5


def test_dba_identity_fixed_point():
    """A barycenter of identical series is that series."""
    x = np.random.default_rng(2).standard_normal(24).astype(np.float32)
    X = np.tile(x, (5, 1))
    c = np.asarray(dba_update(jnp.asarray(x), jnp.asarray(X)))
    assert np.allclose(c, x, atol=1e-5)


def test_dba_kmeans_separates_obvious_clusters():
    rng = np.random.default_rng(5)
    lo = rng.standard_normal((20, 32)).astype(np.float32) * 0.1 - 3
    hi = rng.standard_normal((20, 32)).astype(np.float32) * 0.1 + 3
    X = np.concatenate([lo, hi])
    res = dba_kmeans(jax.random.PRNGKey(0), X, k=2, iters=5, window=4)
    a = np.asarray(res.assignment)
    assert len(np.unique(a[:20])) == 1
    assert len(np.unique(a[20:])) == 1
    assert a[0] != a[20]


def test_dba_kmeans_inertia_reasonable():
    X = _shifted_family(24, 32, seed=9)
    res1 = dba_kmeans(jax.random.PRNGKey(1), X, k=1, iters=4, window=4)
    res4 = dba_kmeans(jax.random.PRNGKey(1), X, k=4, iters=4, window=4)
    assert float(res4.inertia) <= float(res1.inertia) + 1e-5


def test_euclidean_kmeans_matches_structure():
    rng = np.random.default_rng(6)
    a = rng.standard_normal((15, 16)).astype(np.float32) + 4
    b = rng.standard_normal((15, 16)).astype(np.float32) - 4
    X = np.concatenate([a, b])
    res = euclidean_kmeans(jax.random.PRNGKey(2), X, k=2, iters=10)
    lab = np.asarray(res.assignment)
    assert lab[:15].std() == 0 and lab[15:].std() == 0 and lab[0] != lab[-1]
