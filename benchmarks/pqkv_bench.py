"""PQ-KV serving quality/memory sweep (beyond-paper application).

For a reduced dense config: populate an exact cache, compress with PQ at
several (M, K, W) points, and measure (a) the compression ratio, (b) the
greedy-decode agreement with exact attention, (c) logit correlation — the
serving analogue of the paper's accuracy-vs-compression trade-off.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_reduced
from repro.models.lm import init_params
from repro.serve.cache import init_cache
from repro.serve.decode import serve_step
from repro.serve.pqkv import (PQKVConfig, compress_cache, pq_serve_step,
                              pqkv_memory)

from .common import Bench


def run(quick: bool = True) -> Bench:
    b = Bench("pqkv_quality")
    cfg = get_reduced("qwen2-72b")
    B, prompt, gen = (2, 24, 6) if quick else (4, 96, 24)
    Smax = prompt + gen
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, Smax + 1), 0, cfg.vocab_size)

    cache = init_cache(cfg, B, Smax)
    step = jax.jit(lambda p, c, t, pos: serve_step(p, cfg, c, t, pos))
    logits = None
    for p in range(prompt):
        logits, cache = step(params, cache, toks[:, p:p + 1], jnp.int32(p))

    # exact continuation
    ref_cache = jax.tree.map(jnp.array, cache)
    ref_tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    ref_out = [np.asarray(ref_tok)]
    ref_logits = []
    for g in range(gen - 1):
        lg, ref_cache = step(params, ref_cache, ref_tok,
                             jnp.int32(prompt + g))
        ref_logits.append(np.asarray(lg, np.float32))
        ref_tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        ref_out.append(np.asarray(ref_tok))
    ref_out = np.concatenate(ref_out, 1)

    sweeps = ((4, 8, 8, False), (4, 16, 8, False), (8, 16, 8, False),
              (4, 16, 4, True))
    for M, K, W, qv in sweeps:
        pqc = PQKVConfig(n_sub=M, codebook_size=K, recent_window=W,
                         quantize_v=qv, kmeans_iters=6)
        pq_cache = compress_cache(
            {"k": jnp.array(cache["k"]), "v": jnp.array(cache["v"])},
            cfg, pqc, pos=prompt)
        pq_step = jax.jit(
            lambda p, c, t, pos: pq_serve_step(p, cfg, c, t, pos, pqc=pqc))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs = [np.asarray(tok)]
        corrs = []
        for g in range(gen - 1):
            lg, pq_cache = pq_step(params, pq_cache, tok,
                                   jnp.int32(prompt + g))
            a = np.asarray(lg, np.float32).ravel()
            r = ref_logits[g].ravel()
            corrs.append(np.corrcoef(a, r)[0, 1])
            tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok))
        outs = np.concatenate(outs, 1)
        mem = pqkv_memory(cfg, pqc, B, Smax)
        b.add(n_sub=M, codebook=K, window=W, quantize_v=qv,
              compression=round(mem["compression"], 3),
              greedy_agreement=float((outs == ref_out).mean()),
              logit_corr=float(np.mean(corrs)))
    b.save()
    return b


if __name__ == "__main__":
    run(quick=False)
