#!/usr/bin/env python3
"""Fail CI if the dispatch layer silently fell off the expected backend.

Usage: python scripts/check_routing.py ROUTING_DUMP.json [BACKEND]

The dump is written by tests/conftest.py at pytest session end (set
REPRO_ROUTING_DUMP): a ``repro.obs`` metrics snapshot whose
``dispatch_total`` counters mirror the process-lifetime
``repro.core.dispatch.totals`` ledger.  That snapshot is the *only*
accepted format — a dump without counters/histograms keys is rejected
rather than guessed at.  Every elastic op must have dispatched through
BACKEND (default: the REPRO_ELASTIC_BACKEND the tests ran under) at
least once — a kernel import error or an accidental fallback to the
pure-JAX route would otherwise let the suite pass without executing a
single Pallas kernel body.

Measure-parameterized ops are additionally keyed as "op[measure]"; for
MEASURED_OPS the gate also requires at least one NON-DTW measure to have
dispatched through BACKEND, so the measure-generic kernel bodies
(wdtw/erp/msm recurrence steps) are provably exercised, not just the DTW
default.

When the snapshot was captured with obs enabled, a third gate checks
*stage coverage*: every instrumented pipeline stage in EXPECTED_STAGES
must have recorded at least one ``stage_seconds`` span — catching a
refactor that silently drops instrumentation while the routing ledger
still looks healthy.
"""

import json
import os
import re
import sys

EXPECTED_OPS = (
    "elastic_pairwise",
    "elastic_pairwise_adaptive",
    "elastic_cdist",
    "adc_cdist",
    "adc_cdist_quant",
    "adc_lookup",
    "adc_lookup_quant",
    "prealign_encode",
    "lb_refine",
    "lb_refine_adaptive",
    "two_level_coarse",
)

# ops whose recurrence is measure-parameterized: each needs a non-DTW
# dispatch on the asserted backend (lb_refine stays DTW-only by its
# capability gate, so it is not listed here)
MEASURED_OPS = (
    "elastic_pairwise",
    "elastic_cdist",
    "prealign_encode",
    "two_level_coarse",
)

# every instrumented pipeline stage the tier-1 suite must light up when
# it runs with REPRO_OBS=1 (spans live in index/streaming.py,
# index/planner.py and serve_index/)
EXPECTED_STAGES = (
    "index.search",
    "index.search.coarse",
    "index.search.lut",
    "index.search.fine",
    "index.search.hot",
    "index.search.merge",
    "index.insert",
    "index.flush",
    "index.compact",
    "sharded.search",
    "sharded.execute",
    "serving.batch_search",
    "serving.apply",
    "serving.snapshot_swap",
)


def ledger_from_snapshot(snap: dict) -> dict:
    """Rebuild the flat ``{"op:backend": n, "op[measure]:backend": n}``
    ledger from a metrics snapshot's ``dispatch_total`` counters."""
    ledger: dict = {}
    for c in snap.get("counters", []):
        if c["name"] != "dispatch_total":
            continue
        labels = c["labels"]
        op, backend = labels.get("op"), labels.get("backend")
        if not op or not backend:
            continue
        n = int(c["value"])
        key = f"{op}:{backend}"
        ledger[key] = ledger.get(key, 0) + n
        measure = labels.get("measure")
        if measure:
            mkey = f"{op}[{measure}]:{backend}"
            ledger[mkey] = ledger.get(mkey, 0) + n
    return ledger


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__)
        return 2
    path = sys.argv[1]
    backend = (
        sys.argv[2]
        if len(sys.argv) > 2
        else os.environ.get("REPRO_ELASTIC_BACKEND", "pallas_interpret")
    )
    with open(path) as f:
        dump = json.load(f)
    if "counters" not in dump and "histograms" not in dump:
        print(
            f"FAIL: {path} is not a repro.obs metrics snapshot (no "
            "counters/histograms keys); the pre-obs flat routing dict "
            "is no longer accepted"
        )
        return 2
    ledger = ledger_from_snapshot(dump)
    print(f"routing ledger ({path}), asserting backend {backend!r}:")
    for key in sorted(ledger):
        print(f"  {key}: {ledger[key]}")
    missing = [op for op in EXPECTED_OPS if not ledger.get(f"{op}:{backend}")]
    if missing:
        print(
            f"FAIL: ops never dispatched through {backend!r}: "
            f"{', '.join(missing)} — silent backend fallback?"
        )
        return 1
    missing_measure = []
    for op in MEASURED_OPS:
        pat = re.compile(
            rf"^{re.escape(op)}\[(?!dtw\])[^\]]+\]:{re.escape(backend)}$"
        )
        if not any(pat.match(k) and ledger[k] for k in ledger):
            missing_measure.append(op)
    if missing_measure:
        print(
            f"FAIL: measure-parameterized ops never ran a non-DTW measure "
            f"through {backend!r}: {', '.join(missing_measure)} — the "
            "measure-generic kernel bodies are untested"
        )
        return 1
    print(
        f"OK: all {len(EXPECTED_OPS)} elastic ops routed through "
        f"{backend!r} (incl. a non-DTW measure for "
        f"{len(MEASURED_OPS)} measured ops)"
    )
    if dump.get("obs_enabled"):
        seen = {
            h["labels"].get("stage")
            for h in dump.get("histograms", [])
            if h["name"] == "stage_seconds" and h["count"] > 0
        }
        missing_stages = [s for s in EXPECTED_STAGES if s not in seen]
        if missing_stages:
            print(
                "FAIL: instrumented stages recorded zero samples: "
                f"{', '.join(missing_stages)} — span instrumentation "
                "silently dropped?"
            )
            return 1
        print(
            f"OK: all {len(EXPECTED_STAGES)} instrumented stages recorded "
            "spans"
        )
    else:
        print(
            "note: snapshot captured with obs disabled — stage-coverage "
            "gate skipped (set REPRO_OBS=1 to assert it)"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
