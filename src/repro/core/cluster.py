"""Agglomerative hierarchical clustering (§4.2) from a distance matrix.

Lance-Williams agglomeration with single / complete / average linkage,
implemented in numpy (the merge loop is inherently sequential and tiny next
to the distance-matrix construction, which is the part PQDTW accelerates).
"""

from __future__ import annotations

import numpy as np

__all__ = ["linkage", "cut_k", "hierarchical_labels"]

_LW = {
    "single": lambda da, db, na, nb: np.minimum(da, db),
    "complete": lambda da, db, na, nb: np.maximum(da, db),
    "average": lambda da, db, na, nb: (na * da + nb * db) / (na + nb),
}


def linkage(dist: np.ndarray, method: str = "complete") -> np.ndarray:
    """SciPy-compatible linkage matrix ``(N-1, 4)`` from a square distance
    matrix (values: merged id a, id b, merge distance, new cluster size)."""
    d = np.array(dist, np.float64, copy=True)
    n = d.shape[0]
    np.fill_diagonal(d, np.inf)
    update = _LW[method]
    size = np.ones(n)
    cid = np.arange(n)          # current cluster id per active row
    active = np.ones(n, bool)
    Z = np.zeros((n - 1, 4))
    next_id = n
    for t in range(n - 1):
        masked = np.where(active[:, None] & active[None, :], d, np.inf)
        i, j = np.unravel_index(np.argmin(masked), masked.shape)
        if i > j:
            i, j = j, i
        Z[t] = (min(cid[i], cid[j]), max(cid[i], cid[j]), masked[i, j],
                size[i] + size[j])
        # merge j into i via Lance-Williams
        d[i, :] = update(d[i, :], d[j, :], size[i], size[j])
        d[:, i] = d[i, :]
        d[i, i] = np.inf
        active[j] = False
        size[i] += size[j]
        cid[i] = next_id
        next_id += 1
    return Z


def cut_k(Z: np.ndarray, n: int, k: int) -> np.ndarray:
    """Cut the dendrogram at the minimum height producing ``k`` clusters."""
    parent = np.arange(n + len(Z))

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    # apply merges in order until k clusters remain
    merges = len(Z) - (k - 1) if k >= 1 else len(Z)
    for t in range(max(0, merges)):
        a, b = int(Z[t, 0]), int(Z[t, 1])
        ra, rb = find(a), find(b)
        parent[ra] = n + t
        parent[rb] = n + t
    roots = np.array([find(i) for i in range(n)])
    _, labels = np.unique(roots, return_inverse=True)
    return labels


def hierarchical_labels(dist: np.ndarray, k: int,
                        method: str = "complete") -> np.ndarray:
    """Distance matrix -> flat cluster labels with ``k`` clusters."""
    n = dist.shape[0]
    if k >= n:
        return np.arange(n)
    return cut_k(linkage(dist, method), n, k)
