"""Fault-tolerant checkpointing.

Design for 1000+ node runs:
  * atomic step directories (write to ``.tmp-<step>``, fsync, rename) — a
    crash mid-write never corrupts the latest checkpoint;
  * ``keep_last`` garbage collection;
  * async writer thread — training never blocks on storage;
  * elastic restore: leaves are stored *unsharded* (gathered) with a JSON
    manifest, so a restart may use a different mesh/device count — the
    restore path lays leaves out for whatever sharding the new run asks for.

On a real multi-host pod the gather/save would be per-host chunked (e.g.
tensorstore); the storage format and crash-safety protocol are identical.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]

_MANIFEST = "manifest.json"


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _ in flat:
        parts = []
        for k in path:
            if hasattr(k, "name"):
                parts.append(str(k.name))
            elif hasattr(k, "key"):
                parts.append(str(k.key))
            else:
                parts.append(str(getattr(k, "idx", k)))
        names.append("__".join(parts) or "leaf")
    return flat, treedef, names


def save(directory: str, step: int, tree: Any, keep_last: int = 3) -> str:
    """Atomically persist ``tree`` under ``directory/step_<step>``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = os.path.join(directory, f".tmp-step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _, names = _leaf_paths(tree)
    manifest = {"step": step, "leaves": []}
    for (path, leaf), name in zip(flat, names):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"{len(manifest['leaves']):05d}_{name[:80]}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append({"file": fn, "name": name,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep_last)
    return final


def _gc(directory: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and os.path.exists(
                os.path.join(directory, d, _MANIFEST)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Load step ``step`` into the structure of ``like``.

    ``shardings`` (optional pytree of NamedSharding, same structure) lays
    each leaf out for the *current* mesh — elastic restart across different
    device counts.
    """
    d = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    flat, treedef, _ = _leaf_paths(like)
    assert len(flat) == len(manifest["leaves"]), \
        f"checkpoint has {len(manifest['leaves'])} leaves, model expects {len(flat)}"
    shard_flat = (jax.tree.leaves(shardings,
                                  is_leaf=lambda x: hasattr(x, "spec"))
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for meta, (path, ref), sh in zip(manifest["leaves"], flat, shard_flat):
        arr = np.load(os.path.join(d, meta["file"]))
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Background writer: ``submit`` returns immediately; ``wait`` blocks."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.directory, step, tree, self.keep_last)
            except BaseException as e:  # surfaced on next submit/wait
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree: Any) -> None:
        if self._err:
            raise self._err
        # device_get now so the training arrays can be donated/overwritten
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host_tree))

    def wait(self) -> None:
        self._q.join()
        if self._err:
            raise self._err

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)
