"""Filter-and-refine NN-DTW: batched LB-cascade vs the legacy host loop.

Measures the rewrite of ``nn_dtw_pruned`` — one device-resident two-phase
computation (bound all pairs, ``lax.while_loop`` threshold-tightening
refines through the fused ``dispatch.lb_refine`` kernel) — against the
superseded per-query host loop (``nn_dtw_pruned_host``: ascending-LB
chunks with a device round-trip per chunk).  Both are exact, so the
predictions must agree; the interesting numbers are wall clock and each
variant's pruning fraction (the rate of (query, candidate) pairs the
cascade excluded from exact refinement — a per-pair decision count, not
a direct measure of compute skipped).
"""

from __future__ import annotations

import numpy as np

from repro.core.knn import nn_dtw_pruned, nn_dtw_pruned_host
from repro.core.lb_search import filtered_topk

from . import common
from .common import Bench, timeit


def _random_walks(n: int, length: int, seed: int) -> np.ndarray:
    """Random walks: realistically autocorrelated, so the Keogh envelopes
    are tight enough for the cascade to prune (white noise would not be)."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.standard_normal((n, length)), axis=1).astype(
        np.float32)


def _warped_queries(X: np.ndarray, n_q: int, seed: int,
                    drift: int = 2) -> np.ndarray:
    """Queries that are locally-warped copies of database rows — the
    workload where per-pair adaptive corridors stay narrow."""
    rng = np.random.default_rng(seed)
    length = X.shape[1]
    Q = np.empty((n_q, length), np.float32)
    for i in range(n_q):
        src = X[rng.integers(0, X.shape[0])]
        off = np.clip(np.cumsum(rng.integers(-1, 2, size=length)),
                      -drift, drift)
        Q[i] = src[np.clip(np.arange(length) + off, 0,
                           length - 1).astype(np.int64)]
    return Q + rng.normal(scale=0.02, size=Q.shape).astype(np.float32)


def run(quick: bool = True) -> None:
    bench = Bench("lb_cascade")
    # (N database series, L length, Nq queries); the (2048, 256) points are
    # the acceptance size for the batched rewrite — the host loop scales
    # linearly in Nq while the batched search amortizes its bound phase,
    # so both a small and a serving-sized query batch are reported.
    sizes = [(512, 128, 8), (2048, 256, 16), (2048, 256, 64)]
    if common.SMOKE:
        sizes = [(256, 64, 4)]
    elif not quick:
        sizes.append((8192, 256, 16))
    measure = common.MEASURE
    for n, length, n_q in sizes:
        X = _random_walks(n, length, 0)
        Q = _random_walks(n_q, length, 1)
        labels = np.arange(n) % 8
        window = max(1, length // 10)
        preds_new, pruned_new = nn_dtw_pruned(X, labels, Q, window,
                                              measure=measure)
        run_new = lambda: nn_dtw_pruned(X, labels, Q, window,
                                        measure=measure)
        t_new = timeit(run_new)
        row = dict(N=n, L=length, Nq=n_q, window=window, measure=measure,
                   batched_s=t_new["median_s"], pruned_batched=pruned_new)
        if measure == "dtw":
            # the legacy host loop is the DTW-only equivalence baseline
            preds_old, pruned_old = nn_dtw_pruned_host(X, labels, Q, window)
            t_old = timeit(nn_dtw_pruned_host, X, labels, Q, window)
            row.update(host_s=t_old["median_s"],
                       speedup=t_old["median_s"] / t_new["median_s"],
                       pruned_host=pruned_old,
                       preds_equal=bool((preds_new == preds_old).all()))
        bench.add(**row)

    # -- adaptive-band filter-and-refine on locally-warped queries ----------
    # Results are the documented approximate contract: distances are
    # corridor-restricted (>= static), so the interesting numbers are wall
    # clock plus top-1 agreement with the certified-exact static cascade.
    adaptive_rows = []
    adaptive_sizes = [(256, 512, 8)] if common.SMOKE else [(512, 2048, 16)]
    # coarse factor 16 keeps the per-wave corridor-build pass cheap at
    # these lengths; radius 6 keeps the warped queries' optimal paths
    # inside the corridor (same geometry as the dtw_kernel adaptive rows)
    factor, radius = 16, 6
    for n, length, n_q in adaptive_sizes:
        X = _random_walks(n, length, 2)
        Q = _warped_queries(X, n_q, 3)
        window = max(1, length // 10)
        run_static = lambda: filtered_topk(Q, X, window, 1)
        run_adaptive = lambda: filtered_topk(Q, X, window, 1,
                                             band="adaptive",
                                             corridor_factor=factor,
                                             corridor_radius=radius)
        _, idx_s, _ = run_static()
        _, idx_a, _ = run_adaptive()
        t_static = timeit(run_static)
        t_adaptive = timeit(run_adaptive)
        row = dict(N=n, L=length, Nq=n_q, window=window, band="adaptive",
                   corridor_factor=factor, corridor_radius=radius,
                   static_s=t_static["median_s"],
                   adaptive_s=t_adaptive["median_s"],
                   adaptive_vs_static_speedup=(t_static["median_s"]
                                               / t_adaptive["median_s"]),
                   top1_agreement=float((np.asarray(idx_s)
                                         == np.asarray(idx_a)).mean()))
        bench.add(**row)
        adaptive_rows.append(row)
    bench.save(headline={"measure": measure,
                         "adaptive_rows": adaptive_rows})


if __name__ == "__main__":
    run()
