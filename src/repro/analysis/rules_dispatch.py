"""RS2xx — dispatch invariants.

The static complement of the dynamic routing gate
(``scripts/check_routing.py``): the dynamic gate proves the suite *ran*
on the kernel route, these rules prove the wiring cannot silently decay
between runs.

* **RS201** kernel triple incomplete: every package under
  ``src/repro/kernels/<name>/`` must ship ``kernel.py`` (Pallas body),
  ``ops.py`` (public entry points), and ``ref.py`` (the jnp reference
  the dispatch fallback and the tests compare against).
* **RS202** kernel package not registered in ``core/dispatch.py`` — an
  unrouted kernel bypasses backend selection and the routing ledger.
* **RS203** dispatch op (a ``_count("<op>", ...)`` site in
  ``core/dispatch.py``) missing from ``EXPECTED_OPS`` in
  ``scripts/check_routing.py`` — the dynamic gate would never notice
  the op falling off the kernel route.
* **RS204** ``jax.vmap`` applied to a function that can reach a
  ``pl.pallas_call`` (PR 1/PR 6 invariant: Pallas kernels take batch
  dimensions as grid axes, never via vmap batching rules).
* **RS205** ``scripts/check_routing.py`` must consume exactly one gate
  format: every ``ledger = ...`` binding goes through
  ``ledger_from_snapshot`` (no legacy flat-dict fallback branches).
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Set

from .callgraph import CallGraph
from .findings import Finding

__all__ = ["run"]

_TRIPLE = ("kernel.py", "ops.py", "ref.py")


def _first_line(path: Path) -> str:
    try:
        return path.read_text(encoding="utf-8").splitlines()[0]
    except (OSError, IndexError):
        return ""


def run(graph: CallGraph, root: Path) -> List[Finding]:
    out: List[Finding] = []
    kernels_dir = root / "src" / "repro" / "kernels"
    dispatch_path = root / "src" / "repro" / "core" / "dispatch.py"
    routing_path = root / "scripts" / "check_routing.py"
    dispatch_src = (dispatch_path.read_text(encoding="utf-8")
                    if dispatch_path.exists() else "")

    if kernels_dir.is_dir():
        for pkg in sorted(p for p in kernels_dir.iterdir() if p.is_dir()):
            out.extend(_rs201(pkg))
            out.extend(_rs202(pkg, dispatch_src))

    if dispatch_path.exists() and routing_path.exists():
        out.extend(_rs203(dispatch_path, routing_path))
    if routing_path.exists():
        out.extend(_rs205(routing_path))

    out.extend(_rs204(graph))
    return out


def _anchor(pkg: Path) -> Path:
    """The file a kernel-package finding (and its suppression) lives in."""
    for name in ("ops.py", "kernel.py", "__init__.py"):
        if (pkg / name).exists():
            return pkg / name
    return pkg / "ops.py"


def _rs201(pkg: Path) -> List[Finding]:
    if pkg.name == "__pycache__":
        return []
    missing = [n for n in _TRIPLE if not (pkg / n).exists()]
    if not missing or len(missing) == len(_TRIPLE):
        return []
    anchor = _anchor(pkg)
    return [Finding(
        rule="RS201", path=anchor, lineno=1, scope=f"kernels.{pkg.name}",
        message=f"kernel package {pkg.name!r} is missing "
                f"{', '.join(missing)}; every kernel ships the "
                f"kernel.py/ops.py/ref.py triple",
        source_line=_first_line(anchor))]


def _rs202(pkg: Path, dispatch_src: str) -> List[Finding]:
    if pkg.name == "__pycache__" or not (pkg / "ops.py").exists():
        return []
    if f"kernels.{pkg.name}." in dispatch_src:
        return []
    anchor = _anchor(pkg)
    return [Finding(
        rule="RS202", path=anchor, lineno=1, scope=f"kernels.{pkg.name}",
        message=f"kernel package {pkg.name!r} is not registered in "
                f"core/dispatch.py; unrouted kernels bypass backend "
                f"selection and the routing ledger",
        source_line=_first_line(anchor))]


def _string_set(tree: ast.Module, name: str) -> Optional[Set[str]]:
    for stmt in tree.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets = [stmt.target]
        for t in targets:
            if isinstance(t, ast.Name) and t.id == name:
                return {n.value for n in ast.walk(stmt)
                        if isinstance(n, ast.Constant)
                        and isinstance(n.value, str)}
    return None


def _rs203(dispatch_path: Path, routing_path: Path) -> List[Finding]:
    dispatch_tree = ast.parse(dispatch_path.read_text(encoding="utf-8"))
    routing_tree = ast.parse(routing_path.read_text(encoding="utf-8"))
    expected = _string_set(routing_tree, "EXPECTED_OPS")
    if expected is None:
        return [Finding(
            rule="RS203", path=routing_path, lineno=1, scope="<module>",
            message="scripts/check_routing.py has no EXPECTED_OPS set; "
                    "the routing gate cannot assert op coverage",
            source_line=_first_line(routing_path))]
    src_lines = dispatch_path.read_text(encoding="utf-8").splitlines()
    out = []
    for n in ast.walk(dispatch_tree):
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                and n.func.id == "_count" and n.args
                and isinstance(n.args[0], ast.Constant)
                and isinstance(n.args[0].value, str)):
            op = n.args[0].value
            if op not in expected:
                out.append(Finding(
                    rule="RS203", path=dispatch_path, lineno=n.lineno,
                    scope="core.dispatch",
                    message=f"dispatch op {op!r} is not gated by "
                            f"EXPECTED_OPS in scripts/check_routing.py",
                    source_line=src_lines[n.lineno - 1]
                    if n.lineno <= len(src_lines) else ""))
    return out


def _rs204(graph: CallGraph) -> List[Finding]:
    reaches = graph.reaches_pallas()
    out = []
    for site in graph.vmap_sites:
        if site.target is not None and site.target in reaches:
            lines = site.module.source.splitlines()
            out.append(Finding(
                rule="RS204", path=site.module.path, lineno=site.lineno,
                scope=site.caller,
                message=f"jax.vmap over {site.target} which can reach a "
                        f"pallas_call; Pallas kernels take batch dims as "
                        f"grid axes, never vmap batching rules",
                source_line=lines[site.lineno - 1]
                if site.lineno <= len(lines) else ""))
    return out


def _rs205(routing_path: Path) -> List[Finding]:
    tree = ast.parse(routing_path.read_text(encoding="utf-8"))
    lines = routing_path.read_text(encoding="utf-8").splitlines()
    out = []
    for n in ast.walk(tree):
        if not isinstance(n, ast.Assign):
            continue
        if not any(isinstance(t, ast.Name) and t.id == "ledger"
                   for t in n.targets):
            continue
        ok = (isinstance(n.value, ast.Call)
              and isinstance(n.value.func, ast.Name)
              and n.value.func.id == "ledger_from_snapshot")
        if not ok:
            out.append(Finding(
                rule="RS205", path=routing_path, lineno=n.lineno,
                scope="check_routing",
                message="the routing gate must consume exactly one dump "
                        "format: bind `ledger` only via "
                        "ledger_from_snapshot(...) (no legacy fallback)",
                source_line=lines[n.lineno - 1]
                if n.lineno <= len(lines) else ""))
    return out
