"""Sharded query planner: fan a padded query batch out over devices.

The index (coarse centroids, codebook, sealed segments, hot buffer) is
small relative to the query stream and is *replicated*; the query batch is
padded to a multiple of the mesh size and sharded over the 1-D ``search``
axis of :func:`repro.launch.mesh.make_search_mesh`.  Each device runs the
identical single-device plan (:func:`repro.index.streaming.search_impl`)
on its query block — per-segment fine stages, hot-buffer scan, local
top-k merge — and the padded rows are sliced off after the gather.  No
cross-device collective is needed: top-k over queries is embarrassingly
parallel.

On CPU (or any single-device runtime) ``search_sharded`` degenerates to a
1-device mesh whose ``shard_map`` is bit-identical to the plain path, so
the planner is exercised by the tier-1 suite without TPU hardware.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..launch.mesh import make_search_mesh
from .streaming import StreamingIndex, search_impl

__all__ = ["search_sharded"]


def search_sharded(index: StreamingIndex, Q: np.ndarray, *,
                   n_probe: int, topk: int = 1,
                   mesh: Optional[Mesh] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multi-device :meth:`StreamingIndex.search` -> ``(dist, ids)``.

    Results are identical to the single-device path (same kernels, same
    merge order); only the query batch is partitioned.
    """
    Q = index._validate(Q, n_probe, topk)
    mesh = mesh if mesh is not None else make_search_mesh()
    n_dev = mesh.shape["search"]
    Nq = Q.shape[0]
    pad = (-Nq) % n_dev
    if pad:
        Q = jnp.concatenate([Q, jnp.zeros((pad, Q.shape[1]), Q.dtype)], 0)

    plan = (index.coarse, index.cb, tuple(index.segments),
            index._hot_arrays())

    def per_device(plan, Qb):
        coarse, cb, segs, hot = plan
        return search_impl(coarse, cb, segs, hot, Qb, icfg=index.cfg,
                           n_probe=n_probe, topk=topk, dim=index.dim)

    # check_rep=False: jax has no replication rule for pallas_call, and the
    # out_specs fully describe the (embarrassingly parallel) output layout.
    d, ids = shard_map(per_device, mesh=mesh,
                       in_specs=(P(), P("search", None)),
                       out_specs=(P("search", None), P("search", None)),
                       check_rep=False)(plan, Q)
    return d[:Nq], ids[:Nq]
