"""List-to-device placement for data-partitioned (list-sharded) search.

The scale-out unit of the index is the *inverted list*: a list's rows are
always scanned together (the fine stage gathers ``max_list`` candidate
slots from one contiguous range), so a list is atomic — it lives wholly on
one device.  Placement is therefore a bin-packing problem: assign
``n_lists`` lists with known row counts to ``n_shards`` devices so the
heaviest device carries as little as possible.

:func:`plan_placement` uses the classic greedy LPT (longest processing
time) heuristic: lists in decreasing row count, each to the currently
lightest shard.  Its makespan guarantee is what the acceptance bound in
the memory accounting relies on: when the heaviest shard received its last
list it was the *lightest* shard, so its prior load was at most the
average — hence

    max shard load <= total_rows / n_shards + max_list_rows

i.e. per-device occupancy is the perfect split plus at most one list's
worth.  Placement is recomputed from live per-list occupancy whenever a
segment is (re)sealed — in particular at ``compact()`` — and persisted in
snapshots (format 3).
"""

from __future__ import annotations

import numpy as np

__all__ = ["plan_placement", "placement_loads"]


def plan_placement(list_counts: np.ndarray, n_shards: int) -> np.ndarray:
    """Greedy occupancy-aware bin-pack: ``(n_lists,)`` row counts ->
    ``(n_lists,)`` int32 shard ids in ``[0, n_shards)``.

    Deterministic: lists are processed in decreasing count (ties by list
    id) and land on the lowest-id lightest shard, so the same occupancy
    vector always yields the same placement — snapshots restore to the
    exact layout they were written with.
    """
    counts = np.asarray(list_counts, np.int64)
    if counts.ndim != 1:
        raise ValueError(f"list_counts must be 1-D, got {counts.shape}")
    if n_shards < 1:
        raise ValueError(f"n_shards={n_shards} must be >= 1")
    placement = np.zeros(counts.shape[0], np.int32)
    if n_shards == 1:
        return placement
    loads = np.zeros(n_shards, np.int64)
    # np.lexsort: last key is primary -> decreasing count, ties by list id
    for l in np.lexsort((np.arange(counts.shape[0]), -counts)):
        s = int(np.argmin(loads))          # lowest id wins ties
        placement[l] = s
        loads[s] += counts[l]
    return placement


def placement_loads(placement: np.ndarray, list_counts: np.ndarray,
                    n_shards: int) -> np.ndarray:
    """Per-shard row totals ``(n_shards,)`` implied by a placement."""
    return np.bincount(np.asarray(placement),
                       weights=np.asarray(list_counts, np.float64),
                       minlength=n_shards).astype(np.int64)
