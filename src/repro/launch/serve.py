"""Serving launcher: batched prefill + decode, with optional PQ-KV cache.

Demonstrates the paper's technique end-to-end in the LM stack: after the
prompt is prefetched into an exact KV cache, ``--pqkv`` compresses it with
product quantization (codebooks fit on the observed keys), reports the
memory ratio (paper §3.4 applied to the cache) and generates with
ADC-approximated attention + an exact recent window.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --batch 4 --prompt-len 48 --gen 16 --pqkv
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.registry import ARCH_IDS, get_config, get_reduced
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.encdec import init_params_encdec
from repro.models.lm import init_params
from repro.serve.cache import init_cache
from repro.serve.decode import prefill_cache_encdec, serve_step
from repro.serve.pqkv import (PQKVConfig, compress_cache, pq_serve_step,
                              pqkv_memory)
from repro.sharding.partition import activation_sharding, dp_axes


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pqkv", action="store_true",
                    help="compress the cache with PQ after prefill")
    ap.add_argument("--pq-sub", type=int, default=4)
    ap.add_argument("--pq-k", type=int, default=16)
    ap.add_argument("--pq-window", type=int, default=16)
    ap.add_argument("--pq-quantize-v", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    max_len = args.max_len or (args.prompt_len + args.gen)
    print(f"[serve] arch={cfg.name} family={cfg.family} "
          f"B={args.batch} prompt={args.prompt_len} gen={args.gen}")

    key = jax.random.PRNGKey(args.seed)
    init = init_params_encdec if cfg.family == "encdec" else init_params
    with mesh, activation_sharding(dp_axes(mesh)):
        params = init(key, cfg)
        cache = init_cache(cfg, args.batch, max_len)
        prompt = jax.random.randint(key, (args.batch, args.prompt_len),
                                    0, cfg.vocab_size, jnp.int32)
        if cfg.family == "encdec":
            frames = jax.random.normal(
                key, (args.batch, cfg.n_frontend_tokens, cfg.d_model))
            cache = prefill_cache_encdec(params, cfg, cache, frames)

        step = jax.jit(lambda p, c, t, pos: serve_step(p, cfg, c, t, pos),
                       donate_argnums=(1,))

        # ---- prefill: one batched cache-filling pass where supported ----
        t0 = time.time()
        with obs.span("serve.prefill"):
            if cfg.family in ("dense", "moe", "vlm"):
                from repro.serve.prefill import prefill as batched_prefill
                logits, cache = jax.jit(
                    lambda p, c, b: batched_prefill(p, cfg, c, b),
                    donate_argnums=(1,))(params, cache, {"tokens": prompt})
            else:   # ssm/hybrid/encdec decoders prefill token-sequentially
                logits = None
                for p in range(args.prompt_len):
                    logits, cache = step(params, cache, prompt[:, p:p + 1],
                                         jnp.int32(p))
            # repro: ignore[RS101] CLI driver wall-clock timing; not servable
            jax.block_until_ready(logits)
        t_prefill = time.time() - t0
        print(f"[serve] prefill {args.prompt_len} tokens in "
              f"{t_prefill:.2f}s")

        # ---- optional PQ compression of the populated cache ----
        pqc = None
        if args.pqkv:
            assert cfg.family in ("dense", "moe", "vlm"), \
                f"PQ-KV inapplicable to family {cfg.family} (DESIGN.md §5)"
            pqc = PQKVConfig(n_sub=args.pq_sub, codebook_size=args.pq_k,
                             recent_window=args.pq_window,
                             quantize_v=args.pq_quantize_v)
            mem = pqkv_memory(cfg, pqc, args.batch, max_len)
            # copy: the exact cache is donated by the decode loop below and
            # PQKVCache.v would otherwise alias the donated buffer
            pq_cache = compress_cache(
                {"k": jnp.array(cache["k"]), "v": jnp.array(cache["v"])},
                cfg, pqc, pos=args.prompt_len, key=key)
            print(f"[serve] PQ-KV: exact {mem['exact_bytes']/1e6:.2f}MB -> "
                  f"{mem['pq_bytes']/1e6:.2f}MB "
                  f"({mem['compression']:.2f}x compression)")
            pq_step = jax.jit(
                lambda p, c, t, pos: pq_serve_step(p, cfg, c, t, pos, pqc=pqc),
                donate_argnums=(1,))

        # ---- decode ----
        tok = greedy(logits)
        out_exact, out_pq = [tok], [tok]
        t0 = time.time()
        pq_tok = tok
        for g in range(args.gen - 1):
            pos = jnp.int32(args.prompt_len + g)
            # per-step span: with obs enabled the fence syncs each step so
            # p50/p99 step latency is real; disabled, dispatch stays async
            with obs.span("serve.decode_step") as sp:
                logits, cache = step(params, cache, tok, pos)
                tok = greedy(logits)
                sp.fence(tok)
            out_exact.append(tok)
            if args.pqkv:
                pq_logits, pq_cache = pq_step(params, pq_cache, pq_tok, pos)
                pq_tok = greedy(pq_logits)
                out_pq.append(pq_tok)
        # repro: ignore[RS101] CLI driver wall-clock timing; not servable
        jax.block_until_ready(tok)
        t_dec = time.time() - t0
        toks = np.concatenate([np.asarray(t) for t in out_exact], axis=1)
        rate = args.batch * (args.gen - 1) / max(t_dec, 1e-9)
        print(f"[serve] decoded {args.gen - 1} steps x {args.batch} seqs in "
              f"{t_dec:.2f}s ({rate:.1f} tok/s)")
        if obs.enabled() and args.gen > 1:
            h = obs.histogram("stage_seconds", persistent=True,
                              stage="serve.decode_step")
            print(f"[serve] decode step p50/p99: "
                  f"{h.percentile(50) * 1e3:.1f}ms / "
                  f"{h.percentile(99) * 1e3:.1f}ms over {h.count} steps")
        print(f"[serve] sample output ids: {toks[0][:12].tolist()}")
        if args.pqkv:
            pq_toks = np.concatenate([np.asarray(t) for t in out_pq], axis=1)
            agree = float((pq_toks == toks).mean())
            print(f"[serve] PQ-KV greedy agreement with exact decode: "
                  f"{agree:.1%}")


if __name__ == "__main__":
    main()
