"""Jitted public wrapper for the fused LB-cascade filter-and-refine kernel."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ...core import measures
from ...core.measures import MeasureArg
from .. import tune
from ..common import default_interpret, pad_to
from .kernel import make_lb_refine_call

__all__ = ["lb_refine"]


def _default_lane() -> int:
    """Compressed-width lane multiple: full 128-lane tiles on real TPU
    hardware, small tiles under interpret/CPU so tests stay cheap."""
    return 128 if jax.default_backend() == "tpu" else 8


@functools.partial(jax.jit,
                   static_argnames=("window", "block", "interpret", "lane",
                                    "measure", "width"))
def lb_refine(A: jnp.ndarray, B: jnp.ndarray, upper: jnp.ndarray,
              lower: jnp.ndarray, thresh: jnp.ndarray,
              window: Optional[int] = None, block: Optional[int] = None,
              interpret: Optional[bool] = None,
              lane: Optional[int] = None,
              measure: MeasureArg = None,
              corridor: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              width: Optional[int] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cascaded bound + conditional banded-DTW refine over zipped pairs.

    ``A (N, L)`` queries, ``B (N, L)`` candidates, ``upper``/``lower``
    ``(N, L)`` Keogh envelopes of ``A`` (built with the *same* effective
    window as the DTW band, clamped to ``L - 1``), ``thresh (N,)``.
    Returns ``(d (N,), refined (N,) bool)`` where ``d`` is the exact
    squared banded DTW when ``lb < thresh`` (refined) and the lower bound
    ``max(LB_Kim, LB_Keogh)`` otherwise.

    ``corridor=(lo, hi)`` (``(N, 2L-1)`` int32 per-pair envelopes)
    switches the refine sweep to the adaptive band — the refined value
    becomes the corridor-restricted cost (>= the static cost; see
    :mod:`repro.core.corridor` for the exactness contract).
    ``block=None`` consults the tuning table.
    """
    if interpret is None:
        interpret = default_interpret()
    if lane is None:
        lane = _default_lane()
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    n, L = A.shape
    backend = "pallas_interpret" if interpret else "pallas"
    if block is None:
        block = tune.tuned("lb_refine", "block", length=L, window=window,
                           measure=measures.resolve(measure).name,
                           backend=backend, default=8)
    adaptive = corridor is not None
    if adaptive and width is None:
        width = tune.adaptive_width(L, window, lane,
                                    measure=measures.resolve(measure).name,
                                    backend=backend)
    Ap = pad_to(A, block, axis=0)
    Bp = pad_to(B, block, axis=0)
    Up = pad_to(jnp.asarray(upper, jnp.float32), block, axis=0)
    Lp = pad_to(jnp.asarray(lower, jnp.float32), block, axis=0)
    # padded rows never refine: their threshold is -inf
    Tp = pad_to(jnp.asarray(thresh, jnp.float32).reshape(-1, 1), block,
                axis=0, value=-jnp.inf)
    call = make_lb_refine_call(Ap.shape[0], L, window, block, interpret,
                               lane=lane, measure=measure,
                               adaptive=adaptive, width=width)
    if adaptive:
        lo, hi = corridor
        d, flag = call(Ap, Bp, Up, Lp, Tp,
                       pad_to(lo.astype(jnp.int32), block, axis=0),
                       pad_to(hi.astype(jnp.int32), block, axis=0))
    else:
        d, flag = call(Ap, Bp, Up, Lp, Tp)
    return d[:n, 0], flag[:n, 0].astype(bool)
