"""1-NN search/classification with PQ approximates (§4.1) + exact NN-DTW.

The exact NN-DTW path implements the UCR-suite style LB_Keogh early
abandoning (query envelopes, candidate pruning) so benchmarks can report
both the paper's baseline and its pruning statistics.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dispatch import effective_window, elastic_cdist, elastic_pairwise
from .lb import keogh_envelope, lb_keogh
from .lb_search import filtered_topk
from .measures import MeasureArg
from .pq import PQCodebook, PQConfig, cdist_asym, cdist_sym, encode

__all__ = ["knn_classify_sym", "knn_classify_asym", "nn_dtw_exact",
           "nn_dtw_pruned"]


def knn_classify_sym(train_codes: jnp.ndarray, train_labels: jnp.ndarray,
                     Q: jnp.ndarray, cb: PQCodebook, cfg: PQConfig
                     ) -> jnp.ndarray:
    """Symmetric 1-NN: encode the queries, then M LUT gathers per pair."""
    q_codes = encode(Q, cb, cfg)
    d = cdist_sym(q_codes, train_codes, cb.lut)
    return train_labels[jnp.argmin(d, axis=1)]


def knn_classify_asym(train_codes: jnp.ndarray, train_labels: jnp.ndarray,
                      Q: jnp.ndarray, cb: PQCodebook, cfg: PQConfig
                      ) -> jnp.ndarray:
    """Asymmetric 1-NN: one fresh M x K DTW table per query, then gathers."""
    d = cdist_asym(Q, train_codes, cb, cfg)
    return train_labels[jnp.argmin(d, axis=1)]


def nn_dtw_exact(X: jnp.ndarray, labels: jnp.ndarray, Q: jnp.ndarray,
                 window: Optional[int] = None,
                 measure: MeasureArg = None) -> jnp.ndarray:
    """Exact (banded) elastic 1-NN, fully vectorized — the accuracy
    reference (DTW under the default measure)."""
    d = elastic_cdist(jnp.asarray(Q, jnp.float32),
                      jnp.asarray(X, jnp.float32), window, measure=measure)
    return labels[jnp.argmin(d, axis=1)]


def nn_dtw_pruned(X: np.ndarray, labels: np.ndarray, Q: np.ndarray,
                  window: Optional[int] = None, *,
                  budget: Optional[int] = None,
                  measure: MeasureArg = None
                  ) -> Tuple[np.ndarray, float]:
    """LB-cascade filter-and-refine NN-DTW — fully batched on device.

    Two-phase computation through :func:`repro.core.lb_search.filtered_topk`:
    bound every (query, candidate) pair with ``max(LB_Kim, LB_Keogh)``, then
    refine static ``budget``-sized ascending-bound batches through the fused
    ``dispatch.lb_refine`` kernel inside a threshold-tightening
    ``lax.while_loop`` until the verified nearest neighbors are certified
    exact.  Predictions match :func:`nn_dtw_pruned_host` (and exact NN-DTW)
    with no host-side loop or per-chunk device round-trips.  Returns
    (predictions, pruned): ``pruned`` is the fraction of (query, candidate)
    pairs the cascade excluded from exact refinement — the per-pair
    decision rate; how much *compute* that skips is backend-dependent
    (the Pallas route skips the wavefront per surviving tile).
    """
    X = jnp.asarray(X, jnp.float32)
    Q = jnp.asarray(Q, jnp.float32)
    _, idx, n_dtw = filtered_topk(Q, X, window, 1, budget=budget,
                                  measure=measure)
    preds = np.asarray(labels)[np.asarray(idx)[:, 0]]
    pruned = 1.0 - int(n_dtw) / float(Q.shape[0] * X.shape[0])
    return preds, pruned


def nn_dtw_pruned_host(X: np.ndarray, labels: np.ndarray, Q: np.ndarray,
                       window: Optional[int] = None
                       ) -> Tuple[np.ndarray, float]:
    """TEST/BENCHMARK ORACLE — not public API (excluded from the package
    re-exports; PR 4 proved it equivalent to :func:`nn_dtw_pruned`).

    Legacy host-loop LB_Keogh filter-and-refine NN-DTW, DTW-only.  Kept
    solely as the independent equivalence baseline for tests and
    ``benchmarks/lb_cascade.py``.  Per query, candidates are refined in
    ascending-LB chunks with early exit between chunks.
    """
    X = np.asarray(X, np.float32)
    Q = np.asarray(Q, np.float32)
    w = effective_window(X.shape[1], window)
    up, lo = keogh_envelope(jnp.asarray(Q), int(w))
    lbs = np.asarray(jax.vmap(lambda u, l: lb_keogh(jnp.asarray(X), u, l))(
        up, lo))                                           # (Nq, N)
    order = np.argsort(lbs, axis=1)
    preds = np.zeros(Q.shape[0], labels.dtype)
    n_dtw = 0
    for qi in range(Q.shape[0]):
        best, best_i = np.inf, 0
        # batch the refinement in chunks, early-stopping between chunks
        idx = order[qi]
        chunk = max(4, min(64, X.shape[0] // 8))
        for s in range(0, len(idx), chunk):
            cand = idx[s:s + chunk]
            # ascending-LB order: once the chunk's smallest bound reaches
            # the best verified distance, no later candidate can win
            if lbs[qi, cand[0]] >= best:
                break
            cand = cand[lbs[qi, cand] < best]
            # Pad the candidate batch to a power of two so the number of
            # distinct shapes hitting the kernel path stays O(log chunk)
            # instead of one trace/compile per survivor count.
            n_c = len(cand)
            n_pad = 1 << (n_c - 1).bit_length()
            cand_p = np.concatenate([cand, np.repeat(cand[:1], n_pad - n_c)])
            d = np.asarray(elastic_pairwise(
                jnp.broadcast_to(jnp.asarray(Q[qi]), (n_pad, Q.shape[1])),
                jnp.asarray(X[cand_p]), window))[:n_c]
            n_dtw += len(cand)
            j = int(np.argmin(d))
            if d[j] < best:
                best, best_i = float(d[j]), int(cand[j])
        preds[qi] = labels[best_i]
    pruned = 1.0 - n_dtw / float(Q.shape[0] * X.shape[0])
    return preds, pruned
