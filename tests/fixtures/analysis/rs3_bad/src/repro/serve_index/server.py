"""Seeded RS3xx violations."""

import threading

from .view import IndexView


class Server:
    _WRITER_ONLY = frozenset({"_index", "_view"})
    _WRITER_METHODS = frozenset({"_apply"})

    def __init__(self, index):
        self._index = index
        self._lock = threading.Lock()
        self._view = IndexView.capture(index)

    def _apply(self, batch):
        self._index = batch  # writer method: allowed

    def search(self, q):
        self._view = None  # RS301: writer-only field off writer thread
        view = self._view
        view.version = 9  # RS302: mutating a published view
        self._lock.acquire()  # RS303
        try:
            return view, q
        finally:
            self._lock.release()  # RS303
