#!/usr/bin/env python3
"""Static-analysis gate: run the repro.analysis rule engine and fail on
any unsuppressed, unbaselined finding.

Usage:
  python scripts/check_static.py [--root PATH] [--baseline PATH]
                                 [--write-baseline] [--list-rules]

Exit codes: 0 clean; 1 findings (new findings, stale baseline entries,
or baseline entries without a justification); 2 usage/internal error.

Findings are silenced either inline::

    x = float(d)  # repro: ignore[RS101] CLI timing, off the hot path

or by freezing them in the baseline file (``STATIC_BASELINE.json`` at
the repo root).  The baseline only ever shrinks: stale entries (debt
paid) and entries whose ``justification`` field is empty are build
errors, which is what stops the baseline growing without an explicit
written reason.  ``--write-baseline`` regenerates the file from the
current findings with empty justifications for a human to fill in.

``--root`` exists so the fixture tests can point the gate at doctored
trees; CI runs it against the repo root.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import RULES, analyze  # noqa: E402
from repro.analysis.findings import write_baseline  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=str(REPO_ROOT))
    ap.add_argument(
        "--baseline",
        default=None,
        help="baseline file (default: <root>/STATIC_BASELINE.json)",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="freeze current findings into the baseline file and exit",
    )
    ap.add_argument(
        "--list-rules",
        action="store_true",
        help="print the rule catalog and exit",
    )
    args = ap.parse_args()

    if args.list_rules:
        for rule in sorted(RULES):
            print(f"  {rule}  {RULES[rule]}")
        return 0

    root = Path(args.root).resolve()
    if not (root / "src" / "repro").is_dir():
        print(f"FAIL: no src/repro under {root}")
        return 2
    if args.baseline:
        baseline = Path(args.baseline)
    else:
        baseline = root / "STATIC_BASELINE.json"

    if args.write_baseline:
        report = analyze(root, baseline_path=None)
        write_baseline(baseline, report.findings, root)
        print(
            f"wrote {len(report.findings)} finding(s) to {baseline} "
            f"(fill in every justification field)"
        )
        return 0

    report = analyze(root, baseline_path=baseline)
    n_mod = len(report.graph.modules)
    n_fn = len(report.graph.functions)
    n_roots = len(report.graph.trace_roots())
    print(
        f"  analyzed {n_mod} modules / {n_fn} functions "
        f"({n_roots} trace roots), baselined: {len(report.baselined)}"
    )

    failed = False
    if report.findings:
        failed = True
        print(f"FAIL: {len(report.findings)} finding(s):")
        for f in report.findings:
            print(f"  {f.render(root)}")
    if report.stale_baseline:
        failed = True
        print(
            f"FAIL: {len(report.stale_baseline)} stale baseline "
            f"entr(ies) — the finding is gone, delete the entry:"
        )
        for fp in report.stale_baseline:
            print(f"  {fp}")
    if report.unjustified_baseline:
        failed = True
        print(
            f"FAIL: {len(report.unjustified_baseline)} baseline "
            f"entr(ies) with an empty justification:"
        )
        for fp in report.unjustified_baseline:
            print(f"  {fp}")
    if failed:
        print(
            "  (suppress inline with `# repro: ignore[RSxxx] <reason>` "
            "— see docs/static_analysis.md)"
        )
        return 1
    print("OK: static analysis clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
