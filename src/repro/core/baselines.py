"""Baseline distance measures the paper compares against (§5).

ED, cDTW (banded wavefront DTW), SBD (k-Shape's shape-based distance, via
FFT cross-correlation), and SAX with the classic MINDIST.  PQ_ED is obtained
from :mod:`repro.core.pq` with ``metric="euclidean"``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .dtw import dtw_cdist, euclidean_sq

__all__ = ["ed_cdist", "cdtw_cdist", "sbd_cdist", "sax_transform",
           "sax_mindist_cdist", "GAUSS_BREAKPOINTS"]


@jax.jit
def ed_cdist(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """Euclidean distance matrix (not squared, to match metric semantics)."""
    return jnp.sqrt(euclidean_sq(jnp.asarray(A, jnp.float32),
                                 jnp.asarray(B, jnp.float32)))


def cdtw_cdist(A: jnp.ndarray, B: jnp.ndarray, window: int) -> jnp.ndarray:
    """Constrained (Sakoe-Chiba) DTW distance matrix."""
    return jnp.sqrt(dtw_cdist(A, B, window))


@functools.partial(jax.jit, static_argnames=("block",))
def sbd_cdist(A: jnp.ndarray, B: jnp.ndarray, block: int = 64) -> jnp.ndarray:
    """Shape-based distance: ``1 - max_w NCCc_w(a, b)`` for all pairs.

    Cross-correlation over all shifts via zero-padded FFT; blocked over rows
    of A to bound the (block, M, F) intermediate.
    """
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    L = A.shape[1]
    F = 2 * L
    fb = jnp.fft.rfft(B, F, axis=1)                       # (M, F/2+1)
    nb = jnp.linalg.norm(B, axis=1)
    na = jnp.linalg.norm(A, axis=1)
    N = A.shape[0]
    nblk = -(-N // block)
    pad = nblk * block - N
    Ap = jnp.concatenate([A, jnp.zeros((pad, L), A.dtype)], 0)
    nap = jnp.concatenate([na, jnp.ones((pad,), na.dtype)], 0)

    def blk(_, k):
        a = jax.lax.dynamic_slice_in_dim(Ap, k * block, block)
        n_a = jax.lax.dynamic_slice_in_dim(nap, k * block, block)
        fa = jnp.fft.rfft(a, F, axis=1)
        cc = jnp.fft.irfft(fa[:, None, :] * jnp.conj(fb)[None, :, :], F, axis=2)
        denom = jnp.maximum(n_a[:, None] * nb[None, :], 1e-9)
        ncc = jnp.max(cc, axis=2) / denom
        return _, 1.0 - ncc

    _, out = jax.lax.scan(blk, 0, jnp.arange(nblk))
    return out.reshape(nblk * block, -1)[:N]


# Gaussian breakpoints for alphabet sizes 2..8 (Lin et al., SAX).
GAUSS_BREAKPOINTS = {
    2: [0.0],
    3: [-0.43, 0.43],
    4: [-0.67, 0.0, 0.67],
    5: [-0.84, -0.25, 0.25, 0.84],
    6: [-0.97, -0.43, 0.0, 0.43, 0.97],
    7: [-1.07, -0.57, -0.18, 0.18, 0.57, 1.07],
    8: [-1.15, -0.67, -0.32, 0.0, 0.32, 0.67, 1.15],
}


def sax_transform(X: np.ndarray, n_segments: int, alphabet: int = 4
                  ) -> np.ndarray:
    """Z-normalize, PAA to ``n_segments``, discretize with Gaussian breakpoints."""
    X = np.asarray(X, np.float64)
    mu = X.mean(1, keepdims=True)
    sd = X.std(1, keepdims=True)
    Xz = (X - mu) / np.maximum(sd, 1e-9)
    N, L = Xz.shape
    # PAA with possibly non-divisible L: average fractional-weight bins.
    idx = (np.arange(L) * n_segments) // L
    paa = np.zeros((N, n_segments))
    for s in range(n_segments):
        paa[:, s] = Xz[:, idx == s].mean(1)
    bp = np.array(GAUSS_BREAKPOINTS[alphabet])
    return np.searchsorted(bp, paa).astype(np.int8)


def sax_mindist_cdist(Sa: np.ndarray, Sb: np.ndarray, L: int,
                      alphabet: int = 4) -> np.ndarray:
    """MINDIST between SAX words (lower-bounds ED of z-normalized series)."""
    bp = np.array(GAUSS_BREAKPOINTS[alphabet])
    a = alphabet
    cell = np.zeros((a, a))
    for r in range(a):
        for c in range(a):
            if abs(r - c) > 1:
                cell[r, c] = bp[max(r, c) - 1] - bp[min(r, c)]
    d2 = (cell[Sa[:, None, :], Sb[None, :, :]] ** 2).sum(-1)
    n_seg = Sa.shape[1]
    return np.sqrt(L / n_seg) * np.sqrt(d2)
