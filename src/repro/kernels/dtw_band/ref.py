"""Pure-jnp oracle for the banded elastic kernels (independent of the Pallas
path — delegates to the core wavefront implementation, which is itself
validated against O(L^2) numpy DP oracles in tests/test_dtw.py and
tests/test_measures.py)."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ...core.dtw import MeasureArg, dtw_batch, dtw_cdist

__all__ = ["dtw_band_ref", "dtw_band_cdist_ref"]


def dtw_band_ref(A: jnp.ndarray, B: jnp.ndarray,
                 window: Optional[int] = None,
                 measure: MeasureArg = None) -> jnp.ndarray:
    return dtw_batch(A, B, window, measure)


def dtw_band_cdist_ref(A: jnp.ndarray, B: jnp.ndarray,
                       window: Optional[int] = None,
                       measure: MeasureArg = None) -> jnp.ndarray:
    return dtw_cdist(A, B, window, measure=measure)
