"""Model configuration — one dataclass covering every assigned family."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None       # explicit (gemma2 != d_model/heads)

    # attention flavour
    qkv_bias: bool = False               # qwen2
    rope_theta: float = 1e4
    attn_softcap: float = 0.0            # gemma2 attention-logit softcap
    final_softcap: float = 0.0           # gemma2 final-logit softcap
    sliding_window: int = 0              # gemma2 local layers
    local_global: bool = False           # gemma2 alternating pattern
    mrope: bool = False                  # qwen2-vl multimodal RoPE
    mrope_sections: Tuple[int, ...] = (16, 24, 24)

    # mlp
    act: str = "silu"                    # silu (SwiGLU) | gelu (GeGLU)

    # MoE
    n_experts: int = 0
    n_active_experts: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                    # per-expert hidden (fine-grained)

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0                  # hybrid: shared attn block period

    # encoder-decoder
    n_enc_layers: int = 0

    # modality frontend stub
    frontend: str = "none"               # none | vision | audio
    n_frontend_tokens: int = 0           # patches / audio frames per sample

    # numerics / embedding
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so TP=16 / 32-way sharding always divides."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:            # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic context handling (SSM state / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True   # all assigned archs include a decoder stack

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd = self.head_dim_
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        dense_mlp = 3 * d * f
        if self.family == "moe":
            moe = 3 * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
            moe += d * self.n_experts  # router
            block = attn + moe
        elif self.family == "ssm":
            din, N = self.d_inner, self.ssm_state
            H = self.ssm_heads
            block = d * (2 * din + 2 * N + H) + self.ssm_conv * (din + 2 * N) \
                + din * d + 2 * H
        elif self.family == "hybrid":
            din, N = self.d_inner, self.ssm_state
            H = self.ssm_heads
            block = d * (2 * din + 2 * N + H) + self.ssm_conv * (din + 2 * N) \
                + din * d + 2 * H
            n_shared = 1  # weight-tied attention block
            extra = n_shared * (attn + dense_mlp)
            return L * block + extra + self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        else:
            block = attn + dense_mlp
        total = L * block
        if self.n_enc_layers:
            total += self.n_enc_layers * (attn + dense_mlp)
            total += self.n_layers * attn  # cross-attention
        total += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-active experts)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.head_dim_
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        moe_active = 3 * d * self.moe_d_ff * (self.n_active_experts +
                                              self.n_shared_experts)
        total = L * (attn + moe_active + d * self.n_experts)
        total += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return total
