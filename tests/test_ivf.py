"""IVF-PQDTW: recall vs exhaustive search, candidate-slot correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ivf import build_index, search, search_batch
from repro.core.pq import PQConfig, cdist_asym
from repro.data.timeseries import cbf


@pytest.fixture(scope="module")
def setup():
    X, y = cbf(n_per_class=20, length=64, seed=0)
    Q, _ = cbf(n_per_class=4, length=64, seed=9)
    cfg = PQConfig(n_sub=4, codebook_size=16, use_prealign=False,
                   kmeans_iters=3, dba_iters=1)
    index = build_index(jax.random.PRNGKey(0), jnp.asarray(X), cfg,
                        n_lists=6, coarse_iters=4)
    return X, Q, cfg, index


class TestIndexStructure:
    def test_lists_partition_the_database(self, setup):
        X, _, _, index = setup
        ids = np.sort(np.asarray(index.ids))
        np.testing.assert_array_equal(ids, np.arange(len(X)))
        assert int(index.list_len.sum()) == len(X)
        # starts consistent with lengths
        start = np.asarray(index.list_start)
        length = np.asarray(index.list_len)
        for i in range(1, len(start)):
            assert start[i] == start[i - 1] + length[i - 1]

    def test_full_probe_equals_exhaustive_pq(self, setup):
        """Probing every list must reproduce exhaustive asymmetric PQDTW."""
        X, Q, cfg, index = setup
        d_ex = np.asarray(cdist_asym(jnp.asarray(Q), index.codes, index.cb,
                                     cfg))
        ids_ex = np.asarray(index.ids)[d_ex.argmin(1)]
        d, ids = search_batch(index, jnp.asarray(Q), cfg,
                              n_probe=index.n_lists, topk=1)
        np.testing.assert_array_equal(np.asarray(ids)[:, 0], ids_ex)
        np.testing.assert_allclose(np.asarray(d)[:, 0], d_ex.min(1),
                                   rtol=1e-5, atol=1e-5)


class TestRecall:
    def test_recall_monotone_in_probes(self, setup):
        X, Q, cfg, index = setup
        d_ex = np.asarray(cdist_asym(jnp.asarray(Q), index.codes, index.cb,
                                     cfg))
        truth = np.asarray(index.ids)[d_ex.argmin(1)]
        recalls = []
        for p in (1, 3, index.n_lists):
            _, ids = search_batch(index, jnp.asarray(Q), cfg,
                                  n_probe=p, topk=1)
            recalls.append(float((np.asarray(ids)[:, 0] == truth).mean()))
        assert recalls[-1] == 1.0
        assert recalls[0] <= recalls[1] + 1e-9 <= recalls[2] + 2e-9
        assert recalls[1] >= 0.5      # CBF clusters are easy: few probes win

    def test_topk_sorted(self, setup):
        _, Q, cfg, index = setup
        d, ids = search(index, jnp.asarray(Q[0]), cfg, n_probe=3, topk=5)
        dd = np.asarray(d)
        assert (np.diff(dd) >= -1e-6).all()
        assert len(np.unique(np.asarray(ids))) == 5
