"""Fig 5a — empirical time complexity: pairwise-distance-matrix runtime of
PQDTW vs exact DTW on random walks, sweeping series length and collection
size.

The paper reports PQDTW 2.9x (length 100) to 5.6x (length 3200) faster for
100 series, growing to 45.8x for 800 series (costs amortize).  We reproduce
the same protocol at CPU-budget sizes; the headline number is the speedup of
the *distance-matrix phase* (the paper's Fig 5a y-axis), with the one-time
train+encode cost reported separately (amortized in the N-scaling column,
exactly as in the paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dtw import dtw_cdist
from repro.core.pq import PQConfig, cdist_sym, encode, fit
from repro.data.timeseries import random_walks

from .common import Bench, timeit


def run(quick: bool = True) -> Bench:
    b = Bench("fig5a_scaling")
    lengths = (64, 128, 256) if quick else (128, 256, 512, 1024)
    counts = (40, 80) if quick else (100, 200, 400)
    key = jax.random.PRNGKey(0)

    for D in lengths:
        for N in counts:
            X = jnp.asarray(random_walks(N, D, seed=0))
            cfg = PQConfig(n_sub=max(2, round(1 / 0.2)), codebook_size=min(64, N),
                           use_prealign=False, kmeans_iters=4, dba_iters=1)
            window = cfg.window(D)

            t0 = timeit(lambda: dtw_cdist(X, X, window), repeats=2)
            import time as _t
            t1 = _t.perf_counter()
            cb = fit(key, X, cfg)
            codes = encode(X, cb, cfg)
            jax.block_until_ready(codes)
            train_s = _t.perf_counter() - t1
            t2 = timeit(lambda: cdist_sym(codes, codes, cb.lut), repeats=3)

            b.add(length=D, n_series=N,
                  dtw_s=t0["median_s"], pqdtw_s=t2["median_s"],
                  pq_train_encode_s=train_s,
                  speedup=t0["median_s"] / max(t2["median_s"], 1e-9),
                  speedup_amortized=t0["median_s"]
                  / max(t2["median_s"] + train_s / max(N, 1), 1e-9))
    b.save()
    return b


if __name__ == "__main__":
    run(quick=False)
