"""Public entry point for the goodk kernel."""

from jax.experimental import pallas as pl

from .kernel import goodk_kernel


def run_goodk(x):
    return pl.pallas_call(goodk_kernel, out_shape=x)(x)
