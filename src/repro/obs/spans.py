"""Pipeline stage spans and the obs on/off switch.

``span("stage")`` times a host-visible pipeline stage into the
``stage_seconds`` histogram of :data:`repro.obs.registry.REGISTRY`
(labeled ``stage=<name>``), and bridges into device profiles through
``jax.profiler.TraceAnnotation`` so the same stage names show up on the
device timeline when a profiler trace is active.

Zero-overhead-by-default is the load-bearing contract (the reason the
spans are safe to leave wired into every layer of the search/ingest
pipeline):

* disabled (the default — enable with ``REPRO_OBS=1`` or
  :func:`enable`), ``span()`` returns a shared no-op context manager:
  no clock reads, no histogram writes, no ``TraceAnnotation``, and —
  critically — :meth:`Span.fence` NEVER calls ``block_until_ready``,
  so no device sync the un-instrumented code would not have done;
* enabled, :meth:`Span.fence` blocks on its argument (skipping tracers:
  fencing inside a traced computation is a no-op by construction), so
  async-dispatched device work is attributed to the span that launched
  it instead of leaking into whichever stage happens to block next.

Spans nest and re-enter freely: each ``with`` entry pushes onto a
thread-local stack and records its own sample on exit, exceptions
included.  A span opened inside a traced function (e.g. under
``shard_map``) times the *trace*, which runs once per cache entry — real
per-call device time needs the span outside the traced region plus a
fence, which is exactly how the index/planner call sites are written.
"""

from __future__ import annotations

import os
import threading
import time
from typing import List, Optional

import jax

from .registry import REGISTRY

__all__ = ["ENV_VAR", "enabled", "enable", "disable", "override", "span",
           "current_spans", "fence", "Span"]

ENV_VAR = "REPRO_OBS"

_enabled = os.environ.get(ENV_VAR, "0").lower() not in ("", "0", "false")

_local = threading.local()

# test seam: monkeypatch to observe/forbid device syncs
_block = jax.block_until_ready


def enabled() -> bool:
    return _enabled


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


class override:
    """Scoped enable/disable (tests)."""

    def __init__(self, on: bool):
        self.on = bool(on)
        self._prev: Optional[bool] = None

    def __enter__(self):
        global _enabled
        self._prev = _enabled
        _enabled = self.on
        return self

    def __exit__(self, *exc):
        global _enabled
        _enabled = self._prev
        return False


def _stack() -> List[str]:
    st = getattr(_local, "stack", None)
    if st is None:
        st = _local.stack = []
    return st


def current_spans() -> tuple:
    """Names of the spans currently open on this thread, outermost first."""
    return tuple(_stack())


def _is_traced(x) -> bool:
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree_util.tree_leaves(x))


def fence(x):
    """``jax.block_until_ready(x)`` when obs is enabled; identity (and in
    particular no device sync) when disabled or ``x`` contains tracers."""
    if _enabled and not _is_traced(x):
        return _block(x)
    return x


class Span:
    """One timed stage entry (enabled path — see :func:`span`)."""

    __slots__ = ("name", "_t0", "_annotation")

    def __init__(self, name: str):
        self.name = name
        self._t0 = 0.0
        self._annotation = None

    def __enter__(self):
        _stack().append(self.name)
        self._annotation = jax.profiler.TraceAnnotation(self.name)
        self._annotation.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        dt = time.perf_counter() - self._t0
        self._annotation.__exit__(exc_type, exc, tb)
        st = _stack()
        if st and st[-1] == self.name:
            st.pop()
        REGISTRY.histogram("stage_seconds", persistent=True,
                           stage=self.name).record(dt)
        return False

    def fence(self, x):
        """Block on ``x`` so its device work lands in this span (no-op on
        tracers); returns ``x`` for inline use."""
        return fence(x)


class _NullSpan:
    """Disabled path: one shared immutable no-op for every span() call."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    @staticmethod
    def fence(x):
        return x


_NULL_SPAN = _NullSpan()


def span(name: str):
    """Context manager timing stage ``name`` (module docstring)."""
    if not _enabled:
        return _NULL_SPAN
    return Span(name)
