"""Docs staleness gate: scripts/check_docs.py passes on the real tree and
fails on a doctored tree whose docs reference removed identifiers."""

import os
import shutil
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHECK = os.path.join(REPO, "scripts", "check_docs.py")


def _run(root):
    return subprocess.run(
        [sys.executable, CHECK, "--root", str(root)],
        capture_output=True, text=True)


def test_repo_docs_are_clean():
    proc = _run(REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def _doctored_tree(tmp_path):
    """Minimal tree: the real dispatch/snapshot sources + one doc."""
    for rel in ("src/repro/core/dispatch.py", "src/repro/index/snapshot.py"):
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(os.path.join(REPO, rel), dst)
    (tmp_path / "docs").mkdir()
    return tmp_path


def test_gate_fails_on_removed_dispatch_op(tmp_path):
    root = _doctored_tree(tmp_path)
    (root / "docs" / "api.md").write_text(
        "Call `dispatch.totally_fake_op` for speed.\n")
    proc = _run(root)
    assert proc.returncode == 1
    assert "totally_fake_op" in proc.stdout


def test_gate_fails_on_unknown_stage(tmp_path):
    root = _doctored_tree(tmp_path)
    (root / "docs" / "ops.md").write_text(
        "Watch the `serving.retired_stage` span.\n")
    proc = _run(root)
    assert proc.returncode == 1
    assert "serving.retired_stage" in proc.stdout


def test_gate_fails_on_unknown_metric(tmp_path):
    root = _doctored_tree(tmp_path)
    (root / "docs" / "metrics.md").write_text(
        "Alert on `repro_imaginary_counter_total`.\n")
    proc = _run(root)
    assert proc.returncode == 1
    assert "imaginary_counter_total" in proc.stdout


def test_gate_fails_on_bad_snapshot_format(tmp_path):
    root = _doctored_tree(tmp_path)
    (root / "docs" / "persist.md").write_text(
        "Data persists in snapshot format 99.\n")
    proc = _run(root)
    assert proc.returncode == 1
    assert "format 99" in proc.stdout


def test_gate_fails_on_removed_cli_flag(tmp_path):
    root = _doctored_tree(tmp_path)
    (root / "scripts").mkdir(exist_ok=True)
    (root / "scripts" / "tool.py").write_text(
        'import argparse\nap = argparse.ArgumentParser()\n'
        'ap.add_argument("--real-flag")\n')
    (root / "docs" / "cli.md").write_text(
        "Run `python scripts/tool.py --vanished-flag`.\n")
    proc = _run(root)
    assert proc.returncode == 1
    assert "--vanished-flag" in proc.stdout


def test_gate_accepts_valid_references(tmp_path):
    root = _doctored_tree(tmp_path)
    (root / "docs" / "good.md").write_text(
        "Use `dispatch.elastic_cdist`; snapshots use format 3.\n")
    proc = _run(root)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_gate_fails_on_unknown_analysis_rule(tmp_path):
    root = _doctored_tree(tmp_path)
    eng = root / "src" / "repro" / "analysis" / "engine.py"
    eng.parent.mkdir(parents=True, exist_ok=True)
    eng.write_text('RULES = {"RS101": "host sync"}\n')
    (root / "docs" / "rules.md").write_text(
        "RS101 is real but rule RS999 was retired.\n")
    proc = _run(root)
    assert proc.returncode == 1
    assert "RS999" in proc.stdout
    assert "RS101" not in proc.stdout
