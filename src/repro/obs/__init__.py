"""Unified observability layer: metrics registry, stage spans, exporters.

One import surface for every instrumented layer::

    from repro import obs

    with obs.span("index.search.coarse") as sp:
        dc = sp.fence(coarse_dists(...))     # device work lands in the span

    obs.counter("lb_refined_total").inc(int(n_refined))
    obs.gauge("hot_occupancy").set(fill / capacity)
    print(obs.to_prometheus())

Disabled by default (``REPRO_OBS=1`` or :func:`enable` turns it on):
metric *writes* stay cheap host-side dict/list operations either way, and
the disabled path is strictly zero device overhead — no spans, no fences,
no ``block_until_ready`` — so search results are bit-identical with obs
on or off and the instrumentation is safe to keep in every hot path.
``REPRO_OBS_DUMP=<path>`` writes a JSON snapshot at process exit;
``scripts/obs_report.py`` renders one as a console report.

The dispatch routing ledgers (:data:`repro.core.dispatch.stats` /
``totals``) are mirrored into the registry as ``dispatch_total`` counters
labeled ``kind="trace"`` — a reminder that they count *traces*, not
executions (a jitted caller hitting its cache does not re-count), unlike
the run-time ``stage_seconds`` spans which time every call.
"""

from .export import (DUMP_ENV_VAR, PROM_PREFIX, snapshot, to_json,
                     to_prometheus, write_snapshot)
from .registry import (DEFAULT_LATENCY_BUCKETS, MAX_SAMPLES, REGISTRY,
                       Counter, Gauge, Histogram, Registry, exp_buckets,
                       percentile)
from .report import (check_stages, counter_value, missing_stages, render,
                     stage_rows)
from .spans import (ENV_VAR, Span, current_spans, disable, enable, enabled,
                    fence, override, span)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
    "exp_buckets", "percentile", "DEFAULT_LATENCY_BUCKETS", "MAX_SAMPLES",
    "ENV_VAR", "DUMP_ENV_VAR", "PROM_PREFIX",
    "enabled", "enable", "disable", "override",
    "span", "Span", "fence", "current_spans",
    "counter", "gauge", "histogram", "reset",
    "snapshot", "to_json", "to_prometheus", "write_snapshot",
    "render", "stage_rows", "counter_value", "missing_stages",
    "check_stages",
]


def counter(name: str, persistent: bool = False, **labels: str) -> Counter:
    """Get-or-create a counter in the process-wide registry.

    Same ``(name, labels)`` always returns the same object, so call sites
    never cache handles:

    >>> from repro import obs
    >>> obs.counter("doc_requests_total", route="a").inc()
    >>> obs.counter("doc_requests_total", route="a").inc(2)
    >>> obs.counter("doc_requests_total", route="a").value
    3
    >>> obs.reset()
    """
    return REGISTRY.counter(name, persistent=persistent, **labels)


def gauge(name: str, persistent: bool = False, **labels: str) -> Gauge:
    """Get-or-create a gauge in the process-wide registry.

    >>> from repro import obs
    >>> obs.gauge("doc_queue_depth").set(7)
    >>> int(obs.gauge("doc_queue_depth").value)
    7
    >>> obs.reset()
    """
    return REGISTRY.gauge(name, persistent=persistent, **labels)


def histogram(name: str, buckets=None, persistent: bool = False,
              **labels: str) -> Histogram:
    """Get-or-create a histogram in the process-wide registry.

    Default bounds are the exponential latency ladder
    (:data:`DEFAULT_LATENCY_BUCKETS`); percentiles are exact over the
    recorded samples:

    >>> from repro import obs
    >>> h = obs.histogram("doc_wait_seconds")
    >>> for v in (0.010, 0.020, 0.030):
    ...     h.record(v)
    >>> h.count
    3
    >>> round(h.percentile(50.0), 3)
    0.02
    >>> obs.reset()
    """
    return REGISTRY.histogram(name, buckets=buckets, persistent=persistent,
                              **labels)


def reset(include_persistent: bool = False) -> None:
    """Reset the process-wide registry (scratch metrics only by default —
    dispatch routing counters and stage spans are persistent).

    >>> from repro import obs
    >>> obs.counter("doc_scratch_total").inc()
    >>> obs.counter("doc_survivor_total", persistent=True).inc()
    >>> obs.reset()
    >>> obs.counter("doc_scratch_total").value       # re-created fresh
    0
    >>> obs.counter("doc_survivor_total", persistent=True).value
    1

    ``include_persistent=True`` wipes everything — on the *process-wide*
    registry that erases the dispatch routing evidence CI's gate reads,
    so the full wipe is demonstrated on a private registry:

    >>> reg = obs.Registry()
    >>> reg.counter("doc_all_total", persistent=True).inc()
    >>> reg.reset(include_persistent=True)
    >>> reg.counter("doc_all_total", persistent=True).value
    0
    """
    REGISTRY.reset(include_persistent=include_persistent)
