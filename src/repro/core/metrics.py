"""Evaluation metrics: Rand index, adjusted Rand index, error rate."""

from __future__ import annotations

import numpy as np

__all__ = ["rand_index", "adjusted_rand_index", "error_rate"]


def _contingency(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    ua, ia = np.unique(a, return_inverse=True)
    ub, ib = np.unique(b, return_inverse=True)
    c = np.zeros((len(ua), len(ub)), np.int64)
    np.add.at(c, (ia, ib), 1)
    return c


def rand_index(labels_true: np.ndarray, labels_pred: np.ndarray) -> float:
    """Rand (1971) index: fraction of concordant pairs."""
    c = _contingency(labels_true, labels_pred)
    n = c.sum()
    sum_sq = (c.astype(np.float64) ** 2).sum()
    sum_a = (c.sum(1).astype(np.float64) ** 2).sum()
    sum_b = (c.sum(0).astype(np.float64) ** 2).sum()
    agreements = n * (n - 1) / 2 + sum_sq - 0.5 * (sum_a + sum_b)
    return float(agreements / (n * (n - 1) / 2))


def adjusted_rand_index(labels_true: np.ndarray,
                        labels_pred: np.ndarray) -> float:
    c = _contingency(labels_true, labels_pred).astype(np.float64)
    n = c.sum()
    comb = lambda x: x * (x - 1) / 2.0
    sum_ij = comb(c).sum()
    sum_a = comb(c.sum(1)).sum()
    sum_b = comb(c.sum(0)).sum()
    expected = sum_a * sum_b / comb(n)
    max_idx = 0.5 * (sum_a + sum_b)
    if max_idx == expected:
        return 1.0
    return float((sum_ij - expected) / (max_idx - expected))


def error_rate(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    return float(np.mean(np.asarray(y_true) != np.asarray(y_pred)))
