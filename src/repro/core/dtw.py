"""Elastic alignment distances in JAX — anti-diagonal wavefront formulation.

The classic DP recurrence (DTW shown; every registered measure shares the
shape, only the per-move costs differ — see :mod:`repro.core.measures`)

    T[i, j] = min(T[i-1, j-1] + diag_cost,
                  T[i-1, j  ] + vert_cost,
                  T[i,   j-1] + horiz_cost)

has a row-wise prefix dependency, which serializes on vector hardware.  We
instead sweep the DP table anti-diagonal by anti-diagonal: every cell on
diagonal ``d = i + j`` depends only on diagonals ``d-1`` and ``d-2``, so each
diagonal is one vector operation (VPU lanes = cells) and a length-``2L-1``
``lax.scan`` carries two diagonal registers.  A Sakoe-Chiba band ``|i-j| <= w``
is a static mask, keeping every shape fixed.

The measure spec is a *static* argument: its per-move cost step is inlined
at trace time, so DTW (the default) compiles to exactly the pre-registry
graph, while ERP additionally threads its virtual first row/column (prefix
sums of gap costs) through the same sweep.

DTW/WDTW distances are *squared* costs (the paper aggregates squared
subspace distances); ERP/MSM use absolute differences — the norm under
which they are metrics.  Take ``jnp.sqrt`` of DTW costs at the end if a
metric-scaled value is needed.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import measures
from .measures import MeasureArg

__all__ = [
    "dtw",
    "dtw_pair",
    "dtw_batch",
    "dtw_cdist",
    "dtw_full_table",
    "euclidean_sq",
]

_INF = jnp.float32(jnp.inf)


def _diag_sweep(a: jnp.ndarray, b: jnp.ndarray, window: Optional[int],
                return_table: bool, measure: MeasureArg = None):
    """Shared wavefront sweep.  ``a``/``b`` are rank-1, equal length L.

    Returns the final elastic cost under ``measure`` (default DTW), and
    (optionally) the full stack of diagonals ``(2L-1, L)`` where
    ``table[d, i] == T[i, d-i]`` — used by the DBA backtracking pass
    (DTW only).
    """
    spec = measures.resolve(measure)
    L = a.shape[0]
    w = L if window is None else int(window)
    idx = jnp.arange(L)

    # b gathered along a diagonal: cell (i, d-i) needs b[d - i].
    # Pad b so that out-of-range gathers read masked positions.
    b_pad = jnp.concatenate([b, jnp.zeros((L,), b.dtype)])
    # a_{i-1} with a sentinel at i = 0 (never used: the vertical move into
    # row 0 reads an inf / border predecessor there)
    xp = jnp.concatenate([a[:1], a[:-1]]) if spec.uses_neighbors else None

    if spec.uses_gap_border:
        # virtual first column/row: T[i, -1] = ga[i], T[-1, j] = gb[j]
        ga = jnp.cumsum(measures.gap_costs(spec, a))
        gb = jnp.cumsum(measures.gap_costs(spec, b))
        ga_prev = jnp.concatenate([jnp.zeros((1,), ga.dtype), ga[:-1]])
        gb_prev = jnp.concatenate([jnp.zeros((1,), gb.dtype), gb[:-1]])
        gb_pad = jnp.concatenate([gb, jnp.zeros((L,), gb.dtype)])
        gb_prev_pad = jnp.concatenate([gb_prev, jnp.zeros((L,), gb.dtype)])

    def step(carry, d):
        prev1, prev2 = carry  # diagonals d-1 and d-2, indexed by i
        j = d - idx
        jc = jnp.clip(j, 0, 2 * L - 1)
        valid = (j >= 0) & (j < L) & (jnp.abs(idx - j) <= w)
        y = b_pad[jc]
        yp = b_pad[jnp.clip(j - 1, 0, 2 * L - 1)] if spec.uses_neighbors \
            else None
        dd = jnp.abs(idx - j) if spec.uses_position else None
        c_d, c_v, c_h = measures.move_costs(spec, a, y, xp, yp, dd, L)

        # Predecessors (indexed by i on their own diagonals):
        #   T[i-1, j-1] -> prev2 shifted down by one in i   (diag)
        #   T[i-1, j  ] -> prev1 shifted down by one in i   (vert)
        #   T[i,   j-1] -> prev1 at i                       (horiz)
        pred_v = jnp.concatenate([jnp.full((1,), _INF), prev1[:-1]])
        pred_d = jnp.concatenate([jnp.full((1,), _INF), prev2[:-1]])
        pred_h = prev1
        is_i0 = idx == 0
        is_j0 = j == 0
        if spec.uses_gap_border:
            pred_d = jnp.where(is_i0, gb_prev_pad[jc],
                               jnp.where(is_j0, ga_prev[idx], pred_d))
            pred_d = jnp.where(is_i0 & is_j0, 0.0, pred_d)
            pred_v = jnp.where(is_i0, gb_pad[jc], pred_v)
            pred_h = jnp.where(is_j0, ga[idx], pred_h)
        else:
            # Base case: cell (0, 0) starts from 0 via the diagonal move.
            pred_d = jnp.where(is_i0 & is_j0, 0.0, pred_d)
        if c_v is c_d and c_h is c_d:   # shared-cost family (DTW, WDTW)
            cell = c_d + jnp.minimum(jnp.minimum(pred_d, pred_h), pred_v)
        else:
            cell = jnp.minimum(jnp.minimum(pred_d + c_d, pred_v + c_v),
                               pred_h + c_h)
        diag = jnp.where(valid, cell, _INF)
        out = diag if return_table else None
        return (diag, prev1), out

    init = (jnp.full((L,), _INF), jnp.full((L,), _INF))
    (last, _), table = jax.lax.scan(step, init, jnp.arange(2 * L - 1))
    final = last[L - 1]
    return final, table


def dtw_pair(a: jnp.ndarray, b: jnp.ndarray,
             window: Optional[int] = None,
             measure: MeasureArg = None) -> jnp.ndarray:
    """Elastic cost between two equal-length 1-D series (squared DTW by
    default; any registered measure via ``measure``)."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    final, _ = _diag_sweep(a, b, window, return_table=False, measure=measure)
    return final


# Public alias used across the library.
dtw = dtw_pair


def dtw_full_table(a: jnp.ndarray, b: jnp.ndarray,
                   window: Optional[int] = None) -> jnp.ndarray:
    """Full DP table in diagonal layout: ``table[i + j, i] == dtw[i, j]``.

    Used by DBA to backtrack the optimal alignment path.  DTW only: DBA's
    move semantics (every move is a match) do not transfer to gap/edit
    measures.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    _, table = _diag_sweep(a, b, window, return_table=True)
    return table


@functools.partial(jax.jit, static_argnames=("window", "measure"))
def dtw_batch(A: jnp.ndarray, B: jnp.ndarray,
              window: Optional[int] = None,
              measure: MeasureArg = None) -> jnp.ndarray:
    """Pairwise elastic cost over zipped batches: ``A (N, L)``, ``B (N, L)``."""
    spec = measures.resolve(measure)
    return jax.vmap(lambda a, b: dtw_pair(a, b, window, spec))(A, B)


@functools.partial(jax.jit, static_argnames=("window", "block", "measure"))
def dtw_cdist(A: jnp.ndarray, B: jnp.ndarray,
              window: Optional[int] = None, block: int = 4096,
              measure: MeasureArg = None) -> jnp.ndarray:
    """All-pairs elastic cost: ``A (N, L)``, ``B (M, L)`` -> ``(N, M)``.

    Flattens the cross-product and sweeps it in fixed-size blocks; the pair
    indices are derived arithmetically (``idx // M``, ``idx % M``) inside
    each block, so peak memory is bounded by ``block`` — nothing of size
    N*M is ever materialized.
    """
    spec = measures.resolve(measure)
    N, L = A.shape
    M = B.shape[0]
    total = N * M
    nblk = -(-total // block)

    def blk(carry, k):
        idx = jnp.minimum(k * block + jnp.arange(block), total - 1)
        aa = A[idx // M]
        bb = B[idx % M]
        d = jax.vmap(lambda x, y: dtw_pair(x, y, window, spec))(aa, bb)
        return carry, d

    _, out = jax.lax.scan(blk, 0, jnp.arange(nblk))
    return out.reshape(-1)[:total].reshape(N, M)


def euclidean_sq(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """All-pairs squared Euclidean distance (lock-step baseline)."""
    a2 = jnp.sum(A * A, -1)[:, None]
    b2 = jnp.sum(B * B, -1)[None, :]
    return jnp.maximum(a2 + b2 - 2.0 * A @ B.T, 0.0)
