"""Benchmark entry point: one module per paper table/figure + the roofline
aggregation.  ``python -m benchmarks.run [--full] [--only NAME]``."""

from __future__ import annotations

import argparse
import time

from . import (common, dtw_kernel_bench, fig5a_scaling, fig5b_params,
               fig5c_prealign, index_scaling, ivf_scaling, lb_cascade,
               memory_cost, pqkv_bench, roofline, serving_qps,
               table1_accuracy)

SUITES = {
    "dtw_kernel": dtw_kernel_bench.run,
    "fig5a": fig5a_scaling.run,
    "fig5b": fig5b_params.run,
    "fig5c": fig5c_prealign.run,
    "table1": table1_accuracy.run,
    "memory": memory_cost.run,
    "ivf": ivf_scaling.run,
    "index": index_scaling.run,
    "lb_cascade": lb_cascade.run,
    "pqkv": pqkv_bench.run,
    "serving": serving_qps.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: quick sizes (further shrunk where a "
                         "suite supports it), 1 repetition per point")
    ap.add_argument("--only", choices=tuple(SUITES), default=None)
    ap.add_argument("--measure", default=None,
                    help="elastic measure for the measure-aware suites "
                         "(lb_cascade, ivf, index): a registry name or "
                         "'name:param=value', e.g. msm or erp:g=0.5")
    ap.add_argument("--device", choices=("tpu", "gpu"), default=None,
                    help="opt-in real-hardware leg: verify JAX actually "
                         "runs on this backend and record results as "
                         "experiments/bench/hw_<device>_*.json; the "
                         "committed BENCH_* summaries (CPU/interpret "
                         "baselines) are never touched")
    args = ap.parse_args()
    if args.smoke and args.full:
        ap.error("--smoke and --full are mutually exclusive")
    if args.smoke:
        common.set_smoke(True)
    if args.device:
        common.set_device(args.device)
    if args.measure:
        from repro.core import measures as _measures
        _measures.resolve(args.measure)   # fail fast on unknown names
        common.set_measure(args.measure)

    names = (args.only,) if args.only else tuple(SUITES)
    for name in names:
        print(f"== {name} ==", flush=True)
        t0 = time.time()
        SUITES[name](quick=not args.full)
        print(f"== {name} done in {time.time() - t0:.1f}s ==\n", flush=True)


if __name__ == "__main__":
    main()
