"""Clean equivalents of the rs1_bad tree: zero findings expected."""

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("k",))
def topk(x, k=4):
    d = helper(x)
    d = jnp.where(jnp.any(d > 0), -d, d)
    return jnp.sort(d)[:k]


def helper(x):
    return x - jnp.min(x)


@functools.partial(jax.jit, static_argnames=("opts",))
def scale(x, opts=()):
    return x * len(opts)


def memo(x):
    return x


def memo_root(x):
    return jax.jit(memo)(x)


def offline(x):
    return np.asarray(x)
