"""A reasoned suppression silences its finding: zero findings."""

import jax


def pull(x):
    return jax.device_get(x)  # repro: ignore[RS101] export path, documented
