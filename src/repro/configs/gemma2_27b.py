"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    act="gelu",
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    local_global=True,
    tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG, name="gemma2-27b-reduced", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16, sliding_window=32)
