"""Filter-and-refine NN-DTW: batched LB-cascade vs the legacy host loop.

Measures the rewrite of ``nn_dtw_pruned`` — one device-resident two-phase
computation (bound all pairs, ``lax.while_loop`` threshold-tightening
refines through the fused ``dispatch.lb_refine`` kernel) — against the
superseded per-query host loop (``nn_dtw_pruned_host``: ascending-LB
chunks with a device round-trip per chunk).  Both are exact, so the
predictions must agree; the interesting numbers are wall clock and each
variant's pruning fraction (the rate of (query, candidate) pairs the
cascade excluded from exact refinement — a per-pair decision count, not
a direct measure of compute skipped).
"""

from __future__ import annotations

import numpy as np

from repro.core.knn import nn_dtw_pruned, nn_dtw_pruned_host

from . import common
from .common import Bench, timeit


def _random_walks(n: int, length: int, seed: int) -> np.ndarray:
    """Random walks: realistically autocorrelated, so the Keogh envelopes
    are tight enough for the cascade to prune (white noise would not be)."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.standard_normal((n, length)), axis=1).astype(
        np.float32)


def run(quick: bool = True) -> None:
    bench = Bench("lb_cascade")
    # (N database series, L length, Nq queries); the (2048, 256) points are
    # the acceptance size for the batched rewrite — the host loop scales
    # linearly in Nq while the batched search amortizes its bound phase,
    # so both a small and a serving-sized query batch are reported.
    sizes = [(512, 128, 8), (2048, 256, 16), (2048, 256, 64)]
    if common.SMOKE:
        sizes = [(256, 64, 4)]
    elif not quick:
        sizes.append((8192, 256, 16))
    measure = common.MEASURE
    for n, length, n_q in sizes:
        X = _random_walks(n, length, 0)
        Q = _random_walks(n_q, length, 1)
        labels = np.arange(n) % 8
        window = max(1, length // 10)
        preds_new, pruned_new = nn_dtw_pruned(X, labels, Q, window,
                                              measure=measure)
        run_new = lambda: nn_dtw_pruned(X, labels, Q, window,
                                        measure=measure)
        t_new = timeit(run_new)
        row = dict(N=n, L=length, Nq=n_q, window=window, measure=measure,
                   batched_s=t_new["median_s"], pruned_batched=pruned_new)
        if measure == "dtw":
            # the legacy host loop is the DTW-only equivalence baseline
            preds_old, pruned_old = nn_dtw_pruned_host(X, labels, Q, window)
            t_old = timeit(nn_dtw_pruned_host, X, labels, Q, window)
            row.update(host_s=t_old["median_s"],
                       speedup=t_old["median_s"] / t_new["median_s"],
                       pruned_host=pruned_old,
                       preds_equal=bool((preds_new == preds_old).all()))
        bench.add(**row)
    bench.save(headline={"measure": measure})


if __name__ == "__main__":
    run()
