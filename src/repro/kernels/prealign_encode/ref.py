"""Pure-JAX oracle for the fused prealign+encode kernel.

Definitionally the two-step path the kernel fuses: ``modwt.prealign``
segmentation followed by an exact DTW-1NN scan of every subspace codebook
(the ``exact_encode`` route of ``pq.encode``, without the HBM round-trip
removed by the kernel).  Used as the ``"jax"`` dispatch backend and as the
equality reference in tests.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.dtw import dtw_cdist
from ...core.measures import MeasureArg
from ...core.modwt import prealign

__all__ = ["prealign_encode_ref", "check_geometry"]


def check_geometry(D: int, centroids: jnp.ndarray, tail: int) -> None:
    """Clear error when series length / codebook / tail disagree — instead
    of an opaque shape mismatch deep inside the segment interpolation."""
    M, _, S = centroids.shape
    want = D // M + tail
    if S != want:
        raise ValueError(
            f"prealign geometry mismatch: centroids have subseq_len={S} but "
            f"series of length {D} with n_sub={M}, tail={tail} produce "
            f"segments of length {want}")


@functools.partial(jax.jit, static_argnames=("level", "tail", "window",
                                             "measure"))
def prealign_encode_ref(X: jnp.ndarray, centroids: jnp.ndarray, level: int,
                        tail: int, window: Optional[int] = None,
                        measure: MeasureArg = None
                        ) -> jnp.ndarray:
    """``X (N, D)``, ``centroids (M, K, S)`` -> codes ``(N, M)`` int32."""
    X = jnp.asarray(X, jnp.float32)
    centroids = jnp.asarray(centroids, jnp.float32)
    check_geometry(X.shape[-1], centroids, tail)
    M = centroids.shape[0]
    segs = prealign(X, M, level, tail)               # (N, M, S)
    d = jnp.stack([dtw_cdist(segs[:, m], centroids[m], window,
                             measure=measure)
                   for m in range(M)], axis=1)       # (N, M, K)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)
