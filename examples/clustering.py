"""Hierarchical clustering with PQDTW distances (paper §4.2).

    PYTHONPATH=src python examples/clustering.py

Builds the pairwise matrix three ways — exact DTW, plain symmetric PQDTW,
and the §4.2 LB-refined symmetric PQDTW (identical codes replaced by the
Keogh lower bound so rankings stay informative) — and compares Rand indices
of the complete-linkage clustering.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cluster import hierarchical_labels
from repro.core.dtw import dtw_cdist
from repro.core.metrics import adjusted_rand_index, rand_index
from repro.core.pq import (PQConfig, cdist_sym, cdist_sym_refined, encode,
                           fit, segment)
from repro.data.timeseries import cbf


def main():
    X, y = cbf(n_per_class=15, length=128, seed=3)
    Xj = jnp.asarray(X)
    k = len(np.unique(y))
    window = int(0.1 * X.shape[1])
    print(f"{X.shape[0]} series, {k} classes")

    cfg = PQConfig(n_sub=4, codebook_size=16, use_prealign=True,
                   kmeans_iters=5)
    cb = fit(jax.random.PRNGKey(0), Xj, cfg)
    codes = encode(Xj, cb, cfg)
    segs = segment(Xj, cfg)

    t0 = time.time()
    d_exact = np.sqrt(np.asarray(dtw_cdist(Xj, Xj, window)))
    t_exact = time.time() - t0

    t0 = time.time()
    d_sym = np.asarray(cdist_sym(codes, codes, cb.lut))
    t_sym = time.time() - t0

    t0 = time.time()
    d_ref = np.asarray(cdist_sym_refined(codes, segs, codes, segs, cb))
    t_ref = time.time() - t0

    print(f"\n{'distance':24s} {'RI':>7s} {'ARI':>7s} {'seconds':>8s}")
    for name, d, sec in (("exact DTW", d_exact, t_exact),
                         ("PQDTW symmetric", d_sym, t_sym),
                         ("PQDTW sym + LB refine", d_ref, t_ref)):
        labels = hierarchical_labels(d, k, method="complete")
        print(f"{name:24s} {rand_index(y, labels):7.3f} "
              f"{adjusted_rand_index(y, labels):7.3f} {sec:8.3f}")

    same_code = (np.asarray(d_sym) == 0).mean()
    print(f"\nzero symmetric distances (identical codes): {same_code:.1%} "
          "of pairs -> refined by the Keogh lower bound")


if __name__ == "__main__":
    main()
