"""AdamW + LR schedules, implemented on raw pytrees (no external deps).

Optimizer state is a pytree of the same structure as params (first/second
moments), so the sharding rules that apply to params apply verbatim to the
moments — ZeRO-style optimizer-state sharding falls out of the param specs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_step",
           "warmup_cosine"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    mu: Any           # first moments (pytree like params)
    nu: Any           # second moments
    count: jnp.ndarray


def adamw_init(params) -> OptState:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return OptState(mu=zeros(params), nu=zeros(params),
                    count=jnp.zeros((), jnp.int32))


def warmup_cosine(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_step(cfg: AdamWConfig, params, grads, state: OptState
               ) -> Tuple[Any, OptState]:
    """One AdamW update with global-norm clipping and decoupled decay."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = warmup_cosine(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p if p.ndim >= 2 else 0.0  # no decay on norms
        return (p - lr * (step + decay)).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(mu=new_m, nu=new_v, count=count)
