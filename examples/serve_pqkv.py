"""End-to-end serving driver: batched requests against a small LM with a
PQ-compressed KV cache — the paper's compression-for-similarity-search idea
running inside the serving stack.

    PYTHONPATH=src python examples/serve_pqkv.py

Drives the production launcher (`repro.launch.serve`) with batched
requests, exact vs PQ-KV decode, and the memory report.
"""

from repro.launch.serve import main as serve_main


def main():
    serve_main([
        "--arch", "internlm2-1.8b",
        "--reduced",
        "--batch", "4",
        "--prompt-len", "32",
        "--gen", "12",
        "--pqkv",
        "--pq-sub", "4",
        "--pq-k", "16",
        "--pq-window", "8",
    ])


if __name__ == "__main__":
    main()
