"""DBA k-means — the codebook learner of the paper's training phase.

Assignment uses the batched wavefront through the elastic dispatch layer
(`dispatch.elastic_cdist` — Pallas kernel on TPU) under any registered
elastic measure; the update step runs one
or more DBA iterations per round, where each series contributes only to its
assigned centroid (scatter-add by cluster id, so the cost per round is N
backtracks, not N*K).  The DBA barycenter update itself always averages
along *DTW* alignment paths — for non-DTW measures it is the standard
averaging heuristic (centroids are representatives; assignment and every
LUT/search distance use the configured measure).

A Euclidean variant (`euclidean_kmeans`) backs the PQ_ED baseline.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .dispatch import elastic_cdist
from .dtw import euclidean_sq
from .dba import alignment_path
from .measures import MeasureArg

__all__ = ["KMeansResult", "dba_kmeans", "euclidean_kmeans"]


class KMeansResult(NamedTuple):
    centroids: jnp.ndarray   # (K, L)
    assignment: jnp.ndarray  # (N,)
    inertia: jnp.ndarray     # scalar: sum of within-cluster squared DTW


def _init_centroids(key: jax.Array, X: jnp.ndarray, k: int) -> jnp.ndarray:
    n = X.shape[0]
    if n >= k:
        idx = jax.random.choice(key, n, (k,), replace=False)
    else:  # codebook larger than data: sample with replacement + jitter
        idx = jax.random.choice(key, n, (k,), replace=True)
    return X[idx]


@functools.partial(jax.jit, static_argnames=("window",))
def _dba_assigned_update(C: jnp.ndarray, X: jnp.ndarray, assign: jnp.ndarray,
                         window: Optional[int]) -> jnp.ndarray:
    """Scatter-add DBA update: every series aligns to its assigned centroid."""
    K, L = C.shape

    def per_series(x, a):
        i_cells, j_cells, active = alignment_path(C[a], x, window)
        w = active.astype(jnp.float32)
        return i_cells, x[j_cells] * w, w

    i_cells, vals, w = jax.vmap(per_series)(X, assign)  # (N, 2L-1) each
    rows = jnp.broadcast_to(assign[:, None], i_cells.shape)
    assoc = jnp.zeros((K, L), jnp.float32).at[rows, i_cells].add(vals)
    count = jnp.zeros((K, L), jnp.float32).at[rows, i_cells].add(w)
    return jnp.where(count > 0, assoc / jnp.maximum(count, 1e-9), C)


def dba_kmeans(key: jax.Array, X: jnp.ndarray, k: int, iters: int = 10,
               dba_iters: int = 2, window: Optional[int] = None,
               measure: MeasureArg = None) -> KMeansResult:
    """DBA k-means over ``X (N, L)`` with ``k`` clusters.

    Python-level outer loop (iters is small) over jitted assignment/update
    steps; fully deterministic given ``key``.  ``measure`` selects the
    assignment/inertia distance (DTW by default); the DBA update remains
    DTW-alignment averaging (see module docstring).
    """
    X = jnp.asarray(X, jnp.float32)
    C = _init_centroids(key, X, k)
    assign = jnp.zeros((X.shape[0],), jnp.int32)
    for _ in range(iters):
        d = elastic_cdist(X, C, window, measure=measure)   # (N, K)
        assign = jnp.argmin(d, axis=1)
        for _ in range(dba_iters):
            C = _dba_assigned_update(C, X, assign, window)
    d = elastic_cdist(X, C, window, measure=measure)
    assign = jnp.argmin(d, axis=1)
    inertia = jnp.sum(jnp.min(d, axis=1))
    return KMeansResult(C, assign, inertia)


def euclidean_kmeans(key: jax.Array, X: jnp.ndarray, k: int,
                     iters: int = 20) -> KMeansResult:
    """Plain Lloyd k-means (squared Euclidean) for the PQ_ED baseline."""
    X = jnp.asarray(X, jnp.float32)
    C = _init_centroids(key, X, k)

    @jax.jit
    def step(C):
        d = euclidean_sq(X, C)
        assign = jnp.argmin(d, axis=1)
        oh = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # (N, K)
        count = oh.sum(0)[:, None]
        mean = (oh.T @ X) / jnp.maximum(count, 1e-9)
        return jnp.where(count > 0, mean, C), assign, d

    assign = jnp.zeros((X.shape[0],), jnp.int32)
    d = None
    for _ in range(iters):
        C, assign, d = step(C)
    inertia = jnp.sum(jnp.min(d, axis=1))
    return KMeansResult(C, assign, inertia)
