"""IVF-PQDTW: inverted-file index for million-scale elastic search.

Paper §4.1: "To handle million-scale search, a search system with inverted
indexing was developed in the original PQ paper" — this is that system,
adapted to DTW.  A coarse DBA-k-means quantizer over *whole* series routes
each database series to one of ``n_lists`` inverted lists; queries compute
``n_lists`` coarse DTW distances, probe the ``n_probe`` nearest lists, and
evaluate the PQDTW asymmetric distance only for candidates in those lists.

DTW adaptation notes (vs IVFADC): the Euclidean residual trick (encode
``x - c``) is unsound under warping — subtracting unaligned series destroys
shape — so lists share one global PQ codebook over raw series and the coarse
stage is used purely for pruning.  Search cost per query drops from
O(N·M) table look-ups to O(n_lists·D²w) coarse DTWs + O(cap·M) look-ups,
with ``cap`` a static candidate budget (TPU-friendly shapes).

The fine stage is *segment-searchable*: :func:`fine_rank` operates on bare
list-layout arrays (codes / ids / list_start / list_len [+ optional
tombstone mask]) instead of a whole :class:`IVFPQIndex`, so the streaming
segmented index (:mod:`repro.index`) ranks each sealed segment with exactly
the same code path as the monolithic index.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dispatch import elastic_cdist, two_level_coarse
from .kmeans import dba_kmeans
from .lb import lb_lut
from .measures import MeasureArg
from .pq import (PQCodebook, PQConfig, _adc_gather, encode, fit,
                 query_lut_batch, segment)

__all__ = ["IVFPQIndex", "TwoLevelCoarse", "build_index", "build_lists",
           "build_two_level", "coarse_assign", "coarse_dists", "fine_rank",
           "search", "search_batch", "validate_n_probe",
           "validate_codebook"]


def validate_codebook(cb: PQCodebook, cfg: PQConfig, D: int) -> None:
    """Reject a pre-trained codebook whose geometry disagrees with ``cfg``
    for series of length ``D`` — e.g. a codebook trained without
    pre-alignment paired with a ``use_prealign=True`` config (or with a
    different ``snap_tail``).  Catches the mismatch at build/restore time
    with a clear message instead of a shape error inside encode."""
    want = cfg.subseq_len(D)
    if cb.n_sub != cfg.n_sub or cb.subseq_len != want:
        raise ValueError(
            f"codebook geometry (n_sub={cb.n_sub}, subseq_len="
            f"{cb.subseq_len}) does not match config (n_sub={cfg.n_sub}, "
            f"subseq_len={want} for D={D}) — check the prealign settings "
            f"(use_prealign/tail_frac/snap_tail) the codebook was trained "
            f"with")


class IVFPQIndex(NamedTuple):
    coarse: jnp.ndarray       # (n_lists, D) DBA centroids of whole series
    cb: PQCodebook            # shared PQ codebook (paper §3.1)
    codes: jnp.ndarray        # (N, M) PQ codes, list-sorted order
    ids: jnp.ndarray          # (N,) original indices, list-sorted
    list_start: jnp.ndarray   # (n_lists,) offset of each list in codes/ids
    list_len: jnp.ndarray     # (n_lists,)
    max_list: int             # python int: longest list (static shapes)
    coarse_window: int        # python int: Sakoe-Chiba band the inverted
                              # lists were assigned with — the search-time
                              # default, so probe ranking matches the
                              # build-time metric

    @property
    def n_lists(self) -> int:
        return self.coarse.shape[0]


def coarse_assign(X: jnp.ndarray, coarse: jnp.ndarray,
                  window: Optional[int],
                  measure: MeasureArg = None) -> jnp.ndarray:
    """Route series ``X (N, D)`` to their nearest coarse centroid (banded
    elastic distance through the dispatch layer) -> ``(N,)`` int32 list
    ids."""
    return jnp.argmin(elastic_cdist(X, coarse, window, measure=measure),
                      axis=1).astype(jnp.int32)


class TwoLevelCoarse(NamedTuple):
    """Hierarchical coarse quantizer: a k-means clustering *of the coarse
    centroids themselves*, so queries rank ``n_top`` top cells and fan
    out only to the probed cells' children instead of evaluating all
    ``n_lists`` centroids (the per-query coarse bottleneck once
    ``n_lists`` reaches tens of thousands).  A pytree of arrays —
    replicable across a device mesh alongside the flat centroids."""
    top: jnp.ndarray          # (n_top, D) centroids of the coarse centroids
    child_idx: jnp.ndarray    # (n_top, max_children) int32 into coarse
    child_valid: jnp.ndarray  # (n_top, max_children) bool padding mask

    @property
    def n_top(self) -> int:
        return self.top.shape[0]

    @property
    def max_children(self) -> int:
        return self.child_idx.shape[1]


def build_two_level(key: jax.Array, coarse: jnp.ndarray, n_top: int,
                    window: Optional[int], measure: MeasureArg = None,
                    iters: int = 8) -> TwoLevelCoarse:
    """Cluster the ``(n_lists, D)`` coarse centroids into ``n_top`` top
    cells (same elastic DBA k-means as the bottom level) and tabulate each
    cell's children as a static padded table."""
    coarse = jnp.asarray(coarse, jnp.float32)
    n_lists = coarse.shape[0]
    if not 1 <= n_top <= n_lists:
        raise ValueError(
            f"n_top={n_top} out of range: must satisfy 1 <= n_top <= "
            f"n_lists={n_lists}")
    res = dba_kmeans(key, coarse, n_top, iters=iters, dba_iters=1,
                     window=window, measure=measure)
    assign = np.asarray(res.assignment)
    order, start, length, max_children = build_lists(assign, n_top)
    max_children = max(1, max_children)
    child_idx = np.zeros((n_top, max_children), np.int32)
    child_valid = np.zeros((n_top, max_children), bool)
    for t in range(n_top):
        kids = order[start[t]:start[t] + length[t]]
        child_idx[t, :len(kids)] = kids
        child_valid[t, :len(kids)] = True
    return TwoLevelCoarse(top=res.centroids,
                          child_idx=jnp.asarray(child_idx),
                          child_valid=jnp.asarray(child_valid))


def coarse_dists(Q: jnp.ndarray, coarse: jnp.ndarray,
                 window: Optional[int], measure: MeasureArg = None,
                 two_level: Optional[TwoLevelCoarse] = None,
                 n_probe_top: Optional[int] = None) -> jnp.ndarray:
    """Coarse distance rows ``(Nq, n_lists)`` for the probe stage: the
    flat all-pairs cdist, or — when a :class:`TwoLevelCoarse` is given —
    the hierarchical fan-out (``+inf`` outside the ``n_probe_top``
    nearest top cells' children).  Shared by the monolithic
    :func:`search_batch`, the streaming index, and the sharded planner,
    so every plan ranks probes with identical numbers."""
    if two_level is None:
        return elastic_cdist(Q, coarse, window, measure=measure)
    if n_probe_top is None:
        raise ValueError("two_level coarse search requires n_probe_top")
    return two_level_coarse(Q, two_level.top, coarse, two_level.child_idx,
                            two_level.child_valid, window,
                            n_probe_top=n_probe_top, measure=measure)


def build_lists(assign: np.ndarray, n_lists: int
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """List-sorted layout from a coarse assignment (host-side).

    Returns ``(order, list_start, list_len, max_list)``: stable sort
    permutation into list order plus the per-list offsets/lengths.
    """
    assign = np.asarray(assign)
    order = np.argsort(assign, kind="stable")
    sorted_assign = assign[order]
    start = np.searchsorted(sorted_assign, np.arange(n_lists)).astype(np.int32)
    length = (np.searchsorted(sorted_assign, np.arange(n_lists), "right")
              - start).astype(np.int32)
    max_list = int(length.max()) if assign.size else 0
    return order, start, length, max_list


def build_index(key: jax.Array, X: jnp.ndarray, cfg: PQConfig,
                n_lists: int, coarse_iters: int = 8,
                coarse_window_frac: float = 0.1, *,
                coarse: Optional[jnp.ndarray] = None,
                cb: Optional[PQCodebook] = None) -> IVFPQIndex:
    """Train coarse + fine quantizers and populate the inverted lists.

    Pass pre-trained ``coarse`` centroids and/or a ``cb`` codebook to skip
    the corresponding training stage — the path the streaming index uses to
    rebuild an equivalent monolithic index from a shared quantizer.
    """
    X = jnp.asarray(X, jnp.float32)
    N, D = X.shape
    kc, kf = jax.random.split(key)
    w = max(1, int(round(coarse_window_frac * D)))
    spec = cfg.measure()
    if coarse is None:
        res = dba_kmeans(kc, X, n_lists, iters=coarse_iters, dba_iters=1,
                         window=w, measure=spec)
        coarse_cents, assign = res.centroids, np.asarray(res.assignment)
    else:
        coarse_cents = jnp.asarray(coarse, jnp.float32)
        if coarse_cents.shape[0] != n_lists:
            raise ValueError(
                f"pre-trained coarse quantizer has {coarse_cents.shape[0]} "
                f"centroids but n_lists={n_lists}")
        assign = np.asarray(coarse_assign(X, coarse_cents, w, spec))

    if cb is None:
        cb = fit(kf, X, cfg)
    else:
        validate_codebook(cb, cfg, D)
    codes = np.asarray(encode(X, cb, cfg))

    order, start, length, max_list = build_lists(assign, n_lists)
    return IVFPQIndex(
        coarse=coarse_cents,
        cb=cb,
        codes=jnp.asarray(codes[order]),
        ids=jnp.asarray(order.astype(np.int32)),
        list_start=jnp.asarray(start),
        list_len=jnp.asarray(length),
        max_list=max_list,
        coarse_window=w)


def _candidates(list_start: jnp.ndarray, list_len: jnp.ndarray,
                max_list: int, probe_lists: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Static-shape candidate slots for ``n_probe`` lists.

    Returns (slots (n_probe*max_list,) int32 into codes/ids, valid mask).
    """
    offs = jnp.arange(max_list)
    start = list_start[probe_lists]                # (P,)
    length = list_len[probe_lists]
    slots = start[:, None] + offs[None, :]         # (P, max_list)
    valid = offs[None, :] < length[:, None]
    slots = jnp.where(valid, slots, 0)
    return slots.reshape(-1), valid.reshape(-1)


def fine_rank(codes: jnp.ndarray, ids: jnp.ndarray,
              list_start: jnp.ndarray, list_len: jnp.ndarray, max_list: int,
              dc: jnp.ndarray, qlut: jnp.ndarray, n_probe: int, topk: int,
              live: Optional[jnp.ndarray] = None,
              lb_qlut: Optional[jnp.ndarray] = None,
              lb_budget: Optional[int] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rank one list-sorted shard against a single query.

    ``dc (n_lists,)`` coarse distances, ``qlut (M, K)`` asymmetric table;
    ``live`` is an optional ``(N,)`` tombstone mask (False = deleted).
    Returns ``(distances (topk,), ids (topk,))`` with ``inf`` / ``-1``
    filling invalid slots, so shard results can be merged by a plain top-k.

    ``lb_qlut (M, K)`` (see :func:`repro.core.lb.lb_lut`) enables the
    cascaded LB pre-filter: candidates are first ranked by their cheap
    lower-bound ADC sum and only the ``lb_budget`` most promising proceed
    to the exact ADC gather.  The bound never exceeds the true asymmetric
    distance, so with ``lb_budget == cap`` results are identical to the
    unfiltered path; smaller budgets trade recall for gather work.
    """
    _, probes = jax.lax.top_k(-dc, n_probe)
    slots, valid = _candidates(list_start, list_len, max_list, probes)
    # Hierarchical coarse stage (:func:`coarse_dists` with a two-level
    # quantizer) leaves unprobed lists at dc == +inf; if n_probe exceeds
    # the finite fan-out, top_k pads with such lists — their rows were
    # never coarse-ranked and must not become candidates.  Flat coarse
    # distances are always finite, so this is a no-op there.
    valid = valid & jnp.repeat(jnp.isfinite(dc[probes]), max_list)
    if live is not None:
        valid = valid & live[slots]
    cand_codes = codes[slots]                               # (cap, M)
    if lb_qlut is not None and lb_budget is not None \
            and lb_budget < slots.shape[0]:
        lb_d = jnp.where(valid, _adc_gather(lb_qlut, cand_codes), jnp.inf)
        _, keep = jax.lax.top_k(-lb_d, lb_budget)
        slots = slots[keep]
        valid = valid[keep]
        cand_codes = cand_codes[keep]
    d = jnp.where(valid, _adc_gather(qlut, cand_codes), jnp.inf)
    neg, best = jax.lax.top_k(-d, topk)
    out_ids = jnp.where(jnp.isfinite(neg), ids[slots[best]], -1)
    return -neg, out_ids


def _fine_stage(index: IVFPQIndex, dc: jnp.ndarray, qlut: jnp.ndarray,
                n_probe: int, topk: int,
                lb_qlut: Optional[jnp.ndarray] = None,
                lb_budget: Optional[int] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return fine_rank(index.codes, index.ids, index.list_start,
                     index.list_len, index.max_list, dc, qlut, n_probe, topk,
                     lb_qlut=lb_qlut, lb_budget=lb_budget)


def validate_n_probe(n_probe: int, n_lists: int) -> None:
    """Shared probe-budget check (monolithic and streaming indexes)."""
    if not 1 <= n_probe <= n_lists:
        raise ValueError(
            f"n_probe={n_probe} out of range: must satisfy "
            f"1 <= n_probe <= n_lists={n_lists}")


def _validate_probe(n_lists: int, max_list: int, n_probe: int,
                    topk: int, lb_budget: Optional[int] = None) -> None:
    """Static-shape sanity for the probe/rank stage — a clear ``ValueError``
    instead of an XLA shape error deep inside ``top_k``."""
    validate_n_probe(n_probe, n_lists)
    cap = n_probe * max_list
    if not 1 <= topk <= cap:
        raise ValueError(
            f"topk={topk} out of range: must satisfy 1 <= topk <= "
            f"n_probe*max_list={cap} (n_probe={n_probe}, "
            f"max_list={max_list}); raise n_probe or shrink topk")
    if lb_budget is not None and not topk <= lb_budget <= cap:
        raise ValueError(
            f"lb_budget={lb_budget} out of range: must satisfy topk="
            f"{topk} <= lb_budget <= n_probe*max_list={cap}")


def search(index: IVFPQIndex, q: jnp.ndarray, cfg: PQConfig, *,
           n_probe: int, topk: int = 1,
           coarse_window: Optional[int] = None,
           lb_budget: Optional[int] = None
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single query ``q (D,)`` -> (distances (topk,), ids (topk,)).

    Coarse stage: banded DTW to all list centroids; fine stage: asymmetric
    PQDTW over the probed lists' candidates only.
    """
    d, ids = search_batch(index, q[None, :], cfg, n_probe=n_probe,
                          topk=topk, coarse_window=coarse_window,
                          lb_budget=lb_budget)
    return d[0], ids[0]


def search_batch(index: IVFPQIndex, Q: jnp.ndarray, cfg: PQConfig, *,
                 n_probe: int, topk: int = 1,
                 coarse_window: Optional[int] = None,
                 lb_budget: Optional[int] = None,
                 two_level: Optional[TwoLevelCoarse] = None,
                 n_probe_top: Optional[int] = None):
    """Batched search over queries ``Q (Nq, D)``.

    The coarse DTW stage and the asymmetric query tables are computed for
    the whole batch in two dispatch-layer launches (Pallas kernels on TPU);
    only the cheap probe/gather/top-k tail is vmapped.

    ``coarse_window`` defaults to ``index.coarse_window`` — the band the
    inverted lists were assigned with at build time — so probe ranking
    always matches the list-assignment metric unless explicitly overridden.
    ``lb_budget`` enables the cascaded LB pre-filter in the fine stage
    (see :func:`fine_rank`): candidates beyond the budget are discarded on
    their envelope lower bound before the exact ADC gather.  The budget is
    capability-gated: for measures without a sound Keogh cascade it is
    ignored (exact full gather) instead of pruning unsoundly.

    ``two_level`` + ``n_probe_top`` switch the coarse stage to the
    hierarchical quantizer (:func:`build_two_level`): probe ranking is
    restricted to the children of each query's ``n_probe_top`` nearest
    top cells.  With ``n_probe_top == two_level.n_top`` the results match
    the flat coarse stage; smaller fan-outs trade coarse recall for an
    ``O(n_top + n_probe_top * max_children)`` coarse cost.
    """
    _validate_probe(index.n_lists, index.max_list, n_probe, topk, lb_budget)
    Q = jnp.asarray(Q, jnp.float32)
    D = Q.shape[-1]
    spec = cfg.measure()
    w = coarse_window if coarse_window is not None else index.coarse_window
    dc = coarse_dists(Q, index.coarse, w, measure=spec,
                      two_level=two_level,
                      n_probe_top=n_probe_top)             # (Nq, n_lists)
    q_segs = segment(Q, cfg)                                # (Nq, M, S)
    qluts = query_lut_batch(q_segs, index.cb, cfg.window(D),
                            not cfg.is_elastic, spec)       # (Nq, M, K)
    if lb_budget is not None and spec is not None and not spec.has_keogh_lb:
        # The envelope bound table is only a lower bound for measures with
        # a sound Keogh cascade; fall back to the exact full gather rather
        # than an unsound prune.
        lb_budget = None
    if lb_budget is not None and lb_budget < n_probe * index.max_list:
        lb_luts = lb_lut(q_segs, index.cb.centroids, index.cb.env_upper,
                         index.cb.env_lower)                # (Nq, M, K)
        fn = lambda dcr, ql, lbl: _fine_stage(index, dcr, ql, n_probe,
                                              topk, lbl, lb_budget)
        return jax.vmap(fn)(dc, qluts, lb_luts)
    fn = lambda dcr, ql: _fine_stage(index, dcr, ql, n_probe, topk)
    return jax.vmap(fn)(dc, qluts)
