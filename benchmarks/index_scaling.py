"""Streaming index lifecycle costs: insert throughput, query latency as a
function of sealed-segment count, and the cost + payoff of compaction."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.pq import PQConfig
from repro.data.timeseries import random_walks
from repro.index import IndexConfig, StreamingIndex

from . import common
from .common import Bench, timeit


def _make_index(D: int, n_lists: int, hot_capacity: int,
                train_n: int) -> StreamingIndex:
    cfg = IndexConfig(
        pq=PQConfig(n_sub=4, codebook_size=32, use_prealign=False,
                    **common.measure_config_fields(),
                    kmeans_iters=3, dba_iters=1),
        n_lists=n_lists, hot_capacity=hot_capacity, coarse_iters=4)
    sample = random_walks(train_n, D, seed=0)
    return StreamingIndex.bootstrap(jax.random.PRNGKey(0), sample, cfg)


def run(quick: bool = True) -> Bench:
    b = Bench("index_scaling")
    D, n_lists, cap = (96, 8, 64) if quick else (256, 32, 256)
    n_segments_sweep = (1, 2, 4, 8) if quick else (1, 2, 4, 8, 16)
    Q = random_walks(16, D, seed=99)

    # --- insert throughput: amortized over fills + seals --------------------
    index = _make_index(D, n_lists, cap, train_n=2 * cap)
    stream = random_walks(4 * cap, D, seed=1)
    index.insert(stream[:cap])          # warm up the encode/assign jits
    t0 = time.perf_counter()
    index.insert(stream[cap:])
    t_ins = time.perf_counter() - t0
    b.add(op="insert", series=3 * cap,
          throughput_per_s=3 * cap / t_ins, total_s=t_ins)

    # --- query latency vs segment count -------------------------------------
    for n_seg in n_segments_sweep:
        index = _make_index(D, n_lists, cap, train_n=2 * cap)
        index.insert(random_walks(n_seg * cap, D, seed=2))
        assert index.n_segments == n_seg
        t = timeit(lambda: index.search(Q, n_probe=4, topk=3), repeats=3)
        b.add(op="search", n_segments=n_seg, rows=n_seg * cap,
              latency_s=t["median_s"])

    # --- compaction: cost of the merge, payoff on query latency -------------
    t0 = time.perf_counter()
    index.compact()
    t_cmp = time.perf_counter() - t0
    t = timeit(lambda: index.search(Q, n_probe=4, topk=3), repeats=3)
    b.add(op="compact", merged_rows=index.segments[0].rows,
          max_list=index.segments[0].max_list, compact_s=t_cmp,
          post_compact_latency_s=t["median_s"])
    b.save(headline={
        "quick": quick, "measure": common.MEASURE,
        "config": dict(D=D, n_lists=n_lists, hot_capacity=cap),
        "insert_throughput_per_s": next(
            (r["throughput_per_s"] for r in b.rows if r["op"] == "insert"),
            None)})
    return b


if __name__ == "__main__":
    run(quick=True)
