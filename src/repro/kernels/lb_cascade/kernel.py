"""Fused LB-cascade filter + banded-DTW refine Pallas kernel.

The paper's cascading lower bounds (§3.2) make elastic search viable by
skipping most exact DTW evaluations; "Exact Indexing for Massive Time
Series Databases under Time Warping Distance" is the database-scale version
of the same idea.  On TPU the pruning decision cannot change any shape, so
the cascade is expressed as a *tile-level* skip instead of a per-candidate
branch: for each ``(block, L)`` tile of zipped (query, candidate) pairs the
kernel

  1. evaluates ``LB_Kim`` (first/last aligned points) and the reversed
     ``LB_Keogh`` (candidate against the query's precomputed envelope) —
     a handful of VPU ops per pair;
  2. compares ``lb = max(kim, keogh)`` against the per-pair threshold
     (the caller's current k-th best verified distance);
  3. runs the band-compressed DTW wavefront shared with
     :mod:`..dtw_band.kernel` **only if any pair in the tile survives**
     (a scalar ``lax.cond`` — a fully pruned tile costs O(L) bound math
     instead of the O(L * width) wavefront sweep).

Outputs per pair: a distance that is the *exact* squared banded DTW when
``lb < thresh`` and the (valid lower-bound) ``lb`` otherwise, plus the
refined mask.  Callers that order candidates by ascending bound (the
two-phase batched search in :mod:`repro.core.lb_search`) concentrate the
survivors in few tiles, so late tiles skip the wavefront entirely.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.dispatch import effective_window
from ...core.lb import lb_keogh, lb_kim
from ...core.measures import MeasureArg
from ..dtw_band.kernel import band_width, wavefront_compressed

__all__ = [
    "lb_cascade_kernel",
    "lb_cascade_adaptive_kernel",
    "make_lb_refine_call",
]


def lb_cascade_kernel(a_ref, b_ref, u_ref, l_ref, t_ref, d_ref, f_ref, *,
                      length: int, window: int, block: int, width: int,
                      measure: MeasureArg = None):
    """``a_ref (block, L)`` queries, ``b_ref (block, L)`` candidates,
    ``u_ref``/``l_ref (block, L)`` query envelopes, ``t_ref (block, 1)``
    thresholds -> ``d_ref (block, 1)`` distances, ``f_ref (block, 1)``
    refined flags (int32 0/1)."""
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    up = u_ref[...].astype(jnp.float32)
    lo = l_ref[...].astype(jnp.float32)
    thresh = t_ref[...].astype(jnp.float32)            # (block, 1)

    # shared bound definitions (the filter must agree with the caller's
    # phase-1 ledger, which uses the same core.lb helpers)
    lb = jnp.maximum(lb_kim(a, b), lb_keogh(b, up, lo))[:, None]
    surv = lb < thresh                                 # (block, 1)

    def refine(_):
        return wavefront_compressed(a, b, length=length, window=window,
                                    width=width, measure=measure)

    def skip(_):
        return jnp.zeros((block, 1), jnp.float32)

    d = jax.lax.cond(jnp.any(surv), refine, skip, 0)
    d_ref[...] = jnp.where(surv, d, lb)
    f_ref[...] = surv.astype(jnp.int32)


def lb_cascade_adaptive_kernel(a_ref, b_ref, u_ref, l_ref, t_ref, lo_ref,
                               hi_ref, d_ref, f_ref, *, length: int,
                               window: int, block: int, width: int,
                               measure: MeasureArg = None):
    """Adaptive-corridor cascade tile: the static kernel plus per-pair
    corridor envelopes ``lo_ref``/``hi_ref (block, 2L-1)`` int32 feeding
    the refine sweep.  The bound math is unchanged (``lb`` stays a valid
    lower bound of the *static*-band distance); the refined distance is
    the corridor-restricted cost — an upper bound of the static cost, so
    the overall result is the documented approximate ``band="adaptive"``
    contract, not the certified-exact cascade."""
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    up = u_ref[...].astype(jnp.float32)
    lo = l_ref[...].astype(jnp.float32)
    thresh = t_ref[...].astype(jnp.float32)            # (block, 1)

    lb = jnp.maximum(lb_kim(a, b), lb_keogh(b, up, lo))[:, None]
    surv = lb < thresh                                 # (block, 1)

    def refine(_):
        return wavefront_compressed(a, b, length=length, window=window,
                                    width=width, measure=measure,
                                    corridor=(lo_ref[...], hi_ref[...]))

    def skip(_):
        return jnp.zeros((block, 1), jnp.float32)

    d = jax.lax.cond(jnp.any(surv), refine, skip, 0)
    d_ref[...] = jnp.where(surv, d, lb)
    f_ref[...] = surv.astype(jnp.int32)


def make_lb_refine_call(n_pairs: int, length: int, window: Optional[int],
                        block: int, interpret: bool, lane: int = 8,
                        measure: MeasureArg = None, adaptive: bool = False,
                        width: Optional[int] = None):
    """Build the pallas_call over ``(n_pairs, L)`` zipped pair batches.

    ``n_pairs`` must already be padded to a multiple of ``block``.
    ``adaptive=True`` adds two ``(n_pairs, 2L-1)`` int32 corridor
    operands and requires an explicit register ``width``.
    """
    w = effective_window(length, window)
    if width is None:
        width = band_width(length, w, lane)
    row_spec = pl.BlockSpec((block, length), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block, 1), lambda i: (i, 0))
    in_specs = [row_spec, row_spec, row_spec, row_spec, out_spec]
    if adaptive:
        kernel = functools.partial(lb_cascade_adaptive_kernel, length=length,
                                   window=w, block=block, width=width,
                                   measure=measure)
        cor_spec = pl.BlockSpec((block, 2 * length - 1), lambda i: (i, 0))
        in_specs += [cor_spec, cor_spec]
    else:
        kernel = functools.partial(lb_cascade_kernel, length=length,
                                   window=w, block=block, width=width,
                                   measure=measure)
    return pl.pallas_call(
        kernel,
        grid=(n_pairs // block,),
        in_specs=in_specs,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((n_pairs, 1), jnp.float32),
                   jax.ShapeDtypeStruct((n_pairs, 1), jnp.int32)],
        interpret=interpret,
    )
