"""Pure-jnp oracle for the PQ-ADC kernels (plain gathers, no one-hot)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "adc_sym_cdist_ref",
    "adc_lookup_ref",
    "adc_sym_cdist_quant_ref",
    "adc_lookup_quant_ref",
]


def _dequant(qlut: jnp.ndarray, scale: jnp.ndarray,
             zero: jnp.ndarray) -> jnp.ndarray:
    """Per-subspace affine dequantization back to f32: the quant kernels
    are numerically this table through the f32 oracle."""
    shape = (qlut.shape[0],) + (1,) * (qlut.ndim - 1)
    return (qlut.astype(jnp.float32) * scale.reshape(shape)
            + zero.reshape(shape))


@jax.jit
def adc_sym_cdist_ref(codes_a: jnp.ndarray, codes_b: jnp.ndarray,
                      lut: jnp.ndarray) -> jnp.ndarray:
    def per_sub(am, bm, lut_m):
        return lut_m[am[:, None], bm[None, :]]
    d2 = jnp.sum(jax.vmap(per_sub, in_axes=(1, 1, 0))(
        codes_a.astype(jnp.int32), codes_b.astype(jnp.int32),
        lut.astype(jnp.float32)), axis=0)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


@jax.jit
def adc_lookup_ref(codes: jnp.ndarray, qlut: jnp.ndarray) -> jnp.ndarray:
    m_idx = jnp.arange(qlut.shape[0])
    d2 = jnp.sum(qlut[m_idx[None, :], codes.astype(jnp.int32)], axis=-1)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


@jax.jit
def adc_sym_cdist_quant_ref(codes_a: jnp.ndarray, codes_b: jnp.ndarray,
                            qlut: jnp.ndarray, scale: jnp.ndarray,
                            zero: jnp.ndarray) -> jnp.ndarray:
    return adc_sym_cdist_ref(codes_a, codes_b, _dequant(qlut, scale, zero))


@jax.jit
def adc_lookup_quant_ref(codes: jnp.ndarray, qlut: jnp.ndarray,
                         scale: jnp.ndarray,
                         zero: jnp.ndarray) -> jnp.ndarray:
    return adc_lookup_ref(codes, _dequant(qlut, scale, zero))
