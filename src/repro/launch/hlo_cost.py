"""Structural cost model over optimized HLO text.

``compiled.cost_analysis()`` visits every computation ONCE — a scan over 80
layers or 16 grad-accum microbatches is counted as a single body execution,
under-reporting FLOPs/bytes by orders of magnitude.  This module parses the
optimized HLO, builds the computation call graph, and multiplies while-loop
bodies by their ``known_trip_count`` annotation (XLA records it for counted
loops, which is what ``lax.scan`` lowers to).

Per-op costs:
  * flops  — dot: 2 x result_elems x contraction_size (from the
    ``lhs_contracting_dims`` attribute and the operand symbol table);
    convolution: 2 x out_elems x kernel_elems / out_features.
  * HBM traffic — for every top-level (post-fusion) op: operand bytes +
    output bytes.  Fusion internals move through registers/VMEM and add no
    traffic; tuple plumbing (parameter/tuple/gte/bitcast) is free.
  * collectives — per-kind byte counts with ring-cost conventions:
    all-reduce 2x output, all-gather output, reduce-scatter input,
    all-to-all / collective-permute output.  ``-start`` counted,
    ``-done`` skipped.

All shapes in the SPMD module are per-device shard shapes, so every total
is per-device per-step.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_module", "parse_computations"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<shape>\(.*?\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>[a-zA-Z][\w\-]*)\(")

# header: "[ENTRY] %name (params...) -> type {"; params may contain nested
# parens (tuple-typed args), so only the name prefix is matched.
_COMP_HEADER_RE = re.compile(
    r"^(?P<entry>ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(")

_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_FREE_OPS = frozenset((
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "copy-done", "opt-barrier",
    "domain", "token",
))

_ELEMENT_COUNT_OPS = frozenset((
    "add", "subtract", "multiply", "divide", "exponential", "tanh", "rsqrt",
    "sqrt", "maximum", "minimum", "compare", "select", "negate", "abs",
    "power", "log", "logistic", "floor", "ceil", "round-nearest-even",
    "convert", "reduce", "and", "or", "xor", "not",
))


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(shape_text: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_dims(shape_text: str) -> List[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: List[str]
    attrs: str
    line: str


def parse_computations(hlo: str) -> Tuple[Dict[str, List[Instr]], str]:
    """Split module text into computations; returns (comps, entry_name)."""
    comps: Dict[str, List[Instr]] = {}
    entry = ""
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HEADER_RE.match(stripped)
                if m:
                    cur = m.group("name")
                    comps[cur] = []
                    if m.group("entry"):
                        entry = cur
            continue
        if stripped == "}" or stripped.startswith("} "):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        head_end = m.end()
        # operands: scan to the matching close paren
        depth = 1
        i = head_end
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        operand_text = line[head_end:i - 1]
        attrs = line[i:]
        comps[cur].append(Instr(
            name=m.group("name"), shape=m.group("shape"), op=m.group("op"),
            operands=_OPERAND_RE.findall(operand_text), attrs=attrs,
            line=line))
    return comps, entry


@dataclasses.dataclass
class HloCost:
    """``hbm_min`` counts traffic only at must-materialize ops (dot/conv
    operands+results, collectives, copies, dynamic-update-slices, gathers)
    — the TPU perfect-fusion bound, since XLA:TPU fuses elementwise chains
    into producers/consumers.  ``hbm_max`` additionally charges every
    CPU-fusion boundary and elementwise op — an upper bound tied to this
    container's XLA:CPU fusion decisions.  Roofline uses ``hbm_min``."""
    flops: float = 0.0
    hbm_min: float = 0.0
    hbm_max: float = 0.0
    vpu_elems: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in (
            "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute")})

    @property
    def hbm_bytes(self) -> float:            # roofline default
        return self.hbm_min

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())

    def __iadd__(self, other: "HloCost"):
        self.flops += other.flops
        self.hbm_min += other.hbm_min
        self.hbm_max += other.hbm_max
        self.vpu_elems += other.vpu_elems
        for k in self.coll:
            self.coll[k] += other.coll[k]
        return self

    def scaled(self, f: float) -> "HloCost":
        return HloCost(self.flops * f, self.hbm_min * f, self.hbm_max * f,
                       self.vpu_elems * f,
                       {k: v * f for k, v in self.coll.items()})

    def to_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_min,
                "hbm_max": self.hbm_max, "vpu_elems": self.vpu_elems,
                "coll": dict(self.coll), "coll_bytes": self.coll_bytes}


def _dot_flops(ins: Instr, symtab: Dict[str, str]) -> float:
    out_elems = _shape_elems(ins.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    if not m or not ins.operands:
        return 2.0 * out_elems
    lhs_shape = symtab.get(ins.operands[0], "")
    dims = _first_dims(lhs_shape)
    contraction = 1
    if m.group(1):
        for d in m.group(1).split(","):
            idx = int(d)
            if idx < len(dims):
                contraction *= dims[idx]
    return 2.0 * out_elems * contraction


def _conv_flops(ins: Instr, symtab: Dict[str, str]) -> float:
    out_elems = _shape_elems(ins.shape)
    if len(ins.operands) < 2:
        return 2.0 * out_elems
    kernel_elems = _shape_elems(symtab.get(ins.operands[1], ""))
    out_dims = _first_dims(ins.shape)
    # dim_labels like b0f_0io->b0f : feature dim = position of 'f' in output
    m = re.search(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)", ins.attrs)
    out_features = 1
    if m and "f" in m.group(3):
        pos = m.group(3).index("f")
        if pos < len(out_dims):
            out_features = max(1, out_dims[pos])
    return 2.0 * out_elems * max(1, kernel_elems // out_features)


def _collective_kind(op: str) -> Optional[str]:
    base = op[:-6] if op.endswith("-start") else op
    if base in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"):
        return base
    return None


def _bf16_width(ins: Instr, consumers: Dict[str, List["Instr"]]) -> bool:
    """True when an f32 collective is a CPU float-normalization artifact:
    the value is bf16 on either side (convert feeding it, or every consumer
    converts it straight to bf16).  XLA:TPU runs these collectives natively
    in bf16, so the roofline charges 2 bytes/elem, not 4."""
    if "promoted" in ins.attrs:                  # promoted bf16 reducer
        return True
    if not ins.shape.startswith("f32") and "f32[" not in ins.shape[:6]:
        return False
    outs = consumers.get(ins.name, ())
    if outs and all(
            o.shape.startswith("bf16") and
            (o.op == "convert" or "convert" in o.name)
            for o in outs):
        return True
    return False


def _instr_cost(ins: Instr, symtab: Dict[str, str],
                comp_cost, comps, internal: bool = False,
                consumers: Dict[str, List["Instr"]] = {}) -> HloCost:
    """``internal=True`` = inside a fused computation: only true stores
    (DUS / scatter) and compute count; data movement was already charged at
    the fusion boundary (hbm_max) or is VMEM-resident (hbm_min)."""
    c = HloCost()
    op = ins.op

    if op in _FREE_OPS or op.endswith("-done"):
        return c

    if op == "while":
        tc_m = _TRIP_RE.search(ins.attrs)
        tc = int(tc_m.group(1)) if tc_m else 1
        body = _BODY_RE.search(ins.attrs)
        cond = _COND_RE.search(ins.attrs)
        inner = HloCost()
        if body and body.group(1) in comps:
            inner += comp_cost(body.group(1))
        if cond and cond.group(1) in comps:
            inner += comp_cost(cond.group(1))
        return inner.scaled(tc)

    if op == "conditional":
        br = _BRANCHES_RE.search(ins.attrs)
        best = HloCost()
        if br:
            for name in _OPERAND_RE.findall(br.group(1)):
                if name in comps:
                    sub = comp_cost(name)
                    if sub.flops + sub.hbm_bytes > best.flops + best.hbm_bytes:
                        best = sub
        return best

    if op == "call":
        m = _CALLS_RE.search(ins.attrs) or re.search(
            r"to_apply=%?([\w.\-]+)", ins.attrs)
        if m and m.group(1) in comps:
            return comp_cost(m.group(1))
        return c

    out_bytes = _shape_bytes(ins.shape)
    in_bytes = sum(_shape_bytes(symtab.get(o, "")) for o in ins.operands)
    io = in_bytes + out_bytes

    kind = _collective_kind(op)
    if kind is not None:
        if kind == "all-reduce":
            moved = 2.0 * out_bytes
        elif kind == "reduce-scatter":
            moved = float(in_bytes)
        else:                       # all-gather / all-to-all / permute
            moved = float(out_bytes)
        if _bf16_width(ins, consumers):
            moved *= 0.5            # TPU-native bf16 collective width
        c.coll[kind] += moved
        c.hbm_min += io
        c.hbm_max += io
        return c

    if op == "fusion":
        c.hbm_max += io             # CPU fusion boundary; TPU would merge
        m = _CALLS_RE.search(ins.attrs)
        if m and m.group(1) in comps:
            inner = comp_cost(m.group(1), True)
            c.flops += inner.flops           # dots fused in count as compute
            c.vpu_elems += inner.vpu_elems
            c.hbm_min += inner.hbm_min       # true stores inside the fusion
        return c

    if op == "dot":
        c.flops += _dot_flops(ins, symtab)
        c.hbm_min += io
        c.hbm_max += io
        return c

    if op == "convolution":
        c.flops += _conv_flops(ins, symtab)
        c.hbm_min += io
        c.hbm_max += io
        return c

    if op == "dynamic-update-slice":
        upd = (_shape_bytes(symtab.get(ins.operands[1], ""))
               if len(ins.operands) > 1 else out_bytes)
        c.hbm_min += 2.0 * upd
        c.hbm_max += 2.0 * upd
        return c

    if op in ("gather", "scatter", "sort", "select-and-scatter"):
        c.hbm_min += io
        c.hbm_max += io
        return c

    if op in ("copy", "copy-start", "transpose", "rng",
              "rng-bit-generator", "cholesky", "triangular-solve",
              "custom-call", "dynamic-slice", "slice", "concatenate",
              "pad", "reverse"):
        if not internal:            # fused copies/slices are VMEM-resident
            c.hbm_min += io
        c.hbm_max += io
        return c

    if op in ("reshape", "broadcast", "iota", "reduce-window"):
        c.hbm_max += io             # usually fused / layout-free on TPU
        return c

    if op in _ELEMENT_COUNT_OPS:
        c.vpu_elems += _shape_elems(ins.shape)
        c.hbm_max += io             # fusable on TPU
        return c

    # unknown op: charge traffic on both bounds, no flops
    c.hbm_min += io
    c.hbm_max += io
    return c


def analyze_module(hlo: str) -> HloCost:
    """Whole-module per-device cost with loop trip counts applied."""
    comps, entry = parse_computations(hlo)
    if not entry:
        # fall back: the largest computation
        entry = max(comps, key=lambda k: len(comps[k])) if comps else ""
    memo: Dict[Tuple[str, bool], HloCost] = {}

    def comp_cost(name: str, internal: bool = False) -> HloCost:
        key = (name, internal)
        if key in memo:
            return memo[key]
        memo[key] = HloCost()        # guard against recursion
        total = HloCost()
        symtab = {i.name: i.shape for i in comps[name]}
        consumers: Dict[str, List[Instr]] = {}
        for i in comps[name]:
            for o in i.operands:
                consumers.setdefault(o, []).append(i)
        for ins in comps[name]:
            total += _instr_cost(ins, symtab, comp_cost, comps, internal,
                                 consumers)
        memo[key] = total
        return total

    return comp_cost(entry) if entry else HloCost()
