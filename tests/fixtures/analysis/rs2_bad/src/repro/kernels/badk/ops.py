"""badk entry point: no ref.py sibling, unregistered in dispatch."""

from jax.experimental import pallas as pl

from .kernel import badk_kernel


def run_badk(x):
    return pl.pallas_call(badk_kernel, out_shape=x)(x)
