"""Streaming segmented index: lifecycle, equivalence to the monolithic
IVF-PQDTW index, snapshot round-trips, sharded planner, accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.dispatch import use_backend
from repro.core.ivf import build_index, search_batch
from repro.core.pq import PQConfig, memory_cost
from repro.data.timeseries import cbf
from repro.index import (IndexConfig, StreamingIndex, latest_snapshot,
                         restore_snapshot, save_snapshot, search_sharded)


def _config(n_lists=4, hot_capacity=12):
    pq = PQConfig(n_sub=4, codebook_size=8, use_prealign=False,
                  kmeans_iters=2, dba_iters=1)
    return IndexConfig(pq=pq, n_lists=n_lists, hot_capacity=hot_capacity,
                       coarse_iters=3)


@pytest.fixture(scope="module")
def data():
    X, _ = cbf(n_per_class=12, length=48, seed=0)    # 36 series
    Q, _ = cbf(n_per_class=2, length=48, seed=7)     # 6 queries
    return X.astype(np.float32), Q.astype(np.float32)


@pytest.fixture(scope="module")
def booted(data):
    X, _ = data
    return StreamingIndex.bootstrap(jax.random.PRNGKey(0), X, _config())


def _fresh(booted):
    """Empty index sharing booted's trained quantizers (cheap per-test)."""
    idx = StreamingIndex.from_parts(booted.cfg, booted.coarse, booted.cb,
                                    booted.dim)
    return idx


class TestLifecycle:
    def test_insert_autoflushes_into_segments(self, data, booted):
        X, _ = data
        idx = _fresh(booted)
        ids = idx.insert(X[:30])
        np.testing.assert_array_equal(ids, np.arange(30))
        assert idx.n_segments == 2              # 2 x 12 sealed, 6 hot
        assert idx.hot.count == 6
        assert idx.n_live() == 30

    def test_hot_only_search_is_exact_banded_dtw(self, data, booted,
                                                 dtw_ref):
        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:8])                       # stays entirely in hot
        d, ids = idx.search(Q[:1], n_probe=1, topk=1)
        w = idx.cfg.coarse_window(X.shape[1])
        want = min(np.sqrt(dtw_ref(Q[0], X[j], w)) for j in range(8))
        assert float(d[0, 0]) == pytest.approx(want, rel=1e-5)

    def test_delete_tombstones_hot_and_sealed(self, data, booted):
        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:20])                      # 12 sealed + 8 hot
        hit = idx.delete([3, 15, 99])           # one sealed, one hot, one miss
        assert hit == 2
        assert idx.n_live() == 18
        _, ids = idx.search(Q, n_probe=idx.cfg.n_lists, topk=18)
        found = set(np.asarray(ids).ravel().tolist())
        assert 3 not in found and 15 not in found

    def test_compact_preserves_live_set(self, data, booted):
        X, _ = data
        idx = _fresh(booted)
        idx.insert(X)
        idx.flush()
        idx.delete([1, 13, 25])
        before = idx.live_ids()
        idx.compact()
        assert idx.n_segments == 1
        np.testing.assert_array_equal(idx.live_ids(), before)
        # dead padding and tombstones were physically dropped
        assert idx.segments[0].rows == len(before)

    def test_euclidean_metric_hot_and_sealed_merge_consistently(self, data):
        """Under the PQ_ED baseline metric the hot scan must rank with
        Euclidean distance (not DTW), so a row keeps its sqrt-space scale
        when a flush moves it from hot to sealed."""
        X, Q = data
        pq = PQConfig(n_sub=4, codebook_size=8, metric="euclidean",
                      use_prealign=False, kmeans_iters=2)
        cfg = IndexConfig(pq=pq, n_lists=4, hot_capacity=12, coarse_iters=3)
        idx = StreamingIndex.bootstrap(jax.random.PRNGKey(0), X, cfg)
        idx.insert(X[:8])                      # hot only
        d_hot, _ = idx.search(Q[:2], n_probe=4, topk=1)
        want = np.sqrt(((Q[:2, None] - X[None, :8]) ** 2).sum(-1)).min(1)
        np.testing.assert_allclose(np.asarray(d_hot)[:, 0], want,
                                   rtol=1e-4, atol=1e-4)

    def test_tombstoned_id_reserved_until_dropped(self, data, booted):
        X, _ = data
        idx = _fresh(booted)
        idx.insert(X[:12])                     # exactly one sealed segment
        idx.delete([5])
        with pytest.raises(ValueError, match="already resident"):
            idx.insert(X[:1], ids=[5])         # still occupies a sealed slot
        idx.compact()                          # physically dropped
        idx.insert(X[:1], ids=[5])             # now reusable
        assert 5 in idx.live_ids()

    def test_empty_index_searches_clean(self, data, booted):
        _, Q = data
        idx = _fresh(booted)
        d, ids = idx.search(Q, n_probe=1, topk=3)
        assert np.isinf(np.asarray(d)).all()
        assert (np.asarray(ids) == -1).all()

    def test_validation_errors(self, data, booted):
        X, Q = data
        idx = _fresh(booted)
        with pytest.raises(ValueError, match="n_probe"):
            idx.search(Q, n_probe=idx.cfg.n_lists + 1)
        with pytest.raises(ValueError, match="topk"):
            idx.search(Q, n_probe=1, topk=0)
        with pytest.raises(ValueError, match="series"):
            idx.insert(np.zeros((2, 7), np.float32))
        with pytest.raises(ValueError, match="queries"):
            idx.search(Q[:, :10], n_probe=1)
        with pytest.raises(ValueError, match="ids must be >= 0"):
            idx.insert(X[:2], ids=[-1, 3])
        with pytest.raises(ValueError, match="duplicate ids"):
            idx.insert(X[:2], ids=[5, 5])
        idx.insert(X[:14], ids=np.arange(14))    # fills hot -> one sealed
        with pytest.raises(ValueError, match="already resident"):
            idx.insert(X[:1], ids=[2])           # collides with sealed row
        with pytest.raises(ValueError, match="already resident"):
            idx.insert(X[:1], ids=[13])          # collides with hot row
        with pytest.raises(ValueError, match="hot_capacity"):
            StreamingIndex.from_parts(
                dataclasses.replace(idx.cfg, hot_capacity=0),
                idx.coarse, idx.cb, idx.dim)


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_incremental_matches_from_scratch(data, booted, backend, tmp_path):
    """Acceptance: inserts across >=3 segments + deletes + compaction +
    snapshot/restore returns the same top-1 as a from-scratch build_index
    over the equivalent live dataset (shared quantizers, full probe)."""
    X, Q = data
    with use_backend(backend):
        jax.clear_caches()                       # force backend re-dispatch
        idx = _fresh(booted)
        idx.insert(X)                            # 36 rows -> 3 segments
        assert idx.n_segments == 3
        dead = [2, 9, 17, 30]
        assert idx.delete(dead) == len(dead)
        idx.compact()
        save_snapshot(str(tmp_path), idx)
        idx = restore_snapshot(str(tmp_path))

        live = np.setdiff1d(np.arange(len(X)), dead)
        ref = build_index(jax.random.PRNGKey(1), jnp.asarray(X[live]),
                          idx.cfg.pq, n_lists=idx.cfg.n_lists,
                          coarse=idx.coarse, cb=idx.cb)
        d_ref, i_ref = search_batch(ref, jnp.asarray(Q), idx.cfg.pq,
                                    n_probe=idx.cfg.n_lists, topk=1)
        d, ids = idx.search(Q, n_probe=idx.cfg.n_lists, topk=1)
        np.testing.assert_allclose(np.asarray(d)[:, 0],
                                   np.asarray(d_ref)[:, 0],
                                   rtol=1e-5, atol=1e-5)
        # ref ids are positions into the live subset; map to external ids
        np.testing.assert_array_equal(np.asarray(ids)[:, 0],
                                      live[np.asarray(i_ref)[:, 0]])


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_incremental_prealigned_matches_from_scratch(data, backend,
                                                     tmp_path):
    """Acceptance (pre-aligned path): with prealign=True every seal routes
    through the fused prealign_encode dispatch op, and an incrementally
    built index (3 segments + snapshot/restore) returns the same top-1 as
    a from-scratch prealigned build_index; the prealign config (including
    snap_tail) round-trips through the snapshot."""
    from repro.core import dispatch
    from repro.core.pq import uses_fused_prealign

    X, Q = data
    pq = PQConfig(n_sub=4, codebook_size=8, use_prealign=True,
                  wavelet_level=2, snap_tail=3, exact_encode=True,
                  kmeans_iters=2, dba_iters=1)
    assert uses_fused_prealign(pq)
    cfg = IndexConfig(pq=pq, n_lists=4, hot_capacity=12, coarse_iters=3)
    with use_backend(backend):
        jax.clear_caches()
        dispatch.reset_stats()
        booted = StreamingIndex.bootstrap(jax.random.PRNGKey(0), X, cfg)
        idx = StreamingIndex.from_parts(cfg, booted.coarse, booted.cb,
                                        booted.dim)
        idx.insert(X)                            # 36 rows -> 3 sealed
        assert idx.n_segments == 3
        assert dispatch.stats.get(("prealign_encode", backend), 0) > 0
        save_snapshot(str(tmp_path), idx)
        idx = restore_snapshot(str(tmp_path))
        assert idx.cfg == cfg                    # snap_tail etc. round-trip

        ref = build_index(jax.random.PRNGKey(1), jnp.asarray(X), cfg.pq,
                          n_lists=cfg.n_lists, coarse=idx.coarse, cb=idx.cb)
        d_ref, i_ref = search_batch(ref, jnp.asarray(Q), cfg.pq,
                                    n_probe=cfg.n_lists, topk=1)
        d, ids = idx.search(Q, n_probe=cfg.n_lists, topk=1)
        np.testing.assert_allclose(np.asarray(d)[:, 0],
                                   np.asarray(d_ref)[:, 0],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(ids)[:, 0],
                                      np.asarray(i_ref)[:, 0])


def test_mismatched_prealign_codebook_rejected(data, booted):
    """A codebook trained without pre-alignment cannot back a prealigned
    config: segment lengths differ, caught at construction time."""
    import dataclasses as dc
    cfg_pre = dc.replace(booted.cfg,
                         pq=dc.replace(booted.cfg.pq, use_prealign=True))
    with pytest.raises(ValueError, match="geometry"):
        StreamingIndex.from_parts(cfg_pre, booted.coarse, booted.cb,
                                  booted.dim)
    with pytest.raises(ValueError, match="geometry"):
        build_index(jax.random.PRNGKey(0), jnp.asarray(data[0]), cfg_pre.pq,
                    n_lists=4, coarse=booted.coarse, cb=booted.cb)


def test_hot_scan_routes_lb_refine(data, booted):
    """The hot-segment scan runs through the LB-cascade filter-and-refine
    dispatch op (no dense DTW cdist over the buffer)."""
    from repro.core import dispatch
    X, Q = data
    with use_backend("pallas_interpret"):
        jax.clear_caches()
        dispatch.reset_stats()
        idx = _fresh(booted)
        idx.insert(X[:8])                        # hot only
        d, ids = idx.search(Q[:2], n_probe=1, topk=2)
        assert dispatch.stats.get(("lb_refine", "pallas_interpret"), 0) > 0
    assert np.isfinite(np.asarray(d)).all()


def test_snapshot_roundtrips_coarse_window(data, tmp_path):
    """A non-default ``coarse_window_frac`` survives the snapshot, so the
    restored index keeps ranking probes with the band its lists were
    assigned under."""
    X, Q = data
    pq = PQConfig(n_sub=4, codebook_size=8, use_prealign=False,
                  kmeans_iters=2, dba_iters=1)
    cfg = IndexConfig(pq=pq, n_lists=4, hot_capacity=12, coarse_iters=3,
                      coarse_window_frac=0.35)
    idx = StreamingIndex.bootstrap(jax.random.PRNGKey(0), X, cfg)
    idx.insert(X[:20])
    save_snapshot(str(tmp_path), idx)
    back = restore_snapshot(str(tmp_path))
    assert back.cfg == cfg
    assert back.cfg.coarse_window(X.shape[1]) == max(
        1, int(round(0.35 * X.shape[1])))
    d0, i0 = idx.search(Q, n_probe=2, topk=3)
    d1, i1 = back.search(Q, n_probe=2, topk=3)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1))


class TestSnapshot:
    def test_roundtrip_identical_search(self, data, booted, tmp_path):
        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:20])                      # sealed + live hot rows
        idx.delete([4, 14])
        save_snapshot(str(tmp_path), idx)
        back = restore_snapshot(str(tmp_path))
        assert back.next_id == idx.next_id
        d0, i0 = idx.search(Q, n_probe=2, topk=5)
        d1, i1 = back.search(Q, n_probe=2, topk=5)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                                   rtol=1e-6, atol=1e-6)

    def test_tombstones_stay_deleted_after_restore(self, data, booted,
                                                   tmp_path):
        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:24])
        idx.flush()
        idx.delete([0, 7, 20])
        idx.compact()
        save_snapshot(str(tmp_path), idx)
        back = restore_snapshot(str(tmp_path))
        assert back.n_live() == 21
        _, ids = back.search(Q, n_probe=back.cfg.n_lists, topk=21)
        found = set(np.asarray(ids).ravel().tolist())
        assert found.isdisjoint({0, 7, 20})

    def test_latest_step_and_gc(self, data, booted, tmp_path):
        X, _ = data
        idx = _fresh(booted)
        idx.insert(X[:6])
        for _ in range(4):
            save_snapshot(str(tmp_path), idx, keep_last=2)
        assert latest_snapshot(str(tmp_path)) == 3
        restore_snapshot(str(tmp_path), step=2)   # survivor of GC
        with pytest.raises(FileNotFoundError):
            restore_snapshot(str(tmp_path / "nowhere"))

    @pytest.mark.slow
    @settings(max_examples=8, deadline=None)
    @given(st.integers(6, 36), st.sets(st.integers(0, 35), max_size=6),
           st.booleans(), st.booleans())
    def test_snapshot_roundtrip_property(self, data, booted,
                                         n_ins, dead, do_flush, do_compact):
        """Property sweep: random ingest/delete/flush/compact schedules
        round-trip to bit-identical (distances, ids) search results, with
        tombstoned entries staying deleted after restore."""
        import shutil
        import tempfile

        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:n_ins])
        idx.delete(sorted(dead))
        if do_flush:
            idx.flush()
        if do_compact:
            idx.compact()
        sub = tempfile.mkdtemp(prefix="snap_prop_")
        try:
            save_snapshot(sub, idx)
            back = restore_snapshot(sub)
        finally:
            shutil.rmtree(sub, ignore_errors=True)
        k = min(4, max(1, idx.n_live()))
        d0, i0 = idx.search(Q, n_probe=2, topk=k)
        d1, i1 = back.search(Q, n_probe=2, topk=k)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
        np.testing.assert_array_equal(idx.live_ids(), back.live_ids())
        assert not set(back.live_ids()).intersection(
            d for d in dead if d < n_ins)


class TestPlanner:
    def test_sharded_matches_direct(self, data, booted):
        X, Q = data
        idx = _fresh(booted)
        idx.insert(X[:20])
        idx.delete([2, 13])
        d0, i0 = idx.search(Q, n_probe=3, topk=4)
        d1, i1 = search_sharded(idx, Q, n_probe=3, topk=4)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                                   rtol=1e-6, atol=1e-6)

    def test_sharded_validates(self, data, booted):
        _, Q = data
        idx = _fresh(booted)
        with pytest.raises(ValueError, match="n_probe"):
            search_sharded(idx, Q, n_probe=99)

    @pytest.mark.slow
    def test_sharded_multi_device(self):
        """The shard_map fan-out on 4 simulated host devices (query count
        not divisible -> padded) matches the single-device path."""
        import os
        import subprocess
        import sys

        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=4",
                   JAX_PLATFORMS="cpu",
                   PYTHONPATH=os.path.join(root, "src"))
        code = """
import numpy as np, jax
assert len(jax.devices()) == 4
from repro.core.pq import PQConfig
from repro.index import IndexConfig, StreamingIndex, search_sharded
from repro.data.timeseries import cbf
X, _ = cbf(12, length=48, seed=0)
Q, _ = cbf(2, length=48, seed=7)          # 6 queries -> padded to 8
pq = PQConfig(n_sub=4, codebook_size=8, use_prealign=False,
              kmeans_iters=2, dba_iters=1)
idx = StreamingIndex.bootstrap(
    jax.random.PRNGKey(0), X,
    IndexConfig(pq=pq, n_lists=4, hot_capacity=12, coarse_iters=3))
idx.insert(X[:30]); idx.delete([3, 17])
d0, i0 = idx.search(Q, n_probe=3, topk=4)
d1, i1 = search_sharded(idx, Q, n_probe=3, topk=4)
np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-6)
"""
        res = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
        assert res.returncode == 0, res.stderr[-2000:]


class TestAccounting:
    def test_memory_cost_gains_segmented_keys(self, data, booted):
        X, _ = data
        idx = _fresh(booted)
        idx.insert(X)
        m = idx.memory_cost()
        for key in ("sidecar_bytes", "list_bytes", "hot_bytes",
                    "index_bytes", "total_bytes"):
            assert key in m and m[key] >= 0
        assert m["total_bytes"] >= m["index_bytes"]
        # plain (non-segmented) call keeps its old surface
        plain = memory_cost(idx.cfg.pq, idx.dim, 100)
        assert "total_bytes" not in plain and "compression" in plain
        # hot-only index: no sealed segments -> no inverted-list tables
        hot_only = _fresh(booted)
        hot_only.insert(X[:4])
        assert hot_only.memory_cost()["list_bytes"] == 0

    def test_compaction_shrinks_accounting(self, data, booted):
        X, _ = data
        idx = _fresh(booted)
        idx.insert(X)
        idx.flush()
        idx.delete([0, 1, 2, 3])
        before = idx.memory_cost()["index_bytes"]
        idx.compact()
        assert idx.memory_cost()["index_bytes"] < before
