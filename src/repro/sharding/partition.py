"""Partitioning rules: params / optimizer state / caches / batches -> specs.

Scheme (single-pod mesh ``(data, model)``, multi-pod ``(pod, data, model)``):

  * TP over ``model``: attention heads & ffn columns (column-parallel),
    output rows (row-parallel), vocab, experts (EP), SSM heads.
  * FSDP over ``data`` (+``pod``): the non-TP dimension of every large
    matrix is sharded too, so param + optimizer memory scales with the
    full chip count (ZeRO-3 style; XLA inserts the per-layer all-gathers).
  * DP over ``data`` (+``pod``): the batch dimension of activations; the
    sequence axis of KV caches is TP-sharded (decode attention becomes a
    ``model``-axis reduction).

Rules are *name -> trailing-dims spec*; leading (scan/stack) axes are padded
with ``None``.  Any dim not divisible by its axis size falls back to
replication for that dim (e.g. batch=1 long-context decode).
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "cache_specs", "batch_specs", "named_shardings",
           "fsdp_axes", "dp_axes", "activation_sharding", "constrain_batch",
           "current_act_axes"]

# ---------------------------------------------------------------------------
# Activation-sharding context.
#
# Sharding propagation alone does NOT keep activations batch-sharded through
# the layer scan: the embedding's FSDP axis (d over 'data') conflicts with
# batch-over-'data' at the token gather, and the partitioner resolves the tie
# by replicating the batch — silently multiplying per-device compute by the
# DP degree (caught by the dry-run cost model).  Model code therefore calls
# ``constrain_batch(x)`` on (B, ...) activations; outside a mesh/launch
# context it is a no-op, so tests and CPU examples are unaffected.
# ---------------------------------------------------------------------------

_ACT_AXES: contextvars.ContextVar[Optional[Tuple[str, ...]]] = \
    contextvars.ContextVar("repro_act_axes", default=None)
_MODEL_SIZE: contextvars.ContextVar[int] = \
    contextvars.ContextVar("repro_model_axis_size", default=1)


@contextlib.contextmanager
def activation_sharding(axes: Optional[Tuple[str, ...]],
                        model_size: int = 1):
    """Enable batch-dim activation constraints during tracing.

    ``model_size`` exposes the TP degree to model code that needs
    shard-blocked layouts (e.g. the PQ-KV ADC scorer)."""
    tok = _ACT_AXES.set(tuple(axes) if axes else None)
    tok2 = _MODEL_SIZE.set(model_size)
    try:
        yield
    finally:
        _ACT_AXES.reset(tok)
        _MODEL_SIZE.reset(tok2)


def current_act_axes() -> Optional[Tuple[str, ...]]:
    return _ACT_AXES.get()


def current_model_size() -> int:
    return _MODEL_SIZE.get()


def constrain_batch(x):
    """Pin dim 0 of an activation to the DP axes (no-op outside context,
    or when the batch does not divide the DP degree)."""
    axes = _ACT_AXES.get()
    if axes is None or x.ndim < 1:
        return x
    spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_dims(x, dims):
    """Pin named dims of an activation: ``dims`` maps axis index -> "dp"
    (the DP axes) or a mesh axis name.  No-op outside the launch context."""
    axes = _ACT_AXES.get()
    if axes is None:
        return x
    entries = [None] * x.ndim
    for i, a in dims.items():
        entries[i] = axes if a == "dp" else a
    return jax.lax.with_sharding_constraint(x, P(*entries))

_F = "__fsdp__"   # placeholder resolved to ('data',) or ('pod', 'data')
_D = "__dp__"

# name -> spec for the TRAILING dims of the leaf
_PARAM_RULES = {
    # embeddings / heads
    "embed": ("model", _F),
    "lm_head": ("model", _F),
    "patch_proj": (_F, "model"),
    "frame_proj": (_F, "model"),
    # attention
    "wq": (_F, "model"), "wk": (_F, "model"), "wv": (_F, "model"),
    "wo": ("model", _F),
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    # dense mlp
    "w_gate": (_F, "model"), "w_up": (_F, "model"), "w_down": ("model", _F),
    # moe (experts on model = EP; dense dims FSDP)
    "router": (_F, None),
    "we_gate": ("model", _F, None), "we_up": ("model", _F, None),
    "we_down": ("model", None, _F),
    # mamba2
    "wz": (_F, "model"), "wx": (_F, "model"),
    "wB": (_F, None), "wC": (_F, None), "wdt": (_F, "model"),
    "conv_x": (None, "model"), "conv_B": (None, None), "conv_C": (None, None),
    "conv_bx": ("model",), "conv_bB": (None,), "conv_bC": (None,),
    "a_log": ("model",), "d_skip": ("model",), "dt_bias": ("model",),
    "norm": ("model",),          # SSM gated-norm scale over d_inner
    "out_proj": ("model", _F),
    # layer norms (d_model,) — small, replicated
    "ln": (None,), "ln1": (None,), "ln2": (None,), "ln_x": (None,),
    "post_attn_ln": (None,), "post_mlp_ln": (None,),
    "final_norm": (None,), "enc_norm": (None,),
}

_CACHE_RULES = {
    # KV caches: trailing (B, S, G, hd) — batch on DP, sequence on model
    "k": (_D, "model", None, None), "v": (_D, "model", None, None),
    # PQ-compressed cache (serve/pqkv.py): codes shard like the exact cache,
    # codebooks are small and replicated, exact rings shard on batch only
    "k_codes": (_D, "model", None, None),
    "v_codes": (_D, "model", None, None),
    "k_books": (None, None, None, None),
    "v_books": (None, None, None, None),
    "k_recent": (_D, None, None, None),
    "v_recent": (_D, None, None, None),
    "self_k": (_D, "model", None, None), "self_v": (_D, "model", None, None),
    "cross_k": (_D, "model", None, None), "cross_v": (_D, "model", None, None),
    "attn_k": (_D, "model", None, None), "attn_v": (_D, "model", None, None),
    # SSM states: trailing (B, H, P, N) / conv (B, ck-1, C)
    "ssd": (_D, "model", None, None),
    "conv_x": (_D, None, "model"), "conv_B": (_D, None, None),
    "conv_C": (_D, None, None),
}

_BATCH_RULES = {
    "tokens": (_D, None), "labels": (_D, None), "token": (_D, None),
    "patches": (_D, None, None), "frames": (_D, None, None),
}


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return fsdp_axes(mesh)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def _resolve(rule, mesh: Mesh, shape, fsdp_enabled: bool = True) -> P:
    fsdp = fsdp_axes(mesh)
    fsdp = fsdp if len(fsdp) > 1 else fsdp[0]
    entries = []
    for e in rule:
        if e is _F and not fsdp_enabled:
            entries.append(None)         # TP-only (serving layout)
        elif e in (_F, _D):
            entries.append(fsdp)
        else:
            entries.append(e)
    # pad leading scan/stack axes with None
    pad = len(shape) - len(entries)
    entries = [None] * pad + entries
    # divisibility guard: replicate any dim the axis does not divide
    out = []
    for dim, e in zip(shape, entries):
        if e is not None and dim % _axis_size(mesh, e) != 0:
            e = None
        out.append(e)
    return P(*out)


def _last_name(path) -> Optional[str]:
    for key in reversed(path):
        if hasattr(key, "name"):
            return key.name
        if hasattr(key, "key"):
            return str(key.key)
    return None


def _specs(tree, mesh: Mesh, rules, fsdp_enabled: bool = True) -> Any:
    def leaf(path, x):
        name = _last_name(path)
        rule = rules.get(name)
        if rule is None or len(rule) > x.ndim:
            return P()
        return _resolve(rule, mesh, x.shape, fsdp_enabled)
    return jax.tree_util.tree_map_with_path(leaf, tree)


def param_specs(params, mesh: Mesh, fsdp: bool = True):
    """PartitionSpecs for model params (and, by structure, Adam moments).

    ``fsdp=False`` gives the TP-only serving layout: weights replicated
    across the DP axes so decode steps never re-gather them (training needs
    FSDP for optimizer-state memory; serving keeps bf16 weights resident).
    """
    return _specs(params, mesh, _PARAM_RULES, fsdp)


def cache_specs(cache, mesh: Mesh):
    return _specs(cache, mesh, _CACHE_RULES)


def batch_specs(batch, mesh: Mesh):
    def leaf(path, x):
        name = _last_name(path)
        rule = _BATCH_RULES.get(name)
        if rule is None or x.ndim == 0:
            return P()
        return _resolve(rule, mesh, x.shape)
    return jax.tree_util.tree_map_with_path(leaf, batch)


def named_shardings(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
