"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_search_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU tests/examples (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_search_mesh(n_devices: int | None = None):
    """1-D ``("search",)`` mesh for the index query planner: the padded
    query batch is sharded across all (or the first ``n_devices``) chips,
    with the index itself replicated.  Degenerates to a 1-device mesh on
    CPU, where the planner's shard_map path is bit-identical to the plain
    vmap path."""
    n = n_devices if n_devices is not None else len(jax.devices())
    return jax.make_mesh((n,), ("search",))
