"""Run the public-API docstring examples as tests.

`repro` is a namespace package (no `src/repro/__init__.py`), which breaks
`pytest --doctest-modules src/...` path collection — so the docs CI job and
tier-1 both come through here: import each documented module and run its
doctests via :mod:`doctest` proper.
"""

import doctest
import importlib

import pytest

MODULES = (
    "repro.core.dispatch",
    "repro.core.pq",
    "repro.index.planner",
    "repro.index.streaming",
    "repro.obs",
)


@pytest.mark.parametrize("name", MODULES)
def test_module_doctests(name):
    mod = importlib.import_module(name)
    result = doctest.testmod(mod, verbose=False, report=True)
    assert result.attempted > 0, f"{name} has no doctest examples"
    assert result.failed == 0, f"{name}: {result.failed} doctest(s) failed"
