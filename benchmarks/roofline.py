"""§Roofline — aggregate the dry-run records into the per-cell roofline
table (compute / memory / collective terms, dominant bound, useful-flop
ratio) and emit the markdown that EXPERIMENTS.md embeds.

Reads experiments/dryrun/*.json produced by ``repro.launch.dryrun``.
"""

from __future__ import annotations

import glob
import json
import os
from typing import List

from .common import Bench, OUT_DIR

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_OUT", "experiments/dryrun")

_ARCH_ORDER = ("qwen2-72b", "gemma2-27b", "minitron-8b", "internlm2-1.8b",
               "seamless-m4t-large-v2", "qwen3-moe-30b-a3b",
               "deepseek-moe-16b", "zamba2-2.7b", "qwen2-vl-72b",
               "mamba2-780m")
_SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load_records(mesh: str = "single", tag: str = "") -> List[dict]:
    recs = []
    for path in glob.glob(os.path.join(DRYRUN_DIR, "*.json")):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh and r.get("tag", "") == tag and r.get("ok"):
            recs.append(r)
    recs.sort(key=lambda r: (_ARCH_ORDER.index(r["arch"]),
                             _SHAPE_ORDER.index(r["shape"])))
    return recs


def markdown_table(recs: List[dict]) -> str:
    lines = [
        "| arch | shape | peak GB/dev | compute s | memory s | collective s"
        " | bound | useful/HLO | roofline frac |",
        "|---|---|---:|---:|---:|---:|---|---:|---:|",
    ]
    for r in recs:
        ro, m = r["roofline"], r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {m['peak_bytes']/1e9:.2f} "
            f"| {ro['compute_s']:.4g} | {ro['memory_s']:.4g} "
            f"| {ro['collective_s']:.4g} | {ro['bound']} "
            f"| {ro['useful_ratio']:.3f} | {ro['roofline_frac']:.3f} |")
    return "\n".join(lines)


def run(quick: bool = True) -> Bench:
    del quick
    b = Bench("roofline")
    for mesh in ("single", "multi"):
        recs = load_records(mesh)
        for r in recs:
            ro = r["roofline"]
            b.add(mesh=mesh, arch=r["arch"], shape=r["shape"],
                  bound=ro["bound"],
                  compute_s=round(ro["compute_s"], 5),
                  memory_s=round(ro["memory_s"], 5),
                  collective_s=round(ro["collective_s"], 5),
                  peak_gb=round(r["memory"]["peak_bytes"] / 1e9, 2),
                  useful_ratio=round(ro["useful_ratio"], 4),
                  roofline_frac=round(ro["roofline_frac"], 4))
    b.save()
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "roofline_table.md"), "w") as f:
        for mesh in ("single", "multi"):
            recs = load_records(mesh)
            if recs:
                f.write(f"### {mesh}-pod mesh\n\n")
                f.write(markdown_table(recs))
                f.write("\n\n")
    return b


if __name__ == "__main__":
    run()
