"""Fig 5c — cost of the MODWT pre-alignment step.

The paper finds pre-alignment has a minor effect on runtime, driven mainly
by the wavelet decomposition level; tail length is immaterial.  We sweep
J (level) and t (tail fraction) and report the encode-path overhead vs the
fixed-split baseline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.modwt import prealign, fixed_segments
from repro.core.pq import PQConfig, encode, fit
from repro.data.timeseries import trace_like

from .common import Bench, timeit


def run(quick: bool = True) -> Bench:
    b = Bench("fig5c_prealign")
    n = 30 if quick else 100
    X, _ = trace_like(n, length=128 if quick else 256, seed=0)
    X = jnp.asarray(X)
    D = X.shape[1]
    M = 4

    base = timeit(lambda: fixed_segments(X, M), repeats=3)
    b.add(mode="fixed", level=0, tail_frac=0.0,
          segment_s=base["median_s"], overhead=1.0)

    for J in ((1, 2, 3) if quick else (1, 2, 3, 4, 5)):
        for tail_frac in (0.1, 0.2):
            tail = max(1, int(round(tail_frac * (D // M))))
            t = timeit(lambda: prealign(X, M, J, tail), repeats=3)
            b.add(mode="modwt", level=J, tail_frac=tail_frac,
                  segment_s=t["median_s"],
                  overhead=t["median_s"] / max(base["median_s"], 1e-9))

    # end-to-end: encode with vs without pre-alignment
    key = jax.random.PRNGKey(0)
    for pre in (False, True):
        cfg = PQConfig(n_sub=M, codebook_size=min(32, X.shape[0]),
                       use_prealign=pre, kmeans_iters=3, dba_iters=1)
        cb = fit(key, X, cfg)
        t = timeit(lambda: encode(X, cb, cfg), repeats=2)
        b.add(mode=f"encode_prealign={pre}", level=cfg.wavelet_level,
              tail_frac=cfg.tail_frac, segment_s=t["median_s"],
              overhead=0.0)
    b.save()
    return b


if __name__ == "__main__":
    run(quick=False)
