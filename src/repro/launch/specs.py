"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

Same pattern as shannon/kernels: weak-type-correct, shardable, zero device
allocation.  ``input_specs`` returns the abstract batch for train/prefill;
decode cells additionally get an abstract cache from ``cache_specs_abstract``.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..configs.registry import ShapeSpec
from ..serve.cache import init_cache

__all__ = ["input_specs", "abstract_cache", "abstract_train_state",
           "abstract_params"]

_S = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract model inputs for one cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": _S((B, S), jnp.int32),
                 "labels": _S((B, S), jnp.int32)}
    elif shape.kind == "prefill":
        batch = {"tokens": _S((B, S), jnp.int32)}
    elif shape.kind == "decode":
        return {"token": _S((B, 1), jnp.int32),
                "pos": _S((), jnp.int32)}
    else:
        raise ValueError(shape.kind)
    if cfg.family == "vlm":
        batch["patches"] = _S((B, cfg.n_frontend_tokens, cfg.d_model),
                              jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = _S((B, cfg.n_frontend_tokens, cfg.d_model),
                             jnp.float32)
    return batch


def abstract_cache(cfg: ModelConfig, shape: ShapeSpec):
    """Abstract KV/state cache for decode cells (no allocation)."""
    return jax.eval_shape(
        functools.partial(init_cache, cfg, shape.global_batch, shape.seq_len))


def abstract_pq_cache(cfg: ModelConfig, shape: ShapeSpec, pqc):
    """Abstract PQ-compressed cache (books included, no allocation)."""
    from ..serve.pqkv import init_pq_cache
    L, G = cfg.n_layers, cfg.n_kv_heads
    hd, M, K = cfg.head_dim_, pqc.n_sub, pqc.codebook_size
    books = _S((L, G, M, K, hd // M), jnp.float32)
    vbooks = books if pqc.quantize_v else None
    return jax.eval_shape(
        functools.partial(init_pq_cache, cfg, pqc, shape.global_batch,
                          shape.seq_len), books, vbooks)


def abstract_params(cfg: ModelConfig):
    from ..train.step import model_init
    init = model_init(cfg)
    return jax.eval_shape(functools.partial(init, cfg=cfg),
                          jax.random.PRNGKey(0))


def abstract_train_state(cfg: ModelConfig):
    from ..train.step import init_train_state
    return jax.eval_shape(functools.partial(init_train_state, cfg=cfg),
                          jax.random.PRNGKey(0))
