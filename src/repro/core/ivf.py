"""IVF-PQDTW: inverted-file index for million-scale elastic search.

Paper §4.1: "To handle million-scale search, a search system with inverted
indexing was developed in the original PQ paper" — this is that system,
adapted to DTW.  A coarse DBA-k-means quantizer over *whole* series routes
each database series to one of ``n_lists`` inverted lists; queries compute
``n_lists`` coarse DTW distances, probe the ``n_probe`` nearest lists, and
evaluate the PQDTW asymmetric distance only for candidates in those lists.

DTW adaptation notes (vs IVFADC): the Euclidean residual trick (encode
``x - c``) is unsound under warping — subtracting unaligned series destroys
shape — so lists share one global PQ codebook over raw series and the coarse
stage is used purely for pruning.  Search cost per query drops from
O(N·M) table look-ups to O(n_lists·D²w) coarse DTWs + O(cap·M) look-ups,
with ``cap`` a static candidate budget (TPU-friendly shapes).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dispatch import elastic_cdist
from .kmeans import dba_kmeans
from .pq import (PQCodebook, PQConfig, _adc_gather, encode, fit,
                 query_lut_batch, segment)

__all__ = ["IVFPQIndex", "build_index", "search", "search_batch"]


class IVFPQIndex(NamedTuple):
    coarse: jnp.ndarray       # (n_lists, D) DBA centroids of whole series
    cb: PQCodebook            # shared PQ codebook (paper §3.1)
    codes: jnp.ndarray        # (N, M) PQ codes, list-sorted order
    ids: jnp.ndarray          # (N,) original indices, list-sorted
    list_start: jnp.ndarray   # (n_lists,) offset of each list in codes/ids
    list_len: jnp.ndarray     # (n_lists,)
    max_list: int             # python int: longest list (static shapes)

    @property
    def n_lists(self) -> int:
        return self.coarse.shape[0]


def build_index(key: jax.Array, X: jnp.ndarray, cfg: PQConfig,
                n_lists: int, coarse_iters: int = 8,
                coarse_window_frac: float = 0.1) -> IVFPQIndex:
    """Train coarse + fine quantizers and populate the inverted lists."""
    X = jnp.asarray(X, jnp.float32)
    N, D = X.shape
    kc, kf = jax.random.split(key)
    w = max(1, int(round(coarse_window_frac * D)))
    res = dba_kmeans(kc, X, n_lists, iters=coarse_iters, dba_iters=1,
                     window=w)
    assign = np.asarray(res.assignment)

    cb = fit(kf, X, cfg)
    codes = np.asarray(encode(X, cb, cfg))

    order = np.argsort(assign, kind="stable")
    sorted_assign = assign[order]
    start = np.searchsorted(sorted_assign, np.arange(n_lists))
    length = np.searchsorted(sorted_assign, np.arange(n_lists), "right") - start
    return IVFPQIndex(
        coarse=res.centroids,
        cb=cb,
        codes=jnp.asarray(codes[order]),
        ids=jnp.asarray(order.astype(np.int32)),
        list_start=jnp.asarray(start.astype(np.int32)),
        list_len=jnp.asarray(length.astype(np.int32)),
        max_list=int(length.max()) if N else 0)


def _candidates(index: IVFPQIndex, probe_lists: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Static-shape candidate slots for ``n_probe`` lists.

    Returns (slots (n_probe*max_list,) int32 into codes/ids, valid mask).
    """
    P = probe_lists.shape[0]
    offs = jnp.arange(index.max_list)
    start = index.list_start[probe_lists]          # (P,)
    length = index.list_len[probe_lists]
    slots = start[:, None] + offs[None, :]         # (P, max_list)
    valid = offs[None, :] < length[:, None]
    slots = jnp.where(valid, slots, 0)
    return slots.reshape(-1), valid.reshape(-1)


def _fine_stage(index: IVFPQIndex, dc: jnp.ndarray, qlut: jnp.ndarray,
                n_probe: int, topk: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Probe the ``n_probe`` nearest lists and rank their candidates with
    the precomputed asymmetric table.  ``dc (n_lists,)``, ``qlut (M, K)``."""
    _, probes = jax.lax.top_k(-dc, n_probe)
    slots, valid = _candidates(index, probes)
    cand_codes = index.codes[slots]                         # (cap, M)
    d = jnp.where(valid, _adc_gather(qlut, cand_codes), jnp.inf)
    neg, best = jax.lax.top_k(-d, topk)
    return -neg, index.ids[slots[best]]


def search(index: IVFPQIndex, q: jnp.ndarray, cfg: PQConfig, *,
           n_probe: int, topk: int = 1,
           coarse_window: Optional[int] = None
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single query ``q (D,)`` -> (distances (topk,), ids (topk,)).

    Coarse stage: banded DTW to all list centroids; fine stage: asymmetric
    PQDTW over the probed lists' candidates only.
    """
    d, ids = search_batch(index, q[None, :], cfg, n_probe=n_probe,
                          topk=topk, coarse_window=coarse_window)
    return d[0], ids[0]


def search_batch(index: IVFPQIndex, Q: jnp.ndarray, cfg: PQConfig, *,
                 n_probe: int, topk: int = 1,
                 coarse_window: Optional[int] = None):
    """Batched search over queries ``Q (Nq, D)``.

    The coarse DTW stage and the asymmetric query tables are computed for
    the whole batch in two dispatch-layer launches (Pallas kernels on TPU);
    only the cheap probe/gather/top-k tail is vmapped.
    """
    Q = jnp.asarray(Q, jnp.float32)
    D = Q.shape[-1]
    w = coarse_window if coarse_window is not None else max(
        1, int(round(0.1 * D)))
    dc = elastic_cdist(Q, index.coarse, w)                  # (Nq, n_lists)
    q_segs = segment(Q, cfg)                                # (Nq, M, S)
    qluts = query_lut_batch(q_segs, index.cb, cfg.window(D),
                            cfg.metric != "dtw")            # (Nq, M, K)
    fn = lambda dcr, ql: _fine_stage(index, dcr, ql, n_probe, topk)
    return jax.vmap(fn)(dc, qluts)
