"""RS3xx — serving-layer concurrency discipline.

The ``serve_index`` threading model (PR 8): one writer thread owns all
mutable index state and publishes immutable frozen ``IndexView``
snapshots by atomic rebind; readers only ever touch a captured view;
all lock/condition use goes through ``with`` blocks.

* **RS301** a field named in a class's ``_WRITER_ONLY`` set is assigned
  outside ``__init__`` / the methods named in ``_WRITER_METHODS`` —
  i.e. off the writer thread.
* **RS302** attribute assignment on a published view object (a local
  bound from ``*.capture(...)`` or read from ``.view``/``._view``) —
  views are immutable after publish; build a new one instead.
* **RS303** bare ``.acquire()``/``.release()`` on a lock-like object in
  ``repro.serve_index`` — pairing by hand leaks on exceptions; use
  ``with``.
"""

from __future__ import annotations

import ast
from typing import List, Set

from .callgraph import CallGraph, FunctionInfo, ModuleInfo
from .findings import Finding

__all__ = ["run"]

_VIEW_ATTRS = frozenset({"view", "_view"})
_LOCK_MODULE_PREFIX = "repro.serve_index"


def _line(mod: ModuleInfo, lineno: int) -> str:
    lines = mod.source.splitlines()
    return lines[lineno - 1] if 0 < lineno <= len(lines) else ""


def run(graph: CallGraph) -> List[Finding]:
    out: List[Finding] = []
    for mod in graph.modules.values():
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                out.extend(_rs301(mod, node))
    for info in graph.functions.values():
        out.extend(_rs302(info))
        if info.module.qualname.startswith(_LOCK_MODULE_PREFIX):
            out.extend(_rs303(info))
    return out


# -- RS301 -------------------------------------------------------------------

def _class_name_set(cls: ast.ClassDef, attr: str) -> Set[str]:
    for stmt in cls.body:
        if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
                and stmt.targets[0].id == attr):
            return {n.value for n in ast.walk(stmt.value)
                    if isinstance(n, ast.Constant)
                    and isinstance(n.value, str)}
    return set()


def _rs301(mod: ModuleInfo, cls: ast.ClassDef) -> List[Finding]:
    writer_only = _class_name_set(cls, "_WRITER_ONLY")
    if not writer_only:
        return []
    writer_methods = _class_name_set(cls, "_WRITER_METHODS") | {"__init__"}
    out = []
    for stmt in cls.body:
        if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if stmt.name in writer_methods:
            continue
        for n in ast.walk(stmt):
            targets = []
            if isinstance(n, ast.Assign):
                targets = n.targets
            elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
                targets = [n.target]
            for t in targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"
                        and t.attr in writer_only):
                    out.append(Finding(
                        rule="RS301", path=mod.path, lineno=n.lineno,
                        scope=f"{mod.qualname}.{cls.name}.{stmt.name}",
                        message=f"writer-only field self.{t.attr} "
                                f"assigned outside the writer methods "
                                f"({', '.join(sorted(writer_methods))})",
                        source_line=_line(mod, n.lineno)))
    return out


# -- RS302 -------------------------------------------------------------------

def _view_locals(info: FunctionInfo) -> Set[str]:
    """Local names bound from ``*.capture(...)`` or ``.view``/``._view``."""
    names: Set[str] = set()
    for n in ast.walk(info.node):
        if not isinstance(n, ast.Assign) or len(n.targets) != 1:
            continue
        t = n.targets[0]
        if not isinstance(t, ast.Name):
            continue
        v = n.value
        if (isinstance(v, ast.Call) and isinstance(v.func, ast.Attribute)
                and v.func.attr == "capture"):
            names.add(t.id)
        elif isinstance(v, ast.Attribute) and v.attr in _VIEW_ATTRS:
            names.add(t.id)
    return names


def _rs302(info: FunctionInfo) -> List[Finding]:
    # the view module itself may build instances however it likes
    if info.module.qualname.endswith(".view"):
        return []
    views = _view_locals(info)
    if not views:
        return []
    out = []
    for n in ast.walk(info.node):
        hit = None
        if isinstance(n, (ast.Assign, ast.AugAssign)):
            targets = n.targets if isinstance(n, ast.Assign) else [n.target]
            for t in targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id in views):
                    hit = f"{t.value.id}.{t.attr} = ..."
        elif (isinstance(n, ast.Call)
              and isinstance(n.func, ast.Attribute)
              and n.func.attr == "__setattr__"
              and n.args and isinstance(n.args[0], ast.Name)
              and n.args[0].id in views):
            hit = f"object.__setattr__({n.args[0].id}, ...)"
        if hit is not None:
            out.append(Finding(
                rule="RS302", path=info.module.path, lineno=n.lineno,
                scope=info.qualname,
                message=f"{hit} mutates a published IndexView; views are "
                        f"immutable after publish — capture a new one",
                source_line=_line(info.module, n.lineno)))
    return out


# -- RS303 -------------------------------------------------------------------

def _rs303(info: FunctionInfo) -> List[Finding]:
    out = []
    for n in ast.walk(info.node):
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
                and n.func.attr in ("acquire", "release")):
            out.append(Finding(
                rule="RS303", path=info.module.path, lineno=n.lineno,
                scope=info.qualname,
                message=f"bare .{n.func.attr}() pairs the lock by hand "
                        f"and leaks on exceptions; use `with`",
                source_line=_line(info.module, n.lineno)))
    return out
