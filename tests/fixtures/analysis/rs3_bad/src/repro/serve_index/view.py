"""Frozen view; the .view module is exempt from RS302 internally."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class IndexView:
    version: int

    @classmethod
    def capture(cls, index, version=0):
        del index
        return cls(version=version)
