#!/usr/bin/env python3
"""Fail CI when the docs rot: every greppable identifier that docs/ or
README.md references must still exist in the source.

Usage: python scripts/check_docs.py [--root PATH]

Checked reference classes:

* ``dispatch.<op>`` tokens -> the name must appear in
  ``src/repro/core/dispatch.py``;
* backtick-quoted dotted stage names whose first component is a known
  span namespace (``index``, ``sharded``, ``serve``, ``serving``,
  ``service``), plus ``stage="..."`` label examples -> the stage string
  must appear quoted somewhere under ``src/``, ``examples/``,
  ``scripts/`` or ``benchmarks/``;
* ``repro_<metric>`` Prometheus tokens -> the unprefixed metric name
  must appear as a quoted string under ``src/``;
* ``snapshot format N`` mentions -> ``N`` must be in
  ``_SUPPORTED_FORMATS`` of ``src/repro/index/snapshot.py``;
* ``--flags`` on ``python <script>.py`` / ``python -m <module>`` command
  lines -> the flag must appear in the named file;
* ``RSxxx`` static-analysis rule IDs -> the ID must exist (quoted) in
  the ``src/repro/analysis`` rule engine;
* ``REPRO_*`` environment-variable tokens -> the variable name must
  appear quoted somewhere under the source dirs (a doc that advertises
  a knob the code no longer reads is stale).

``--root`` exists so the negative test can point the gate at a doctored
tree and assert it fails; CI runs it against the repo root.
"""

from __future__ import annotations

import argparse
import os
import re
import sys
from typing import Dict, List, Tuple

STAGE_NAMESPACES = ("index", "sharded", "serve", "serving", "service")
SOURCE_DIRS = ("src", "examples", "scripts", "benchmarks")

DOTTED = re.compile(r"`([a-z_]+(?:\.[a-z_]+)+)`")
STAGE_LABEL = re.compile(r'stage="([a-z_.]+)"')
DISPATCH_OP = re.compile(r"\bdispatch\.([a-z_]+)\b")
PROM_METRIC = re.compile(r"\brepro_([a-z_]+)\b")
FORMAT_REF = re.compile(r"\bformats?\s+(\d+)(?:\s*[-–]\s*(\d+))?")
CMD_LINE = re.compile(r"\bpython(?:3)?\s+(?:-m\s+([\w.]+)|([\w./-]+\.py))")
FLAG = re.compile(r"(--[\w-]+)")
RS_RULE = re.compile(r"\bRS\d{3}\b")
ENV_VAR = re.compile(r"\b(REPRO_[A-Z][A-Z0-9_]*)\b")


def _read(path: str) -> str:
    with open(path, encoding="utf-8") as f:
        return f.read()


def _doc_files(root: str) -> List[str]:
    out = []
    readme = os.path.join(root, "README.md")
    if os.path.exists(readme):
        out.append(readme)
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        for name in sorted(os.listdir(docs)):
            if name.endswith(".md"):
                out.append(os.path.join(docs, name))
    return out


def _source_text(root: str, subdirs: Tuple[str, ...]) -> str:
    chunks = []
    for sub in subdirs:
        top = os.path.join(root, sub)
        for dirpath, _, files in os.walk(top):
            for name in files:
                if name.endswith(".py"):
                    chunks.append(_read(os.path.join(dirpath, name)))
    return "\n".join(chunks)


def _supported_formats(root: str) -> List[int]:
    path = os.path.join(root, "src", "repro", "index", "snapshot.py")
    if not os.path.exists(path):
        return []
    text = _read(path)
    m = re.search(r"_SUPPORTED_FORMATS\s*=\s*\(([\d,\s]+)\)", text)
    if m:
        return [int(x) for x in m.group(1).split(",") if x.strip()]
    m = re.search(r"_FORMAT\s*=\s*(\d+)", text)
    return [int(m.group(1))] if m else []


def _resolves_as_module(root: str, dotted: str) -> bool:
    """True when a dotted token is a live module path under ``src/repro``
    (``index.placement``), optionally with trailing attributes that appear
    in the module's text (``index.planner.search_sharded``)."""
    parts = dotted.split(".")
    base = os.path.join(root, "src", "repro")
    for i in range(len(parts), 0, -1):
        cand = os.path.join(base, *parts[:i])
        target = None
        if os.path.exists(cand + ".py"):
            target = cand + ".py"
        elif os.path.isdir(cand):
            if i == len(parts):
                return True
            init = os.path.join(cand, "__init__.py")
            target = init if os.path.exists(init) else None
        if target is None:
            continue
        if i == len(parts):
            return True
        text = _read(target)
        return all(re.search(rf"\b{re.escape(p)}\b", text) for p in parts[i:])
    return False


def check_file(
    path: str,
    dispatch_src: str,
    stage_src: str,
    metric_src: str,
    formats: List[int],
    root: str,
    analysis_src: str = "",
) -> List[str]:
    errors = []
    rel = os.path.relpath(path, root)
    text = _read(path)

    for op in sorted(set(DISPATCH_OP.findall(text))):
        if not re.search(rf"\b{re.escape(op)}\b", dispatch_src):
            errors.append(f"{rel}: dispatch.{op} not found in core/dispatch.py")

    stages = {
        s
        for s in DOTTED.findall(text)
        if s.split(".", 1)[0] in STAGE_NAMESPACES
    }
    stages.update(STAGE_LABEL.findall(text))
    for stage in sorted(stages):
        quoted = f'"{stage}"' in stage_src or f"'{stage}'" in stage_src
        if not quoted and not _resolves_as_module(root, stage):
            errors.append(f"{rel}: stage {stage!r} not found in source")

    for env in sorted(set(ENV_VAR.findall(text))):
        quoted = f'"{env}"' in stage_src or f"'{env}'" in stage_src
        if not quoted:
            errors.append(
                f"{rel}: env var {env} has no quoted reference in source",
            )

    for rule in sorted(set(RS_RULE.findall(text))):
        if f'"{rule}"' not in analysis_src and f"'{rule}'" not in analysis_src:
            errors.append(
                f"{rel}: static-analysis rule {rule} not found in "
                f"src/repro/analysis",
            )

    for metric in sorted(set(PROM_METRIC.findall(text))):
        if f'"{metric}"' not in metric_src and f"'{metric}'" not in metric_src:
            errors.append(
                f"{rel}: metric repro_{metric} has no quoted "
                f"{metric!r} in src/",
            )

    if formats:
        for m in FORMAT_REF.finditer(text):
            nums = [int(m.group(1))]
            if m.group(2):
                nums.append(int(m.group(2)))
            for n in nums:
                if n not in formats:
                    errors.append(
                        f"{rel}: snapshot format {n} not in supported "
                        f"formats {formats}",
                    )

    for line in text.splitlines():
        cmd = CMD_LINE.search(line)
        if not cmd:
            continue
        module, script = cmd.group(1), cmd.group(2)
        target = (
            os.path.join(root, module.replace(".", os.sep) + ".py")
            if module
            else os.path.join(root, script)
        )
        if not os.path.exists(target):
            continue  # external module (pytest, ...) or absolute example
        target_text = _read(target)
        for flag in FLAG.findall(line):
            if flag not in target_text:
                errors.append(
                    f"{rel}: flag {flag} not found in "
                    f"{os.path.relpath(target, root)}",
                )
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=".")
    args = ap.parse_args()
    root = args.root

    docs = _doc_files(root)
    if not docs:
        print(f"FAIL: no README.md or docs/*.md under {root}")
        return 1

    dispatch_path = os.path.join(root, "src", "repro", "core", "dispatch.py")
    dispatch_src = _read(dispatch_path) if os.path.exists(dispatch_path) else ""
    stage_src = _source_text(root, SOURCE_DIRS)
    metric_src = _source_text(root, ("src",))
    formats = _supported_formats(root)
    analysis_dir = os.path.join("src", "repro", "analysis")
    if os.path.isdir(os.path.join(root, analysis_dir)):
        analysis_src = _source_text(root, (analysis_dir,))
    else:
        analysis_src = ""

    counts: Dict[str, int] = {}
    errors: List[str] = []
    for path in docs:
        errs = check_file(
            path, dispatch_src, stage_src, metric_src, formats, root, analysis_src
        )
        counts[os.path.relpath(path, root)] = len(errs)
        errors.extend(errs)

    for rel in sorted(counts):
        print(f"  {rel}: {counts[rel]} stale reference(s)")
    if errors:
        print(f"FAIL: {len(errors)} stale doc reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"OK: {len(docs)} doc file(s) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
