"""Static analysis for the repro engine.

An AST-based rule engine over ``src/repro``: a best-effort call graph
(:mod:`repro.analysis.callgraph`) feeds three rule families —

* **RS1xx** trace safety (:mod:`repro.analysis.rules_trace`): no host
  syncs or data-dependent Python control flow on jit-reachable paths;
* **RS2xx** dispatch invariants (:mod:`repro.analysis.rules_dispatch`):
  every kernel triple registered, referenced, routing-gated, and never
  vmapped over;
* **RS3xx** concurrency discipline
  (:mod:`repro.analysis.rules_concurrency`): writer-only state, immutable
  published views, ``with``-scoped locks in ``serve_index``.

Driven by ``scripts/check_static.py``; findings are suppressed inline
with ``# repro: ignore[RSxxx] <reason>`` or frozen in the committed
``STATIC_BASELINE.json``.  See ``docs/static_analysis.md`` for the rule
catalog.
"""

from .engine import RULES, Report, analyze
from .findings import Finding

__all__ = ["RULES", "Report", "analyze", "Finding"]
