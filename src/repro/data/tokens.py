"""Deterministic synthetic token pipeline for LM training.

Every batch is a pure function of (seed, step) — restart-safe by
construction: after checkpoint restore at step k, the stream resumes at the
exact batch k+1 on any host layout.  The generator synthesizes structured
sequences (a Zipfian unigram mix with short-range repetition) so tiny models
have something learnable — loss decreases measurably within a few hundred
steps, which the integration tests assert.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["TokenStream"]


class TokenStream:
    def __init__(self, vocab_size: int, seq_len: int, batch: int,
                 seed: int = 0, repeat_period: int = 16,
                 extras: Optional[Dict[str, tuple]] = None):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.repeat_period = repeat_period
        self.extras = extras or {}
        # Zipf-ish unigram distribution
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self._p = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        base = rng.choice(self.vocab_size, size=(self.batch, self.seq_len + 1),
                          p=self._p).astype(np.int32)
        # inject learnable short-range structure: token at t repeats t-P
        # with high probability in the second half of each period
        t = np.arange(self.seq_len + 1)
        recall = (t % self.repeat_period) >= self.repeat_period // 2
        src = np.maximum(t - self.repeat_period // 2, 0)
        gate = rng.random((self.batch, self.seq_len + 1)) < 0.8
        rep = base[:, src]
        tokens_full = np.where(recall[None, :] & gate, rep, base)
        out = {"tokens": tokens_full[:, :-1],
               "labels": tokens_full[:, 1:].astype(np.int32)}
        for name, shape in self.extras.items():
            out[name] = rng.standard_normal((self.batch, *shape)).astype(
                np.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
