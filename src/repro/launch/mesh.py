"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_search_mesh",
           "validate_search_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU tests/examples (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_search_mesh(n_devices: int | None = None):
    """1-D ``("search",)`` mesh for the index query planner.

    Both planner strategies run over this axis: query-sharded search
    splits the padded batch across it (index replicated), list-sharded
    search splits the sealed inverted lists across it (queries
    replicated, partial top-k fanned in with an ``all_gather``).
    Degenerates to a 1-device mesh on CPU, where the planner's shard_map
    path is bit-identical to the plain vmap path."""
    n = n_devices if n_devices is not None else len(jax.devices())
    return jax.make_mesh((n,), ("search",))


def validate_search_mesh(mesh, n_shards: int) -> None:
    """Reject a mesh whose ``search`` axis disagrees with a data-partition
    count ``n_shards`` — a clear error at plan time instead of a shape
    error inside ``shard_map``."""
    if "search" not in mesh.shape:
        raise ValueError(
            f"expected a 1-D ('search',) mesh, got axes {mesh.axis_names}")
    n_dev = mesh.shape["search"]
    if n_shards != n_dev:
        raise ValueError(
            f"index layout is sealed for n_shards={n_shards} but the mesh "
            f"has {n_dev} devices on its 'search' axis — reseal the index "
            f"(IndexConfig(n_shards={n_dev}) + compact()) or build the "
            f"mesh with make_search_mesh({n_shards})")
