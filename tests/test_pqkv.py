"""PQ-compressed KV cache: invariants + end-to-end decode agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.models.config import ModelConfig
from repro.models.lm import init_params
from repro.serve.cache import init_cache
from repro.serve.decode import serve_step
from repro.serve.pqkv import (PQKVConfig, compress_cache, decode_kv,
                              encode_kv, fit_kv_books, init_pq_cache,
                              pq_attention_decode, pq_serve_step, pqkv_memory)

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16)


def _rand_books(key, G, M, K, Ds):
    return jax.random.normal(key, (G, M, K, Ds), jnp.float32)


class TestCodec:
    def test_roundtrip_exact_on_codewords(self):
        key = jax.random.PRNGKey(0)
        G, M, K, Ds = 2, 4, 16, 4
        books = _rand_books(key, G, M, K, Ds)
        codes = jax.random.randint(key, (8, G, M), 0, K)
        vecs = decode_kv(codes, books)
        codes2 = encode_kv(vecs.reshape(8, G, M * Ds), books)
        np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes2))

    def test_encode_picks_nearest(self):
        key = jax.random.PRNGKey(1)
        G, M, K, Ds = 1, 2, 8, 4
        books = _rand_books(key, G, M, K, Ds)
        x = jax.random.normal(jax.random.PRNGKey(2), (5, G, M * Ds))
        codes = encode_kv(x, books)
        xs = np.asarray(x).reshape(5, G, M, Ds)
        bb = np.asarray(books)
        for n in range(5):
            for m in range(M):
                d = ((bb[0, m] - xs[n, 0, m]) ** 2).sum(-1)
                assert codes[n, 0, m] == d.argmin()

    def test_fit_books_shape(self):
        kv = jax.random.normal(jax.random.PRNGKey(0), (2, 2, 32, 2, 16))
        pqc = PQKVConfig(n_sub=4, codebook_size=8, kmeans_iters=2)
        books = fit_kv_books(jax.random.PRNGKey(1), kv, pqc)
        assert books.shape == (2, 2, 4, 8, 4)
        assert not np.isnan(np.asarray(books)).any()


def _exact_attn(q, k, v, pos):
    """Oracle: full-precision masked decode attention."""
    B, G, R, hd = q.shape
    S = k.shape[1]
    scores = jnp.einsum("bgrh,bsgh->bgrs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    mask = jnp.arange(S) <= pos
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, -1)
    return jnp.einsum("bgrs,bsgh->bgrh", p, v.astype(jnp.float32))


class TestDecodeAttention:
    def _setup(self, S=32, W=8, quantize_v=False, K=16):
        key = jax.random.PRNGKey(0)
        B, G, R, hd, M = 2, 2, 2, 16, 4
        ks = jax.random.split(key, 5)
        q = jax.random.normal(ks[0], (B, G, R, hd))
        k = jax.random.normal(ks[1], (B, S, G, hd))
        v = jax.random.normal(ks[2], (B, S, G, hd))
        books = _rand_books(ks[3], G, M, K, hd // M)
        vbooks = _rand_books(ks[4], G, M, K, hd // M)
        codes = encode_kv(k, books)
        ring_k = jnp.zeros((B, W, G, hd))
        ring_v = jnp.zeros((B, W, G, hd))
        for p in range(S):
            ring_k = ring_k.at[:, p % W].set(k[:, p])
            ring_v = ring_v.at[:, p % W].set(v[:, p])
        if quantize_v:
            vcodes = encode_kv(v, vbooks)
            lc = (codes, books, None, vcodes, vbooks, ring_k, ring_v)
        else:
            lc = (codes, books, v, None, None, ring_k, ring_v)
        return q, k, v, lc

    def test_exact_when_window_covers_everything(self):
        """W >= S: every position is refined exactly -> matches the oracle
        bit-for-bit regardless of (random) codebooks."""
        S = 16
        q, k, v, lc = self._setup(S=S, W=S)
        pos = S - 1
        out = pq_attention_decode(q, lc, jnp.int32(pos),
                                  pqc=PQKVConfig(recent_window=S))
        ref = _exact_attn(q, k, v, pos)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), rtol=2e-2, atol=2e-2)

    def test_exact_when_keys_are_codewords(self):
        """Keys drawn exactly from the codebook: ADC scores are exact."""
        key = jax.random.PRNGKey(3)
        B, S, G, R, hd, M, K, W = 1, 24, 2, 2, 16, 4, 8, 4
        books = _rand_books(key, G, M, K, hd // M)
        codes = jax.random.randint(key, (B, S, G, M), 0, K)
        k = jax.vmap(lambda c: decode_kv(c, books))(codes)
        k = k.reshape(B, S, G, hd)
        v = jax.random.normal(jax.random.PRNGKey(4), (B, S, G, hd))
        q = jax.random.normal(jax.random.PRNGKey(5), (B, G, R, hd))
        ring_k = jnp.zeros((B, W, G, hd))
        ring_v = jnp.zeros((B, W, G, hd))
        for p in range(S):
            ring_k = ring_k.at[:, p % W].set(k[:, p])
            ring_v = ring_v.at[:, p % W].set(v[:, p])
        lc = (codes, books, v, None, None, ring_k, ring_v)
        pos = S - 1
        out = pq_attention_decode(q, lc, jnp.int32(pos),
                                  pqc=PQKVConfig(n_sub=M, codebook_size=K,
                                                 recent_window=W))
        ref = _exact_attn(q, k, v, pos)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), rtol=1e-2, atol=1e-2)

    def test_quantized_values_mass_aggregation(self):
        """quantize_v: output equals attention against reconstructed values."""
        q, k, v, lc = self._setup(S=16, W=4, quantize_v=True)
        codes, books, _, vcodes, vbooks, ring_k, ring_v = lc
        pos = 15
        pqc = PQKVConfig(n_sub=4, codebook_size=16, recent_window=4,
                         quantize_v=True)
        out = pq_attention_decode(q, lc, jnp.int32(pos), pqc=pqc)
        # oracle: reconstruct keys+values, exact window overrides, softmax
        khat = jax.vmap(lambda c: decode_kv(c, books))(codes).reshape(k.shape)
        vhat = jax.vmap(lambda c: decode_kv(c, vbooks))(vcodes).reshape(v.shape)
        W = 4
        S = 16
        in_recent = (jnp.arange(S) > pos - W) & (jnp.arange(S) <= pos)
        k_mix = jnp.where(in_recent[None, :, None, None], k, khat)
        v_mix = jnp.where(in_recent[None, :, None, None], v, vhat)
        ref = _exact_attn(q, k_mix, v_mix, pos)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), rtol=1e-2, atol=1e-2)

    def test_topk_covers_softmax_when_t_is_s(self):
        """top-T with T = S reduces to the dense softmax path."""
        q, k, v, lc = self._setup(S=16, W=4)
        pos = 15
        dense = pq_attention_decode(q, lc, jnp.int32(pos),
                                    pqc=PQKVConfig(recent_window=4))
        sparse = pq_attention_decode(q, lc, jnp.int32(pos),
                                     pqc=PQKVConfig(recent_window=4,
                                                    mode="topk", top_t=16))
        np.testing.assert_allclose(np.asarray(dense, np.float32),
                                   np.asarray(sparse, np.float32),
                                   rtol=2e-2, atol=2e-2)


@pytest.mark.slow
class TestServeStep:
    def test_pq_serve_matches_exact_when_ring_covers(self):
        """End-to-end: W >= Smax makes PQ decode == exact decode."""
        cfg = CFG
        Smax = 16
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        cache = init_cache(cfg, batch=2, max_len=Smax)

        # drive 6 exact decode steps to populate the cache
        toks = jax.random.randint(key, (2, 7), 0, cfg.vocab_size)
        for p in range(6):
            _, cache = serve_step(params, cfg, cache, toks[:, p:p + 1], p)

        pqc = PQKVConfig(n_sub=4, codebook_size=8, recent_window=Smax,
                         kmeans_iters=2)
        pq_cache = compress_cache({"k": cache["k"], "v": cache["v"]},
                                  cfg, pqc, pos=6)
        logits_pq, _ = pq_serve_step(params, cfg, pq_cache,
                                     toks[:, 6:7], 6, pqc=pqc)
        logits_ref, _ = serve_step(params, cfg, cache, toks[:, 6:7], 6)
        np.testing.assert_allclose(np.asarray(logits_pq, np.float32),
                                   np.asarray(logits_ref, np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_pq_serve_approximates_with_small_window(self):
        """W < pos: tail positions are ADC-approximated; logits stay close
        because codebooks are fit on the very keys they encode."""
        cfg = CFG
        Smax = 32
        key = jax.random.PRNGKey(1)
        params = init_params(key, cfg)
        cache = init_cache(cfg, batch=2, max_len=Smax)
        toks = jax.random.randint(key, (2, 17), 0, cfg.vocab_size)
        for p in range(16):
            _, cache = serve_step(params, cfg, cache, toks[:, p:p + 1], p)
        pqc = PQKVConfig(n_sub=4, codebook_size=16, recent_window=4,
                         kmeans_iters=8)
        pq_cache = compress_cache({"k": cache["k"], "v": cache["v"]},
                                  cfg, pqc, pos=16)
        logits_pq, new_pq = pq_serve_step(params, cfg, pq_cache,
                                          toks[:, 16:17], 16, pqc=pqc)
        logits_ref, _ = serve_step(params, cfg, cache, toks[:, 16:17], 16)
        a = np.asarray(logits_pq, np.float32).ravel()
        b = np.asarray(logits_ref, np.float32).ravel()
        assert not np.isnan(a).any()
        # rank correlation of the logits stays high under quantization
        corr = np.corrcoef(a, b)[0, 1]
        assert corr > 0.98, corr
        # cache was updated at pos
        assert new_pq.k_codes.shape == pq_cache.k_codes.shape

    def test_moe_family_supported(self):
        cfg = ModelConfig(name="tinymoe", family="moe", n_layers=2,
                          d_model=32, n_heads=4, n_kv_heads=2, d_ff=0,
                          vocab_size=64, head_dim=8, n_experts=4,
                          n_active_experts=2, moe_d_ff=16)
        params = init_params(jax.random.PRNGKey(0), cfg)
        pqc = PQKVConfig(n_sub=2, codebook_size=4, recent_window=8,
                         kmeans_iters=2)
        books = fit_kv_books(jax.random.PRNGKey(1),
                             jax.random.normal(jax.random.PRNGKey(2),
                                               (2, 1, 16, 2, 8)), pqc)
        pq_cache = init_pq_cache(cfg, pqc, batch=1, max_len=16, books=books)
        tok = jnp.zeros((1, 1), jnp.int32)
        logits, _ = pq_serve_step(params, cfg, pq_cache, tok, 0, pqc=pqc)
        assert logits.shape == (1, 1, cfg.padded_vocab)
        assert not np.isnan(np.asarray(logits, np.float32)).any()


class TestMemory:
    def test_compression_factor(self):
        from repro.configs.registry import get_config
        cfg = get_config("qwen2-72b")      # pure arithmetic, no allocation
        pqc = PQKVConfig(n_sub=8, codebook_size=256, recent_window=128)
        mem = pqkv_memory(cfg, pqc, batch=1, seq_len=4096)
        # keys 2*hd bytes -> M bytes; values exact: ~2x overall
        assert 1.5 < mem["compression"] < 2.5
        full = pqkv_memory(cfg, PQKVConfig(n_sub=8, codebook_size=256,
                                           recent_window=128,
                                           quantize_v=True),
                           batch=1, seq_len=4096)
        assert full["compression"] > mem["compression"]

    def test_books_negligible(self):
        cfg = get_reduced("qwen2-72b")
        pqc = PQKVConfig()
        mem = pqkv_memory(cfg, pqc, batch=4, seq_len=32768)
        assert mem["books_bytes"] < 0.05 * mem["pq_bytes"]
