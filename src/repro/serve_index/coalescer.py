"""Request-coalescing query batcher.

Queries submitted from any number of client threads are merged into one
padded device launch per coalescing window: the first pending request
opens a window of ``ServeConfig.coalesce_window_s``, every request
arriving before it closes (or before the batch reaches the largest
bucket) joins the batch, and the batch launches at the smallest
``q_buckets`` size that fits — real rows flagged by a ``q_valid`` mask,
exactly like the sharded planner's padded query blocks.  Because the
launch shapes are drawn from the finite bucket family, a warmed server
answers arbitrary mixed traffic from a handful of compiled executables;
``tests/test_serving.py`` asserts (via the trace-time dispatch counters)
that steady-state traffic triggers zero new compilations.

The coalescer is index-agnostic: it owns request queuing and padding and
delegates the actual search to a ``run_batch(Q_padded, q_valid, n_real)``
callable (the server's, which binds the current :class:`~repro.
serve_index.view.IndexView`).  A failed batch fails every request in it;
later batches are unaffected.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Tuple

import jax.numpy as jnp
import numpy as np

from .. import obs
from .config import ServeConfig

__all__ = ["QueryCoalescer"]


class _Pending:
    __slots__ = ("Q", "future", "t_submit")

    def __init__(self, Q: np.ndarray, future: Future):
        self.Q = Q
        self.future = future
        self.t_submit = time.monotonic()


def _chain_chunks(futures: List[Future]) -> Future:
    """One future resolving to the row-concatenation of chunk futures
    (for requests larger than the largest bucket)."""
    out: Future = Future()
    remaining = [len(futures)]
    lock = threading.Lock()

    def done(_):
        with lock:
            remaining[0] -= 1
            if remaining[0]:
                return
        try:
            parts = [f.result() for f in futures]
        except BaseException as e:           # noqa: BLE001 - forwarded
            out.set_exception(e)
            return
        first = parts[0]
        out.set_result(first._replace(
            dist=jnp.concatenate([p.dist for p in parts], axis=0),
            ids=jnp.concatenate([p.ids for p in parts], axis=0),
            version=min(p.version for p in parts)))

    for f in futures:
        f.add_done_callback(done)
    return out


class QueryCoalescer:
    """Batches concurrent search requests into bucketed padded launches."""

    def __init__(self, run_batch: Callable, cfg: ServeConfig):
        self._run_batch = run_batch
        self.cfg = cfg
        self._pending: List[_Pending] = []
        self._pending_rows = 0
        self._cond = threading.Condition()
        self._stop = False
        self._thread: threading.Thread = threading.Thread(
            target=self._loop, name="repro-serve-coalescer", daemon=True)

    # -- client side ---------------------------------------------------------

    def start(self) -> None:
        self._thread.start()

    def stop(self) -> None:
        """Stop the worker; already-queued requests are still answered."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._thread.join()

    def submit(self, Q: np.ndarray) -> Future:
        """Enqueue ``Q (n, D)``; resolves to a ``SearchResult``.  Requests
        wider than the largest bucket are split into bucket-sized chunks
        (their results re-concatenated transparently)."""
        maxb = self.cfg.max_batch
        if Q.shape[0] > maxb:
            futs = [self._submit_one(Q[i:i + maxb])
                    for i in range(0, Q.shape[0], maxb)]
            return _chain_chunks(futs)
        return self._submit_one(Q)

    def _submit_one(self, Q: np.ndarray) -> Future:
        fut: Future = Future()
        with self._cond:
            if self._stop:
                raise RuntimeError("coalescer is stopped")
            self._pending.append(_Pending(Q, fut))
            self._pending_rows += Q.shape[0]
            if obs.enabled():
                obs.gauge("serving_pending_queries",
                          persistent=True).set(self._pending_rows)
            self._cond.notify_all()
        return fut

    # -- worker side ---------------------------------------------------------

    def _take_batch(self) -> List[_Pending]:
        """Block until a batch is ready (window elapsed or bucket full);
        returns [] only when stopping with nothing queued."""
        maxb = self.cfg.max_batch
        with self._cond:
            while not self._pending and not self._stop:
                self._cond.wait()
            if not self._pending:
                return []
            deadline = self._pending[0].t_submit + self.cfg.coalesce_window_s
            while (not self._stop and self._pending_rows < maxb
                   and (left := deadline - time.monotonic()) > 0):
                self._cond.wait(timeout=left)
            batch, rows = [], 0
            while self._pending and rows + self._pending[0].Q.shape[0] <= maxb:
                p = self._pending.pop(0)
                rows += p.Q.shape[0]
                batch.append(p)
            self._pending_rows -= rows
            if obs.enabled():
                obs.gauge("serving_pending_queries",
                          persistent=True).set(self._pending_rows)
            return batch

    def _loop(self) -> None:
        while True:
            batch = self._take_batch()
            if not batch:
                return                        # stopped and drained
            self._execute(batch)

    def _execute(self, batch: List[_Pending]) -> None:
        n_real = sum(p.Q.shape[0] for p in batch)
        bucket = self.cfg.bucket_for(n_real)
        D = batch[0].Q.shape[1]
        Qp = np.zeros((bucket, D), np.float32)
        Qp[:n_real] = np.concatenate([p.Q for p in batch], axis=0)
        q_valid = np.arange(bucket) < n_real
        try:
            with obs.span("serving.batch_search") as sp:
                result = self._run_batch(jnp.asarray(Qp),
                                         jnp.asarray(q_valid), n_real)
                sp.fence((result.dist, result.ids))
        except BaseException as e:            # noqa: BLE001 - forwarded
            for p in batch:
                p.future.set_exception(e)
            return
        if obs.enabled():
            obs.counter("serving_batches_total", persistent=True,
                        bucket=str(bucket)).inc()
            obs.counter("serving_queries_total", persistent=True).inc(n_real)
            # bucket bounds derive from q_buckets, so the layout is part
            # of the metric identity: servers with different configs in
            # one process get distinct series instead of a get-or-create
            # bucket-mismatch error in the coalescer thread
            obs.histogram("serving_batch_queries", persistent=True,
                          q_buckets=",".join(map(str, self.cfg.q_buckets)),
                          buckets=tuple(float(b) for b in
                                        self.cfg.q_buckets)).record(n_real)
            now = time.monotonic()
            wait_h = obs.histogram("serving_coalesce_wait_seconds",
                                   persistent=True)
            for p in batch:
                wait_h.record(now - p.t_submit)
        row = 0
        for p in batch:
            n = p.Q.shape[0]
            p.future.set_result(result._replace(
                dist=result.dist[row:row + n], ids=result.ids[row:row + n]))
            row += n
