"""Jitted public wrappers for the PQ-ADC Pallas kernels."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import default_interpret, pad_to
from .kernel import make_adc_lookup_call, make_adc_sym_call

__all__ = ["adc_sym_cdist", "adc_lookup"]


@functools.partial(jax.jit, static_argnames=("block_a", "block_b", "interpret"))
def adc_sym_cdist(codes_a: jnp.ndarray, codes_b: jnp.ndarray,
                  lut: jnp.ndarray, block_a: int = 128, block_b: int = 128,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Symmetric PQ distance matrix via one-hot MXU contractions.

    ``codes_a (Na, M)``, ``codes_b (Nb, M)`` int32; ``lut (M, K, K)``.
    """
    if interpret is None:
        interpret = default_interpret()
    nA, M = codes_a.shape
    nB = codes_b.shape[0]
    K = lut.shape[-1]
    block_a = min(block_a, max(8, nA))
    block_b = min(block_b, max(8, nB))
    a = pad_to(codes_a.astype(jnp.int32), block_a, axis=0, value=0)
    b = pad_to(codes_b.astype(jnp.int32), block_b, axis=0, value=0)
    call = make_adc_sym_call(a.shape[0], b.shape[0], M, K,
                             block_a, block_b, interpret)
    return call(a, b, lut.astype(jnp.float32))[:nA, :nB]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def adc_lookup(codes: jnp.ndarray, qlut: jnp.ndarray, block: int = 256,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """Asymmetric scan: ``codes (N, M)``, ``qlut (M, K)`` -> ``(N,)``."""
    if interpret is None:
        interpret = default_interpret()
    n, M = codes.shape
    K = qlut.shape[-1]
    block = min(block, max(8, n))
    c = pad_to(codes.astype(jnp.int32), block, axis=0, value=0)
    call = make_adc_lookup_call(c.shape[0], M, K, block, interpret)
    return call(c, qlut.astype(jnp.float32))[:n, 0]
