"""Fig 5b — effect of subspace count M and codebook size K on PQDTW runtime.

Theory (paper §3.2): encoding is O(K * D^2 / M) — runtime rises linearly
with K and falls with M.  We sweep both around the defaults and also report
the symmetric-distance phase (O(M) per pair) to show the encode/search
trade-off.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pq import PQConfig, cdist_sym, encode, fit
from repro.data.timeseries import random_walks

from .common import Bench, timeit


def run(quick: bool = True) -> Bench:
    b = Bench("fig5b_params")
    D = 128 if quick else 512
    N = 60 if quick else 200
    X = jnp.asarray(random_walks(N, D, seed=1))
    key = jax.random.PRNGKey(0)

    subspaces = (2, 4, 8) if quick else (2, 4, 8, 16)
    codebooks = (16, 32, 64) if quick else (64, 128, 256)

    for M in subspaces:
        for K in codebooks:
            cfg = PQConfig(n_sub=M, codebook_size=min(K, N),
                           use_prealign=False, kmeans_iters=3, dba_iters=1)
            cb = fit(key, X, cfg)
            enc = timeit(lambda: encode(X, cb, cfg), repeats=2)
            codes = encode(X, cb, cfg)
            sym = timeit(lambda: cdist_sym(codes, codes, cb.lut), repeats=3)
            b.add(n_sub=M, codebook=K,
                  encode_s=enc["median_s"], sym_cdist_s=sym["median_s"],
                  encode_per_series_ms=1e3 * enc["median_s"] / N)
    b.save()
    return b


if __name__ == "__main__":
    run(quick=False)
