"""Mamba2 — SSD (state-space duality) blocks, chunked-scan formulation.

Shapes follow the paper: inner width ``din = expand * d_model`` split into
``H = din / P`` heads of dim ``P``; state size ``N`` (one shared B/C group).

Projections are kept *separate* (z, x, B, C, dt) rather than fused: each
output can then be tensor-sharded on its own dimension (heads for z/x/dt,
replicated for the small shared B/C), so the `split` never crosses shard
boundaries — the TPU-sharding analogue of the fused-GEMM CUDA layout.

Training/prefill uses the chunked SSD algorithm: a quadratic intra-chunk
term (batched (Q, Q) matmuls — MXU work) plus an inter-chunk recurrence
carried by ``lax.scan``.  Decode is the exact O(1) recurrence on cached
state.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rms_norm

__all__ = ["SsmParams", "init_ssm", "ssd_forward", "ssd_decode_step",
           "init_ssm_state"]


class SsmParams(NamedTuple):
    wz: jnp.ndarray         # (d, din)   gate
    wx: jnp.ndarray         # (d, din)   ssm input
    wB: jnp.ndarray         # (d, N)     input matrix (shared group)
    wC: jnp.ndarray         # (d, N)     output matrix
    wdt: jnp.ndarray        # (d, H)     timestep
    conv_x: jnp.ndarray     # (ck, din)  depthwise causal conv
    conv_B: jnp.ndarray     # (ck, N)
    conv_C: jnp.ndarray     # (ck, N)
    conv_bx: jnp.ndarray    # (din,)
    conv_bB: jnp.ndarray    # (N,)
    conv_bC: jnp.ndarray    # (N,)
    a_log: jnp.ndarray      # (H,)
    d_skip: jnp.ndarray     # (H,)
    dt_bias: jnp.ndarray    # (H,)
    norm: jnp.ndarray       # (din,)
    out_proj: jnp.ndarray   # (din, d)


def init_ssm(key: jax.Array, cfg: ModelConfig) -> SsmParams:
    d, din, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, ck = cfg.ssm_heads, cfg.ssm_conv
    ks = jax.random.split(key, 9)
    sc = 0.02
    n = lambda k, shape: jax.random.normal(k, shape, jnp.float32) * sc
    return SsmParams(
        wz=n(ks[0], (d, din)), wx=n(ks[1], (d, din)),
        wB=n(ks[2], (d, N)), wC=n(ks[3], (d, N)), wdt=n(ks[4], (d, H)),
        conv_x=n(ks[5], (ck, din)), conv_B=n(ks[6], (ck, N)),
        conv_C=n(ks[7], (ck, N)),
        conv_bx=jnp.zeros((din,), jnp.float32),
        conv_bB=jnp.zeros((N,), jnp.float32),
        conv_bC=jnp.zeros((N,), jnp.float32),
        a_log=jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        d_skip=jnp.ones((H,), jnp.float32),
        dt_bias=jnp.full((H,), -2.0, jnp.float32),
        norm=jnp.zeros((din,), jnp.float32),
        out_proj=n(ks[8], (din, d)))


def _proj(x, w):
    return jnp.einsum("btd,de->bte", x.astype(jnp.bfloat16),
                      w.astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32)


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time: ``u (B, T, C)``, ``w (ck, C)``."""
    ck = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (ck - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * w[i][None, None, :]
              for i in range(ck))
    return jax.nn.silu(out + b[None, None, :])


def ssd_forward(p: SsmParams, cfg: ModelConfig, x: jnp.ndarray,
                chunk: int = 128,
                initial_state: Optional[jnp.ndarray] = None,
                return_state: bool = False):
    """Chunked SSD over a full sequence: ``x (B, T, d)`` -> ``(B, T, d)``.

    Recurrence (per head h, inclusive cumsum ``cum_j = sum_{l<=j} dt_l A_h``):
        S_j = exp(dt_j A) S_{j-1} + dt_j B_j x_j^T
        y_j = C_j . S_j + D x_j
    so  y_j = C_j exp(cum_j) S_prev                       [inter-chunk]
            + sum_{l<=j} exp(cum_j - cum_l) dt_l (C_j.B_l) x_l   [intra]
    """
    B, T, d = x.shape
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    Q = chunk if (T % chunk == 0 and T >= chunk) else T
    nc = T // Q

    z = _proj(x, p.wz)                                           # (B,T,din)
    xin = _causal_conv(_proj(x, p.wx), p.conv_x, p.conv_bx)
    Bm = _causal_conv(_proj(x, p.wB), p.conv_B, p.conv_bB)       # (B,T,N)
    Cm = _causal_conv(_proj(x, p.wC), p.conv_C, p.conv_bC)
    dt = jax.nn.softplus(_proj(x, p.wdt).astype(jnp.float32) + p.dt_bias)
    A = -jnp.exp(p.a_log.astype(jnp.float32))                    # (H,)
    xh = xin.reshape(B, T, H, P).astype(jnp.float32)

    dtc = dt.reshape(B, nc, Q, H)
    dA = dtc * A
    cum = jnp.cumsum(dA, axis=2)                                 # inclusive
    seg_end = cum[:, :, -1]                                      # (B,nc,H)
    Bc = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, N).astype(jnp.float32)
    xc = xh.reshape(B, nc, Q, H, P)

    # ---- intra-chunk (batched (Q,Q) matmuls) ----
    G = jnp.einsum("bciN,bcjN->bcij", Cc, Bc)                    # (B,nc,Q,Q)
    Lmat = jnp.exp(jnp.clip(cum[:, :, :, None, :] - cum[:, :, None, :, :],
                            -60.0, 0.0))                         # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = G[..., None] * jnp.where(tri[None, None, :, :, None], Lmat, 0.0)
    xdt = xc * dtc[..., None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xdt)

    # ---- inter-chunk recurrence ----
    decay_out = jnp.exp(jnp.clip(seg_end[:, :, None, :] - cum, -60.0, 0.0))
    S_local = jnp.einsum("bcjh,bcjhp,bcjn->bchpn", decay_out * dtc, xc, Bc)

    S0 = (jnp.zeros((B, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(S_prev, inp):
        S_loc, seg, C_chunk, cum_chunk = inp
        dec = jnp.exp(jnp.clip(cum_chunk, -60.0, 0.0))           # (B,Q,H)
        y = jnp.einsum("bjn,bjh,bhpn->bjhp", C_chunk, dec, S_prev)
        S_new = S_prev * jnp.exp(seg)[:, :, None, None] + S_loc
        return S_new, y

    xs = (jnp.moveaxis(S_local, 1, 0), jnp.moveaxis(seg_end, 1, 0),
          jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(cum, 1, 0))
    S_fin, y_inter = jax.lax.scan(step, S0, xs)
    y_inter = jnp.moveaxis(y_inter, 0, 1)

    y = (y_intra + y_inter).reshape(B, T, H, P)
    y = y + p.d_skip[None, None, :, None] * xh
    y = y.reshape(B, T, din)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(jnp.bfloat16), p.norm, cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y.astype(jnp.bfloat16),
                     p.out_proj.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32).astype(jnp.bfloat16)
    if return_state:
        return out, S_fin
    return out


def init_ssm_state(cfg: ModelConfig, batch: int):
    """(ssd_state, conv_x_state, conv_B_state, conv_C_state) zero caches."""
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    din, ck = cfg.d_inner, cfg.ssm_conv
    return (jnp.zeros((batch, H, P, N), jnp.float32),
            jnp.zeros((batch, ck - 1, din), jnp.float32),
            jnp.zeros((batch, ck - 1, N), jnp.float32),
            jnp.zeros((batch, ck - 1, N), jnp.float32))


def _conv_step(state, u_new, w, b):
    """One causal-conv step: ``state (B, ck-1, C)``, ``u_new (B, C)``."""
    window = jnp.concatenate([state, u_new[:, None, :]], axis=1)  # (B, ck, C)
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    return jax.nn.silu(out), window[:, 1:, :]


def ssd_decode_step(p: SsmParams, cfg: ModelConfig, x: jnp.ndarray, state):
    """Exact single-token recurrence: ``x (B, 1, d)`` -> (out, new_state)."""
    B = x.shape[0]
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    S, cx, cB, cC = state

    z = _proj(x, p.wz)[:, 0]                                     # (B, din)
    xin, cx = _conv_step(cx, _proj(x, p.wx)[:, 0], p.conv_x, p.conv_bx)
    Bm, cB = _conv_step(cB, _proj(x, p.wB)[:, 0], p.conv_B, p.conv_bB)
    Cm, cC = _conv_step(cC, _proj(x, p.wC)[:, 0], p.conv_C, p.conv_bC)
    dt = jax.nn.softplus(_proj(x, p.wdt)[:, 0].astype(jnp.float32) + p.dt_bias)
    A = -jnp.exp(p.a_log.astype(jnp.float32))
    xhead = xin.reshape(B, H, P).astype(jnp.float32)

    dA = jnp.exp(dt * A)                                          # (B, H)
    S_new = S * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xhead, Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", S_new, Cm.astype(jnp.float32))
    y = y + p.d_skip[None, :, None] * xhead
    y = y.reshape(B, 1, din)
    y = y * jax.nn.silu(z.astype(jnp.float32))[:, None, :]
    y = rms_norm(y.astype(jnp.bfloat16), p.norm, cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y.astype(jnp.bfloat16),
                     p.out_proj.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32).astype(jnp.bfloat16)
    return out, (S_new, cx, cB, cC)
