"""End-to-end application behaviour: 1NN classification, clustering,
baseline distance measures (§4, §6)."""

import jax
import numpy as np
import pytest

from repro.core import pq as pqm
from repro.core.baselines import (cdtw_cdist, ed_cdist, sax_mindist_cdist,
                                  sax_transform, sbd_cdist)
from repro.core.cluster import hierarchical_labels, linkage
from repro.core.knn import (knn_classify_asym, knn_classify_sym,
                            nn_dtw_exact, nn_dtw_pruned)
from repro.core.metrics import adjusted_rand_index, error_rate, rand_index
from repro.core.pq import PQConfig
from repro.data.timeseries import cbf, trace_like

pytestmark = pytest.mark.slow    # end-to-end application accuracy: tier-2


@pytest.fixture(scope="module")
def cbf_split():
    Xtr, ytr = cbf(20, length=64, seed=0)   # 60 train
    Xte, yte = cbf(10, length=64, seed=1)   # 30 test
    return Xtr, ytr, Xte, yte


@pytest.fixture(scope="module")
def trained(cbf_split):
    Xtr, ytr, _, _ = cbf_split
    cfg = PQConfig(n_sub=4, codebook_size=16, window_frac=0.2,
                   kmeans_iters=4, dba_iters=1, refine_frac=0.5)
    cb = pqm.fit(jax.random.PRNGKey(0), Xtr, cfg)
    codes = pqm.encode(Xtr, cb, cfg)
    return cfg, cb, codes


def test_1nn_sym_beats_chance(cbf_split, trained):
    Xtr, ytr, Xte, yte = cbf_split
    cfg, cb, codes = trained
    pred = np.asarray(knn_classify_sym(codes, jax.numpy.asarray(ytr), Xte,
                                       cb, cfg))
    err = error_rate(yte, pred)
    assert err < 0.45  # 3 classes -> chance is 0.67


def test_1nn_asym_at_least_as_good_as_sym(cbf_split, trained):
    Xtr, ytr, Xte, yte = cbf_split
    cfg, cb, codes = trained
    pred_s = np.asarray(knn_classify_sym(codes, jax.numpy.asarray(ytr), Xte,
                                         cb, cfg))
    pred_a = np.asarray(knn_classify_asym(codes, jax.numpy.asarray(ytr), Xte,
                                          cb, cfg))
    # asymmetric removes query-side quantization noise; allow small slack
    assert error_rate(yte, pred_a) <= error_rate(yte, pred_s) + 0.15


def test_exact_nn_dtw_reference(cbf_split):
    Xtr, ytr, Xte, yte = cbf_split
    pred = np.asarray(nn_dtw_exact(Xtr, jax.numpy.asarray(ytr), Xte, window=8))
    assert error_rate(yte, pred) < 0.3


def test_pruned_nn_matches_exact(cbf_split):
    Xtr, ytr, Xte, yte = cbf_split
    exact = np.asarray(nn_dtw_exact(Xtr, jax.numpy.asarray(ytr), Xte, window=8))
    pruned, frac = nn_dtw_pruned(Xtr, ytr, Xte, window=8)
    assert (pruned == exact).mean() > 0.95  # ties may break differently
    assert 0.0 <= frac < 1.0


def test_linkage_matches_scipy():
    scipy_hier = pytest.importorskip("scipy.cluster.hierarchy")
    rng = np.random.default_rng(0)
    pts = rng.standard_normal((12, 3))
    d = np.sqrt(((pts[:, None] - pts[None, :]) ** 2).sum(-1))
    from scipy.spatial.distance import squareform
    for method in ("single", "complete", "average"):
        ours = linkage(d, method)
        theirs = scipy_hier.linkage(squareform(d, checks=False), method)
        assert np.allclose(ours[:, 2], theirs[:, 2], atol=1e-8), method
        ours_lab = hierarchical_labels(d, 3, method)
        theirs_lab = scipy_hier.fcluster(theirs, 3, criterion="maxclust")
        assert adjusted_rand_index(ours_lab, theirs_lab) == pytest.approx(1.0)


def test_clustering_with_pq_distances(trained, cbf_split):
    Xtr, ytr, _, _ = cbf_split
    cfg, cb, codes = trained
    segs = pqm.segment(Xtr, cfg)
    D = np.asarray(pqm.cdist_sym_refined(codes, segs, codes, segs, cb))
    labels = hierarchical_labels(D, 3, "complete")
    ri = rand_index(ytr, labels)
    assert ri > 0.5


def test_baseline_distances_sane():
    X, y = trace_like(5, length=64, seed=2)
    ed = np.asarray(ed_cdist(X, X))
    assert np.allclose(np.diag(ed), 0, atol=1e-2)  # fp32 a2+b2-2ab cancellation
    cd = np.asarray(cdtw_cdist(X, X, window=6))
    assert (cd <= ed + 1e-2).all()   # banded DTW <= lock-step
    sbd = np.asarray(sbd_cdist(X, X))
    assert np.allclose(np.diag(sbd), 0, atol=1e-4)
    assert (sbd >= -1e-6).all() and (sbd <= 2.0 + 1e-6).all()


def test_sax_mindist_lower_bounds_ed():
    X, _ = cbf(5, length=60, seed=3)
    S = sax_transform(X, n_segments=12, alphabet=4)
    assert S.min() >= 0 and S.max() < 4
    md = sax_mindist_cdist(S, S, L=60)
    # MINDIST lower-bounds ED on z-normalized series
    Xz = (X - X.mean(1, keepdims=True)) / X.std(1, keepdims=True)
    ed = np.asarray(ed_cdist(Xz, Xz))
    assert (md <= ed + 1e-3).all()


def test_rand_index_properties():
    a = np.array([0, 0, 1, 1, 2, 2])
    assert rand_index(a, a) == 1.0
    assert adjusted_rand_index(a, a) == 1.0
    b = np.array([1, 1, 2, 2, 0, 0])  # same partition, renamed
    assert adjusted_rand_index(a, b) == 1.0
