"""Streaming index lifecycle costs: insert throughput, query latency as a
function of sealed-segment count, the cost + payoff of compaction, and the
device-scaling axis of the sharded planner (replicated vs list-sharded
layout on 1/2/4 simulated devices)."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax

from repro.core.pq import PQConfig
from repro.data.timeseries import random_walks
from repro.index import IndexConfig, StreamingIndex

from . import common
from .common import Bench, timeit

# Runs in a subprocess per device count: XLA fixes the host device count at
# first init, so each mesh size needs a fresh process.  Prints one JSON
# marker line the parent collects into the shared Bench.
_DEVICE_LEG = r"""
import json, numpy as np, jax
from repro.core.pq import PQConfig
from repro.data.timeseries import random_walks
from repro.index import IndexConfig, StreamingIndex, search_sharded
from benchmarks import common
from benchmarks.common import timeit

n_dev = int({n_dev})
assert len(jax.devices()) == n_dev
D, n_lists, cap, n_seg = {D}, {n_lists}, {cap}, {n_seg}
cfg = IndexConfig(
    pq=PQConfig(n_sub=4, codebook_size=32, use_prealign=False,
                **common.measure_config_fields(),
                kmeans_iters=3, dba_iters=1),
    n_lists=n_lists, hot_capacity=cap, coarse_iters=4, n_shards=n_dev)
index = StreamingIndex.bootstrap(
    jax.random.PRNGKey(0), random_walks(2 * cap, D, seed=0), cfg)
index.insert(random_walks(n_seg * cap, D, seed=2))
index.compact()                       # one merged, placement-balanced shard
Q = random_walks(16, D, seed=99)
lat, lat_p99 = dict(), dict()
t = timeit(lambda: index.search(Q, n_probe=4, topk=3), repeats=3)
lat["direct"], lat_p99["direct"] = t["median_s"], t["p99_s"]
for part in ("queries", "lists"):
    t = timeit(lambda: search_sharded(index, Q, n_probe=4, topk=3,
                                      partition=part), repeats=3)
    lat[part], lat_p99[part] = t["median_s"], t["p99_s"]
sg = index.segments[0]
mc = index.memory_cost()
print("LEG:" + json.dumps(dict(
    n_devices=n_dev, latency_s=lat, latency_p99_s=lat_p99,
    live_rows=index.n_live(),
    shard_cap=sg.shard_cap, max_list=int(np.asarray(sg.list_len).max()),
    code_bytes=mc["code_bytes"],
    max_device_bytes=mc.get("max_device_bytes", mc["total_bytes"]),
    replicated_bytes=mc.get("replicated_bytes", 0),
    partitioned_bytes=mc.get("partitioned_bytes",
                             mc["code_bytes"] + mc["sidecar_bytes"]))))
"""


def _make_index(D: int, n_lists: int, hot_capacity: int,
                train_n: int) -> StreamingIndex:
    cfg = IndexConfig(
        pq=PQConfig(n_sub=4, codebook_size=32, use_prealign=False,
                    **common.measure_config_fields(),
                    kmeans_iters=3, dba_iters=1),
        n_lists=n_lists, hot_capacity=hot_capacity, coarse_iters=4)
    sample = random_walks(train_n, D, seed=0)
    return StreamingIndex.bootstrap(jax.random.PRNGKey(0), sample, cfg)


def run(quick: bool = True) -> Bench:
    b = Bench("index_scaling")
    D, n_lists, cap = (96, 8, 64) if quick else (256, 32, 256)
    n_segments_sweep = (1, 2, 4, 8) if quick else (1, 2, 4, 8, 16)
    Q = random_walks(16, D, seed=99)

    # --- insert throughput: amortized over fills + seals --------------------
    index = _make_index(D, n_lists, cap, train_n=2 * cap)
    stream = random_walks(4 * cap, D, seed=1)
    index.insert(stream[:cap])          # warm up the encode/assign jits
    t0 = time.perf_counter()
    index.insert(stream[cap:])
    t_ins = time.perf_counter() - t0
    b.add(op="insert", series=3 * cap,
          throughput_per_s=3 * cap / t_ins, total_s=t_ins)

    # --- query latency vs segment count -------------------------------------
    for n_seg in n_segments_sweep:
        index = _make_index(D, n_lists, cap, train_n=2 * cap)
        index.insert(random_walks(n_seg * cap, D, seed=2))
        assert index.n_segments == n_seg
        t = timeit(lambda: index.search(Q, n_probe=4, topk=3), repeats=3)
        b.add(op="search", n_segments=n_seg, rows=n_seg * cap,
              latency_s=t["median_s"], latency_p50_s=t["p50_s"],
              latency_p99_s=t["p99_s"])

    # --- compaction: cost of the merge, payoff on query latency -------------
    t0 = time.perf_counter()
    index.compact()
    t_cmp = time.perf_counter() - t0
    t = timeit(lambda: index.search(Q, n_probe=4, topk=3), repeats=3)
    b.add(op="compact", merged_rows=index.segments[0].rows,
          max_list=index.segments[0].max_list, compact_s=t_cmp,
          post_compact_latency_s=t["median_s"],
          post_compact_latency_p99_s=t["p99_s"])

    # --- device scaling: replicated vs list-sharded layout ------------------
    # Simulated host devices share one CPU, so wall-clock speedup is not the
    # point here; what the rows pin down is the *structure* of the scale-out:
    # per-device occupancy (hence sealed-code HBM) shrinking ~linearly with
    # the mesh, and the cost of the all_gather fan-in merge relative to the
    # query-sharded plan doing identical kernel work.
    n_seg_dev = 4
    for n_dev in (1, 2, 4):
        env = dict(os.environ, JAX_PLATFORMS="cpu",
                   XLA_FLAGS=f"--xla_force_host_platform_device_count="
                             f"{n_dev}")
        code = _DEVICE_LEG.format(n_dev=n_dev, D=D, n_lists=n_lists,
                                  cap=cap, n_seg=n_seg_dev)
        res = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=1200)
        if res.returncode != 0:
            raise RuntimeError(
                f"device leg n_dev={n_dev} failed:\n{res.stderr[-2000:]}")
        leg = json.loads(next(ln for ln in res.stdout.splitlines()
                              if ln.startswith("LEG:"))[4:])
        lat = leg["latency_s"]
        # the placement guarantee, on the physically sealed layout:
        # per-device rows <= perfect split + one list's worth
        assert leg["shard_cap"] <= (-(-leg["live_rows"] // n_dev)
                                    + leg["max_list"]), leg
        if n_dev > 1:
            # per-device partitioned share shrinks ~linearly with the mesh
            share = leg["max_device_bytes"] - leg["replicated_bytes"]
            assert share <= leg["partitioned_bytes"] / n_dev + 1, leg
        b.add(op="device_scaling", n_devices=n_dev,
              rows=leg["live_rows"], shard_cap=leg["shard_cap"],
              latency_direct_s=lat["direct"],
              latency_query_sharded_s=lat["queries"],
              latency_list_sharded_s=lat["lists"],
              latency_list_sharded_p99_s=leg["latency_p99_s"]["lists"],
              fanin_overhead_s=lat["lists"] - lat["queries"],
              per_device_speedup=lat["direct"] / lat["lists"],
              max_device_bytes=leg["max_device_bytes"],
              partitioned_bytes=leg["partitioned_bytes"])

    b.save(headline={
        "quick": quick, "measure": common.MEASURE,
        "config": dict(D=D, n_lists=n_lists, hot_capacity=cap),
        "insert_throughput_per_s": next(
            (r["throughput_per_s"] for r in b.rows if r["op"] == "insert"),
            None),
        "max_device_bytes_by_mesh": {
            str(r["n_devices"]): r["max_device_bytes"]
            for r in b.rows if r["op"] == "device_scaling"}})
    return b


if __name__ == "__main__":
    run(quick=True)
