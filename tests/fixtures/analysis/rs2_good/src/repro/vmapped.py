"""vmap over a pure-jnp function: no RS204 finding."""

import jax

from .kernels.goodk.ref import run_goodk_ref


def batched(xs):
    return jax.vmap(run_goodk_ref)(xs)
