"""AST module index + best-effort call graph over ``src/repro``.

The static rules (:mod:`repro.analysis.rules_trace`, ``rules_dispatch``,
``rules_concurrency``) need three global facts no single-file linter can
compute:

* which functions are *trace roots* — wrapped in ``jax.jit`` (decorator,
  ``functools.partial(jax.jit, ...)``, or an inline ``jax.jit(fn)`` /
  ``jax.jit(lambda ...)``), or handed to another tracing transform
  (``vmap``/``scan``/``shard_map``/...), so their bodies run under
  tracers;
* which functions are *trace-reachable* — called (directly, through a
  locally defined helper, or referenced as a function argument) from a
  trace root, so a host sync inside them silently lands on a jitted hot
  path;
* which functions can *launch a Pallas kernel* — reach a
  ``pl.pallas_call`` through the same edges — so a ``jax.vmap`` over one
  can be flagged (the PR 1/PR 6 "never Pallas under vmap" invariant).

Resolution is intentionally best-effort and *overapproximating*: a name
that cannot be resolved contributes no edge (no false reachability), a
function reference passed anywhere contributes an edge whether or not it
is ultimately invoked (reachability never under-reports on the hot
paths, which is the failure mode that matters for a gate).  Method calls
through ``self`` resolve within the class; calls through arbitrary
objects do not resolve and are dropped.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

__all__ = ["FunctionInfo", "ModuleInfo", "CallGraph", "build_graph",
           "dotted_parts", "TRACE_WRAPPERS", "PALLAS_CALL"]

# transforms that trace the function handed to them: jit compilation or a
# tracer-driven transform (either way the wrapped body sees tracers, so
# trace-safety rules apply to everything reachable from it)
TRACE_WRAPPERS = frozenset({
    "jax.jit", "jax.pmap", "jax.vmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.lax.scan", "jax.lax.map",
    "jax.lax.while_loop", "jax.lax.cond", "jax.lax.fori_loop",
    "jax.lax.switch", "jax.lax.associative_scan",
    "jax.experimental.shard_map.shard_map",
})

# the Pallas launch entry point (``pl.pallas_call`` under the canonical
# ``from jax.experimental import pallas as pl`` import)
PALLAS_CALL = frozenset({
    "jax.experimental.pallas.pallas_call",
})

_VMAP = frozenset({"jax.vmap"})


@dataclasses.dataclass
class FunctionInfo:
    """One function-like scope: def, method, nested def, or lambda."""

    qualname: str                      # repro.core.pq.encode / ...Cls.meth
    module: "ModuleInfo"
    node: ast.AST                      # FunctionDef | AsyncFunctionDef | Lambda
    lineno: int
    class_qual: Optional[str] = None   # enclosing class qualname, if a method
    parent: Optional[str] = None       # enclosing function qualname
    is_trace_root: bool = False
    # static_argnames attached by a jit wrapper (names, wrapper lineno)
    jit_static: Optional[Tuple[Tuple[str, ...], int]] = None
    calls: Set[str] = dataclasses.field(default_factory=set)
    refs: Set[str] = dataclasses.field(default_factory=set)

    @property
    def params(self) -> Set[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return set(names)


@dataclasses.dataclass
class ModuleInfo:
    qualname: str                      # repro.index.streaming
    path: Path
    tree: ast.Module
    source: str
    imports: Dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class VmapSite:
    """One ``jax.vmap(fn)`` call: who vmapped what, and where."""

    caller: str                        # enclosing scope qualname
    target: Optional[str]              # resolved fn qualname (None: unknown)
    module: ModuleInfo
    lineno: int


class CallGraph:
    """The module/function index plus derived reachability sets."""

    def __init__(self) -> None:
        self.modules: Dict[str, ModuleInfo] = {}
        self.functions: Dict[str, FunctionInfo] = {}
        self.vmap_sites: List[VmapSite] = []
        # (function qual, local name) -> lambda/def qualname for
        # ``fn = lambda ...`` aliases
        self._local_alias: Dict[Tuple[str, str], str] = {}

    # -- reachability --------------------------------------------------------

    def edges(self, qual: str) -> Set[str]:
        fn = self.functions.get(qual)
        if fn is None:
            return set()
        return {c for c in fn.calls | fn.refs if c in self.functions}

    def reachable_from(self, roots) -> Set[str]:
        seen, todo = set(), [r for r in roots if r in self.functions]
        while todo:
            q = todo.pop()
            if q in seen:
                continue
            seen.add(q)
            todo.extend(self.edges(q) - seen)
        return seen

    def trace_roots(self) -> Set[str]:
        return {q for q, f in self.functions.items() if f.is_trace_root}

    def trace_reachable(self) -> Set[str]:
        return self.reachable_from(self.trace_roots())

    def pallas_launchers(self) -> Set[str]:
        return {q for q, f in self.functions.items()
                if f.calls & PALLAS_CALL}

    def reaches_pallas(self) -> Set[str]:
        """Every function from which a ``pallas_call`` is reachable."""
        launchers = self.pallas_launchers()
        out = set(launchers)
        # iterate to fixpoint over the (small) function set
        changed = True
        while changed:
            changed = False
            for q in self.functions:
                if q in out:
                    continue
                if self.edges(q) & out:
                    out.add(q)
                    changed = True
        return out


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def dotted_parts(node: ast.AST) -> Optional[List[str]]:
    """``a.b.c`` -> ["a", "b", "c"]; None for anything richer."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def _module_qualname(path: Path, src_root: Path) -> str:
    rel = path.relative_to(src_root).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _resolve_imports(mod_qual: str, tree: ast.Module) -> Dict[str, str]:
    pkg_parts = mod_qual.split(".")[:-1]
    imports: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                imports[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = pkg_parts[:len(pkg_parts) - node.level + 1]
                prefix = ".".join(base + ([node.module] if node.module
                                          else []))
            else:
                prefix = node.module or ""
            for a in node.names:
                if a.name == "*":
                    continue
                target = f"{prefix}.{a.name}" if prefix else a.name
                imports[a.asname or a.name] = target
    return imports


class _Indexer(ast.NodeVisitor):
    """Pass 1: register every function-like scope."""

    def __init__(self, graph: CallGraph, module: ModuleInfo):
        self.g = graph
        self.m = module
        self.scope: List[str] = [module.qualname]
        self.class_stack: List[str] = []
        self.fn_stack: List[str] = []

    def _register(self, node, name: str) -> FunctionInfo:
        qual = f"{self.scope[-1]}.{name}"
        info = FunctionInfo(
            qualname=qual, module=self.m, node=node, lineno=node.lineno,
            class_qual=self.class_stack[-1] if self.class_stack else None,
            parent=self.fn_stack[-1] if self.fn_stack else None)
        self.g.functions[qual] = info
        if self.fn_stack:
            # containment edge: a nested scope is treated as reachable
            # from its parent (overapproximation, see module docstring)
            self.g.functions[self.fn_stack[-1]].refs.add(qual)
        return info

    def visit_ClassDef(self, node: ast.ClassDef):
        qual = f"{self.scope[-1]}.{node.name}"
        self.scope.append(qual)
        self.class_stack.append(qual)
        self.generic_visit(node)
        self.class_stack.pop()
        self.scope.pop()

    def _visit_function(self, node):
        info = self._register(node, node.name)
        self._apply_decorators(info, node)
        self.scope.append(info.qualname)
        self.fn_stack.append(info.qualname)
        self.generic_visit(node)
        self.fn_stack.pop()
        self.scope.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def visit_Lambda(self, node: ast.Lambda):
        info = self._register(node, f"<lambda@{node.lineno}>")
        self.scope.append(info.qualname)
        self.fn_stack.append(info.qualname)
        self.generic_visit(node)
        self.fn_stack.pop()
        self.scope.pop()

    def visit_Assign(self, node: ast.Assign):
        # ``fn = lambda ...`` / ``fn = helper``: remember the local alias so
        # ``jax.vmap(fn)`` can resolve through it
        if (self.fn_stack and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            name = node.targets[0].id
            if isinstance(node.value, ast.Lambda):
                lam = f"{self.scope[-1]}.<lambda@{node.value.lineno}>"
                self.g._local_alias[(self.fn_stack[-1], name)] = lam
        self.generic_visit(node)

    def _apply_decorators(self, info: FunctionInfo, node) -> None:
        for dec in node.decorator_list:
            target, static = _unwrap_jit_expr(dec, self.m.imports)
            if target == "__decorated__":
                info.is_trace_root = True
                if static is not None:
                    info.jit_static = (static, dec.lineno)


def _resolve_external(parts: List[str], imports: Dict[str, str]
                      ) -> Optional[str]:
    if parts and parts[0] in imports:
        return ".".join([imports[parts[0]]] + parts[1:])
    return None


def _unwrap_jit_expr(node: ast.AST, imports: Dict[str, str]):
    """Recognize a jit/tracing wrapper used as a decorator.

    Returns ``("__decorated__", static_argnames or None)`` when ``node``
    is ``jax.jit`` / ``functools.partial(jax.jit, ...)`` / a call of
    either; ``(None, None)`` otherwise.
    """
    parts = dotted_parts(node)
    if parts is not None:
        qual = _resolve_external(parts, imports) or ".".join(parts)
        if qual in TRACE_WRAPPERS:
            return "__decorated__", None
        return None, None
    if isinstance(node, ast.Call):
        fparts = dotted_parts(node.func)
        fqual = (_resolve_external(fparts, imports) or ".".join(fparts)
                 if fparts else "")
        if fqual in ("functools.partial", "partial") and node.args:
            inner = dotted_parts(node.args[0])
            iqual = (_resolve_external(inner, imports) or ".".join(inner)
                     if inner else "")
            if iqual in TRACE_WRAPPERS:
                return "__decorated__", _static_argnames(node)
        if fqual in TRACE_WRAPPERS:
            return "__decorated__", _static_argnames(node)
    return None, None


def _static_argnames(call: ast.Call) -> Optional[Tuple[str, ...]]:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names: List[str] = []
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                names.append(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                for el in v.elts:
                    if isinstance(el, ast.Constant) and isinstance(
                            el.value, str):
                        names.append(el.value)
            return tuple(names)
    return None


class _Resolver(ast.NodeVisitor):
    """Pass 2: resolve calls/references inside one function scope."""

    def __init__(self, graph: CallGraph, info: FunctionInfo):
        self.g = graph
        self.info = info

    def resolve(self, node: ast.AST) -> Optional[str]:
        parts = dotted_parts(node)
        if parts is None:
            if isinstance(node, ast.Lambda):
                return f"{self.info.qualname}.<lambda@{node.lineno}>"
            return None
        m = self.info.module
        head = parts[0]
        if head == "self" and self.info.class_qual and len(parts) > 1:
            return f"{self.info.class_qual}.{parts[1]}"
        # local lambda aliases, innermost scope first
        scope: Optional[str] = self.info.qualname
        while scope is not None:
            alias = self.g._local_alias.get((scope, head))
            if alias is not None:
                return alias
            cand = f"{scope}.{head}"
            if cand in self.g.functions:
                return ".".join([cand] + parts[1:]) if len(parts) > 1 \
                    else cand
            scope = self.g.functions[scope].parent \
                if scope in self.g.functions else None
        mod_cand = f"{m.qualname}.{head}"
        if mod_cand in self.g.functions:
            return ".".join([mod_cand] + parts[1:]) if len(parts) > 1 \
                else mod_cand
        if len(parts) > 1 and mod_cand in {f.class_qual for f in
                                           self.g.functions.values()
                                           if f.class_qual}:
            return f"{mod_cand}.{parts[1]}"
        ext = _resolve_external(parts, m.imports)
        if ext is not None:
            return ext
        return ".".join(parts)

    def _body_nodes(self):
        """Walk the scope's own statements, not nested function bodies."""
        todo = list(ast.iter_child_nodes(self.info.node))
        while todo:
            n = todo.pop()
            yield n
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                continue
            todo.extend(ast.iter_child_nodes(n))

    def run(self) -> None:
        for n in self._body_nodes():
            if isinstance(n, ast.Call):
                self._handle_call(n)

    def _handle_call(self, node: ast.Call) -> None:
        qual = self.resolve(node.func)
        if qual is not None:
            self.info.calls.add(qual)
        # function references handed as arguments (vmap/scan/jit/callbacks)
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            r = self.resolve(arg)
            if r is not None and r in self.g.functions:
                self.info.refs.add(r)
        if qual in TRACE_WRAPPERS and node.args:
            target = self.resolve(node.args[0])
            if target is not None and target in self.g.functions:
                tinfo = self.g.functions[target]
                tinfo.is_trace_root = True
                if qual == "jax.jit":
                    static = _static_argnames(node)
                    if static is not None and tinfo.jit_static is None:
                        tinfo.jit_static = (static, node.lineno)
        if qual in _VMAP and node.args:
            target = self.resolve(node.args[0])
            self.g.vmap_sites.append(VmapSite(
                caller=self.info.qualname,
                target=target if target in self.g.functions else None,
                module=self.info.module, lineno=node.lineno))


def build_graph(py_files, src_root: Path) -> CallGraph:
    """Index ``py_files`` (under ``src_root``, e.g. ``<repo>/src``) into a
    :class:`CallGraph` with calls resolved and trace roots marked."""
    g = CallGraph()
    for path in py_files:
        source = path.read_text(encoding="utf-8")
        tree = ast.parse(source, filename=str(path))
        qual = _module_qualname(path, src_root)
        mod = ModuleInfo(qualname=qual, path=path, tree=tree, source=source)
        mod.imports = _resolve_imports(qual, tree)
        g.modules[qual] = mod
        _Indexer(g, mod).visit(tree)
    for info in list(g.functions.values()):
        _Resolver(g, info).run()
    return g
