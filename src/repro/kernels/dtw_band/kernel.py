"""Banded-DTW wavefront Pallas kernels.

Two generations of the same anti-diagonal sweep live here:

``dtw_band_kernel`` (full-width, legacy)
    The two live diagonals are ``(block, L)`` registers and the Sakoe-Chiba
    band is only a *mask*: every wavefront step still pays for all ``L``
    lanes, so at the paper's default ``w = 0.1*L`` roughly ``L/(w+1) ~ 5-10x``
    of the VPU work is thrown away.  Kept as the benchmark baseline.

``dtw_band_compressed_kernel`` (band-compressed)
    The registers hold only the *feasible* cells of each diagonal.  On
    anti-diagonal ``d`` the valid rows are ``i in [lo(d), hi(d)]`` with

        lo(d) = max(0, d - (L-1), ceil((d-w)/2))
        hi(d) = min(L-1, d,        floor((d+w)/2))

    so at most ``w + 1`` cells are live; the register width is
    ``W = min(L, roundup(min(w, L-1) + 1, lane))`` — per-step cost scales
    with the band, not the series length.  Sequential depth stays ``2L-1``.

    Compressed-coordinate recurrence: slot ``t`` on diagonal ``d`` is cell
    ``i = lo(d) + t``.  Its predecessors sit at slots shifted by the *base
    drift* between consecutive diagonals:

        (i,   j-1) on d-1  ->  t + s1,      s1 = lo(d) - lo(d-1)   in {0, 1}
        (i-1, j  ) on d-1  ->  t + s1 - 1
        (i-1, j-1) on d-2  ->  t + s2,      s2 = lo(d) - lo(d-2) - 1
                                                                in {-1, 0, 1}

    All shifts are lane rotates selected by the (scalar) drift — no gathers.

TPU notes (both kernels):
  * the diagonal gather ``b[d - i]`` is a dynamic slice of a pre-reversed,
    pre-padded copy of ``b`` (built once per tile) — no scatter/gather ops;
  * the band geometry is integer arithmetic on the loop counter, so shapes
    never depend on data.

Measure-generic: the band-compressed sweep takes a static
:class:`repro.core.measures.MeasureSpec` whose per-move costs are inlined
into the wavefront step, so one kernel body serves DTW, WDTW, ERP and MSM
(plus anything registered later).  ERP-style virtual first rows/columns
are prefix sums of gap costs, sliced per diagonal exactly like the series
values.  The legacy full-width kernel stays DTW-only.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core import measures
from ...core.dispatch import effective_window
from ...core.measures import MeasureArg

__all__ = [
    "dtw_band_kernel",
    "dtw_band_compressed_kernel",
    "dtw_band_adaptive_kernel",
    "make_dtw_band_call",
    "make_dtw_band_cdist_call",
    "band_width",
    "wavefront_compressed",
]

_NEG_SAFE_INF = 3.0e38  # finite stand-in for +inf (avoids inf-inf NaNs)


def band_width(length: int, window: Optional[int], lane: int = 8) -> int:
    """Compressed register width: band cells padded up to a lane multiple,
    capped at ``length`` (beyond which compression cannot help).

    Contract: when ``min(window, length-1) + 1`` is already a lane
    multiple the width is exactly that cell count — no extra lane of
    padding is ever added on an aligned band.
    """
    w = length if window is None else int(window)
    need = min(w, length - 1) + 1
    if need % lane == 0:            # aligned band: width == cell count
        return min(length, need)
    return min(length, -(-need // lane) * lane)


# ---------------------------------------------------------------------------
# Full-width kernel (legacy / benchmark baseline)
# ---------------------------------------------------------------------------

def dtw_band_kernel(a_ref, b_ref, o_ref, *, length: int, window: int,
                    block: int):
    """Kernel body: ``a_ref (block, L)``, ``b_ref (block, L)`` ->
    ``o_ref (block, 1)`` squared banded DTW costs."""
    L = length
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)

    idx = jax.lax.broadcasted_iota(jnp.int32, (block, L), 1)
    # b_big[:, L + t] == b_rev[:, t]; diagonal d needs v[i] = b[d - i]
    #   = b_rev[i + L - 1 - d] = b_big[:, i + 2L - 1 - d].
    b_rev = jnp.flip(b, axis=1)
    zeros = jnp.zeros((block, L), jnp.float32)
    b_big = jnp.concatenate([zeros, b_rev, zeros], axis=1)

    inf = jnp.float32(_NEG_SAFE_INF)

    def step(d, carry):
        prev1, prev2 = carry
        j = d - idx
        valid = (j >= 0) & (j < L) & (jnp.abs(idx - j) <= window)
        v = jax.lax.dynamic_slice_in_dim(b_big, 2 * L - 1 - d, L, axis=1)
        cost = (a - v) ** 2

        shift1 = jnp.where(idx == 0, inf, jnp.roll(prev1, 1, axis=1))
        shift2 = jnp.where(idx == 0, inf, jnp.roll(prev2, 1, axis=1))
        best = jnp.minimum(jnp.minimum(shift2, prev1), shift1)
        best = jnp.where((idx == 0) & (d == 0), 0.0, best)
        diag = jnp.where(valid, cost + best, inf)
        # clamp so accumulating inf + cost never overflows to inf*2
        diag = jnp.minimum(diag, inf)
        return diag, prev1

    init = (jnp.full((block, L), inf), jnp.full((block, L), inf))
    last, _ = jax.lax.fori_loop(0, 2 * L - 1, step, init)
    o_ref[...] = last[:, L - 1:L]


# ---------------------------------------------------------------------------
# Band-compressed kernel
# ---------------------------------------------------------------------------

def _prefix_sum(x: jnp.ndarray, length: int) -> jnp.ndarray:
    """Inclusive prefix sum along axis 1 — log-depth shifted adds (rolls +
    masks only, so it lowers inside a Pallas kernel body; no cumsum
    primitive)."""
    t = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    shift = 1
    while shift < length:
        x = x + jnp.where(t >= shift, jnp.roll(x, shift, axis=1), 0.0)
        shift *= 2
    return x


def wavefront_compressed(a: jnp.ndarray, b: jnp.ndarray, *, length: int,
                         window: int, width: int,
                         measure: MeasureArg = None,
                         corridor=None) -> jnp.ndarray:
    """Band-compressed anti-diagonal sweep over zipped pair *arrays*.

    ``a (rows, L)`` vs ``b (rows, L)`` -> ``(rows, 1)`` banded elastic cost
    under ``measure`` (squared banded DTW by default).  This is the
    in-register DP shared by :func:`dtw_band_compressed_kernel`, the fused
    LB-cascade refine and the fused pre-align+encode kernel (which calls it
    on segment x centroid pairs it has just built in VMEM) — everything
    stays ``(rows, width)`` with ``width ~ window + 1``.

    The measure spec is static: its per-move costs are inlined into the
    step, and ERP-style measures additionally thread their virtual first
    row/column (prefix sums of gap costs, sliced per diagonal exactly like
    the series values) through the same sweep.

    ``corridor`` switches the sweep to *per-pair adaptive bands*: a pair of
    ``(rows, 2L-1)`` int32 arrays ``(lo_arr, hi_arr)`` giving each pair's
    feasible cell range on every anti-diagonal (see
    :mod:`repro.core.corridor` for the builder and the structural
    invariants: ``lo`` non-decreasing with per-diagonal drift <= 1,
    ``lo(0) = 0``, ``lo(2L-2) = L-1``, ``lo <= hi``).  Registers stay
    ``(rows, width)``; the per-row base offsets turn the value windows into
    ``take_along_axis`` gathers and the predecessor shifts into per-row
    rotate-selects — no shapes depend on data.  With ``corridor=None`` the
    static Sakoe-Chiba geometry is traced exactly as before.
    """
    spec = measures.resolve(measure)
    L, w, W = length, window, width
    rows = a.shape[0]
    adaptive = corridor is not None
    if adaptive:
        lo_arr, hi_arr = corridor
        lo_arr = lo_arr.astype(jnp.int32)
        hi_arr = hi_arr.astype(jnp.int32)

    inf = jnp.float32(_NEG_SAFE_INF)
    t = jax.lax.broadcasted_iota(jnp.int32, (rows, W), 1)

    # Padded copies so the per-diagonal windows are plain dynamic slices:
    #   a cells:  a[lo + t]              -> slice of a_pad at lo
    #   b cells:  b[d - lo - t]
    #           = b_rev[L-1-d+lo + t]    -> slice of b_rev_pad at L-1-d+lo
    # (0 <= lo <= L-1 and 0 <= L-1-d+lo <= L-1 for every feasible diagonal.)
    pad = jnp.zeros((rows, W), jnp.float32)
    a_pad = jnp.concatenate([a, pad], axis=1)
    b_rev_pad = jnp.concatenate([jnp.flip(b, axis=1), pad], axis=1)

    if spec.uses_neighbors:
        # a_{i-1} / b_{j-1} values (sentinel = element 0 at the borders,
        # where the corresponding move reads an inf predecessor anyway)
        a_prev = jnp.concatenate([a[:, :1], a[:, :-1]], axis=1)
        b_prev = jnp.concatenate([b[:, :1], b[:, :-1]], axis=1)
        a_prev_pad = jnp.concatenate([a_prev, pad], axis=1)
        b_prev_rev_pad = jnp.concatenate([jnp.flip(b_prev, axis=1), pad],
                                         axis=1)
    if spec.uses_gap_border:
        # virtual first column/row: T[i, -1] = ga[i], T[-1, j] = gb[j]
        ga = _prefix_sum(measures.gap_costs(spec, a), L)
        gb = _prefix_sum(measures.gap_costs(spec, b), L)
        zero = jnp.zeros((rows, 1), jnp.float32)
        ga_prev = jnp.concatenate([zero, ga[:, :-1]], axis=1)
        gb_prev = jnp.concatenate([zero, gb[:, :-1]], axis=1)
        ga_pad = jnp.concatenate([ga, pad], axis=1)
        ga_prev_pad = jnp.concatenate([ga_prev, pad], axis=1)
        gb_rev_pad = jnp.concatenate([jnp.flip(gb, axis=1), pad], axis=1)
        gb_prev_rev_pad = jnp.concatenate([jnp.flip(gb_prev, axis=1), pad],
                                          axis=1)

    def lo_of(d):
        # max(0, d - (L-1), ceil((d - w) / 2)); jnp // is floor division.
        return jnp.maximum(jnp.maximum(0, d - (L - 1)), -((w - d) // 2))

    def read(reg, s):
        """``reg[t + s]`` for scalar shift ``s`` in {-1, 0, 1}; out-of-range
        slots read the +inf sentinel (lane rotate + edge mask, gather-free)."""
        left = jnp.where(t == W - 1, inf, jnp.roll(reg, -1, axis=1))
        right = jnp.where(t == 0, inf, jnp.roll(reg, 1, axis=1))
        return jnp.where(s == 0, reg, jnp.where(s > 0, left, right))

    def step(d, carry):
        prev1, prev2 = carry  # compressed diagonals d-1 / d-2, inf-masked
        if adaptive:
            def band_at(arr, dd):
                return jax.lax.dynamic_slice_in_dim(
                    arr, jnp.maximum(dd, 0), 1, axis=1)

            lo = band_at(lo_arr, d)                      # (rows, 1)
            hi = band_at(hi_arr, d)
            s1 = lo - band_at(lo_arr, d - 1)             # in {0, 1}
            s2 = lo - band_at(lo_arr, d - 2) - 1         # in {-1, 0, 1}

            def fetch(arr, base):
                return jnp.take_along_axis(arr, base + t, axis=1)
        else:
            lo = lo_of(d)
            hi = jnp.minimum(jnp.minimum(L - 1, d), (d + w) // 2)
            s1 = lo - lo_of(d - 1)
            s2 = lo - lo_of(d - 2) - 1

            def fetch(arr, base):
                return jax.lax.dynamic_slice_in_dim(arr, base, W, axis=1)
        off_b = L - 1 - d + lo

        av = fetch(a_pad, lo)
        bv = fetch(b_rev_pad, off_b)
        i_arr = lo + t
        xp = fetch(a_prev_pad, lo) if spec.uses_neighbors else None
        yp = fetch(b_prev_rev_pad, off_b) if spec.uses_neighbors else None
        dd = jnp.abs(2 * i_arr - d) if spec.uses_position else None
        c_d, c_v, c_h = measures.move_costs(spec, av, bv, xp, yp, dd, L)

        # Predecessor slots (see module header): horiz (i, j-1) at t + s1
        # on d-1, vert (i-1, j) at t + s1 - 1 on d-1, diag (i-1, j-1) at
        # t + s2 on d-2.  In adaptive mode s1/s2 are (rows, 1) columns and
        # the rotate-select in ``read`` broadcasts per row.
        pred_h = read(prev1, s1)
        pred_v = read(prev1, s1 - 1)
        pred_d = read(prev2, s2)
        is_i0 = i_arr == 0
        is_j0 = (d - i_arr) == 0
        if spec.uses_gap_border:
            ga_v = fetch(ga_pad, lo)
            gap_v = fetch(ga_prev_pad, lo)
            gb_v = fetch(gb_rev_pad, off_b)
            gbp_v = fetch(gb_prev_rev_pad, off_b)
            pred_d = jnp.where(is_i0, gbp_v, jnp.where(is_j0, gap_v, pred_d))
            pred_d = jnp.where(is_i0 & is_j0, 0.0, pred_d)
            pred_v = jnp.where(is_i0, gb_v, pred_v)
            pred_h = jnp.where(is_j0, ga_v, pred_h)
        else:
            # Base case: cell (0, 0) starts from 0 via the diagonal move.
            pred_d = jnp.where(is_i0 & is_j0, 0.0, pred_d)
        if c_v is c_d and c_h is c_d:   # shared-cost family (DTW, WDTW)
            cell = c_d + jnp.minimum(jnp.minimum(pred_d, pred_h), pred_v)
        else:
            cell = jnp.minimum(jnp.minimum(pred_d + c_d, pred_v + c_v),
                               pred_h + c_h)
        diag = jnp.where(t <= hi - lo, cell, inf)
        diag = jnp.minimum(diag, inf)
        return diag, prev1

    init = (jnp.full((rows, W), inf), jnp.full((rows, W), inf))
    last, _ = jax.lax.fori_loop(0, 2 * L - 1, step, init)
    # Diagonal 2L-2 has lo = L-1: cell (L-1, L-1) sits in slot 0.
    return last[:, 0:1]


def dtw_band_compressed_kernel(a_ref, b_ref, o_ref, *, length: int,
                               window: int, block: int, width: int,
                               broadcast_b: bool = False,
                               measure: MeasureArg = None):
    """Kernel body: ``a_ref (block, L)`` and ``b_ref (block, L)`` (or
    ``(1, L)`` with ``broadcast_b``) -> ``o_ref (block, 1)``.

    Registers are ``(block, width)`` — only the feasible band cells of each
    anti-diagonal are materialized.
    """
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    if broadcast_b:
        b = jnp.broadcast_to(b, (block, length))
    o_ref[...] = wavefront_compressed(a, b, length=length, window=window,
                                      width=width, measure=measure)


def dtw_band_adaptive_kernel(a_ref, b_ref, lo_ref, hi_ref, o_ref, *,
                             length: int, window: int, block: int,
                             width: int, measure: MeasureArg = None):
    """Adaptive-corridor kernel body: ``a_ref (block, L)``, ``b_ref
    (block, L)`` plus per-pair corridor envelopes ``lo_ref``/``hi_ref``
    ``(block, 2L-1)`` int32 -> ``o_ref (block, 1)``.

    Same band-compressed registers as the static kernel, but the live cell
    range of every anti-diagonal comes from the pair's own corridor (built
    by :mod:`repro.core.corridor`), so ``width`` can be far below the
    static ``window + 1`` when alignment paths hug the diagonal.
    """
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] = wavefront_compressed(
        a, b, length=length, window=window, width=width, measure=measure,
        corridor=(lo_ref[...], hi_ref[...]))


# ---------------------------------------------------------------------------
# pallas_call builders
# ---------------------------------------------------------------------------

def make_dtw_band_call(n_pairs: int, length: int, window: Optional[int],
                       block: int, interpret: bool, mode: str = "compressed",
                       lane: int = 8, measure: MeasureArg = None,
                       width: Optional[int] = None):
    """Build the pallas_call for ``(n_pairs, L)`` zipped pair batches.

    ``n_pairs`` must already be padded to a multiple of ``block``.
    ``mode`` selects the band-compressed sweep (default), the legacy
    full-width sweep (DTW-only benchmark baseline), or the
    adaptive-corridor sweep (``mode="adaptive"``, which adds two
    ``(n_pairs, 2L-1)`` int32 corridor operands and requires an explicit
    register ``width`` — normally the tuned adaptive width, see
    :mod:`repro.kernels.tune`).
    """
    spec = measures.resolve(measure)
    w = effective_window(length, window)
    grid = (n_pairs // block,)
    in_specs = [
        pl.BlockSpec((block, length), lambda i: (i, 0)),
        pl.BlockSpec((block, length), lambda i: (i, 0)),
    ]
    if mode == "full":
        if spec.name != "dtw":
            raise ValueError(
                "mode='full' is the legacy DTW-only benchmark baseline; "
                f"measure {spec.name!r} requires mode='compressed'")
        kernel = functools.partial(dtw_band_kernel, length=length, window=w,
                                   block=block)
    elif mode == "compressed":
        if width is None:
            width = band_width(length, w, lane)
        kernel = functools.partial(dtw_band_compressed_kernel, length=length,
                                   window=w, block=block, width=width,
                                   measure=spec)
    elif mode == "adaptive":
        if width is None:
            raise ValueError("mode='adaptive' needs an explicit width "
                             "(the corridor cap)")
        kernel = functools.partial(dtw_band_adaptive_kernel, length=length,
                                   window=w, block=block, width=width,
                                   measure=spec)
        in_specs += [
            pl.BlockSpec((block, 2 * length - 1), lambda i: (i, 0)),
            pl.BlockSpec((block, 2 * length - 1), lambda i: (i, 0)),
        ]
    else:
        raise ValueError(f"unknown dtw_band mode: {mode!r}")
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pairs, 1), jnp.float32),
        interpret=interpret,
    )


def make_dtw_band_cdist_call(n_a: int, n_b: int, length: int,
                             window: Optional[int], block_a: int,
                             interpret: bool, lane: int = 8,
                             measure: MeasureArg = None):
    """All-pairs call on a 2-D grid: ``A (n_a, L) x B (n_b, L) -> (n_a, n_b)``.

    Each grid step sweeps ``block_a`` rows of A against ONE row of B
    (broadcast inside the kernel), so the N*M cross-product is never
    materialized in HBM.  ``n_a`` must be padded to a multiple of
    ``block_a``.
    """
    w = effective_window(length, window)
    kernel = functools.partial(dtw_band_compressed_kernel, length=length,
                               window=w, block=block_a,
                               width=band_width(length, w, lane),
                               broadcast_b=True,
                               measure=measures.resolve(measure))
    return pl.pallas_call(
        kernel,
        grid=(n_a // block_a, n_b),
        in_specs=[
            pl.BlockSpec((block_a, length), lambda i, j: (i, 0)),
            pl.BlockSpec((1, length), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_a, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_a, n_b), jnp.float32),
        interpret=interpret,
    )
