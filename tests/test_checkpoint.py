"""Checkpoint layer: atomic saves, keep-K GC, restore, async writer."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step, restore,
                                   save)


def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
            "scalar": jnp.float32(seed)}


class TestSaveRestore:
    def test_roundtrip(self, tmp_path):
        d = str(tmp_path)
        t = _tree(3)
        save(d, 7, t)
        assert latest_step(d) == 7
        back = restore(d, 7, jax.tree.map(jnp.zeros_like, t))
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_last_gc(self, tmp_path):
        d = str(tmp_path)
        for s in (1, 2, 3, 4, 5):
            save(d, s, _tree(s), keep_last=2)
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                       if x.startswith("step_"))
        assert steps == [4, 5]
        assert latest_step(d) == 5

    def test_latest_ignores_partial(self, tmp_path):
        d = str(tmp_path)
        save(d, 3, _tree(0))
        # a torn write: directory without manifest must not be "latest"
        os.makedirs(os.path.join(d, "step_0000000009"))
        assert latest_step(d) == 3

    def test_structure_mismatch_raises(self, tmp_path):
        d = str(tmp_path)
        save(d, 1, _tree(0))
        with pytest.raises(AssertionError):
            restore(d, 1, {"only": jnp.zeros((2,))})

    def test_crashed_overwrite_recovers_old_version(self, tmp_path):
        """Simulated crash between the two renames of an overwrite: the
        .old- aside is the only complete copy and must be rediscovered."""
        d = str(tmp_path)
        save(d, 5, _tree(1))
        os.rename(os.path.join(d, "step_0000000005"),
                  os.path.join(d, ".old-step_0000000005"))
        assert latest_step(d) == 5           # recovery renames it back
        back = restore(d, 5, _tree(0))
        assert float(back["scalar"]) == 1.0

    def test_resave_same_step_replaces_cleanly(self, tmp_path):
        """Re-publishing an existing step must leave the new version (and
        no .old-/.tmp- staging debris) — the crash-safe overwrite path."""
        d = str(tmp_path)
        save(d, 5, _tree(1))
        save(d, 5, _tree(2))
        back = restore(d, 5, _tree(0))
        np.testing.assert_array_equal(np.asarray(back["nested"]["b"]),
                                      np.arange(5))
        assert float(back["scalar"]) == 2.0
        assert os.listdir(d) == ["step_0000000005"]


class TestAsyncWriter:
    def test_async_submit_wait(self, tmp_path):
        d = str(tmp_path)
        ck = AsyncCheckpointer(d, keep_last=3)
        for s in (10, 20):
            ck.submit(s, _tree(s))
        ck.wait()
        ck.close()
        assert latest_step(d) == 20
        back = restore(d, 10, _tree(0))
        np.testing.assert_array_equal(np.asarray(back["nested"]["b"]),
                                      np.arange(5))

    def test_submit_snapshot_is_immediate(self, tmp_path):
        """The tree is device_get at submit time: later donation-style
        mutation of the live arrays must not corrupt the checkpoint."""
        d = str(tmp_path)
        ck = AsyncCheckpointer(d)
        t = {"x": jnp.ones((3,))}
        ck.submit(1, t)
        t["x"] = t["x"] * 0          # rebind after submit
        ck.wait()
        ck.close()
        back = restore(d, 1, {"x": jnp.zeros((3,))})
        np.testing.assert_array_equal(np.asarray(back["x"]), np.ones(3))
