"""Fused MODWT pre-alignment + nearest-centroid encode Pallas kernel.

The paper's pre-aligned encode (§3.5 + Alg. 2) is a four-stage pipeline —
Haar MODWT scale recursion, change-point detection, split snapping, segment
re-interpolation — followed by a DTW-1NN scan against every subspace
codebook.  Run as the ``modwt.prealign`` + ``pq.encode`` two-step, the
``(B, M, D/M + t)`` segment tensor round-trips through HBM between the
stages.  This kernel fuses the whole pipeline over one ``(block, L)`` batch
tile, so segments only ever exist in VMEM:

  1. *MODWT scale recursion* — ``level`` shifted adds (circular ``roll``):
     ``v_j = (v_{j-1} + roll(v_{j-1}, 2^{j-1})) / 2``.
  2. *Change points* — sign changes of ``x - v_J``; exact zeros carry the
     previous nonzero sign via a log-depth forward fill (masked rolls), the
     gather-free equivalent of the reference's ``associative_scan``.
  3. *Split snapping* — every interior fixed split ``l = m * (L/M)`` is
     static, so the tail window ``[l - t, l]`` is ``t + 1`` static column
     reads; the right-most change point wins (masked min over offsets).
  4. *Segment gather + linear re-interpolation* — data-dependent boundaries
     become per-row fractional positions; two lane gathers
     (``take_along_axis``) plus a lerp resample each segment to the static
     length ``S = L/M + t``.
  5. *Encode* — the ``(block, K)`` pair block per subspace is swept with the
     band-compressed DTW wavefront shared with :mod:`..dtw_band.kernel`;
     codes are the per-row argmin (first-index tie-break, matching
     ``jnp.argmin``).

Static geometry: ``L``, ``M``, ``K``, ``S``, ``level``, ``tail`` and the
band ``window`` are all trace-time constants — data-dependent boundaries
become *indices*, never shapes, exactly like the reference pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.measures import MeasureArg
from ..dtw_band.kernel import wavefront_compressed

__all__ = ["prealign_encode_kernel", "make_prealign_encode_call"]


def _forward_fill_sign(s: jnp.ndarray, t: jnp.ndarray,
                       length: int) -> jnp.ndarray:
    """Replace zeros in ``s (rows, L)`` by the nearest nonzero value to the
    left (log-depth doubling; positions with no nonzero left stay 0)."""
    shift = 1
    while shift < length:
        moved = jnp.where(t >= shift, jnp.roll(s, shift, axis=1), 0.0)
        s = jnp.where(s == 0.0, moved, s)
        shift *= 2
    return s


def prealign_encode_kernel(x_ref, c_ref, lin_ref, o_ref, *, length: int,
                           n_sub: int, n_k: int, seg_len: int, level: int,
                           tail: int, window: int, block: int, width: int,
                           measure: MeasureArg = None):
    """``x_ref (block, L)``, ``c_ref (M, K, S)``, ``lin_ref (1, S)`` ->
    ``o_ref (block, M)`` int32 codes."""
    L, M, K, S = length, n_sub, n_k, seg_len
    x = x_ref[...].astype(jnp.float32)
    lin = lin_ref[...].astype(jnp.float32)            # linspace(0, 1, S)
    t = jax.lax.broadcasted_iota(jnp.int32, (block, L), 1)

    # -- 1. Haar MODWT scale coefficients (circular boundary) ---------------
    v = x
    for j in range(1, level + 1):
        v = 0.5 * (v + jnp.roll(v, 2 ** (j - 1), axis=1))

    # -- 2. change points: sign changes of x - v, zeros carry previous sign -
    s = _forward_fill_sign(jnp.sign(x - v), t, L)
    prev = jnp.where(t == 0, s[:, 0:1], jnp.roll(s, 1, axis=1))
    change = ((s * prev) < 0.0) & (t > 0)             # (block, L) bool

    # -- 3. snap the static interior splits to the right-most change point --
    seg = L // M
    bounds = [jnp.zeros((block, 1), jnp.int32)]
    for m in range(1, M):
        l = m * seg
        cand = [change[:, c:c + 1] if c >= 1 else
                jnp.zeros((block, 1), bool) for c in range(l, l - tail - 1, -1)]
        ok = jnp.concatenate(cand, axis=1)            # (block, tail + 1)
        offs = jax.lax.broadcasted_iota(jnp.int32, (block, tail + 1), 1)
        first = jnp.min(jnp.where(ok, offs, tail + 1), axis=1, keepdims=True)
        bounds.append(jnp.where(first <= tail, l - first, l).astype(jnp.int32))
    bounds.append(jnp.full((block, 1), L, jnp.int32))

    # -- 4 + 5. per subspace: re-interpolate, then DTW-1NN over K centroids -
    for m in range(M):
        start, stop = bounds[m], bounds[m + 1]        # (block, 1) int32
        n = stop - start
        pos = start.astype(jnp.float32) + lin * (n - 1).astype(jnp.float32)
        lo = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, L - 1)
        hi = jnp.clip(lo + 1, 0, L - 1)
        frac = pos - lo.astype(jnp.float32)
        x_lo = jnp.take_along_axis(x, lo, axis=1)     # (block, S)
        x_hi = jnp.take_along_axis(x, hi, axis=1)
        segm = x_lo * (1.0 - frac) + x_hi * frac

        cents = c_ref[m]                              # (K, S)
        a = jnp.broadcast_to(segm[:, None, :], (block, K, S))
        b = jnp.broadcast_to(cents[None, :, :], (block, K, S))
        d = wavefront_compressed(a.reshape(block * K, S),
                                 b.reshape(block * K, S),
                                 length=S, window=window, width=width,
                                 measure=measure)
        d = d.reshape(block, K)
        k_iota = jax.lax.broadcasted_iota(jnp.int32, (block, K), 1)
        dmin = jnp.min(d, axis=1, keepdims=True)
        code = jnp.min(jnp.where(d == dmin, k_iota, K), axis=1, keepdims=True)
        o_ref[:, m:m + 1] = code


def make_prealign_encode_call(n: int, length: int, n_sub: int, n_k: int,
                              seg_len: int, level: int, tail: int,
                              window: int, block: int, width: int,
                              interpret: bool,
                              measure: MeasureArg = None):
    """Build the pallas_call: ``X (n, L)`` tiles x one resident codebook.

    ``n`` must already be padded to a multiple of ``block``; the centroid
    tensor and the interpolation grid are broadcast to every tile.
    """
    kernel = functools.partial(
        prealign_encode_kernel, length=length, n_sub=n_sub, n_k=n_k,
        seg_len=seg_len, level=level, tail=tail, window=window, block=block,
        width=width, measure=measure)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, length), lambda i: (i, 0)),
            pl.BlockSpec((n_sub, n_k, seg_len), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, seg_len), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, n_sub), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_sub), jnp.int32),
        interpret=interpret,
    )
