"""1-NN search/classification with PQ approximates (§4.1) + exact NN-DTW.

The exact NN-DTW path implements the UCR-suite style LB_Keogh early
abandoning (query envelopes, candidate pruning) so benchmarks can report
both the paper's baseline and its pruning statistics.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dispatch import elastic_cdist, elastic_pairwise
from .lb import keogh_envelope, lb_keogh
from .pq import PQCodebook, PQConfig, cdist_asym, cdist_sym, encode

__all__ = ["knn_classify_sym", "knn_classify_asym", "nn_dtw_exact",
           "nn_dtw_pruned"]


def knn_classify_sym(train_codes: jnp.ndarray, train_labels: jnp.ndarray,
                     Q: jnp.ndarray, cb: PQCodebook, cfg: PQConfig
                     ) -> jnp.ndarray:
    """Symmetric 1-NN: encode the queries, then M LUT gathers per pair."""
    q_codes = encode(Q, cb, cfg)
    d = cdist_sym(q_codes, train_codes, cb.lut)
    return train_labels[jnp.argmin(d, axis=1)]


def knn_classify_asym(train_codes: jnp.ndarray, train_labels: jnp.ndarray,
                      Q: jnp.ndarray, cb: PQCodebook, cfg: PQConfig
                      ) -> jnp.ndarray:
    """Asymmetric 1-NN: one fresh M x K DTW table per query, then gathers."""
    d = cdist_asym(Q, train_codes, cb, cfg)
    return train_labels[jnp.argmin(d, axis=1)]


def nn_dtw_exact(X: jnp.ndarray, labels: jnp.ndarray, Q: jnp.ndarray,
                 window: Optional[int] = None) -> jnp.ndarray:
    """Exact (banded) NN-DTW, fully vectorized — the accuracy reference."""
    d = elastic_cdist(jnp.asarray(Q, jnp.float32),
                      jnp.asarray(X, jnp.float32), window)
    return labels[jnp.argmin(d, axis=1)]


def nn_dtw_pruned(X: np.ndarray, labels: np.ndarray, Q: np.ndarray,
                  window: Optional[int] = None
                  ) -> Tuple[np.ndarray, float]:
    """LB_Keogh filter-and-refine NN-DTW.

    Vectorized two-phase equivalent of UCR early abandoning: compute the
    cheap bound for all (query, candidate) pairs, run real DTW only where the
    bound cannot exclude the candidate (per query, bounds above the best
    *verified* distance so far, processed in ascending-LB order).  Returns
    (predictions, fraction_of_DTW_computations_pruned).
    """
    X = np.asarray(X, np.float32)
    Q = np.asarray(Q, np.float32)
    w = window if window is not None else X.shape[1]
    up, lo = keogh_envelope(jnp.asarray(Q), int(w))
    lbs = np.asarray(jax.vmap(lambda u, l: lb_keogh(jnp.asarray(X), u, l))(
        up, lo))                                           # (Nq, N)
    order = np.argsort(lbs, axis=1)
    preds = np.zeros(Q.shape[0], labels.dtype)
    n_dtw = 0
    for qi in range(Q.shape[0]):
        best, best_i = np.inf, 0
        # batch the refinement in chunks, early-stopping between chunks
        idx = order[qi]
        chunk = max(4, min(64, X.shape[0] // 8))
        for s in range(0, len(idx), chunk):
            cand = idx[s:s + chunk]
            cand = cand[lbs[qi, cand] < best]
            if len(cand) == 0:
                if lbs[qi, idx[min(s, len(idx) - 1)]] >= best:
                    break
                continue
            # Pad the candidate batch to a power of two so the number of
            # distinct shapes hitting the kernel path stays O(log chunk)
            # instead of one trace/compile per survivor count.
            n_c = len(cand)
            n_pad = 1 << (n_c - 1).bit_length()
            cand_p = np.concatenate([cand, np.repeat(cand[:1], n_pad - n_c)])
            d = np.asarray(elastic_pairwise(
                jnp.broadcast_to(jnp.asarray(Q[qi]), (n_pad, Q.shape[1])),
                jnp.asarray(X[cand_p]), window))[:n_c]
            n_dtw += len(cand)
            j = int(np.argmin(d))
            if d[j] < best:
                best, best_i = float(d[j]), int(cand[j])
        preds[qi] = labels[best_i]
    pruned = 1.0 - n_dtw / float(Q.shape[0] * X.shape[0])
    return preds, pruned
