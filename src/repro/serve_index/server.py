"""The serving core: coalesced queries + concurrent ingest over one index.

:class:`IndexServer` wraps a :class:`~repro.index.streaming.
StreamingIndex` with the two halves production traffic needs:

* **Read path** — a :class:`~repro.serve_index.coalescer.QueryCoalescer`
  merges concurrent search requests into bucketed padded launches against
  the latest published :class:`~repro.serve_index.view.IndexView`.
  Searches never take a lock and never block on ingest: a seal or
  compaction running on the writer thread is invisible until its finished
  state is published as a new immutable view (snapshot swap = one
  reference assignment).
* **Write path** — inserts, deletes and maintenance (flush/compact) are
  applied by a single writer thread that owns the underlying index,
  feeding from a *bounded* queue.  Admission control is the queue bound
  plus a shed policy (:data:`~repro.serve_index.config.SHED_POLICIES`):
  under sustained overload the server sheds inserts (raising
  :class:`Backpressure` to the producer) while still admitting deletes,
  instead of growing an unbounded backlog.  After applying a batch of
  write ops the writer captures and publishes a fresh view; completed
  write futures resolve only after the publish, so ``insert(...).result()``
  implies the rows are visible to subsequent queries.

Every stage is metered through :mod:`repro.obs` (queue depth, coalesced
batch sizes, shed counts, snapshot-swap latency — full table in
``docs/serving.md``) and the whole surface degrades to zero overhead with
obs disabled, like the rest of the library.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core.ivf import validate_n_probe
from ..index.streaming import StreamingIndex
from .coalescer import QueryCoalescer
from .config import ServeConfig
from .view import IndexView

__all__ = ["IndexServer", "Backpressure", "SearchResult"]


class Backpressure(RuntimeError):
    """Raised to a producer when admission control sheds its write."""


class SearchResult(NamedTuple):
    """One request's answer: distances/ids plus the view version that
    produced them (every row of one request shares a version — the whole
    coalesced batch ran against a single immutable snapshot)."""
    dist: jnp.ndarray    # (n, topk) float32
    ids: jnp.ndarray     # (n, topk) int32, -1 where < topk live rows
    version: int


class _Op(NamedTuple):
    kind: str            # "insert" | "delete" | "flush" | "compact" | "barrier"
    payload: tuple
    future: Future


_STOP = object()


class IndexServer:
    """Concurrent serving front-end over a :class:`StreamingIndex`.

    The server takes ownership of the index: while it is running, all
    mutation must go through :meth:`insert` / :meth:`delete` /
    :meth:`flush` / :meth:`compact` (the writer thread is the only code
    touching the underlying object) and all searches through
    :meth:`search` / :meth:`submit_search`.  Use as a context manager::

        with IndexServer(index, ServeConfig(n_probe=4, topk=3)) as srv:
            srv.insert(X).result()            # applied + visible
            d, nn = srv.search(Q)             # coalesced with other threads

    ``on_publish`` (optional) is called with every newly published
    :class:`IndexView` from the writer thread — a seam for tests and for
    replication/backup hooks; it must not mutate the index.
    """

    # Threading contract, enforced statically (RS301 in repro.analysis):
    # these fields are owned by the writer thread and may only be
    # (re)bound from the methods below — readers see them through the
    # immutable published IndexView, never directly.
    _WRITER_ONLY = frozenset({"_index", "_version", "_view"})
    _WRITER_METHODS = frozenset({"_writer_loop", "_apply", "_publish"})

    def __init__(self, index: StreamingIndex,
                 cfg: Optional[ServeConfig] = None,
                 on_publish=None):
        self.cfg = cfg if cfg is not None else ServeConfig()
        validate_n_probe(self.cfg.n_probe, index.cfg.n_lists)
        self._index = index
        self._on_publish = on_publish
        self._version = 0
        self._view = IndexView.capture(index, version=0)
        self._wq: "queue.Queue" = queue.Queue(maxsize=self.cfg.queue_bound)
        self._coalescer = QueryCoalescer(self._run_batch, self.cfg)
        self._writer = threading.Thread(
            target=self._writer_loop, name="repro-serve-writer", daemon=True)
        self._started = False
        self._stopped = False

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "IndexServer":
        if self._started:
            raise RuntimeError("server already started")
        self._started = True
        self._coalescer.start()
        self._writer.start()
        return self

    def stop(self) -> None:
        """Graceful shutdown: queued writes are applied and queued queries
        answered before the threads exit."""
        if not self._started or self._stopped:
            return
        self._stopped = True
        self._wq.put(_STOP)               # blocking: always admitted
        self._writer.join()
        self._coalescer.stop()

    def __enter__(self) -> "IndexServer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False

    # -- read path -----------------------------------------------------------

    def submit_search(self, Q: np.ndarray) -> Future:
        """Enqueue ``Q (n, D)`` for the next coalesced batch; the future
        resolves to a :class:`SearchResult`."""
        Q = np.asarray(Q, np.float32)
        if Q.ndim != 2 or Q.shape[1] != self._index.dim:
            raise ValueError(
                f"expected (n, {self._index.dim}) queries, got {Q.shape}")
        if Q.shape[0] == 0:
            raise ValueError("empty query batch")
        return self._coalescer.submit(Q)

    def search(self, Q: np.ndarray, timeout: Optional[float] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Blocking convenience wrapper -> ``(dist, ids)`` like
        :meth:`StreamingIndex.search` (``n_probe``/``topk`` are fixed by
        the :class:`ServeConfig`)."""
        r = self.submit_search(Q).result(timeout)
        return r.dist, r.ids

    def _run_batch(self, Qp: jnp.ndarray, q_valid: jnp.ndarray,
                   n_real: int) -> SearchResult:
        view = self._view                 # one atomic read: the whole batch
        d, ids = view.search(Qp, n_probe=self.cfg.n_probe,
                             topk=self.cfg.topk, q_valid=q_valid)
        return SearchResult(d, ids, view.version)

    # -- write path ----------------------------------------------------------

    def insert(self, X: np.ndarray, ids: Optional[Sequence[int]] = None
               ) -> Future:
        """Admit an insert; resolves to the assigned external ids.  Raises
        :class:`Backpressure` immediately when the queue is full under a
        shedding policy."""
        X = np.asarray(X, np.float32)
        return self._submit_write("insert", (X, ids))

    def delete(self, ids: Sequence[int]) -> Future:
        """Admit a delete (tombstone); resolves to the hit count.  Under
        the default ``shed_inserts`` policy deletes are never shed — a
        full queue blocks the caller instead (deletes free space)."""
        return self._submit_write("delete", (np.asarray(ids, np.int32),))

    def flush(self) -> Future:
        """Request a seal of the hot buffer (maintenance; never shed)."""
        return self._submit_write("flush", ())

    def compact(self) -> Future:
        """Request a compaction (maintenance; never shed)."""
        return self._submit_write("compact", ())

    def quiesce(self, timeout: Optional[float] = None) -> int:
        """Wait until every previously admitted write is applied and
        published; returns the version of the resulting view."""
        fut = self._submit_write("barrier", ())
        return fut.result(timeout)

    def _submit_write(self, kind: str, payload: tuple) -> Future:
        if not self._started or self._stopped:
            raise RuntimeError("server is not running")
        fut: Future = Future()
        op = _Op(kind, payload, fut)
        sheddable = (kind == "insert" if self.cfg.shed_policy ==
                     "shed_inserts" else
                     kind in ("insert", "delete")
                     if self.cfg.shed_policy == "shed_all" else False)
        if sheddable:
            try:
                self._wq.put_nowait(op)
            except queue.Full:
                if obs.enabled():
                    obs.counter("serving_shed_total", persistent=True,
                                op=kind).inc()
                raise Backpressure(
                    f"write queue full ({self.cfg.queue_bound} pending): "
                    f"{kind} shed under policy "
                    f"{self.cfg.shed_policy!r}") from None
        else:
            self._wq.put(op)              # backpressure: block the producer
        if obs.enabled():
            obs.gauge("serving_write_queue_depth",
                      persistent=True).set(self._wq.qsize())
        return fut

    def _writer_loop(self) -> None:
        while True:
            op = self._wq.get()
            stop = op is _STOP
            ops = [] if stop else [op]
            while not stop and len(ops) < self.cfg.apply_batch:
                try:
                    nxt = self._wq.get_nowait()
                except queue.Empty:
                    break
                if nxt is _STOP:
                    stop = True
                    break
                ops.append(nxt)
            if ops:
                self._apply(ops)
            if stop:
                return

    def _apply(self, ops) -> None:
        index = self._index
        outcomes = []                     # (op, ok, value_or_exc)
        with obs.span("serving.apply"):
            for op in ops:
                try:
                    if op.kind == "insert":
                        outcomes.append((op, True, index.insert(*op.payload)))
                    elif op.kind == "delete":
                        outcomes.append((op, True, index.delete(*op.payload)))
                    elif op.kind == "flush":
                        index.flush()
                        outcomes.append((op, True, None))
                    elif op.kind == "compact":
                        index.compact()
                        outcomes.append((op, True, None))
                    # "barrier": resolved with the published version below
                except BaseException as e:   # noqa: BLE001 - forwarded
                    outcomes.append((op, False, e))
        version = self._publish()
        # futures resolve only after the publish: a completed write is a
        # *visible* write
        for op, ok, val in outcomes:
            (op.future.set_result if ok else op.future.set_exception)(val)
        for op in ops:
            if op.kind == "barrier":
                op.future.set_result(version)
        if obs.enabled():
            obs.gauge("serving_write_queue_depth",
                      persistent=True).set(self._wq.qsize())

    def _publish(self) -> int:
        t0 = time.perf_counter()
        with obs.span("serving.snapshot_swap"):
            self._version += 1
            view = IndexView.capture(self._index, self._version)
            self._view = view             # the swap: one atomic rebind
        if obs.enabled():
            obs.histogram("serving_snapshot_swap_seconds",
                          persistent=True).record(time.perf_counter() - t0)
            obs.counter("serving_view_swaps_total", persistent=True).inc()
            obs.gauge("serving_view_version",
                      persistent=True).set(view.version)
        if self._on_publish is not None:
            self._on_publish(view)
        return view.version

    # -- introspection -------------------------------------------------------

    @property
    def view(self) -> IndexView:
        """The currently published immutable snapshot."""
        return self._view

    @property
    def version(self) -> int:
        return self._view.version

    def pressure(self) -> float:
        """Write-queue occupancy in [0, 1] — the backpressure signal a
        producer can watch to pace itself before shedding starts."""
        return self._wq.qsize() / self.cfg.queue_bound

    def stats(self) -> dict:
        """Host-side serving stats (no device syncs)."""
        return dict(version=self._view.version,
                    n_segments=len(self._view.segments),
                    write_queue_depth=self._wq.qsize(),
                    pressure=self.pressure())
