"""Streaming segmented IVF-PQDTW index — the lifecycle layer.

The paper's §4.1 IVF system (``repro.core.ivf``) builds one frozen index
from one frozen array.  This package turns it into a long-lived structure:

    insert   -> fresh series land in a fixed-capacity exact "hot" segment
                (searched with banded DTW through ``core.dispatch``)
    flush    -> a full hot segment is *sealed*: PQ-encoded against the
                shared codebook and laid out as an inverted-list shard
    delete   -> tombstone masks in hot and sealed segments
    compact  -> sealed segments merge into one shard (dead rows dropped,
                inverted lists re-balanced)
    snapshot -> atomic tmp-dir/fsync/rename persistence (checkpoint
                protocol), restore on any device topology

Search fans a query batch out over the hot segment and every sealed
segment, merging per-shard top-k with one final ``lax.top_k``; the planner
(:mod:`repro.index.planner`) additionally scales out over a device mesh
with ``shard_map`` — either sharding the query batch (index replicated)
or partitioning the inverted lists themselves across devices
(:mod:`repro.index.placement`, ``IndexConfig(n_shards=...)``) with a
device-resident ``all_gather`` top-k fan-in.
"""

from .placement import placement_loads, plan_placement
from .segments import HotBuffer, SealedSegment
from .streaming import IndexConfig, StreamingIndex
from .snapshot import latest_snapshot, restore_snapshot, save_snapshot
from .planner import search_sharded

__all__ = [
    "HotBuffer", "SealedSegment",
    "IndexConfig", "StreamingIndex",
    "plan_placement", "placement_loads",
    "save_snapshot", "restore_snapshot", "latest_snapshot",
    "search_sharded",
]
