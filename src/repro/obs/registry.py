"""Process-wide metrics registry: Counter / Gauge / Histogram.

The primitives are deliberately plain host-side Python — incrementing a
counter is two dict operations, recording a histogram sample is a list
append — so the *write* path is cheap enough to leave wired into every
pipeline layer.  Anything device-related (fencing, syncing) lives in
:mod:`repro.obs.spans`, gated behind :func:`repro.obs.enabled`.

Histograms keep BOTH representations the observability layer needs:

* exponential ``le`` buckets (Prometheus-style cumulative counts on
  export), for cheap aggregation across processes;
* the raw recorded samples (up to :data:`MAX_SAMPLES`), so ``p50/p95/p99``
  are *exact* — :func:`percentile` implements numpy's default
  linear-interpolation definition and is tested against
  ``numpy.percentile`` directly.

Metrics created with ``persistent=True`` survive :meth:`Registry.reset`
(the analogue of ``dispatch.totals`` vs ``dispatch.stats``): the library's
own instrumentation — dispatch routing counters, stage spans — is
persistent, so a test/CI session can reset scratch metrics without
erasing the process-lifetime ledgers the routing/coverage gates assert on.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
           "exp_buckets", "percentile", "DEFAULT_LATENCY_BUCKETS",
           "MAX_SAMPLES"]

# Raw-sample cap per histogram: beyond this, new samples still update
# count/sum/min/max and the buckets, but are no longer stored verbatim
# (percentiles then interpolate within the stored prefix — flagged via
# ``samples_capped`` in snapshots so readers know they are approximate).
MAX_SAMPLES = 100_000

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def exp_buckets(start: float, factor: float, count: int) -> Tuple[float, ...]:
    """``count`` exponential bucket upper bounds: ``start * factor**i``.
    The implicit ``+Inf`` overflow bucket is always appended on export."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError(
            f"exp_buckets needs start > 0, factor > 1, count >= 1; got "
            f"start={start}, factor={factor}, count={count}")
    return tuple(start * factor ** i for i in range(count))


# 100us .. ~52s in doublings: covers a kernel launch on real hardware up
# to a cold-trace CPU-interpret search.
DEFAULT_LATENCY_BUCKETS = exp_buckets(1e-4, 2.0, 20)


def percentile(samples: Sequence[float], p: float) -> float:
    """Exact percentile of ``samples`` (numpy's default linear
    interpolation — ``numpy.percentile(samples, p)``)."""
    if not 0 <= p <= 100:
        raise ValueError(f"percentile p={p} out of range [0, 100]")
    s = sorted(samples)
    if not s:
        raise ValueError("percentile of an empty sample set")
    if len(s) == 1:
        return float(s[0])
    rank = (p / 100.0) * (len(s) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(s) - 1)
    frac = rank - lo
    return float(s[lo] * (1.0 - frac) + s[hi] * frac)


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "labels", "persistent", "value")

    def __init__(self, name: str, labels: Dict[str, str],
                 persistent: bool = False):
        self.name = name
        self.labels = dict(labels)
        self.persistent = persistent
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} increment must be >= 0")
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "labels", "persistent", "value")

    def __init__(self, name: str, labels: Dict[str, str],
                 persistent: bool = False):
        self.name = name
        self.labels = dict(labels)
        self.persistent = persistent
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Exponential-bucket histogram with exact raw-sample percentiles."""

    __slots__ = ("name", "labels", "persistent", "bounds", "bucket_counts",
                 "count", "sum", "min", "max", "samples")

    def __init__(self, name: str, labels: Dict[str, str],
                 buckets: Optional[Sequence[float]] = None,
                 persistent: bool = False):
        bounds = tuple(buckets) if buckets is not None \
            else DEFAULT_LATENCY_BUCKETS
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(
                f"histogram {name} bucket bounds must be strictly "
                f"increasing, got {bounds}")
        self.name = name
        self.labels = dict(labels)
        self.persistent = persistent
        self.bounds = bounds
        # non-cumulative per-bucket counts; [-1] is the +Inf overflow
        self.bucket_counts = [0] * (len(bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.samples: List[float] = []

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self.bucket_counts[self._bucket(v)] += 1
        if len(self.samples) < MAX_SAMPLES:
            self.samples.append(v)

    def _bucket(self, v: float) -> int:
        # Prometheus ``le`` semantics: a sample equal to a bound belongs
        # to that bound's bucket (first i with v <= bounds[i]).
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        return lo

    @property
    def samples_capped(self) -> bool:
        return self.count > len(self.samples)

    def percentile(self, p: float) -> float:
        return percentile(self.samples, p)

    def cumulative_counts(self) -> List[int]:
        """Prometheus cumulative bucket counts (last entry == count)."""
        out, acc = [], 0
        for c in self.bucket_counts:
            acc += c
            out.append(acc)
        return out


class Registry:
    """Get-or-create store of metrics keyed by (name, sorted labels)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, str, LabelKey], object] = {}

    def _get(self, kind: str, cls, name: str, labels: Dict[str, str],
             persistent: bool, **kwargs):
        key = (kind, name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, labels, persistent=persistent, **kwargs)
                self._metrics[key] = m
            return m

    def counter(self, name: str, persistent: bool = False,
                **labels: str) -> Counter:
        return self._get("counter", Counter, name, labels, persistent)

    def gauge(self, name: str, persistent: bool = False,
              **labels: str) -> Gauge:
        return self._get("gauge", Gauge, name, labels, persistent)

    def histogram(self, name: str, buckets: Optional[Sequence[float]] = None,
                  persistent: bool = False, **labels: str) -> Histogram:
        h = self._get("histogram", Histogram, name, labels, persistent,
                      buckets=buckets)
        if buckets is not None and tuple(buckets) != h.bounds:
            raise ValueError(
                f"histogram {name}{labels} already exists with buckets "
                f"{h.bounds}, asked for {tuple(buckets)}")
        return h

    def counters(self) -> List[Counter]:
        return [m for (k, _, _), m in sorted(self._metrics.items())
                if k == "counter"]

    def gauges(self) -> List[Gauge]:
        return [m for (k, _, _), m in sorted(self._metrics.items())
                if k == "gauge"]

    def histograms(self) -> List[Histogram]:
        return [m for (k, _, _), m in sorted(self._metrics.items())
                if k == "histogram"]

    def reset(self, include_persistent: bool = False) -> None:
        """Drop metrics (scratch only by default — the process-lifetime
        instrumentation ledgers survive unless ``include_persistent``)."""
        with self._lock:
            if include_persistent:
                self._metrics.clear()
            else:
                self._metrics = {k: m for k, m in self._metrics.items()
                                 if m.persistent}


# The process-wide default registry every instrumented layer writes to.
REGISTRY = Registry()
