"""Transformer building blocks — pure-JAX, pytree params, shape-static.

Conventions:
  * params are float32 pytrees; matmuls run in bfloat16 with float32
    accumulation (``preferred_element_type``); norms/softmax in float32.
  * attention activations use the GQA layout (B, S, G, R, hd) so the
    head-group structure is visible to the SPMD partitioner.
  * prefill attention is query-chunked (`lax.scan` over chunks) with the
    full score block materialised per chunk — bounded VMEM/HBM per step and
    a natural remat boundary for 32k-token prefill.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

__all__ = ["rms_norm", "rotary", "apply_rope", "mrope_positions",
           "attention", "attention_decode", "mlp", "moe", "init_attn",
           "init_mlp", "init_moe", "softcap"]

_NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Norms / activations / rope
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMS norm: f32 variance reduction, bf16 normalization multiply.

    Keeping the full-width elementwise ops in the input dtype means no
    (B, S, d) f32 activation ever exists — XLA was hoisting the f32 cast
    into the remat save buffer, doubling per-layer saved-residual memory
    (10.7 GB/device on qwen2-72b train_4k; EXPERIMENTS.md §Perf B3)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)       # (B, S, 1), tiny
    return x * inv * (1.0 + scale.astype(x.dtype))


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def _act(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def rotary(positions: jnp.ndarray, head_dim: int, theta: float
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables: ``positions (..., S)`` -> ``(..., S, hd/2)`` each."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """``x (B, S, ..., hd)`` rotated by position tables ``(B, S, hd/2)``."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # broadcast cos/sin over the head axes between S and hd
    extra = x.ndim - cos.ndim
    for _ in range(extra):
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_positions(text_positions: jnp.ndarray, n_frontend: int,
                    sections: Tuple[int, ...]) -> jnp.ndarray:
    """Qwen2-VL M-RoPE position ids ``(3, B, S)`` for (t, h, w).

    The first ``n_frontend`` positions are vision patches laid out on an
    (h, w) grid with constant t; text positions advance all three equally.
    """
    B, S = text_positions.shape
    side = max(1, int(n_frontend ** 0.5))
    pos = text_positions
    idx = jnp.arange(S)
    is_patch = idx < n_frontend
    t = jnp.where(is_patch[None, :], 0, pos)
    h = jnp.where(is_patch[None, :], (idx // side)[None, :], pos)
    w = jnp.where(is_patch[None, :], (idx % side)[None, :], pos)
    return jnp.stack([t, h, w])


def _mrope_tables(mpos: jnp.ndarray, head_dim: int, theta: float,
                  sections: Tuple[int, ...]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sectioned rope tables from ``mpos (3, B, S)`` -> ``(B, S, hd/2)``."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = mpos.astype(jnp.float32)[..., None] * freqs     # (3, B, S, half)
    sec = jnp.cumsum(jnp.asarray((0,) + tuple(sections)))
    which = jnp.searchsorted(sec[1:], jnp.arange(half), side="right")
    which = jnp.clip(which, 0, 2)
    picked = jnp.take_along_axis(
        ang, which[None, None, None, :].astype(jnp.int32), axis=0)[0]
    return jnp.cos(picked), jnp.sin(picked)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

class AttnParams(NamedTuple):
    wq: jnp.ndarray            # (d, H*hd)
    wk: jnp.ndarray            # (d, G*hd)
    wv: jnp.ndarray            # (d, G*hd)
    wo: jnp.ndarray            # (H*hd, d)
    bq: Optional[jnp.ndarray]  # (H*hd,) or None
    bk: Optional[jnp.ndarray]
    bv: Optional[jnp.ndarray]


def init_attn(key: jax.Array, cfg: ModelConfig, d_in: Optional[int] = None
              ) -> AttnParams:
    d = d_in or cfg.d_model
    hd, H, G = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    sc = 0.02
    bias = (lambda n: jnp.zeros((n,), jnp.float32)) if cfg.qkv_bias else (lambda n: None)
    return AttnParams(
        wq=jax.random.normal(ks[0], (d, H * hd), jnp.float32) * sc,
        wk=jax.random.normal(ks[1], (d, G * hd), jnp.float32) * sc,
        wv=jax.random.normal(ks[2], (d, G * hd), jnp.float32) * sc,
        wo=jax.random.normal(ks[3], (H * hd, d), jnp.float32) * sc,
        bq=bias(H * hd), bk=bias(G * hd), bv=bias(G * hd))


def _dot(x, w, bias=None, preferred=jnp.bfloat16):
    """bf16 matmul.  ``preferred`` bf16 keeps partial sums bf16 so the TP
    all-reduce of row-parallel outputs (wo / w_down / MoE combine) moves
    half the bytes — each shard's matmul still accumulates in f32 on the
    MXU; only the cross-shard combine is bf16 (Megatron convention).
    Pass ``preferred=jnp.float32`` where full precision matters (router)."""
    y = jax.lax.dot_general(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                            (((x.ndim - 1,), (0,)), ((), ())),
                            preferred_element_type=preferred)
    if bias is not None:
        y = y + bias
    return y.astype(jnp.bfloat16)


def _qkv(p: AttnParams, cfg: ModelConfig, x: jnp.ndarray,
         cos, sin) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    hd, H, G = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    R = H // G
    q = _dot(x, p.wq, p.bq).reshape(B, S, G, R, hd)
    k = _dot(x, p.wk, p.bk).reshape(B, S, G, hd)
    v = _dot(x, p.wv, p.bv).reshape(B, S, G, hd)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _attend_block(q_blk, k, v, *, scale, cap, mask):
    """``q_blk (B, Qc, G, R, hd)``, ``k/v (B, S, G, hd)``, ``mask (Qc, S)``
    or ``(B, Qc, S)`` -> ``(B, Qc, G, R, hd)``."""
    scores = jnp.einsum("bqgrh,bkgh->bgrqk", q_blk.astype(jnp.bfloat16),
                        k.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cap)
    if mask.ndim == 2:
        mask_b = mask[None, None, None]
    else:
        mask_b = mask[:, None, None]
    scores = jnp.where(mask_b, scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(jnp.bfloat16)
    # bf16 partial sums: the decode-time seq-sharded contraction all-reduces
    # in bf16 (within-shard accumulation is still f32 on the MXU)
    return jnp.einsum("bgrqk,bkgh->bqgrh", p, v.astype(jnp.bfloat16),
                      preferred_element_type=jnp.bfloat16)


def attention(p: AttnParams, cfg: ModelConfig, x: jnp.ndarray,
              positions: jnp.ndarray, *, causal: bool = True,
              window: int = 0, q_chunk: int = 512,
              cos_sin: Optional[Tuple] = None,
              kv_override: Optional[Tuple] = None) -> jnp.ndarray:
    """Full-sequence attention (training / prefill), query-chunked.

    ``window > 0`` restricts to a sliding window (gemma2 local layers).
    ``kv_override=(k, v, kv_mask)`` implements cross-attention: K/V come
    from the encoder instead of ``x`` (rope skipped on overridden K).
    """
    B, S, _ = x.shape
    hd = cfg.head_dim_
    scale = hd ** -0.5
    if cos_sin is None:
        cos, sin = rotary(positions, hd, cfg.rope_theta)
    else:
        cos, sin = cos_sin

    if kv_override is None:
        q, k, v = _qkv(p, cfg, x, cos, sin)
        Sk = S
        kv_mask = None
    else:
        G = cfg.n_kv_heads
        R = cfg.n_heads // G
        q = _dot(x, p.wq, p.bq).reshape(B, S, G, R, hd)
        q = apply_rope(q, cos, sin)
        k, v, kv_mask = kv_override
        Sk = k.shape[1]

    nc = S // q_chunk if (S % q_chunk == 0 and S > q_chunk) else 1
    qc = S // nc
    kpos = jnp.arange(Sk)

    def chunk(start):
        q_blk = jax.lax.dynamic_slice_in_dim(q, start * qc, qc, axis=1)
        qpos = start * qc + jnp.arange(qc)
        if causal:
            mask = kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kpos[None, :] > (qpos[:, None] - window)
        else:
            mask = jnp.ones((qc, Sk), bool)
        if kv_mask is not None:
            mask = mask[None] & kv_mask[:, None, :]
        return _attend_block(q_blk, k, v, scale=scale,
                             cap=cfg.attn_softcap, mask=mask)

    if nc == 1:
        out = chunk(jnp.int32(0))
    else:
        _, outs = jax.lax.scan(lambda c, i: (c, chunk(i)), 0, jnp.arange(nc))
        moved = jnp.moveaxis(outs, 0, 1)          # (B, nc, qc, G, R, hd)
        out = moved.reshape(B, nc * qc, *moved.shape[3:])
    out = out.reshape(B, S, cfg.n_heads * hd)
    return _dot(out, p.wo)


def attention_decode(p: AttnParams, cfg: ModelConfig, x: jnp.ndarray,
                     k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     pos: jnp.ndarray, *, window: int = 0,
                     update_cache: bool = True,
                     cos_sin: Optional[Tuple] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode: ``x (B, 1, d)``; caches ``(B, Smax, G, hd)``.

    Returns (out (B, 1, d), new_k_cache, new_v_cache).  ``update_cache=False``
    reads without writing (cross-attention decode).
    """
    B, _, _ = x.shape
    hd, H, G = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    R = H // G
    Smax = k_cache.shape[1]
    scale = hd ** -0.5
    positions = jnp.full((B, 1), pos, jnp.int32)
    if cos_sin is None:
        cos, sin = rotary(positions, hd, cfg.rope_theta)
    else:
        cos, sin = cos_sin

    q = _dot(x, p.wq, p.bq).reshape(B, 1, G, R, hd)
    q = apply_rope(q, cos, sin)
    if update_cache:
        k_new = _dot(x, p.wk, p.bk).reshape(B, 1, G, hd)
        v_new = _dot(x, p.wv, p.bv).reshape(B, 1, G, hd)
        k_new = apply_rope(k_new, cos, sin)
        # one-hot select instead of dynamic-update-slice: a DUS at a runtime
        # position on the model-sharded seq axis makes SPMD all-gather the
        # cache every layer (EXPERIMENTS.md §Perf C1); the select is
        # shard-local and aliases the donated cache buffer.
        write = (jnp.arange(Smax) == pos)[None, :, None, None]
        k_cache = jnp.where(write, k_new.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(write, v_new.astype(v_cache.dtype), v_cache)

    kpos = jnp.arange(Smax)
    mask = kpos <= pos
    if window > 0:
        mask &= kpos > (pos - window)
    out = _attend_block(q, k_cache, v_cache, scale=scale,
                        cap=cfg.attn_softcap, mask=mask[None, :])
    out = out.reshape(B, 1, H * hd)
    return _dot(out, p.wo), k_cache, v_cache


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

class MlpParams(NamedTuple):
    w_gate: jnp.ndarray   # (d, f)
    w_up: jnp.ndarray     # (d, f)
    w_down: jnp.ndarray   # (f, d)


def init_mlp(key: jax.Array, d: int, f: int) -> MlpParams:
    ks = jax.random.split(key, 3)
    sc = 0.02
    return MlpParams(
        w_gate=jax.random.normal(ks[0], (d, f), jnp.float32) * sc,
        w_up=jax.random.normal(ks[1], (d, f), jnp.float32) * sc,
        w_down=jax.random.normal(ks[2], (f, d), jnp.float32) * sc)


def mlp(p: MlpParams, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    g = _act(_dot(x, p.w_gate).astype(jnp.float32), act).astype(jnp.bfloat16)
    u = _dot(x, p.w_up)
    return _dot(g * u, p.w_down)


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k routing, capacity drop, optional shared experts)
# ---------------------------------------------------------------------------

class MoeParams(NamedTuple):
    router: jnp.ndarray              # (d, E)
    we_gate: jnp.ndarray             # (E, d, f)
    we_up: jnp.ndarray               # (E, d, f)
    we_down: jnp.ndarray             # (E, f, d)
    shared: Optional[MlpParams]      # fused shared experts or None


def init_moe(key: jax.Array, cfg: ModelConfig) -> MoeParams:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    sc = 0.02
    shared = None
    if cfg.n_shared_experts:
        shared = init_mlp(ks[4], d, f * cfg.n_shared_experts)
    return MoeParams(
        router=jax.random.normal(ks[0], (d, E), jnp.float32) * sc,
        we_gate=jax.random.normal(ks[1], (E, d, f), jnp.float32) * sc,
        we_up=jax.random.normal(ks[2], (E, d, f), jnp.float32) * sc,
        we_down=jax.random.normal(ks[3], (E, f, d), jnp.float32) * sc,
        shared=shared)


def moe(p: MoeParams, cfg: ModelConfig, x: jnp.ndarray,
        capacity_factor: float = 1.25) -> jnp.ndarray:
    """Top-k routed experts with static per-expert capacity.

    Dispatch = per-expert top-C token selection (gather), compute = grouped
    einsum over the expert axis (EP-shardable), combine = scatter-add.
    FLOPs scale with *active* experts only — honest MoE roofline.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.n_active_experts
    T = B * S
    xf = x.reshape(T, d)

    logits = _dot(xf, p.router, preferred=jnp.float32
                  ).astype(jnp.float32)                    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                 # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # sparse routing matrix (T, E): weight where routed, else 0
    W = jnp.zeros((T, E), jnp.float32)
    W = W.at[jnp.arange(T)[:, None], top_i].set(top_w)

    C = max(8, int(-(-k * T * capacity_factor // E) // 8 * 8))
    C = min(C, T)
    w_ec, tok_ec = jax.lax.top_k(W.T, C)                   # (E, C) each

    # expert-parallel layout: experts (E) on the model axis, capacity (C)
    # on the DP axes — without the C constraint every DP shard runs the
    # SAME expert matmuls and their grads all-reduce over data
    # (EXPERIMENTS.md §Perf A3: 0.9 TB/device of (E,C,f) grad collectives).
    from ..sharding.partition import constrain_dims
    w_ec = constrain_dims(w_ec, {0: "model", 1: "dp"})
    tok_ec = constrain_dims(tok_ec, {0: "model", 1: "dp"})

    xg = xf[tok_ec.reshape(-1)].reshape(E, C, d)           # gather
    xg = constrain_dims(xg.astype(jnp.bfloat16),
                        {0: "model", 1: "dp"})
    g = jnp.einsum("ecd,edf->ecf", xg, p.we_gate.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xg, p.we_up.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    h = (_act(g, cfg.act) * u).astype(jnp.bfloat16)
    y = jnp.einsum("ecf,efd->ecd", h, p.we_down.astype(jnp.bfloat16),
                   preferred_element_type=jnp.bfloat16)    # (E, C, d)

    y = y * w_ec[..., None].astype(jnp.bfloat16)
    # bf16 combine: the cross-expert-shard all-reduce of the (T, d) scatter
    # output moves half the bytes; <= top-k partials summed per token.
    out = jnp.zeros((T, d), jnp.bfloat16)
    out = out.at[tok_ec.reshape(-1)].add(y.reshape(-1, d))

    if p.shared is not None:
        out = out + mlp(p.shared, xf.astype(jnp.bfloat16), cfg.act)
    return out.reshape(B, S, d)
