"""Mini dispatch: every counted op registered and routing-gated."""

from ..kernels.goodk.ops import run_goodk
from ..kernels.goodk.ref import run_goodk_ref


def _count(op, route, measure=None):
    del op, route, measure


def goodk(x, backend="pallas"):
    _count("goodk", backend)
    if backend == "jnp":
        return run_goodk_ref(x)
    return run_goodk(x)


def goodk_adaptive(x, backend="pallas"):
    _count("goodk_adaptive", backend)  # mode twin, gated in EXPECTED_OPS
    if backend == "jnp":
        return run_goodk_ref(x)
    return run_goodk(x)
