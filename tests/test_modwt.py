"""MODWT pre-alignment: scale coefficients, segmentation, snapping, interp."""

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.modwt import (modwt_scale, segment_points, snap_splits,
                              extract_segments, prealign, fixed_segments)


def test_scale_level1_is_pairwise_mean():
    x = np.arange(8, dtype=np.float32)
    v = np.asarray(modwt_scale(x, 1))
    want = 0.5 * (x + np.roll(x, 1))
    assert np.allclose(v, want)


def test_scale_level_j_is_dyadic_mean():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(64).astype(np.float32)
    for j in (1, 2, 3):
        v = np.asarray(modwt_scale(x, j))
        width = 2 ** j
        want = np.array([np.mean([x[(i - s) % 64] for s in range(width)])
                         for i in range(64)])
        assert np.allclose(v, want, atol=1e-5), j


def test_constant_series_has_no_segment_points():
    x = np.ones(32, np.float32)
    pts = np.asarray(segment_points(x, 2))
    assert not pts.any()


def test_segment_points_on_square_wave():
    t = np.arange(64)
    x = np.where((t // 16) % 2 == 0, 1.0, -1.0).astype(np.float32)
    pts = np.asarray(segment_points(x, 3))
    assert pts.any()  # transitions must be detected


def test_snap_splits_uses_rightmost_point_in_tail():
    L, n_sub, tail = 32, 4, 4
    pts = np.zeros(L, bool)
    pts[6] = True   # inside [8-4, 8] -> split 8 moves to 6
    pts[5] = True   # 6 is right-most, wins
    pts[20] = True  # inside [24-4, 24] -> split 24 moves to 20; split 16 stays
    bounds = np.asarray(snap_splits(pts, n_sub, tail))
    assert bounds.tolist() == [0, 6, 16, 20, 32]


def test_snap_splits_batched_shape():
    pts = np.zeros((5, 64), bool)
    b = np.asarray(snap_splits(pts, 4, 3))
    assert b.shape == (5, 5)
    assert (b[:, 0] == 0).all() and (b[:, -1] == 64).all()


def test_extract_segments_identity_resample():
    x = np.arange(16, dtype=np.float32)
    bounds = np.array([0, 8, 16], np.int32)
    segs = np.asarray(extract_segments(x, bounds, 8))
    assert np.allclose(segs[0], x[:8], atol=1e-5)
    assert np.allclose(segs[1], x[8:], atol=1e-5)


def test_extract_segments_linear_interp():
    x = np.arange(16, dtype=np.float32)
    bounds = np.array([0, 4, 16], np.int32)
    segs = np.asarray(extract_segments(x, bounds, 7))
    # first segment covers x[0..3], resampled to 7 points: linspace(0,3,7)
    assert np.allclose(segs[0], np.linspace(0, 3, 7), atol=1e-5)


def test_prealign_shapes_and_finiteness():
    rng = np.random.default_rng(3)
    X = rng.standard_normal((6, 120)).astype(np.float32)
    out = np.asarray(prealign(X, n_sub=4, level=3, tail=5))
    assert out.shape == (6, 4, 120 // 4 + 5)
    assert np.isfinite(out).all()


def test_fixed_segments_roundtrip():
    X = np.arange(24, dtype=np.float32).reshape(2, 12)
    segs = np.asarray(fixed_segments(X, 3))
    assert segs.shape == (2, 3, 4)
    assert np.allclose(segs.reshape(2, 12), X)


# ---------------------------------------------------------------------------
# Pre-alignment edge-case properties (fused-path contract)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.floats(-100.0, 100.0), st.integers(1, 4), st.integers(0, 6))
def test_property_constant_series_keeps_fixed_splits(value, level, tail):
    """A constant series has no sign changes, so every split stays at its
    fixed position and the re-interpolated segments are constant too."""
    L, M = 32, 4
    x = np.full((L,), value, np.float32)
    pts = np.asarray(segment_points(x, level))
    assert not pts.any()
    bounds = np.asarray(snap_splits(pts, M, tail))
    np.testing.assert_array_equal(bounds, np.arange(M + 1) * (L // M))
    out = np.asarray(prealign(x[None], M, level, tail))
    assert out.shape == (1, M, L // M + tail)
    np.testing.assert_allclose(out, value, rtol=1e-6, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4),
       st.sampled_from([(32, 4), (48, 3), (24, 2), (64, 8)]))
def test_property_tail_zero_reduces_to_fixed_segments(seed, level, shape):
    """snap_tail=0 means an empty snap window: pre-alignment degenerates to
    the fixed equal-length chop (up to interpolation roundoff)."""
    L, M = shape
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((3, L)).astype(np.float32)
    got = np.asarray(prealign(X, M, level, tail=0))
    want = np.asarray(fixed_segments(X, M))
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_config_snap_tail_zero_segments_like_fixed():
    """PQConfig.snap_tail=0 flows through segment(): same shapes/values as
    a no-prealign config."""
    from repro.core.pq import PQConfig, segment
    rng = np.random.default_rng(5)
    X = rng.standard_normal((4, 48)).astype(np.float32)
    cfg0 = PQConfig(n_sub=4, use_prealign=True, snap_tail=0)
    cfg_off = PQConfig(n_sub=4, use_prealign=False)
    assert cfg0.tail(48) == 0
    assert cfg0.subseq_len(48) == cfg_off.subseq_len(48) == 12
    np.testing.assert_allclose(np.asarray(segment(X, cfg0)),
                               np.asarray(segment(X, cfg_off)),
                               rtol=1e-5, atol=1e-5)


def test_config_snap_tail_overrides_tail_frac():
    from repro.core.pq import PQConfig
    cfg = PQConfig(n_sub=4, tail_frac=0.15, snap_tail=5)
    assert cfg.tail(48) == 5
    assert cfg.subseq_len(48) == 17
    # None keeps the fractional default
    assert PQConfig(n_sub=4, tail_frac=0.15).tail(48) == 2
