"""Snapshot persistence for the streaming index.

Reuses the checkpoint layer's atomic-directory protocol
(:func:`repro.checkpoint.ckpt.begin_atomic_dir` / ``write_manifest`` /
``commit_atomic_dir``): arrays land as ``.npy`` leaves in a staging dir,
the JSON manifest is fsync'd as the commit record, and a rename publishes
the snapshot — a crash mid-write never corrupts the latest restorable
state.  The manifest carries the full :class:`IndexConfig` (including the
nested :class:`PQConfig`) plus per-segment static metadata, so restore
needs no out-of-band configuration and works on any device topology.

Format 2 additionally records the elastic measure (name + params) as a
dedicated manifest entry and *validates* it on restore: an unregistered
measure name or a record that disagrees with the embedded config is a
hard error — codes in the snapshot were produced under that measure, so
silently reinterpreting them under another would corrupt every distance.

Format 3 persists the scale-out state: each segment's list-to-device
``placement`` array plus its ``n_shards`` / ``shard_cap`` static metadata
(the shard-major layout restores bit-exactly — no re-placement on
restore), and the two-level coarse quantizer tables when the index has
one.  Formats 1–2 remain restorable: their segments load as the
single-shard layout (``placement`` all zeros, ``shard_cap`` = rows).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from ..checkpoint.ckpt import (MANIFEST, begin_atomic_dir, commit_atomic_dir,
                               gc_numbered_dirs, latest_numbered_dir,
                               write_manifest)
from ..core.ivf import TwoLevelCoarse
from ..core.pq import PQCodebook, PQConfig
from .segments import SealedSegment
from .streaming import IndexConfig, StreamingIndex

__all__ = ["save_snapshot", "restore_snapshot", "latest_snapshot"]

_PREFIX = "snap_"
_FORMAT = 3
_SUPPORTED_FORMATS = (1, 2, 3)   # 1 = pre-measure-registry snapshots (DTW),
                                 # 2 = pre-scale-out (single-shard layout)


def _name(step: int) -> str:
    return f"{_PREFIX}{step:010d}"


def latest_snapshot(directory: str) -> Optional[int]:
    """Newest committed (manifest-bearing) snapshot step, or None."""
    return latest_numbered_dir(directory, _PREFIX)


def save_snapshot(directory: str, index: StreamingIndex,
                  step: Optional[int] = None, keep_last: int = 3) -> str:
    """Atomically persist ``index`` under ``directory/snap_<step>``.

    ``step`` defaults to one past the latest existing snapshot.  The hot
    buffer is persisted raw (inserts survive a restart without a forced
    flush).  Returns the committed path.
    """
    if step is None:
        last = latest_snapshot(directory)
        step = 0 if last is None else last + 1
    tmp = begin_atomic_dir(directory, _name(step))

    arrays: Dict[str, np.ndarray] = {
        "coarse": index.coarse,
        "cb_centroids": index.cb.centroids,
        "cb_lut": index.cb.lut,
        "cb_env_upper": index.cb.env_upper,
        "cb_env_lower": index.cb.env_lower,
        "hot_data": index.hot.data,
        "hot_ids": index.hot.ids,
        "hot_live": index.hot.live,
    }
    if index.two_level is not None:
        arrays["tl_top"] = index.two_level.top
        arrays["tl_child_idx"] = index.two_level.child_idx
        arrays["tl_child_valid"] = index.two_level.child_valid
    seg_meta = []
    for s, sg in enumerate(index.segments):
        for field in ("codes", "ids", "live", "assign", "list_start",
                      "list_len", "placement"):
            arrays[f"seg{s:04d}_{field}"] = getattr(sg, field)
        seg_meta.append({"max_list": sg.max_list, "n_shards": sg.n_shards,
                         "shard_cap": sg.shard_cap})
    for name, arr in arrays.items():
        np.save(os.path.join(tmp, f"{name}.npy"), np.asarray(arr))

    cfg = dataclasses.asdict(index.cfg)
    cfg["pq"] = dataclasses.asdict(index.cfg.pq)
    spec = index.cfg.pq.measure()
    write_manifest(tmp, {
        "format": _FORMAT,
        "step": step,
        "config": cfg,
        "measure": None if spec is None else spec.to_manifest(),
        "dim": index.dim,
        "two_level": index.two_level is not None,
        "next_id": index.next_id,
        "hot_count": index.hot.count,
        "segments": seg_meta,
        "arrays": sorted(arrays),
    })
    final = commit_atomic_dir(tmp, directory, _name(step))
    gc_numbered_dirs(directory, keep_last, _PREFIX)
    return final


def _validate_measure(manifest: dict, cfg: IndexConfig) -> None:
    """Hard-fail on a measure mismatch between the dedicated manifest
    record and the embedded config (and on unregistered measure names) —
    the snapshot's codes/LUTs are only meaningful under the measure that
    produced them.  Format-1 snapshots predate the record and carry their
    measure solely in the config (validated by PQConfig itself)."""
    if manifest["format"] < 2:
        return
    recorded = manifest.get("measure")
    spec = cfg.pq.measure()   # raises for unregistered names
    expected = None if spec is None else spec.to_manifest()
    if recorded != expected:
        raise ValueError(
            f"snapshot measure record {recorded!r} does not match the "
            f"snapshot config's measure {expected!r} — refusing to restore "
            "(codes/LUTs are bound to the measure that built them)")


def restore_snapshot(directory: str, step: Optional[int] = None
                     ) -> StreamingIndex:
    """Rebuild a :class:`StreamingIndex` from ``directory`` (latest snapshot
    unless ``step`` is given); tombstones, hot rows and id allocation state
    all round-trip."""
    if step is None:
        step = latest_snapshot(directory)
        if step is None:
            raise FileNotFoundError(f"no snapshots under {directory!r}")
    d = os.path.join(directory, _name(step))
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    if manifest["format"] not in _SUPPORTED_FORMATS:
        raise ValueError(
            f"snapshot format {manifest['format']} not in supported "
            f"{_SUPPORTED_FORMATS}")

    def load(name: str) -> np.ndarray:
        return np.load(os.path.join(d, f"{name}.npy"))

    cfg_d = dict(manifest["config"])
    cfg_d["pq"] = dict(cfg_d["pq"])
    cfg_d["pq"]["measure_params"] = [
        tuple(p) for p in cfg_d["pq"].get("measure_params", [])]
    cfg = IndexConfig(**{**cfg_d, "pq": PQConfig(**cfg_d["pq"])})
    _validate_measure(manifest, cfg)
    cb = PQCodebook(jnp.asarray(load("cb_centroids")),
                    jnp.asarray(load("cb_lut")),
                    jnp.asarray(load("cb_env_upper")),
                    jnp.asarray(load("cb_env_lower")))
    two_level = None
    if manifest.get("two_level"):
        two_level = TwoLevelCoarse(
            top=jnp.asarray(load("tl_top")),
            child_idx=jnp.asarray(load("tl_child_idx")),
            child_valid=jnp.asarray(load("tl_child_valid")))
    index = StreamingIndex.from_parts(cfg, jnp.asarray(load("coarse")), cb,
                                      manifest["dim"], two_level=two_level)
    index.next_id = manifest["next_id"]
    index.hot.data[:] = load("hot_data")
    index.hot.ids[:] = load("hot_ids")
    index.hot.live[:] = load("hot_live")
    index.hot.count = manifest["hot_count"]
    index._resident.update(
        index.hot.ids[index.hot.ids >= 0].tolist())
    for s, meta in enumerate(manifest["segments"]):
        host_ids = load(f"seg{s:04d}_ids")
        host_live = load(f"seg{s:04d}_live")
        codes = load(f"seg{s:04d}_codes")
        list_start = load(f"seg{s:04d}_list_start")
        if manifest["format"] >= 3:
            placement = load(f"seg{s:04d}_placement")
            n_shards = int(meta["n_shards"])
            shard_cap = int(meta["shard_cap"])
        else:
            # pre-scale-out snapshots are the single-shard layout: every
            # list on shard 0, the whole segment one shard block
            placement = np.zeros(list_start.shape[0], np.int32)
            n_shards = 1
            shard_cap = codes.shape[0]
        index._add_segment(SealedSegment(
            codes=jnp.asarray(codes),
            ids=jnp.asarray(host_ids),
            live=jnp.asarray(host_live),
            assign=jnp.asarray(load(f"seg{s:04d}_assign")),
            list_start=jnp.asarray(list_start),
            list_len=jnp.asarray(load(f"seg{s:04d}_list_len")),
            placement=jnp.asarray(placement),
            max_list=int(meta["max_list"]), n_shards=n_shards,
            shard_cap=shard_cap), host_ids=host_ids,
            host_live=host_live)
    return index
