"""Immutable point-in-time index views — the read side of the serving core.

A :class:`IndexView` captures everything :func:`repro.index.streaming.
search_impl` needs — the frozen quantizers, the tuple of sealed segments,
a device-resident copy of the hot buffer — as *immutable* state:

* sealed segments are already copy-on-write (``SealedSegment`` is a
  frozen dataclass; a tombstone builds a *new* segment object, and the
  index's segment list is only ever re-pointed, never mutated in place),
  so a view's segment tuple stays consistent no matter how many
  seals/compactions happen after capture;
* the hot buffer is the one mutable structure, so capture copies it to
  fresh device arrays (``jnp.array`` forces a copy) — the double-buffer:
  the writer keeps mutating its host-side numpy staging buffers while
  every published view holds its own frozen device copy.

Searching a view is therefore safe from any thread while the writer
mutates the underlying :class:`~repro.index.streaming.StreamingIndex`,
and is *bit-identical* to searching a quiesced index in the captured
state — same ``search_impl``, same kernels, same compiled shapes (the
acceptance test in ``tests/test_serving.py`` asserts exactly this on both
the jax and Pallas-interpret backends).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from ..index.streaming import StreamingIndex, search_impl

__all__ = ["IndexView"]


@dataclasses.dataclass(frozen=True)
class IndexView:
    """One consistent, immutable snapshot of a streaming index.

    ``version`` is the publish sequence number: the writer bumps it on
    every snapshot swap, and every :class:`~repro.serve_index.server.
    SearchResult` records the version it was computed against.
    """

    cfg: object                   # repro.index.IndexConfig (frozen)
    dim: int
    coarse: jnp.ndarray
    cb: object                    # repro.core.pq.PQCodebook (NamedTuple)
    segments: Tuple              # tuple of SealedSegment (frozen)
    hot: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
    two_level: Optional[object]
    version: int = 0

    @classmethod
    def capture(cls, index: StreamingIndex, version: int = 0) -> "IndexView":
        """Snapshot ``index`` (must not race with writes — the serving
        writer thread is the only caller while a server runs)."""
        hot = None
        if index.hot.count:
            # jnp.array copies: the view's device arrays must not alias
            # the writer's mutable numpy staging buffers
            hot = (jnp.array(index.hot.data), jnp.array(index.hot.ids),
                   jnp.array(index.hot.live))
        return cls(cfg=index.cfg, dim=index.dim, coarse=index.coarse,
                   cb=index.cb, segments=tuple(index.segments), hot=hot,
                   two_level=index.two_level, version=version)

    def n_live(self) -> int:
        """Live rows visible to this view (host-side sum)."""
        hot_live = int(jnp.sum(self.hot[2])) if self.hot is not None else 0
        return hot_live + sum(sg.n_live() for sg in self.segments)

    def search(self, Q: jnp.ndarray, *, n_probe: int, topk: int = 1,
               q_valid: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Top-``topk`` neighbors within this snapshot -> ``(dist, ids)``.

        Identical math to :meth:`repro.index.streaming.StreamingIndex.
        search` (it is literally the same ``search_impl``); ``q_valid``
        marks padding rows of a coalesced batch, exactly as in the
        sharded planner.
        """
        return search_impl(self.coarse, self.cb, self.segments, self.hot,
                           Q, icfg=self.cfg, n_probe=n_probe, topk=topk,
                           dim=self.dim, two_level=self.two_level,
                           q_valid=q_valid)
