"""Serving-core configuration: coalescing, batching, admission control.

One frozen dataclass holds every operational knob of the request
coalescer and the concurrent-ingest writer (documented operationally in
``docs/serving.md``).  The load-bearing property is that the knobs fix a
*finite family of compiled shapes*: queries are only ever launched at the
``q_buckets`` batch sizes with a fixed ``(n_probe, topk)``, so a warmed
server reuses a handful of compiled executables for arbitrary mixed
traffic instead of recompiling per request size.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Tuple

__all__ = ["ServeConfig", "SHED_POLICIES"]

# Admission-control policies for the bounded write queue (see
# ``IndexServer``):
#
#   "shed_inserts"  full queue sheds inserts (Backpressure raised to the
#                   producer) but admits deletes with a blocking put —
#                   deletes free space, so under pressure the index should
#                   prefer shrinking over growing.  The default.
#   "shed_all"      full queue sheds inserts AND deletes.
#   "block"         nothing is shed; producers block until the writer
#                   drains the queue (pure backpressure).
SHED_POLICIES = ("shed_inserts", "shed_all", "block")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Operational knobs of :class:`repro.serve_index.IndexServer`.

    ``n_probe`` / ``topk`` are fixed per server so every coalesced batch
    shares the same compiled search plan; run two servers over one index
    if two serving contracts are needed.

    >>> cfg = ServeConfig(n_probe=4, topk=3)
    >>> cfg.bucket_for(5)
    8
    >>> cfg.max_batch
    64
    """

    n_probe: int = 4
    topk: int = 1
    # Queries arriving within this window of the batch's first request are
    # coalesced into one padded launch (0.0 = launch as soon as the
    # coalescer thread wakes; still batches truly concurrent arrivals).
    coalesce_window_s: float = 0.002
    # Allowed padded batch sizes, strictly increasing.  A request batch of
    # n queries launches at the smallest bucket >= n; requests larger than
    # the last bucket are split into max-bucket chunks at submit time.
    q_buckets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)
    # Bounded write queue (admission control): max pending insert/delete/
    # maintenance operations before the shed policy engages.
    queue_bound: int = 256
    shed_policy: str = "shed_inserts"
    # Max write ops the writer drains per view publish: larger values
    # amortize snapshot swaps under ingest bursts, smaller values shrink
    # the window between an accepted write and its visibility to queries.
    apply_batch: int = 8

    def __post_init__(self):
        if self.n_probe < 1:
            raise ValueError(f"n_probe={self.n_probe} must be >= 1")
        if self.topk < 1:
            raise ValueError(f"topk={self.topk} must be >= 1")
        if self.coalesce_window_s < 0:
            raise ValueError(
                f"coalesce_window_s={self.coalesce_window_s} must be >= 0")
        if not self.q_buckets:
            raise ValueError("q_buckets must be non-empty")
        if any(b < 1 for b in self.q_buckets) or \
                list(self.q_buckets) != sorted(set(self.q_buckets)):
            raise ValueError(
                f"q_buckets={self.q_buckets} must be strictly increasing "
                "positive sizes")
        if self.queue_bound < 1:
            raise ValueError(
                f"queue_bound={self.queue_bound} must be >= 1")
        if self.shed_policy not in SHED_POLICIES:
            raise ValueError(
                f"shed_policy={self.shed_policy!r} must be one of "
                f"{SHED_POLICIES}")
        if self.apply_batch < 1:
            raise ValueError(
                f"apply_batch={self.apply_batch} must be >= 1")

    @property
    def max_batch(self) -> int:
        """Largest allowed coalesced batch (the last bucket)."""
        return self.q_buckets[-1]

    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= ``n`` (``n`` must not exceed ``max_batch``)."""
        if not 1 <= n <= self.max_batch:
            raise ValueError(
                f"batch of {n} queries outside bucket range "
                f"[1, {self.max_batch}]")
        return self.q_buckets[bisect.bisect_left(self.q_buckets, n)]
