"""zamba2-2.7b [hybrid] — Mamba2 backbone + weight-shared attention blocks.
[arXiv:2411.15242; hf]

Simplification recorded in DESIGN.md: the released model interleaves two
alternating shared transformer blocks with LoRA-adapted projections; we
model one weight-tied attention+MLP block applied every ``attn_every``
Mamba2 blocks (same compute/communication shape, fewer bespoke details).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,              # mamba2 blocks
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,               # shared attention block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,             # shared attn block before every 6 mamba blocks
)

REDUCED = dataclasses.replace(
    CONFIG, name="zamba2-2.7b-reduced", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=512, ssm_state=16, ssm_head_dim=16,
    attn_every=2)
