"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.
[arXiv:2308.11596; hf]

The speech frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings ``(B, n_frontend_tokens, d_model)``.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,              # decoder
    n_enc_layers=24,          # encoder
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    frontend="audio",
    n_frontend_tokens=1024,   # precomputed speech frames per sample
)

REDUCED = dataclasses.replace(
    CONFIG, name="seamless-m4t-large-v2-reduced", n_layers=2, n_enc_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    n_frontend_tokens=16)
