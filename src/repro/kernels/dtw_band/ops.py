"""Jitted public wrappers for the banded elastic-measure Pallas kernels."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import default_interpret, pad_to
from .kernel import MeasureArg, make_dtw_band_call, make_dtw_band_cdist_call

__all__ = ["dtw_band", "dtw_band_cdist"]


def _default_lane() -> int:
    """Lane multiple for the compressed register width: full 128-lane tiles
    on real TPU hardware, small tiles under interpret/CPU so tests stay
    cheap and the band compression is visible at short lengths."""
    return 128 if jax.default_backend() == "tpu" else 8


@functools.partial(jax.jit,
                   static_argnames=("window", "block", "interpret", "mode",
                                    "lane", "measure"))
def dtw_band(A: jnp.ndarray, B: jnp.ndarray, window: Optional[int] = None,
             block: int = 8, interpret: Optional[bool] = None,
             mode: str = "compressed",
             lane: Optional[int] = None,
             measure: MeasureArg = None) -> jnp.ndarray:
    """Banded elastic cost over zipped pairs: ``A (N, L)``, ``B (N, L)`` ->
    ``(N,)`` (squared banded DTW under the default measure).

    ``mode="compressed"`` (default) runs the band-compressed wavefront whose
    per-step cost scales with the Sakoe-Chiba band; ``mode="full"`` runs the
    legacy full-width sweep (kept as the DTW-only benchmark baseline).
    ``measure`` selects any registered elastic measure (static).
    """
    if interpret is None:
        interpret = default_interpret()
    if lane is None:
        lane = _default_lane()
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    n, L = A.shape
    Ap = pad_to(A, block, axis=0)
    Bp = pad_to(B, block, axis=0)
    call = make_dtw_band_call(Ap.shape[0], L, window, block, interpret,
                              mode=mode, lane=lane, measure=measure)
    out = call(Ap, Bp)
    return out[:n, 0]


@functools.partial(jax.jit,
                   static_argnames=("window", "block", "interpret", "lane",
                                    "measure"))
def dtw_band_cdist(A: jnp.ndarray, B: jnp.ndarray,
                   window: Optional[int] = None, block: int = 8,
                   interpret: Optional[bool] = None,
                   lane: Optional[int] = None,
                   measure: MeasureArg = None) -> jnp.ndarray:
    """All-pairs banded elastic cost: ``A (N, L)``, ``B (M, L)`` -> ``(N, M)``.

    Runs the band-compressed kernel on a 2-D grid (A row-blocks x B rows);
    the N*M cross-product is never materialized — B rows are broadcast
    inside the kernel tile.
    """
    if interpret is None:
        interpret = default_interpret()
    if lane is None:
        lane = _default_lane()
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    N, L = A.shape
    M = B.shape[0]
    Ap = pad_to(A, block, axis=0)
    call = make_dtw_band_cdist_call(Ap.shape[0], M, L, window, block,
                                    interpret, lane=lane, measure=measure)
    return call(Ap, B)[:N]
