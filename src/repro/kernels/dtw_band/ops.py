"""Jitted public wrappers for the banded-DTW Pallas kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import cdiv, default_interpret, pad_to
from .kernel import make_dtw_band_call

__all__ = ["dtw_band", "dtw_band_cdist"]


@functools.partial(jax.jit,
                   static_argnames=("window", "block", "interpret"))
def dtw_band(A: jnp.ndarray, B: jnp.ndarray, window: Optional[int] = None,
             block: int = 8, interpret: Optional[bool] = None) -> jnp.ndarray:
    """Squared banded DTW over zipped pairs: ``A (N, L)``, ``B (N, L)`` -> ``(N,)``."""
    if interpret is None:
        interpret = default_interpret()
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    n, L = A.shape
    Ap = pad_to(A, block, axis=0)
    Bp = pad_to(B, block, axis=0)
    call = make_dtw_band_call(Ap.shape[0], L, window, block, interpret)
    out = call(Ap, Bp)
    return out[:n, 0]


@functools.partial(jax.jit,
                   static_argnames=("window", "block", "interpret"))
def dtw_band_cdist(A: jnp.ndarray, B: jnp.ndarray,
                   window: Optional[int] = None, block: int = 8,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """All-pairs squared banded DTW: ``A (N, L)``, ``B (M, L)`` -> ``(N, M)``."""
    N, L = A.shape
    M = B.shape[0]
    AA = jnp.repeat(A, M, axis=0)
    BB = jnp.tile(B, (N, 1))
    return dtw_band(AA, BB, window, block, interpret).reshape(N, M)
