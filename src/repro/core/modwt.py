"""MODWT (Haar) pre-alignment — §3.5 of the paper.

Pipeline:
  1. Haar MODWT scale coefficients at level J (circular, undecimated): the
     level-j scaling output is a dyadic moving average — computed by the
     pyramid recursion ``v_j[i] = (v_{j-1}[i] + v_{j-1}[i - 2^{j-1}]) / 2``.
  2. Segment points = sign changes of ``x - v_J``.
  3. Each fixed split ``l_m = m * (D/M)`` is snapped to the *right-most*
     MODWT segment point inside the tail window ``[l_m - t, l_m]`` (if any).
  4. Each variable-length segment is linearly re-interpolated to the static
     length ``D/M + t`` so downstream envelopes/LUTs stay shape-static.

Everything is shape-static and vmappable: data-dependent boundaries become
gather indices, never shapes.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["modwt_scale", "segment_points", "snap_splits",
           "extract_segments", "prealign", "fixed_segments"]


@functools.partial(jax.jit, static_argnames=("level",))
def modwt_scale(x: jnp.ndarray, level: int) -> jnp.ndarray:
    """Level-``level`` Haar MODWT scaling coefficients (circular boundary).

    ``x (..., L)`` -> same shape.  Proportional to a local mean with dyadic
    support ``2**level``.
    """
    v = jnp.asarray(x, jnp.float32)
    for j in range(1, level + 1):
        v = 0.5 * (v + jnp.roll(v, 2 ** (j - 1), axis=-1))
    return v


def segment_points(x: jnp.ndarray, level: int) -> jnp.ndarray:
    """Boolean mask of MODWT segment points: positions ``i`` where
    ``sign(x - v_J)`` changes between ``i-1`` and ``i``."""
    v = modwt_scale(x, level)
    d = x - v
    s = jnp.sign(d)
    # Exact zeros (series == local mean) carry the previous nonzero sign, so
    # a plateau touch produces exactly one change point, not zero or two.
    s = jax.lax.associative_scan(
        lambda a, b: jnp.where(b == 0, a, b), s, axis=-1)
    prev = jnp.concatenate([s[..., :1], s[..., :-1]], axis=-1)
    change = (s * prev) < 0
    change = change.at[..., 0].set(False)
    return change


def snap_splits(points: jnp.ndarray, n_sub: int, tail: int) -> jnp.ndarray:
    """Snap the ``n_sub - 1`` interior fixed splits to MODWT points.

    ``points (..., L)`` boolean.  Returns boundaries ``(..., n_sub + 1)``
    int32 including 0 and L.  Each interior split ``l`` moves to the
    right-most true position in ``[l - tail, l]``; stays at ``l`` otherwise.
    """
    points = jnp.asarray(points)
    L = points.shape[-1]
    seg = L // n_sub
    fixed = jnp.arange(1, n_sub) * seg  # (n_sub-1,)

    offs = jnp.arange(tail + 1)  # candidate offsets, 0 = at l (right-most)

    def snap_one(l):
        cand = l - offs
        ok = points[..., :][..., jnp.clip(cand, 0, L - 1)] & (cand >= 1)
        # first True along offs = right-most point in the window
        any_ok = jnp.any(ok, axis=-1)
        first = jnp.argmax(ok, axis=-1)
        return jnp.where(any_ok, l - first, l)

    interior = jax.vmap(snap_one, in_axes=0, out_axes=-1)(fixed)
    batch_shape = points.shape[:-1]
    zero = jnp.zeros(batch_shape + (1,), jnp.int32)
    end = jnp.full(batch_shape + (1,), L, jnp.int32)
    return jnp.concatenate([zero, interior.astype(jnp.int32), end], axis=-1)


def _interp_segment(x: jnp.ndarray, start: jnp.ndarray, stop: jnp.ndarray,
                    out_len: int) -> jnp.ndarray:
    """Linearly resample ``x[start:stop]`` to ``out_len`` points (gathers)."""
    L = x.shape[-1]
    n = stop - start  # actual length (traced)
    pos = start + jnp.linspace(0.0, 1.0, out_len) * (n - 1)
    lo = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, L - 1)
    hi = jnp.clip(lo + 1, 0, L - 1)
    frac = pos - lo
    return x[lo] * (1.0 - frac) + x[hi] * frac


def extract_segments(x: jnp.ndarray, bounds: jnp.ndarray,
                     out_len: int) -> jnp.ndarray:
    """``x (L,)``, ``bounds (M+1,)`` -> ``(M, out_len)`` resampled segments."""
    x = jnp.asarray(x, jnp.float32)
    bounds = jnp.asarray(bounds)
    starts = bounds[:-1]
    stops = bounds[1:]
    return jax.vmap(lambda s, e: _interp_segment(x, s, e, out_len))(starts, stops)


@functools.partial(jax.jit, static_argnames=("n_sub", "level", "tail"))
def prealign(X: jnp.ndarray, n_sub: int, level: int, tail: int) -> jnp.ndarray:
    """Full pre-alignment: ``X (N, D)`` -> ``(N, n_sub, D//n_sub + tail)``.

    MODWT-guided segmentation with tail snapping, then re-interpolation of
    every segment to the static length ``D//n_sub + tail``.
    """
    X = jnp.asarray(X, jnp.float32)
    out_len = X.shape[-1] // n_sub + tail

    def one(x):
        pts = segment_points(x, level)
        bounds = snap_splits(pts, n_sub, tail)
        return extract_segments(x, bounds, out_len)

    return jax.vmap(one)(X)


@functools.partial(jax.jit, static_argnames=("n_sub",))
def fixed_segments(X: jnp.ndarray, n_sub: int) -> jnp.ndarray:
    """Baseline segmentation without pre-alignment: equal-length chop.

    ``X (N, D)`` -> ``(N, n_sub, D//n_sub)`` (D must be divisible by n_sub;
    callers pad/truncate beforehand).
    """
    N, D = X.shape
    seg = D // n_sub
    return X[:, : n_sub * seg].reshape(N, n_sub, seg).astype(jnp.float32)
