"""minitron-8b [dense] — width-pruned nemotron. [arXiv:2407.14679; hf]"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    head_dim=128,
)

REDUCED = dataclasses.replace(
    CONFIG, name="minitron-8b-reduced", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16)
