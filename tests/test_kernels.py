"""Pallas kernels (interpret mode) vs pure-jnp oracles — shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dtw_band.ops import dtw_band, dtw_band_cdist
from repro.kernels.dtw_band.ref import dtw_band_ref, dtw_band_cdist_ref
from repro.kernels.pq_adc.ops import adc_lookup, adc_sym_cdist
from repro.kernels.pq_adc.ref import adc_lookup_ref, adc_sym_cdist_ref
from repro.kernels.pq_attn.ops import (build_qlut, encode_keys,
                                       pq_attn_decode)
from repro.kernels.pq_attn.ref import pq_attn_decode_ref, reconstruct_keys
from repro.kernels.prealign_encode.ops import prealign_encode
from repro.kernels.prealign_encode.ref import prealign_encode_ref


# ---------------------------------------------------------------------------
# dtw_band
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,L", [(1, 8), (5, 16), (8, 32), (13, 64), (32, 24)])
@pytest.mark.parametrize("window", [None, 2, 5])
def test_dtw_band_matches_ref(n, L, window):
    rng = np.random.default_rng(n * 131 + L)
    A = rng.standard_normal((n, L)).astype(np.float32)
    B = rng.standard_normal((n, L)).astype(np.float32)
    got = np.asarray(dtw_band(A, B, window, interpret=True))
    want = np.asarray(dtw_band_ref(A, B, window))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.float16])
def test_dtw_band_dtypes(dtype):
    rng = np.random.default_rng(0)
    A = rng.standard_normal((4, 16)).astype(dtype)
    B = rng.standard_normal((4, 16)).astype(dtype)
    got = np.asarray(dtw_band(A, B, 3, interpret=True))
    want = np.asarray(dtw_band_ref(A.astype(np.float32),
                                   B.astype(np.float32), 3))
    rtol = 1e-5 if dtype != np.float16 else 2e-2
    np.testing.assert_allclose(got, want, rtol=rtol, atol=1e-2)


def test_dtw_band_cdist_matches_ref():
    rng = np.random.default_rng(3)
    A = rng.standard_normal((6, 20)).astype(np.float32)
    B = rng.standard_normal((9, 20)).astype(np.float32)
    got = np.asarray(dtw_band_cdist(A, B, 4, interpret=True))
    want = np.asarray(dtw_band_cdist_ref(A, B, 4))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dtw_band_odd_batch_padding():
    """Batch not divisible by block must round-trip through padding."""
    rng = np.random.default_rng(4)
    A = rng.standard_normal((7, 12)).astype(np.float32)
    B = rng.standard_normal((7, 12)).astype(np.float32)
    got = np.asarray(dtw_band(A, B, None, block=8, interpret=True))
    want = np.asarray(dtw_band_ref(A, B, None))
    np.testing.assert_allclose(got, want, rtol=1e-5)


# ---------------------------------------------------------------------------
# dtw_band: band-compressed vs full-width sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,L", [(1, 8), (5, 16), (7, 32), (13, 64), (3, 2)])
@pytest.mark.parametrize("window", [None, 1, 3, 100])  # 100 >= every L
def test_dtw_band_compressed_matches_ref(n, L, window):
    rng = np.random.default_rng(n * 311 + L)
    A = rng.standard_normal((n, L)).astype(np.float32)
    B = rng.standard_normal((n, L)).astype(np.float32)
    got = np.asarray(dtw_band(A, B, window, interpret=True,
                              mode="compressed"))
    want = np.asarray(dtw_band_ref(A, B, window))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dtw_band_modes_agree():
    """Full-width and band-compressed sweeps are the same DP."""
    rng = np.random.default_rng(8)
    A = rng.standard_normal((6, 40)).astype(np.float32)
    B = rng.standard_normal((6, 40)).astype(np.float32)
    full = np.asarray(dtw_band(A, B, 4, interpret=True, mode="full"))
    comp = np.asarray(dtw_band(A, B, 4, interpret=True, mode="compressed"))
    np.testing.assert_allclose(comp, full, rtol=1e-6, atol=1e-6)


def test_dtw_band_cdist_no_materialize_grid():
    """2-D grid cdist (B broadcast per tile) vs reference, odd shapes."""
    rng = np.random.default_rng(12)
    A = rng.standard_normal((11, 24)).astype(np.float32)
    B = rng.standard_normal((5, 24)).astype(np.float32)
    for window in (None, 2, 50):
        got = np.asarray(dtw_band_cdist(A, B, window, block=4,
                                        interpret=True))
        want = np.asarray(dtw_band_cdist_ref(A, B, window))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# pq_adc
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("na,nb,M,K", [(4, 4, 2, 8), (17, 9, 4, 16),
                                       (64, 64, 8, 256), (3, 130, 5, 32)])
def test_adc_sym_matches_ref(na, nb, M, K):
    rng = np.random.default_rng(na * 7 + nb)
    lut = np.abs(rng.standard_normal((M, K, K))).astype(np.float32)
    lut = lut + lut.transpose(0, 2, 1)
    a = rng.integers(0, K, (na, M)).astype(np.int32)
    b = rng.integers(0, K, (nb, M)).astype(np.int32)
    got = np.asarray(adc_sym_cdist(a, b, lut, block_a=8, block_b=8,
                                   interpret=True))
    want = np.asarray(adc_sym_cdist_ref(a, b, lut))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,M,K", [(5, 3, 8), (100, 7, 256), (257, 4, 64)])
def test_adc_lookup_matches_ref(n, M, K):
    rng = np.random.default_rng(n)
    qlut = np.abs(rng.standard_normal((M, K))).astype(np.float32)
    codes = rng.integers(0, K, (n, M)).astype(np.int32)
    got = np.asarray(adc_lookup(codes, qlut, block=32, interpret=True))
    want = np.asarray(adc_lookup_ref(codes, qlut))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_adc_sym_consistent_with_core_pq():
    """Kernel output must equal the core library's symmetric distance."""
    from repro.core.pq import cdist_sym
    rng = np.random.default_rng(11)
    M, K = 4, 16
    lut = np.abs(rng.standard_normal((M, K, K))).astype(np.float32)
    for m in range(M):
        np.fill_diagonal(lut[m], 0.0)
    codes = rng.integers(0, K, (12, M)).astype(np.int32)
    got = np.asarray(adc_sym_cdist(codes, codes, lut, interpret=True))
    want = np.asarray(cdist_sym(jnp.asarray(codes), jnp.asarray(codes),
                                jnp.asarray(lut)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# pq_attn
# ---------------------------------------------------------------------------

def _attn_setup(S, G, H, M, K, Ds, seed=0):
    rng = np.random.default_rng(seed)
    D = M * Ds
    q = rng.standard_normal((H, D)).astype(np.float32)
    k_books = rng.standard_normal((G, M, K, Ds)).astype(np.float32)
    k_codes = rng.integers(0, K, (S, G, M)).astype(np.int32)
    v = rng.standard_normal((S, G, D)).astype(np.float32)
    return q, k_codes, k_books, v


@pytest.mark.parametrize("S,G,H,M,K,Ds",
                         [(16, 1, 1, 2, 4, 4),
                          (64, 2, 4, 4, 16, 8),
                          (100, 2, 8, 2, 32, 16),
                          (256, 4, 8, 8, 64, 8)])
def test_pq_attn_matches_ref(S, G, H, M, K, Ds):
    q, k_codes, k_books, v = _attn_setup(S, G, H, M, K, Ds, seed=S)
    got = np.asarray(pq_attn_decode(q, k_codes, k_books, v, block_s=32,
                                    interpret=True))
    want = np.asarray(pq_attn_decode_ref(q, k_codes, k_books, v))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_pq_attn_valid_len_masking():
    q, k_codes, k_books, v = _attn_setup(64, 2, 4, 4, 16, 8, seed=1)
    got = np.asarray(pq_attn_decode(q, k_codes, k_books, v, valid_len=40,
                                    block_s=16, interpret=True))
    want = np.asarray(pq_attn_decode_ref(q, k_codes, k_books, v,
                                         valid_len=40))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # masked tail must actually change the answer vs full length
    full = np.asarray(pq_attn_decode_ref(q, k_codes, k_books, v))
    assert not np.allclose(want, full, atol=1e-4)


def test_pq_attn_exact_when_codes_reconstruct_exactly():
    """If every key IS a codeword, PQ attention == exact attention."""
    rng = np.random.default_rng(5)
    S, G, H, M, K, Ds = 32, 1, 2, 2, 8, 8
    D = M * Ds
    k_books = rng.standard_normal((G, M, K, Ds)).astype(np.float32)
    k_codes = rng.integers(0, K, (S, G, M)).astype(np.int32)
    keys = np.asarray(reconstruct_keys(jnp.asarray(k_codes),
                                       jnp.asarray(k_books)))  # (S, G, D)
    q = rng.standard_normal((H, D)).astype(np.float32)
    v = rng.standard_normal((S, G, D)).astype(np.float32)
    # exact attention with the reconstructed keys
    scores = np.einsum("hd,sd->hs", q, keys[:, 0]) / np.sqrt(D)
    p = np.exp(scores - scores.max(1, keepdims=True))
    p /= p.sum(1, keepdims=True)
    want = p @ v[:, 0]
    got = np.asarray(pq_attn_decode(q, k_codes, k_books, v, block_s=8,
                                    interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_encode_keys_roundtrip():
    """encode_keys must pick the true nearest codeword."""
    rng = np.random.default_rng(7)
    G, M, K, Ds, S = 2, 3, 16, 4, 20
    k_books = rng.standard_normal((G, M, K, Ds)).astype(np.float32)
    codes = rng.integers(0, K, (S, G, M)).astype(np.int32)
    keys = np.asarray(reconstruct_keys(jnp.asarray(codes),
                                       jnp.asarray(k_books)))
    got = np.asarray(encode_keys(jnp.asarray(keys).reshape(S, G, M * Ds),
                                 jnp.asarray(k_books)))
    assert (got == codes).all()


# ---------------------------------------------------------------------------
# prealign_encode (fused MODWT prealign + DTW-1NN encode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,L,M,K,level,tail", [(7, 32, 4, 5, 2, 2),
                                                (12, 64, 4, 8, 3, 3),
                                                (3, 48, 3, 6, 1, 0),
                                                (5, 40, 2, 4, 3, 5),
                                                (1, 24, 4, 3, 2, 1)])
@pytest.mark.parametrize("window", [None, 2])
def test_prealign_encode_fused_matches_ref(n, L, M, K, level, tail, window):
    """Fused kernel codes == modwt.prealign + exact DTW-1NN reference."""
    rng = np.random.default_rng(n * 101 + L + (0 if window is None else window))
    S = L // M + tail
    X = rng.standard_normal((n, L)).astype(np.float32)
    C = rng.standard_normal((M, K, S)).astype(np.float32)
    got = np.asarray(prealign_encode(X, C, level, tail, window, block=4,
                                     interpret=True))
    want = np.asarray(prealign_encode_ref(X, C, level, tail, window))
    np.testing.assert_array_equal(got, want)


def test_prealign_encode_matches_two_step_library_path():
    """Fused kernel == modwt.prealign + pq.encode (exact) on trained
    centroids, and the geometry check rejects mismatched codebooks."""
    import jax as _jax
    from repro.core import pq as pqm
    from repro.core.modwt import prealign as modwt_prealign
    from repro.core.pq import PQConfig
    rng = np.random.default_rng(0)
    X = rng.standard_normal((10, 48)).astype(np.float32)
    cfg = PQConfig(n_sub=4, codebook_size=4, use_prealign=True,
                   wavelet_level=2, tail_frac=0.25, kmeans_iters=2,
                   dba_iters=1, exact_encode=True, fused_encode=False)
    cb = pqm.fit(_jax.random.PRNGKey(0), X, cfg)
    two_step = np.asarray(pqm.encode(X, cb, cfg))       # prealign + encode
    tail, w = cfg.tail(48), cfg.window(48)
    fused = np.asarray(prealign_encode(X, cb.centroids, cfg.wavelet_level,
                                       tail, w, interpret=True))
    np.testing.assert_array_equal(fused, two_step)
    # sanity: the segments the kernel never materializes match modwt
    segs = np.asarray(modwt_prealign(X, cfg.n_sub, cfg.wavelet_level, tail))
    assert segs.shape == (10, 4, cb.subseq_len)
    with pytest.raises(ValueError, match="geometry"):
        prealign_encode(X, cb.centroids, cfg.wavelet_level, tail + 1, w,
                        interpret=True)


# ---------------------------------------------------------------------------
# lb_cascade (fused LB filter + conditional banded-DTW refine)
# ---------------------------------------------------------------------------

from repro.core.lb import keogh_envelope
from repro.kernels.lb_cascade.ops import lb_refine as lb_refine_kernel
from repro.kernels.lb_cascade.ref import cascade_bound_ref, lb_refine_ref


def _lb_setup(n, L, window, seed):
    rng = np.random.default_rng(seed)
    A = np.cumsum(rng.standard_normal((n, L)), 1).astype(np.float32)
    B = np.cumsum(rng.standard_normal((n, L)), 1).astype(np.float32)
    w_env = L - 1 if window is None else min(window, L - 1)
    up, lo = keogh_envelope(A, w_env)
    return A, B, np.asarray(up), np.asarray(lo)


@pytest.mark.parametrize("n,L", [(1, 8), (7, 16), (13, 32), (32, 24)])
@pytest.mark.parametrize("window", [None, 2, 5])
def test_lb_cascade_matches_ref(n, L, window):
    """Mixed thresholds: some tiles refine, some are fully pruned."""
    A, B, up, lo = _lb_setup(n, L, window, n * 37 + L)
    lb = np.asarray(cascade_bound_ref(A, B, up, lo))
    thresh = np.full(n, np.median(lb) if n > 1 else lb[0] + 1.0, np.float32)
    got_d, got_f = lb_refine_kernel(A, B, up, lo, thresh, window, block=4,
                                    interpret=True)
    want_d, want_f = lb_refine_ref(A, B, up, lo, thresh, window)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-5, atol=1e-5)


def test_lb_cascade_threshold_extremes():
    """+inf threshold refines everything (== exact banded DTW); -inf
    refines nothing (returns the cascade bound)."""
    A, B, up, lo = _lb_setup(9, 20, 3, 5)
    inf = np.full(9, np.inf, np.float32)
    d, f = lb_refine_kernel(A, B, up, lo, inf, 3, interpret=True)
    np.testing.assert_allclose(np.asarray(d),
                               np.asarray(dtw_band_ref(A, B, 3)),
                               rtol=1e-5, atol=1e-5)
    assert np.asarray(f).all()
    d, f = lb_refine_kernel(A, B, up, lo, -inf, 3, interpret=True)
    np.testing.assert_allclose(np.asarray(d),
                               np.asarray(cascade_bound_ref(A, B, up, lo)),
                               rtol=1e-5, atol=1e-5)
    assert not np.asarray(f).any()


def test_lb_cascade_odd_batch_padding():
    """Pair count not divisible by block round-trips through padding (the
    padded rows run with a -inf threshold and are sliced off)."""
    A, B, up, lo = _lb_setup(7, 12, 2, 11)
    thresh = np.full(7, np.inf, np.float32)
    d, f = lb_refine_kernel(A, B, up, lo, thresh, 2, block=8, interpret=True)
    assert d.shape == (7,) and f.shape == (7,)
    np.testing.assert_allclose(np.asarray(d),
                               np.asarray(dtw_band_ref(A, B, 2)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# dispatch layer
# ---------------------------------------------------------------------------

from repro.core import dispatch


@pytest.fixture
def fresh_dispatch():
    """Clear jit caches + counters so routing is observable at trace time."""
    jax.clear_caches()
    dispatch.reset_stats()
    yield dispatch
    dispatch.set_backend(None)


def _route_count(op, route="pallas_interpret"):
    return dispatch.stats.get((op, route), 0)


def test_dispatch_backend_selection(fresh_dispatch):
    with dispatch.use_backend("jax"):
        assert dispatch.get_backend() == "jax"
        with dispatch.use_backend("pallas_interpret"):
            assert dispatch.get_backend() == "pallas_interpret"
        assert dispatch.get_backend() == "jax"
    with pytest.raises(ValueError):
        dispatch.set_backend("cuda")


@pytest.mark.parametrize("n,L,window", [(3, 8, None), (7, 16, 2),
                                        (8, 24, 30), (13, 32, 3)])
def test_dispatch_pairwise_backends_agree(fresh_dispatch, n, L, window):
    rng = np.random.default_rng(n * 17 + L)
    A = rng.standard_normal((n, L)).astype(np.float32)
    B = rng.standard_normal((n, L)).astype(np.float32)
    with dispatch.use_backend("jax"):
        want = np.asarray(dispatch.elastic_pairwise(A, B, window))
    with dispatch.use_backend("pallas_interpret"):
        got = np.asarray(dispatch.elastic_pairwise(A, B, window))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("n,m,L,window", [(4, 6, 12, None), (9, 5, 16, 2),
                                          (6, 6, 20, 40)])
def test_dispatch_cdist_backends_agree(fresh_dispatch, n, m, L, window):
    rng = np.random.default_rng(n * 13 + m)
    A = rng.standard_normal((n, L)).astype(np.float32)
    B = rng.standard_normal((m, L)).astype(np.float32)
    with dispatch.use_backend("jax"):
        want = np.asarray(dispatch.elastic_cdist(A, B, window))
    with dispatch.use_backend("pallas_interpret"):
        got = np.asarray(dispatch.elastic_cdist(A, B, window))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_dispatch_adc_backends_agree(fresh_dispatch):
    rng = np.random.default_rng(2)
    M, K = 3, 16
    lut = np.abs(rng.standard_normal((M, K, K))).astype(np.float32)
    codes_a = rng.integers(0, K, (10, M)).astype(np.int32)
    codes_b = rng.integers(0, K, (7, M)).astype(np.int32)
    qlut = np.abs(rng.standard_normal((M, K))).astype(np.float32)
    with dispatch.use_backend("jax"):
        want_c = np.asarray(dispatch.adc_cdist(codes_a, codes_b, lut))
        want_l = np.asarray(dispatch.adc_lookup(codes_a, qlut))
    with dispatch.use_backend("pallas_interpret"):
        got_c = np.asarray(dispatch.adc_cdist(codes_a, codes_b, lut))
        got_l = np.asarray(dispatch.adc_lookup(codes_a, qlut))
    np.testing.assert_allclose(got_c, want_c, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(got_l, want_l, rtol=1e-5, atol=1e-4)


def _toy_corpus(n=20, d=32, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def _toy_cfg():
    from repro.core.pq import PQConfig
    return PQConfig(n_sub=2, codebook_size=4, kmeans_iters=2, dba_iters=1)


def test_encode_and_fit_route_through_dispatch(fresh_dispatch):
    """PQ training + encoding must execute on the Pallas route, and agree
    with the pure-JAX route to <= 1e-4."""
    from repro.core.pq import encode_with_stats, fit
    X = _toy_corpus()
    cfg = _toy_cfg()
    key = jax.random.PRNGKey(0)
    with dispatch.use_backend("jax"):
        cb = fit(key, X, cfg)
        codes_j, _ = encode_with_stats(X, cb, cfg)
    jax.clear_caches()
    dispatch.reset_stats()
    with dispatch.use_backend("pallas_interpret"):
        cb_p = fit(key, X, cfg)
        codes_p, _ = encode_with_stats(X, cb_p, cfg)
        assert _route_count("elastic_cdist") > 0       # k-means + LUT build
        assert _route_count("elastic_pairwise") > 0    # encode refinement
    np.testing.assert_allclose(np.asarray(cb_p.lut), np.asarray(cb.lut),
                               rtol=1e-5, atol=1e-4)
    assert (np.asarray(codes_p) == np.asarray(codes_j)).all()


def test_query_and_sym_route_through_dispatch(fresh_dispatch):
    from repro.core.pq import cdist_asym, cdist_sym, encode, fit
    X = _toy_corpus(seed=3)
    cfg = _toy_cfg()
    with dispatch.use_backend("jax"):
        cb = fit(jax.random.PRNGKey(1), X, cfg)
        codes = encode(X, cb, cfg)
        want_sym = np.asarray(cdist_sym(codes, codes, cb.lut))
        want_asym = np.asarray(cdist_asym(X[:3], codes, cb, cfg))
    jax.clear_caches()
    dispatch.reset_stats()
    with dispatch.use_backend("pallas_interpret"):
        got_sym = np.asarray(cdist_sym(codes, codes, cb.lut))
        got_asym = np.asarray(cdist_asym(X[:3], codes, cb, cfg))
        assert _route_count("adc_cdist") > 0           # MXU ADC kernel
        assert _route_count("elastic_cdist") > 0       # query LUT build
    np.testing.assert_allclose(got_sym, want_sym, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(got_asym, want_asym, rtol=1e-5, atol=1e-4)


def test_ivf_search_routes_through_dispatch(fresh_dispatch):
    from repro.core import ivf
    X = _toy_corpus(n=24, seed=5)
    cfg = _toy_cfg()
    with dispatch.use_backend("jax"):
        index = ivf.build_index(jax.random.PRNGKey(2), X, cfg, n_lists=3)
        want_d, want_i = ivf.search_batch(index, X[:4], cfg, n_probe=2,
                                          topk=3)
    jax.clear_caches()
    dispatch.reset_stats()
    with dispatch.use_backend("pallas_interpret"):
        got_d, got_i = ivf.search_batch(index, X[:4], cfg, n_probe=2,
                                        topk=3)
        assert _route_count("elastic_cdist") > 0       # coarse + query LUTs
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-5, atol=1e-4)
    assert (np.asarray(got_i) == np.asarray(want_i)).all()


def test_knn_exact_routes_through_dispatch(fresh_dispatch):
    from repro.core.knn import nn_dtw_exact
    X = _toy_corpus(n=16, seed=7)
    Q = _toy_corpus(n=5, seed=8)
    labels = jnp.arange(16) % 3
    with dispatch.use_backend("jax"):
        want = np.asarray(nn_dtw_exact(X, labels, Q, window=3))
    jax.clear_caches()
    dispatch.reset_stats()
    with dispatch.use_backend("pallas_interpret"):
        got = np.asarray(nn_dtw_exact(X, labels, Q, window=3))
        assert _route_count("elastic_cdist") > 0
    assert (got == want).all()


def test_prealign_encode_backends_agree(fresh_dispatch):
    """dispatch.prealign_encode: identical codes on jax / pallas_interpret,
    and the routing counters record both routes."""
    rng = np.random.default_rng(21)
    L, M, K, level, tail, window = 40, 4, 6, 2, 2, 3
    X = rng.standard_normal((9, L)).astype(np.float32)
    C = rng.standard_normal((M, K, L // M + tail)).astype(np.float32)
    with dispatch.use_backend("jax"):
        want = np.asarray(dispatch.prealign_encode(
            X, C, level=level, tail=tail, window=window))
    with dispatch.use_backend("pallas_interpret"):
        got = np.asarray(dispatch.prealign_encode(
            X, C, level=level, tail=tail, window=window))
    np.testing.assert_array_equal(got, want)
    assert _route_count("prealign_encode", "jax") == 1
    assert _route_count("prealign_encode") == 1


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_fused_encode_routes_through_dispatch(fresh_dispatch, backend):
    """pq.encode with an exact prealigned config must take the fused
    prealign_encode dispatch route and agree with the two-step path."""
    import dataclasses
    from repro.core.pq import PQConfig, encode, fit, uses_fused_prealign
    X = _toy_corpus(n=14, d=32, seed=9)
    cfg = dataclasses.replace(_toy_cfg(), use_prealign=True,
                              wavelet_level=2, tail_frac=0.25,
                              exact_encode=True)
    assert uses_fused_prealign(cfg)
    with dispatch.use_backend(backend):
        jax.clear_caches()
        cb = fit(jax.random.PRNGKey(3), X, cfg)
        dispatch.reset_stats()
        fused = np.asarray(encode(X, cb, cfg))
        assert _route_count("prealign_encode", backend) == 1
        two_step = np.asarray(encode(
            X, cb, dataclasses.replace(cfg, fused_encode=False)))
    np.testing.assert_array_equal(fused, two_step)


def test_dispatch_lb_refine_backends_agree(fresh_dispatch):
    A, B, up, lo = _lb_setup(11, 16, 3, 2)
    lb = np.asarray(cascade_bound_ref(A, B, up, lo))
    thresh = np.full(11, float(np.median(lb)), np.float32)
    with dispatch.use_backend("jax"):
        want_d, want_f = dispatch.lb_refine(A, B, up, lo, thresh, 3)
    with dispatch.use_backend("pallas_interpret"):
        got_d, got_f = dispatch.lb_refine(A, B, up, lo, thresh, 3)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-5, atol=1e-4)
    assert _route_count("lb_refine", "jax") == 1
    assert _route_count("lb_refine") == 1


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_filtered_topk_routes_through_dispatch(fresh_dispatch, backend):
    """The batched filter-and-refine search must run its refines through
    dispatch.lb_refine and return the exact banded-DTW top-k."""
    from repro.core.lb_search import filtered_topk
    rng = np.random.default_rng(3)
    X = np.cumsum(rng.standard_normal((40, 24)), 1).astype(np.float32)
    Q = np.cumsum(rng.standard_normal((5, 24)), 1).astype(np.float32)
    with dispatch.use_backend(backend):
        jax.clear_caches()
        dispatch.reset_stats()
        d, idx, n_ref = filtered_topk(Q, X, 3, 2)
        assert _route_count("lb_refine", backend) > 0
        dense = np.asarray(dispatch.elastic_cdist(Q, X, 3))
    want = np.sort(dense, axis=1)[:, :2]
    np.testing.assert_allclose(np.asarray(d), want, rtol=1e-5, atol=1e-5)
    assert 0 < int(n_ref) <= Q.shape[0] * X.shape[0]


def test_dispatch_totals_survive_reset(fresh_dispatch):
    """`totals` is the process-lifetime ledger the CI routing gate reads:
    reset_stats() must not clear it."""
    rng = np.random.default_rng(1)
    A = rng.standard_normal((4, 8)).astype(np.float32)
    with dispatch.use_backend("pallas_interpret"):
        dispatch.elastic_pairwise(A, A, 2)
    before = dispatch.totals.get(("elastic_pairwise", "pallas_interpret"), 0)
    assert before > 0
    dispatch.reset_stats()
    assert not dispatch.stats
    assert dispatch.totals.get(("elastic_pairwise", "pallas_interpret"),
                               0) == before


def test_build_qlut_algebra():
    """qlut gathers must equal dot products with reconstructed keys."""
    rng = np.random.default_rng(9)
    G, R, M, K, Ds = 2, 3, 4, 8, 4
    H, D = G * R, M * Ds
    q = rng.standard_normal((H, D)).astype(np.float32)
    books = rng.standard_normal((G, M, K, Ds)).astype(np.float32)
    qlut = np.asarray(build_qlut(jnp.asarray(q), jnp.asarray(books)))
    codes = rng.integers(0, K, (5, G, M)).astype(np.int32)
    keys = np.asarray(reconstruct_keys(jnp.asarray(codes),
                                       jnp.asarray(books)))
    for s in range(5):
        for h in range(H):
            g = h // R
            want = float(q[h] @ keys[s, g])
            got = sum(qlut[h, m, codes[s, g, m]] for m in range(M))
            assert got == pytest.approx(want, rel=1e-4, abs=1e-4)
