"""Quickstart: the PQDTW public API in ~50 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains a product quantizer under DTW on a small synthetic collection,
encodes it, and compares symmetric / asymmetric / exact distances.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dtw import dtw_cdist
from repro.core.pq import (PQConfig, cdist_asym, cdist_sym, encode, fit,
                           memory_cost)
from repro.data.timeseries import cbf


def main():
    # --- data: 60 Cylinder-Bell-Funnel series, length 128 ------------------
    X, y = cbf(n_per_class=20, length=128, seed=0)
    X = jnp.asarray(X)
    N, D = X.shape
    print(f"dataset: {N} series of length {D}")

    # --- train the quantizer (Algorithm 1) ---------------------------------
    cfg = PQConfig(n_sub=4,            # M subspaces
                   codebook_size=32,   # K centroids per subspace
                   window_frac=0.1,    # Sakoe-Chiba band inside subspaces
                   use_prealign=True)  # MODWT pre-alignment (paper §3.5)
    cb = fit(jax.random.PRNGKey(0), X, cfg)
    print(f"codebook: M={cb.n_sub} K={cb.codebook_size} "
          f"subseq_len={cb.subseq_len}")

    # --- encode (Algorithm 2: LB-filtered DTW-1NN per subspace) ------------
    codes = encode(X, cb, cfg)
    print(f"codes: {codes.shape} {codes.dtype} "
          f"(was {N}x{D} float32)")

    mem = memory_cost(cfg, D, N)
    print(f"compression: {mem['compression']:.1f}x "
          f"(+{mem['aux_bytes'] / 1e6:.2f}MB one-time auxiliaries)")

    # --- distances (§3.3) ---------------------------------------------------
    d_sym = cdist_sym(codes, codes, cb.lut)          # M gathers per pair
    d_asym = cdist_asym(X, codes, cb, cfg)           # fresh LUT per query
    d_true = jnp.sqrt(dtw_cdist(X, X, cfg.window(D)))

    off = ~jnp.eye(N, dtype=bool)
    for name, d in (("symmetric", d_sym), ("asymmetric", d_asym)):
        err = jnp.abs(d - d_true)[off]
        corr = np.corrcoef(np.asarray(d[off]), np.asarray(d_true[off]))[0, 1]
        print(f"{name:10s} vs exact DTW: mean |err| = {float(err.mean()):.3f},"
              f" corr = {corr:.3f}")

    # --- 1-NN sanity ---------------------------------------------------------
    nn = np.asarray(jnp.argsort(d_sym, axis=1)[:, 1])   # skip self-match
    acc = float((y[nn] == y).mean())
    print(f"leave-one-out 1NN accuracy with symmetric PQDTW: {acc:.2%}")


if __name__ == "__main__":
    main()
