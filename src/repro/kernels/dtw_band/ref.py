"""Pure-jnp oracle for the banded-DTW kernel (independent of the Pallas path
— delegates to the core wavefront implementation, which is itself validated
against an O(L^2) numpy DP oracle in tests/test_dtw.py)."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ...core.dtw import dtw_batch, dtw_cdist

__all__ = ["dtw_band_ref", "dtw_band_cdist_ref"]


def dtw_band_ref(A: jnp.ndarray, B: jnp.ndarray,
                 window: Optional[int] = None) -> jnp.ndarray:
    return dtw_batch(A, B, window)


def dtw_band_cdist_ref(A: jnp.ndarray, B: jnp.ndarray,
                       window: Optional[int] = None) -> jnp.ndarray:
    return dtw_cdist(A, B, window)
