"""Jitted public wrapper for the fused prealign+encode Pallas kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.dispatch import effective_window
from ...core.measures import MeasureArg
from ..common import default_interpret, pad_to
from ..dtw_band.kernel import band_width
from .kernel import make_prealign_encode_call
from .ref import check_geometry

__all__ = ["prealign_encode"]


def _default_lane() -> int:
    """Compressed-width lane multiple: full 128-lane tiles on real TPU
    hardware, small tiles under interpret/CPU so tests stay cheap."""
    return 128 if jax.default_backend() == "tpu" else 8


@functools.partial(jax.jit, static_argnames=("level", "tail", "window",
                                             "block", "interpret", "lane",
                                             "measure"))
def prealign_encode(X: jnp.ndarray, centroids: jnp.ndarray, level: int,
                    tail: int, window: Optional[int] = None,
                    block: Optional[int] = None,
                    interpret: Optional[bool] = None,
                    lane: Optional[int] = None,
                    measure: MeasureArg = None) -> jnp.ndarray:
    """Fused MODWT prealign + DTW-1NN encode: ``X (N, D)`` -> ``(N, M)``.

    ``centroids (M, K, S)`` with ``S = D // M + tail``; ``window`` is the
    Sakoe-Chiba band over the *subsequence* length (``None`` = unbanded).
    Codes match ``modwt.prealign`` + exact ``pq.encode``.
    ``block=None`` consults the :mod:`repro.kernels.tune` table.
    """
    if interpret is None:
        interpret = default_interpret()
    if lane is None:
        lane = _default_lane()
    X = jnp.asarray(X, jnp.float32)
    centroids = jnp.asarray(centroids, jnp.float32)
    N, D = X.shape
    M, K, S = centroids.shape
    check_geometry(D, centroids, tail)
    w = effective_window(S, window)
    if block is None:
        from ...core import measures as _measures
        from .. import tune
        block = tune.tuned(
            "prealign_encode", "block", length=S, window=window,
            measure=_measures.resolve(measure).name,
            backend="pallas_interpret" if interpret else "pallas",
            default=8)
    block = min(block, max(1, N))
    Xp = pad_to(X, block, axis=0)
    lin = jnp.linspace(0.0, 1.0, S, dtype=jnp.float32)[None, :]
    call = make_prealign_encode_call(
        Xp.shape[0], D, M, K, S, level, tail, w, block,
        band_width(S, w, lane), interpret, measure=measure)
    return call(Xp, centroids, lin)[:N]
