"""Suppression hygiene seeds: reasonless ignore + unused ignore."""

import jax


def pull(x):
    return jax.device_get(x)  # repro: ignore[RS101]


def fine(x):
    return x + 1  # repro: ignore[RS303] nothing here matches
