"""Shared kernel utilities."""

from __future__ import annotations

import jax

__all__ = ["default_interpret", "pad_to", "cdiv"]


def default_interpret() -> bool:
    """Pallas kernels target TPU; everywhere else run the kernel body in
    interpret mode (Python/XLA emulation) for correctness validation."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x, multiple: int, axis: int = 0, value=0):
    """Pad ``x`` along ``axis`` up to the next multiple of ``multiple``."""
    import jax.numpy as jnp
    n = x.shape[axis]
    pad = cdiv(n, multiple) * multiple - n
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)
