"""DTW lower bounds: Keogh envelopes, LB_Keogh (reversed), LB_Kim, cascade.

All bounds are for *squared* DTW cost, matching :mod:`repro.core.dtw`.

The paper reverses the query/data role of LB_Keogh: envelopes are built once
around the *codebook centroids* at training time, so encoding a fresh series
costs only O(D/M) per bound evaluation.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["keogh_envelope", "lb_keogh", "lb_kim", "lb_cascade", "lb_lut"]


def _shift(x: jnp.ndarray, offset: int, fill: float) -> jnp.ndarray:
    """``x[..., i + offset]`` with out-of-range slots reading ``fill``."""
    if offset == 0:
        return x
    pad = jnp.full(x.shape[:-1] + (abs(offset),), fill, x.dtype)
    if offset > 0:
        return jnp.concatenate([x[..., offset:], pad], axis=-1)
    return jnp.concatenate([pad, x[..., :offset]], axis=-1)


def _rolling_extreme(x: jnp.ndarray, w: int, combine, fill: float
                     ) -> jnp.ndarray:
    """``combine`` over the truncated window ``x[max(0, i-w) .. min(L-1,
    i+w)]`` via doubling: O(L log w) time, O(L) memory.

    The series is padded with ``w`` identity elements (``fill``) per side
    so every centered window is full width ``2w+1``; forward windows
    ``g[s] = combine(pad[s .. s+p-1])`` for the largest power of two
    ``p <= 2w+1`` are built in log2(p) shifted-combine steps, and each
    centered window is the combine of the two (overlapping) ``p``-windows
    that cover it.
    """
    width = 2 * w + 1
    p = 1 << (width.bit_length() - 1)       # largest power of two <= width
    L = x.shape[-1]
    pad = jnp.full(x.shape[:-1] + (w,), fill, x.dtype)
    g = jnp.concatenate([pad, x, pad], axis=-1)
    step = 1
    while step < p:
        g = combine(g, _shift(g, step, fill))
        step *= 2
    # window i spans pad[i .. i+width-1]; its two covering p-windows start
    # at i and i + width - p (p > width/2, so together they cover it all)
    return combine(g[..., :L], g[..., width - p:width - p + L])


@functools.partial(jax.jit, static_argnames=("window",))
def keogh_envelope(x: jnp.ndarray, window: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Upper/lower Keogh envelope: rolling max/min over ``|shift| <= window``.

    ``x`` may be ``(L,)`` or batched ``(..., L)``.  Returns ``(U, L)`` with
    the same shape as ``x``.  Rolling extrema are computed by log-depth
    shifted combines — O(L log window) time and O(L) memory, so a full-width
    envelope (``window >= L``) no longer materializes an O(L^2) shift stack.
    The effective window is clamped to ``L - 1``: shifts beyond the series
    length never contribute.
    """
    x = jnp.asarray(x, jnp.float32)
    L = x.shape[-1]
    w = max(0, min(int(window), L - 1))
    if w == 0:
        return x, x
    upper = _rolling_extreme(x, w, jnp.maximum, -jnp.inf)
    lower = _rolling_extreme(x, w, jnp.minimum, jnp.inf)
    return upper, lower


def lb_keogh(q: jnp.ndarray, upper: jnp.ndarray, lower: jnp.ndarray) -> jnp.ndarray:
    """LB_Keogh(q, c) given c's envelope — a lower bound on squared DTW(q, c).

    Broadcasts: ``q (..., L)`` against envelopes ``(..., L)``.
    """
    above = jnp.where(q > upper, (q - upper) ** 2, 0.0)
    below = jnp.where(q < lower, (lower - q) ** 2, 0.0)
    return jnp.sum(above + below, axis=-1)


def lb_kim(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Simplified LB_Kim: first and last points are always aligned by DTW,
    so their squared differences lower-bound the squared DTW cost."""
    return (q[..., 0] - c[..., 0]) ** 2 + (q[..., -1] - c[..., -1]) ** 2


def lb_cascade(q: jnp.ndarray, centroids: jnp.ndarray,
               upper: jnp.ndarray, lower: jnp.ndarray) -> jnp.ndarray:
    """Cascading bound used for the filter-then-refine encoder.

    ``q (L,)`` vs ``centroids (K, L)`` with envelopes ``(K, L)`` each.
    Returns the *tightest available* cheap bound per centroid:
    ``max(LB_Kim, reversed LB_Keogh)`` — both are valid lower bounds, so the
    max is too.
    """
    kim = lb_kim(q[None, :], centroids)
    keogh = lb_keogh(q[None, :], upper, lower)
    return jnp.maximum(kim, keogh)


def lb_lut(q_segs: jnp.ndarray, centroids: jnp.ndarray,
           upper: jnp.ndarray, lower: jnp.ndarray) -> jnp.ndarray:
    """Cascaded lower-bound table for the asymmetric query LUT.

    ``q_segs (..., M, S)`` vs ``centroids (M, K, S)`` with envelopes
    ``(M, K, S)`` -> ``(..., M, K)``; every entry lower-bounds the
    corresponding squared subspace distance in ``pq.query_lut``, so
    code-wise sums of this table lower-bound the asymmetric ADC distance.
    """
    kim = lb_kim(q_segs[..., None, :], centroids)
    keogh = lb_keogh(q_segs[..., None, :], upper, lower)
    return jnp.maximum(kim, keogh)
