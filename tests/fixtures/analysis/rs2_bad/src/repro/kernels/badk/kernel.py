"""Pallas kernel body for the badk op (deliberately incomplete)."""


def badk_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] + 1
