#!/usr/bin/env python3
"""Fail CI if the dispatch layer silently fell off the expected backend.

Usage: python scripts/check_routing.py ROUTING_DUMP.json [BACKEND]

The dump is written by tests/conftest.py at pytest session end (set
REPRO_ROUTING_DUMP) from the process-lifetime `repro.core.dispatch.totals`
ledger. Every elastic op listed below must have dispatched through BACKEND
(default: the REPRO_ELASTIC_BACKEND the tests ran under) at least once —
a kernel import error or an accidental fallback to the pure-JAX route
would otherwise let the suite pass without executing a single Pallas
kernel body.

Measure-parameterized ops are additionally ledgered as "op[measure]";
for MEASURED_OPS the gate also requires at least one NON-DTW measure to
have dispatched through BACKEND, so the measure-generic kernel bodies
(wdtw/erp/msm recurrence steps) are provably exercised, not just the DTW
default.
"""

import json
import os
import re
import sys

EXPECTED_OPS = (
    "elastic_pairwise",
    "elastic_cdist",
    "adc_cdist",
    "adc_lookup",
    "prealign_encode",
    "lb_refine",
    "two_level_coarse",
)

# ops whose recurrence is measure-parameterized: each needs a non-DTW
# dispatch on the asserted backend (lb_refine stays DTW-only by its
# capability gate, so it is not listed here)
MEASURED_OPS = (
    "elastic_pairwise",
    "elastic_cdist",
    "prealign_encode",
    "two_level_coarse",
)


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__)
        return 2
    path = sys.argv[1]
    backend = (
        sys.argv[2]
        if len(sys.argv) > 2
        else os.environ.get("REPRO_ELASTIC_BACKEND", "pallas_interpret")
    )
    with open(path) as f:
        ledger = json.load(f)
    print(f"routing ledger ({path}), asserting backend {backend!r}:")
    for key in sorted(ledger):
        print(f"  {key}: {ledger[key]}")
    missing = [op for op in EXPECTED_OPS if not ledger.get(f"{op}:{backend}")]
    if missing:
        print(
            f"FAIL: ops never dispatched through {backend!r}: "
            f"{', '.join(missing)} — silent backend fallback?"
        )
        return 1
    missing_measure = []
    for op in MEASURED_OPS:
        pat = re.compile(
            rf"^{re.escape(op)}\[(?!dtw\])[^\]]+\]:{re.escape(backend)}$"
        )
        if not any(pat.match(k) and ledger[k] for k in ledger):
            missing_measure.append(op)
    if missing_measure:
        print(
            f"FAIL: measure-parameterized ops never ran a non-DTW measure "
            f"through {backend!r}: {', '.join(missing_measure)} — the "
            "measure-generic kernel bodies are untested"
        )
        return 1
    print(
        f"OK: all {len(EXPECTED_OPS)} elastic ops routed through "
        f"{backend!r} (incl. a non-DTW measure for "
        f"{len(MEASURED_OPS)} measured ops)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
