"""Clean equivalents of the rs3_bad tree: zero findings expected."""

import threading

from .view import IndexView


class Server:
    _WRITER_ONLY = frozenset({"_index", "_view"})
    _WRITER_METHODS = frozenset({"_apply"})

    def __init__(self, index):
        self._index = index
        self._lock = threading.Lock()
        self._view = IndexView.capture(index)

    def _apply(self, batch):
        self._index = batch
        self._view = IndexView.capture(batch, version=1)

    def search(self, q):
        view = self._view
        with self._lock:
            return view, q
