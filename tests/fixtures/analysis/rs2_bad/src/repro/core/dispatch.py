"""Mini dispatch: goodk registered; orphan_op not routing-gated."""

from ..kernels.goodk.ops import run_goodk
from ..kernels.goodk.ref import run_goodk_ref


def _count(op, route, measure=None):
    del op, route, measure


def goodk(x, backend="pallas"):
    _count("goodk", backend)
    if backend == "jnp":
        return run_goodk_ref(x)
    return run_goodk(x)


def orphan(x):
    _count("orphan_op", "jnp")  # RS203: not in EXPECTED_OPS
    return x


def orphan_adaptive(x):
    # RS203 twin: a mode-specific counter name that never made it into
    # the gate's EXPECTED_OPS (the adaptive/quant-path failure shape)
    _count("orphan_op_adaptive", "jnp")
    return x
