"""Decoder-only LM assembly for every assigned family.

All repeated layers are ``lax.scan``-stacked (params carry a leading layer
axis) so the lowered HLO contains ONE block body per block type regardless
of depth — critical for 80-layer archs and for dry-run compile times.

Families:
  dense    — [pre-norm attn + SwiGLU] x L; gemma2 adds sandwich norms,
             softcaps and local/global alternation (scanned in pairs).
  moe      — attention + top-k routed experts (+ optional shared experts).
  ssm      — Mamba2 SSD blocks, attention-free.
  hybrid   — Mamba2 backbone; ONE weight-tied attention block applied before
             every ``attn_every`` SSM blocks (zamba2-style).
  vlm      — dense backbone + patch-embedding stub + M-RoPE positions.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (AttnParams, MlpParams, MoeParams, attention,
                     init_attn, init_mlp, init_moe, mlp, moe, mrope_positions,
                     _mrope_tables, rms_norm, rotary, softcap)
from .ssm import SsmParams, init_ssm, ssd_forward
from ..sharding.partition import constrain_batch

__all__ = ["LmParams", "DenseBlock", "MoeBlock", "SsmBlock", "init_params",
           "forward", "logits_from_hidden"]


class DenseBlock(NamedTuple):
    ln1: jnp.ndarray
    attn: AttnParams
    post_attn_ln: Optional[jnp.ndarray]   # gemma2 sandwich norm
    ln2: jnp.ndarray
    mlp: MlpParams
    post_mlp_ln: Optional[jnp.ndarray]


class MoeBlock(NamedTuple):
    ln1: jnp.ndarray
    attn: AttnParams
    ln2: jnp.ndarray
    moe: MoeParams


class SsmBlock(NamedTuple):
    ln: jnp.ndarray
    ssm: SsmParams


class LmParams(NamedTuple):
    embed: jnp.ndarray                     # (Vp, d)
    blocks: Any                            # scan-stacked block params
    shared_attn: Optional[DenseBlock]      # hybrid only (weight-tied)
    final_norm: jnp.ndarray                # (d,)
    lm_head: Optional[jnp.ndarray]         # (Vp, d); None when tied
    patch_proj: Optional[jnp.ndarray]      # (d, d) vlm stub projection


def _zeros_d(cfg):
    return jnp.zeros((cfg.d_model,), jnp.float32)


def _init_dense_block(key, cfg: ModelConfig, sandwich: bool) -> DenseBlock:
    k1, k2 = jax.random.split(key)
    return DenseBlock(
        ln1=_zeros_d(cfg), attn=init_attn(k1, cfg),
        post_attn_ln=_zeros_d(cfg) if sandwich else None,
        ln2=_zeros_d(cfg),
        mlp=init_mlp(k2, cfg.d_model, cfg.d_ff),
        post_mlp_ln=_zeros_d(cfg) if sandwich else None)


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key: jax.Array, cfg: ModelConfig) -> LmParams:
    """Real initialization (reduced configs / examples).  Dry-runs use
    ``jax.eval_shape(init_params, ...)`` so nothing is allocated."""
    keys = jax.random.split(key, cfg.n_layers + 4)
    Vp, d = cfg.padded_vocab, cfg.d_model
    embed = jax.random.normal(keys[-1], (Vp, d), jnp.float32) * 0.02
    lm_head = None if cfg.tie_embeddings else (
        jax.random.normal(keys[-2], (Vp, d), jnp.float32) * 0.02)
    patch_proj = None
    if cfg.family == "vlm":
        patch_proj = jax.random.normal(keys[-3], (d, d), jnp.float32) * 0.02
    shared_attn = None

    fam = cfg.family
    if fam in ("dense", "vlm"):
        sandwich = cfg.local_global          # gemma2
        blocks = _stack([_init_dense_block(keys[i], cfg, sandwich)
                         for i in range(cfg.n_layers)])
        if cfg.local_global:                 # regroup into (L/2, 2) pairs
            blocks = jax.tree.map(
                lambda x: x.reshape(cfg.n_layers // 2, 2, *x.shape[1:]),
                blocks)
    elif fam == "moe":
        def mk(i):
            k1, k2 = jax.random.split(keys[i])
            return MoeBlock(ln1=_zeros_d(cfg), attn=init_attn(k1, cfg),
                            ln2=_zeros_d(cfg), moe=init_moe(k2, cfg))
        blocks = _stack([mk(i) for i in range(cfg.n_layers)])
    elif fam == "ssm":
        blocks = _stack([SsmBlock(ln=_zeros_d(cfg), ssm=init_ssm(keys[i], cfg))
                         for i in range(cfg.n_layers)])
    elif fam == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        blocks = _stack([SsmBlock(ln=_zeros_d(cfg), ssm=init_ssm(keys[i], cfg))
                         for i in range(cfg.n_layers)])
        blocks = jax.tree.map(
            lambda x: x.reshape(n_groups, cfg.attn_every, *x.shape[1:]),
            blocks)
        shared_attn = _init_dense_block(keys[-4], cfg, sandwich=False)
    else:
        raise ValueError(f"init_params: family {fam!r} (encdec lives in "
                         "repro.models.encdec)")
    return LmParams(embed=embed, blocks=blocks, shared_attn=shared_attn,
                    final_norm=_zeros_d(cfg), lm_head=lm_head,
                    patch_proj=patch_proj)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _dense_block_apply(blk: DenseBlock, cfg: ModelConfig, h, positions,
                       cos_sin, *, window: int, q_chunk: int):
    h = constrain_batch(h)
    a = attention(blk.attn, cfg, rms_norm(h, blk.ln1, cfg.norm_eps),
                  positions, causal=True, window=window, q_chunk=q_chunk,
                  cos_sin=cos_sin)
    if blk.post_attn_ln is not None:
        a = rms_norm(a, blk.post_attn_ln, cfg.norm_eps)
    h = h + a
    m = mlp(blk.mlp, rms_norm(h, blk.ln2, cfg.norm_eps), cfg.act)
    if blk.post_mlp_ln is not None:
        m = rms_norm(m, blk.post_mlp_ln, cfg.norm_eps)
    return constrain_batch(h + m)


def _moe_block_apply(blk: MoeBlock, cfg: ModelConfig, h, positions, cos_sin,
                     *, q_chunk: int):
    h = constrain_batch(h)
    a = attention(blk.attn, cfg, rms_norm(h, blk.ln1, cfg.norm_eps),
                  positions, causal=True, q_chunk=q_chunk, cos_sin=cos_sin)
    h = h + a
    return constrain_batch(
        h + moe(blk.moe, cfg, rms_norm(h, blk.ln2, cfg.norm_eps)))


def _ssm_block_apply(blk: SsmBlock, cfg: ModelConfig, h, *, chunk: int = 128):
    h = constrain_batch(h)
    return constrain_batch(
        h + ssd_forward(blk.ssm, cfg, rms_norm(h, blk.ln, cfg.norm_eps),
                        chunk=chunk))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed(params: LmParams, cfg: ModelConfig, batch) -> jnp.ndarray:
    tokens = batch["tokens"]
    x = params.embed[tokens].astype(jnp.bfloat16)
    if cfg.local_global:                       # gemma scales embeddings
        x = x * jnp.bfloat16(cfg.d_model ** 0.5)
    if cfg.family == "vlm" and "patches" in batch:
        proj = jnp.einsum("bpd,de->bpe", batch["patches"].astype(jnp.bfloat16),
                          params.patch_proj.astype(jnp.bfloat16),
                          preferred_element_type=jnp.float32
                          ).astype(jnp.bfloat16)
        x = jax.lax.dynamic_update_slice_in_dim(x, proj, 0, axis=1)
    return constrain_batch(x)


def logits_from_hidden(params: LmParams, cfg: ModelConfig,
                       h: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(h, params.final_norm, cfg.norm_eps)
    head = params.embed if params.lm_head is None else params.lm_head
    logits = jnp.einsum("bsd,vd->bsv", h.astype(jnp.bfloat16),
                        head.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    return softcap(logits, cfg.final_softcap)


def forward(params: LmParams, cfg: ModelConfig, batch, *,
            q_chunk: int = 512, remat: bool = True,
            ssm_chunk: int = 128, return_hidden: bool = False) -> jnp.ndarray:
    """Token logits ``(B, S, padded_vocab)`` for a full sequence.

    ``return_hidden=True`` skips the LM head and returns the final hidden
    states (prefill lowers this + a last-position projection, so the
    (B, S, V) logits tensor is never materialised)."""
    x = _embed(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    hd = cfg.head_dim_ if cfg.n_heads else 0

    if cfg.family == "ssm":
        cos_sin = None
    elif cfg.mrope:
        mpos = mrope_positions(positions, cfg.n_frontend_tokens,
                               cfg.mrope_sections)
        cos_sin = _mrope_tables(mpos, hd, cfg.rope_theta, cfg.mrope_sections)
    else:
        cos_sin = rotary(positions, hd, cfg.rope_theta)

    fam = cfg.family
    ckpt = (jax.checkpoint if remat else (lambda f, **kw: f))

    if fam in ("dense", "vlm"):
        if cfg.local_global:
            def pair_body(h, blk_pair):
                blk_l = jax.tree.map(lambda x: x[0], blk_pair)
                blk_g = jax.tree.map(lambda x: x[1], blk_pair)
                h = _dense_block_apply(blk_l, cfg, h, positions, cos_sin,
                                       window=cfg.sliding_window,
                                       q_chunk=q_chunk)
                h = _dense_block_apply(blk_g, cfg, h, positions, cos_sin,
                                       window=0, q_chunk=q_chunk)
                return h, None
            body = ckpt(pair_body)
        else:
            def blk_body(h, blk):
                return _dense_block_apply(blk, cfg, h, positions, cos_sin,
                                          window=0, q_chunk=q_chunk), None
            body = ckpt(blk_body)
        x, _ = jax.lax.scan(body, x, params.blocks)

    elif fam == "moe":
        def blk_body(h, blk):
            return _moe_block_apply(blk, cfg, h, positions, cos_sin,
                                    q_chunk=q_chunk), None
        x, _ = jax.lax.scan(ckpt(blk_body), x, params.blocks)

    elif fam == "ssm":
        def blk_body(h, blk):
            return _ssm_block_apply(blk, cfg, h, chunk=ssm_chunk), None
        x, _ = jax.lax.scan(ckpt(blk_body), x, params.blocks)

    elif fam == "hybrid":
        shared = params.shared_attn

        def group_body(h, group_blocks):
            h = _dense_block_apply(shared, cfg, h, positions, cos_sin,
                                   window=0, q_chunk=q_chunk)
            def inner(hh, blk):
                return _ssm_block_apply(blk, cfg, hh, chunk=ssm_chunk), None
            h, _ = jax.lax.scan(inner, h, group_blocks)
            return h, None
        x, _ = jax.lax.scan(ckpt(group_body), x, params.blocks)

    else:
        raise ValueError(fam)

    if return_hidden:
        return x
    return logits_from_hidden(params, cfg, x)
