"""Production serving core over the streaming index.

Coalesced query microbatching (bucketed padded launches — a warmed server
answers mixed traffic with zero new compilations) + a concurrent ingest
writer publishing immutable copy-on-write snapshots, with admission
control on the write path.  Operations guide: ``docs/serving.md``.

    from repro.serve_index import IndexServer, ServeConfig

    with IndexServer(index, ServeConfig(n_probe=4, topk=3)) as srv:
        srv.insert(X).result()
        dist, ids = srv.search(Q)
"""

from .config import SHED_POLICIES, ServeConfig
from .coalescer import QueryCoalescer
from .server import Backpressure, IndexServer, SearchResult
from .view import IndexView

__all__ = [
    "IndexServer",
    "ServeConfig",
    "SHED_POLICIES",
    "IndexView",
    "SearchResult",
    "Backpressure",
    "QueryCoalescer",
]
