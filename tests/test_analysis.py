"""repro.analysis: fixture trees seed one violation per RS rule (bad)
with clean equivalents (good), plus suppression/baseline mechanics, the
CLI exit codes, the check_routing single-format contract, and the
self-check that the live tree is clean."""

import json
import subprocess
import sys
from pathlib import Path

from repro.analysis import analyze
from repro.analysis.findings import write_baseline

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).parent / "fixtures" / "analysis"


def _rules(report):
    return {f.rule for f in report.findings}


def _by_rule(report, rule):
    return [f for f in report.findings if f.rule == rule]


# -- RS1xx: trace safety -----------------------------------------------------

def test_rs1_bad_tree_flags_each_rule():
    r = analyze(FIXTURES / "rs1_bad")
    assert _rules(r) == {"RS101", "RS102", "RS103", "RS104"}
    # float(jnp.min(...)) in the trace-reachable helper + .item()
    rs101 = _by_rule(r, "RS101")
    assert {f.scope.rsplit(".", 1)[-1] for f in rs101} == \
        {"helper", "report"}
    # np.asarray in the never-traced function must NOT be flagged
    assert not any("offline" in f.scope for f in r.findings)
    # both static_argnames defects: unknown name + mutable default
    assert len(_by_rule(r, "RS103")) == 2


def test_rs1_good_tree_is_clean():
    r = analyze(FIXTURES / "rs1_good")
    assert r.clean, [f.render(FIXTURES) for f in r.findings]


# -- RS2xx: dispatch invariants ----------------------------------------------

def test_rs2_bad_tree_flags_each_rule():
    r = analyze(FIXTURES / "rs2_bad")
    assert _rules(r) == {"RS201", "RS202", "RS203", "RS204", "RS205"}
    # the incomplete/unregistered kernel anchors at its ops.py
    for rule in ("RS201", "RS202"):
        (f,) = _by_rule(r, rule)
        assert f.path.parts[-3:] == ("kernels", "badk", "ops.py")
    # both orphan _count sites flag independently: the base op and its
    # mode twin (adaptive/quant-style counter names are separate ops)
    f203 = _by_rule(r, "RS203")
    assert len(f203) == 2
    assert {m for f in f203 for m in ("orphan_op", "orphan_op_adaptive")
            if f"{m}'" in f.message} == {"orphan_op", "orphan_op_adaptive"}
    (f204,) = _by_rule(r, "RS204")
    assert "run_badk" in f204.message
    (f205,) = _by_rule(r, "RS205")
    assert f205.path.name == "check_routing.py"


def test_rs2_good_tree_is_clean():
    r = analyze(FIXTURES / "rs2_good")
    assert r.clean, [f.render(FIXTURES) for f in r.findings]


# -- RS3xx: serving concurrency ----------------------------------------------

def test_rs3_bad_tree_flags_each_rule():
    r = analyze(FIXTURES / "rs3_bad")
    assert _rules(r) == {"RS301", "RS302", "RS303"}
    (f301,) = _by_rule(r, "RS301")
    assert "_view" in f301.message and f301.scope.endswith("search")
    (f302,) = _by_rule(r, "RS302")
    assert "view.version" in f302.message
    assert len(_by_rule(r, "RS303")) == 2  # acquire + release


def test_rs3_good_tree_is_clean():
    r = analyze(FIXTURES / "rs3_good")
    assert r.clean, [f.render(FIXTURES) for f in r.findings]


# -- suppression + baseline mechanics ----------------------------------------

def test_suppression_hygiene_meta_rules():
    r = analyze(FIXTURES / "meta_bad")
    # the reasonless ignore suppresses RS101 but raises RS001; the
    # ignore that matches nothing raises RS002
    assert _rules(r) == {"RS001", "RS002"}


def test_reasoned_suppression_silences():
    r = analyze(FIXTURES / "meta_good")
    assert r.clean, [f.render(FIXTURES) for f in r.findings]


def test_baseline_freezes_then_ratchets(tmp_path):
    bad = FIXTURES / "rs1_bad"
    baseline = tmp_path / "baseline.json"
    write_baseline(baseline, analyze(bad).findings, bad)

    # frozen but unjustified: still a failure (the CI growth gate)
    r = analyze(bad, baseline_path=baseline)
    assert not r.findings and r.unjustified_baseline and not r.clean

    data = json.loads(baseline.read_text())
    for entry in data["findings"].values():
        entry["justification"] = "frozen pre-existing debt"
    baseline.write_text(json.dumps(data))
    assert analyze(bad, baseline_path=baseline).clean

    # debt paid (the good tree): every entry is stale and must go
    r = analyze(FIXTURES / "rs1_good", baseline_path=baseline)
    assert not r.findings and r.stale_baseline and not r.clean


# -- CLI + live tree ---------------------------------------------------------

def _run_static(*args):
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_static.py"), *args],
        capture_output=True, text=True)


def test_cli_exit_codes():
    bad = _run_static("--root", str(FIXTURES / "rs1_bad"))
    assert bad.returncode == 1 and "RS101" in bad.stdout
    good = _run_static("--root", str(FIXTURES / "rs1_good"))
    assert good.returncode == 0
    rules = _run_static("--list-rules")
    assert rules.returncode == 0 and "RS204" in rules.stdout


def test_live_tree_is_clean():
    r = analyze(REPO, baseline_path=REPO / "STATIC_BASELINE.json")
    assert r.clean, (
        [f.render(REPO) for f in r.findings],
        r.stale_baseline, r.unjustified_baseline)


def test_live_tree_graph_sanity():
    # the call graph must actually see the hot paths it guards: jitted
    # roots exist and a Pallas launcher is known in the kernels package
    r = analyze(REPO)
    roots = r.graph.trace_roots()
    assert len(roots) >= 10
    assert any(q.startswith("repro.kernels.") for q in
               r.graph.pallas_launchers())


# -- check_routing: exactly one accepted dump format -------------------------

def _run_routing(path):
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_routing.py"),
         str(path), "pallas_interpret"],
        capture_output=True, text=True)


def test_check_routing_rejects_legacy_flat_dict(tmp_path):
    legacy = tmp_path / "legacy.json"
    legacy.write_text(json.dumps({"elastic_pairwise:pallas_interpret": 3}))
    res = _run_routing(legacy)
    assert res.returncode == 2
    assert "no longer accepted" in res.stdout


def test_check_routing_accepts_snapshot_format(tmp_path):
    snap = tmp_path / "snap.json"
    snap.write_text(json.dumps({"counters": []}))
    res = _run_routing(snap)
    # accepted format, but the (empty) ledger fails the op gate
    assert res.returncode == 1
    assert "never dispatched" in res.stdout
