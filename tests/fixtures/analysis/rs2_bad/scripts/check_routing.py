"""Mini routing gate with the legacy two-format fallback (RS205)."""

import json
import sys

EXPECTED_OPS = {"goodk"}


def ledger_from_snapshot(dump):
    return dump.get("counters", {})


def main():
    dump = json.load(open(sys.argv[1]))
    is_snapshot = "counters" in dump
    ledger = ledger_from_snapshot(dump) if is_snapshot else dump  # RS205
    return 0 if all(ledger.get(op) for op in EXPECTED_OPS) else 1


if __name__ == "__main__":
    sys.exit(main())
