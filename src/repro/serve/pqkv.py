"""PQ-compressed KV cache — the paper's technique as a serving feature.

The paper compresses a database of time series with product quantization and
answers elastic-similarity queries from code look-up tables.  An LM decoder
does the same thing every step: the KV cache is a *database of key vectors*
and attention is a *similarity search* of the query against it.  We map the
paper's machinery 1:1:

  codebook training   -> per-(layer, kv-head) Euclidean k-means over observed
                         keys, subspaces along head_dim (``fit_kv_books``) —
                         the same ``euclidean_kmeans`` that backs the paper's
                         PQ_ED baseline.
  encoding            -> every cached key becomes M uint8 codes
                         (``encode_keys``; 2*hd bytes -> M bytes).
  asymmetric distance -> the decode query builds one small ADC table per
                         layer; every cached position's attention score is
                         M table look-ups (kernels/pq_attn).
  filter-then-refine  -> scores inside an exact *recent window* (a ring
                         buffer of raw keys) override their ADC estimates —
                         the refinement step of the paper's §3.2 cascade,
                         applied to the positions that matter most.

Beyond the paper (recorded in EXPERIMENTS.md §Perf):

  * ``mode="topk"``    — sparse value reads: only the top-T scored positions'
                         values are gathered (HBM traffic S*hd -> T*hd).
  * ``quantize_v=True``— values PQ-coded too; the attention output is then
                         computed WITHOUT reconstructing values: softmax mass
                         is aggregated per codeword (``w[k] = sum p_s``,
                         ``out = w @ book``) — O(K*hd) instead of O(S*hd).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.kmeans import euclidean_kmeans
from ..models.config import ModelConfig
from ..models.layers import mlp, moe, rms_norm, rotary, apply_rope, _dot
from ..sharding.partition import constrain_dims

__all__ = ["PQKVConfig", "PQKVCache", "fit_kv_books", "compress_cache",
           "init_pq_cache", "pq_attention_decode", "pq_serve_step",
           "pqkv_memory"]

_NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class PQKVConfig:
    """Serving-time PQ configuration (paper §3.4 semantics)."""
    n_sub: int = 8              # M subspaces along head_dim
    codebook_size: int = 256    # K
    recent_window: int = 128    # exact ring-buffer length (refinement window)
    mode: str = "softmax"       # "softmax" (dense ADC) | "topk" (sparse reads)
    top_t: int = 128            # T for mode="topk"
    quantize_v: bool = False    # PQ the values too (full 4D/M-style ratio)
    kmeans_iters: int = 12
    fit_sample: int = 4096      # max tokens sampled per (layer, group) fit


class PQKVCache(NamedTuple):
    """Layer-stacked compressed cache (a pytree; shard rules in sharding/)."""
    k_codes: jnp.ndarray            # (L, B, Smax, G, M) int32
    k_books: jnp.ndarray            # (L, G, M, K, hd/M) f32
    v: Optional[jnp.ndarray]        # (L, B, Smax, G, hd) bf16 | None
    v_codes: Optional[jnp.ndarray]  # (L, B, Smax, G, M) int32 | None
    v_books: Optional[jnp.ndarray]  # (L, G, M, K, hd/M) f32   | None
    k_recent: jnp.ndarray           # (L, B, W, G, hd) bf16 exact ring
    v_recent: jnp.ndarray           # (L, B, W, G, hd) bf16 exact ring


# ---------------------------------------------------------------------------
# Codebook fitting / encoding
# ---------------------------------------------------------------------------

def _subspace_kmeans(key: jax.Array, vecs: jnp.ndarray, n_sub: int, K: int,
                     iters: int) -> jnp.ndarray:
    """``vecs (T, hd)`` -> books ``(M, K, hd/M)`` by per-subspace k-means."""
    T, hd = vecs.shape
    Ds = hd // n_sub
    sub = vecs.reshape(T, n_sub, Ds)
    keys = jax.random.split(key, n_sub)

    def one(k, x):
        return euclidean_kmeans(k, x, K, iters=iters).centroids

    return jnp.stack([one(keys[m], sub[:, m, :]) for m in range(n_sub)])


def fit_kv_books(key: jax.Array, kv: jnp.ndarray, pqc: PQKVConfig,
                 valid_len: Optional[int] = None) -> jnp.ndarray:
    """Fit codebooks from observed keys (or values).

    ``kv (L, B, S, G, hd)`` -> books ``(L, G, M, K, hd/M)``.  Tokens are
    subsampled to ``fit_sample`` per (layer, group); fitting is a one-time
    prefill-side cost, amortized over the whole decode (paper §3.1).
    """
    L, B, S, G, hd = kv.shape
    S_eff = valid_len if valid_len is not None else S
    flat = kv[:, :, :S_eff].astype(jnp.float32)
    flat = jnp.moveaxis(flat, 3, 1).reshape(L, G, B * S_eff, hd)
    T = flat.shape[2]
    n = min(pqc.fit_sample, T)
    keys = jax.random.split(key, L * G).reshape(L, G, 2)

    books = []
    for l in range(L):
        per_g = []
        for g in range(G):
            kk = jax.random.fold_in(jax.random.PRNGKey(0), l * G + g)
            idx = jax.random.choice(kk, T, (n,), replace=n > T)
            per_g.append(_subspace_kmeans(keys[l, g], flat[l, g][idx],
                                          pqc.n_sub, pqc.codebook_size,
                                          pqc.kmeans_iters))
        books.append(jnp.stack(per_g))
    return jnp.stack(books)          # (L, G, M, K, Ds)


def encode_kv(kv: jnp.ndarray, books: jnp.ndarray) -> jnp.ndarray:
    """``kv (..., G, hd)``, books ``(G, M, K, Ds)`` -> codes ``(..., G, M)``."""
    G, M, K, Ds = books.shape
    lead = kv.shape[:-2]
    x = kv.astype(jnp.float32).reshape(*lead, G, M, Ds)
    d2 = (jnp.sum(x * x, -1)[..., None]
          - 2.0 * jnp.einsum("...gmd,gmkd->...gmk", x, books)
          + jnp.sum(books * books, -1))
    # uint8 storage: K <= 256 always (paper §3.4's 8-bit code convention)
    return jnp.argmin(d2, axis=-1).astype(jnp.uint8)


def decode_kv(codes: jnp.ndarray, books: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`encode_kv` (reconstruction, test/debug only)."""
    G, M, K, Ds = books.shape
    oh = jax.nn.one_hot(codes, K, dtype=jnp.float32)        # (..., G, M, K)
    rec = jnp.einsum("...gmk,gmkd->...gmd", oh, books)
    return rec.reshape(*codes.shape[:-1], M * Ds)


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def init_pq_cache(cfg: ModelConfig, pqc: PQKVConfig, batch: int,
                  max_len: int, books: jnp.ndarray,
                  v_books: Optional[jnp.ndarray] = None) -> PQKVCache:
    """Empty compressed cache (books must be pre-fit)."""
    L, G = cfg.n_layers, cfg.n_kv_heads
    hd, M, W = cfg.head_dim_, pqc.n_sub, pqc.recent_window
    codes = jnp.zeros((L, batch, max_len, G, M), jnp.uint8)
    if pqc.quantize_v:
        v = None
        v_codes = jnp.zeros((L, batch, max_len, G, M), jnp.uint8)
        assert v_books is not None, "quantize_v=True needs fitted v_books"
    else:
        v = jnp.zeros((L, batch, max_len, G, hd), jnp.bfloat16)
        v_codes, v_books = None, None
    return PQKVCache(
        k_codes=codes, k_books=books, v=v, v_codes=v_codes, v_books=v_books,
        k_recent=jnp.zeros((L, batch, W, G, hd), jnp.bfloat16),
        v_recent=jnp.zeros((L, batch, W, G, hd), jnp.bfloat16))


def compress_cache(cache: Dict[str, jnp.ndarray], cfg: ModelConfig,
                   pqc: PQKVConfig, pos: int,
                   key: jax.Array = None) -> PQKVCache:
    """Compress an exact prefill cache {k, v} into a :class:`PQKVCache`.

    Fits key (and optionally value) codebooks on the first ``pos`` cached
    entries, encodes them, and seeds the exact ring with the last W tokens.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    k_cache, v_cache = cache["k"], cache["v"]
    L, B, Smax, G, hd = k_cache.shape
    W = pqc.recent_window
    kk, kv_ = jax.random.split(key)
    books = fit_kv_books(kk, k_cache, pqc, valid_len=pos)
    codes = jax.vmap(encode_kv)(k_cache, books)       # over L, uint8

    v_books = v_codes = None
    v = v_cache
    if pqc.quantize_v:
        v_books = fit_kv_books(kv_, v_cache, pqc, valid_len=pos)
        v_codes = jax.vmap(encode_kv)(v_cache, v_books)
        v = None

    # seed the exact ring with the last W prefill tokens (ring slot = p % W)
    take = jnp.arange(W)
    ring_pos = (pos - W + take) % Smax                # absolute positions
    slot = ((pos - W + take) % W + W) % W
    k_ring = jnp.zeros((L, B, W, G, hd), jnp.bfloat16)
    v_ring = jnp.zeros((L, B, W, G, hd), jnp.bfloat16)
    k_ring = k_ring.at[:, :, slot].set(
        k_cache[:, :, ring_pos].astype(jnp.bfloat16))
    v_ring = v_ring.at[:, :, slot].set(
        v_cache[:, :, ring_pos].astype(jnp.bfloat16))
    return PQKVCache(k_codes=codes, k_books=books, v=v, v_codes=v_codes,
                     v_books=v_books, k_recent=k_ring, v_recent=v_ring)


# ---------------------------------------------------------------------------
# Decode attention against the compressed cache (one layer)
# ---------------------------------------------------------------------------

def _adc_scores(q: jnp.ndarray, codes: jnp.ndarray,
                books: jnp.ndarray) -> jnp.ndarray:
    """ADC scores: ``q (B, G, R, hd)``, codes ``(B, S, G, M)``,
    books ``(G, M, K, Ds)`` -> ``(B, G, R, S)`` un-scaled dot products.

    The per-query cost is one tiny LUT build (G*R*M*K*Ds MACs) plus M LUT
    gathers per cached position — the paper's asymmetric distance
    computation.  Gather form: a dense one-hot over the full cache would
    materialise (B,S,G,M,K) floats; the Pallas kernel (kernels/pq_attn)
    uses the one-hot MXU contraction blockwise in VMEM instead.
    """
    from ..sharding.partition import current_model_size
    G, M, K, Ds = books.shape
    B, S = codes.shape[0], codes.shape[1]
    qr = q.astype(jnp.float32).reshape(B, G, -1, M, Ds)
    R = qr.shape[2]
    qlut = jnp.einsum("bgrmd,gmkd->bgrmk", qr, books
                      ).astype(jnp.bfloat16)                 # (B,G,R,M,K)

    # shard-blocked one-hot contraction (the pq_attn kernel's formulation):
    # S is laid out as (P shards, inner chunks, chunk) so every scan step
    # works on all TP shards in parallel and the transient one-hot block
    # stays small.  take_along_axis gathers over a sharded S axis trigger
    # SPMD "involuntary full rematerialization" (21 GB/layer all-gathers).
    P = current_model_size()
    P = P if S % P == 0 else 1
    Sp = S // P
    inner = 256 if (Sp % 256 == 0 and Sp > 256) else Sp
    nc = Sp // inner
    cs = codes.transpose(0, 2, 3, 1).reshape(B, G, M, P, nc, inner)
    cs = constrain_dims(cs, {0: "dp", 3: "model"})

    def one_chunk(cc):                                       # (B,G,M,P,i)
        oh = jax.nn.one_hot(cc, K, dtype=jnp.bfloat16)       # (B,G,M,P,i,K)
        return jnp.einsum("bgrmk,bgmpik->bgrpi", qlut, oh,
                          preferred_element_type=jnp.float32)

    if nc == 1:
        scores = one_chunk(cs[:, :, :, :, 0])[..., None, :]  # (B,G,R,P,1,i)
    else:
        _, out = jax.lax.scan(
            lambda c, cc: (c, one_chunk(cc)), 0,
            jnp.moveaxis(cs, 4, 0))                          # (nc,B,G,R,P,i)
        scores = jnp.moveaxis(out, 0, 4)                     # (B,G,R,P,nc,i)
    return scores.reshape(B, G, R, S)


def pq_attention_decode(q: jnp.ndarray, layer_cache, pos: jnp.ndarray, *,
                        pqc: PQKVConfig, window: int = 0) -> jnp.ndarray:
    """One-layer decode attention against a compressed cache slice.

    ``q (B, G, R, hd)``; ``layer_cache`` holds this layer's
    (k_codes (B,S,G,M), k_books, v or v_codes/v_books, k_recent, v_recent).
    Returns ``(B, G, R, hd)`` attention output.
    """
    k_codes, k_books, v, v_codes, v_books, k_rec, v_rec = layer_cache
    B, S, G, M = k_codes.shape
    hd = q.shape[-1]
    W = k_rec.shape[1]                           # per-layer ring (B, W, G, hd)
    scale = hd ** -0.5

    scores = _adc_scores(q, k_codes, k_books) * scale        # (B,G,R,S)
    # under the launch context: batch on DP, cache positions on "model"
    # (matches the code layout — ADC stays shard-local per cache shard)
    scores = constrain_dims(scores, {0: "dp", 3: "model"})

    # Two-piece softmax: the ADC tail (positions outside the ring, S-axis
    # sharded) and the exact ring (slot space, replicated).  Keeping the
    # ring piece in slot space avoids cross-shard scatter/gather between
    # the S axis and the W ring (SPMD rematerialization hazard).
    kpos = jnp.arange(S)
    in_recent = (kpos > pos - W) & (kpos <= pos)
    mask_tail = (kpos <= pos) & ~in_recent
    if window > 0:
        mask_tail &= kpos > (pos - window)
    s_tail = jnp.where(mask_tail[None, None, None, :], scores, _NEG_INF)

    qf = q.astype(jnp.float32)
    s_ring = jnp.einsum("bgrh,bwgh->bgrw", qf,
                        k_rec.astype(jnp.float32)) * scale   # (B,G,R,W)
    slots = jnp.arange(W)
    ring_abs = pos - jnp.mod(pos - slots, W)                 # abs position
    ring_valid = ring_abs >= 0
    if window > 0:
        ring_valid &= ring_abs > (pos - window)
    s_ring = jnp.where(ring_valid[None, None, None, :], s_ring, _NEG_INF)

    if pqc.mode == "topk":
        # sparse value reads: only the top-T ADC-scored tail positions'
        # values are read; the exact ring is always attended.
        T = min(pqc.top_t, S)
        top_s, top_i = jax.lax.top_k(s_tail, T)              # (B,G,R,T)
        m = jnp.maximum(jnp.max(top_s, -1, keepdims=True),
                        jnp.max(s_ring, -1, keepdims=True))
        et = jnp.exp(top_s - m)
        er = jnp.exp(s_ring - m)
        denom = et.sum(-1, keepdims=True) + er.sum(-1, keepdims=True)
        if v is not None:
            vg = jnp.take_along_axis(
                v.astype(jnp.float32)[:, :, :, None, :].transpose(
                    0, 2, 3, 1, 4),
                top_i[..., None], axis=3)                    # (B,G,R,T,hd)
        else:
            cg = jnp.take_along_axis(
                v_codes.transpose(0, 2, 1, 3)[:, :, None, :, :],
                top_i[..., None], axis=3)                    # (B,G,R,T,M)
            oh = jax.nn.one_hot(cg, v_books.shape[2], dtype=jnp.float32)
            vg = jnp.einsum("bgrtmk,gmkd->bgrtmd", oh, v_books)
            vg = vg.reshape(*vg.shape[:-2], -1)
        out = jnp.einsum("bgrt,bgrth->bgrh", et, vg)
        out = out + jnp.einsum("bgrw,bwgh->bgrh", er,
                               v_rec.astype(jnp.float32))
        return (out / denom).astype(jnp.bfloat16)

    m = jnp.maximum(jnp.max(s_tail, -1, keepdims=True),
                    jnp.max(s_ring, -1, keepdims=True))
    et = jnp.exp(s_tail - m)                                 # (B,G,R,S)
    er = jnp.exp(s_ring - m)                                 # (B,G,R,W)
    denom = et.sum(-1, keepdims=True) + er.sum(-1, keepdims=True)

    out = jnp.einsum("bgrw,bwgh->bgrh", er, v_rec.astype(jnp.float32))
    if v is not None:
        out = out + jnp.einsum("bgrs,bsgh->bgrh",
                               et.astype(jnp.bfloat16),
                               v.astype(jnp.bfloat16),
                               preferred_element_type=jnp.float32)
    else:
        # values PQ-coded: aggregate softmax mass per codeword with the
        # same shard-blocked one-hot contraction, then one (K x Ds)
        # contraction per subspace — O(K*hd) value reads, not O(S*hd).
        from ..sharding.partition import current_model_size
        K = v_books.shape[2]
        R = et.shape[2]
        P = current_model_size()
        P = P if S % P == 0 else 1
        Sp = S // P
        inner = 256 if (Sp % 256 == 0 and Sp > 256) else Sp
        nc = Sp // inner
        ct = v_codes.transpose(0, 2, 3, 1).reshape(B, G, M, P, nc, inner)
        ct = constrain_dims(ct, {0: "dp", 3: "model"})
        pt = et.reshape(B, G, R, P, nc, inner)

        def mass_chunk(cc, pc):                              # per nc chunk
            oh = jax.nn.one_hot(cc, K, dtype=jnp.bfloat16)   # (B,G,M,P,i,K)
            return jnp.einsum("bgrpi,bgmpik->bgrmk",
                              pc.astype(jnp.bfloat16), oh,
                              preferred_element_type=jnp.float32)

        if nc == 1:
            wmass = mass_chunk(ct[:, :, :, :, 0], pt[:, :, :, :, 0])
        else:
            def body(acc, xs):
                cc, pc = xs
                return acc + mass_chunk(cc, pc), None
            wmass, _ = jax.lax.scan(
                body, jnp.zeros((B, G, R, M, K), jnp.float32),
                (jnp.moveaxis(ct, 4, 0), jnp.moveaxis(pt, 4, 0)))
        vhat = jnp.einsum("bgrmk,gmkd->bgrmd", wmass, v_books)
        out = out + vhat.reshape(*vhat.shape[:-2], -1)
    return (out / denom).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# Full decode step with the compressed cache (dense / moe / vlm families)
# ---------------------------------------------------------------------------

def _pq_attn_block(attn_p, cfg: ModelConfig, x, layer_cache, pos, *,
                   pqc: PQKVConfig, window: int, cos_sin):
    """Project q/k/v, update the compressed cache at ``pos``, attend."""
    B = x.shape[0]
    hd, H, G = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    R = H // G
    cos, sin = cos_sin

    q = _dot(x, attn_p.wq, attn_p.bq).reshape(B, 1, G, R, hd)
    q = apply_rope(q, cos, sin)[:, 0]                        # (B,G,R,hd)
    k_new = apply_rope(_dot(x, attn_p.wk, attn_p.bk).reshape(B, 1, G, hd),
                       cos, sin)[:, 0]                       # (B,G,hd)
    v_new = _dot(x, attn_p.wv, attn_p.bv).reshape(B, G, hd)

    (k_codes, k_books, v, v_codes, v_books, k_rec, v_rec) = layer_cache
    W = k_rec.shape[1]                           # (B, W, G, hd)

    # write-through: PQ code at pos AND raw copy into the ring at pos % W.
    # One-hot selects on the (model-sharded) S axis — a DUS at a runtime
    # position would make SPMD all-gather the cache (see layers.py decode).
    kc_new = encode_kv(k_new, k_books)                       # (B,G,M)
    S = k_codes.shape[1]
    at_pos = (jnp.arange(S) == pos)[None, :, None, None]
    k_codes = jnp.where(at_pos, kc_new[:, None], k_codes)
    if v is not None:
        v = jnp.where(at_pos, v_new.astype(v.dtype)[:, None], v)
    else:
        vc_new = encode_kv(v_new, v_books)
        v_codes = jnp.where(at_pos, vc_new[:, None], v_codes)
    slot = pos % W
    k_rec = jax.lax.dynamic_update_slice_in_dim(     # ring: replicated axis
        k_rec, k_new.astype(k_rec.dtype)[:, None], slot, axis=1)
    v_rec = jax.lax.dynamic_update_slice_in_dim(
        v_rec, v_new.astype(v_rec.dtype)[:, None], slot, axis=1)

    new_cache = (k_codes, k_books, v, v_codes, v_books, k_rec, v_rec)
    out = pq_attention_decode(q, new_cache, pos, pqc=pqc, window=window)
    out = out.reshape(B, 1, H * hd).astype(jnp.bfloat16)
    return _dot(out, attn_p.wo), new_cache


def pq_serve_step(params, cfg: ModelConfig, pq_cache: PQKVCache,
                  token: jnp.ndarray, pos, *, pqc: PQKVConfig
                  ) -> Tuple[jnp.ndarray, PQKVCache]:
    """Single-token decode with the PQ-compressed cache.

    Families: dense / moe / vlm (uniform GQA blocks) and gemma2-style
    local/global alternation (PQ on both; local layers add window masking).
    SSM/hybrid have no (or tiny) KV caches — the technique is inapplicable
    there (DESIGN.md §5).
    """
    from ..models.lm import logits_from_hidden

    fam = cfg.family
    assert fam in ("dense", "moe", "vlm"), f"PQ-KV: unsupported family {fam}"
    pos = jnp.asarray(pos, jnp.int32)
    B = token.shape[0]
    x = params.embed[token].astype(jnp.bfloat16)
    if cfg.local_global:
        x = x * jnp.bfloat16(cfg.d_model ** 0.5)
    positions = jnp.full((B, 1), pos, jnp.int32)
    cos_sin = rotary(positions, cfg.head_dim_, cfg.rope_theta)

    cache_leaves = (pq_cache.k_codes, pq_cache.k_books, pq_cache.v,
                    pq_cache.v_codes, pq_cache.v_books,
                    pq_cache.k_recent, pq_cache.v_recent)

    if cfg.local_global:
        L = cfg.n_layers
        def regroup(t):
            return (None if t is None
                    else t.reshape(L // 2, 2, *t.shape[1:]))
        leaves = tuple(regroup(t) for t in cache_leaves)

        def body(h, inp):
            blk_pair = jax.tree.map(lambda t: t, inp[0])
            lc_pair = inp[1:]
            outs = []
            for i, win in enumerate((cfg.sliding_window, 0)):
                blk = jax.tree.map(lambda t: t[i], blk_pair)
                lc = tuple(None if t is None else t[i] for t in lc_pair)
                a, lc = _pq_attn_block(blk.attn, cfg,
                                       rms_norm(h, blk.ln1, cfg.norm_eps),
                                       lc, pos, pqc=pqc, window=win,
                                       cos_sin=cos_sin)
                if blk.post_attn_ln is not None:
                    a = rms_norm(a, blk.post_attn_ln, cfg.norm_eps)
                h = h + a
                m = mlp(blk.mlp, rms_norm(h, blk.ln2, cfg.norm_eps), cfg.act)
                if blk.post_mlp_ln is not None:
                    m = rms_norm(m, blk.post_mlp_ln, cfg.norm_eps)
                h = h + m
                outs.append(lc)
            stacked = tuple(
                None if a is None else jnp.stack([a, b])
                for a, b in zip(outs[0], outs[1]))
            return h, stacked

        x, new_leaves = _scan_optional(body, x, (params.blocks,) + leaves)
        new_leaves = tuple(
            None if t is None else t.reshape(L, *t.shape[2:])
            for t in new_leaves)
    else:
        def body(h, inp):
            blk = inp[0]
            lc = inp[1:]
            a, lc = _pq_attn_block(blk.attn, cfg,
                                   rms_norm(h, blk.ln1, cfg.norm_eps),
                                   lc, pos, pqc=pqc, window=0,
                                   cos_sin=cos_sin)
            h = h + a
            if fam == "moe":
                h = h + moe(blk.moe, cfg, rms_norm(h, blk.ln2, cfg.norm_eps))
            else:
                m = mlp(blk.mlp, rms_norm(h, blk.ln2, cfg.norm_eps), cfg.act)
                if blk.post_mlp_ln is not None:
                    m = rms_norm(m, blk.post_mlp_ln, cfg.norm_eps)
                h = h + m
            return h, lc

        x, new_leaves = _scan_optional(body, x, (params.blocks,) + cache_leaves)

    new_cache = PQKVCache(k_codes=new_leaves[0], k_books=pq_cache.k_books,
                          v=new_leaves[2], v_codes=new_leaves[3],
                          v_books=pq_cache.v_books,
                          k_recent=new_leaves[5], v_recent=new_leaves[6])
    return logits_from_hidden(params, cfg, x), new_cache


def _scan_optional(body, init, xs):
    """``lax.scan`` that tolerates ``None`` leaves in ``xs``.

    ``xs[0]`` is the per-layer params pytree; the rest are cache leaves (or
    None).  ``body`` receives the full tuple per layer and must return the
    cache leaves (len(xs) - 1 items, Nones preserved positionally).
    Returns ``(carry, cache_outs)`` with Nones reinserted.
    """
    flags = tuple(x is None for x in xs)
    xs_real = tuple(x for x in xs if x is not None)

    def wrapped(c, xr):
        it = iter(xr)
        full = tuple(None if f else next(it) for f in flags)
        c, out = body(c, full)
        out_real = tuple(o for o in out if o is not None)
        return c, out_real

    c, outs_real = jax.lax.scan(wrapped, init, xs_real)
    it = iter(outs_real)
    # body's outputs correspond to the cache slots (xs[1:])
    outs = tuple(None if f else next(it) for f in flags[1:])
    return c, outs


# ---------------------------------------------------------------------------
# Memory accounting (paper §3.4, applied to the KV cache)
# ---------------------------------------------------------------------------

def pqkv_memory(cfg: ModelConfig, pqc: PQKVConfig, batch: int,
                seq_len: int) -> dict:
    """Bytes for the exact vs PQ-compressed cache (per the paper's §3.4)."""
    L, G, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_
    M, K, W = pqc.n_sub, pqc.codebook_size, pqc.recent_window
    n_vec = L * batch * seq_len * G
    exact = 2 * n_vec * hd * 2                       # k+v bf16
    code_bytes = max(1, (K - 1).bit_length() // 8 + (1 if (K - 1).bit_length() % 8 else 0))
    codes = n_vec * M * code_bytes
    k_side = codes if pqc.quantize_v else codes + n_vec * hd * 2
    v_side = codes if pqc.quantize_v else 0
    books = L * G * M * K * (hd // M) * 4 * (2 if pqc.quantize_v else 1)
    ring = 2 * L * batch * W * G * hd * 2
    total = k_side + v_side + books + ring
    return dict(exact_bytes=exact, pq_bytes=total, books_bytes=books,
                ring_bytes=ring, compression=exact / max(total, 1))
