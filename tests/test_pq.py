"""Product quantizer invariants: fit/encode/distances/memory (§3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import pq as pqm
from repro.core.dtw import dtw_cdist
from repro.core.pq import PQConfig
from repro.data.timeseries import cbf


@pytest.fixture(scope="module")
def small_pq():
    X, y = cbf(12, length=64, seed=0)  # 36 series
    cfg = PQConfig(n_sub=4, codebook_size=8, window_frac=0.15,
                   kmeans_iters=3, dba_iters=1, use_prealign=True,
                   wavelet_level=2, tail_frac=0.2, refine_frac=0.5)
    cb = pqm.fit(jax.random.PRNGKey(0), X, cfg)
    return X, y, cfg, cb


def test_codebook_shapes(small_pq):
    X, _, cfg, cb = small_pq
    S = cfg.subseq_len(X.shape[1])
    assert cb.centroids.shape == (4, 8, S)
    assert cb.lut.shape == (4, 8, 8)
    assert cb.env_upper.shape == (4, 8, S)
    assert np.isfinite(np.asarray(cb.centroids)).all()


def test_lut_is_symmetric_dtw(small_pq):
    X, _, cfg, cb = small_pq
    lut = np.asarray(cb.lut)
    assert np.allclose(lut, lut.transpose(0, 2, 1), atol=1e-4)
    assert np.allclose(np.diagonal(lut, axis1=1, axis2=2), 0.0, atol=1e-5)
    # spot-check one entry against direct DTW
    w = cfg.window(X.shape[1])
    d = dtw_cdist(cb.centroids[0], cb.centroids[0], w)
    assert np.allclose(lut[0], np.asarray(d), atol=1e-4)


def test_encode_in_range_and_deterministic(small_pq):
    X, _, cfg, cb = small_pq
    c1 = np.asarray(pqm.encode(X, cb, cfg))
    c2 = np.asarray(pqm.encode(X, cb, cfg))
    assert c1.shape == (X.shape[0], cfg.n_sub)
    assert (c1 >= 0).all() and (c1 < cfg.codebook_size).all()
    assert (c1 == c2).all()


def test_exact_encode_matches_bruteforce(small_pq):
    X, _, cfg, cb = small_pq
    cfg_exact = PQConfig(**{**cfg.__dict__, "exact_encode": True})
    codes = np.asarray(pqm.encode(X, cb, cfg_exact))
    segs = np.asarray(pqm.segment(X, cfg))
    w = cfg.window(X.shape[1])
    for n in range(0, X.shape[0], 7):
        for m in range(cfg.n_sub):
            d = np.asarray(dtw_cdist(segs[n][m][None], cb.centroids[m], w))[0]
            assert codes[n, m] == int(np.argmin(d))


def test_filter_refine_soundness_certificate(small_pq):
    """With refine_frac=0.5 on easy data most codes should be certified, and
    certified codes must equal the exact encoding."""
    X, _, cfg, cb = small_pq
    codes, sound = pqm.encode_with_stats(X, cb, cfg)
    codes, sound = np.asarray(codes), np.asarray(sound)
    cfg_exact = PQConfig(**{**cfg.__dict__, "exact_encode": True})
    exact = np.asarray(pqm.encode(X, cb, cfg_exact))
    assert (codes[sound] == exact[sound]).all()
    assert sound.mean() > 0.5


def test_sym_distance_matches_lut_sum(small_pq):
    X, _, cfg, cb = small_pq
    codes = pqm.encode(X, cb, cfg)
    D = np.asarray(pqm.cdist_sym(codes, codes, cb.lut))
    codes_np = np.asarray(codes)
    lut = np.asarray(cb.lut)
    i, j = 3, 17
    want = np.sqrt(sum(lut[m, codes_np[i, m], codes_np[j, m]]
                       for m in range(cfg.n_sub)))
    assert D[i, j] == pytest.approx(want, rel=1e-5)
    assert np.allclose(D, D.T, atol=1e-5)
    assert np.allclose(np.diag(D), 0.0, atol=1e-5)


def test_asym_le_sym_error(small_pq):
    """Asymmetric distances use the raw query, so queries identical to a
    database series should give distance <= the symmetric value."""
    X, _, cfg, cb = small_pq
    codes = pqm.encode(X, cb, cfg)
    Dس = np.asarray(pqm.cdist_sym(codes, codes, cb.lut))
    Da = np.asarray(pqm.cdist_asym(X, codes, cb, cfg))
    assert Da.shape == Dس.shape
    assert np.isfinite(Da).all()


def test_sym_refined_bounds(small_pq):
    """§4.2: refined distance equals sym where codes differ; for identical
    codes it is >= 0 and <= the true subspace DTW distance."""
    X, _, cfg, cb = small_pq
    codes = pqm.encode(X, cb, cfg)
    segs = pqm.segment(X, cfg)
    D_ref = np.asarray(pqm.cdist_sym_refined(codes, segs, codes, segs, cb))
    D_sym = np.asarray(pqm.cdist_sym(codes, codes, cb.lut))
    codes_np = np.asarray(codes)
    diff_mask = (codes_np[:, None, :] != codes_np[None, :, :]).all(-1)
    assert np.allclose(D_ref[diff_mask], D_sym[diff_mask], atol=1e-5)
    assert (D_ref >= -1e-6).all()
    # Where codes are shared the fallback is the Keogh LB of the raw subspace
    # vs the shared centroid — bounded by the true DTW to that centroid.
    from repro.core.dtw import dtw_pair
    segs_np = np.asarray(segs)
    w = cfg.window(X.shape[1])
    i = 0  # diagonal pair (i, i) shares every code
    per_sub_sq = np.asarray(D_ref[i, i]) ** 2
    true_sq = sum(float(dtw_pair(segs_np[i, m],
                                 np.asarray(cb.centroids[m, codes_np[i, m]]),
                                 w)) for m in range(cfg.n_sub))
    assert per_sub_sq <= true_sq + 1e-4


def test_memory_cost_formula():
    cfg = PQConfig(n_sub=7, codebook_size=256, use_prealign=False)
    mc = pqm.memory_cost(cfg, D=140, n_series=1_000_000)
    # paper §3.4: 140-long series -> 80x compression with 7 subspaces,
    # and ~2.3MB of auxiliary structures for D=140, K=256, M=7.
    assert mc["compression"] == pytest.approx(80.0)
    assert mc["code_bytes"] == 7 * 1_000_000
    assert mc["aux_bytes"] == pytest.approx(2.3e6, rel=0.05)
    assert mc["aux_bytes"] < 0.01 * mc["raw_bytes"]


def test_euclidean_metric_variant():
    X, y = cbf(8, length=64, seed=1)
    cfg = PQConfig(n_sub=4, codebook_size=8, metric="euclidean",
                   kmeans_iters=5, use_prealign=False)
    cb = pqm.fit(jax.random.PRNGKey(1), X, cfg)
    codes = np.asarray(pqm.encode(X, cb, cfg))
    assert codes.shape == (X.shape[0], 4)
    segs = np.asarray(pqm.segment(X, cfg))
    # exactness: euclidean encoding is always exact argmin
    for n in range(0, X.shape[0], 5):
        d = ((np.asarray(cb.centroids[2]) - segs[n, 2][None]) ** 2).sum(-1)
        assert codes[n, 2] == int(np.argmin(d))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_property_sym_distance_triangle_of_zero(seed):
    """Series mapping to identical codes have symmetric distance exactly 0."""
    rng = np.random.default_rng(seed)
    lut = np.abs(rng.standard_normal((3, 5, 5))).astype(np.float32)
    for m in range(3):
        np.fill_diagonal(lut[m], 0.0)
    codes = rng.integers(0, 5, (4, 3)).astype(np.int32)
    D = np.asarray(pqm.cdist_sym(jnp.asarray(codes), jnp.asarray(codes),
                                 jnp.asarray(lut)))
    assert np.allclose(np.diag(D), 0.0, atol=1e-6)
