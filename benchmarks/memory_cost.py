"""§3.4 memory cost — compression factor of PQ codes vs raw series, plus the
auxiliary structures (codebook, LUT, envelopes), including the paper's own
worked example (D=140, K=256, M=7 -> 80x, aux ~2.3MB).  Extended with the
PQ-KV serving numbers (the paper's memory argument applied to the KV cache).
"""

from __future__ import annotations

from repro.core.pq import PQConfig, memory_cost
from repro.configs.registry import get_config
from repro.serve.pqkv import PQKVConfig, pqkv_memory

from .common import Bench


def run(quick: bool = True) -> Bench:
    del quick
    b = Bench("memory_cost")

    # the paper's worked example: 140-long series, M=7, K=256 -> 80x
    cfg = PQConfig(n_sub=7, codebook_size=256, use_prealign=False)
    m = memory_cost(cfg, D=140, n_series=10_000)
    b.add(case="paper_example_D140_M7_K256",
          compression=m["compression"],
          aux_mb=m["aux_bytes"] / 1e6,
          code_bytes_per_series=m["code_bytes"] / 10_000)

    for D, M, K in ((256, 8, 256), (512, 8, 256), (1024, 16, 256),
                    (4096, 32, 256)):
        cfg = PQConfig(n_sub=M, codebook_size=K, use_prealign=False)
        m = memory_cost(cfg, D=D, n_series=100_000)
        b.add(case=f"D{D}_M{M}_K{K}", compression=m["compression"],
              aux_mb=m["aux_bytes"] / 1e6,
              code_bytes_per_series=m["code_bytes"] / 100_000)

    # PQ-KV: the same accounting on LM KV caches (full configs, pure math)
    for arch, B, S in (("qwen2-72b", 128, 32768),
                       ("gemma2-27b", 128, 32768),
                       ("internlm2-1.8b", 128, 32768)):
        mc = get_config(arch)
        for qv in (False, True):
            pq = PQKVConfig(n_sub=8, codebook_size=256, recent_window=128,
                            quantize_v=qv)
            m = pqkv_memory(mc, pq, batch=B, seq_len=S)
            b.add(case=f"pqkv_{arch}{'_qv' if qv else ''}",
                  compression=m["compression"],
                  exact_gb=m["exact_bytes"] / 1e9,
                  pq_gb=m["pq_bytes"] / 1e9)
    b.save()
    return b


if __name__ == "__main__":
    run()
