"""Segment containers for the streaming index.

Two kinds of segment, one searchable contract:

* :class:`HotBuffer` — host-side fixed-capacity staging area for raw
  series.  Inserts are numpy writes into pre-allocated buffers; the search
  path uploads the (small, constant-shape) buffers and runs exact banded
  DTW against every live row.
* :class:`SealedSegment` — an immutable device-resident inverted-list
  shard of PQ codes sharing the index-wide codebook.  Registered as a
  pytree with the shard geometry as *static* metadata, so jitted search
  caches on segment shape, not segment identity: every flush-born segment
  is padded to the same per-shard width and reuses one compiled fine
  stage.

Partitioned layout (``n_shards > 1``): rows are ordered *shard-major* —
all lists placed on shard 0 (list-sorted), padding to ``shard_cap``, then
shard 1's lists, and so on — so shard ``s`` owns exactly the contiguous
row block ``[s * shard_cap, (s + 1) * shard_cap)`` and the whole segment
can be resharded across a device mesh by reshaping to ``(n_shards,
shard_cap, ...)``.  Because a list lives wholly on one shard
(:mod:`repro.index.placement`), every inverted list remains a contiguous
run and ``list_start`` / ``list_len`` keep working unchanged for the
single-device plan; the layout costs only per-shard padding, never a
second copy.  ``n_shards == 1`` reproduces the historical plain
list-sorted layout exactly.

Row padding convention: dead rows carry ``ids == -1``, ``live == False``
and ``assign == n_lists`` (sorted past every real list, so no inverted
list ever addresses them — the ``live`` mask is a second line of defense).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ivf import build_lists
from .placement import placement_loads, plan_placement

__all__ = ["HotBuffer", "SealedSegment", "seal"]


@partial(jax.tree_util.register_dataclass,
         data_fields=("codes", "ids", "live", "assign", "list_start",
                      "list_len", "placement"),
         meta_fields=("max_list", "n_shards", "shard_cap"))
@dataclasses.dataclass(frozen=True)
class SealedSegment:
    codes: jnp.ndarray        # (n_shards*shard_cap, M) int32, shard-major
    ids: jnp.ndarray          # (rows,) int32 external ids, -1 = padding
    live: jnp.ndarray         # (rows,) bool, False = deleted or padding
    assign: jnp.ndarray       # (rows,) int32 coarse list id, n_lists = pad
    list_start: jnp.ndarray   # (n_lists,) int32
    list_len: jnp.ndarray     # (n_lists,) int32
    placement: jnp.ndarray    # (n_lists,) int32 shard id of each list
    max_list: int             # static: candidate width of the fine stage
    n_shards: int             # static: data-partition count of the layout
    shard_cap: int            # static: padded rows per shard block

    @property
    def rows(self) -> int:
        return self.codes.shape[0]

    @property
    def n_lists(self) -> int:
        return self.list_start.shape[0]

    def n_live(self) -> int:
        return int(jnp.sum(self.live))

    def tombstone(self, dead: np.ndarray) -> "SealedSegment":
        """New segment with ``dead`` (host bool mask over rows) deleted."""
        live = self.live & ~jnp.asarray(dead)
        return dataclasses.replace(self, live=live)

    def shard_views(self) -> Tuple[jnp.ndarray, ...]:
        """Per-shard arrays for the list-sharded planner.

        Returns ``(codes (n_shards, shard_cap, M), ids, live
        (n_shards, shard_cap), loc_start, loc_len (n_shards, n_lists))``
        where the local list tables address rows *within* a shard block
        (lists placed elsewhere have length 0) — sharding the leading axis
        over a mesh gives every device exactly its locally-placed lists.
        """
        n, cap = self.n_shards, self.shard_cap
        M = self.codes.shape[1]
        sh = jnp.arange(n, dtype=jnp.int32)[:, None]
        own = self.placement[None, :] == sh
        loc_start = jnp.where(own, self.list_start[None, :] - sh * cap,
                              0).astype(jnp.int32)
        loc_len = jnp.where(own, self.list_len[None, :], 0).astype(jnp.int32)
        return (self.codes.reshape(n, cap, M), self.ids.reshape(n, cap),
                self.live.reshape(n, cap), loc_start, loc_len)


def seal(codes: np.ndarray, ids: np.ndarray, assign: np.ndarray,
         n_lists: int, rows: int, max_list: Optional[int] = None, *,
         n_shards: int = 1, shard_round: int = 1) -> SealedSegment:
    """Lay ``(n, M)`` codes out as a shard-major list-sorted segment.

    ``rows`` is the minimum total padded size (flush-born segments pass
    the hot capacity so every flush shares one compiled search shape);
    with ``n_shards > 1`` the total grows to ``n_shards * shard_cap``
    where ``shard_cap`` covers the heaviest shard of a fresh
    occupancy-aware placement (:func:`plan_placement`), rounded up to a
    multiple of ``shard_round`` — flush callers round to ``ceil(rows /
    n_shards)`` to bound the number of distinct compiled fine-stage
    shapes, compaction keeps the exact (tightest) width.

    ``max_list`` is the static fine-stage width; it defaults to the true
    longest list.  Flush-born segments pass ``rows == max_list == hot
    capacity`` instead (same compiled search for every segment regardless
    of list skew); compaction takes the default so the merged shard prunes
    with its true longest list.
    """
    n = len(ids)
    if n > rows:
        raise ValueError(f"cannot seal {n} rows into a {rows}-row segment")
    if shard_round < 1:
        raise ValueError(f"shard_round={shard_round} must be >= 1")
    order, start0, length, true_max = build_lists(assign, n_lists)
    if max_list is None:
        max_list = true_max
    placement = plan_placement(length, n_shards)
    loads = placement_loads(placement, length, n_shards)
    base = -(-rows // n_shards) if rows else 1
    shard_cap = max(1, base,
                    -(-int(max(loads.max(initial=0), 1)) // shard_round)
                    * shard_round)
    total = n_shards * shard_cap

    # Exclusive running offset of each list inside the shard-major layout:
    # lists grouped by (shard, list id), each shard block based at
    # s * shard_cap.
    ordL = np.lexsort((np.arange(n_lists), placement))
    lens = length[ordL].astype(np.int64)
    shard_of = placement[ordL]
    run = np.cumsum(lens) - lens                     # grouped exclusive sum
    first = np.searchsorted(shard_of, np.arange(n_shards))
    shard_base = np.where(first < n_lists, run[np.minimum(first,
                                                          n_lists - 1)], 0)
    new_start = np.empty(n_lists, np.int64)
    new_start[ordL] = (run - shard_base[shard_of]
                       + shard_of.astype(np.int64) * shard_cap)
    new_start = new_start.astype(np.int32)

    M = codes.shape[1]
    codes_p = np.zeros((total, M), np.int32)
    ids_p = np.full((total,), -1, np.int32)
    live_p = np.zeros((total,), bool)
    assign_p = np.full((total,), n_lists, np.int32)
    if n:
        sorted_assign = np.asarray(assign)[order]
        dest = new_start[sorted_assign] + (np.arange(n, dtype=np.int64)
                                           - start0[sorted_assign])
        codes_p[dest] = codes[order]
        ids_p[dest] = ids[order]
        live_p[dest] = True
        assign_p[dest] = sorted_assign
    return SealedSegment(
        codes=jnp.asarray(codes_p), ids=jnp.asarray(ids_p),
        live=jnp.asarray(live_p), assign=jnp.asarray(assign_p),
        list_start=jnp.asarray(new_start), list_len=jnp.asarray(length),
        placement=jnp.asarray(placement),
        max_list=int(max_list), n_shards=int(n_shards),
        shard_cap=int(shard_cap))


class HotBuffer:
    """Fixed-capacity staging buffer for raw series (host-side, mutable)."""

    def __init__(self, capacity: int, dim: int):
        self.capacity = int(capacity)
        self.dim = int(dim)
        self.data = np.zeros((capacity, dim), np.float32)
        self.ids = np.full((capacity,), -1, np.int32)
        self.live = np.zeros((capacity,), bool)
        self.count = 0                      # filled slots (live or dead)

    @property
    def space(self) -> int:
        return self.capacity - self.count

    def n_live(self) -> int:
        return int(self.live.sum())

    def append(self, X: np.ndarray, ids: np.ndarray) -> int:
        """Write up to ``space`` rows; returns how many were taken."""
        take = min(self.space, len(ids))
        if take:
            lo = self.count
            self.data[lo:lo + take] = X[:take]
            self.ids[lo:lo + take] = ids[:take]
            self.live[lo:lo + take] = True
            self.count += take
        return take

    def tombstone(self, dead_ids: np.ndarray) -> int:
        hit = np.isin(self.ids, dead_ids) & self.live
        self.live &= ~hit
        return int(hit.sum())

    def take_live(self) -> Tuple[np.ndarray, np.ndarray]:
        """Drain: return (live rows, their ids) and reset the buffer."""
        rows = self.data[self.live].copy()
        ids = self.ids[self.live].copy()
        self.ids[:] = -1
        self.live[:] = False
        self.count = 0
        return rows, ids
