import os

import numpy as np
import pytest


def pytest_sessionfinish(session, exitstatus):
    """Dump the process-lifetime metrics snapshot when asked.

    CI sets ``REPRO_ROUTING_DUMP`` and, after the test run, feeds the file
    to ``scripts/check_routing.py`` — which fails the build if any elastic
    op silently fell back off the expected backend, or (with REPRO_OBS=1)
    if any instrumented pipeline stage recorded zero spans.  The snapshot's
    ``dispatch_total`` counters mirror ``dispatch.totals`` (not ``stats``,
    which per-test fixtures reset); they are ``persistent`` in the
    registry, so an ``obs.reset()`` in a test can't erase them either.
    """
    path = os.environ.get("REPRO_ROUTING_DUMP")
    if not path:
        return
    from repro import obs
    obs.write_snapshot(path)


def dtw_reference(a: np.ndarray, b: np.ndarray, window=None) -> float:
    """O(L^2) numpy oracle for squared DTW with optional Sakoe-Chiba band."""
    n, m = len(a), len(b)
    w = max(n, m) if window is None else int(window)
    D = np.full((n + 1, m + 1), np.inf)
    D[0, 0] = 0.0
    for i in range(1, n + 1):
        lo = max(1, i - w)
        hi = min(m, i + w)
        for j in range(lo, hi + 1):
            cost = (a[i - 1] - b[j - 1]) ** 2
            D[i, j] = cost + min(D[i - 1, j - 1], D[i, j - 1], D[i - 1, j])
    return float(D[n, m])


@pytest.fixture
def dtw_ref():
    return dtw_reference
