"""PQDTW core — the paper's contribution as a composable JAX library.

Public API:
    dispatch    — unified elastic-kernel dispatch (Pallas on TPU, pure-JAX
                  fallback; $REPRO_ELASTIC_BACKEND / set_backend override)
    measures    — pluggable elastic-measure registry (dtw/wdtw/erp/msm)
    dtw         — wavefront (banded) elastic-distance primitives
    lb          — Keogh envelopes + lower bounds
    lb_search   — batched LB-cascade filter-and-refine top-k search
    modwt       — MODWT pre-alignment (§3.5)
    dba/kmeans  — DBA barycenters and DBA k-means codebook learning
    pq          — PQConfig / fit / encode / symmetric & asymmetric distances
    knn         — 1-NN with PQ approximates + exact NN-DTW
    cluster     — agglomerative hierarchical clustering
    baselines   — ED / cDTW / SBD / SAX comparators
"""

from .pq import (PQConfig, PQCodebook, fit, encode, encode_with_stats,
                 cdist_sym, cdist_asym, cdist_sym_refined, segment,
                 memory_cost, query_lut, query_lut_batch,
                 uses_fused_prealign)
from .dtw import dtw, dtw_pair, dtw_batch, dtw_cdist
from .dispatch import (elastic_pairwise, elastic_cdist, adc_cdist,
                       adc_lookup, prealign_encode, lb_refine, get_backend,
                       set_backend, use_backend, effective_window)
from .measures import (MeasureSpec, register_measure, get_measure,
                       resolve as resolve_measure, available as
                       available_measures, registry_rows)
from .lb import keogh_envelope, lb_keogh, lb_kim, lb_cascade, lb_lut
from .lb_search import filtered_topk
from .modwt import prealign, fixed_segments, modwt_scale
from .dba import dba, dba_update, alignment_path
from .kmeans import dba_kmeans, euclidean_kmeans
from .knn import (knn_classify_sym, knn_classify_asym, nn_dtw_exact,
                  nn_dtw_pruned)
from .cluster import linkage, cut_k, hierarchical_labels
from .metrics import rand_index, adjusted_rand_index, error_rate

__all__ = [
    "PQConfig", "PQCodebook", "fit", "encode", "encode_with_stats",
    "cdist_sym", "cdist_asym", "cdist_sym_refined", "segment", "memory_cost",
    "query_lut", "query_lut_batch",
    "dtw", "dtw_pair", "dtw_batch", "dtw_cdist", "uses_fused_prealign",
    "elastic_pairwise", "elastic_cdist", "adc_cdist", "adc_lookup",
    "prealign_encode", "lb_refine", "get_backend", "set_backend",
    "use_backend", "effective_window",
    "MeasureSpec", "register_measure", "get_measure", "resolve_measure",
    "available_measures", "registry_rows",
    "keogh_envelope", "lb_keogh", "lb_kim", "lb_cascade", "lb_lut",
    "filtered_topk",
    "prealign", "fixed_segments", "modwt_scale",
    "dba", "dba_update", "alignment_path",
    "dba_kmeans", "euclidean_kmeans",
    "knn_classify_sym", "knn_classify_asym", "nn_dtw_exact", "nn_dtw_pruned",
    "linkage", "cut_k", "hierarchical_labels",
    "rand_index", "adjusted_rand_index", "error_rate",
]
