"""Optional-hypothesis shim.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt /
pyproject's ``[dev]`` extra).  Test modules that mix plain pytest tests
with property-based ones import ``given`` / ``settings`` / ``st`` from
here: when hypothesis is installed they are the real thing; when it is
not, ``@given`` replaces the test with a cleanly-skipped stub so the rest
of the module still runs.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Placeholder for ``hypothesis.strategies``: every attribute is a
        callable returning None, so module-level ``st.integers(...)`` in
        decorator position evaluates without the real package."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

__all__ = ["given", "settings", "st", "HAS_HYPOTHESIS"]
