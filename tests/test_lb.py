"""Lower bounds must never exceed true (squared, banded) DTW."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import dispatch
from repro.core.dtw import dtw_pair
from repro.core.lb import keogh_envelope, lb_keogh, lb_kim, lb_cascade


def test_envelope_contains_series():
    x = np.random.default_rng(0).standard_normal(64).astype(np.float32)
    up, lo = keogh_envelope(x, window=5)
    assert np.all(np.asarray(up) >= x - 1e-6)
    assert np.all(np.asarray(lo) <= x + 1e-6)


def test_envelope_batched():
    X = np.random.default_rng(1).standard_normal((7, 32)).astype(np.float32)
    up, lo = keogh_envelope(X, window=3)
    assert up.shape == X.shape and lo.shape == X.shape
    u0, l0 = keogh_envelope(X[0], window=3)
    assert np.allclose(np.asarray(up[0]), np.asarray(u0))


def test_envelope_window_zero_is_identity():
    x = np.random.default_rng(2).standard_normal(16).astype(np.float32)
    up, lo = keogh_envelope(x, window=0)
    assert np.allclose(np.asarray(up), x) and np.allclose(np.asarray(lo), x)


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 40), st.integers(1, 8), st.integers(0, 10_000))
def test_lb_keogh_is_lower_bound(L, w, seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal(L).astype(np.float32)
    c = rng.standard_normal(L).astype(np.float32)
    w = min(w, L - 1)
    up, lo = keogh_envelope(c, window=w)
    bound = float(lb_keogh(jnp.asarray(q), up, lo))
    true = float(dtw_pair(q, c, window=w))
    assert bound <= true + 1e-4


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 40), st.integers(0, 10_000))
def test_lb_kim_is_lower_bound(L, seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal(L).astype(np.float32)
    c = rng.standard_normal(L).astype(np.float32)
    assert float(lb_kim(q, c)) <= float(dtw_pair(q, c)) + 1e-4


def test_cascade_le_banded_dtw():
    rng = np.random.default_rng(7)
    q = rng.standard_normal(32).astype(np.float32)
    C = rng.standard_normal((16, 32)).astype(np.float32)
    w = 4
    up, lo = keogh_envelope(C, window=w)
    bounds = np.asarray(lb_cascade(jnp.asarray(q), C, up, lo))
    for k in range(16):
        assert bounds[k] <= float(dtw_pair(q, C[k], window=w)) + 1e-4


# ---------------------------------------------------------------------------
# rolling-extrema envelope (O(L log w) doubling vs the shift-stack oracle)
# ---------------------------------------------------------------------------

def _envelope_oracle(x: np.ndarray, w: int):
    """The old O(L * window) shift-stack construction, kept as the oracle."""
    L = x.shape[-1]
    his, los = [], []
    for s in range(-w, w + 1):
        rolled = np.roll(x, s, axis=-1)
        i = np.arange(L)
        valid = (i - s >= 0) & (i - s < L)
        his.append(np.where(valid, rolled, -np.inf))
        los.append(np.where(valid, rolled, np.inf))
    return np.max(his, axis=0), np.min(los, axis=0)


@pytest.mark.parametrize("L", [1, 2, 3, 7, 16, 33, 64])
@pytest.mark.parametrize("rel_w", [0, 1, 2, "L-1", "L", "2L"])
def test_envelope_matches_shift_stack_oracle(L, rel_w):
    w = {"L-1": L - 1, "L": L, "2L": 2 * L}.get(rel_w, rel_w)
    if isinstance(w, int) and w < 0:
        pytest.skip("negative window")
    rng = np.random.default_rng(L * 19 + 1)
    x = rng.standard_normal((4, L)).astype(np.float32)
    want_up, want_lo = _envelope_oracle(x, int(w))
    up, lo = keogh_envelope(x, int(w))
    np.testing.assert_allclose(np.asarray(up), want_up)
    np.testing.assert_allclose(np.asarray(lo), want_lo)


def test_envelope_long_series_full_window():
    """Regression: ``window >= L`` on a long series must not materialize an
    O(L^2) shift stack (the old construction needed ~(2L+1, L) floats —
    gigabytes at this length).  With a full-width window every truncated
    window spans the whole series, so the envelope is flat."""
    L = 1 << 15                                    # 32768
    rng = np.random.default_rng(0)
    x = rng.standard_normal(L).astype(np.float32)
    up, lo = keogh_envelope(x, window=L)           # old nn_dtw_pruned default
    assert np.allclose(np.asarray(up), x.max())
    assert np.allclose(np.asarray(lo), x.min())


# ---------------------------------------------------------------------------
# fused-kernel filter bound + batched search equivalence
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(4, 32), st.integers(1, 6), st.integers(0, 10_000))
def test_lb_refine_filter_is_lower_bound(L, w, seed):
    """The fused kernel's unrefined outputs are valid lower bounds and its
    refined outputs are the exact squared banded DTW, on both backends."""
    rng = np.random.default_rng(seed)
    n = 6
    A = rng.standard_normal((n, L)).astype(np.float32)
    B = rng.standard_normal((n, L)).astype(np.float32)
    w = min(w, L - 1)
    up, lo = keogh_envelope(A, window=w)
    true = np.array([float(dtw_pair(A[i], B[i], window=w))
                     for i in range(n)])
    thresh = np.asarray(rng.uniform(0, true.max() + 1.0, n), np.float32)
    for backend in ("jax", "pallas_interpret"):
        with dispatch.use_backend(backend):
            d, refined = dispatch.lb_refine(A, B, np.asarray(up),
                                            np.asarray(lo), thresh, w)
        d, refined = np.asarray(d), np.asarray(refined)
        assert (d <= true + 1e-3).all()               # always a lower bound
        np.testing.assert_allclose(d[refined], true[refined], rtol=1e-4,
                                   atol=1e-4)         # refined => exact


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_nn_dtw_pruned_matches_legacy_and_exact(backend):
    """Batched rewrite == legacy host loop == exact NN-DTW predictions."""
    from repro.core.knn import (nn_dtw_exact, nn_dtw_pruned,
                                nn_dtw_pruned_host)
    rng = np.random.default_rng(4)
    X = np.cumsum(rng.standard_normal((48, 40)), 1).astype(np.float32)
    Q = np.cumsum(rng.standard_normal((9, 40)), 1).astype(np.float32)
    labels = rng.integers(0, 4, 48)
    for window in (None, 4):
        with dispatch.use_backend(backend):
            jax.clear_caches()
            exact = np.asarray(nn_dtw_exact(
                jnp.asarray(X), jnp.asarray(labels), jnp.asarray(Q),
                window=window))
            new, frac_new = nn_dtw_pruned(X, labels, Q, window=window)
            old, frac_old = nn_dtw_pruned_host(X, labels, Q, window=window)
        np.testing.assert_array_equal(new, exact)
        np.testing.assert_array_equal(old, exact)
        assert 0.0 <= frac_new <= 1.0 and 0.0 <= frac_old <= 1.0
