"""Closed-loop serving benchmark: sustained mixed traffic through the
coalescing :class:`~repro.serve_index.IndexServer`.

Unlike every earlier suite (one-shot operation latency under ``timeit``),
this drives the server the way production traffic would: concurrent
client threads submit small search requests in a closed loop while an
ingest thread inserts/deletes/compacts through the bounded write queue,
for a fixed wall-clock duration.  Reported per scenario:

* achieved QPS (completed queries / wall time) and per-request p50/p99
  latency — including coalescing wait, so the numbers are end-to-end;
* write throughput, shed count, view swaps, and the mean coalesced batch
  size (from the serving obs counters).
"""

from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro import obs
from repro.core.pq import PQConfig
from repro.data.timeseries import random_walks
from repro.index import IndexConfig, StreamingIndex
from repro.serve_index import Backpressure, IndexServer, ServeConfig

from . import common
from .common import Bench


def _build(n_rows: int, dim: int, n_lists: int, hot_capacity: int
           ) -> StreamingIndex:
    cfg = IndexConfig(
        pq=PQConfig(n_sub=4, codebook_size=32, use_prealign=False,
                    **common.measure_config_fields(),
                    kmeans_iters=3, dba_iters=1),
        n_lists=n_lists, hot_capacity=hot_capacity, coarse_iters=4)
    index = StreamingIndex.bootstrap(
        jax.random.PRNGKey(0), random_walks(min(n_rows, 512), dim, seed=0),
        cfg)
    index.insert(random_walks(n_rows, dim, seed=1))
    index.compact()
    return index


def _counter_value(name: str, **labels) -> int:
    return obs.counter(name, persistent=True, **labels).value


def _batches_total() -> int:
    from repro.obs import export
    snap = export.snapshot()
    return sum(c["value"] for c in snap["counters"]
               if c["name"] == "serving_batches_total")


def _drive(srv: IndexServer, Q: np.ndarray, dim: int, duration_s: float,
           n_clients: int, ingest: bool) -> dict:
    """Run the closed loop for ``duration_s``; returns the scenario row."""
    deadline = time.monotonic() + duration_s
    lock = threading.Lock()
    latencies: list = []
    totals = {"queries": 0, "inserted": 0, "deleted": 0, "shed": 0}
    q0 = _counter_value("serving_queries_total")
    b0 = _batches_total()

    def client(seed: int) -> None:
        rng = np.random.default_rng(seed)
        mine, done = [], 0
        while time.monotonic() < deadline:
            n = int(rng.integers(1, 5))
            q = Q[rng.integers(0, len(Q), size=n)]
            t0 = time.perf_counter()
            srv.submit_search(q).result()
            mine.append(time.perf_counter() - t0)
            done += n
        with lock:
            latencies.extend(mine)
            totals["queries"] += done

    def ingester() -> None:
        rng = np.random.default_rng(4242)
        resident: list = []
        it = 0
        while time.monotonic() < deadline:
            it += 1
            try:
                if resident and rng.random() < 0.35:
                    k = min(8, len(resident))
                    victims, resident[:k] = resident[:k], []
                    srv.delete(victims).result()
                    totals["deleted"] += k
                else:
                    ids = srv.insert(
                        rng.standard_normal((8, dim)).astype(np.float32)
                    ).result()
                    resident.extend(int(i) for i in ids)
                    totals["inserted"] += len(ids)
                if it % 32 == 0:
                    srv.compact().result()
            except Backpressure:
                totals["shed"] += 1
                time.sleep(0.001)

    threads = [threading.Thread(target=client, args=(s,))
               for s in range(n_clients)]
    if ingest:
        threads.append(threading.Thread(target=ingester))
    t_start = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.monotonic() - t_start
    srv.quiesce()

    n_batches = _batches_total() - b0
    n_batched = _counter_value("serving_queries_total") - q0
    return dict(
        wall_s=wall,
        qps=totals["queries"] / wall,
        p50_ms=1e3 * obs.percentile(latencies, 50.0),
        p99_ms=1e3 * obs.percentile(latencies, 99.0),
        requests=len(latencies),
        queries=totals["queries"],
        mean_coalesced=(n_batched / n_batches) if n_batches else 0.0,
        inserted=totals["inserted"],
        deleted=totals["deleted"],
        shed=totals["shed"],
        view_version=srv.version,
    )


def run(quick: bool = True) -> None:
    if common.SMOKE:
        n_rows, dim, duration, clients = 192, 48, 0.6, 2
    elif quick:
        n_rows, dim, duration, clients = 1024, 96, 3.0, 4
    else:
        n_rows, dim, duration, clients = 8192, 128, 10.0, 8

    prev_obs = obs.enabled()
    obs.enable()                     # the bench reads serving counters
    bench = Bench("serving_qps", root_name="serving")
    scfg = ServeConfig(n_probe=4, topk=3)
    try:
        for scenario, ingest in (("read_only", False), ("mixed", True)):
            index = _build(n_rows, dim, n_lists=8,
                           hot_capacity=max(64, dim))
            Q = random_walks(64, dim, seed=9)
            with IndexServer(index, scfg) as srv:
                # warm every bucket the traffic can coalesce into (each
                # client submits <= 4 queries), so steady state is
                # measured, not compilation
                reachable = [b for b in scfg.q_buckets
                             if b <= 4 * clients] or [scfg.q_buckets[0]]
                for n in reachable:
                    srv.submit_search(Q[:n]).result()
                row = _drive(srv, Q, dim, duration, clients, ingest)
            bench.add(scenario=scenario, n_rows=n_rows, dim=dim,
                      clients=clients, **row)
    finally:
        if not prev_obs:
            obs.disable()

    mixed = next(r for r in bench.rows if r["scenario"] == "mixed")
    bench.save(headline=dict(
        measure=common.MEASURE,
        scenario="mixed insert/query/delete, closed loop",
        duration_s=duration,
        clients=clients,
        qps=round(mixed["qps"], 1),
        p50_ms=round(mixed["p50_ms"], 3),
        p99_ms=round(mixed["p99_ms"], 3),
        shed=mixed["shed"],
    ))


if __name__ == "__main__":
    run()
