"""1-NN time-series classification with PQ over any elastic measure
(paper §4.1).

    PYTHONPATH=src python examples/nn_classification.py [--measure MEASURE]

Compares symmetric PQ, asymmetric PQ, exact elastic 1-NN, and the
LB-pruned search baseline (with its pruning statistics) on a Trace-like
dataset.  ``--measure`` takes any registered measure ("dtw", "wdtw",
"erp", "msm", optionally with params: "erp:g=0.5"); measures without a
sound LB cascade automatically use the exact dense search path.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.knn import (knn_classify_asym, knn_classify_sym,
                            nn_dtw_exact, nn_dtw_pruned)
from repro.core.pq import PQConfig, encode, fit
from repro.data.timeseries import trace_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--measure", default="dtw",
                    help="elastic measure: registry name, optionally with "
                         "params ('erp:g=0.5'); see repro.core.measures")
    args = ap.parse_args()
    from repro.core import measures
    spec = measures.resolve(args.measure)
    print(f"elastic measure: {spec.label} "
          f"(LB cascade: {'yes' if spec.can_prune else 'no — dense path'})")

    Xtr, ytr = trace_like(n_per_class=15, length=128, seed=0)
    Xte, yte = trace_like(n_per_class=10, length=128, seed=7)
    Xtr_j, Xte_j = jnp.asarray(Xtr), jnp.asarray(Xte)
    window = int(0.1 * Xtr.shape[1])
    print(f"train {Xtr.shape}, test {Xte.shape}, classes "
          f"{len(np.unique(ytr))}")

    cfg = PQConfig(n_sub=4, codebook_size=min(32, len(Xtr)),
                   metric=spec.name, measure_params=spec.params,
                   use_prealign=True, kmeans_iters=5)
    t0 = time.time()
    cb = fit(jax.random.PRNGKey(0), Xtr_j, cfg)
    tr_codes = encode(Xtr_j, cb, cfg)
    jax.block_until_ready(tr_codes)
    print(f"PQ train+encode: {time.time() - t0:.2f}s (one-time)")

    runs = {}
    t0 = time.time()
    pred = knn_classify_sym(tr_codes, jnp.asarray(ytr), Xte_j, cb, cfg)
    runs["PQ sym"] = (np.asarray(pred), time.time() - t0)

    t0 = time.time()
    pred = knn_classify_asym(tr_codes, jnp.asarray(ytr), Xte_j, cb, cfg)
    runs["PQ asym"] = (np.asarray(pred), time.time() - t0)

    t0 = time.time()
    pred = nn_dtw_exact(Xtr_j, jnp.asarray(ytr), Xte_j, window, spec)
    runs["NN exact"] = (np.asarray(pred), time.time() - t0)

    t0 = time.time()
    pred, pruned = nn_dtw_pruned(Xtr, ytr, Xte, window, measure=spec)
    runs["NN LB-pruned"] = (pred, time.time() - t0)
    print(f"LB cascade pruned {pruned:.1%} of exact distance computations")

    print(f"\n{'method':20s} {'accuracy':>9s} {'seconds':>9s}")
    for name, (pred, sec) in runs.items():
        acc = float((pred == yte).mean())
        print(f"{name:20s} {acc:9.2%} {sec:9.3f}")


if __name__ == "__main__":
    main()
