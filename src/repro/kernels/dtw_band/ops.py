"""Jitted public wrappers for the banded elastic-measure Pallas kernels."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ...core import measures
from .. import tune
from ..common import default_interpret, pad_to
from .kernel import MeasureArg, make_dtw_band_call, make_dtw_band_cdist_call

__all__ = ["dtw_band", "dtw_band_cdist"]


def _default_lane() -> int:
    """Lane multiple for the compressed register width: full 128-lane tiles
    on real TPU hardware, small tiles under interpret/CPU so tests stay
    cheap and the band compression is visible at short lengths."""
    return 128 if jax.default_backend() == "tpu" else 8


def _backend_name(interpret: bool) -> str:
    return "pallas_interpret" if interpret else "pallas"


def _tuned_block(op: str, block: Optional[int], *, length: int,
                 window: Optional[int], measure: MeasureArg,
                 interpret: bool, param: str = "block",
                 default: int = 8) -> int:
    """``block=None`` consults the tuning table (a trace-time Python
    resolution — the result is a static launch parameter); an explicit
    block always wins."""
    if block is not None:
        return block
    return tune.tuned(op, param, length=length, window=window,
                      measure=measures.resolve(measure).name,
                      backend=_backend_name(interpret), default=default)


@functools.partial(jax.jit,
                   static_argnames=("window", "block", "interpret", "mode",
                                    "lane", "measure", "width"))
def dtw_band(A: jnp.ndarray, B: jnp.ndarray, window: Optional[int] = None,
             block: Optional[int] = None, interpret: Optional[bool] = None,
             mode: str = "compressed",
             lane: Optional[int] = None,
             measure: MeasureArg = None,
             corridor: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
             width: Optional[int] = None) -> jnp.ndarray:
    """Banded elastic cost over zipped pairs: ``A (N, L)``, ``B (N, L)`` ->
    ``(N,)`` (squared banded DTW under the default measure).

    ``mode="compressed"`` (default) runs the band-compressed wavefront whose
    per-step cost scales with the Sakoe-Chiba band; ``mode="full"`` runs the
    legacy full-width sweep (kept as the DTW-only benchmark baseline).
    ``measure`` selects any registered elastic measure (static).

    ``corridor=(lo, hi)`` (``(N, 2L-1)`` int32 envelopes from
    :mod:`repro.core.corridor`) switches to the adaptive per-pair band
    sweep; ``width`` caps its registers (default: the tuned adaptive
    width for this geometry).  ``block=None`` consults the
    :mod:`repro.kernels.tune` table for the launch block.
    """
    if interpret is None:
        interpret = default_interpret()
    if lane is None:
        lane = _default_lane()
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    n, L = A.shape
    if corridor is not None:
        mode = "adaptive"
        if width is None:
            width = tune.adaptive_width(
                L, window, lane, measure=measures.resolve(measure).name,
                backend=_backend_name(interpret))
    block = _tuned_block("dtw_band", block, length=L, window=window,
                         measure=measure, interpret=interpret)
    Ap = pad_to(A, block, axis=0)
    Bp = pad_to(B, block, axis=0)
    call = make_dtw_band_call(Ap.shape[0], L, window, block, interpret,
                              mode=mode, lane=lane, measure=measure,
                              width=width)
    if corridor is not None:
        lo, hi = corridor
        out = call(Ap, Bp, pad_to(lo.astype(jnp.int32), block, axis=0),
                   pad_to(hi.astype(jnp.int32), block, axis=0))
    else:
        out = call(Ap, Bp)
    return out[:n, 0]


@functools.partial(jax.jit,
                   static_argnames=("window", "block", "interpret", "lane",
                                    "measure"))
def dtw_band_cdist(A: jnp.ndarray, B: jnp.ndarray,
                   window: Optional[int] = None, block: Optional[int] = None,
                   interpret: Optional[bool] = None,
                   lane: Optional[int] = None,
                   measure: MeasureArg = None) -> jnp.ndarray:
    """All-pairs banded elastic cost: ``A (N, L)``, ``B (M, L)`` -> ``(N, M)``.

    Runs the band-compressed kernel on a 2-D grid (A row-blocks x B rows);
    the N*M cross-product is never materialized — B rows are broadcast
    inside the kernel tile.  ``block=None`` consults the tuning table
    (``block_a``).
    """
    if interpret is None:
        interpret = default_interpret()
    if lane is None:
        lane = _default_lane()
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    N, L = A.shape
    M = B.shape[0]
    block = _tuned_block("dtw_band_cdist", block, length=L, window=window,
                         measure=measure, interpret=interpret,
                         param="block_a")
    Ap = pad_to(A, block, axis=0)
    call = make_dtw_band_cdist_call(Ap.shape[0], M, L, window, block,
                                    interpret, lane=lane, measure=measure)
    return call(Ap, B)[:N]
