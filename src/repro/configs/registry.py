"""Architecture registry: ``--arch <id>`` -> (full config, reduced config).

Shape sets (assignment): every LM arch is paired with
    train_4k      seq 4096,   batch 256   (train_step)
    prefill_32k   seq 32768,  batch 32    (prefill forward)
    decode_32k    seq 32768,  batch 128   (serve_step, KV cache 32k)
    long_500k     seq 524288, batch 1     (serve_step; SSM/hybrid only)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.models.config import ModelConfig

from . import (deepseek_moe_16b, gemma2_27b, internlm2_1_8b, mamba2_780m,
               minitron_8b, qwen2_72b, qwen2_vl_72b, qwen3_moe_30b_a3b,
               seamless_m4t_large_v2, zamba2_2_7b)

_MODULES = {
    "qwen2-72b": qwen2_72b,
    "gemma2-27b": gemma2_27b,
    "minitron-8b": minitron_8b,
    "internlm2-1.8b": internlm2_1_8b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "zamba2-2.7b": zamba2_2_7b,
    "qwen2-vl-72b": qwen2_vl_72b,
    "mamba2-780m": mamba2_780m,
}

ARCH_IDS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _MODULES[arch].REDUCED


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Which (arch x shape) cells run (skips recorded in DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k context needs "
                       "sub-quadratic attention (DESIGN.md §5)")
    return True, ""


def all_cells():
    """All 40 (arch, shape) cells with applicability flags."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            yield arch, shape, ok, why
