"""jnp reference for the goodk kernel."""

import jax.numpy as jnp


def run_goodk_ref(x):
    return jnp.multiply(x, 2)
