"""Banded elastic-kernel throughput: pure-JAX scan vs full-width Pallas vs
band-compressed Pallas, at several ``(L, window, batch)`` points — plus a
per-measure sweep of the measure-generic band-compressed wavefront.

The band-compressed wavefront keeps the sequential depth at ``2L-1`` but
shrinks every step from ``L`` lanes to ``~window+1`` lanes, so at the
paper's default ``window_frac = 0.1`` it should approach a ``~L/(w+1)``-x
reduction in per-step VPU work over the full-width sweep.  The measure
sweep runs the same kernel under every registered elastic measure (the
recurrence step is the only thing that changes) and checks the DTW path's
throughput is unaffected by the measure-generic refactor.

Results go to ``experiments/bench/dtw_kernel.json`` (the shared Bench dir)
AND to a top-level ``BENCH_dtw_kernel.json`` summary with the headline
band-vs-full speedups — both written by ``benchmarks.common.Bench`` (the
single JSON writer).  Run with ``python -m benchmarks.dtw_kernel_bench``
or via ``python -m benchmarks.run --only dtw_kernel``.
"""

from __future__ import annotations

import numpy as np

from repro.core import corridor as corridor_mod
from repro.core import measures
from repro.core.dtw import dtw_batch
from repro.kernels import tune
from repro.kernels.common import default_interpret
from repro.kernels.dtw_band.ops import dtw_band

from .common import Bench, timeit

WINDOW_FRAC = 0.1

# Adaptive-corridor geometry for the long-series rows: a coarser grid
# (factor 16) keeps the corridor-build pass cheap at these lengths, and
# the wider safety radius keeps warped pairs certified (corridor contains
# the static optimal path -> bit-identical distances).
ADAPTIVE_FACTOR = 16
ADAPTIVE_RADIUS = 6


def _points(quick: bool):
    # (length, batch) — windows derive from WINDOW_FRAC
    if quick:
        return ((128, 64), (256, 64), (512, 32))
    return ((128, 256), (256, 256), (512, 128), (1024, 64), (2048, 32))


def _adaptive_points(quick: bool):
    # long-series rows where the per-pair corridor register (bounded by
    # the coarse projection width) is several lanes narrower than the
    # static band register — short series keep the static band
    if quick:
        return ((2048, 8),)
    return ((3072, 16), (4096, 8))


def _measure_points(quick: bool):
    return ((128, 64),) if quick else ((256, 128), (512, 64))


def _locally_warped(n: int, length: int, seed: int, drift: int = 2):
    """Pair batches where B is A under a small random local time warp —
    the workload adaptive corridors are built for: the true alignment
    path stays within ``drift`` cells of the diagonal, far inside the
    ``window_frac * L`` static band."""
    rng = np.random.default_rng(seed)
    A = np.cumsum(rng.standard_normal((n, length)), axis=1).astype(
        np.float32)
    B = np.empty_like(A)
    for i in range(n):
        off = np.clip(np.cumsum(rng.integers(-1, 2, size=length)),
                      -drift, drift)
        idx = np.clip(np.arange(length) + off, 0, length - 1)
        B[i] = A[i, idx.astype(np.int64)]
    B += rng.normal(scale=0.02, size=B.shape).astype(np.float32)
    return A, B


def run(quick: bool = True) -> Bench:
    b = Bench("dtw_kernel")
    interpret = default_interpret()
    rng = np.random.default_rng(0)
    summary = []
    for L, batch in _points(quick):
        w = max(1, int(round(WINDOW_FRAC * L)))
        A = rng.standard_normal((batch, L)).astype(np.float32)
        B = rng.standard_normal((batch, L)).astype(np.float32)

        impls = {
            "jax_scan": lambda: dtw_batch(A, B, w),
            "pallas_full": lambda: dtw_band(A, B, w, interpret=interpret,
                                            mode="full"),
            "pallas_band": lambda: dtw_band(A, B, w, interpret=interpret,
                                            mode="compressed"),
        }
        # all three must agree before timing means anything
        ref = np.asarray(impls["jax_scan"]())
        times = {}
        for name, fn in impls.items():
            np.testing.assert_allclose(np.asarray(fn()), ref,
                                       rtol=1e-4, atol=1e-4)
            times[name] = timeit(fn, repeats=3)["median_s"]

        pairs_per_s = {k: batch / v for k, v in times.items()}
        band_vs_full = times["pallas_full"] / times["pallas_band"]
        band_vs_jax = times["jax_scan"] / times["pallas_band"]
        b.add(L=L, batch=batch, window=w,
              jax_scan_s=times["jax_scan"],
              pallas_full_s=times["pallas_full"],
              pallas_band_s=times["pallas_band"],
              band_vs_full_speedup=band_vs_full,
              band_vs_jax_speedup=band_vs_jax,
              pairs_per_s_band=pairs_per_s["pallas_band"])
        summary.append(dict(L=L, batch=batch, window=w, times_s=times,
                            band_vs_full_speedup=band_vs_full,
                            band_vs_jax_speedup=band_vs_jax))

    # -- adaptive corridors vs the static band on locally-warped data -------
    adaptive_rows = []
    for L, batch in _adaptive_points(quick):
        w = max(1, int(round(WINDOW_FRAC * L)))
        A, B = _locally_warped(batch, L, seed=L)
        width = tune.adaptive_width(L, w, factor=ADAPTIVE_FACTOR,
                                    radius=ADAPTIVE_RADIUS)

        def run_static():
            return dtw_band(A, B, w, interpret=interpret)

        def run_adaptive():
            # end-to-end: corridor build + clip + adaptive sweep
            lo, hi = corridor_mod.clip_to_width(
                *corridor_mod.build_corridor(A, B, w,
                                             factor=ADAPTIVE_FACTOR,
                                             radius=ADAPTIVE_RADIUS),
                width)
            return dtw_band(A, B, w, interpret=interpret,
                            corridor=(lo, hi), width=width)

        d_static = np.asarray(run_static())
        d_adaptive = np.asarray(run_adaptive())
        lo, hi = corridor_mod.clip_to_width(
            *corridor_mod.build_corridor(A, B, w, factor=ADAPTIVE_FACTOR,
                                         radius=ADAPTIVE_RADIUS), width)
        cert = np.asarray(corridor_mod.certify_adaptive(
            A, B, lo, hi, window=w, width=width))
        # exactness contract: certified pairs are bit-identical
        assert (d_adaptive[cert] == d_static[cert]).all(), \
            "certified adaptive distances must equal static bit-for-bit"
        t_static = timeit(run_static, repeats=5)["median_s"]
        t_adaptive = timeit(run_adaptive, repeats=5)["median_s"]
        from repro.kernels.dtw_band.kernel import band_width
        row = dict(L=L, batch=batch, window=w,
                   static_width=band_width(L, w),
                   adaptive_width=width,
                   corridor_factor=ADAPTIVE_FACTOR,
                   corridor_radius=ADAPTIVE_RADIUS,
                   pallas_band_s=t_static,
                   adaptive_s=t_adaptive,
                   adaptive_vs_band_speedup=t_static / t_adaptive,
                   certified_frac=float(cert.mean()),
                   certified_bit_identical=True)
        b.add(**row)
        adaptive_rows.append(row)

    # -- per-measure sweep of the measure-generic band-compressed kernel ----
    measure_rows = []
    for meas in measures.available():
        spec = measures.get_measure(meas)
        for L, batch in _measure_points(quick):
            w = max(1, int(round(WINDOW_FRAC * L)))
            A = rng.standard_normal((batch, L)).astype(np.float32)
            B = rng.standard_normal((batch, L)).astype(np.float32)
            fn_jax = lambda: dtw_batch(A, B, w, spec)
            fn_band = lambda: dtw_band(A, B, w, interpret=interpret,
                                       measure=spec)
            np.testing.assert_allclose(np.asarray(fn_band()),
                                       np.asarray(fn_jax()),
                                       rtol=1e-4, atol=1e-4)
            t_jax = timeit(fn_jax, repeats=3)["median_s"]
            t_band = timeit(fn_band, repeats=3)["median_s"]
            row = dict(measure=spec.label, L=L, batch=batch, window=w,
                       jax_scan_s=t_jax, pallas_band_s=t_band,
                       pairs_per_s_band=batch / t_band)
            b.add(**row)
            measure_rows.append(row)

    headline = {
        "window_frac": WINDOW_FRAC,
        "dtw_rows": summary,
        "measure_rows": measure_rows,
        "adaptive_rows": adaptive_rows,
        "min_band_vs_full_speedup": min(r["band_vs_full_speedup"]
                                        for r in summary),
        "min_adaptive_vs_band_speedup": min(
            r["adaptive_vs_band_speedup"] for r in adaptive_rows),
    }
    b.save(headline)
    print(f"  min band-vs-full speedup "
          f"{headline['min_band_vs_full_speedup']:.2f}x")
    print(f"  min adaptive-vs-band speedup "
          f"{headline['min_adaptive_vs_band_speedup']:.2f}x")
    return b


if __name__ == "__main__":
    run()
