"""IVF-PQDTW: recall vs exhaustive search, candidate-slot correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ivf import build_index, search, search_batch
from repro.core.pq import PQConfig, cdist_asym
from repro.data.timeseries import cbf


@pytest.fixture(scope="module")
def setup():
    X, y = cbf(n_per_class=20, length=64, seed=0)
    Q, _ = cbf(n_per_class=4, length=64, seed=9)
    cfg = PQConfig(n_sub=4, codebook_size=16, use_prealign=False,
                   kmeans_iters=3, dba_iters=1)
    index = build_index(jax.random.PRNGKey(0), jnp.asarray(X), cfg,
                        n_lists=6, coarse_iters=4)
    return X, Q, cfg, index


class TestIndexStructure:
    def test_lists_partition_the_database(self, setup):
        X, _, _, index = setup
        ids = np.sort(np.asarray(index.ids))
        np.testing.assert_array_equal(ids, np.arange(len(X)))
        assert int(index.list_len.sum()) == len(X)
        # starts consistent with lengths
        start = np.asarray(index.list_start)
        length = np.asarray(index.list_len)
        for i in range(1, len(start)):
            assert start[i] == start[i - 1] + length[i - 1]

    def test_full_probe_equals_exhaustive_pq(self, setup):
        """Probing every list must reproduce exhaustive asymmetric PQDTW."""
        X, Q, cfg, index = setup
        d_ex = np.asarray(cdist_asym(jnp.asarray(Q), index.codes, index.cb,
                                     cfg))
        ids_ex = np.asarray(index.ids)[d_ex.argmin(1)]
        d, ids = search_batch(index, jnp.asarray(Q), cfg,
                              n_probe=index.n_lists, topk=1)
        np.testing.assert_array_equal(np.asarray(ids)[:, 0], ids_ex)
        np.testing.assert_allclose(np.asarray(d)[:, 0], d_ex.min(1),
                                   rtol=1e-5, atol=1e-5)


class TestRecall:
    def test_recall_monotone_in_probes(self, setup):
        X, Q, cfg, index = setup
        d_ex = np.asarray(cdist_asym(jnp.asarray(Q), index.codes, index.cb,
                                     cfg))
        truth = np.asarray(index.ids)[d_ex.argmin(1)]
        recalls = []
        for p in (1, 3, index.n_lists):
            _, ids = search_batch(index, jnp.asarray(Q), cfg,
                                  n_probe=p, topk=1)
            recalls.append(float((np.asarray(ids)[:, 0] == truth).mean()))
        assert recalls[-1] == 1.0
        assert recalls[0] <= recalls[1] + 1e-9 <= recalls[2] + 2e-9
        assert recalls[1] >= 0.5      # CBF clusters are easy: few probes win

    def test_topk_sorted(self, setup):
        _, Q, cfg, index = setup
        d, ids = search(index, jnp.asarray(Q[0]), cfg, n_probe=3, topk=5)
        dd = np.asarray(d)
        assert (np.diff(dd) >= -1e-6).all()
        assert len(np.unique(np.asarray(ids))) == 5


class TestValidation:
    """Bad probe/topk budgets fail with a clear ValueError, not an XLA
    shape error deep inside top_k."""

    def test_n_probe_out_of_range_raises(self, setup):
        _, Q, cfg, index = setup
        for bad in (0, -1, index.n_lists + 1):
            with pytest.raises(ValueError, match="n_probe"):
                search_batch(index, jnp.asarray(Q), cfg, n_probe=bad)

    def test_topk_exceeds_candidate_budget_raises(self, setup):
        _, Q, cfg, index = setup
        cap = 1 * index.max_list
        with pytest.raises(ValueError, match="topk"):
            search(index, jnp.asarray(Q[0]), cfg, n_probe=1, topk=cap + 1)
        with pytest.raises(ValueError, match="topk"):
            search_batch(index, jnp.asarray(Q), cfg, n_probe=2, topk=0)


class TestCoarseWindow:
    """The band the lists were assigned with is stored on the index and is
    the search-time default (regression: search used to hardcode 0.1*D
    regardless of ``coarse_window_frac``)."""

    def test_coarse_window_stored(self, setup):
        X, _, cfg, index = setup
        D = X.shape[1]
        assert index.coarse_window == max(1, int(round(0.1 * D)))
        wide = build_index(jax.random.PRNGKey(3), jnp.asarray(X), cfg,
                           n_lists=4, coarse_iters=2,
                           coarse_window_frac=0.4)
        assert wide.coarse_window == max(1, int(round(0.4 * D)))

    def test_search_defaults_to_build_window(self, setup):
        X, Q, cfg, _ = setup
        index = build_index(jax.random.PRNGKey(3), jnp.asarray(X), cfg,
                            n_lists=4, coarse_iters=2,
                            coarse_window_frac=0.4)
        d0, i0 = search_batch(index, jnp.asarray(Q), cfg, n_probe=2, topk=3)
        d1, i1 = search_batch(index, jnp.asarray(Q), cfg, n_probe=2, topk=3,
                              coarse_window=index.coarse_window)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d1))


class TestLBPrefilter:
    """Cascaded lower-bound pre-filter ahead of the exact ADC gather."""

    def test_full_budget_identical(self, setup):
        X, Q, cfg, index = setup
        cap = 3 * index.max_list
        d0, i0 = search_batch(index, jnp.asarray(Q), cfg, n_probe=3, topk=4)
        d1, i1 = search_batch(index, jnp.asarray(Q), cfg, n_probe=3, topk=4,
                              lb_budget=cap)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d1))

    def test_lb_lut_lower_bounds_query_lut(self, setup):
        from repro.core.lb import lb_lut
        from repro.core.pq import query_lut_batch, segment
        X, Q, cfg, index = setup
        D = Q.shape[1]
        q_segs = segment(jnp.asarray(Q), cfg)
        qluts = np.asarray(query_lut_batch(q_segs, index.cb, cfg.window(D),
                                           cfg.metric != "dtw"))
        lbs = np.asarray(lb_lut(q_segs, index.cb.centroids,
                                index.cb.env_upper, index.cb.env_lower))
        assert (lbs <= qluts + 1e-4).all()

    def test_small_budget_still_returns_topk(self, setup):
        X, Q, cfg, index = setup
        d, ids = search_batch(index, jnp.asarray(Q), cfg, n_probe=3, topk=2,
                              lb_budget=8)
        dd = np.asarray(d)
        assert (np.diff(dd, axis=1) >= -1e-6).all()
        assert (np.asarray(ids) >= 0).all()

    def test_budget_validation(self, setup):
        X, Q, cfg, index = setup
        cap = 2 * index.max_list
        with pytest.raises(ValueError, match="lb_budget"):
            search_batch(index, jnp.asarray(Q), cfg, n_probe=2, topk=3,
                         lb_budget=2)
        with pytest.raises(ValueError, match="lb_budget"):
            search_batch(index, jnp.asarray(Q), cfg, n_probe=2, topk=3,
                         lb_budget=cap + 1)


class TestPretrainedQuantizers:
    def test_build_index_with_shared_quantizers_matches(self, setup):
        """Re-building from the trained coarse/cb must reproduce the same
        inverted-list layout (the streaming-index equivalence path)."""
        X, _, cfg, index = setup
        rebuilt = build_index(jax.random.PRNGKey(42), jnp.asarray(X), cfg,
                              n_lists=index.n_lists, coarse=index.coarse,
                              cb=index.cb)
        np.testing.assert_array_equal(np.asarray(rebuilt.codes),
                                      np.asarray(index.codes))
        np.testing.assert_array_equal(np.asarray(rebuilt.ids),
                                      np.asarray(index.ids))
        np.testing.assert_array_equal(np.asarray(rebuilt.list_len),
                                      np.asarray(index.list_len))
        assert rebuilt.max_list == index.max_list

    def test_build_index_coarse_shape_mismatch_raises(self, setup):
        X, _, cfg, index = setup
        with pytest.raises(ValueError, match="centroids"):
            build_index(jax.random.PRNGKey(0), jnp.asarray(X), cfg,
                        n_lists=index.n_lists + 1, coarse=index.coarse)
