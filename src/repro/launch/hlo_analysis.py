"""Compiled-HLO analysis: collective bytes, roofline terms.

The dry-run cannot measure wall time (CPU container, TPU target), so the
perf report derives three roofline terms per (arch x shape x mesh) cell from
the compiled artifact:

    compute_s    = HLO_FLOPs_per_device / peak_FLOPs            (197 TF bf16)
    memory_s     = HLO_bytes_per_device / HBM_bw                (819 GB/s)
    collective_s = collective_bytes_per_device / link_bw        (~50 GB/s)

``cost_analysis()`` provides per-device FLOPs and bytes (the compiled module
is the per-device SPMD program).  Collective bytes are NOT in cost_analysis:
``collective_bytes`` parses the optimized HLO text and sums the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute — again per-device, since SPMD operand shapes are shard
shapes.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = ["HW", "TPU_V5E", "collective_bytes", "roofline",
           "model_flops_per_step", "RooflineReport"]


@dataclasses.dataclass(frozen=True)
class HW:
    """Per-chip hardware constants (assignment: TPU v5e)."""
    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # bytes/s
    link_bw: float = 50e9           # bytes/s per ICI link
    hbm_bytes: float = 16e9


TPU_V5E = HW()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

# e.g. "bf16[8,4096,1848]{2,1,0}" or "f32[]" ; tuple shapes handled by caller
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")
# "%x = bf16[...] all-gather(...)" — capture op name and full line
_COLL_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every dtype[dims] occurrence in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str, per_op: bool = False):
    """Sum output-shape bytes of every collective op in optimized HLO.

    Uses the *result* shape (the data that moves onto this device); `-done`
    ops are skipped so async start/done pairs count once.  Returns total
    bytes, or a per-op-kind dict if ``per_op``.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    for m in _COLL_LINE_RE.finditer(hlo_text):
        shape_part, op = m.group(1), m.group(2)
        if m.group(0).rstrip("(").endswith("-done("):
            continue
        out[op] += _shape_bytes(shape_part)
    if per_op:
        return out
    return sum(out.values())


def model_flops_per_step(param_count: int, active_param_count: int,
                         tokens: int, kind: str) -> float:
    """Useful model FLOPs: 6·N·D train, 2·N·D forward-only (N = active)."""
    n = active_param_count
    if kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


@dataclasses.dataclass
class RooflineReport:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective bytes
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str                   # dominant term
    model_flops: float           # global useful flops (6ND / 2ND)
    useful_ratio: float          # model_flops / (flops * chips)
    roofline_frac: float         # min(terms)/max(terms) utilisation proxy

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline(cost: dict, coll_bytes: float, chips: int, *,
             model_flops: float, hw: HW = TPU_V5E) -> RooflineReport:
    """Three-term roofline from ``compiled.cost_analysis()`` + HLO parse."""
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / hw.peak_flops
    memory_s = hbm / hw.hbm_bw
    coll_s = coll_bytes / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bound = max(terms, key=terms.get)
    total = flops * chips
    useful = model_flops / total if total else 0.0
    # fraction of the step spent on the useful-compute term if perfectly
    # overlapped: useful compute time / dominant term time
    useful_compute_s = (model_flops / chips) / hw.peak_flops
    dominant = max(terms.values()) or 1.0
    return RooflineReport(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bound=bound, model_flops=model_flops, useful_ratio=useful,
        roofline_frac=useful_compute_s / dominant)
