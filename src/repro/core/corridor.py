"""Per-pair adaptive alignment corridors — FastDTW-style coarse projection.

The static Sakoe-Chiba band sweeps ``~window + 1`` register lanes for
every pair even when the true alignment path hugs the diagonal.  This
module bounds the corridor *per pair* from a cheap coarse pass:

1. **PAA downsample** both series by ``factor`` (edge-padded means), so
   the coarse grid is ``Lc = ceil(L / factor)`` cells per side;
2. **banded DTW on the coarse grid**, forward *and* backward, via the
   core anti-diagonal sweep with full tables — ``O((L/factor)^2)`` work;
3. **on-path envelope**: a coarse cell lies on a (near-)optimal path iff
   ``F[i,j] + G[i,j] - cost(i,j) <= opt * (1+rtol) + atol``; per coarse
   anti-diagonal the on-path cells give a ``[lo_c, hi_c]`` range
   (dilated across neighbouring diagonals, since a diagonal move skips
   one);
4. **projection** back to the fine grid with a safety ``radius``,
   intersected with the static band and closed so the envelope satisfies
   the structural invariants the band-compressed kernel needs:
   ``lo`` non-decreasing with per-diagonal drift <= 1 (so the register
   base shifts stay lane rotates), ``lo(0) = 0``, ``lo(2L-2) = L-1``,
   and ``lo <= hi`` everywhere (every diagonal keeps at least one live
   cell, so the DP remains connected).

**Exactness contract.**  The corridor is always a *subset* of the static
band, so the adaptive cost is an upper bound on the static banded cost:
``adaptive >= static``, with equality — bit-identical floats, same
sweep order — whenever the corridor contains the static band's optimal
path.  :func:`certify_adaptive` checks this cheaply at the corridor
boundary: if re-sweeping with the corridor dilated by one cell does not
change the cost, the optimum has converged inside the corridor.  Pairs
that fail the check fall back to a documented *approximate* result
(still a valid banded alignment cost, just over a narrower corridor) —
which is why ``band="adaptive"`` is capability-gated out of the
certified LB cascade, mirroring how measures gate pruning.

The coarse pass always uses plain DTW geometry: the corridor is a
*search-space heuristic*, and a DTW coarse path is a good corridor
predictor for every registered measure; the fine sweep itself runs the
requested measure.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .dtw import _diag_sweep

__all__ = [
    "build_corridor",
    "static_band",
    "clip_to_width",
    "corridor_width",
    "certify_adaptive",
    "corridor_sweep",
]

# on-path tolerance for the coarse through-cost test (f32 accumulation
# order differs between the forward and backward tables)
_RTOL = 1e-4
_ATOL = 1e-5


def _eff_window(length: int, window: Optional[int]) -> int:
    w = length - 1 if window is None else int(window)
    return max(0, min(w, length - 1))


def paa(X: jnp.ndarray, factor: int) -> jnp.ndarray:
    """Piecewise-aggregate downsample ``(N, L) -> (N, ceil(L/factor))``.

    The tail segment is edge-padded so every coarse cell is a mean of
    ``factor`` values.
    """
    n, L = X.shape
    Lc = -(-L // factor)
    pad = Lc * factor - L
    if pad:
        X = jnp.concatenate([X, jnp.repeat(X[:, -1:], pad, axis=1)], axis=1)
    return X.reshape(n, Lc, factor).mean(axis=2)


def static_band(length: int, window: Optional[int]
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The static Sakoe-Chiba envelope as ``(lo, hi)`` int32 ``(2L-1,)``
    arrays — the widest corridor any adaptive envelope is clipped to."""
    L = length
    w = _eff_window(length, window)
    d = jnp.arange(2 * L - 1, dtype=jnp.int32)
    lo = jnp.maximum(jnp.maximum(0, d - (L - 1)), -((w - d) // 2))
    hi = jnp.minimum(jnp.minimum(L - 1, d), (d + w) // 2)
    return lo, hi


@functools.partial(jax.jit, static_argnames=("window", "factor", "radius"))
def build_corridor(A: jnp.ndarray, B: jnp.ndarray,
                   window: Optional[int] = None, *, factor: int = 8,
                   radius: int = 2) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-pair corridor envelopes for zipped batches ``A, B (N, L)``.

    Returns ``(lo, hi)`` int32 arrays of shape ``(N, 2L-1)`` satisfying
    the structural invariants in the module header.  Pure ``jnp`` — safe
    to call inside a jitted caller (``factor``/``radius``/``window`` are
    static).
    """
    N, L = A.shape
    A = A.astype(jnp.float32)
    B = B.astype(jnp.float32)
    w = _eff_window(L, window)
    lo_s, hi_s = static_band(L, w)
    Lc = -(-L // factor)
    if Lc < 4:
        # coarse grid too small to say anything: fall back to the static
        # band (adaptive == static, trivially certified)
        return (jnp.broadcast_to(lo_s, (N, 2 * L - 1)),
                jnp.broadcast_to(hi_s, (N, 2 * L - 1)))

    Ac = paa(A, factor)
    Bc = paa(B, factor)
    wc = min(Lc - 1, w // factor + 2)

    sweep = jax.vmap(
        lambda a, b: _diag_sweep(a, b, wc, return_table=True)[1])
    F = sweep(Ac, Bc)                       # (N, 2Lc-1, Lc): T[i, d-i]
    G = sweep(Ac[:, ::-1], Bc[:, ::-1])[:, ::-1, ::-1]  # cost-to-go

    i_c = jnp.arange(Lc, dtype=jnp.int32)
    d_c = jnp.arange(2 * Lc - 1, dtype=jnp.int32)
    j_mat = d_c[:, None] - i_c[None, :]     # (2Lc-1, Lc)
    cost = (Ac[:, None, :]
            - jnp.take(Bc, jnp.clip(j_mat, 0, Lc - 1), axis=1)) ** 2
    opt = F[:, -1:, -1:]
    through = F + G - cost
    on = ((j_mat >= 0) & (j_mat < Lc)
          & jnp.isfinite(F) & jnp.isfinite(G)
          & (through <= opt * (1.0 + _RTOL) + _ATOL))

    lo_c = jnp.min(jnp.where(on, i_c, Lc), axis=2)      # (N, 2Lc-1)
    hi_c = jnp.max(jnp.where(on, i_c, -1), axis=2)
    # a diagonal move skips one anti-diagonal: cover skipped diagonals
    # from their neighbours
    lo_p = jnp.pad(lo_c, ((0, 0), (1, 1)), constant_values=Lc)
    hi_p = jnp.pad(hi_c, ((0, 0), (1, 1)), constant_values=-1)
    lo_c = jnp.minimum(jnp.minimum(lo_p[:, :-2], lo_p[:, 1:-1]),
                       lo_p[:, 2:])
    hi_c = jnp.maximum(jnp.maximum(hi_p[:, :-2], hi_p[:, 1:-1]),
                       hi_p[:, 2:])

    # project: fine diagonal d intersects the blocks of coarse diagonals
    # floor(d/f)-1 and floor(d/f) only (block span 2f-2 < 2f)
    d_f = jnp.arange(2 * L - 1, dtype=jnp.int32)
    dc0 = jnp.clip(d_f // factor, 0, 2 * Lc - 2)
    dc1 = jnp.maximum(dc0 - 1, 0)
    lo_raw = (factor * jnp.minimum(lo_c[:, dc1], lo_c[:, dc0]) - radius)
    hi_raw = (factor * jnp.maximum(hi_c[:, dc1], hi_c[:, dc0])
              + factor - 1 + radius)

    # structural closure of lo: clamp to feasible cells, enforce
    # "reachable from the left" (lo(d) <= lo(d') + d - d' for d' < d) via
    # a running min of lo - d, then monotonicity via a reverse running
    # min.  Both only *lower* lo, so corridor containment is preserved;
    # the final max with the static band lo (itself non-decreasing with
    # drift <= 1) keeps both invariants and pins lo(0)=0, lo(2L-2)=L-1.
    feas_hi = jnp.minimum(d_f, L - 1)
    lo0 = jnp.minimum(lo_raw, feas_hi)
    lo1 = d_f + jax.lax.cummin(lo0 - d_f, axis=1)
    lo2 = jax.lax.cummin(lo1, axis=1, reverse=True)
    lo = jnp.maximum(lo2, lo_s)
    hi = jnp.maximum(jnp.minimum(hi_raw, hi_s), lo)
    return lo.astype(jnp.int32), hi.astype(jnp.int32)


def clip_to_width(lo: jnp.ndarray, hi: jnp.ndarray,
                  width: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cap the corridor at the (static) register ``width``.  A clipped
    pair may lose containment of the optimal path — exactly what
    :func:`certify_adaptive` detects."""
    return lo, jnp.minimum(hi, lo + width - 1)


def corridor_width(lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """Per-pair maximum live cells on any diagonal — the register width
    the pair actually needs."""
    return jnp.max(hi - lo + 1, axis=-1)


def dilate(lo: jnp.ndarray, hi: jnp.ndarray, length: int,
           window: Optional[int]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Widen the corridor by one cell per side, re-clipped to the static
    band (preserves every structural invariant)."""
    lo_s, hi_s = static_band(length, window)
    return jnp.maximum(lo - 1, lo_s), jnp.minimum(hi + 1, hi_s)


def corridor_sweep(A: jnp.ndarray, B: jnp.ndarray, lo: jnp.ndarray,
                   hi: jnp.ndarray, *, window: Optional[int], width: int,
                   measure=None) -> jnp.ndarray:
    """Adaptive band-compressed sweep on the pure-JAX route:
    ``A, B (N, L)`` with corridors ``(N, 2L-1)`` -> ``(N, 1)`` costs."""
    from ..kernels.dtw_band.kernel import wavefront_compressed
    L = A.shape[1]
    return wavefront_compressed(
        A.astype(jnp.float32), B.astype(jnp.float32), length=L,
        window=_eff_window(L, window), width=width, measure=measure,
        corridor=(lo, hi))


@functools.partial(jax.jit, static_argnames=("window", "width", "measure"))
def certify_adaptive(A: jnp.ndarray, B: jnp.ndarray, lo: jnp.ndarray,
                     hi: jnp.ndarray, *, window: Optional[int], width: int,
                     measure=None) -> jnp.ndarray:
    """Corridor-boundary convergence check, per pair -> bool ``(N,)``.

    Re-sweeps with the corridor dilated by one cell (still inside the
    static band): if the cost is unchanged the optimum has converged
    inside the corridor and the adaptive result equals the static-band
    result bit-for-bit whenever the corridor contains the static optimal
    path.  Cost: one extra sweep at ``width + 2`` registers."""
    L = A.shape[1]
    base = corridor_sweep(A, B, lo, hi, window=window, width=width,
                          measure=measure)
    lo_d, hi_d = dilate(lo, hi, L, window)
    wide = corridor_sweep(A, B, lo_d, hi_d, window=window, width=width + 2,
                          measure=measure)
    return (base == wide)[:, 0]
