"""Measure registry + measure-generic engine: oracles, backends, gating."""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch, measures
from repro.core.dtw import dtw_batch, euclidean_sq
from repro.core.lb_search import filtered_topk
from repro.core.measures import MeasureSpec, get_measure, resolve

ALL_MEASURES = ("dtw", "wdtw:g=0.1", "erp:g=0.3", "msm:c=0.5")
NON_DTW = ("wdtw:g=0.1", "erp:g=0.3", "msm:c=0.5")


# ---------------------------------------------------------------------------
# numpy DP oracle (textbook recurrences, O(L^2), independent of the sweeps)
# ---------------------------------------------------------------------------

def measure_reference(a, b, spec: MeasureSpec, window=None) -> float:
    n, m = len(a), len(b)
    w = max(n, m) if window is None else int(window)
    p = dict(spec.params)
    T = np.full((n + 1, m + 1), np.inf)
    T[0, 0] = 0.0
    if spec.name == "erp":
        for i in range(1, n + 1):
            T[i, 0] = T[i - 1, 0] + abs(a[i - 1] - p["g"])
        for j in range(1, m + 1):
            T[0, j] = T[0, j - 1] + abs(b[j - 1] - p["g"])
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            if abs((i - 1) - (j - 1)) > w:
                continue
            x, y = float(a[i - 1]), float(b[j - 1])
            if spec.name == "dtw":
                cd = cv = ch = (x - y) ** 2
            elif spec.name == "wdtw":
                wt = 2.0 / (1.0 + np.exp(
                    -p["g"] * (abs((i - 1) - (j - 1)) - 0.5 * n)))
                cd = cv = ch = wt * (x - y) ** 2
            elif spec.name == "erp":
                cd, cv, ch = abs(x - y), abs(x - p["g"]), abs(y - p["g"])
            elif spec.name == "msm":
                c = p["c"]

                def C(new, prev, other):
                    if prev <= new <= other or prev >= new >= other:
                        return c
                    return c + min(abs(new - prev), abs(new - other))

                cd = abs(x - y)
                cv = C(x, float(a[i - 2]), y) if i >= 2 else 0.0
                ch = C(y, float(b[j - 2]), x) if j >= 2 else 0.0
            else:  # pragma: no cover
                raise ValueError(spec.name)
            T[i, j] = min(T[i - 1, j - 1] + cd, T[i - 1, j] + cv,
                          T[i, j - 1] + ch)
    return float(T[n, m])


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_ships_required_measures():
    for name in ("dtw", "wdtw", "erp", "msm"):
        assert name in measures.available()
    rows = measures.registry_rows()
    assert {r["name"] for r in rows} >= {"dtw", "wdtw", "erp", "msm"}
    dtw_row = next(r for r in rows if r["name"] == "dtw")
    assert dtw_row["has_keogh_lb"] and dtw_row["euclid_is_upper_bound"]


def test_resolve_forms_and_errors():
    assert resolve(None).name == "dtw"
    spec = resolve("erp:g=1.5")
    assert spec.name == "erp" and spec.param("g") == 1.5
    assert resolve(spec) is spec
    assert resolve("msm").param("c") == 0.5          # default
    with pytest.raises(ValueError, match="unknown elastic measure"):
        resolve("frechet")
    with pytest.raises(ValueError, match="no parameter"):
        get_measure("erp", gamma=1.0)


def test_spec_is_static_jit_key():
    """Equal-by-value specs must share a jit cache entry (hashable, eq)."""
    a = get_measure("erp", g=0.25)
    b = get_measure("erp", g=0.25)
    c = get_measure("erp", g=0.5)
    assert a == b and hash(a) == hash(b) and a != c
    assert a.to_manifest() == {"name": "erp", "params": {"g": 0.25}}


def test_register_custom_measure_flows_through_engine():
    """A user-registered measure runs the whole dispatch path unchanged."""
    if "sqed" not in measures.available():
        def step(params, x, y, xp, yp, dd, length):
            c = (x - y) ** 2 + params["bias"]
            return c, c, c
        measures.register_measure("sqed", step=step,
                                  defaults=(("bias", 0.0),),
                                  doc="test-only: dtw + constant bias")
    spec = get_measure("sqed", bias=0.0)
    rng = np.random.default_rng(0)
    A = rng.standard_normal((4, 12)).astype(np.float32)
    B = rng.standard_normal((4, 12)).astype(np.float32)
    with dispatch.use_backend("jax"):
        want = np.asarray(dispatch.elastic_pairwise(A, B, 3))
        got = np.asarray(dispatch.elastic_pairwise(A, B, 3, measure=spec))
    np.testing.assert_allclose(got, want, rtol=1e-6)


# ---------------------------------------------------------------------------
# recurrence correctness: both backends vs the numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("measure", ALL_MEASURES)
@pytest.mark.parametrize("n,L,window", [(3, 8, None), (5, 16, 2), (4, 24, 5),
                                        (2, 1, None)])
def test_sweep_matches_oracle(measure, n, L, window):
    spec = resolve(measure)
    rng = np.random.default_rng(n * 31 + L)
    A = rng.standard_normal((n, L)).astype(np.float32)
    B = rng.standard_normal((n, L)).astype(np.float32)
    got = np.asarray(dtw_batch(A, B, window, spec))
    want = np.array([measure_reference(A[i], B[i], spec, window)
                     for i in range(n)])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("measure", ALL_MEASURES)
@pytest.mark.parametrize("n,m,L,window", [(4, 6, 12, None), (7, 5, 16, 3)])
def test_dispatch_cdist_backends_agree_per_measure(measure, n, m, L, window):
    """Acceptance: elastic_cdist agrees between jax and pallas_interpret
    for every registered measure."""
    rng = np.random.default_rng(n * 13 + m)
    A = rng.standard_normal((n, L)).astype(np.float32)
    B = rng.standard_normal((m, L)).astype(np.float32)
    with dispatch.use_backend("jax"):
        want = np.asarray(dispatch.elastic_cdist(A, B, window,
                                                 measure=measure))
    with dispatch.use_backend("pallas_interpret"):
        got = np.asarray(dispatch.elastic_cdist(A, B, window,
                                                measure=measure))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("measure", ALL_MEASURES)
def test_dispatch_pairwise_backends_agree_per_measure(measure):
    rng = np.random.default_rng(7)
    A = rng.standard_normal((9, 20)).astype(np.float32)
    B = rng.standard_normal((9, 20)).astype(np.float32)
    with dispatch.use_backend("jax"):
        want = np.asarray(dispatch.elastic_pairwise(A, B, 4,
                                                    measure=measure))
    with dispatch.use_backend("pallas_interpret"):
        got = np.asarray(dispatch.elastic_pairwise(A, B, 4,
                                                   measure=measure))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# limiting-case equivalences
# ---------------------------------------------------------------------------

def test_wdtw_flat_weight_equals_dtw():
    """g = 0 makes the logistic weight flat 1, so wdtw == dtw exactly."""
    rng = np.random.default_rng(2)
    A = rng.standard_normal((6, 18)).astype(np.float32)
    B = rng.standard_normal((6, 18)).astype(np.float32)
    for window in (None, 3):
        flat = np.asarray(dtw_batch(A, B, window, get_measure("wdtw", g=0.0)))
        plain = np.asarray(dtw_batch(A, B, window))
        np.testing.assert_allclose(flat, plain, rtol=1e-5, atol=1e-5)


def test_erp_dtw_lockstep_limits():
    """The two lock-step limits that tie erp and dtw together: a huge gap
    penalty makes every ERP gap unaffordable (-> Manhattan, the L1
    lock-step), and window=0 restricts both DPs to the diagonal (ERP ->
    Manhattan again, DTW -> squared Euclidean)."""
    rng = np.random.default_rng(3)
    A = rng.standard_normal((5, 14)).astype(np.float32)
    B = rng.standard_normal((5, 14)).astype(np.float32)
    manhattan = np.abs(A - B).sum(1)
    big_g = np.asarray(dtw_batch(A, B, None, get_measure("erp", g=1e6)))
    np.testing.assert_allclose(big_g, manhattan, rtol=1e-4, atol=1e-3)
    banded = np.asarray(dtw_batch(A, B, 0, get_measure("erp", g=0.0)))
    np.testing.assert_allclose(banded, manhattan, rtol=1e-5, atol=1e-5)
    dtw0 = np.asarray(dtw_batch(A, B, 0))
    np.testing.assert_allclose(dtw0, ((A - B) ** 2).sum(1), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# capability gating
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
@pytest.mark.parametrize("measure", ALL_MEASURES)
def test_filtered_topk_exact_per_measure(backend, measure):
    """Acceptance: filtered_topk returns exactly the dense-cdist top-k for
    every measure — via pruning when capabilities allow it (dtw), via the
    gated dense fallback otherwise."""
    spec = resolve(measure)
    rng = np.random.default_rng(11)
    X = np.cumsum(rng.standard_normal((30, 16)), 1).astype(np.float32)
    Q = np.cumsum(rng.standard_normal((4, 16)), 1).astype(np.float32)
    with dispatch.use_backend(backend):
        d, idx, n_ref = filtered_topk(Q, X, 3, 2, measure=spec)
        dense = np.asarray(dispatch.elastic_cdist(Q, X, 3, measure=spec))
    want = np.sort(dense, axis=1)[:, :2]
    np.testing.assert_allclose(np.asarray(d), want, rtol=1e-5, atol=1e-5)
    if spec.can_prune:
        assert int(n_ref) <= Q.shape[0] * X.shape[0]
    else:
        assert int(n_ref) == Q.shape[0] * X.shape[0]   # dense fallback


def test_filtered_topk_dense_fallback_respects_valid_mask():
    rng = np.random.default_rng(5)
    X = rng.standard_normal((12, 10)).astype(np.float32)
    Q = rng.standard_normal((3, 10)).astype(np.float32)
    valid = np.ones(12, bool)
    valid[::2] = False
    with dispatch.use_backend("jax"):
        d, idx, n_ref = filtered_topk(Q, X, 2, 2, valid=jnp.asarray(valid),
                                      measure="msm")
    assert int(n_ref) == 3 * int(valid.sum())
    assert set(np.asarray(idx).ravel().tolist()) <= set(
        np.flatnonzero(valid).tolist())


def test_lb_refine_rejects_uncascaded_measures():
    rng = np.random.default_rng(6)
    A = rng.standard_normal((4, 8)).astype(np.float32)
    with pytest.raises(ValueError, match="no sound Keogh"):
        dispatch.lb_refine(A, A, A, A, np.zeros(4, np.float32), 2,
                           measure="erp")


def test_full_width_kernel_is_dtw_only():
    from repro.kernels.dtw_band.ops import dtw_band
    rng = np.random.default_rng(8)
    A = rng.standard_normal((4, 8)).astype(np.float32)
    with pytest.raises(ValueError, match="DTW-only"):
        dtw_band(A, A, 2, interpret=True, mode="full", measure="msm")


def test_euclid_upper_bound_flags_are_sound():
    """Where the flag is set, squared ED must dominate the measure (the
    threshold-seed soundness filtered_topk relies on)."""
    rng = np.random.default_rng(9)
    A = rng.standard_normal((8, 12)).astype(np.float32)
    B = rng.standard_normal((8, 12)).astype(np.float32)
    ed = np.asarray(euclidean_sq(A, B)).diagonal()
    for measure in ALL_MEASURES:
        spec = resolve(measure)
        if not spec.euclid_is_upper_bound:
            continue
        d = np.asarray(dtw_batch(A, B, None, spec))
        assert (d <= ed + 1e-4 + 1e-5 * np.abs(ed)).all(), spec.label


# ---------------------------------------------------------------------------
# PQ end-to-end + routing per measure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("measure", ALL_MEASURES)
def test_pq_fit_encode_classify_per_measure(measure):
    """Acceptance: a full pq fit -> encode -> 1NN classification run
    completes for every registered measure, with codes agreeing across
    backends."""
    from repro.core.knn import knn_classify_sym
    from repro.core.pq import PQConfig, encode, fit
    from repro.data.timeseries import trace_like
    spec = resolve(measure)
    Xtr, ytr = trace_like(n_per_class=5, length=32, seed=0)
    Xte, _ = trace_like(n_per_class=2, length=32, seed=3)
    cfg = PQConfig(n_sub=4, codebook_size=4, metric=spec.name,
                   measure_params=spec.params, kmeans_iters=2, dba_iters=1)
    key = jax.random.PRNGKey(0)
    with dispatch.use_backend("jax"):
        cb = fit(key, jnp.asarray(Xtr), cfg)
        codes_j = np.asarray(encode(jnp.asarray(Xtr), cb, cfg))
        pred = knn_classify_sym(jnp.asarray(codes_j), jnp.asarray(ytr),
                                jnp.asarray(Xte), cb, cfg)
    assert pred.shape == (len(Xte),)
    with dispatch.use_backend("pallas_interpret"):
        codes_p = np.asarray(encode(jnp.asarray(Xtr), cb, cfg))
    np.testing.assert_array_equal(codes_p, codes_j)
    assert codes_j.min() >= 0 and codes_j.max() < cfg.codebook_size


@pytest.mark.parametrize("measure", NON_DTW)
def test_fused_prealign_encode_per_measure(measure):
    """The fused prealign+encode path is measure-generic: identical codes
    on both backends, and non-cascade measures force the full-scan (fused)
    route even without exact_encode."""
    from repro.core.pq import PQConfig, encode, fit, uses_fused_prealign
    spec = resolve(measure)
    rng = np.random.default_rng(4)
    X = jnp.asarray(rng.standard_normal((10, 32)).astype(np.float32))
    cfg = PQConfig(n_sub=4, codebook_size=4, metric=spec.name,
                   measure_params=spec.params, use_prealign=True,
                   wavelet_level=2, kmeans_iters=2, dba_iters=1)
    assert cfg.full_scan_encode()        # capability-gated off the LB filter
    assert uses_fused_prealign(cfg)
    with dispatch.use_backend("jax"):
        cb = fit(jax.random.PRNGKey(1), X, cfg)
        dispatch.reset_stats()
        codes_j = np.asarray(encode(X, cb, cfg))
        assert dispatch.stats.get(
            (f"prealign_encode[{spec.name}]", "jax"), 0) == 1
    with dispatch.use_backend("pallas_interpret"):
        codes_p = np.asarray(encode(X, cb, cfg))
    np.testing.assert_array_equal(codes_j, codes_p)


def test_per_measure_routing_counters():
    """The dispatch ledger records op[measure] alongside the bare op."""
    rng = np.random.default_rng(10)
    A = rng.standard_normal((4, 8)).astype(np.float32)
    jax.clear_caches()
    dispatch.reset_stats()
    with dispatch.use_backend("pallas_interpret"):
        dispatch.elastic_pairwise(A, A, 2, measure="msm")
    assert dispatch.stats.get(("elastic_pairwise", "pallas_interpret")) == 1
    assert dispatch.stats.get(
        ("elastic_pairwise[msm]", "pallas_interpret")) == 1
    assert dispatch.totals.get(
        ("elastic_pairwise[msm]", "pallas_interpret"), 0) >= 1


# ---------------------------------------------------------------------------
# IVF + streaming index per measure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("measure", ["msm:c=0.5", "erp:g=0.2"])
def test_ivf_search_and_lb_budget_gate(measure):
    from repro.core import ivf
    from repro.core.pq import PQConfig
    spec = resolve(measure)
    rng = np.random.default_rng(12)
    X = rng.standard_normal((24, 32)).astype(np.float32)
    cfg = PQConfig(n_sub=2, codebook_size=4, metric=spec.name,
                   measure_params=spec.params, kmeans_iters=2, dba_iters=1)
    with dispatch.use_backend("jax"):
        index = ivf.build_index(jax.random.PRNGKey(2), X, cfg, n_lists=3)
        d0, i0 = ivf.search_batch(index, X[:4], cfg, n_probe=3, topk=3)
        # lb_budget must be ignored (not unsoundly applied) for measures
        # without a Keogh cascade: results identical to the exact path
        d1, i1 = ivf.search_batch(index, X[:4], cfg, n_probe=3, topk=3,
                                  lb_budget=3)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_streaming_snapshot_roundtrips_measure(tmp_path, backend):
    """Acceptance: a streaming-index snapshot round-trips the measure
    config, and a tampered measure record is a hard error on restore."""
    from repro.core.pq import PQConfig
    from repro.data.timeseries import random_walks
    from repro.index import (IndexConfig, StreamingIndex, restore_snapshot,
                             save_snapshot)
    from repro.index.snapshot import MANIFEST
    cfg = IndexConfig(
        pq=PQConfig(n_sub=4, codebook_size=8, metric="erp",
                    measure_params=(("g", 0.25),), use_prealign=False,
                    kmeans_iters=2, dba_iters=1),
        n_lists=4, hot_capacity=16, coarse_iters=2)
    with dispatch.use_backend(backend):
        index = StreamingIndex.bootstrap(
            jax.random.PRNGKey(0), random_walks(24, 48, seed=0), cfg)
        index.insert(random_walks(20, 48, seed=1))
        Q = random_walks(3, 48, seed=9)
        d1, n1 = index.search(Q, n_probe=2, topk=3)
        snapdir = str(tmp_path / backend)
        save_snapshot(snapdir, index)
        restored = restore_snapshot(snapdir)
        assert restored.cfg.pq.metric == "erp"
        assert restored.cfg.pq.measure_params == (("g", 0.25),)
        assert restored.cfg.pq.measure() == cfg.pq.measure()
        d2, n2 = restored.search(Q, n_probe=2, topk=3)
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)
    # tamper: flip the measure record -> hard error, not silent reinterpret
    snap = next(p for p in sorted(os.listdir(snapdir))
                if p.startswith("snap_"))
    mpath = os.path.join(snapdir, snap, MANIFEST)
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["measure"] = {"name": "msm", "params": {"c": 0.5}}
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="does not match"):
        restore_snapshot(snapdir)


def test_pqconfig_validates_and_normalizes_measure():
    from repro.core.pq import PQConfig
    cfg = PQConfig(metric="msm", measure_params={"c": 0.1})
    assert cfg.measure_params == (("c", 0.1),)
    assert cfg.measure().param("c") == 0.1
    assert dataclasses.replace(cfg).measure_params == (("c", 0.1),)
    with pytest.raises(ValueError, match="unknown elastic measure"):
        PQConfig(metric="nope")
    assert PQConfig(metric="euclidean").measure() is None


# ---------------------------------------------------------------------------
# window-default contract
# ---------------------------------------------------------------------------

def test_effective_window_contract():
    from repro.core.dispatch import effective_window
    assert effective_window(16, None) == 15
    assert effective_window(16, 100) == 15
    assert effective_window(16, 3) == 3
    assert effective_window(16, 0) == 0
    assert effective_window(1, None) == 0
