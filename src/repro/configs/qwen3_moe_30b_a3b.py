"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,                 # per-expert hidden size
    moe_d_ff=768,
    vocab_size=151936,
    head_dim=128,
    n_experts=128,
    n_active_experts=8,
    rope_theta=1e6,
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen3-moe-30b-a3b-reduced", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=32, moe_d_ff=32, vocab_size=512,
    head_dim=16, n_experts=8, n_active_experts=2)
