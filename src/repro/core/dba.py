"""DTW Barycenter Averaging (Petitjean et al.) in shape-static JAX.

DBA alternates: (1) align every member series to the current barycenter with
DTW, (2) replace each barycenter point by the mean of all member points
aligned to it.  The alignment path is recovered by backtracking the DP table
produced by :func:`repro.core.dtw.dtw_full_table` (diagonal layout).

Backtracking is inherently sequential, but the path has at most ``2L - 1``
cells, so a fixed-length ``lax.scan`` (carrying ``(i, j, done)``) makes it
shape-static and vmappable over a batch of series.  This is a training-time
cost only — it never sits on the query path.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .dtw import dtw_full_table

__all__ = ["alignment_path", "dba_update", "dba"]

_INF = jnp.float32(jnp.inf)


def alignment_path(c: jnp.ndarray, x: jnp.ndarray,
                   window: Optional[int] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Optimal-path cells aligning barycenter ``c`` (index i) to series ``x``
    (index j).  Returns ``(i_cells, j_cells, active)`` each ``(2L-1,)``;
    inactive tail entries repeat (0, 0) with ``active=False``."""
    L = c.shape[0]
    table = dtw_full_table(c, x, window)  # table[i+j, i] = dtw[i, j]

    def value(i, j):
        ok = (i >= 0) & (j >= 0)
        d = jnp.clip(i + j, 0, 2 * L - 2)
        ii = jnp.clip(i, 0, L - 1)
        return jnp.where(ok, table[d, ii], _INF)

    def step(carry, _):
        i, j, done = carry
        emit = (i, j, jnp.logical_not(done))
        v_diag = value(i - 1, j - 1)
        v_left = value(i, j - 1)
        v_up = value(i - 1, j)
        best = jnp.argmin(jnp.stack([v_diag, v_left, v_up]))
        ni = jnp.where(best != 1, i - 1, i)
        nj = jnp.where(best != 2, j - 1, j)
        at_origin = (i == 0) & (j == 0)
        ndone = done | at_origin
        ni = jnp.where(ndone, 0, ni)
        nj = jnp.where(ndone, 0, nj)
        return (ni, nj, ndone), emit

    init = (jnp.int32(L - 1), jnp.int32(L - 1), jnp.bool_(False))
    _, (i_cells, j_cells, active) = jax.lax.scan(step, init, None, length=2 * L - 1)
    return i_cells, j_cells, active


def _contributions(c: jnp.ndarray, x: jnp.ndarray, weight: jnp.ndarray,
                   window: Optional[int]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-series DBA accumulators: ``assoc[i] = sum of x[j] aligned to i``,
    ``count[i]`` likewise, both scaled by ``weight``."""
    L = c.shape[0]
    i_cells, j_cells, active = alignment_path(c, x, window)
    w = active.astype(jnp.float32) * weight
    assoc = jnp.zeros((L,), jnp.float32).at[i_cells].add(x[j_cells] * w)
    count = jnp.zeros((L,), jnp.float32).at[i_cells].add(w)
    return assoc, count


@functools.partial(jax.jit, static_argnames=("window",))
def dba_update(c: jnp.ndarray, X: jnp.ndarray,
               weights: Optional[jnp.ndarray] = None,
               window: Optional[int] = None) -> jnp.ndarray:
    """One DBA iteration: re-estimate barycenter ``c (L,)`` from ``X (N, L)``.

    ``weights (N,)`` lets k-means pass soft/masked memberships; points with a
    zero total count keep their previous value.
    """
    X = jnp.asarray(X, jnp.float32)
    if weights is None:
        weights = jnp.ones((X.shape[0],), jnp.float32)
    assoc, count = jax.vmap(lambda x, w: _contributions(c, x, w, window))(X, weights)
    assoc = assoc.sum(0)
    count = count.sum(0)
    return jnp.where(count > 0, assoc / jnp.maximum(count, 1e-9), c)


@functools.partial(jax.jit, static_argnames=("iters", "window"))
def dba(c0: jnp.ndarray, X: jnp.ndarray, iters: int = 5,
        window: Optional[int] = None) -> jnp.ndarray:
    """Run ``iters`` DBA iterations starting from ``c0``."""
    def body(c, _):
        return dba_update(c, X, None, window), None
    c, _ = jax.lax.scan(body, jnp.asarray(c0, jnp.float32), None, length=iters)
    return c
