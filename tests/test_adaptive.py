"""Adaptive alignment corridors, the kernel autotuner, and the quantized
ADC LUT path (the perf-opt PR's three new surfaces).

Exactness contract under test: when a pair's corridor contains the
static-band optimal path, ``band="adaptive"`` results are *bit-identical*
to the static band on both the jax and pallas_interpret routes; when the
corridor is too tight the adaptive result is the documented approximate
upper bound (>= static, still certifiable as such).
"""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import corridor as corr
from repro.core import dispatch
from repro.core.lb import keogh_envelope
from repro.core.lb_search import filtered_topk
from repro.kernels import tune

from conftest import dtw_reference


def _warped_pairs(n, L, seed=0, drift=3):
    """Locally-warped pairs: B is A with small random time warps, so the
    true alignment path hugs the diagonal within a few cells — the shape
    adaptive corridors exploit."""
    rng = np.random.default_rng(seed)
    A = np.cumsum(rng.normal(size=(n, L)), axis=1).astype(np.float32)
    B = np.empty_like(A)
    for i in range(n):
        # piecewise-smooth monotone warp within +/- drift cells
        steps = rng.integers(-1, 2, size=L).astype(np.float64)
        off = np.clip(np.cumsum(steps), -drift, drift)
        idx = np.clip(np.arange(L) + off, 0, L - 1)
        B[i] = A[i, idx.astype(np.int64)]
    return jnp.asarray(A), jnp.asarray(B + rng.normal(
        scale=0.05, size=B.shape).astype(np.float32))


# -- corridor construction ---------------------------------------------------

def test_corridor_invariants():
    A, B = _warped_pairs(6, 96, seed=1)
    L = 96
    lo, hi = corr.build_corridor(A, B, 9)
    lo = np.asarray(lo)
    hi = np.asarray(hi)
    lo_s, hi_s = map(np.asarray, corr.static_band(L, 9))
    assert lo.shape == (6, 2 * L - 1)
    # endpoints pinned, monotone lo with drift <= 1, inside the static band
    assert (lo[:, 0] == 0).all() and (lo[:, -1] == L - 1).all()
    d = np.diff(lo, axis=1)
    assert ((d >= 0) & (d <= 1)).all()
    assert (lo >= lo_s[None]).all() and (hi <= hi_s[None]).all()
    assert (hi >= lo).all()


def test_corridor_narrower_than_static_band_on_warped_data():
    # window_frac ~ 0.1 at L=512: the static band is ~52 cells per
    # diagonal while the projected corridor stays near the coarse path
    A, B = _warped_pairs(4, 512, seed=2)
    w = 51
    lo, hi = corr.build_corridor(A, B, w)
    lo_s, hi_s = corr.static_band(512, w)
    static_cells = float(jnp.sum(hi_s - lo_s + 1))
    adaptive_cells = float(jnp.mean(jnp.sum(hi - lo + 1, axis=1)))
    assert adaptive_cells < 0.8 * static_cells
    # and the adaptive *register* (what the kernel actually allocates)
    # is narrower than the static compressed register
    from repro.kernels.dtw_band.kernel import band_width
    assert tune.adaptive_width(512, w) < band_width(512, w, 8)


# -- adaptive exactness ------------------------------------------------------

@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_adaptive_bit_identical_when_corridor_contains_path(backend):
    A, B = _warped_pairs(8, 64, seed=3, drift=2)
    w = 6
    with dispatch.use_backend(backend):
        ds = dispatch.elastic_pairwise(A, B, w)
        da = dispatch.elastic_pairwise(A, B, w, band="adaptive")
    ok = np.asarray(corr.certify_adaptive(
        A, B, *corr.build_corridor(A, B, w), window=w,
        width=tune.adaptive_width(64, w)))
    assert ok.all()                      # corridors converged on this data
    np.testing.assert_array_equal(np.asarray(da), np.asarray(ds))


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_adaptive_matches_numpy_oracle(backend):
    A, B = _warped_pairs(4, 48, seed=4, drift=2)
    w = 5
    with dispatch.use_backend(backend):
        da = np.asarray(dispatch.elastic_pairwise(A, B, w, band="adaptive"))
    ref = np.array([dtw_reference(np.asarray(A[i]), np.asarray(B[i]), w)
                    for i in range(4)])
    # certified pairs are exactly the static distance
    ok = np.asarray(corr.certify_adaptive(
        A, B, *corr.build_corridor(A, B, w), window=w,
        width=tune.adaptive_width(48, w)))
    np.testing.assert_allclose(da[ok], ref[ok], rtol=1e-5, atol=1e-5)
    # uncertified pairs (if any) are valid upper bounds
    assert (da >= ref - 1e-4).all()


def test_adaptive_violation_is_upper_bound_not_crash():
    # anti-correlated pairs: the optimal path wanders the whole band, so a
    # tight corridor (tiny width cap) must clip it
    rng = np.random.default_rng(5)
    A = jnp.asarray(np.cumsum(rng.normal(size=(6, 64)), axis=1),
                    jnp.float32)
    B = jnp.asarray(np.cumsum(rng.normal(size=(6, 64)), axis=1),
                    jnp.float32)
    w = 16
    lo, hi = corr.build_corridor(A, B, w, factor=4, radius=0)
    lo, hi = corr.clip_to_width(lo, hi, 8)
    with dispatch.use_backend("jax"):
        ds = np.asarray(dispatch.elastic_pairwise(A, B, w))
        da = np.asarray(dispatch.elastic_pairwise(
            A, B, w, band="adaptive", corridor=(lo, hi), width=8))
    assert (da >= ds - 1e-4).all()
    cert = np.asarray(corr.certify_adaptive(A, B, lo, hi, window=w,
                                            width=8))
    # wherever certification failed the result may exceed static; wherever
    # it held the result is exact
    np.testing.assert_allclose(da[cert], ds[cert], rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_lb_refine_adaptive_refines_and_bounds(backend):
    A, B = _warped_pairs(8, 64, seed=6, drift=2)
    w = 6
    up, lo_env = keogh_envelope(A, w)
    th = jnp.full((8,), jnp.inf, jnp.float32)
    with dispatch.use_backend(backend):
        ds, rs = dispatch.lb_refine(A, B, up, lo_env, th, w)
        da, ra = dispatch.lb_refine(A, B, up, lo_env, th, w,
                                    band="adaptive")
    assert np.asarray(rs).all() and np.asarray(ra).all()
    assert (np.asarray(da) >= np.asarray(ds) - 1e-4).all()
    # warped data: corridors converge, results bit-identical
    ok = np.asarray(corr.certify_adaptive(
        A, B, *corr.build_corridor(A, B, w), window=w,
        width=tune.adaptive_width(64, w)))
    np.testing.assert_array_equal(np.asarray(da)[ok], np.asarray(ds)[ok])


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_filtered_topk_adaptive_top1_agrees_on_warped_data(backend):
    Q, X = _warped_pairs(4, 64, seed=7, drift=2)
    X = jnp.concatenate([X, X[::-1] + 5.0], axis=0)   # 8 candidates
    with dispatch.use_backend(backend):
        d_s, i_s, _ = filtered_topk(Q, X, 6, 1)
        d_a, i_a, _ = filtered_topk(Q, X, 6, 1, band="adaptive")
    np.testing.assert_array_equal(np.asarray(i_s), np.asarray(i_a))
    assert (np.asarray(d_a) >= np.asarray(d_s) - 1e-4).all()


def test_filtered_topk_rejects_unknown_band():
    Q, X = _warped_pairs(2, 32, seed=8)
    with pytest.raises(ValueError, match="band"):
        filtered_topk(Q, X, 4, 1, band="wavy")


def test_dispatch_rejects_unknown_band():
    A, B = _warped_pairs(2, 32, seed=9)
    with pytest.raises(ValueError, match="band"):
        dispatch.elastic_pairwise(A, B, 4, band="wavy")
    up, lo_env = keogh_envelope(A, 4)
    with pytest.raises(ValueError, match="band"):
        dispatch.lb_refine(A, B, up, lo_env, jnp.zeros((2,)), 4,
                           band="wavy")


# -- streaming index adaptive band -------------------------------------------

def test_streaming_index_adaptive_band_smoke():
    from repro.core.pq import PQConfig
    from repro.index.streaming import IndexConfig, StreamingIndex

    rng = np.random.default_rng(10)
    D = 32
    X = np.cumsum(rng.normal(size=(24, D)), axis=1).astype(np.float32)
    cfgs = {}
    for band in ("static", "adaptive"):
        icfg = IndexConfig(PQConfig(n_sub=2, codebook_size=4,
                                    kmeans_iters=2, dba_iters=1),
                           n_lists=2, hot_capacity=64, band=band)
        idx = StreamingIndex.bootstrap(jax.random.PRNGKey(0), X[:16], icfg)
        idx.insert(X[16:], ids=np.arange(16, 24))
        d, ids = idx.search(X[16:20], n_probe=2, topk=1)
        cfgs[band] = np.asarray(ids)
    # hot rows are exact self-matches under both bands
    np.testing.assert_array_equal(cfgs["static"], cfgs["adaptive"])


def test_index_config_rejects_bad_band():
    from repro.core.pq import PQConfig
    from repro.index.streaming import IndexConfig
    with pytest.raises(ValueError, match="band"):
        IndexConfig(PQConfig(), n_lists=2, band="diagonal")


# -- quantized ADC LUT path --------------------------------------------------

@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
@pytest.mark.parametrize("dtype", ["int8", "bfloat16"])
def test_adc_cdist_quant_within_tolerance(backend, dtype):
    rng = np.random.default_rng(11)
    M, K = 4, 16
    lut = jnp.asarray(rng.normal(size=(M, K, K)).astype(np.float32) ** 2)
    codes = jnp.asarray(rng.integers(0, K, size=(12, M)), jnp.int32)
    with dispatch.use_backend(backend):
        Df = np.asarray(dispatch.adc_cdist(codes, codes, lut))
        Dq = np.asarray(dispatch.adc_cdist(codes, codes, lut,
                                           lut_dtype=dtype))
    scale = np.abs(Df).max() + 1e-6
    assert np.abs(Dq - Df).max() / scale < 0.02


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
@pytest.mark.parametrize("dtype", ["int8", "bfloat16"])
def test_adc_lookup_quant_within_tolerance(backend, dtype):
    rng = np.random.default_rng(12)
    M, K = 4, 16
    qlut = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32) ** 2)
    codes = jnp.asarray(rng.integers(0, K, size=(12, M)), jnp.int32)
    with dispatch.use_backend(backend):
        vf = np.asarray(dispatch.adc_lookup(codes, qlut))
        vq = np.asarray(dispatch.adc_lookup(codes, qlut, lut_dtype=dtype))
    scale = np.abs(vf).max() + 1e-6
    assert np.abs(vq - vf).max() / scale < 0.02


def test_pq_cdist_sym_quant_route():
    from repro.core.pq import cdist_sym
    codes = jnp.array([[0, 1], [1, 0]], jnp.int32)
    lut = jnp.stack([1.0 - jnp.eye(2)] * 2)
    with dispatch.use_backend("jax"):
        Df = np.asarray(cdist_sym(codes, codes, lut))
        Dq = np.asarray(cdist_sym(codes, codes, lut, lut_dtype="int8"))
    np.testing.assert_allclose(Dq, Df, atol=0.02)


def test_quantize_lut_roundtrip():
    from repro.kernels.pq_adc.ops import quantize_lut
    from repro.kernels.pq_adc.ref import _dequant
    rng = np.random.default_rng(13)
    lut = jnp.asarray(rng.normal(size=(3, 8, 8)).astype(np.float32) * 7)
    q, sc, zp = quantize_lut(lut, dtype="int8")
    assert q.dtype == jnp.int8
    back = np.asarray(_dequant(q, sc, zp))
    err = np.abs(back - np.asarray(lut)).max()
    rng_span = float(lut.max() - lut.min())
    assert err <= rng_span / 254 + 1e-5


def test_adc_cdist_rejects_unknown_lut_dtype():
    codes = jnp.zeros((2, 2), jnp.int32)
    lut = jnp.zeros((2, 4, 4))
    with pytest.raises(ValueError, match="dtype"):
        dispatch.adc_cdist(codes, codes, lut, lut_dtype="fp4")


# -- autotuner ---------------------------------------------------------------

def test_tune_off_returns_defaults(monkeypatch):
    monkeypatch.setenv(tune.ENV, "off")
    tune.reset()
    assert tune.tuned("dtw_band", "block", length=128, window=12,
                      default=7) == 7


def test_tune_pinned_table_is_deterministic(tmp_path, monkeypatch):
    key = tune.table_key("dtw_band", length=128, window=12, measure="dtw",
                         backend="pallas_interpret")
    table = {key: {"block": 16}}
    path = tmp_path / "pinned.json"
    path.write_text(json.dumps(table))
    monkeypatch.setenv(tune.ENV, str(path))
    tune.reset()
    for _ in range(3):
        assert tune.tuned("dtw_band", "block", length=128, window=12,
                          measure="dtw", backend="pallas_interpret",
                          default=8) == 16
    # a geometry the table does not pin falls back to the default
    assert tune.tuned("dtw_band", "block", length=4096, window=400,
                      measure="dtw", backend="pallas_interpret",
                      default=8) == 8


def test_tune_auto_benchmarks_and_memoizes(tmp_path, monkeypatch):
    monkeypatch.setenv(tune.ENV, "auto")
    monkeypatch.setenv(tune.GRID_ENV, "minimal")
    monkeypatch.setenv(tune.OUT_ENV, str(tmp_path))
    tune.reset()
    got = tune.tuned("dtw_band", "block", length=32, window=3,
                     measure="dtw", backend="pallas_interpret", default=8)
    assert got == 8                      # minimal grid = (default,)
    out = tmp_path / "tuning.json"
    assert out.exists()
    saved = json.loads(out.read_text())
    key = tune.table_key("dtw_band", length=32, window=3, measure="dtw",
                         backend="pallas_interpret")
    assert saved[key]["block"] == 8
    # second call hits the memo (and must not re-write a different value)
    assert tune.tuned("dtw_band", "block", length=32, window=3,
                      measure="dtw", backend="pallas_interpret",
                      default=8) == 8


def test_tuned_is_noop_inside_trace(monkeypatch):
    # block resolution happens at trace time; mid-trace the tuner must
    # fall back to defaults instead of launching benchmark kernels
    monkeypatch.setenv(tune.ENV, "auto")
    monkeypatch.setenv(tune.GRID_ENV, "minimal")
    tune.reset()
    seen = []

    @jax.jit
    def f(x):
        seen.append(tune.tuned("dtw_band", "block", length=64, window=6,
                               default=8))
        return x

    f(jnp.zeros(3))
    assert seen == [8]


def test_adaptive_width_is_lane_aligned_and_capped():
    from repro.kernels.dtw_band.kernel import band_width
    for L, w in ((128, 12), (512, 51), (64, 63)):
        aw = tune.adaptive_width(L, w)
        assert aw % 8 == 0
        assert aw <= band_width(L, w, 8)


def test_band_width_exact_when_lane_aligned():
    from repro.kernels.dtw_band.kernel import band_width
    # aligned band: width == cell count, no extra padding lane
    assert band_width(128, 15, 8) == 16          # need 16 -> exactly 16
    assert band_width(128, 31, 8) == 32          # need 32 -> exactly 32
    # unaligned band rounds up to the next lane multiple
    assert band_width(128, 12, 8) == 16          # need 13 -> 16
    assert band_width(128, 16, 8) == 24          # need 17 -> 24
    # capped at the series length
    assert band_width(64, 1000, 8) == 64
