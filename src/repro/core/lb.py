"""DTW lower bounds: Keogh envelopes, LB_Keogh (reversed), LB_Kim, cascade.

All bounds are for *squared* DTW cost, matching :mod:`repro.core.dtw`.

The paper reverses the query/data role of LB_Keogh: envelopes are built once
around the *codebook centroids* at training time, so encoding a fresh series
costs only O(D/M) per bound evaluation.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["keogh_envelope", "lb_keogh", "lb_kim", "lb_cascade"]


@functools.partial(jax.jit, static_argnames=("window",))
def keogh_envelope(x: jnp.ndarray, window: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Upper/lower Keogh envelope: rolling max/min over ``|shift| <= window``.

    ``x`` may be ``(L,)`` or batched ``(..., L)``.  Returns ``(U, L)`` with the
    same shape as ``x``.  Implemented as a stack of shifted copies (window is
    small after PQ partitioning), which vectorizes cleanly.
    """
    x = jnp.asarray(x, jnp.float32)
    L = x.shape[-1]
    shifts = jnp.arange(-window, window + 1)

    def shifted(s):
        rolled = jnp.roll(x, s, axis=-1)
        i = jnp.arange(L)
        valid = (i - s >= 0) & (i - s < L)
        hi = jnp.where(valid, rolled, -jnp.inf)
        lo = jnp.where(valid, rolled, jnp.inf)
        return hi, lo

    his, los = jax.vmap(shifted)(shifts)
    return jnp.max(his, axis=0), jnp.min(los, axis=0)


def lb_keogh(q: jnp.ndarray, upper: jnp.ndarray, lower: jnp.ndarray) -> jnp.ndarray:
    """LB_Keogh(q, c) given c's envelope — a lower bound on squared DTW(q, c).

    Broadcasts: ``q (..., L)`` against envelopes ``(..., L)``.
    """
    above = jnp.where(q > upper, (q - upper) ** 2, 0.0)
    below = jnp.where(q < lower, (lower - q) ** 2, 0.0)
    return jnp.sum(above + below, axis=-1)


def lb_kim(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Simplified LB_Kim: first and last points are always aligned by DTW,
    so their squared differences lower-bound the squared DTW cost."""
    return (q[..., 0] - c[..., 0]) ** 2 + (q[..., -1] - c[..., -1]) ** 2


def lb_cascade(q: jnp.ndarray, centroids: jnp.ndarray,
               upper: jnp.ndarray, lower: jnp.ndarray) -> jnp.ndarray:
    """Cascading bound used for the filter-then-refine encoder.

    ``q (L,)`` vs ``centroids (K, L)`` with envelopes ``(K, L)`` each.
    Returns the *tightest available* cheap bound per centroid:
    ``max(LB_Kim, reversed LB_Keogh)`` — both are valid lower bounds, so the
    max is too.
    """
    kim = lb_kim(q[None, :], centroids)
    keogh = lb_keogh(q[None, :], upper, lower)
    return jnp.maximum(kim, keogh)
