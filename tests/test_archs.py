"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates its REDUCED config (same family/structure,
laptop scale) and runs one forward pass, one train step, and — where the
family has a decode path — one serve step, asserting output shapes and
finite values.  The FULL configs are exercised only via the dry-run
(ShapeDtypeStructs, no allocation): tested here structurally.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import (ARCH_IDS, SHAPES, all_cells, get_config,
                                    get_reduced, shape_applicable)
from repro.launch.specs import abstract_params, input_specs
from repro.serve.cache import init_cache
from repro.serve.decode import prefill_cache_encdec, serve_step
from repro.train.optim import AdamWConfig
from repro.train.step import init_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family in ("vlm",):
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
class TestReducedSmoke:
    def test_train_step(self, arch):
        cfg = get_reduced(arch)
        key = jax.random.PRNGKey(0)
        state = init_train_state(key, cfg)
        step = make_train_step(cfg, AdamWConfig(lr=1e-3),
                               q_chunk=16, microbatches=2)
        state, metrics = jax.jit(step)(state, _batch(cfg, key))
        assert int(state.step) == 1
        loss = float(metrics["loss"])
        assert np.isfinite(loss) and loss > 0
        # params actually moved
        leaves0 = jax.tree.leaves(init_train_state(key, cfg).params)
        leaves1 = jax.tree.leaves(state.params)
        assert any(not np.allclose(np.asarray(a), np.asarray(b))
                   for a, b in zip(leaves0, leaves1))

    def test_serve_step(self, arch):
        cfg = get_reduced(arch)
        key = jax.random.PRNGKey(1)
        from repro.train.step import model_init
        params = model_init(cfg)(key, cfg)
        cache = init_cache(cfg, B, max_len=16)
        if cfg.family == "encdec":
            frames = jax.random.normal(
                key, (B, cfg.n_frontend_tokens, cfg.d_model))
            cache = prefill_cache_encdec(params, cfg, cache, frames)
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
        logits, new_cache = serve_step(params, cfg, cache, tok, 0)
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        # cache structure preserved, something was written
        assert jax.tree.structure(new_cache) == jax.tree.structure(cache)
        changed = any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(new_cache),
                            jax.tree.leaves(cache)))
        assert changed

    def test_full_config_is_abstractable(self, arch):
        """FULL config: abstract params + inputs build without allocation."""
        cfg = get_config(arch)
        p = abstract_params(cfg)
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
        # abstract leaf count should be within 2x of the analytic count
        # (analytic skips small norms/biases)
        assert n > 0.5 * cfg.param_count()
        for shape in SHAPES.values():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            assert all(hasattr(s, "shape") for s in jax.tree.leaves(specs))


def test_cell_enumeration_covers_40():
    cells = list(all_cells())
    assert len(cells) == 40
    applicable = [c for c in cells if c[2]]
    assert len(applicable) == 32          # 8 documented long_500k skips
    skipped = {(a, s.name) for a, s, ok, _ in cells if not ok}
    assert all(s == "long_500k" for _, s in skipped)
    assert ("mamba2-780m", "long_500k") not in skipped
    assert ("zamba2-2.7b", "long_500k") not in skipped
