"""Sharded query planner: scale search out over a device mesh.

Two partitioning strategies over the 1-D ``search`` axis of
:func:`repro.launch.mesh.make_search_mesh`:

* ``"queries"`` — the index (coarse centroids, codebook, sealed segments,
  hot buffer) is *replicated*; the query batch is padded to a multiple of
  the mesh size and sharded.  Each device runs the identical single-device
  plan (:func:`repro.index.streaming.search_impl`) on its query block —
  top-k over queries is embarrassingly parallel, so the only collective is
  the implicit output gather.  Padding rows carry a ``q_valid`` mask down
  the whole plan, so they are excluded from LB-cascade refine work and
  pruning statistics instead of burning wavefront sweeps.  Right when the
  index fits on every device and the query stream is wide.

* ``"lists"`` — the *data* is partitioned: sealed segments are laid out
  shard-major (:func:`repro.index.segments.seal` with ``n_shards`` equal
  to the mesh size, lists placed by :mod:`repro.index.placement`), and
  each device scans only its locally-placed inverted lists.  The query
  batch, coarse distances and query LUTs are replicated; every device
  ranks its local lists with the existing fine-stage kernels, scans a
  striped slice of the hot buffer, merges a device-local top-k, and the
  partial ``(topk, ids)`` tiles fan in with a device-resident
  ``all_gather`` + masked merge — no host round-trip.  Because every
  candidate row is scanned by exactly one device and the final merge
  re-ranks the union of all partials, results match the single-device
  plan exactly.  Right when the sealed codes outgrow one device's memory:
  per-device bytes shrink ~linearly with the mesh (see
  ``repro.core.pq.memory_cost`` ``max_device_bytes``).

On CPU (or any single-device runtime) both strategies degenerate to a
1-device mesh whose ``shard_map`` is bit-identical to the plain path, so
the planner is exercised by the tier-1 suite without TPU hardware.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .. import obs
from ..core.ivf import coarse_dists
from ..core.pq import query_lut_batch, segment
from ..launch.mesh import make_search_mesh, validate_search_mesh
from .streaming import (StreamingIndex, _merge_topk, _rank_segment,
                        _scan_hot, search_impl)

__all__ = ["search_sharded"]

_PARTITIONS = ("auto", "queries", "lists")


def _pad_queries(Q: jnp.ndarray, n_dev: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Pad ``Q`` to a multiple of ``n_dev`` rows; returns
    ``(Q_padded, q_valid, Nq)`` where ``q_valid`` masks the real rows."""
    Nq = Q.shape[0]
    pad = (-Nq) % n_dev
    if pad:
        Q = jnp.concatenate([Q, jnp.zeros((pad, Q.shape[1]), Q.dtype)], 0)
    q_valid = jnp.arange(Nq + pad) < Nq
    return Q, q_valid, Nq


def _search_query_sharded(index: StreamingIndex, Q: jnp.ndarray,
                          mesh: Mesh, n_probe: int, topk: int
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n_dev = mesh.shape["search"]
    Q, q_valid, Nq = _pad_queries(Q, n_dev)

    plan = (index.coarse, index.cb, tuple(index.segments),
            index._hot_arrays(), index.two_level)

    def per_device(plan, Qb, qv):
        coarse, cb, segs, hot, two_level = plan
        return search_impl(coarse, cb, segs, hot, Qb, icfg=index.cfg,
                           n_probe=n_probe, topk=topk, dim=index.dim,
                           two_level=two_level, q_valid=qv)

    # check_rep=False: jax has no replication rule for pallas_call, and the
    # out_specs fully describe the (embarrassingly parallel) output layout.
    with obs.span("sharded.execute") as sp:
        d, ids = sp.fence(shard_map(
            per_device, mesh=mesh,
            in_specs=(P(), P("search", None), P("search")),
            out_specs=(P("search", None), P("search", None)),
            check_rep=False)(plan, Q, q_valid))
    return d[:Nq], ids[:Nq]


def _search_list_sharded(index: StreamingIndex, Q: jnp.ndarray,
                         mesh: Mesh, n_probe: int, topk: int
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n_dev = mesh.shape["search"]
    icfg = index.cfg
    validate_search_mesh(mesh, icfg.n_shards)
    for sg in index.segments:
        if sg.n_shards != n_dev:
            raise ValueError(
                f"list-sharded search on a {n_dev}-device mesh needs every "
                f"segment sealed with n_shards={n_dev}, found a segment "
                f"with n_shards={sg.n_shards} — set "
                f"IndexConfig(n_shards={n_dev}) and compact() (or flush "
                f"new data) to re-seal the layout")

    Q = jnp.asarray(Q, jnp.float32)
    Nq = Q.shape[0]
    segs = tuple(index.segments)
    hot = index._hot_arrays()
    if not segs and hot is None:
        return (jnp.full((Nq, topk), jnp.inf),
                jnp.full((Nq, topk), -1, jnp.int32))

    spec = icfg.pq.measure()
    w = icfg.coarse_window(index.dim)
    # Replicated stages: the coarse ranking and the per-query LUTs are
    # tiny relative to the sealed codes, so they are computed once for the
    # full batch and broadcast — every device probes with identical
    # numbers, which is what makes the fan-in merge exact.
    with obs.span("sharded.coarse") as sp:
        dc = sp.fence(coarse_dists(
            Q, index.coarse, w, measure=spec,
            two_level=index.two_level,
            n_probe_top=icfg.n_probe_top if index.two_level
            is not None else None))                          # (Nq, n_lists)
    with obs.span("sharded.lut") as sp:
        qluts = sp.fence(query_lut_batch(
            segment(Q, icfg.pq), index.cb, icfg.pq.window(index.dim),
            not icfg.pq.is_elastic, spec))                   # (Nq, M, K)

    views = tuple(sg.shard_views() for sg in segs)
    metas = tuple((sg.max_list, min(topk, n_probe * sg.max_list))
                  for sg in segs)

    def per_device(dc, qluts, Qb, hot, views):
        # spans inside this function run under the shard_map trace: they
        # time tracing (once per compilation) and bridge the stage names
        # into device profiles via TraceAnnotation — per-call wall time
        # lives in the host-level "sharded.execute" span around the launch
        parts_d, parts_i = [], []
        with obs.span("sharded.device_scan"):
            for (codes, ids, live, loc_start, loc_len), (max_list, k) \
                    in zip(views, metas):
                if k < 1:
                    continue
                # leading shard axis is sliced to 1 by shard_map: [0] is
                # this device's block; loc_start/loc_len address rows
                # inside it, lists placed elsewhere have local length 0
                d, i = _rank_segment(codes[0], ids[0], live[0],
                                     loc_start[0], loc_len[0], dc, qluts,
                                     max_list=max_list, n_probe=n_probe,
                                     k=k)
                parts_d.append(d)
                parts_i.append(i)
            if hot is not None:
                data, h_ids, h_live = hot
                cap = data.shape[0]
                # stripe the (replicated) hot buffer: row r belongs to
                # device r % n_dev, so every live row is scanned by
                # exactly one device
                mine = (jnp.arange(cap) % n_dev
                        ) == jax.lax.axis_index("search")
                d, i = _scan_hot(data, h_ids, h_live & mine, Qb,
                                 window=w, k=min(topk, cap),
                                 euclidean=not icfg.pq.is_elastic,
                                 measure=spec)
                parts_d.append(d)
                parts_i.append(i)
        with obs.span("sharded.fanin_merge"):
            if parts_d:
                d_loc, i_loc = _merge_topk(tuple(parts_d), tuple(parts_i),
                                           topk=topk)
            else:
                d_loc = jnp.full((Qb.shape[0], topk), jnp.inf)
                i_loc = jnp.full((Qb.shape[0], topk), -1, jnp.int32)
            # device-resident fan-in: gather every device's partial top-k
            # and re-rank the union — the merged result is replicated, no
            # host round-trip.  Empty partial slots carry +inf / -1 and
            # lose to any real candidate, so padded lanes never surface.
            g_d = jax.lax.all_gather(d_loc, "search")  # (n_dev, Nq, topk)
            g_i = jax.lax.all_gather(i_loc, "search")
            all_d = jnp.moveaxis(g_d, 0, 1).reshape(
                Qb.shape[0], n_dev * topk)
            all_i = jnp.moveaxis(g_i, 0, 1).reshape(
                Qb.shape[0], n_dev * topk)
            neg, best = jax.lax.top_k(-all_d, topk)
            return -neg, jnp.take_along_axis(all_i, best, axis=1)

    view_spec = (P("search", None, None), P("search", None),
                 P("search", None), P("search", None), P("search", None))
    with obs.span("sharded.execute") as sp:
        d, ids = sp.fence(shard_map(
            per_device, mesh=mesh,
            in_specs=(P(), P(), P(), P(), tuple(view_spec for _ in views)),
            out_specs=(P(None, None), P(None, None)),
            check_rep=False)(dc, qluts, Q, hot, views))
    return d, ids


def search_sharded(index: StreamingIndex, Q: np.ndarray, *,
                   n_probe: int, topk: int = 1,
                   mesh: Optional[Mesh] = None,
                   partition: str = "auto"
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multi-device :meth:`StreamingIndex.search` -> ``(dist, ids)``.

    ``partition`` selects the strategy (module docstring): ``"queries"``
    replicates the index and shards the batch, ``"lists"`` partitions the
    sealed inverted lists across the mesh (requires segments sealed with
    ``n_shards`` equal to the mesh size) and fans the per-device partial
    top-k back in with a device-resident ``all_gather`` merge.  ``"auto"``
    picks ``"lists"`` when the index layout matches the mesh
    (``cfg.n_shards == n_devices > 1``) and ``"queries"`` otherwise.

    Results match the single-device path under either strategy — same
    kernels, same distances; candidate sets are identical, only the merge
    order of exact distance ties can differ.

    On a single-device host ``"auto"`` degrades to ``"queries"`` and the
    call is a drop-in for :meth:`StreamingIndex.search`:

    >>> import jax, numpy as np
    >>> from repro.core.pq import PQConfig
    >>> from repro.index.streaming import IndexConfig, StreamingIndex
    >>> cfg = IndexConfig(
    ...     PQConfig(n_sub=2, codebook_size=4, use_prealign=False,
    ...              kmeans_iters=1, dba_iters=1),
    ...     n_lists=2, hot_capacity=4, coarse_iters=2)
    >>> X = np.sin(np.arange(8 * 16, dtype=np.float32)).reshape(8, 16)
    >>> idx = StreamingIndex.bootstrap(jax.random.PRNGKey(0), X, cfg)
    >>> _ = idx.insert(X)
    >>> dist, ids = search_sharded(idx, X[:2], n_probe=2, topk=1)
    >>> ids.shape, int(ids[0, 0])
    ((2, 1), 0)
    """
    if partition not in _PARTITIONS:
        raise ValueError(
            f"partition={partition!r} must be one of {_PARTITIONS}")
    Q = index._validate(Q, n_probe, topk)
    mesh = mesh if mesh is not None else make_search_mesh()
    n_dev = mesh.shape["search"]
    if partition == "auto":
        partition = ("lists" if n_dev > 1 and index.cfg.n_shards == n_dev
                     else "queries")
    with obs.span("sharded.search"):
        if obs.enabled():
            obs.counter("sharded_searches_total", persistent=True,
                        partition=partition).inc()
        if partition == "lists":
            return _search_list_sharded(index, Q, mesh, n_probe, topk)
        return _search_query_sharded(index, Q, mesh, n_probe, topk)
