"""Segment containers for the streaming index.

Two kinds of segment, one searchable contract:

* :class:`HotBuffer` — host-side fixed-capacity staging area for raw
  series.  Inserts are numpy writes into pre-allocated buffers; the search
  path uploads the (small, constant-shape) buffers and runs exact banded
  DTW against every live row.
* :class:`SealedSegment` — an immutable device-resident inverted-list
  shard of PQ codes sharing the index-wide codebook.  Registered as a
  pytree with ``max_list`` as *static* metadata, so jitted search caches
  on segment shape, not segment identity: every flush-born segment is
  padded to the same ``capacity`` rows and reuses one compiled fine stage.

Row padding convention: dead rows carry ``ids == -1``, ``live == False``
and ``assign == n_lists`` (sorted past every real list, so no inverted
list ever addresses them — the ``live`` mask is a second line of defense).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ivf import build_lists

__all__ = ["HotBuffer", "SealedSegment", "seal"]


@partial(jax.tree_util.register_dataclass,
         data_fields=("codes", "ids", "live", "assign", "list_start",
                      "list_len"),
         meta_fields=("max_list",))
@dataclasses.dataclass(frozen=True)
class SealedSegment:
    codes: jnp.ndarray        # (rows, M) int32 PQ codes, list-sorted
    ids: jnp.ndarray          # (rows,) int32 external ids, -1 = padding
    live: jnp.ndarray         # (rows,) bool, False = deleted or padding
    assign: jnp.ndarray       # (rows,) int32 coarse list id, n_lists = pad
    list_start: jnp.ndarray   # (n_lists,) int32
    list_len: jnp.ndarray     # (n_lists,) int32
    max_list: int             # static: candidate width of the fine stage

    @property
    def rows(self) -> int:
        return self.codes.shape[0]

    @property
    def n_lists(self) -> int:
        return self.list_start.shape[0]

    def n_live(self) -> int:
        return int(jnp.sum(self.live))

    def tombstone(self, dead: np.ndarray) -> "SealedSegment":
        """New segment with ``dead`` (host bool mask over rows) deleted."""
        live = self.live & ~jnp.asarray(dead)
        return dataclasses.replace(self, live=live)


def seal(codes: np.ndarray, ids: np.ndarray, assign: np.ndarray,
         n_lists: int, rows: int,
         max_list: Optional[int] = None) -> SealedSegment:
    """Lay ``(n, M)`` codes out as a list-sorted segment padded to ``rows``.

    ``max_list`` is the static fine-stage width; it defaults to the true
    longest list.  Flush-born segments pass ``rows == max_list == hot
    capacity`` instead (same compiled search for every segment regardless
    of list skew); compaction takes the default so the merged shard prunes
    with its true longest list.
    """
    n = len(ids)
    if n > rows:
        raise ValueError(f"cannot seal {n} rows into a {rows}-row segment")
    order, start, length, true_max = build_lists(assign, n_lists)
    if max_list is None:
        max_list = true_max
    M = codes.shape[1]
    codes_p = np.zeros((rows, M), np.int32)
    ids_p = np.full((rows,), -1, np.int32)
    live_p = np.zeros((rows,), bool)
    assign_p = np.full((rows,), n_lists, np.int32)
    codes_p[:n] = codes[order]
    ids_p[:n] = ids[order]
    live_p[:n] = True
    assign_p[:n] = assign[order]
    return SealedSegment(
        codes=jnp.asarray(codes_p), ids=jnp.asarray(ids_p),
        live=jnp.asarray(live_p), assign=jnp.asarray(assign_p),
        list_start=jnp.asarray(start), list_len=jnp.asarray(length),
        max_list=int(max_list))


class HotBuffer:
    """Fixed-capacity staging buffer for raw series (host-side, mutable)."""

    def __init__(self, capacity: int, dim: int):
        self.capacity = int(capacity)
        self.dim = int(dim)
        self.data = np.zeros((capacity, dim), np.float32)
        self.ids = np.full((capacity,), -1, np.int32)
        self.live = np.zeros((capacity,), bool)
        self.count = 0                      # filled slots (live or dead)

    @property
    def space(self) -> int:
        return self.capacity - self.count

    def n_live(self) -> int:
        return int(self.live.sum())

    def append(self, X: np.ndarray, ids: np.ndarray) -> int:
        """Write up to ``space`` rows; returns how many were taken."""
        take = min(self.space, len(ids))
        if take:
            lo = self.count
            self.data[lo:lo + take] = X[:take]
            self.ids[lo:lo + take] = ids[:take]
            self.live[lo:lo + take] = True
            self.count += take
        return take

    def tombstone(self, dead_ids: np.ndarray) -> int:
        hit = np.isin(self.ids, dead_ids) & self.live
        self.live &= ~hit
        return int(hit.sum())

    def take_live(self) -> Tuple[np.ndarray, np.ndarray]:
        """Drain: return (live rows, their ids) and reset the buffer."""
        rows = self.data[self.live].copy()
        ids = self.ids[self.live].copy()
        self.ids[:] = -1
        self.live[:] = False
        self.count = 0
        return rows, ids
