"""deepseek-moe-16b [moe] — 2 shared + 64 routed experts, top-6,
fine-grained segmentation. [arXiv:2401.06066; hf]"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                # per-expert hidden size (fine-grained)
    moe_d_ff=1408,
    vocab_size=102400,
    n_experts=64,
    n_active_experts=6,
    n_shared_experts=2,
)

REDUCED = dataclasses.replace(
    CONFIG, name="deepseek-moe-16b-reduced", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=32, moe_d_ff=32, vocab_size=512,
    n_experts=8, n_active_experts=2, n_shared_experts=1)
