"""Single-token decode (``serve_step``) for every family.

``serve_step(params, cfg, cache, token, pos) -> (logits, new_cache)``

The layer stack is consumed with ``lax.scan`` carrying the hidden state and
threading per-layer cache slices through the scan outputs, so decode HLO
contains one block body per block type.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.layers import attention_decode, mlp, moe, rms_norm, rotary
from ..models.lm import LmParams, logits_from_hidden
from ..models.encdec import EncDecParams, cross_kv, encode_frames
from ..models.ssm import ssd_decode_step
from ..sharding.partition import constrain_batch

__all__ = ["serve_step", "prefill_cache_encdec"]


def _tok_embed(params, cfg: ModelConfig, token: jnp.ndarray) -> jnp.ndarray:
    x = params.embed[token].astype(jnp.bfloat16)      # (B, 1, d)
    if cfg.local_global:
        x = x * jnp.bfloat16(cfg.d_model ** 0.5)
    return x


def _dense_decode_block(blk, cfg, h, kc, vc, pos, *, window, cos_sin):
    a, kc, vc = attention_decode(blk.attn, cfg,
                                 rms_norm(h, blk.ln1, cfg.norm_eps),
                                 kc, vc, pos, window=window, cos_sin=cos_sin)
    if blk.post_attn_ln is not None:
        a = rms_norm(a, blk.post_attn_ln, cfg.norm_eps)
    h = h + a
    m = mlp(blk.mlp, rms_norm(h, blk.ln2, cfg.norm_eps), cfg.act)
    if blk.post_mlp_ln is not None:
        m = rms_norm(m, blk.post_mlp_ln, cfg.norm_eps)
    return h + m, kc, vc


def serve_step(params, cfg: ModelConfig, cache: Dict[str, Any],
               token: jnp.ndarray, pos) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """``token (B, 1)`` int32, ``pos`` scalar int32 (current write index)."""
    fam = cfg.family
    pos = jnp.asarray(pos, jnp.int32)
    if fam in ("dense", "moe", "vlm"):
        return _serve_decoder(params, cfg, cache, token, pos)
    if fam == "ssm":
        return _serve_ssm(params, cfg, cache, token)
    if fam == "hybrid":
        return _serve_hybrid(params, cfg, cache, token, pos)
    if fam == "encdec":
        return _serve_encdec(params, cfg, cache, token, pos)
    raise ValueError(fam)


def _decode_cos_sin(cfg, B, pos):
    positions = jnp.full((B, 1), pos, jnp.int32)
    return rotary(positions, cfg.head_dim_, cfg.rope_theta)


def _serve_decoder(params: LmParams, cfg, cache, token, pos):
    B = token.shape[0]
    x = constrain_batch(_tok_embed(params, cfg, token))
    cos_sin = _decode_cos_sin(cfg, B, pos)

    if cfg.local_global:
        # blocks stacked as (L/2, 2, ...); caches as (L, ...): regroup
        L = cfg.n_layers
        kc = cache["k"].reshape(L // 2, 2, *cache["k"].shape[1:])
        vc = cache["v"].reshape(L // 2, 2, *cache["v"].shape[1:])

        def body(h, inp):
            h = constrain_batch(h)
            blk_pair, kc2, vc2 = inp
            blk_l = jax.tree.map(lambda t: t[0], blk_pair)
            blk_g = jax.tree.map(lambda t: t[1], blk_pair)
            h, k0, v0 = _dense_decode_block(blk_l, cfg, h, kc2[0], vc2[0],
                                            pos, window=cfg.sliding_window,
                                            cos_sin=cos_sin)
            h, k1, v1 = _dense_decode_block(blk_g, cfg, h, kc2[1], vc2[1],
                                            pos, window=0, cos_sin=cos_sin)
            return h, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))

        x, (kc, vc) = jax.lax.scan(body, x, (params.blocks, kc, vc))
        new_cache = {"k": kc.reshape(L, *kc.shape[2:]),
                     "v": vc.reshape(L, *vc.shape[2:])}
    elif cfg.family == "moe":
        def body(h, inp):
            h = constrain_batch(h)
            blk, kc, vc = inp
            a, kc, vc = attention_decode(blk.attn, cfg,
                                         rms_norm(h, blk.ln1, cfg.norm_eps),
                                         kc, vc, pos, cos_sin=cos_sin)
            h = h + a
            h = h + moe(blk.moe, cfg, rms_norm(h, blk.ln2, cfg.norm_eps))
            return h, (kc, vc)
        x, (kc, vc) = jax.lax.scan(body, x, (params.blocks, cache["k"],
                                             cache["v"]))
        new_cache = {"k": kc, "v": vc}
    else:
        def body(h, inp):
            h = constrain_batch(h)
            blk, kc, vc = inp
            h, kc, vc = _dense_decode_block(blk, cfg, h, kc, vc, pos,
                                            window=0, cos_sin=cos_sin)
            return h, (kc, vc)
        x, (kc, vc) = jax.lax.scan(body, x, (params.blocks, cache["k"],
                                             cache["v"]))
        new_cache = {"k": kc, "v": vc}

    return logits_from_hidden(params, cfg, x), new_cache


def _serve_ssm(params: LmParams, cfg, cache, token):
    x = constrain_batch(params.embed[token].astype(jnp.bfloat16))

    def body(h, inp):
        h = constrain_batch(h)
        blk, ssd, cx, cB, cC = inp
        out, (ssd, cx, cB, cC) = ssd_decode_step(
            blk.ssm, cfg, rms_norm(h, blk.ln, cfg.norm_eps),
            (ssd, cx, cB, cC))
        return h + out, (ssd, cx, cB, cC)

    x, (ssd, cx, cB, cC) = jax.lax.scan(
        body, x, (params.blocks, cache["ssd"], cache["conv_x"],
                  cache["conv_B"], cache["conv_C"]))
    new_cache = {"ssd": ssd, "conv_x": cx, "conv_B": cB, "conv_C": cC}
    return logits_from_hidden(params, cfg, x), new_cache


def _serve_hybrid(params: LmParams, cfg, cache, token, pos):
    B = token.shape[0]
    x = constrain_batch(params.embed[token].astype(jnp.bfloat16))
    cos_sin = _decode_cos_sin(cfg, B, pos)
    shared = params.shared_attn

    def group_body(h, inp):
        h = constrain_batch(h)
        grp_blocks, ak, av, ssd, cx, cB, cC = inp
        h, ak, av = _dense_decode_block(shared, cfg, h, ak, av, pos,
                                        window=0, cos_sin=cos_sin)

        def inner(hh, blk_state):
            blk, s0, s1, s2, s3 = blk_state
            out, (s0, s1, s2, s3) = ssd_decode_step(
                blk.ssm, cfg, rms_norm(hh, blk.ln, cfg.norm_eps),
                (s0, s1, s2, s3))
            return hh + out, (s0, s1, s2, s3)

        h, (ssd, cx, cB, cC) = jax.lax.scan(
            inner, h, (grp_blocks, ssd, cx, cB, cC))
        return h, (ak, av, ssd, cx, cB, cC)

    x, (ak, av, ssd, cx, cB, cC) = jax.lax.scan(
        group_body, x,
        (params.blocks, cache["attn_k"], cache["attn_v"], cache["ssd"],
         cache["conv_x"], cache["conv_B"], cache["conv_C"]))
    new_cache = {"attn_k": ak, "attn_v": av, "ssd": ssd, "conv_x": cx,
                 "conv_B": cB, "conv_C": cC}
    return logits_from_hidden(params, cfg, x), new_cache


def _serve_encdec(params: EncDecParams, cfg, cache, token, pos):
    B = token.shape[0]
    x = constrain_batch(params.embed[token].astype(jnp.bfloat16))
    cos_sin = _decode_cos_sin(cfg, B, pos)
    zero_cos_sin = rotary(jnp.zeros((B, 1), jnp.int32), cfg.head_dim_,
                          cfg.rope_theta)

    def body(h, inp):
        h = constrain_batch(h)
        blk, sk, sv, ck, cv = inp
        a, sk, sv = attention_decode(blk.self_attn, cfg,
                                     rms_norm(h, blk.ln1, cfg.norm_eps),
                                     sk, sv, pos, cos_sin=cos_sin)
        h = h + a
        c, _, _ = attention_decode(blk.cross_attn, cfg,
                                   rms_norm(h, blk.ln_x, cfg.norm_eps),
                                   ck, cv, jnp.int32(ck.shape[1] - 1),
                                   update_cache=False, cos_sin=zero_cos_sin)
        h = h + c
        h = h + mlp(blk.mlp, rms_norm(h, blk.ln2, cfg.norm_eps), cfg.act)
        return h, (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        body, x, (params.dec_blocks, cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    new_cache = dict(cache)
    new_cache["self_k"] = sk
    new_cache["self_v"] = sv
    return logits_from_hidden(params, cfg, x), new_cache


def prefill_cache_encdec(params: EncDecParams, cfg, cache, frames,
                         q_chunk: int = 512):
    """Run the encoder once and fill the cross-attention K/V cache."""
    enc_out = encode_frames(params, cfg, frames, q_chunk=q_chunk, remat=False)

    def per_layer(blk):
        k, v = cross_kv(blk.cross_attn, cfg, enc_out)
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

    ck, cv = jax.vmap(per_layer)(params.dec_blocks)
    new_cache = dict(cache)
    new_cache["cross_k"] = ck
    new_cache["cross_v"] = cv
    return new_cache
