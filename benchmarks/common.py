"""Shared benchmark harness: timing, result records, CSV/JSON output."""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import jax

__all__ = ["timeit", "Bench", "OUT_DIR", "SMOKE", "set_smoke"]

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")

# CI smoke mode (benchmarks/run.py --smoke): every suite runs its quick
# sizes with a single repetition — the goal is "the benchmark still runs
# and emits JSON", not stable numbers.
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def set_smoke(on: bool = True) -> None:
    global SMOKE
    SMOKE = on


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1,
           **kwargs) -> Dict[str, float]:
    """Median wall time of ``fn(*args)`` with jit warmup; blocks on results."""
    if SMOKE:
        repeats, warmup = 1, min(warmup, 1)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    times.sort()
    return {"median_s": times[len(times) // 2], "min_s": times[0],
            "max_s": times[-1], "repeats": repeats}


class Bench:
    """Collects rows, prints a table, persists JSON."""

    def __init__(self, name: str):
        self.name = name
        self.rows: List[dict] = []

    def add(self, **row):
        self.rows.append(row)
        print("  " + " ".join(f"{k}={_fmt(v)}" for k, v in row.items()),
              flush=True)

    def save(self) -> str:
        os.makedirs(OUT_DIR, exist_ok=True)
        path = os.path.join(OUT_DIR, f"{self.name}.json")
        with open(path, "w") as f:
            json.dump({"name": self.name, "rows": self.rows}, f, indent=1)
        return path


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)
