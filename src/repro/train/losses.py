"""Training losses: next-token cross-entropy with z-loss regularizer."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["next_token_loss"]


def next_token_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                    z_loss: float = 1e-4,
                    ignore_id: int = -100) -> Tuple[jnp.ndarray, dict]:
    """``logits (B, S, V)`` vs ``labels (B, S)``; returns (loss, metrics).

    ``labels`` already aligned (caller shifts); ``ignore_id`` masked out.
    z-loss (log^2 Z) keeps the softmax normalizer from drifting — standard
    large-scale stabilizer.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)                      # (B, S)
    label_safe = jnp.maximum(labels, 0)
    picked = jnp.take_along_axis(logits, label_safe[..., None],
                                 axis=-1)[..., 0]
    nll = lse - picked
    mask = (labels != ignore_id).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = jnp.sum(nll * mask) / denom
    zl = jnp.sum((lse ** 2) * mask) / denom
    loss = ce + z_loss * zl
    metrics = {"ce": ce, "z_loss": zl,
               "ppl": jnp.exp(jnp.clip(ce, 0.0, 20.0)),
               "tokens": mask.sum()}
    return loss, metrics
