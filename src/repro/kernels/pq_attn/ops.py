# repro: ignore[RS202] serving-side attention kernel, consumed directly
# by serve/pqkv (one-hot contraction formulation), not an elastic
# dispatch op
"""Jitted public wrapper: PQ-KV decode attention (one new token vs a
PQ-compressed KV cache)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import cdiv, default_interpret
from .kernel import make_pq_attn_call

__all__ = ["pq_attn_decode", "build_qlut", "encode_keys"]


def build_qlut(q: jnp.ndarray, k_books: jnp.ndarray) -> jnp.ndarray:
    """ADC tables: ``q (H, D)``, ``k_books (G, M, K, D/M)`` -> ``(H, M, K)``.

    ``qlut[h, m, k] = q[h, m-th slice] . k_books[group(h), m, k]``.
    """
    H, D = q.shape
    G, M, K, Ds = k_books.shape
    R = H // G
    qr = q.reshape(G, R, M, Ds)
    return jnp.einsum("grmd,gmkd->grmk", qr, k_books).reshape(H, M, K)


def encode_keys(k: jnp.ndarray, k_books: jnp.ndarray) -> jnp.ndarray:
    """Quantize keys: ``k (S, G, D)``, books ``(G, M, K, D/M)`` -> ``(S, G, M)``.

    Euclidean nearest codeword per subspace (the standard PQ encoder; keys
    are feature vectors, not time series, so ED is the right metric here).
    """
    S, G, D = k.shape
    _, M, K, Ds = k_books.shape
    ks = k.reshape(S, G, M, Ds)
    # d2[s,g,m,k] = |ks - book|^2
    d2 = (jnp.sum(ks ** 2, -1)[..., None]
          - 2.0 * jnp.einsum("sgmd,gmkd->sgmk", ks, k_books)
          + jnp.sum(k_books ** 2, -1)[None])
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("valid_len", "block_s", "interpret"))
def pq_attn_decode(q: jnp.ndarray, k_codes: jnp.ndarray,
                   k_books: jnp.ndarray, v: jnp.ndarray,
                   valid_len: Optional[int] = None, block_s: int = 128,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Approximate decode attention against a PQ-compressed key cache.

    Args:
      q:        (H, D) query for the new token.
      k_codes:  (S, G, M) int32 PQ codes of cached keys.
      k_books:  (G, M, K, D/M) per-group codebooks.
      v:        (S, G, Dv) exact cached values.
      valid_len: number of real cache entries (rest masked); default S.

    Returns (H, Dv) attention output.
    """
    if interpret is None:
        interpret = default_interpret()
    H, D = q.shape
    S, G, M = k_codes.shape
    K = k_books.shape[2]
    Dv = v.shape[-1]
    if valid_len is None:
        valid_len = S
    scale = 1.0 / (D ** 0.5)

    qlut = build_qlut(q.astype(jnp.float32), k_books.astype(jnp.float32))
    block_s = min(block_s, S)
    Sp = cdiv(S, block_s) * block_s
    pad = Sp - S
    codes = jnp.pad(k_codes.astype(jnp.int32), ((0, pad), (0, 0), (0, 0)))
    vv = jnp.pad(v.astype(jnp.float32), ((0, pad), (0, 0), (0, 0)))

    call = make_pq_attn_call(H, Sp, G, M, K, Dv, scale, block_s,
                             int(valid_len), interpret)
    return call(qlut.reshape(H, M * K),
                codes.reshape(Sp, G * M),
                vv.reshape(Sp, G * Dv))
