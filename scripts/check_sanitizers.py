#!/usr/bin/env python3
"""Dynamic sanitizer leg over the kernel/dispatch surface: every elastic
op on both backends under JAX's tracer-leak checker and a
device-to-host transfer guard.

Usage: python scripts/check_sanitizers.py

Two passes per (op, backend):

* ``jax.checking_leaks()`` around a fresh ``jax.jit`` trace of the op —
  a helper stashing a tracer in module/closure state (the bug class
  RS104 guards statically) fails here with a named leak;
* ``jax.transfer_guard_device_to_host("disallow")`` around an eager
  replay on device-resident inputs — any hidden ``.item()`` /
  ``np.asarray`` / implicit host pull inside an op body (the RS101 bug
  class) raises.  Only the device-to-host direction is guarded:
  constant uploads at trace time are legitimate, silent result pulls
  are not.

Backends: ``jax`` (jnp reference route) and ``pallas_interpret`` (the
kernel bodies, interpretable on CPU).  Exit 0 clean, 1 on any sanitizer
trip.
"""

from __future__ import annotations

import sys
import traceback
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import dispatch  # noqa: E402

BACKENDS = ("jax", "pallas_interpret")


def _ops():
    """(name, thunk) per dispatch op, on tiny device-resident inputs."""
    A = jnp.zeros((2, 8))
    B = jnp.ones((2, 8))
    B3 = jnp.ones((3, 8))
    codes = jnp.array([[0, 1], [1, 0]], jnp.int32)
    lut = jnp.stack([1.0 - jnp.eye(2)] * 2)
    qlut = jnp.array([[0.0, 2.0], [0.0, 2.0]])
    env = jnp.zeros((2, 8))
    thresh = jnp.array([100.0, 0.0])
    cents = jnp.stack([jnp.zeros((2, 5)), jnp.ones((2, 5))], axis=1)
    coarse = jnp.arange(4, dtype=jnp.float32)[:, None] * jnp.ones(8)
    top = jnp.array([[0.5] * 8, [2.5] * 8])
    child_idx = jnp.array([[0, 1], [2, 3]], jnp.int32)
    child_valid = jnp.ones((2, 2), bool)

    yield (
        "elastic_pairwise",
        lambda: dispatch.elastic_pairwise(A, B, 2),
    )
    yield (
        "elastic_cdist",
        lambda: dispatch.elastic_cdist(A, B3, 2),
    )
    yield (
        "adc_cdist",
        lambda: dispatch.adc_cdist(codes, codes, lut),
    )
    yield (
        "adc_lookup",
        lambda: dispatch.adc_lookup(codes, qlut),
    )
    yield (
        "prealign_encode",
        lambda: dispatch.prealign_encode(A, cents, level=1, tail=1, window=2),
    )
    yield (
        "lb_refine",
        lambda: dispatch.lb_refine(A, B, env, env, thresh, 2),
    )
    yield (
        "two_level_coarse",
        lambda: dispatch.two_level_coarse(
            A, top, coarse, child_idx, child_valid, n_probe_top=1
        ),
    )


def main() -> int:
    failures = []
    for backend in BACKENDS:
        with dispatch.use_backend(backend):
            for name, thunk in _ops():
                try:
                    with jax.checking_leaks():
                        out = jax.jit(thunk)()
                    jax.block_until_ready(out)
                except Exception:
                    failures.append((backend, name, "checking_leaks"))
                    traceback.print_exc()
                    continue
                try:
                    with jax.transfer_guard_device_to_host("disallow"):
                        out = thunk()
                    jax.block_until_ready(out)
                except Exception:
                    failures.append((backend, name, "transfer_guard"))
                    traceback.print_exc()
                    continue
                print(f"  ok {backend}:{name} (leak check + d2h guard)")
    if failures:
        print(f"FAIL: {len(failures)} sanitizer trip(s):")
        for backend, name, leg in failures:
            print(f"  {backend}:{name} failed under {leg}")
        return 1
    n_ops = sum(1 for _ in _ops()) * len(BACKENDS)
    print(f"OK: {n_ops} (op, backend) legs clean under both sanitizers")
    return 0


if __name__ == "__main__":
    sys.exit(main())
