"""Encoder-decoder backbone (seamless-m4t style).

The speech frontend is a stub: the encoder consumes precomputed frame
embeddings ``(B, S_frames, d_model)`` (assignment spec).  Encoder blocks are
bidirectional; decoder blocks add cross-attention to the encoder output.
Cross-attention queries use position 0 rope tables (identity rotation), and
cross K/V are built without rope, the usual enc-dec convention.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (AttnParams, MlpParams, _dot, attention,
                     init_attn, init_mlp, mlp, rms_norm, rotary)
from .lm import logits_from_hidden
from ..sharding.partition import constrain_batch

__all__ = ["EncBlock", "DecBlock", "EncDecParams", "init_params_encdec",
           "forward_encdec", "encode_frames", "cross_kv"]


class EncBlock(NamedTuple):
    ln1: jnp.ndarray
    attn: AttnParams
    ln2: jnp.ndarray
    mlp: MlpParams


class DecBlock(NamedTuple):
    ln1: jnp.ndarray
    self_attn: AttnParams
    ln_x: jnp.ndarray
    cross_attn: AttnParams
    ln2: jnp.ndarray
    mlp: MlpParams


class EncDecParams(NamedTuple):
    embed: jnp.ndarray          # (Vp, d) decoder token embeddings
    frame_proj: jnp.ndarray     # (d, d) frontend-stub projection
    enc_blocks: Any             # stacked EncBlock
    enc_norm: jnp.ndarray
    dec_blocks: Any             # stacked DecBlock
    final_norm: jnp.ndarray
    lm_head: Optional[jnp.ndarray]
    # lm.logits_from_hidden compatibility
    patch_proj: Optional[jnp.ndarray] = None


def _zeros_d(cfg):
    return jnp.zeros((cfg.d_model,), jnp.float32)


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params_encdec(key: jax.Array, cfg: ModelConfig) -> EncDecParams:
    keys = jax.random.split(key, cfg.n_enc_layers + cfg.n_layers + 3)
    Vp, d = cfg.padded_vocab, cfg.d_model

    def enc(i):
        k1, k2 = jax.random.split(keys[i])
        return EncBlock(ln1=_zeros_d(cfg), attn=init_attn(k1, cfg),
                        ln2=_zeros_d(cfg), mlp=init_mlp(k2, d, cfg.d_ff))

    def dec(i):
        k1, k2, k3 = jax.random.split(keys[cfg.n_enc_layers + i], 3)
        return DecBlock(ln1=_zeros_d(cfg), self_attn=init_attn(k1, cfg),
                        ln_x=_zeros_d(cfg), cross_attn=init_attn(k2, cfg),
                        ln2=_zeros_d(cfg), mlp=init_mlp(k3, d, cfg.d_ff))

    return EncDecParams(
        embed=jax.random.normal(keys[-1], (Vp, d), jnp.float32) * 0.02,
        frame_proj=jax.random.normal(keys[-2], (d, d), jnp.float32) * 0.02,
        enc_blocks=_stack([enc(i) for i in range(cfg.n_enc_layers)]),
        enc_norm=_zeros_d(cfg),
        dec_blocks=_stack([dec(i) for i in range(cfg.n_layers)]),
        final_norm=_zeros_d(cfg),
        lm_head=jax.random.normal(keys[-3], (Vp, d), jnp.float32) * 0.02)


def encode_frames(params: EncDecParams, cfg: ModelConfig,
                  frames: jnp.ndarray, *, q_chunk: int = 512,
                  remat: bool = True) -> jnp.ndarray:
    """Bidirectional encoder over frontend-stub frames ``(B, Sf, d)``."""
    x = jnp.einsum("bfd,de->bfe", frames.astype(jnp.bfloat16),
                   params.frame_proj.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32).astype(jnp.bfloat16)
    B, Sf, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(Sf, dtype=jnp.int32)[None], (B, Sf))
    cos_sin = rotary(positions, cfg.head_dim_, cfg.rope_theta)

    def body(h, blk):
        h = constrain_batch(h)
        a = attention(blk.attn, cfg, rms_norm(h, blk.ln1, cfg.norm_eps),
                      positions, causal=False, q_chunk=q_chunk,
                      cos_sin=cos_sin)
        h = h + a
        return constrain_batch(
            h + mlp(blk.mlp, rms_norm(h, blk.ln2, cfg.norm_eps), cfg.act)), None

    body = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body, x, params.enc_blocks)
    return rms_norm(x, params.enc_norm, cfg.norm_eps)


def cross_kv(blk_cross: AttnParams, cfg: ModelConfig, enc_out: jnp.ndarray):
    """Per-layer cross-attention K/V from encoder output (no rope)."""
    B, Sf, _ = enc_out.shape
    G, hd = cfg.n_kv_heads, cfg.head_dim_
    k = _dot(enc_out, blk_cross.wk, blk_cross.bk).reshape(B, Sf, G, hd)
    v = _dot(enc_out, blk_cross.wv, blk_cross.bv).reshape(B, Sf, G, hd)
    return k, v


def forward_encdec(params: EncDecParams, cfg: ModelConfig, batch, *,
                   q_chunk: int = 512, remat: bool = True,
                   return_hidden: bool = False) -> jnp.ndarray:
    """``batch = {frames (B, Sf, d), tokens (B, S)}`` -> logits (B, S, Vp)."""
    enc_out = encode_frames(params, cfg, batch["frames"], q_chunk=q_chunk,
                            remat=remat)
    B, Sf, _ = enc_out.shape
    tokens = batch["tokens"]
    S = tokens.shape[1]
    x = params.embed[tokens].astype(jnp.bfloat16)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cos_sin = rotary(positions, cfg.head_dim_, cfg.rope_theta)
    zero_pos = jnp.zeros_like(positions)
    zero_cos_sin = rotary(zero_pos, cfg.head_dim_, cfg.rope_theta)
    kv_mask = jnp.ones((B, Sf), bool)

    def body(h, blk):
        h = constrain_batch(h)
        a = attention(blk.self_attn, cfg, rms_norm(h, blk.ln1, cfg.norm_eps),
                      positions, causal=True, q_chunk=q_chunk, cos_sin=cos_sin)
        h = h + a
        k, v = cross_kv(blk.cross_attn, cfg, enc_out)
        c = attention(blk.cross_attn, cfg, rms_norm(h, blk.ln_x, cfg.norm_eps),
                      zero_pos, causal=False, q_chunk=q_chunk,
                      cos_sin=zero_cos_sin, kv_override=(k, v, kv_mask))
        h = h + c
        return constrain_batch(
            h + mlp(blk.mlp, rms_norm(h, blk.ln2, cfg.norm_eps), cfg.act)), None

    body = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body, x, params.dec_blocks)
    if return_hidden:
        return x
    return logits_from_hidden(params, cfg, x)
