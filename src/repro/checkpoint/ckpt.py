"""Fault-tolerant checkpointing.

Design for 1000+ node runs:
  * atomic step directories (write to ``.tmp-<step>``, fsync, rename) — a
    crash mid-write never corrupts the latest checkpoint;
  * ``keep_last`` garbage collection;
  * async writer thread — training never blocks on storage;
  * elastic restore: leaves are stored *unsharded* (gathered) with a JSON
    manifest, so a restart may use a different mesh/device count — the
    restore path lays leaves out for whatever sharding the new run asks for.

On a real multi-host pod the gather/save would be per-host chunked (e.g.
tensorstore); the storage format and crash-safety protocol are identical.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer",
           "begin_atomic_dir", "write_manifest", "commit_atomic_dir",
           "latest_numbered_dir", "gc_numbered_dirs", "MANIFEST"]

MANIFEST = "manifest.json"
_MANIFEST = MANIFEST


# ---------------------------------------------------------------------------
# Atomic-directory protocol (shared with repro.index.snapshot)
#
# Writers populate a ``.tmp-<name>`` staging directory, fsync a manifest as
# the commit record, then rename over the final path (an existing version
# is moved to a ``.old-<name>`` aside first, never deleted in place): a
# crash at any point leaves a complete version on disk — as the final dir,
# or as an aside that discovery (:func:`latest_numbered_dir`) renames back —
# plus at worst stale staging dirs that the next writer clears.  Never a
# torn read.
# ---------------------------------------------------------------------------

def begin_atomic_dir(directory: str, name: str) -> str:
    """Create (clearing any stale leftover) the staging dir for ``name``."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f".tmp-{name}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    return tmp


def write_manifest(tmp: str, manifest: dict) -> None:
    """fsync'd manifest write — the durability point of the protocol."""
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())


def commit_atomic_dir(tmp: str, directory: str, name: str) -> str:
    """Atomically publish the staged dir as ``directory/name``.

    Durability order: every staged file is fsync'd *before* the rename (a
    published manifest must never point at torn data blocks), and the
    parent directory is fsync'd *after* it (the rename itself survives the
    crash).
    """
    for fn in os.listdir(tmp):
        fd = os.open(os.path.join(tmp, fn), os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
    tfd = os.open(tmp, os.O_RDONLY)      # the staged dirents themselves
    try:
        os.fsync(tfd)
    finally:
        os.close(tfd)
    final = os.path.join(directory, name)
    # Re-publishing an existing name: move the old version aside rather
    # than deleting it first, so no crash window destroys the only copy
    # (the ".old-" prefix keeps it invisible to latest_numbered_dir).
    old = os.path.join(directory, f".old-{name}")
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(final):
        os.rename(final, old)
    os.rename(tmp, final)
    shutil.rmtree(old, ignore_errors=True)
    dfd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
    return final


def _recover_old_dirs(directory: str, prefix: str) -> None:
    """Crash recovery for the re-publish window of :func:`commit_atomic_dir`:
    a ``.old-<name>`` aside whose ``<name>`` is gone means the process died
    between the two renames — the aside IS the newest complete version, so
    rename it back into discoverability."""
    for d in os.listdir(directory):
        if not d.startswith(f".old-{prefix}"):
            continue
        final = os.path.join(directory, d[len(".old-"):])
        if os.path.exists(final):
            continue                 # superseded; next commit cleans it up
        try:
            os.rename(os.path.join(directory, d), final)
        except OSError:
            pass                     # read-only fs / concurrent writer


def latest_numbered_dir(directory: str, prefix: str) -> Optional[int]:
    """Newest committed (manifest-bearing) ``<prefix><n>`` dir, or None."""
    if not os.path.isdir(directory):
        return None
    _recover_old_dirs(directory, prefix)
    steps = [int(d[len(prefix):]) for d in os.listdir(directory)
             if d.startswith(prefix)
             and os.path.exists(os.path.join(directory, d, MANIFEST))]
    return max(steps) if steps else None


def gc_numbered_dirs(directory: str, keep_last: int, prefix: str) -> None:
    """Drop all but the newest ``keep_last`` ``<prefix><n>`` dirs."""
    dirs = sorted(d for d in os.listdir(directory) if d.startswith(prefix))
    for d in dirs[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _ in flat:
        parts = []
        for k in path:
            if hasattr(k, "name"):
                parts.append(str(k.name))
            elif hasattr(k, "key"):
                parts.append(str(k.key))
            else:
                parts.append(str(getattr(k, "idx", k)))
        names.append("__".join(parts) or "leaf")
    return flat, treedef, names


def save(directory: str, step: int, tree: Any, keep_last: int = 3) -> str:
    """Atomically persist ``tree`` under ``directory/step_<step>``."""
    name = f"step_{step:010d}"
    tmp = begin_atomic_dir(directory, name)
    flat, _, names = _leaf_paths(tree)
    manifest = {"step": step, "leaves": []}
    for (path, leaf), leaf_name in zip(flat, names):
        # repro: ignore[RS101] checkpoint persistence requires host copies
        arr = np.asarray(jax.device_get(leaf))
        fn = f"{len(manifest['leaves']):05d}_{leaf_name[:80]}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append({"file": fn, "name": leaf_name,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    write_manifest(tmp, manifest)
    final = commit_atomic_dir(tmp, directory, name)
    _gc(directory, keep_last)
    return final


def _gc(directory: str, keep_last: int) -> None:
    gc_numbered_dirs(directory, keep_last, "step_")


def latest_step(directory: str) -> Optional[int]:
    return latest_numbered_dir(directory, "step_")


def restore(directory: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Load step ``step`` into the structure of ``like``.

    ``shardings`` (optional pytree of NamedSharding, same structure) lays
    each leaf out for the *current* mesh — elastic restart across different
    device counts.
    """
    d = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    flat, treedef, _ = _leaf_paths(like)
    assert len(flat) == len(manifest["leaves"]), \
        f"checkpoint has {len(manifest['leaves'])} leaves, model expects {len(flat)}"
    shard_flat = (jax.tree.leaves(shardings,
                                  is_leaf=lambda x: hasattr(x, "spec"))
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for meta, (path, ref), sh in zip(manifest["leaves"], flat, shard_flat):
        arr = np.load(os.path.join(d, meta["file"]))
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Background writer: ``submit`` returns immediately; ``wait`` blocks."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.directory, step, tree, self.keep_last)
            except BaseException as e:  # surfaced on next submit/wait
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree: Any) -> None:
        if self._err:
            raise self._err
        # device_get now so the training arrays can be donated/overwritten
        # repro: ignore[RS101] snapshot-for-write must leave the device
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host_tree))

    def wait(self) -> None:
        self._q.join()
        if self._err:
            raise self._err

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)
