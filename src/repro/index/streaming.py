"""StreamingIndex — LSM-style lifecycle over IVF-PQDTW shards.

Write path (host-side, numpy): ``insert`` fills the fixed-capacity
:class:`~repro.index.segments.HotBuffer`; a full buffer auto-``flush``\\ es
into a :class:`~repro.index.segments.SealedSegment` — PQ codes against the
*shared* codebook, list-sorted under the *shared* coarse quantizer.  Both
quantizers are trained once (``bootstrap``) and never change afterwards,
which is what makes segments mergeable: ``compact`` concatenates live rows
and re-balances the inverted lists without touching a single code.

Read path (device-side, jitted): one coarse-DTW launch + one query-LUT
launch for the whole batch (shared by every segment), then a per-segment
fine stage (:func:`repro.core.ivf.fine_rank`) and an exact LB-cascade
filter-and-refine scan of the hot buffer, merged with a final
``lax.top_k``.  All shapes are
static: flush-born segments share one compiled fine stage, the hot scan is
always ``(Nq, capacity)``, and tombstones are masks, not re-layouts.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core.dtw import euclidean_sq
from ..core.ivf import (TwoLevelCoarse, build_two_level, coarse_assign,
                        coarse_dists, fine_rank, validate_codebook,
                        validate_n_probe)
from ..core.lb_search import filtered_topk
from ..core.kmeans import dba_kmeans
from ..core.pq import (PQCodebook, PQConfig, encode, fit, memory_cost,
                       query_lut_batch, segment)
from .segments import HotBuffer, SealedSegment, seal

__all__ = ["IndexConfig", "StreamingIndex"]


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Lifecycle hyper-parameters around a :class:`PQConfig`.

    ``n_shards`` is the data-partition count of the sealed layout: every
    segment is sealed shard-major for ``n_shards`` devices
    (:mod:`repro.index.placement`), which the list-sharded planner
    (:func:`repro.index.planner.search_sharded`) maps 1:1 onto the search
    mesh.  ``n_shards == 1`` is the historical replicated layout.

    ``n_top_lists > 0`` enables the hierarchical (two-level) coarse
    quantizer: queries rank ``n_top_lists`` top cells and fan out to the
    children of their ``n_probe_top`` nearest — an ``O(n_top +
    fan_out)`` coarse stage instead of ``O(n_lists)``.  With
    ``n_probe_top == n_top_lists`` results match the flat stage exactly.

    ``band="adaptive"`` switches the hot-buffer elastic scan to per-pair
    alignment corridors (:mod:`repro.core.corridor`): narrower registers,
    faster sweeps, documented *approximate* results — the certified-exact
    LB cascade applies to the default ``"static"`` band only.

    >>> from repro.core.pq import PQConfig
    >>> cfg = IndexConfig(PQConfig(n_sub=2, codebook_size=4), n_lists=4)
    >>> cfg.coarse_window(48)
    5
    >>> IndexConfig(PQConfig(), n_lists=4, n_probe_top=2)
    Traceback (most recent call last):
        ...
    ValueError: n_probe_top=2 requires a two-level coarse quantizer (set n_top_lists > 0)
    """
    pq: PQConfig
    n_lists: int = 8
    hot_capacity: int = 128
    coarse_iters: int = 8
    coarse_window_frac: float = 0.1
    n_shards: int = 1
    n_top_lists: int = 0
    n_probe_top: int = 0
    band: str = "static"

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards={self.n_shards} must be >= 1")
        if self.band not in ("static", "adaptive"):
            raise ValueError(f"band={self.band!r} must be 'static' or "
                             f"'adaptive'")
        if self.n_top_lists:
            if not 1 <= self.n_top_lists <= self.n_lists:
                raise ValueError(
                    f"n_top_lists={self.n_top_lists} out of range: must "
                    f"satisfy 1 <= n_top_lists <= n_lists={self.n_lists}")
            if not 1 <= self.n_probe_top <= self.n_top_lists:
                raise ValueError(
                    f"n_probe_top={self.n_probe_top} out of range: must "
                    f"satisfy 1 <= n_probe_top <= n_top_lists="
                    f"{self.n_top_lists}")
        elif self.n_probe_top:
            raise ValueError(
                f"n_probe_top={self.n_probe_top} requires a two-level "
                f"coarse quantizer (set n_top_lists > 0)")

    def coarse_window(self, D: int) -> int:
        return max(1, int(round(self.coarse_window_frac * D)))


# ---------------------------------------------------------------------------
# Pure search math (shared by StreamingIndex.search and the sharded planner)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_list", "n_probe", "k"))
def _rank_segment(codes, ids, live, list_start, list_len, dc, qluts, *,
                  max_list: int, n_probe: int, k: int):
    """vmap'd fine stage over one sealed segment -> ``(Nq, k)`` d, ids.

    Jitted per *shape*, not per segment: every flush-born segment (same
    padded rows, same ``max_list`` = hot capacity) reuses one compiled
    fine stage regardless of how many segments exist."""
    fn = lambda dcr, ql: fine_rank(codes, ids, list_start, list_len,
                                   max_list, dcr, ql, n_probe, k, live=live)
    return jax.vmap(fn)(dc, qluts)


@functools.partial(jax.jit, static_argnames=("window", "k", "euclidean",
                                             "measure", "with_stats",
                                             "band"))
def _scan_hot(data, ids, live, Q, q_valid=None, *, window: int, k: int,
              euclidean: bool, measure=None, with_stats: bool = False,
              band: str = "static"):
    """Exact scan of the hot buffer -> ``(Nq, k)`` d, ids.

    The configured elastic measure under PQDTW-style metrics, squared
    Euclidean under the PQ_ED
    baseline — matching the metric the sealed segments' LUTs encode, so
    hot and sealed distances stay order-compatible in the merge.  The
    elastic path runs the LB-cascade filter-and-refine top-k
    (:func:`repro.core.lb_search.filtered_topk`): every (query, hot row)
    pair is bounded cheaply and only candidates the cascade cannot exclude
    reach the exact banded wavefront — same distances, fewer sweeps.
    Measures without the pruning capabilities take its exact dense
    fallback automatically.  ``q_valid`` is the optional query padding
    mask of the sharded planner — masked rows produce ``inf``/``-1`` and
    never claim LB-cascade refine work.

    ``with_stats=True`` (static, obs-enabled callers only) additionally
    returns the LB-cascade pruning telemetry dict of
    :func:`repro.core.lb_search.filtered_topk`; the default path compiles
    the exact pre-telemetry graph, so obs-off results stay bit-identical.
    """
    if euclidean:
        d2 = euclidean_sq(Q, data)
        dh = jnp.sqrt(jnp.maximum(d2, 0.0))
        dh = jnp.where(live[None, :], dh, jnp.inf)           # (Nq, cap)
        if q_valid is not None:
            dh = jnp.where(q_valid[:, None], dh, jnp.inf)
        neg, idx = jax.lax.top_k(-dh, k)
        out_ids = jnp.where(jnp.isfinite(neg), ids[idx], -1)
        if with_stats:
            # no elastic cascade under the PQ_ED baseline: report an empty
            # telemetry record rather than a fake 0% pruning rate
            zero = jnp.zeros((), jnp.int32)
            return -neg, out_ids, {"n_bounded": zero, "n_refined": zero,
                                   "n_waves": zero,
                                   "refined_per_wave": zero[None]}
        return -neg, out_ids
    d2, idx, st = filtered_topk(Q, data, window, k, valid=live,
                                measure=measure, q_valid=q_valid,
                                with_stats=with_stats, band=band)
    dh = jnp.sqrt(jnp.maximum(d2, 0.0))
    out_ids = jnp.where(idx >= 0, ids[jnp.maximum(idx, 0)], -1)
    if with_stats:
        return dh, out_ids, st
    return dh, out_ids


@functools.partial(jax.jit, static_argnames=("topk",))
def _merge_topk(parts_d: Tuple[jnp.ndarray, ...],
                parts_i: Tuple[jnp.ndarray, ...], *, topk: int):
    all_d = jnp.concatenate(parts_d, axis=1)
    all_i = jnp.concatenate(parts_i, axis=1)
    missing = topk - all_d.shape[1]
    if missing > 0:
        Nq = all_d.shape[0]
        all_d = jnp.concatenate(
            [all_d, jnp.full((Nq, missing), jnp.inf)], 1)
        all_i = jnp.concatenate(
            [all_i, jnp.full((Nq, missing), -1, all_i.dtype)], 1)
    neg, best = jax.lax.top_k(-all_d, topk)
    return -neg, jnp.take_along_axis(all_i, best, axis=1)


def search_impl(coarse: jnp.ndarray, cb: PQCodebook,
                segs: Tuple[SealedSegment, ...],
                hot: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]],
                Q: jnp.ndarray, *, icfg: IndexConfig, n_probe: int,
                topk: int, dim: int,
                two_level: Optional[TwoLevelCoarse] = None,
                q_valid: Optional[jnp.ndarray] = None,
                with_stats: bool = False):
    """Fan ``Q (Nq, D)`` out over every segment and merge top-k.

    ``segs`` is a (possibly empty) tuple of sealed segments; ``hot`` is
    ``(data (cap, D), ids (cap,), live (cap,))`` or None when the buffer is
    empty.  Returns ``(distances, ids)`` of shape ``(Nq, topk)``, distance
    ``inf`` / id ``-1`` where fewer than ``topk`` live rows exist.  Sealed
    rows are ranked by asymmetric PQDTW, hot rows by exact banded DTW —
    both in sqrt space, so the merge is order-compatible.

    ``two_level`` switches the coarse stage to the hierarchical quantizer
    with the config's ``n_probe_top`` fan-out; ``q_valid (Nq,)`` marks
    padding rows of a sharded query batch (results for masked rows are
    arbitrary — the caller slices them off — but they are excluded from
    LB-cascade refine work and pruning statistics).

    ``with_stats=True`` returns ``(distances, ids, stats)`` where
    ``stats`` is the hot-scan LB-cascade telemetry dict (device scalars;
    ``None`` when the hot buffer is empty) — the obs-enabled entry point
    (:meth:`StreamingIndex.search`) pulls it to host and feeds the
    registry.  The flag threads a *static* argument into the jitted hot
    scan, so the default path compiles the exact pre-telemetry graph.

    Pipeline stages run inside :func:`repro.obs.span` blocks (coarse, lut,
    fine, hot, merge) with device work fenced into its span when obs is
    enabled; disabled spans are shared no-ops — no fences, no syncs, no
    timing.  When this function is itself traced (the query-sharded
    planner's ``shard_map``), the spans time the trace — once per
    compilation — and the fences no-op on tracers.

    Deliberately NOT one enclosing jit: the pieces (coarse cdist, query
    LUTs, per-segment fine stage, hot scan, final merge) are jitted
    separately, so growing the segment count only recompiles the tiny
    concat/top-k merge instead of the whole search graph — no query-latency
    spike every time a flush adds a segment.
    """
    Q = jnp.asarray(Q, jnp.float32)
    parts_d, parts_i = [], []
    hot_stats = None

    spec = icfg.pq.measure()
    if segs:
        w = icfg.coarse_window(dim)
        with obs.span("index.search.coarse") as sp:
            dc = sp.fence(coarse_dists(
                Q, coarse, w, measure=spec, two_level=two_level,
                n_probe_top=icfg.n_probe_top if two_level is not None
                else None))                                  # (Nq, n_lists)
        with obs.span("index.search.lut") as sp:
            qluts = sp.fence(query_lut_batch(
                segment(Q, icfg.pq), cb, icfg.pq.window(dim),
                not icfg.pq.is_elastic, spec))                # (Nq, M, K)
        with obs.span("index.search.fine") as sp:
            for sg in segs:
                k = min(topk, n_probe * sg.max_list)
                if k < 1:
                    continue
                d, i = _rank_segment(sg.codes, sg.ids, sg.live,
                                     sg.list_start, sg.list_len, dc, qluts,
                                     max_list=sg.max_list, n_probe=n_probe,
                                     k=k)
                parts_d.append(d)
                parts_i.append(i)
            sp.fence(parts_d)

    if hot is not None:
        data, ids, live = hot
        with obs.span("index.search.hot") as sp:
            out = _scan_hot(data, ids, live, Q, q_valid,
                            window=icfg.coarse_window(dim),
                            k=min(topk, data.shape[0]),
                            euclidean=not icfg.pq.is_elastic,
                            measure=spec, with_stats=with_stats,
                            band=icfg.band)
            if with_stats:
                d, i, hot_stats = out
            else:
                d, i = out
            sp.fence((d, i))
        parts_d.append(d)
        parts_i.append(i)

    if not parts_d:
        Nq = Q.shape[0]
        empty = (jnp.full((Nq, topk), jnp.inf),
                 jnp.full((Nq, topk), -1, jnp.int32))
        return empty + (None,) if with_stats else empty

    with obs.span("index.search.merge") as sp:
        d, i = sp.fence(_merge_topk(tuple(parts_d), tuple(parts_i),
                                    topk=topk))
    if with_stats:
        return d, i, hot_stats
    return d, i


# ---------------------------------------------------------------------------
# The lifecycle object
# ---------------------------------------------------------------------------

class StreamingIndex:
    """Incrementally maintained IVF-PQDTW index (see module docstring).

    Construct with :meth:`bootstrap` (trains the shared quantizers on a
    sample) or :meth:`from_parts` (pre-trained quantizers / restore path).

    The full write/read lifecycle in one example (tiny shapes so it runs
    as a doctest):

    >>> import jax, numpy as np
    >>> from repro.core.pq import PQConfig
    >>> cfg = IndexConfig(
    ...     PQConfig(n_sub=2, codebook_size=4, use_prealign=False,
    ...              kmeans_iters=1, dba_iters=1),
    ...     n_lists=2, hot_capacity=4, coarse_iters=2)
    >>> X = np.sin(np.arange(12 * 16, dtype=np.float32)).reshape(12, 16)
    >>> idx = StreamingIndex.bootstrap(jax.random.PRNGKey(0), X, cfg)
    >>> ids = idx.insert(X[:6])            # fills hot_capacity=4 -> 1 seal
    >>> [int(i) for i in ids[:3]], len(idx.segments)
    ([0, 1, 2], 1)
    >>> idx.delete([1])                    # tombstone by external id
    1
    >>> dist, out = idx.search(X[:2], n_probe=2, topk=1)
    >>> out.shape                          # (Nq, topk) external ids
    (2, 1)
    >>> bool(np.isfinite(np.asarray(dist)).all())
    True
    >>> idx.flush(); idx.compact()         # seal the tail, drop dead rows
    >>> len(idx.segments), idx.n_live()
    (1, 5)
    """

    def __init__(self, cfg: IndexConfig, coarse: jnp.ndarray,
                 cb: PQCodebook, dim: int,
                 two_level: Optional[TwoLevelCoarse] = None):
        if coarse.shape[0] != cfg.n_lists:
            raise ValueError(
                f"coarse quantizer has {coarse.shape[0]} centroids, "
                f"config says n_lists={cfg.n_lists}")
        if cfg.hot_capacity < 1:
            raise ValueError(
                f"hot_capacity={cfg.hot_capacity} must be >= 1 (inserts "
                f"stage in the hot buffer before sealing)")
        # the prealign geometry (use_prealign/tail) must match the codebook:
        # every seal re-encodes through it, so a drifted config would write
        # segments of the wrong static length into immutable shards
        validate_codebook(cb, cfg.pq, int(dim))
        self.cfg = cfg
        self.coarse = jnp.asarray(coarse, jnp.float32)
        self.cb = cb
        self.dim = int(dim)
        # hierarchical coarse quantizer: derived deterministically from the
        # (frozen) coarse centroids when the config asks for one, unless a
        # pre-built table is handed in (the snapshot-restore path)
        if two_level is None and cfg.n_top_lists:
            two_level = build_two_level(
                jax.random.PRNGKey(0), self.coarse, cfg.n_top_lists,
                cfg.coarse_window(self.dim), measure=cfg.pq.measure(),
                iters=cfg.coarse_iters)
        self.two_level = two_level
        self.hot = HotBuffer(cfg.hot_capacity, dim)
        self.segments: List[SealedSegment] = []
        # host-side mirrors of each segment's id array (immutable) and live
        # mask (updated alongside tombstone()), so the delete/accounting
        # paths never download device arrays
        self._seg_ids: List[np.ndarray] = []
        self._seg_live: List[np.ndarray] = []
        # every id physically resident anywhere (tombstoned rows included —
        # they occupy slots until flush/compact drops them), for O(batch)
        # collision checks on explicit-id inserts
        self._resident: set = set()
        # device copy of the hot buffer, rebuilt only after a mutation
        self._hot_device: Optional[Tuple] = None
        self.next_id = 0

    # -- construction -------------------------------------------------------

    @classmethod
    def bootstrap(cls, key: jax.Array, X_train: np.ndarray,
                  cfg: IndexConfig) -> "StreamingIndex":
        """Train the shared coarse + PQ quantizers on ``X_train`` and return
        an *empty* index (the sample is not inserted)."""
        X_train = jnp.asarray(X_train, jnp.float32)
        D = X_train.shape[-1]
        kc, kf = jax.random.split(key)
        res = dba_kmeans(kc, X_train, cfg.n_lists, iters=cfg.coarse_iters,
                         dba_iters=1, window=cfg.coarse_window(D),
                         measure=cfg.pq.measure())
        cb = fit(kf, X_train, cfg.pq)
        return cls(cfg, res.centroids, cb, D)

    @classmethod
    def from_parts(cls, cfg: IndexConfig, coarse: jnp.ndarray,
                   cb: PQCodebook, dim: int,
                   two_level: Optional[TwoLevelCoarse] = None
                   ) -> "StreamingIndex":
        return cls(cfg, coarse, cb, dim, two_level=two_level)

    # -- write path ---------------------------------------------------------

    def insert(self, X: np.ndarray, ids: Optional[Sequence[int]] = None
               ) -> np.ndarray:
        """Add series ``X (n, D)``; returns their external ids.  Flushes
        automatically whenever the hot buffer fills."""
        X = np.asarray(X, np.float32)
        if X.ndim != 2 or X.shape[1] != self.dim:
            raise ValueError(
                f"expected (n, {self.dim}) series, got {X.shape}")
        n = X.shape[0]
        if ids is None:
            out = np.arange(self.next_id, self.next_id + n, dtype=np.int32)
            self.next_id += n
        else:
            out = np.asarray(ids, np.int32)
            if len(out) != n:
                raise ValueError(f"{n} series but {len(out)} ids")
            if n and int(out.min()) < 0:
                raise ValueError(
                    "external ids must be >= 0 (-1 is the reserved "
                    "empty-slot / no-result sentinel)")
            if len(np.unique(out)) != n:
                raise ValueError("duplicate ids within one insert batch")
            # one row per external id: reject ids still resident anywhere
            # (tombstoned rows occupy slots until flush/compact drops them)
            clash = self._resident.intersection(out.tolist())
            if clash:
                raise ValueError(
                    f"ids already resident in the index: "
                    f"{sorted(clash)[:8]}")
            self.next_id = max(self.next_id, int(out.max(initial=-1)) + 1)
        self._resident.update(out.tolist())
        self._hot_device = None
        with obs.span("index.insert"):
            i = 0
            while i < n:
                i += self.hot.append(X[i:], out[i:])
                if self.hot.space == 0:
                    self.flush()
        if obs.enabled():
            obs.counter("index_inserted_total", persistent=True).inc(n)
            self._update_obs_gauges()
        return out

    def delete(self, ids: Sequence[int]) -> int:
        """Tombstone by external id; returns how many rows were hit."""
        dead = np.asarray(ids, np.int32)
        hit = self.hot.tombstone(dead)
        if hit:
            self._hot_device = None
        for s, sg in enumerate(self.segments):
            mask = np.isin(self._seg_ids[s], dead) & self._seg_live[s]
            if mask.any():
                self.segments[s] = sg.tombstone(mask)
                self._seg_live[s] = self._seg_live[s] & ~mask
                hit += int(mask.sum())
        if obs.enabled():
            obs.counter("index_deleted_total", persistent=True).inc(hit)
            self._update_obs_gauges()
        return hit

    def flush(self) -> None:
        """Seal the hot buffer's live rows into a new sealed segment."""
        with obs.span("index.flush"):
            dropped = self.hot.ids[(self.hot.ids >= 0) & ~self.hot.live]
            rows, ids = self.hot.take_live()
            self._resident.difference_update(dropped.tolist())
            self._hot_device = None
            if len(ids) == 0:
                return
            Xj = jnp.asarray(rows)
            codes = np.asarray(encode(Xj, self.cb, self.cfg.pq))
            assign = np.asarray(coarse_assign(
                Xj, self.coarse, self.cfg.coarse_window(self.dim),
                self.cfg.pq.measure()))
            cap = self.cfg.hot_capacity
            # shard_round = ceil(cap / n_shards): every flush-born segment
            # gets the same shard_cap regardless of list skew, so they all
            # share one compiled fine-stage / planner shape
            self._add_segment(seal(codes, ids, assign, self.cfg.n_lists,
                                   rows=cap, max_list=cap,
                                   n_shards=self.cfg.n_shards,
                                   shard_round=-(-cap // self.cfg.n_shards)))
        if obs.enabled():
            obs.counter("index_sealed_rows_total",
                        persistent=True).inc(len(ids))
            self._update_obs_gauges()

    def compact(self) -> None:
        """Merge every sealed segment into one: tombstoned and padding rows
        are dropped, inverted lists re-balanced, and the fine stage's
        candidate width shrinks from the flush-time worst case (the full
        segment capacity) back to the true longest merged list."""
        if not self.segments:
            return
        with obs.span("index.compact"):
            codes, ids, assign = [], [], []
            for s, sg in enumerate(self.segments):
                live = self._seg_live[s]
                dead = self._seg_ids[s][~live]
                self._resident.difference_update(dead[dead >= 0].tolist())
                codes.append(np.asarray(sg.codes)[live])
                ids.append(self._seg_ids[s][live])
                assign.append(np.asarray(sg.assign)[live])
            codes = np.concatenate(codes)
            ids = np.concatenate(ids)
            assign = np.concatenate(assign)
            self.segments, self._seg_ids, self._seg_live = [], [], []
            if len(ids):
                self._add_segment(seal(codes, ids, assign, self.cfg.n_lists,
                                       rows=len(ids),
                                       n_shards=self.cfg.n_shards))
        if obs.enabled():
            obs.counter("index_compactions_total", persistent=True).inc()
            self._update_obs_gauges()

    # -- read path ----------------------------------------------------------

    def search(self, Q: np.ndarray, *, n_probe: int, topk: int = 1
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Top-``topk`` live neighbors of ``Q (Nq, D)`` -> (dist, ids).

        With obs enabled (:func:`repro.obs.enabled`) the search runs under
        stage spans and records LB-cascade pruning telemetry — the stats
        transfer is a deliberate device sync, which is why the disabled
        path never requests stats (``with_stats`` is static: the obs-off
        compiled graph, and therefore the results, are bit-identical to an
        uninstrumented build).
        """
        Q = self._validate(Q, n_probe, topk)
        if not obs.enabled():
            return search_impl(self.coarse, self.cb, tuple(self.segments),
                               self._hot_arrays(), Q,
                               icfg=self.cfg, n_probe=n_probe, topk=topk,
                               dim=self.dim, two_level=self.two_level)
        with obs.span("index.search") as sp:
            d, ids, hot_stats = search_impl(
                self.coarse, self.cb, tuple(self.segments),
                self._hot_arrays(), Q, icfg=self.cfg, n_probe=n_probe,
                topk=topk, dim=self.dim, two_level=self.two_level,
                with_stats=True)
            sp.fence((d, ids))
        self._record_search_obs(Q.shape[0], hot_stats)
        return d, ids

    def _record_search_obs(self, n_queries: int, hot_stats) -> None:
        """Feed one search's counters into the obs registry (obs on)."""
        obs.counter("index_searches_total", persistent=True).inc()
        obs.counter("index_queries_total",
                    persistent=True).inc(int(n_queries))
        if hot_stats is not None:
            bounded = int(hot_stats["n_bounded"])
            refined = int(hot_stats["n_refined"])
            if bounded:
                obs.counter("lb_candidates_bounded_total",
                            persistent=True).inc(bounded)
                obs.counter("lb_candidates_refined_total",
                            persistent=True).inc(refined)
                obs.counter("lb_candidates_pruned_total",
                            persistent=True).inc(bounded - refined)
                obs.counter("lb_refine_waves_total", persistent=True).inc(
                    int(hot_stats["n_waves"]))
                obs.histogram("lb_pruning_rate",
                              buckets=tuple(i / 10 for i in range(1, 11)),
                              persistent=True).record(
                    1.0 - refined / bounded)
        self._update_obs_gauges()

    def _update_obs_gauges(self) -> None:
        """Refresh the lifecycle gauges (host-side mirrors only — no
        device transfers)."""
        cap = self.cfg.hot_capacity
        obs.gauge("hot_fill", persistent=True).set(self.hot.count)
        obs.gauge("hot_occupancy", persistent=True).set(
            self.hot.count / cap)
        obs.gauge("n_segments", persistent=True).set(self.n_segments)
        sealed_resident = sum(int((ids >= 0).sum())
                              for ids in self._seg_ids)
        sealed_live = sum(int(live.sum()) for live in self._seg_live)
        resident = sealed_resident + self.hot.count
        live = sealed_live + self.hot.n_live()
        obs.gauge("sealed_rows", persistent=True).set(sealed_resident)
        obs.gauge("tombstone_fraction", persistent=True).set(
            (resident - live) / resident if resident else 0.0)

    def _validate(self, Q, n_probe: int, topk: int) -> jnp.ndarray:
        Q = jnp.asarray(Q, jnp.float32)
        if Q.ndim != 2 or Q.shape[1] != self.dim:
            raise ValueError(
                f"expected (n, {self.dim}) queries, got {Q.shape}")
        validate_n_probe(n_probe, self.cfg.n_lists)
        if topk < 1:
            raise ValueError(f"topk={topk} must be >= 1")
        return Q

    def _add_segment(self, seg: SealedSegment,
                     host_ids: Optional[np.ndarray] = None,
                     host_live: Optional[np.ndarray] = None) -> None:
        self.segments.append(seg)
        self._seg_ids.append(np.asarray(seg.ids) if host_ids is None
                             else np.asarray(host_ids))
        self._seg_live.append(np.asarray(seg.live) if host_live is None
                              else np.asarray(host_live))
        ids = self._seg_ids[-1]
        self._resident.update(ids[ids >= 0].tolist())

    def _hot_arrays(self):
        if self.hot.count == 0:
            return None
        if self._hot_device is None:      # invalidated on any hot mutation
            self._hot_device = (jnp.asarray(self.hot.data),
                                jnp.asarray(self.hot.ids),
                                jnp.asarray(self.hot.live))
        return self._hot_device

    # -- accounting ---------------------------------------------------------

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    def n_live(self) -> int:
        return self.hot.n_live() + sum(
            int(live.sum()) for live in self._seg_live)

    def live_ids(self) -> np.ndarray:
        out = [self.hot.ids[self.hot.live]]
        out += [ids[live] for ids, live in zip(self._seg_ids,
                                               self._seg_live)]
        return np.sort(np.concatenate(out))

    def memory_cost(self) -> dict:
        """§3.4 accounting extended with the lifecycle-layer overheads."""
        rows = sum(sg.rows for sg in self.segments)
        return memory_cost(self.cfg.pq, self.dim, rows,
                           n_segments=self.n_segments,
                           n_lists=self.cfg.n_lists,
                           hot_capacity=self.cfg.hot_capacity,
                           n_devices=self.cfg.n_shards)

    def stats(self) -> dict:
        return dict(n_segments=self.n_segments, n_live=self.n_live(),
                    hot_fill=self.hot.count, next_id=self.next_id,
                    sealed_rows=sum(sg.rows for sg in self.segments),
                    max_lists=[sg.max_list for sg in self.segments])
