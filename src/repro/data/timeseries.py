"""Synthetic time-series generators.

The UCR archive is not available offline, so benchmarks/tests use
class-structured surrogates with the same statistical character:

* ``random_walks``   — the paper's Fig. 5 scaling workload.
* ``cbf``            — Cylinder-Bell-Funnel, the classic 3-class shape task
                       with random onset/duration (warping matters).
* ``trace_like``     — smooth sine/step morphologies with phase jitter,
                       mimicking the Trace dataset used in Fig. 3.
* ``gun_point_like`` — two classes differing in a localized bump.

All generators are deterministic given a seed and return float32
``(N, D)`` arrays plus integer labels where applicable.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["random_walks", "cbf", "trace_like", "gun_point_like",
           "znorm", "make_dataset"]


def znorm(X: np.ndarray) -> np.ndarray:
    mu = X.mean(-1, keepdims=True)
    sd = X.std(-1, keepdims=True)
    return ((X - mu) / np.maximum(sd, 1e-9)).astype(np.float32)


def random_walks(n: int, length: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    steps = rng.standard_normal((n, length)).astype(np.float32)
    return znorm(np.cumsum(steps, axis=1))


def cbf(n_per_class: int, length: int = 128, seed: int = 0
        ) -> Tuple[np.ndarray, np.ndarray]:
    """Cylinder-Bell-Funnel (Saito 1994). Classes: 0=cyl, 1=bell, 2=funnel."""
    rng = np.random.default_rng(seed)
    n = 3 * n_per_class
    X = np.zeros((n, length), np.float32)
    y = np.repeat(np.arange(3), n_per_class)
    t = np.arange(length)
    for i in range(n):
        a = rng.integers(length // 8, length // 2)
        b = a + rng.integers(length // 4, length // 2)
        b = min(b, length - 1)
        eta = rng.normal(6.0, 1.0)
        eps = rng.standard_normal(length)
        mask = ((t >= a) & (t <= b)).astype(np.float32)
        if y[i] == 0:          # cylinder: plateau
            shape = mask
        elif y[i] == 1:        # bell: ramp up
            shape = mask * (t - a) / max(b - a, 1)
        else:                  # funnel: ramp down
            shape = mask * (b - t) / max(b - a, 1)
        X[i] = eta * shape + eps
    return znorm(X), y


def trace_like(n_per_class: int, length: int = 256, seed: int = 0
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Smooth morphologies with phase jitter: 4 classes mixing a sine carrier
    with/without a mid-series step and a sharp gaussian peak."""
    rng = np.random.default_rng(seed)
    n = 4 * n_per_class
    X = np.zeros((n, length), np.float32)
    y = np.repeat(np.arange(4), n_per_class)
    t = np.linspace(0, 1, length)
    for i in range(n):
        phase = rng.uniform(-0.1, 0.1)
        noise = 0.05 * rng.standard_normal(length)
        sig = np.sin(2 * np.pi * (2 * t + phase))
        if y[i] % 2 == 1:      # add step
            loc = 0.5 + rng.uniform(-0.05, 0.05)
            sig = sig + 1.5 * (t > loc)
        if y[i] >= 2:          # add sharp peak
            loc = 0.25 + rng.uniform(-0.05, 0.05)
            sig = sig + 2.0 * np.exp(-((t - loc) ** 2) / (2 * 0.01 ** 2))
        X[i] = sig + noise
    return znorm(X), y


def gun_point_like(n_per_class: int, length: int = 150, seed: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    n = 2 * n_per_class
    X = np.zeros((n, length), np.float32)
    y = np.repeat(np.arange(2), n_per_class)
    t = np.linspace(0, 1, length)
    for i in range(n):
        rise = 0.3 + rng.uniform(-0.05, 0.05)
        fall = 0.7 + rng.uniform(-0.05, 0.05)
        plateau = 1.0 / (1 + np.exp(-40 * (t - rise))) * \
            (1 - 1.0 / (1 + np.exp(-40 * (t - fall))))
        if y[i] == 1:          # overshoot dip ("gun" draw artifact)
            plateau = plateau + 0.4 * np.exp(
                -((t - rise) ** 2) / (2 * 0.015 ** 2))
        X[i] = plateau + 0.03 * rng.standard_normal(length)
    return znorm(X), y


_GENS = {"cbf": cbf, "trace": trace_like, "gunpoint": gun_point_like}


def make_dataset(name: str, n_per_class: int, length: int, seed: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray]:
    if name == "cbf":
        return cbf(n_per_class, length, seed)
    if name == "trace":
        return trace_like(n_per_class, length, seed)
    if name == "gunpoint":
        return gun_point_like(n_per_class, length, seed)
    raise KeyError(f"unknown dataset {name!r}; options: {sorted(_GENS)}")
