"""Mini routing gate consuming exactly one dump format."""

import json
import sys

EXPECTED_OPS = {"goodk", "goodk_adaptive"}


def ledger_from_snapshot(dump):
    return dump.get("counters", {})


def main():
    dump = json.load(open(sys.argv[1]))
    ledger = ledger_from_snapshot(dump)
    return 0 if all(ledger.get(op) for op in EXPECTED_OPS) else 1


if __name__ == "__main__":
    sys.exit(main())
