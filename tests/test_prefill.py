"""Batched prefill == token-sequential prefill, for every supported family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.models.lm import init_params
from repro.serve.cache import init_cache
from repro.serve.decode import serve_step
from repro.serve.prefill import prefill

pytestmark = pytest.mark.slow    # whole-model prefill/decode: tier-2

ARCHS = ["qwen2-72b", "gemma2-27b", "qwen3-moe-30b-a3b", "qwen2-vl-72b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_batched_prefill_matches_sequential(arch):
    cfg = get_reduced(arch)
    B, S, Smax = 2, 12, 16
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    # tokens-only even for vlm: the sequential reference has no patch path
    # (patches are a prefill-only input; smoke-tested separately below)
    batch = {"tokens": toks}

    # sequential reference
    cache_seq = init_cache(cfg, B, Smax)
    logits_seq = None
    for p in range(S):
        logits_seq, cache_seq = serve_step(params, cfg, cache_seq,
                                           toks[:, p:p + 1], p)

    cache_bat = init_cache(cfg, B, Smax)
    logits_bat, cache_bat = prefill(params, cfg, cache_bat, batch,
                                    q_chunk=4)

    # last-position logits agree (bf16 compute tolerance)
    a = np.asarray(logits_bat, np.float32)
    b = np.asarray(logits_seq, np.float32)
    np.testing.assert_allclose(a, b, rtol=5e-2, atol=5e-2)

    # KV caches agree on the filled region
    for name in ("k", "v"):
        ca = np.asarray(cache_bat[name][:, :, :S], np.float32)
        cb = np.asarray(cache_seq[name][:, :, :S], np.float32)
        np.testing.assert_allclose(ca, cb, rtol=5e-2, atol=5e-2)


def test_vlm_prefill_with_patches_smoke():
    cfg = get_reduced("qwen2-vl-72b")
    B, S = 2, max(8, cfg.n_frontend_tokens + 2)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "patches": jax.random.normal(
                 key, (B, cfg.n_frontend_tokens, cfg.d_model))}
    cache = init_cache(cfg, B, S + 4)
    logits, cache = prefill(params, cfg, cache, batch, q_chunk=4)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_prefill_then_decode_continues():
    """prefill(prompt) -> serve_step(next) == all-sequential decode."""
    cfg = get_reduced("qwen2-72b")
    B, S, Smax = 2, 8, 12
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)

    cache_seq = init_cache(cfg, B, Smax)
    for p in range(S):
        _, cache_seq = serve_step(params, cfg, cache_seq, toks[:, p:p + 1], p)
    ref, _ = serve_step(params, cfg, cache_seq, toks[:, S:S + 1], S)

    cache_bat = init_cache(cfg, B, Smax)
    _, cache_bat = prefill(params, cfg, cache_bat, {"tokens": toks[:, :S]})
    got, _ = serve_step(params, cfg, cache_bat, toks[:, S:S + 1], S)

    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)
