"""Lower bounds must never exceed true (squared, banded) DTW."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.dtw import dtw_pair
from repro.core.lb import keogh_envelope, lb_keogh, lb_kim, lb_cascade


def test_envelope_contains_series():
    x = np.random.default_rng(0).standard_normal(64).astype(np.float32)
    up, lo = keogh_envelope(x, window=5)
    assert np.all(np.asarray(up) >= x - 1e-6)
    assert np.all(np.asarray(lo) <= x + 1e-6)


def test_envelope_batched():
    X = np.random.default_rng(1).standard_normal((7, 32)).astype(np.float32)
    up, lo = keogh_envelope(X, window=3)
    assert up.shape == X.shape and lo.shape == X.shape
    u0, l0 = keogh_envelope(X[0], window=3)
    assert np.allclose(np.asarray(up[0]), np.asarray(u0))


def test_envelope_window_zero_is_identity():
    x = np.random.default_rng(2).standard_normal(16).astype(np.float32)
    up, lo = keogh_envelope(x, window=0)
    assert np.allclose(np.asarray(up), x) and np.allclose(np.asarray(lo), x)


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 40), st.integers(1, 8), st.integers(0, 10_000))
def test_lb_keogh_is_lower_bound(L, w, seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal(L).astype(np.float32)
    c = rng.standard_normal(L).astype(np.float32)
    w = min(w, L - 1)
    up, lo = keogh_envelope(c, window=w)
    bound = float(lb_keogh(jnp.asarray(q), up, lo))
    true = float(dtw_pair(q, c, window=w))
    assert bound <= true + 1e-4


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 40), st.integers(0, 10_000))
def test_lb_kim_is_lower_bound(L, seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal(L).astype(np.float32)
    c = rng.standard_normal(L).astype(np.float32)
    assert float(lb_kim(q, c)) <= float(dtw_pair(q, c)) + 1e-4


def test_cascade_le_banded_dtw():
    rng = np.random.default_rng(7)
    q = rng.standard_normal(32).astype(np.float32)
    C = rng.standard_normal((16, 32)).astype(np.float32)
    w = 4
    up, lo = keogh_envelope(C, window=w)
    bounds = np.asarray(lb_cascade(jnp.asarray(q), C, up, lo))
    for k in range(16):
        assert bounds[k] <= float(dtw_pair(q, C[k], window=w)) + 1e-4
